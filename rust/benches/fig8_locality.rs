//! Bench: regenerate Figure 8 (temporal locality / result reuse).

use eci::harness::{fig8, Scale};

fn main() {
    let scale = Scale::from_env();
    let t0 = std::time::Instant::now();
    let f = fig8::run(scale);
    println!("{}", fig8::render(&f).to_markdown());
    eprintln!("fig8 done in {:?} (scale {scale:?})", t0.elapsed());
}
