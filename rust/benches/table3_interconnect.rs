//! Bench: regenerate Table 3 (inter-socket throughput & latency,
//! Enzian+ECI vs native 2-socket). Custom harness (criterion is not
//! available in the offline registry).

use eci::harness::{table3, Scale};

fn main() {
    let scale = Scale::from_env();
    let t0 = std::time::Instant::now();
    let t = table3::run(scale);
    println!("{}", table3::render(&t).to_markdown());
    println!("{}", table3::render_sliced(&table3::run_sliced(scale)).to_markdown());
    println!("paper:    ECI 12.8 GiB/s / 320 ns   native 19 GiB/s / 150 ns");
    println!(
        "measured: ECI {:.1} GiB/s / {:.0} ns   native {:.1} GiB/s / {:.0} ns   (host {:?}, scale {scale:?})",
        t.eci.throughput_gib, t.eci.latency_ns, t.native.throughput_gib, t.native.latency_ns,
        t0.elapsed()
    );
}
