//! Bench: replay bandwidth vs retransmission discipline (go-back-N vs
//! selective repeat vs selective repeat + adaptive RTO) on the reliable
//! lossy link. Custom harness (criterion is not available in the
//! offline registry).

use eci::harness::{fig_retx, Scale};

fn main() {
    let scale = Scale::from_env();
    let t0 = std::time::Instant::now();
    let f = fig_retx::run(scale);
    println!("{}", fig_retx::render(&f).to_markdown());
    let worst_ber = f.points.iter().map(|p| p.ber).fold(0.0f64, f64::max);
    let cell = |v| f.point(v, fig_retx::SLICE_SWEEP[0], worst_ber).expect("cell swept");
    let gbn = cell(fig_retx::VARIANTS[0]);
    let sr = cell(fig_retx::VARIANTS[1]);
    let arto = cell(fig_retx::VARIANTS[2]);
    println!(
        "replay B/B at ber {:.0e}: gbn {:.4} -> sr {:.4} -> sr+adaptive-rto {:.4} (rto {} ns)   (host {:?}, scale {scale:?})",
        worst_ber,
        gbn.replay_overhead,
        sr.replay_overhead,
        arto.replay_overhead,
        arto.rto_ns,
        t0.elapsed()
    );
}
