//! Bench: live reconfiguration — p99 dip depth and duration per
//! scripted transition (ctrl subsystem). Custom harness (criterion is
//! not available in the offline registry).

use eci::harness::{fig_reconfig, Scale};

fn main() {
    let scale = Scale::from_env();
    let t0 = std::time::Instant::now();
    let f = fig_reconfig::run(scale);
    println!("{}", fig_reconfig::render(&f).to_markdown());
    let executed = f.points.iter().filter(|p| !p.skipped).count();
    let worst = f
        .points
        .iter()
        .filter_map(|p| p.dip.as_ref())
        .max_by(|a, b| a.depth_pct.total_cmp(&b.depth_pct));
    match worst {
        Some(d) => println!(
            "{executed}/{} transitions executed; worst p99 dip {:.0}% for {:.1}us   (host {:?}, scale {scale:?})",
            f.points.len(),
            d.depth_pct,
            d.dip_us,
            t0.elapsed()
        ),
        None => println!(
            "{executed}/{} transitions executed   (host {:?}, scale {scale:?})",
            f.points.len(),
            t0.elapsed()
        ),
    }
}
