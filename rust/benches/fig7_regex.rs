//! Bench: regenerate Figure 7 (regex pushdown vs CPU regex).

use eci::harness::{fig7, Scale};
use eci::runtime::Runtime;

fn main() {
    let scale = Scale::from_env();
    let mut rt = Runtime::load_default().expect("artifacts (run `make artifacts`)");
    let t0 = std::time::Instant::now();
    let f = fig7::run(&mut rt, scale).expect("fig7");
    println!("{}", fig7::render(&f).to_markdown());
    eprintln!("fig7 done in {:?} (scale {scale:?})", t0.elapsed());
}
