//! Bench: open-loop latency vs offered load (workload subsystem) — the
//! saturation knee per directory slice count under the multi-tenant
//! scenario, with credit-accurate link admission. Custom harness
//! (criterion is not available in the offline registry).

use eci::harness::{fig_loadcurve, Scale};

fn main() {
    let scale = Scale::from_env();
    let t0 = std::time::Instant::now();
    let f = fig_loadcurve::run(scale);
    println!("{}", fig_loadcurve::render(&f).to_markdown());
    println!("{}", fig_loadcurve::render_knees(&f).to_markdown());
    let first = f.curves.first().expect("sweep is non-empty");
    let best = f
        .curves
        .iter()
        .max_by(|a, b| a.knee_per_s.total_cmp(&b.knee_per_s))
        .expect("sweep is non-empty");
    let growth = if first.knee_per_s > 0.0 { best.knee_per_s / first.knee_per_s } else { 0.0 };
    println!(
        "knee: {} slice(s) {:.1}M ops/s -> {} slices {:.1}M ops/s ({growth:.2}x)   (host {:?}, scale {scale:?})",
        first.slices,
        first.knee_per_s / 1e6,
        best.slices,
        best.knee_per_s / 1e6,
        t0.elapsed()
    );
}
