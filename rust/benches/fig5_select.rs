//! Bench: regenerate Figure 5 (SELECT pushdown vs CPU scan).

use eci::harness::{fig5, Scale};
use eci::runtime::Runtime;

fn main() {
    let scale = Scale::from_env();
    let mut rt = Runtime::load_default().expect("artifacts (run `make artifacts`)");
    let t0 = std::time::Instant::now();
    let f = fig5::run(&mut rt, scale).expect("fig5");
    println!("{}", fig5::render(&f).to_markdown());
    eprintln!("fig5 done in {:?} (scale {scale:?})", t0.elapsed());
}
