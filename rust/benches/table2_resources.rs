//! Bench: regenerate Table 2 (FPGA resource consumption) plus the
//! protocol-subsetting area ablation.

use eci::harness::table2;

fn main() {
    for t in table2::render() {
        println!("{}", t.to_markdown());
    }
}
