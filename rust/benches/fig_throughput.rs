//! Bench: directory-throughput scaling of the sharded directory
//! controller (dcs) — sustained coherence ops/s and tail latency vs
//! slice count under the closed-loop mixed workload. Custom harness
//! (criterion is not available in the offline registry).

use eci::harness::{fig_throughput, Scale};

fn main() {
    let scale = Scale::from_env();
    let t0 = std::time::Instant::now();
    let f = fig_throughput::run(scale);
    println!("{}", fig_throughput::render(&f).to_markdown());
    let first = f.points.first().expect("sweep is non-empty");
    let best = f
        .points
        .iter()
        .max_by(|a, b| a.ops_per_s.total_cmp(&b.ops_per_s))
        .expect("sweep is non-empty");
    println!(
        "scaling: {} slice(s) {:.1}M ops/s -> {} slices {:.1}M ops/s ({:.2}x)   (host {:?}, scale {scale:?})",
        first.slices,
        first.ops_per_s / 1e6,
        best.slices,
        best.ops_per_s / 1e6,
        best.ops_per_s / first.ops_per_s,
        t0.elapsed()
    );
}
