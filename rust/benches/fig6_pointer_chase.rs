//! Bench: regenerate Figure 6 (KVS pointer chasing — the negative result).

use eci::harness::{fig6, Scale};
use eci::runtime::Runtime;

fn main() {
    let scale = Scale::from_env();
    let mut rt = Runtime::load_default().expect("artifacts (run `make artifacts`)");
    let t0 = std::time::Instant::now();
    let f = fig6::run(&mut rt, scale).expect("fig6");
    println!("{}", fig6::render(&f).to_markdown());
    eprintln!("fig6 done in {:?} (scale {scale:?})", t0.elapsed());
}
