//! Bench: fabric scale-out — aggregate goodput and tail latency vs
//! node count with home migration on/off (fabric subsystem). Custom
//! harness (criterion is not available in the offline registry).

use eci::harness::{fig_fabric, Scale};

fn main() {
    let scale = Scale::from_env();
    let t0 = std::time::Instant::now();
    let f = fig_fabric::run(scale);
    println!("{}", fig_fabric::render(&f).to_markdown());
    let pick = |nodes: usize, migrate: bool| {
        f.points.iter().find(|p| p.nodes == nodes && p.migrate == migrate)
    };
    let one = pick(1, false).expect("1-node row");
    let best = f
        .points
        .iter()
        .filter(|p| !p.migrate)
        .max_by(|a, b| a.delivered_per_s.total_cmp(&b.delivered_per_s))
        .expect("sweep is non-empty");
    let scaling = if one.delivered_per_s > 0.0 {
        best.delivered_per_s / one.delivered_per_s
    } else {
        0.0
    };
    let migrated: u64 = f.points.iter().filter(|p| p.migrate).map(|p| p.migrations).sum();
    println!(
        "goodput: 1 node {:.1}M ops/s -> {} nodes {:.1}M ops/s ({scaling:.2}x); \
         {migrated} migrations across migrate-on rows   (host {:?}, scale {scale:?})",
        one.delivered_per_s / 1e6,
        best.nodes,
        best.delivered_per_s / 1e6,
        t0.elapsed()
    );
}
