//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. credit budget vs interconnect throughput (the Table 3 calibration
//!    knob, swept),
//! 2. the hidden-O (MOESI concession, §3.3 transition 10) policy vs RAM
//!    writeback traffic,
//! 3. frame-error rate vs delivered throughput (go-back-N cost curve),
//! 4. odd/even VC parity split vs a single request VC (the paper's
//!    "simpler load-balancing" claim, quantified).

use eci::agents::dram::MemStore;
use eci::agents::home::HomeAgent;
use eci::machine::{map, Machine, MachineConfig, Workload};
use eci::proto::messages::{CohOp, LineAddr, Message, ReqId};
use eci::proto::spec::{generate_home, HomePolicy};
use eci::proto::states::Node;
use eci::proto::transitions::reference_transitions;

fn stream_gibps(mut cfg: MachineConfig, lines: u64, threads: usize) -> f64 {
    let fpga = MemStore::new(map::TABLE_BASE, ((lines as usize) + 1024) * 128);
    let cpu = MemStore::new(LineAddr(0), 1 << 20);
    cfg.seed = 7;
    let mut m = Machine::memory_node(cfg, fpga, cpu);
    m.set_workload(Workload::StreamRemote { lines }, threads);
    m.run().remote_gib_per_s()
}

fn main() {
    println!("== ablation 1: credits per VC vs remote-stream throughput (48 threads) ==");
    println!("credits  GiB/s");
    for credits in [2u32, 4, 6, 9, 12, 16, 24, 32] {
        let mut cfg = MachineConfig::enzian_eci();
        cfg.link.credits_per_vc = credits;
        println!("{credits:>7}  {:.2}", stream_gibps(cfg, 200_000, 48));
    }

    println!("\n== ablation 2: hidden-O policy vs RAM writes (shared-dirty traffic) ==");
    // home repeatedly dirties a set of lines; remote repeatedly reads them
    // (transition 10 either forwards dirty (hidden O) or writes back first)
    for hidden_o in [true, false] {
        let policy = HomePolicy { hidden_o, cache_writebacks: true, ..HomePolicy::default() };
        let mut home = HomeAgent::new(
            generate_home(&reference_transitions(), policy),
            policy,
            Some(eci::agents::cache::Cache::new(64 * 1024, 4)),
        );
        let mut ram = MemStore::new(LineAddr(0), 1 << 20);
        let mut ram_writes = 0u64;
        for round in 0..200u32 {
            for line in 0..16u64 {
                let a = LineAddr(line);
                // home-side app dirties the line
                let _ = home.local_access(a, true, round as u64, &mut ram);
                // remote reads it (ReadShared of a home-dirty line)
                let fx = home.on_message(
                    Message::coh_req(ReqId(round * 16 + line as u32), Node::Remote, CohOp::ReadShared, a),
                    &mut ram,
                );
                for e in &fx {
                    if matches!(e, eci::agents::home::HomeEffect::RamWrite { .. }) {
                        ram_writes += 1;
                    }
                }
                // remote drops it again so the home can re-dirty
                let _ = home.on_message(
                    Message::coh_req(ReqId(1 << 20 | (round * 16 + line as u32)), Node::Remote, CohOp::VolDowngradeI, a),
                    &mut ram,
                );
            }
        }
        println!(
            "hidden_o={hidden_o:<5}  RAM writes on the share path: {ram_writes:>5}  (3200 shared-dirty reads)"
        );
    }

    println!("\n== ablation 3: frame error rate vs delivered throughput ==");
    println!("err-rate  GiB/s");
    // (rates above 5% make go-back-N replay storms dominate: the window
    // re-sends ~16 frames per loss and losses hit retransmissions too, so
    // the event count grows superlinearly — capped here)
    for rate in [0.0, 0.001, 0.01, 0.05] {
        let mut cfg = MachineConfig::enzian_eci();
        cfg.link.phys.frame_error_rate = rate;
        let lines = if rate >= 0.05 { 20_000 } else { 100_000 };
        println!("{rate:>8}  {:.2}", stream_gibps(cfg, lines, 48));
    }

    println!("\n== ablation 4: odd/even parity split utility ==");
    // The split banks the receiver buffers: two request VCs of depth 9
    // give a mixed-parity stream 18 outstanding line requests, where a
    // split-less design with ONE request VC of the same BRAM depth would
    // allow only 9 (~= credits 5 per VC here, within one credit).
    let split = stream_gibps(MachineConfig::enzian_eci(), 200_000, 48);
    let mut single = MachineConfig::enzian_eci();
    single.link.credits_per_vc = 5; // 10 outstanding ~ one 9-deep VC + slack
    let unsplit = stream_gibps(single, 200_000, 48);
    println!("split (2 x 9-deep request VCs)     : {split:.2} GiB/s");
    println!("unsplit-equivalent (~9 outstanding): {unsplit:.2} GiB/s");
    println!("(the paper's §4.2 odd/even split doubles the outstanding-request budget at the same per-VC BRAM depth)");
}
