//! Microbenchmarks of the hot paths themselves (host-side performance —
//! the L3 optimization targets of DESIGN.md §Perf):
//!
//! * DES event throughput (events/s of the machine's inner loop)
//! * transport layer frame rate
//! * PJRT operator batch latency (select/regex/hash)
//! * spec-generated rule-map construction rate

use std::time::Instant;

use eci::agents::dram::MemStore;
use eci::machine::{map, Machine, MachineConfig, Workload};
use eci::proto::messages::{CohOp, LineAddr, Message, ReqId};
use eci::proto::states::Node;
use eci::runtime::{Runtime, BATCH, ROW_WORDS};
use eci::sim::rng::Rng;
use eci::transport::{LinkConfig, LinkDir};

fn bench<F: FnMut() -> u64>(name: &str, mut f: F) {
    // warmup
    f();
    let t0 = Instant::now();
    let mut units = 0u64;
    let mut iters = 0u32;
    while t0.elapsed().as_secs_f64() < 1.0 {
        units += f();
        iters += 1;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{name:<40} {:>12.0} units/s   ({iters} iters, {dt:.2}s)",
        units as f64 / dt
    );
}

fn main() {
    println!("== eci microbench ==");

    bench("DES: remote stream events/s", || {
        let cfg = MachineConfig::test_small();
        let fpga = MemStore::new(map::TABLE_BASE, 4 << 20);
        let cpu = MemStore::new(LineAddr(0), 1 << 20);
        let mut m = Machine::memory_node(cfg, fpga, cpu);
        m.set_workload(Workload::StreamRemote { lines: 20_000 }, 4);
        let r = m.run();
        r.events
    });

    bench("transport: frames/s (loopback)", || {
        let mut dir = LinkDir::new(LinkConfig::eci(), Node::Remote, Rng::new(1));
        let n = 50_000u32;
        let mut delivered = 0u64;
        let mut now = eci::sim::time::Time(0);
        let mut del = Vec::new();
        let mut ctls = Vec::new();
        for i in 0..n {
            dir.send(Message::coh_req(ReqId(i), Node::Remote, CohOp::ReadShared, LineAddr(i as u64)));
            if let Some((arr, frame)) = dir.try_launch(now) {
                now = arr;
                dir.receive(frame, &mut del, &mut ctls);
                for f in del.drain(..) {
                    delivered += 1;
                    dir.credit_return(f.vc);
                }
                ctls.clear();
            }
        }
        delivered
    });

    if let Ok(mut rt) = Runtime::load_default() {
        let rows = vec![0.5f32; BATCH * ROW_WORDS];
        bench("PJRT: select rows/s", || {
            let (_m, _c) = rt.select(&rows, 0.3, 0.7).unwrap();
            BATCH as u64
        });
        let keys = vec![7i32; BATCH];
        bench("PJRT: hash keys/s", || {
            let _ = rt.hash(&keys, 1023).unwrap();
            BATCH as u64
        });
        let dfa = eci::operators::redfa::compile_regex("erro+r", 32).unwrap();
        let tmat = dfa.onehot_tmat(32);
        let acc = dfa.accept_vec(32);
        let chars = vec![b'x' as i32; BATCH * eci::runtime::STR_LEN];
        bench("PJRT: regex strings/s", || {
            let _ = rt.regex(&chars, &tmat, &acc).unwrap();
            BATCH as u64
        });
    } else {
        eprintln!("(artifacts not built; skipping PJRT benches)");
    }

    bench("redfa: compiles/s", || {
        let mut n = 0;
        for p in ["abc", "a(b|c)+d", "[a-z]+[0-9]?x", "err(o|0)+r"] {
            let _ = eci::operators::redfa::compile_regex(p, 32).unwrap();
            n += 1;
        }
        n
    });

    bench("spec: rule-map generations/s", || {
        let spec = eci::proto::transitions::reference_transitions();
        let _ = eci::proto::spec::generate_home(&spec, Default::default());
        let _ = eci::proto::spec::generate_remote(&spec);
        2
    });
}
