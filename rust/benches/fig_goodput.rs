//! Bench: goodput and tail latency vs bit-error rate on the reliable
//! lossy link (per-VC go-back-N replay beneath the sliced directory).
//! Custom harness (criterion is not available in the offline registry).

use eci::harness::{fig_goodput, Scale};

fn main() {
    let scale = Scale::from_env();
    let t0 = std::time::Instant::now();
    let f = fig_goodput::run(scale);
    println!("{}", fig_goodput::render(&f).to_markdown());
    let clean = f
        .points
        .iter()
        .find(|p| p.ber == 0.0)
        .expect("sweep carries the clean baseline");
    let worst = f
        .points
        .iter()
        .filter(|p| p.slices == clean.slices && !p.home_cached)
        .max_by(|a, b| a.ber.total_cmp(&b.ber))
        .expect("sweep is non-empty");
    println!(
        "goodput: ber 0 {:.2}M ops/s -> ber {:.0e} {:.2}M ops/s (frame goodput {:.3}, {} retx)   (host {:?}, scale {scale:?})",
        clean.delivered_per_s / 1e6,
        worst.ber,
        worst.delivered_per_s / 1e6,
        worst.frame_goodput,
        worst.retransmitted,
        t0.elapsed()
    );
}
