//! Cross-VC reordering races, property-tested directly against the
//! spec-generated agents: the ECI VCs guarantee no ordering *between*
//! channels (§4.2), so responses can overtake home-initiated downgrades
//! and voluntary downgrades can trail the requests that follow them.
//! These are exactly the transient-state cases §3.2 licenses; the agents
//! must stay coherent and every transaction must complete under any legal
//! interleaving.

use eci::agents::cache::Cache;
use eci::agents::dram::MemStore;
use eci::agents::home::{HomeAgent, HomeEffect};
use eci::agents::remote::{RemoteAgent, RemoteEffect};
use eci::proto::messages::{LineAddr, Message, MsgKind};
use eci::proto::spec::{generate_home, generate_remote, HomePolicy};
use eci::proto::states::{CacheState, Node};
use eci::proto::transitions::reference_transitions;
use eci::ptest::{Gen, Prop};
use eci::transport::vc::{class_of, VcClass};

/// A transport that preserves order *within* a VC class but may deliver
/// across classes in any order (the legal reordering envelope).
struct RacyLink {
    /// queues per class, per direction (0 = to home, 1 = to remote)
    q: [[Vec<Message>; 5]; 2],
}

fn class_idx(m: &Message) -> usize {
    match class_of(m) {
        VcClass::Req => 0,
        VcClass::Fwd => 1,
        VcClass::RspNoData => 2,
        VcClass::RspData => 3,
        VcClass::WbData => 4,
        _ => 0,
    }
}

impl RacyLink {
    fn new() -> RacyLink {
        RacyLink { q: Default::default() }
    }
    fn push(&mut self, to_home: bool, m: Message) {
        self.q[!to_home as usize][class_idx(&m)].push(m);
    }
    fn pending(&self) -> bool {
        self.q.iter().flatten().any(|v| !v.is_empty())
    }
    /// Pop one message from a randomly-chosen non-empty class queue
    /// (FIFO within the class).
    fn pop_random(&mut self, g: &mut Gen) -> Option<(bool, Message)> {
        let mut options = Vec::new();
        for dir in 0..2 {
            for c in 0..5 {
                if !self.q[dir][c].is_empty() {
                    options.push((dir, c));
                }
            }
        }
        if options.is_empty() {
            return None;
        }
        let &(dir, c) = g.choose(&options);
        Some((dir == 0, self.q[dir][c].remove(0)))
    }
}

#[derive(Clone, Debug)]
enum Act {
    Read(u8),
    Write(u8),
    Evict(u8),
    Recall(u8),
    /// deliver one queued message (random class)
    Pump,
}

#[test]
fn shrunk_case_debug() {
    use Act::*;
    let acts = vec![Read(0), Read(1), Pump, Pump, Write(2), Pump, Pump, Recall(2), Pump, Pump, Evict(2), Write(2), Pump, Pump, Pump, Pump, Pump, Read(2)];
    assert!(run_case(&acts), "shrunk counterexample must pass");
}

#[test]
fn coherence_survives_cross_vc_reordering() {
    Prop::new("cross-VC reordering races")
        .cases(120)
        .max_size(160)
        .check_vec(
            |g| match g.below(6) {
                0 => Act::Read(g.below(3) as u8),
                1 => Act::Write(g.below(3) as u8),
                2 => Act::Evict(g.below(3) as u8),
                3 => Act::Recall(g.below(3) as u8),
                _ => Act::Pump,
            },
            |acts| run_case(acts),
        );
}

fn run_case(acts: &[Act]) -> bool {
    let spec = reference_transitions();
    let mut remote = RemoteAgent::new(Node::Remote, generate_remote(&spec), LineAddr(0), 1 << 20);
    let mut cache = Cache::new(16 * 1024, 4);
    let mut home = HomeAgent::new(
        generate_home(&spec, HomePolicy::default()),
        HomePolicy::default(),
        None,
    );
    let mut ram = MemStore::new(LineAddr(0), 64 * 128);
    let mut link = RacyLink::new();
    let mut g = Gen { rng: eci::sim::rng::Rng::new(0xACE), size: 4 };

    let mut route_remote = |fx: Vec<RemoteEffect>, link: &mut RacyLink| {
        for e in fx {
            if let RemoteEffect::Send(m) = e {
                link.push(true, m);
            }
        }
    };
    let route_home = |fx: Vec<HomeEffect>, link: &mut RacyLink| {
        for e in fx {
            match e {
                HomeEffect::Respond { msg, .. } | HomeEffect::Fwd { msg } => link.push(false, msg),
                _ => {}
            }
        }
    };

    let mut pump_one = |link: &mut RacyLink,
                        g: &mut Gen,
                        remote: &mut RemoteAgent,
                        cache: &mut Cache,
                        home: &mut HomeAgent,
                        ram: &mut MemStore| {
        if let Some((to_home, m)) = link.pop_random(g) {
            if to_home {
                route_home(home.on_message(m, ram), link);
            } else {
                let fx = remote.on_message(m, cache);
                for e in fx {
                    if let RemoteEffect::Send(m2) = e {
                        link.push(true, m2);
                    }
                }
            }
        }
    };

    for act in acts {
        match act {
            Act::Read(a) => {
                let (_, fx) = remote.local_access(LineAddr(*a as u64), false, &mut cache);
                route_remote(fx, &mut link);
            }
            Act::Write(a) => {
                let (_, fx) = remote.local_access(LineAddr(*a as u64), true, &mut cache);
                route_remote(fx, &mut link);
            }
            Act::Evict(a) => {
                let fx = remote.evict(LineAddr(*a as u64), &mut cache);
                route_remote(fx, &mut link);
            }
            Act::Recall(a) => {
                route_home(home.recall(LineAddr(*a as u64), &mut ram), &mut link);
            }
            Act::Pump => {
                pump_one(&mut link, &mut g, &mut remote, &mut cache, &mut home, &mut ram);
            }
        }
    }
    // drain to quiescence (random order until empty)
    let mut guard = 0;
    while link.pending() {
        pump_one(&mut link, &mut g, &mut remote, &mut cache, &mut home, &mut ram);
        guard += 1;
        if guard > 100_000 {
            if std::env::var("ECI_RACE_DEBUG").is_ok() { eprintln!("FAIL: livelock"); }
            return false; // livelock
        }
    }
    let verbose = std::env::var("ECI_RACE_DEBUG").is_ok();
    // all transactions completed
    if remote.outstanding_count() != 0 {
        if verbose {
            eprintln!("FAIL: {} outstanding", remote.outstanding_count());
            for line in 0..3u64 {
                let a = LineAddr(line);
                eprintln!("  line {a}: remote {:?} home {:?} possession {}", cache.state_of(a), home.state_of(a), home.possession_count(a));
            }
        }
        return false;
    }
    // joint coherence at quiescence
    for line in 0..3u64 {
        let a = LineAddr(line);
        let r = cache.state_of(a);
        let h = home.state_of(a);
        if h.pending_fwd.is_some() {
            if verbose { eprintln!("FAIL: line {a} home pending {:?}", h.pending_fwd); }
            return false; // must have settled
        }
        use eci::proto::spec::RemoteView;
        let ok = match r {
            CacheState::I => true, // view may over-estimate, never under
            CacheState::S => h.view != RemoteView::I,
            CacheState::E | CacheState::M => h.view == RemoteView::EorM && h.own == CacheState::I,
        };
        if !ok {
            if verbose { eprintln!("FAIL: line {a} remote {r:?} vs home {h:?}"); }
            return false;
        }
    }
    true
}

/// Focused deterministic replays of the three named races in
/// `proto::spec`'s documentation.
#[test]
fn named_races_deterministic() {
    let spec = reference_transitions();
    // --- fwd overtakes fill ------------------------------------------
    let mut remote = RemoteAgent::new(Node::Remote, generate_remote(&spec), LineAddr(0), 1 << 20);
    let mut cache = Cache::new(16 * 1024, 4);
    let a = LineAddr(1);
    let (_, fx) = remote.local_access(a, false, &mut cache);
    let req = fx
        .iter()
        .find_map(|e| match e {
            RemoteEffect::Send(m) => Some(m.clone()),
            _ => None,
        })
        .unwrap();
    // home's fwd arrives BEFORE the fill: answered immediately, clean
    let fwd = Message::coh_req(eci::proto::messages::ReqId(99), Node::Home, eci::proto::messages::CohOp::FwdDowngradeI, a);
    let fx = remote.on_message(fwd, &mut cache);
    let responded = fx.iter().any(|e| matches!(e,
        RemoteEffect::Send(m) if matches!(m.kind, MsgKind::CohRsp { op: eci::proto::messages::CohOp::FwdDowngradeI, dirty: false, .. })));
    assert!(responded, "{fx:?}");
    // fill arrives; it is use-once: core served, line not retained
    let rsp = Message::coh_rsp(req.id, Node::Home, eci::proto::messages::CohOp::ReadShared, a, false, Some(Box::new([1; 128])));
    let fx = remote.on_message(rsp, &mut cache);
    assert!(fx.iter().any(|e| matches!(e, RemoteEffect::Filled { .. })));
    assert_eq!(cache.state_of(a), CacheState::I);
}
