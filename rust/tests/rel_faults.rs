//! Loss-transparency gates for the reliable lossy-link transport
//! (`transport::rel`): sequenced per-VC replay beneath the full machine
//! must make drops, bit errors, and reordering invisible to every
//! protocol observable — fill payloads, writeback bytes, final backing
//! store — on the monolithic memory node AND the sliced cached
//! directory. Loss changes timing, never semantics.

use eci::agents::dram::MemStore;
use eci::machine::{map, Machine, MachineConfig, Op, Workload};
use eci::proto::messages::{Line, LineAddr, LINE_BYTES};
use eci::transport::rel::{FaultConfig, FaultSpec, RelConfig, RelMode, RTO_FLOOR};
use eci::transport::NUM_VCS;
use eci::workload::{self, OpenLoopConfig, Scenario};

/// The standard lossy wire of this suite: bit errors sized to corrupt a
/// noticeable fraction of data frames, plus whole-frame drops and
/// reordering.
fn faulty_rel(seed: u64) -> RelConfig {
    let spec = FaultSpec { ber: 1e-3, drop: 0.02, reorder: 0.02, burst_len: 1.0 };
    RelConfig::new(FaultConfig::new(spec, seed))
}

fn machine_with(config: Option<usize>, rel: Option<RelConfig>) -> Machine {
    let mut cfg = MachineConfig::test_small();
    cfg.rel = rel;
    let mut fpga = MemStore::new(map::TABLE_BASE, 1 << 20);
    for i in 0..2048u64 {
        let mut l = [0u8; LINE_BYTES];
        l[0..8].copy_from_slice(&(i.wrapping_mul(0x9E37_79B9)).to_le_bytes());
        fpga.write_line(LineAddr(map::TABLE_BASE.0 + i), &l);
    }
    let cpu = MemStore::new(LineAddr(0), 1 << 20);
    match config {
        None => Machine::memory_node(cfg, fpga, cpu),
        Some(n) => Machine::dcs_cached_node(cfg, n, fpga, cpu),
    }
}

fn a(i: u64) -> LineAddr {
    LineAddr(map::TABLE_BASE.0 + i)
}

fn fpga_mem_snapshot(m: &Machine, lines: u64) -> Vec<Line> {
    (0..lines).map(|i| m.fpga_mem.read_line(a(i))).collect()
}

/// Stream a region with fault injection on vs off, on the memory node
/// and the sliced cached directory: the fill payloads delivered to
/// cores and the settled FPGA memory must be bit-identical.
#[test]
fn stream_observables_identical_with_faults_on_and_off() {
    for config in [None, Some(1), Some(2), Some(4)] {
        let run = |rel: Option<RelConfig>| {
            let mut m = machine_with(config, rel);
            let sums = std::rc::Rc::new(std::cell::RefCell::new(
                std::collections::BTreeMap::<u64, u64>::new(),
            ));
            {
                let sums2 = std::rc::Rc::clone(&sums);
                m.verify_fill = Some(Box::new(move |addr, data| {
                    let v = u64::from_le_bytes(data[0..8].try_into().unwrap());
                    *sums2.borrow_mut().entry(addr.0).or_insert(0) += v;
                }));
            }
            m.set_workload(Workload::StreamRemote { lines: 600 }, 4);
            let r = m.run();
            assert_eq!(r.remote_bytes, 600 * 128, "every line must stream intact");
            m.drain();
            let retx = m.report().counters.get("rel_retransmitted");
            let fills = sums.borrow().clone();
            (fills, fpga_mem_snapshot(&m, 2048), retx)
        };
        let (fills_clean, mem_clean, _) = run(None);
        let (fills_lossy, mem_lossy, retx) = run(Some(faulty_rel(7)));
        assert!(retx > 0, "config {config:?}: the lossy run must have exercised replay");
        assert_eq!(
            fills_lossy, fills_clean,
            "config {config:?}: fill payloads must be loss-invariant"
        );
        assert_eq!(
            mem_lossy, mem_clean,
            "config {config:?}: settled FPGA memory must be loss-invariant"
        );
    }
}

/// A dirty writeback crossing a lossy wire (store, conflict-evict,
/// settle) must land its exact bytes in the home's backing store.
#[test]
fn dirty_writeback_survives_loss() {
    for config in [None, Some(2)] {
        let mut m = machine_with(config, Some(faulty_rel(11)));
        let target = a(0);
        // the test LLC is 256 KiB 16-way = 128 sets; stride-128 lines
        // conflict and 20 fills overflow the 16 ways
        let mut prog = vec![Op::Store(target, 0xFEED_F00D)];
        for k in 1..=20u64 {
            prog.push(Op::Load(a(k * 128)));
        }
        m.set_workload(Workload::Script { programs: vec![prog] }, 1);
        m.run();
        m.drain();
        let line = m.fpga_mem.read_line(target);
        assert_eq!(
            u64::from_le_bytes(line[0..8].try_into().unwrap()),
            0xFEED_F00D,
            "config {config:?}: the writeback must survive the lossy wire"
        );
    }
}

/// Replay costs latency, never correctness: dependent chases on the
/// lossy wire complete with the right data, and the loss shows up in
/// the latency tail.
#[test]
fn rel_replay_costs_latency_not_correctness() {
    let lat = |rel: Option<RelConfig>| {
        let mut m = machine_with(None, rel);
        m.set_workload(Workload::ChaseRemote { count: 1_200, region_lines: 2048 }, 1);
        let r = m.run();
        (r.load_lat.mean() / 1e3, r.load_lat.p99() as f64 / 1e3)
    };
    let (clean_mean, clean_p99) = lat(None);
    let (lossy_mean, lossy_p99) = lat(Some(faulty_rel(3)));
    assert!(
        lossy_p99 > clean_p99 * 1.2,
        "replays must show in the tail: p99 {lossy_p99} vs clean {clean_p99}"
    );
    assert!(lossy_mean >= clean_mean * 0.98, "mean {lossy_mean} vs clean {clean_mean}");
}

/// The lossy machine is bit-reproducible: one seed drives the traffic,
/// the wire, and the fault stream.
#[test]
fn lossy_machine_is_deterministic_for_seed() {
    let run = || {
        let mut m = machine_with(Some(2), Some(faulty_rel(23)));
        m.set_workload(Workload::StreamRemote { lines: 400 }, 3);
        let r = m.run();
        m.drain();
        let rep = m.report();
        (
            r.sim_time,
            r.events,
            r.remote_bytes,
            rep.counters.get("rel_retransmitted"),
            rep.counters.get("rel_injected_drops"),
        )
    };
    assert_eq!(run(), run(), "lossy runs must replay bit-identically");
}

/// Open-loop overload on a faulted link: in-flight frames stay inside
/// the credit budget (a replay must not double-consume), every arrival
/// completes, and the settled end state matches the clean link's.
#[test]
fn faulted_openloop_overload_stays_credit_bounded() {
    let sc = Scenario::preset("scan", 1 << 10, 0.99).expect("preset");
    let mk = |rel: Option<RelConfig>| {
        let mut cfg = OpenLoopConfig { rate_per_s: 40e6, ops: 1_000, ..Default::default() };
        cfg.machine.rel = rel;
        workload::run(cfg, &sc, 1)
    };
    let clean = mk(None);
    let lossy = mk(Some(faulty_rel(13)));
    assert_eq!(clean.completed, 1_000);
    assert_eq!(lossy.completed, 1_000, "faulted overload must still drain");
    let budget =
        OpenLoopConfig::default().machine.link.credits_per_vc * NUM_VCS as u32;
    assert!(lossy.peak_in_flight > 0);
    assert!(
        lossy.peak_in_flight <= budget,
        "faulted in-flight {} exceeds credit budget {budget}",
        lossy.peak_in_flight
    );
    assert!(lossy.counters.get("rel_retransmitted") > 0, "{:?}", lossy.counters);
    // replays burn bandwidth, so the faulted link saturates no higher
    assert!(lossy.delivered_per_s <= clean.delivered_per_s * 1.02);
}

/// The retransmission discipline is an ablation, not a semantic knob:
/// go-back-N, selective repeat, and selective repeat with the adaptive
/// RTO all settle the open loop into the exact state of the clean
/// (rel-less) stack — while SR demonstrably replays less.
#[test]
fn gbn_and_sr_reach_identical_settled_state() {
    let sc = Scenario::preset("scan", 1 << 10, 0.99).expect("preset");
    let run = |rel: Option<RelConfig>| {
        let mut cfg = OpenLoopConfig { rate_per_s: 2e6, ops: 600, ..Default::default() };
        cfg.machine.rel = rel;
        eci::workload::OpenLoop::new(cfg, &sc, 2).run_settled()
    };
    let lossy = faulty_rel(7);
    let (r_plain, d_plain) = run(None);
    let (r_gbn, d_gbn) = run(Some(lossy));
    let (r_sr, d_sr) = run(Some(lossy.with_mode(RelMode::SelectiveRepeat)));
    let (r_arto, d_arto) =
        run(Some(lossy.with_mode(RelMode::SelectiveRepeat).with_adaptive_rto(true)));
    for r in [&r_plain, &r_gbn, &r_sr, &r_arto] {
        assert_eq!(r.completed, 600, "every discipline must drain the open loop");
    }
    assert!(r_gbn.counters.get("rel_retransmitted") > 0, "{:?}", r_gbn.counters);
    assert!(r_sr.counters.get("rel_sacks") > 0, "SR must have sacked: {:?}", r_sr.counters);
    assert_eq!(d_gbn, d_plain, "go-back-N must be invisible to the end state");
    assert_eq!(d_sr, d_plain, "selective repeat must be invisible to the end state");
    assert_eq!(d_arto, d_plain, "the adaptive RTO must be invisible to the end state");
    // the ablation's point, visible even at this scale: same wire, same
    // traffic, fewer replayed bytes
    assert!(
        r_sr.counters.get("rel_retransmitted_bytes")
            < r_gbn.counters.get("rel_retransmitted_bytes"),
        "sr {} vs gbn {} replayed bytes",
        r_sr.counters.get("rel_retransmitted_bytes"),
        r_gbn.counters.get("rel_retransmitted_bytes")
    );
}

/// Machine-path equivalence: streaming observables (fill payloads and
/// settled FPGA memory) are identical across retransmission modes on
/// the sliced cached directory under loss.
#[test]
fn stream_observables_identical_across_retransmission_modes() {
    let run = |rel: Option<RelConfig>| {
        let mut m = machine_with(Some(2), rel);
        let sums = std::rc::Rc::new(std::cell::RefCell::new(
            std::collections::BTreeMap::<u64, u64>::new(),
        ));
        {
            let sums2 = std::rc::Rc::clone(&sums);
            m.verify_fill = Some(Box::new(move |addr, data| {
                let v = u64::from_le_bytes(data[0..8].try_into().unwrap());
                *sums2.borrow_mut().entry(addr.0).or_insert(0) += v;
            }));
        }
        m.set_workload(Workload::StreamRemote { lines: 600 }, 4);
        let r = m.run();
        assert_eq!(r.remote_bytes, 600 * 128, "every line must stream intact");
        m.drain();
        let retx = m.report().counters.get("rel_retransmitted");
        (sums.borrow().clone(), fpga_mem_snapshot(&m, 2048), retx)
    };
    let (fills_clean, mem_clean, _) = run(None);
    let lossy = faulty_rel(7);
    for rel in [
        lossy,
        lossy.with_mode(RelMode::SelectiveRepeat),
        lossy.with_mode(RelMode::SelectiveRepeat).with_adaptive_rto(true),
    ] {
        let label = format!("{:?} adaptive={}", rel.mode, rel.adaptive_rto);
        let (fills, mem, retx) = run(Some(rel));
        assert!(retx > 0, "{label}: the lossy run must have exercised replay");
        assert_eq!(fills, fills_clean, "{label}: fill payloads must be mode-invariant");
        assert_eq!(mem, mem_clean, "{label}: settled FPGA memory must be mode-invariant");
    }
}

/// The adaptive RTO's safety property: on a clean link the timer never
/// fires — the effective RTO converges but is clamped at the floor,
/// which sits above the worst clean-link ack delay.
#[test]
fn adaptive_rto_never_fires_below_the_floor_on_a_clean_link() {
    let sc = Scenario::preset("scan", 1 << 10, 0.99).expect("preset");
    for mode in [RelMode::GoBackN, RelMode::SelectiveRepeat] {
        let mut cfg = OpenLoopConfig { rate_per_s: 4e6, ops: 2_000, ..Default::default() };
        cfg.machine.rel =
            Some(RelConfig::from_ber(0.0, 7).with_mode(mode).with_adaptive_rto(true));
        let r = workload::run(cfg, &sc, 2);
        assert_eq!(r.completed, 2_000);
        assert!(
            r.counters.get("rel_rtt_samples") > 0,
            "{mode:?}: the estimator must have sampled: {:?}",
            r.counters
        );
        assert_eq!(
            r.counters.get("rel_timeouts"),
            0,
            "{mode:?}: a clean link must never time out: {:?}",
            r.counters
        );
        assert_eq!(r.counters.get("rel_retransmitted"), 0, "{mode:?}");
        let rto_ns = r.counters.get("rel_rto_ns");
        assert!(
            rto_ns as f64 >= RTO_FLOOR.as_ns(),
            "{mode:?}: effective RTO {rto_ns} ns must respect the {} ns floor",
            RTO_FLOOR.as_ns()
        );
        assert!(
            (rto_ns as f64) < 2_000.0,
            "{mode:?}: the measured RTO should undercut the fixed 2 µs timer, got {rto_ns} ns"
        );
    }
}

/// Burst errors (clustered losses) are just as transparent as
/// independent ones — the settled open-loop digest is identical.
#[test]
fn burst_errors_are_transparent_to_the_settled_state() {
    let sc = Scenario::preset("scan", 1 << 10, 0.99).expect("preset");
    let run = |rel: Option<RelConfig>| {
        let mut cfg = OpenLoopConfig { rate_per_s: 2e6, ops: 500, ..Default::default() };
        cfg.machine.rel = rel;
        eci::workload::OpenLoop::new(cfg, &sc, 2).run_settled()
    };
    let (r_clean, d_clean) = run(None);
    let spec = FaultSpec { ber: 5e-4, drop: 0.02, reorder: 0.0, burst_len: 8.0 };
    let (r_burst, d_burst) = run(Some(RelConfig::new(FaultConfig::new(spec, 29))));
    assert_eq!(r_clean.completed, 500);
    assert_eq!(r_burst.completed, 500);
    assert!(r_burst.counters.get("rel_retransmitted") > 0, "{:?}", r_burst.counters);
    assert_eq!(d_burst, d_clean, "burst loss must be invisible to the end state");
}
