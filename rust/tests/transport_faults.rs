//! Failure injection: the transaction layer's CRC + go-back-N replay must
//! make the full machine correct (not just the transport unit tests) —
//! every workload completes with intact data even when the physical layer
//! corrupts frames.

use eci::agents::dram::MemStore;
use eci::machine::{map, Machine, MachineConfig, Workload};
use eci::proto::messages::{LineAddr, LINE_BYTES};

fn machine_with_errors(rate: f64) -> Machine {
    let mut cfg = MachineConfig::test_small();
    cfg.link.phys.frame_error_rate = rate;
    let mut fpga = MemStore::new(map::TABLE_BASE, 1 << 20);
    for i in 0..2048u64 {
        let mut l = [0u8; LINE_BYTES];
        l[0..8].copy_from_slice(&(i.wrapping_mul(0x9E37_79B9)).to_le_bytes());
        fpga.write_line(LineAddr(map::TABLE_BASE.0 + i), &l);
    }
    let cpu = MemStore::new(LineAddr(0), 1 << 20);
    Machine::memory_node(cfg, fpga, cpu)
}

#[test]
fn lossy_link_still_delivers_every_line_intact() {
    let mut m = machine_with_errors(0.02);
    let bad = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
    {
        let bad = std::sync::Arc::clone(&bad);
        m.verify_fill = Some(Box::new(move |addr, data| {
            let i = addr.0 - map::TABLE_BASE.0;
            let got = u64::from_le_bytes(data[0..8].try_into().unwrap());
            if got != i.wrapping_mul(0x9E37_79B9) {
                bad.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }
        }));
    }
    m.set_workload(Workload::StreamRemote { lines: 2048 }, 4);
    let r = m.run();
    assert_eq!(bad.load(std::sync::atomic::Ordering::Relaxed), 0, "corrupted payload delivered");
    assert_eq!(r.remote_bytes, 2048 * 128);
}

#[test]
fn replay_costs_latency_but_not_correctness() {
    let lat = |rate: f64| {
        let mut m = machine_with_errors(rate);
        m.set_workload(Workload::ChaseRemote { count: 1_500, region_lines: 2048 }, 1);
        let r = m.run();
        (r.load_lat.mean() / 1e3, r.load_lat.p99() as f64 / 1e3)
    };
    let (clean_mean, clean_p99) = lat(0.0);
    let (lossy_mean, lossy_p99) = lat(0.05);
    // replays show up in the tail (and usually the mean)
    assert!(lossy_p99 > clean_p99 * 1.2, "p99 {lossy_p99} vs clean {clean_p99}");
    assert!(lossy_mean >= clean_mean * 0.98, "mean {lossy_mean} vs clean {clean_mean}");
}

#[test]
fn heavy_loss_converges_eventually() {
    // 20% frame loss is absurd, but the protocol must still terminate
    // with correct data (go-back-N + nack suppression + credit recycling).
    let mut m = machine_with_errors(0.20);
    m.set_workload(Workload::StreamRemote { lines: 300 }, 2);
    let r = m.run();
    assert_eq!(r.remote_bytes, 300 * 128);
}
