//! Transparency gates for the observability layer (`eci::obs`): span
//! tracing and the telemetry ticker are *passive* — they own no RNG,
//! schedule no events, and only read simulation state. Runs with
//! observability on and off must therefore produce bit-identical
//! settled digests and identical observables, on the monolithic memory
//! node, the sliced cached directory (1/2/4 slices), and the faulted
//! selective-repeat transport. Observability changes nothing but what
//! you can see.

use eci::agents::dram::MemStore;
use eci::fabric::{Fabric, FabricConfig, KillSpec};
use eci::machine::{map, Machine, MachineConfig, Workload};
use eci::obs::{ObsConfig, STAGE_NAMES};
use eci::proto::messages::{Line, LineAddr, LINE_BYTES};
use eci::sim::time::Duration;
use eci::trace::checker::{builtin, NfaSpec, OnlineChecker};
use eci::transport::rel::{FaultConfig, FaultSpec, RelConfig, RelMode};
use eci::workload::{OpenLoop, OpenLoopConfig, Scenario};

/// The faulted selective-repeat wire of this suite (same profile as the
/// loss-transparency tests).
fn faulted_sr(seed: u64) -> RelConfig {
    let spec = FaultSpec { ber: 1e-3, drop: 0.02, reorder: 0.02, burst_len: 1.0 };
    RelConfig::new(FaultConfig::new(spec, seed))
        .with_mode(RelMode::SelectiveRepeat)
        .with_adaptive_rto(true)
}

fn machine_with(config: Option<usize>, rel: Option<RelConfig>) -> Machine {
    let mut cfg = MachineConfig::test_small();
    cfg.rel = rel;
    let mut fpga = MemStore::new(map::TABLE_BASE, 1 << 20);
    for i in 0..2048u64 {
        let mut l = [0u8; LINE_BYTES];
        l[0..8].copy_from_slice(&(i.wrapping_mul(0x9E37_79B9)).to_le_bytes());
        fpga.write_line(LineAddr(map::TABLE_BASE.0 + i), &l);
    }
    let cpu = MemStore::new(LineAddr(0), 1 << 20);
    match config {
        None => Machine::memory_node(cfg, fpga, cpu),
        Some(n) => Machine::dcs_cached_node(cfg, n, fpga, cpu),
    }
}

fn fpga_mem_snapshot(m: &Machine, lines: u64) -> Vec<Line> {
    (0..lines).map(|i| m.fpga_mem.read_line(LineAddr(map::TABLE_BASE.0 + i))).collect()
}

/// Everything a machine run exposes, flattened for equality.
type MachineObservables = (u64, u64, u64, String, Vec<(String, u64)>, Vec<Line>);

fn machine_observables(config: Option<usize>, rel: Option<RelConfig>, obs: bool) -> MachineObservables {
    let mut m = machine_with(config, rel);
    if obs {
        let mut ocfg = ObsConfig::with_tick(Duration::from_us(1));
        ocfg.spans = true; // ignored by the machine host, must stay harmless
        m.attach_obs(&ocfg);
    }
    m.set_workload(Workload::StreamRemote { lines: 600 }, 4);
    let r = m.run();
    m.drain();
    if obs {
        let report = m.finish_obs();
        assert!(!report.jsonl.is_empty(), "the ticker must have snapshotted");
    }
    let rep = m.report();
    let lat = format!(
        "{:.6}/{}/{}",
        r.load_lat.mean(),
        r.load_lat.p50(),
        r.load_lat.p99()
    );
    let counters: Vec<(String, u64)> =
        rep.counters.iter().map(|(k, v)| (k.to_string(), v)).collect();
    (r.sim_time.0, r.events, r.remote_bytes, lat, counters, fpga_mem_snapshot(&m, 2048))
}

/// The machine-host gate: the telemetry ticker is invisible to every
/// observable — simulated time, event count, streamed bytes, latency
/// distribution, counters, settled memory — on the memory node, the
/// cached directory at 1/2/4 slices, and the faulted-SR transport.
#[test]
fn machine_ticker_is_transparent() {
    let shapes: [(Option<usize>, Option<RelConfig>); 6] = [
        (None, None),
        (Some(1), None),
        (Some(2), None),
        (Some(4), None),
        (None, Some(faulted_sr(7))),
        (Some(2), Some(faulted_sr(7))),
    ];
    for (config, rel) in shapes {
        let off = machine_observables(config, rel, false);
        let on = machine_observables(config, rel, true);
        assert_eq!(on, off, "config {config:?} rel {}: obs must be passive", rel.is_some());
    }
}

/// Open-loop observables, flattened for equality. `events` is the
/// strictest check: a single extra scheduled event would show here.
type OpenLoopObservables = (u64, u64, u64, u64, String, u32, Vec<(String, u64)>);

fn openloop_observables(
    cfg: OpenLoopConfig,
    scenario: &Scenario,
    slices: usize,
    obs: bool,
) -> (OpenLoopObservables, u64) {
    let (r, digest) = if obs {
        let ocfg = ObsConfig {
            spans: true,
            span_sample_every: 2,
            tick: Some(Duration::from_us(5)),
            ..ObsConfig::default()
        };
        let (r, digest, report) =
            OpenLoop::new(cfg, scenario, slices).with_obs(&ocfg).run_settled_observed();
        let w = report.waterfall.expect("spans were on");
        assert_eq!(w.rows.len(), STAGE_NAMES.len());
        assert!(w.completed > 0, "sampled spans must have completed");
        assert!(!report.jsonl.is_empty(), "the ticker must have snapshotted");
        (r, digest)
    } else {
        let (r, digest) = OpenLoop::new(cfg, scenario, slices).run_settled();
        (r, digest)
    };
    let lat = format!("{:.6}/{}/{}", r.lat.mean(), r.lat.p50(), r.lat.p99());
    let counters: Vec<(String, u64)> =
        r.counters.iter().map(|(k, v)| (k.to_string(), v)).collect();
    ((r.completed, r.sim_time.0, r.events, r.credit_stalls, lat, r.peak_in_flight, counters), digest)
}

/// The workload-host gate: spans + ticker on vs off settle the open
/// loop into the identical digest with identical observables, on the
/// cached directory across 1/2/4 slices.
#[test]
fn openloop_spans_and_ticker_are_transparent_on_cached_slices() {
    let sc = Scenario::preset("scan", 1 << 10, 0.99).expect("preset");
    for slices in [1, 2, 4] {
        let cfg = || OpenLoopConfig { ops: 600, home_cached: true, ..Default::default() };
        let (obs_off, d_off) = openloop_observables(cfg(), &sc, slices, false);
        let (obs_on, d_on) = openloop_observables(cfg(), &sc, slices, true);
        assert_eq!(d_on, d_off, "{slices} slices: settled digests must match");
        assert_eq!(obs_on, obs_off, "{slices} slices: observables must match");
    }
}

/// Same gate on the faulted selective-repeat transport: observability
/// must not perturb the fault stream, the replay schedule, or anything
/// they feed.
#[test]
fn openloop_obs_is_transparent_under_faulted_sr() {
    let sc = Scenario::preset("scan", 1 << 10, 0.99).expect("preset");
    let cfg = || {
        let mut c = OpenLoopConfig { rate_per_s: 2e6, ops: 600, ..Default::default() };
        c.machine.rel = Some(faulted_sr(7));
        c
    };
    let (obs_off, d_off) = openloop_observables(cfg(), &sc, 2, false);
    let (obs_on, d_on) = openloop_observables(cfg(), &sc, 2, true);
    assert!(
        obs_off.6.iter().any(|(k, v)| k == "rel_retransmitted" && *v > 0),
        "the faulted run must have exercised replay: {:?}",
        obs_off.6
    );
    assert_eq!(d_on, d_off, "faulted-SR settled digests must match");
    assert_eq!(obs_on, obs_off, "faulted-SR observables must match");
}

/// Fabric observables, flattened for equality. As for the open loop,
/// `events` is the strictest check: one extra scheduled event shows.
type FabricObservables = (u64, u64, u64, String, Vec<(String, u64)>);

fn fabric_observables(cfg: FabricConfig, sc: &Scenario, obs: bool) -> (FabricObservables, u64) {
    let (r, digest) = if obs {
        // every obs surface at once: spans (with the fabric's derived
        // per-node sampling phases), the ticker, and the flight recorder
        let ocfg = ObsConfig {
            spans: true,
            span_sample_every: 2,
            tick: Some(Duration::from_us(5)),
            flight: Some(128),
            ..ObsConfig::default()
        };
        let (r, digest, report) = Fabric::new(cfg, sc).with_obs(&ocfg).run_settled_observed();
        let w = report.waterfall.expect("spans were on");
        assert!(w.completed + w.remote_completed > 0, "sampled spans must have completed");
        if cfg.nodes > 1 {
            assert!(w.remote_completed > 0, "multi-node runs must trace remote fills");
        }
        assert!(!report.jsonl.is_empty(), "the ticker must have snapshotted");
        assert!(!report.flight_dumps.is_empty(), "the end-of-run dump is always present");
        if cfg.kill.is_some() {
            assert!(
                report.flight_dumps.iter().any(|(t, _)| t == "declare_dead"),
                "a declared death must dump the flight recorder"
            );
        }
        (r, digest)
    } else {
        Fabric::new(cfg, sc).run_settled()
    };
    let lat = format!("{:.6}/{}/{}", r.lat.mean(), r.lat.p50(), r.lat.p99());
    let counters: Vec<(String, u64)> =
        r.counters.iter().map(|(k, v)| (k.to_string(), v)).collect();
    ((r.completed, r.sim_time.0, r.events, lat, counters), digest)
}

/// The fabric-host gate: spans + ticker + flight recorder attached to a
/// 2- and 3-node fabric leave the settled digest and every observable
/// identical.
#[test]
fn fabric_obs_is_transparent_on_two_and_three_nodes() {
    let sc = Scenario::preset("uniform", 1 << 10, 0.99).expect("preset");
    for nodes in [2u8, 3] {
        let cfg = FabricConfig {
            nodes,
            ol: OpenLoopConfig { rate_per_s: 4e6, ops: 600, ..Default::default() },
            ..Default::default()
        };
        let (off, d_off) = fabric_observables(cfg, &sc, false);
        let (on, d_on) = fabric_observables(cfg, &sc, true);
        assert_eq!(d_on, d_off, "{nodes} nodes: settled digests must match");
        assert_eq!(on, off, "{nodes} nodes: observables must match");
    }
}

/// Same gate through a whole-node failure: the kill, the barren-channel
/// detection, the declaration (which snapshots the flight recorder),
/// re-homing, and replay must all be invisible to the run's outcome.
#[test]
fn fabric_obs_is_transparent_under_a_kill() {
    let sc = Scenario::preset("uniform", 1 << 9, 0.99).expect("preset");
    let cfg = FabricConfig {
        nodes: 3,
        kill: Some(KillSpec { node: 1, at: Duration::from_us(20) }),
        ol: OpenLoopConfig { rate_per_s: 4e6, ops: 900, ..Default::default() },
        ..Default::default()
    };
    let (off, d_off) = fabric_observables(cfg, &sc, false);
    let (on, d_on) = fabric_observables(cfg, &sc, true);
    assert_eq!(d_on, d_off, "kill run: settled digests must match");
    assert_eq!(on, off, "kill run: observables must match");
}

/// Satellite gate: the online protocol checker wired into the machine
/// surfaces its accept/violation counts through `Machine::report` —
/// and a healthy stream checks many messages with zero violations.
#[test]
fn machine_checker_counts_surface_in_report() {
    let mut m = machine_with(Some(2), None);
    m.attach_checker(OnlineChecker::new(NfaSpec::parse(builtin::READ_RESPONSE).unwrap()));
    m.set_workload(Workload::StreamRemote { lines: 400 }, 4);
    m.run();
    m.drain();
    let rep = m.report();
    assert!(
        rep.counters.get("checker_messages") > 0,
        "the checker must have observed traffic: {:?}",
        rep.counters.iter().collect::<Vec<_>>()
    );
    assert_eq!(
        rep.counters.get("checker_violations"),
        0,
        "a healthy stream must not violate the read-response property"
    );
    // and attaching it must not perturb the run itself
    let mut m2 = machine_with(Some(2), None);
    m2.set_workload(Workload::StreamRemote { lines: 400 }, 4);
    let r2 = m2.run();
    m2.drain();
    let mut m3 = machine_with(Some(2), None);
    m3.attach_checker(OnlineChecker::new(NfaSpec::parse(builtin::READ_RESPONSE).unwrap()));
    m3.set_workload(Workload::StreamRemote { lines: 400 }, 4);
    let r3 = m3.run();
    m3.drain();
    assert_eq!(r3.sim_time, r2.sim_time);
    assert_eq!(r3.events, r2.events);
    assert_eq!(fpga_mem_snapshot(&m3, 2048), fpga_mem_snapshot(&m2, 2048));
}
