//! Multi-node fabric invariants, checked at the system level: the
//! global interleave (exactly one home per line, under migration
//! overrides too), seed-stable routing, the 1-node degenerate case
//! (a fabric of one node IS the bare open-loop cell, settled digest
//! and all), and migration transparency (moving homes mid-run must
//! change *where* lines live, never *what* the protocol computes).

use eci::fabric::route::Interleave;
use eci::fabric::{Fabric, FabricConfig, KillSpec};
use eci::proto::messages::LineAddr;
use eci::ptest::Prop;
use eci::sim::time::Duration;
use eci::transport::rel::{FaultConfig, FaultSpec, RelConfig, RelMode};
use eci::workload::{OpenLoop, OpenLoopConfig, Scenario};

/// The lossy-link configuration the environment asks for, if any — the
/// same `ECI_LITMUS_FAULTS` / `ECI_LITMUS_REL_MODE` contract as the
/// litmus suite, so the CI matrix runs every fabric invariant below
/// clean AND fault-injected under both retransmission disciplines
/// (per-hop replay on the inter-node channels included).
fn rel_from_env() -> Option<RelConfig> {
    let v = std::env::var("ECI_LITMUS_FAULTS").ok()?;
    if v.is_empty() || v == "off" {
        return None;
    }
    let ber: f64 = v.parse().expect("ECI_LITMUS_FAULTS must be a bit-error rate (or `off`)");
    let spec = FaultSpec {
        ber,
        drop: (ber * 20.0).min(0.05),
        reorder: (ber * 20.0).min(0.05),
        burst_len: 1.0,
    };
    let mut rel = RelConfig::new(FaultConfig::new(spec, 7));
    match std::env::var("ECI_LITMUS_REL_MODE").ok().filter(|m| !m.is_empty()) {
        None => {}
        Some(m) => match RelMode::parse(&m) {
            Some(RelMode::GoBackN) => {}
            Some(RelMode::SelectiveRepeat) => {
                rel = rel.with_mode(RelMode::SelectiveRepeat).with_adaptive_rto(true);
            }
            None => panic!("ECI_LITMUS_REL_MODE must be gbn or sr, got {m:?}"),
        },
    }
    Some(rel)
}

/// An [`OpenLoopConfig`] with the environment's fault profile applied.
fn ol_config(rate_per_s: f64, ops: u64) -> OpenLoopConfig {
    let mut ol = OpenLoopConfig { rate_per_s, ops, ..Default::default() };
    if let Some(rel) = rel_from_env() {
        ol.machine.rel = Some(rel);
    }
    ol
}

/// Model-based interleave property: under a random stream of migration
/// commits (`set_home`), every line always has exactly one home, the
/// home agrees with a shadow override map, and `moved_lines` counts
/// exactly the lines living away from their natural `addr % nodes`
/// home — for 1-, 2- and 4-node fabrics.
#[test]
fn interleave_keeps_exactly_one_home_under_random_overrides() {
    const LINES: u64 = 256;
    Prop::new("interleave exactly-one-home under overrides")
        .cases(40)
        .max_size(80)
        .check_vec(
            |g| (g.below(LINES), g.below(4) as u8),
            |moves| {
                for nodes in [1u8, 2, 4] {
                    let mut il = Interleave::new(nodes);
                    let mut model: std::collections::HashMap<u64, u8> = Default::default();
                    for &(addr, node) in moves {
                        let node = node % nodes;
                        il.set_home(LineAddr(addr), node);
                        if node == (addr % nodes as u64) as u8 {
                            model.remove(&addr);
                        } else {
                            model.insert(addr, node);
                        }
                        for a in 0..LINES {
                            let h = il.home_of(LineAddr(a));
                            if h >= nodes {
                                return false;
                            }
                            let want =
                                model.get(&a).copied().unwrap_or((a % nodes as u64) as u8);
                            if h != want {
                                return false;
                            }
                        }
                        if il.moved_lines() != model.len() {
                            return false;
                        }
                    }
                }
                true
            },
        );
}

/// Routing (and everything downstream of it) is a pure function of the
/// seed: two identical 4-node runs — migration on, so forwarding,
/// parking and re-homing are all exercised — settle to bit-identical
/// state, simulated time and event counts.
#[test]
fn routing_is_seed_stable_across_identical_runs() {
    let sc = Scenario::preset("hot-kvs", 1 << 9, 0.99).expect("preset");
    let cfg = FabricConfig {
        nodes: 4,
        migrate: true,
        threshold: 4,
        ol: ol_config(4e6, 1_200),
        ..Default::default()
    };
    let (r1, d1) = Fabric::new(cfg, &sc).run_settled();
    let (r2, d2) = Fabric::new(cfg, &sc).run_settled();
    assert_eq!(d1, d2, "same seed, same settled state");
    assert_eq!(r1.sim_time, r2.sim_time);
    assert_eq!(r1.events, r2.events);
    assert_eq!(r1.completed, r2.completed);
    assert_eq!(r1.migrations, r2.migrations);
    // a different seed still completes every op (routing stays sound)
    let cfg2 = FabricConfig {
        ol: OpenLoopConfig { seed: cfg.ol.seed.wrapping_add(1), ..cfg.ol },
        ..cfg
    };
    let (r3, _) = Fabric::new(cfg2, &sc).run_settled();
    assert_eq!(r3.completed, 1_200);
}

/// The degenerate fabric: one node, no channels, every line homed
/// locally. It must BE the bare open-loop cell — same settled digest,
/// same completions over the same simulated time.
#[test]
fn one_node_fabric_equals_bare_openloop() {
    let sc = Scenario::preset("hot-kvs", 1 << 10, 0.99).expect("preset");
    let ol = ol_config(4e6, 1_000);
    let fab_cfg = FabricConfig { nodes: 1, ol, ..Default::default() };
    let (fab, fab_digest) = Fabric::new(fab_cfg, &sc).run_settled();
    let (bare, bare_digest) = OpenLoop::new(ol, &sc, fab_cfg.slices).run_settled();
    assert_eq!(fab_digest, bare_digest, "settled state must be bit-identical");
    assert_eq!(fab.completed, bare.completed);
    assert_eq!(fab.sim_time, bare.sim_time);
    assert_eq!(fab.lat.count(), bare.lat.count());
    assert!((fab.lat.mean() - bare.lat.mean()).abs() < 1e-9);
    assert_eq!(fab.fills_remote, 0, "one node has no remote fills");
    assert_eq!(fab.hop_lat.count(), 0, "one node has no fabric hops");
}

/// Migration transparency: a read-only scan over a small footprint (so
/// lines are revisited past the threshold and homes actually move)
/// settles to the same global state with migration on and off — moving
/// a line's home relocates bytes, it never changes them.
#[test]
fn migration_on_and_off_settle_to_the_same_state() {
    let sc = Scenario::preset("scan", 1 << 7, 0.99).expect("preset");
    let mk = |migrate: bool| {
        let cfg = FabricConfig {
            nodes: 2,
            migrate,
            threshold: 2,
            ol: ol_config(4e6, 1_500),
            ..Default::default()
        };
        Fabric::new(cfg, &sc).run_settled()
    };
    let (off, d_off) = mk(false);
    let (on, d_on) = mk(true);
    assert_eq!(off.completed, 1_500);
    assert_eq!(on.completed, 1_500, "migration must not lose operations");
    assert!(on.migrations > 0, "the scan must re-home hot lines: {:?}", on.counters);
    assert_eq!(d_on, d_off, "settled state must not depend on where lines live");
}

/// The acceptance property for whole-node failure (ISSUE 8): a 3-node
/// run with node 1 killed mid-run is *lossless* — every arrival not
/// abandoned with the dead node completes — and *exactly-once* — the
/// run settles (no pending translations, no limboed messages; `settle`
/// asserts both) to the same state digest as the 2-survivor baseline:
/// the same fabric with node 1 dead from the first microsecond, i.e. a
/// run executed almost entirely by the two surviving homes over the
/// re-homed interleave. (The traffic region scales with the node count,
/// so the baseline must be a 3-node fabric minus its dead node, not a
/// literal 2-node one.) The scenario is a read-only scan so the settled
/// digest is independent of *when* lines moved — the same transparency
/// contract the migration test pins.
#[test]
fn whole_node_failure_is_lossless_and_exactly_once() {
    let sc = Scenario::preset("scan", 1 << 9, 0.99).expect("preset");
    let killed = |at_us: u64| {
        let cfg = FabricConfig {
            nodes: 3,
            kill: Some(KillSpec { node: 1, at: Duration::from_us(at_us) }),
            ol: ol_config(4e6, 3_000),
            ..Default::default()
        };
        Fabric::new(cfg, &sc).run_settled()
    };
    let (mid, d_mid) = killed(100);
    let k = mid.kill.as_ref().expect("kill was configured");
    assert!(k.killed_at.is_some(), "node 1 must die mid-run, not after it");
    let detect = k.detect_latency().expect("survivors must declare the death");
    assert!(detect.ps() > 0 && detect.ps() <= Duration::from_us(40).ps(), "watchdog bound");
    assert!(k.rehomed_lines > 0, "node 1 homed about a third of the footprint");
    assert!(k.replayed > 0, "requests in flight at the dead home must replay");
    // lossless: everything except the dead node's own unfinished quota
    // completed, despite the kill landing mid-run
    assert_eq!(mid.completed + k.abandoned_ops, 3_000);
    assert!(
        mid.per_node[1].completed < 1_000,
        "the dead node cannot have finished its whole quota"
    );
    // 2-survivor baseline: the same fabric with node 1 dead from the
    // first microsecond — the survivors' steady-state world
    let (early, d_early) = killed(1);
    let ke = early.kill.as_ref().expect("kill was configured");
    assert_eq!(early.completed + ke.abandoned_ops, 3_000);
    assert!(ke.abandoned_ops > k.abandoned_ops, "an early death abandons more work");
    assert_eq!(d_mid, d_early, "mid-run failover must settle to the 2-survivor state");
}

/// Whole-node failure composed with live home migration: moves whose
/// old home, target, or parked requests touch the dead node are
/// cancelled or re-routed, and the run still settles to the identical
/// read-only state as the migration-off killed run.
#[test]
fn node_failure_with_migration_enabled_is_transparent() {
    let sc = Scenario::preset("scan", 1 << 7, 0.99).expect("preset");
    let killed = |migrate: bool| {
        let cfg = FabricConfig {
            nodes: 3,
            migrate,
            threshold: 2,
            kill: Some(KillSpec { node: 1, at: Duration::from_us(60) }),
            ol: ol_config(4e6, 2_400),
            ..Default::default()
        };
        Fabric::new(cfg, &sc).run_settled()
    };
    let (on, d_on) = killed(true);
    let (off, d_off) = killed(false);
    let kon = on.kill.as_ref().expect("kill was configured");
    let koff = off.kill.as_ref().expect("kill was configured");
    assert!(kon.killed_at.is_some() && koff.killed_at.is_some());
    assert_eq!(on.completed + kon.abandoned_ops, 2_400, "migration must not lose ops");
    assert_eq!(off.completed + koff.abandoned_ops, 2_400);
    assert_eq!(d_on, d_off, "failover must be transparent to migration");
}

/// Satellite: the migration *abort* path, pinned end to end. With
/// `abort_inject` every begun move aborts at its first commit check, so
/// parked requests always replay against the old home in arrival order
/// — and a read-only run must settle to the exact digest of a run that
/// never migrated at all.
#[test]
fn migration_abort_replays_parked_transparently() {
    let sc = Scenario::preset("scan", 1 << 7, 0.99).expect("preset");
    let mk = |migrate: bool, abort_inject: bool| {
        let cfg = FabricConfig {
            nodes: 2,
            migrate,
            threshold: 2,
            abort_inject,
            ol: ol_config(4e6, 1_500),
            ..Default::default()
        };
        Fabric::new(cfg, &sc).run_settled()
    };
    let (aborted, d_aborted) = mk(true, true);
    let (never, d_never) = mk(false, false);
    assert_eq!(aborted.completed, 1_500, "aborted moves must not lose operations");
    assert_eq!(never.completed, 1_500);
    assert!(
        aborted.counters.get("fab_migration_abort") > 0,
        "the scan must begin (and then abort) moves: {:?}",
        aborted.counters
    );
    assert_eq!(aborted.migrations, 0, "abort injection lets no move commit");
    assert_eq!(aborted.moved_lines, 0, "every line stays at its natural home");
    assert_eq!(d_aborted, d_never, "an aborted move must leave no trace in the state");
}

/// The abort path under a read/write mix: digests are time-stamped by
/// writes so state equality is out of reach, but completion accounting
/// still pins losslessness — every parked-then-replayed write finishes.
#[test]
fn migration_abort_with_writes_completes_every_op() {
    let sc = Scenario::preset("hot-kvs", 1 << 7, 0.99).expect("preset");
    let cfg = FabricConfig {
        nodes: 2,
        migrate: true,
        threshold: 2,
        abort_inject: true,
        ol: ol_config(4e6, 1_500),
        ..Default::default()
    };
    let (r, _) = Fabric::new(cfg, &sc).run_settled();
    assert_eq!(r.completed, 1_500);
    assert!(r.counters.get("fab_migration_abort") > 0, "{:?}", r.counters);
    assert_eq!(r.migrations, 0);
}

/// The CI litmus leg (`ECI_LITMUS_KILL=1`): the lossless/exactly-once
/// failover property at a heavier parameterization, composed with
/// whatever fault/retransmission profile the litmus matrix exported via
/// `ECI_LITMUS_FAULTS` / `ECI_LITMUS_REL_MODE` (lossy inter-node
/// channels make the barren-retransmission detector, not just the
/// watchdog, do real work). Skipped unless the environment asks.
#[test]
fn litmus_kill_leg_matches_two_survivor_baseline() {
    if std::env::var("ECI_LITMUS_KILL").ok().as_deref() != Some("1") {
        return;
    }
    let sc = Scenario::preset("scan", 1 << 9, 0.99).expect("preset");
    let killed = |at_us: u64| {
        let cfg = FabricConfig {
            nodes: 3,
            kill: Some(KillSpec { node: 1, at: Duration::from_us(at_us) }),
            ol: ol_config(4e6, 6_000),
            ..Default::default()
        };
        Fabric::new(cfg, &sc).run_settled()
    };
    let (mid, d_mid) = killed(150);
    let k = mid.kill.as_ref().expect("kill was configured");
    assert!(k.killed_at.is_some() && k.declared_at.is_some());
    assert_eq!(mid.completed + k.abandoned_ops, 6_000, "lossless under faults too");
    assert!(k.rehomed_lines > 0);
    let (early, d_early) = killed(1);
    let ke = early.kill.as_ref().expect("kill was configured");
    assert_eq!(early.completed + ke.abandoned_ops, 6_000);
    assert_eq!(d_mid, d_early, "killed run must settle to the 2-survivor baseline");
}
