//! Live-reconfiguration transparency suite: every scripted transition
//! the control plane supports (`eci::ctrl`) must be **lossless** — a
//! run that re-shapes itself mid-flight settles into bit-identical
//! end state (per-line directory states + backing-store bytes) as a
//! run that never reconfigured.
//!
//! Like the litmus suite, the whole file re-runs over the reliable
//! lossy link: `ECI_LITMUS_FAULTS=<ber>` injects bit errors, drops and
//! reordering (both runs of each pair see the same faults, so the
//! digests stay comparable), and `ECI_LITMUS_REL_MODE=sr` starts the
//! link in selective repeat with the adaptive RTO. Empty / "off"
//! values mean unset, so a CI matrix can pass them literally. Loss and
//! reconfiguration compose: a transition quiesces through retransmits
//! like through anything else, and semantics never change.
//!
//! The digest pairs all drive the read-only `scan` scenario: writes
//! stamp arrival timestamps into line bytes, which would make the
//! digest timing-sensitive and mask (or fake) divergence. The region
//! (128 KiB) fits every home-cache shape under test, so cached runs
//! settle eviction-free and residency cannot skew the directory state.

use eci::ctrl::{ReconfigEvent, ReconfigKind};
use eci::sim::time::Duration;
use eci::transport::rel::{FaultConfig, FaultSpec, RelConfig, RelMode};
use eci::transport::NUM_VCS;
use eci::workload::{OpenLoop, OpenLoopConfig, OpenLoopReport, Scenario};

/// The lossy-link configuration the environment asks for, if any
/// (mirrors the litmus suite's knob so one CI matrix drives both).
fn rel_from_env() -> Option<RelConfig> {
    let v = std::env::var("ECI_LITMUS_FAULTS").ok()?;
    if v.is_empty() || v == "off" {
        return None;
    }
    let ber: f64 = v.parse().expect("ECI_LITMUS_FAULTS must be a bit-error rate (or `off`)");
    let spec = FaultSpec {
        ber,
        drop: (ber * 20.0).min(0.05),
        reorder: (ber * 20.0).min(0.05),
        burst_len: 1.0,
    };
    let mut rel = RelConfig::new(FaultConfig::new(spec, 7));
    match std::env::var("ECI_LITMUS_REL_MODE").ok().filter(|m| !m.is_empty()) {
        None => {}
        Some(m) => match RelMode::parse(&m) {
            Some(RelMode::GoBackN) => {}
            Some(RelMode::SelectiveRepeat) => {
                rel = rel.with_mode(RelMode::SelectiveRepeat).with_adaptive_rto(true);
            }
            None => panic!("ECI_LITMUS_REL_MODE must be gbn or sr, got {m:?}"),
        },
    }
    Some(rel)
}

fn base_cfg(ops: u64, home_cached: bool) -> OpenLoopConfig {
    let mut cfg = OpenLoopConfig { rate_per_s: 4e6, ops, home_cached, ..Default::default() };
    if let Some(rel) = rel_from_env() {
        cfg.machine.rel = Some(rel);
    }
    cfg
}

fn scan() -> Scenario {
    Scenario::preset("scan", 1 << 10, 0.99).expect("scan preset")
}

fn ev(us: u64, kind: ReconfigKind) -> ReconfigEvent {
    ReconfigEvent { at: Duration::from_us(us), kind }
}

/// Run the scan scenario on `slices` slices with `events` scripted;
/// returns the report and the settled-state digest.
fn settled(
    cfg: OpenLoopConfig,
    slices: usize,
    events: Vec<ReconfigEvent>,
) -> (OpenLoopReport, u64) {
    let mut ol = OpenLoop::new(cfg, &scan(), slices);
    if !events.is_empty() {
        ol = ol.with_reconfig(events);
    }
    ol.run_settled()
}

/// Digest-gate a script against the never-reconfigured baseline and
/// assert every scripted transition actually executed.
fn assert_lossless(cfg: OpenLoopConfig, slices: usize, events: Vec<ReconfigEvent>, what: &str) {
    let n = events.len();
    let (_, base_digest) = settled(cfg, slices, Vec::new());
    let (r, digest) = settled(cfg, slices, events);
    assert_eq!(r.completed, cfg.ops, "{what}: every arrival must complete");
    let rc = r.reconfig.expect("scripted run reports its transitions");
    assert_eq!(rc.executed(), n, "{what}: no transition may be skipped: {:?}", rc.transitions);
    assert_eq!(digest, base_digest, "{what}: settled state diverged from the baseline");
}

#[test]
fn reslice_2_to_4_is_digest_transparent() {
    // streaming (uncached-home) and cached-home variants both gate
    for home_cached in [false, true] {
        let cfg = base_cfg(1_600, home_cached);
        let what = format!("reslice 2->4 (home_cached={home_cached})");
        let (_, base_digest) = settled(cfg, 2, Vec::new());
        let (r, digest) = settled(cfg, 2, vec![ev(60, ReconfigKind::Reslice(4))]);
        assert_eq!(r.completed, cfg.ops, "{what}");
        assert_eq!(r.per_slice_served.len(), 4, "{what}: report covers the final shape");
        assert!(r.per_slice_served.iter().all(|&s| s > 0), "{what}: all four slices serve");
        assert_eq!(r.reconfig.expect("scripted").executed(), 1, "{what}");
        assert_eq!(digest, base_digest, "{what}: settled state diverged");
    }
}

#[test]
fn drain_and_rejoin_are_digest_transparent() {
    // slice 1 leaves the rotation at 60us (its lines redistribute over
    // the survivors) and rejoins at 180us — both handoffs lossless
    let cfg = base_cfg(1_600, false);
    assert_lossless(
        cfg,
        2,
        vec![ev(60, ReconfigKind::Drain(1)), ev(180, ReconfigKind::Rejoin)],
        "drain + rejoin",
    );
}

#[test]
fn relmode_swap_midrun_is_digest_transparent() {
    // always a *real* swap: when the fault matrix leaves the link
    // unframed, run a clean rel link so there is a mode to change, and
    // swap away from whatever mode the run started in
    let mut cfg = base_cfg(1_600, false);
    if cfg.machine.rel.is_none() {
        cfg.machine.rel = Some(RelConfig::from_ber(0.0, 7));
    }
    let target = match cfg.machine.rel.expect("just set").mode {
        RelMode::GoBackN => RelMode::SelectiveRepeat,
        RelMode::SelectiveRepeat => RelMode::GoBackN,
    };
    assert_lossless(
        cfg,
        2,
        vec![ev(90, ReconfigKind::RelSwap(target))],
        "rel-mode swap",
    );
}

#[test]
fn cache_grow_is_digest_transparent() {
    // double the home-cache budget mid-run; the 128 KiB region fits
    // both shapes, so the settled directory state cannot depend on the
    // budget and the digest must gate exactly
    let cfg = base_cfg(1_600, true);
    assert_lossless(
        cfg,
        2,
        vec![ev(80, ReconfigKind::CacheResize(2 << 20))],
        "home-cache grow",
    );
}

#[test]
fn cache_shrink_to_zero_evicts_and_completes() {
    // shrink-to-zero changes the final shape's residency, so this one
    // is count-gated, not digest-gated: the handoff must export the
    // cached lines, count the victims, and the run must still finish
    // every arrival with the transition executed
    let cfg = base_cfg(1_600, true);
    let (r, _) = settled(cfg, 2, vec![ev(120, ReconfigKind::CacheResize(0))]);
    assert_eq!(r.completed, cfg.ops);
    let rc = r.reconfig.expect("scripted");
    assert_eq!(rc.executed(), 1);
    let t = &rc.transitions[0];
    assert!(t.moved_lines > 0, "directory lines must survive the handoff");
    assert!(t.cache_victims > 0, "shrinking to zero must evict the resident lines");
}

#[test]
fn credits_neither_leak_nor_duplicate_across_handoffs() {
    // the full transition family in one run. A leaked credit shows up
    // as a stall (completed < ops); a duplicated credit shows up as
    // peak in-flight beyond the per-VC budget times the VC count.
    let mut cfg = base_cfg(2_400, true);
    if cfg.machine.rel.is_none() {
        cfg.machine.rel = Some(RelConfig::from_ber(0.0, 7));
    }
    let target = match cfg.machine.rel.expect("just set").mode {
        RelMode::GoBackN => RelMode::SelectiveRepeat,
        RelMode::SelectiveRepeat => RelMode::GoBackN,
    };
    let events = vec![
        ev(60, ReconfigKind::Reslice(4)),
        ev(150, ReconfigKind::Drain(1)),
        ev(240, ReconfigKind::Rejoin),
        ev(330, ReconfigKind::RelSwap(target)),
        ev(420, ReconfigKind::CacheResize(0)),
    ];
    let n = events.len();
    let (r, _) = settled(cfg, 2, events);
    assert_eq!(r.completed, cfg.ops, "a leaked credit would strand arrivals");
    assert_eq!(r.reconfig.expect("scripted").executed(), n);
    let budget = cfg.machine.link.credits_per_vc * NUM_VCS as u32;
    assert!(r.peak_in_flight > 0);
    assert!(
        r.peak_in_flight <= budget,
        "a duplicated credit would overshoot the VC budget: {} > {budget}",
        r.peak_in_flight
    );
}
