//! Property-based coordinator invariants (using the in-crate `ptest`
//! harness; `proptest` is unavailable offline — DESIGN.md).

use eci::agents::cache::Cache;
use eci::agents::dram::MemStore;
use eci::agents::home::{HomeAgent, HomeEffect};
use eci::agents::remote::{RemoteAgent, RemoteEffect};
use eci::proto::envelope::check_envelope;
use eci::proto::messages::{CohOp, LineAddr, Message, MsgKind, ReqId};
use eci::proto::spec::{generate_home, generate_remote, HomePolicy};
use eci::proto::states::{CacheState, Node};
use eci::proto::transitions::reference_transitions;
use eci::ptest::Prop;
use eci::trace::ewf;
use eci::trace::msgjson;
use eci::transport::{Credits, VcId, NUM_VCS};

// ---------------------------------------------------------------------------
// protocol-level properties
// ---------------------------------------------------------------------------

/// Random interleavings of local accesses and evictions against a live
/// remote agent + home agent pair, with the messages actually routed:
/// at every step the *joint* state must remain coherent (single writer),
/// and data written by the remote must never be lost.
#[test]
fn random_access_interleavings_preserve_coherence() {
    #[derive(Clone, Debug)]
    enum Act {
        Read(u8),
        Write(u8),
        Evict(u8),
    }
    Prop::new("coherence under random interleavings")
        .cases(60)
        .max_size(120)
        .check_vec(
            |g| {
                let addr = g.below(4) as u8; // few lines -> lots of conflicts
                match g.below(3) {
                    0 => Act::Read(addr),
                    1 => Act::Write(addr),
                    _ => Act::Evict(addr),
                }
            },
            |acts| {
                let spec = reference_transitions();
                let mut remote =
                    RemoteAgent::new(Node::Remote, generate_remote(&spec), LineAddr(0), 1 << 20);
                let mut cache = Cache::new(16 * 1024, 4);
                let mut home = HomeAgent::new(
                    generate_home(&spec, HomePolicy::default()),
                    HomePolicy::default(),
                    None,
                );
                let mut ram = MemStore::new(LineAddr(0), 64 * 128);
                let mut stamp = 1u64;
                // deliver messages synchronously (in-order transport)
                let mut deliver_to_home = |m: Message,
                                            home: &mut HomeAgent,
                                            ram: &mut MemStore|
                 -> Vec<Message> {
                    home.on_message(m, ram)
                        .into_iter()
                        .filter_map(|e| match e {
                            HomeEffect::Respond { msg, .. } => Some(msg),
                            HomeEffect::Fwd { msg } => Some(msg),
                            _ => None,
                        })
                        .collect()
                };
                for act in acts {
                    let (addr, write, evict) = match act {
                        Act::Read(a) => (LineAddr(*a as u64), false, false),
                        Act::Write(a) => (LineAddr(*a as u64), true, false),
                        Act::Evict(a) => (LineAddr(*a as u64), false, true),
                    };
                    let fx = if evict {
                        remote.evict(addr, &mut cache)
                    } else {
                        let (_, fx) = remote.local_access(addr, write, &mut cache);
                        fx
                    };
                    // pump messages to quiescence
                    let mut to_home: Vec<Message> = fx
                        .into_iter()
                        .filter_map(|e| match e {
                            RemoteEffect::Send(m) => Some(m),
                            _ => None,
                        })
                        .collect();
                    while let Some(m) = to_home.pop() {
                        for rsp in deliver_to_home(m, &mut home, &mut ram) {
                            let fx = remote.on_message(rsp, &mut cache);
                            for e in fx {
                                if let RemoteEffect::Send(m2) = e {
                                    to_home.push(m2);
                                }
                            }
                        }
                    }
                    // after quiescence: single-writer invariant between the
                    // remote cache state and the home directory view
                    for line in 0..4u64 {
                        let a = LineAddr(line);
                        let rstate = cache.state_of(a);
                        let hstate = home.state_of(a);
                        use eci::proto::spec::RemoteView;
                        let consistent = match rstate {
                            CacheState::I => true, // view may lag (benign over-estimate)
                            CacheState::S => hstate.view != RemoteView::I || false,
                            CacheState::E | CacheState::M => hstate.view == RemoteView::EorM,
                        };
                        if !consistent {
                            return false;
                        }
                        // single writer: remote E/M excludes home copy
                        if matches!(rstate, CacheState::E | CacheState::M)
                            && hstate.own != CacheState::I
                        {
                            return false;
                        }
                    }
                    // data-value: a write is stamped and must be readable back
                    if write {
                        if let Some(e) = cache.lookup(addr) {
                            e.data[8..16].copy_from_slice(&stamp.to_le_bytes());
                            stamp += 1;
                        }
                    }
                }
                true
            },
        );
}

/// Mutated transition tables must be rejected by the envelope checker:
/// removing rows or redirecting outcomes at random either keeps the table
/// legal or produces at least one violation — never a panic.
#[test]
fn envelope_checker_total_on_random_mutations() {
    Prop::new("envelope checker totality").cases(150).check(
        |g| {
            let mut table = reference_transitions();
            // random mutation: drop rows or retarget an outcome
            let n_mut = 1 + g.below(3);
            for _ in 0..n_mut {
                if table.is_empty() {
                    break;
                }
                let i = g.below(table.len() as u64) as usize;
                if g.chance(0.5) {
                    table.remove(i);
                } else {
                    let all = eci::proto::states::Joint::ALL;
                    let j = *g.choose(&all);
                    table[i].outcomes = vec![j];
                }
            }
            table
        },
        |table| {
            // must not panic; result is informative either way
            let _ = check_envelope(table);
            true
        },
    );
}

// ---------------------------------------------------------------------------
// transport-level properties
// ---------------------------------------------------------------------------

/// Credit conservation: under any interleaving of consume/restore the
/// in-flight count never exceeds the budget and never goes negative.
#[test]
fn credit_conservation_under_random_traffic() {
    Prop::new("credit conservation").cases(100).max_size(400).check_vec(
        |g| (g.below(NUM_VCS as u64) as u8, g.chance(0.45)),
        |ops| {
            let mut credits = Credits::new(8);
            let mut in_flight = [0u32; NUM_VCS];
            for &(vc, restore) in ops {
                let vc = VcId(vc);
                if restore {
                    if in_flight[vc.0 as usize] > 0 {
                        credits.restore(vc);
                        in_flight[vc.0 as usize] -= 1;
                    }
                } else if credits.consume(vc) {
                    in_flight[vc.0 as usize] += 1;
                }
                if credits.in_flight(vc) != in_flight[vc.0 as usize] {
                    return false;
                }
                if in_flight[vc.0 as usize] > 8 {
                    return false;
                }
            }
            true
        },
    );
}

/// EWF encode/decode is a bijection on random well-formed messages.
#[test]
fn ewf_round_trip_on_random_messages() {
    Prop::new("EWF round trip").cases(300).check(
        |g| {
            let id = ReqId(g.below(1 << 20) as u32);
            let addr = LineAddr(g.below(1 << 40));
            let from = if g.chance(0.5) { Node::Home } else { Node::Remote };
            let ops = CohOp::ALL;
            let op = *g.choose(&ops);
            let payload = if g.chance(0.5) {
                let b = g.below(256) as u8;
                Some(Box::new([b; 128]))
            } else {
                None
            };
            match g.below(4) {
                0 => Message::coh_req(id, from, op, addr),
                1 => Message { id, from, kind: MsgKind::CohRsp { op, dirty: g.chance(0.5), had_copy: g.chance(0.8) }, addr, payload },
                2 => Message { id, from, kind: MsgKind::CohReq { op }, addr, payload },
                _ => Message {
                    id,
                    from,
                    kind: MsgKind::IoWrite { offset: g.below(1 << 20), value: g.below(u64::MAX - 1) },
                    addr,
                    payload: None,
                },
            }
        },
        |msg| {
            let bytes = ewf::encode(msg);
            match ewf::decode(&bytes) {
                Ok((back, used)) => back == *msg && used == bytes.len(),
                Err(_) => false,
            }
        },
    );
}

/// JSON message serialization round-trips too.
#[test]
fn msgjson_round_trip_on_random_messages() {
    Prop::new("msg JSON round trip").cases(200).check(
        |g| {
            let id = ReqId(g.below(1 << 16) as u32);
            let addr = LineAddr(g.below(1 << 30));
            let ops = CohOp::ALL;
            let op = *g.choose(&ops);
            if g.chance(0.5) {
                Message::coh_req(id, Node::Remote, op, addr)
            } else {
                let payload = g.chance(0.5).then(|| Box::new([g.below(256) as u8; 128]));
                Message { id, from: Node::Home, kind: MsgKind::CohRsp { op, dirty: g.chance(0.3), had_copy: g.chance(0.8) }, addr, payload }
            }
        },
        |msg| {
            let text = msgjson::to_json(msg).to_string();
            let parsed = eci::trace::json::parse(&text).unwrap();
            msgjson::from_json(&parsed).map(|b| b == *msg).unwrap_or(false)
        },
    );
}

/// The dissector is total over random messages (never panics, always
/// one-line summaries).
#[test]
fn dissector_total_on_random_messages() {
    Prop::new("dissector totality").cases(200).check(
        |g| {
            let ops = CohOp::ALL;
            let op = *g.choose(&ops);
            let payload = g.chance(0.3).then(|| Box::new([7u8; 128]));
            Message {
                id: ReqId(g.below(1 << 30) as u32),
                from: if g.chance(0.5) { Node::Home } else { Node::Remote },
                kind: if g.chance(0.5) {
                    MsgKind::CohReq { op }
                } else {
                    MsgKind::CohRsp { op, dirty: g.chance(0.5), had_copy: g.chance(0.8) }
                },
                addr: LineAddr(g.below(1 << 40)),
                payload,
            }
        },
        |msg| {
            let s = eci::trace::dissector::summary(eci::sim::time::Time(0), msg);
            let d = eci::trace::dissector::detail(eci::sim::time::Time(0), msg);
            !s.contains('\n') && d.lines().count() >= 6
        },
    );
}

// ---------------------------------------------------------------------------
// dcs (sharded directory) properties
// ---------------------------------------------------------------------------

/// Slice-count transparency: for any interleaving of reads, writes and
/// evictions, routing the identical message trace through a 1-slice and a
/// 4-slice [`eci::dcs::Dcs`] yields identical per-line home->remote
/// message sequences and identical final directory state. (A line maps to
/// exactly one slice and all directory state is line-local, so sharding
/// must be invisible to protocol semantics.)
#[test]
fn sliced_directory_is_equivalent_to_monolith_per_line() {
    use eci::dcs::{Dcs, DcsConfig};

    const LINES: u64 = 8;

    #[derive(Clone, Debug)]
    enum Act {
        Read(u8),
        Write(u8),
        Evict(u8),
    }

    /// Run one trace against an N-slice dcs; return (per-line log of
    /// home-emitted messages, final per-line directory state). Request
    /// ids are deliberately excluded from the log: slice-local id
    /// allocators may number home-initiated messages differently.
    fn run(slices: usize, acts: &[Act]) -> (Vec<Vec<String>>, Vec<eci::proto::spec::HomeSt>) {
        let spec = reference_transitions();
        let mut remote = RemoteAgent::new(Node::Remote, generate_remote(&spec), LineAddr(0), 1 << 20);
        let mut cache = Cache::new(16 * 1024, 4);
        let mut dcs = Dcs::with_reference_rules(DcsConfig::new(slices));
        let mut ram = MemStore::new(LineAddr(0), 64 * 128);
        let mut log: Vec<Vec<String>> = vec![Vec::new(); LINES as usize];
        for act in acts {
            let (addr, write, evict) = match act {
                Act::Read(a) => (LineAddr(*a as u64), false, false),
                Act::Write(a) => (LineAddr(*a as u64), true, false),
                Act::Evict(a) => (LineAddr(*a as u64), false, true),
            };
            let fx = if evict {
                remote.evict(addr, &mut cache)
            } else {
                let (_, fx) = remote.local_access(addr, write, &mut cache);
                fx
            };
            let mut to_home: Vec<Message> = fx
                .into_iter()
                .filter_map(|e| match e {
                    RemoteEffect::Send(m) => Some(m),
                    _ => None,
                })
                .collect();
            while let Some(m) = to_home.pop() {
                let rsps: Vec<Message> = dcs
                    .on_message_sync(m, &mut ram)
                    .into_iter()
                    .filter_map(|e| match e {
                        HomeEffect::Respond { msg, .. } => Some(msg),
                        HomeEffect::Fwd { msg } => Some(msg),
                        _ => None,
                    })
                    .collect();
                for rsp in rsps {
                    let line = rsp.addr.0 as usize % LINES as usize;
                    log[line].push(format!(
                        "{:?} payload={:?}",
                        rsp.kind,
                        rsp.payload.as_ref().map(|p| p[0])
                    ));
                    for e in remote.on_message(rsp, &mut cache) {
                        if let RemoteEffect::Send(m2) = e {
                            to_home.push(m2);
                        }
                    }
                }
            }
        }
        let states = (0..LINES).map(|l| dcs.state_of(LineAddr(l))).collect();
        (log, states)
    }

    Prop::new("dcs slice-count transparency")
        .cases(50)
        .max_size(100)
        .check_vec(
            |g| {
                let addr = g.below(LINES) as u8;
                match g.below(3) {
                    0 => Act::Read(addr),
                    1 => Act::Write(addr),
                    _ => Act::Evict(addr),
                }
            },
            |acts| {
                let (log1, st1) = run(1, acts);
                let (log4, st4) = run(4, acts);
                log1 == log4 && st1 == st4
            },
        );
}

/// Ingress batching is semantically transparent: routing the identical
/// message trace through a batched (batch = 4) and an unbatched sliced
/// directory yields identical per-line home->remote message sequences
/// and identical final directory state. Batching only regroups
/// *deliveries*; per-VC FIFO order is preserved and the mux applies the
/// same rank discipline either way.
#[test]
fn ingress_batching_is_transparent_to_protocol_outcomes() {
    use eci::dcs::{Dcs, DcsConfig, SliceService};
    use eci::sim::time::{Duration, Time};
    use eci::transport::Frame;

    const LINES: u64 = 8;

    #[derive(Clone, Debug)]
    enum Act {
        Read(u8),
        Write(u8),
        Evict(u8),
    }

    /// Deliver `burst` through the framed (batched) ingress and pump the
    /// slices to quiescence, feeding responses back through the remote
    /// (whose follow-up messages form the next burst round).
    #[allow(clippy::too_many_arguments)]
    fn pump_all(
        burst: &mut Vec<Message>,
        dcs: &mut Dcs,
        remote: &mut RemoteAgent,
        cache: &mut Cache,
        ram: &mut MemStore,
        seq: &mut u64,
        log: &mut [Vec<String>],
    ) {
        while !burst.is_empty() {
            for m in burst.drain(..) {
                dcs.enqueue_frame(Time(0), Frame::new(*seq, m));
                *seq += 1;
            }
            for s in 0..dcs.slices() {
                while let Some(sv) = dcs.service_one(s, Time(0), ram) {
                    let SliceService::Done(_, _, _, fx) = sv else {
                        panic!("zero-occupancy slice reported busy")
                    };
                    for e in fx {
                        let rsp = match e {
                            HomeEffect::Respond { msg, .. } => msg,
                            HomeEffect::Fwd { msg } => msg,
                            _ => continue,
                        };
                        let line = rsp.addr.0 as usize % LINES as usize;
                        log[line].push(format!(
                            "{:?} payload={:?}",
                            rsp.kind,
                            rsp.payload.as_ref().map(|p| p[0])
                        ));
                        for e2 in remote.on_message(rsp, cache) {
                            if let RemoteEffect::Send(m2) = e2 {
                                burst.push(m2);
                            }
                        }
                    }
                }
            }
        }
    }

    /// Run one trace through the framed ingress of a 4-slice dcs with
    /// the given batch size (slice pipelines at zero occupancy so the
    /// pump services to quiescence); return (per-line log of
    /// home-emitted messages, final per-line directory state). Acts are
    /// delivered in chunks of 5, so the staged batches genuinely carry
    /// multiple frames; an access landing while its line is still
    /// mid-transaction stalls locally and is dropped — deterministically
    /// identical in both runs, since stalling depends only on per-line
    /// history.
    fn run(batch: usize, acts: &[Act]) -> (Vec<Vec<String>>, Vec<eci::proto::spec::HomeSt>) {
        let spec = reference_transitions();
        let mut remote =
            RemoteAgent::new(Node::Remote, generate_remote(&spec), LineAddr(0), 1 << 20);
        let mut cache = Cache::new(16 * 1024, 4);
        let mut dcs = Dcs::with_reference_rules(
            DcsConfig::new(4).with_slice_proc(Duration::ZERO).with_batch(batch),
        );
        let mut ram = MemStore::new(LineAddr(0), 64 * 128);
        let mut log: Vec<Vec<String>> = vec![Vec::new(); LINES as usize];
        let mut seq = 0u64;
        let mut burst: Vec<Message> = Vec::new();
        for (k, act) in acts.iter().enumerate() {
            let (addr, write, evict) = match act {
                Act::Read(a) => (LineAddr(*a as u64), false, false),
                Act::Write(a) => (LineAddr(*a as u64), true, false),
                Act::Evict(a) => (LineAddr(*a as u64), false, true),
            };
            let fx = if evict {
                remote.evict(addr, &mut cache)
            } else {
                let (_, fx) = remote.local_access(addr, write, &mut cache);
                fx
            };
            burst.extend(fx.into_iter().filter_map(|e| match e {
                RemoteEffect::Send(m) => Some(m),
                _ => None,
            }));
            if (k + 1) % 5 == 0 {
                pump_all(&mut burst, &mut dcs, &mut remote, &mut cache, &mut ram, &mut seq, &mut log);
                assert_eq!(dcs.pending(), 0, "trace must quiesce between chunks");
            }
        }
        pump_all(&mut burst, &mut dcs, &mut remote, &mut cache, &mut ram, &mut seq, &mut log);
        assert_eq!(dcs.pending(), 0, "trace must quiesce");
        let states = (0..LINES).map(|l| dcs.state_of(LineAddr(l))).collect();
        (log, states)
    }

    Prop::new("ingress batching transparency")
        .cases(40)
        .max_size(100)
        .check_vec(
            |g| {
                let addr = g.below(LINES) as u8;
                match g.below(3) {
                    0 => Act::Read(addr),
                    1 => Act::Write(addr),
                    _ => Act::Evict(addr),
                }
            },
            |acts| {
                let (log1, st1) = run(1, acts);
                let (log4, st4) = run(4, acts);
                log1 == log4 && st1 == st4
            },
        );
}

/// Batched delivery never exceeds the credit budget: frames staged in
/// the ingress batcher still occupy their receiver buffer slot, so
/// launched-but-unserviced frames (queued, staged OR in a slice FIFO)
/// exactly account for the held credits, and the budget bounds them at
/// every step. Credits flow back only at `SliceService::Done`.
#[test]
fn batched_ingress_holds_credits_until_slice_service() {
    use eci::dcs::{Dcs, DcsConfig, SliceService};
    use eci::sim::rng::Rng;
    use eci::sim::time::{Duration, Time};
    use eci::transport::{FramedIngress, LinkConfig};

    Prop::new("batched ingress credit accounting").cases(25).check(
        |g| {
            let credits = 1 + g.below(5) as u32;
            let msgs = 30 + g.below(120);
            let batch = 2 + g.below(4) as usize;
            let seed = g.below(1 << 32);
            (credits, msgs, batch, seed)
        },
        |&(credits, msgs, batch, seed)| {
            let mut cfg = LinkConfig::eci();
            cfg.credits_per_vc = credits;
            let mut ing = FramedIngress::new(cfg, Node::Remote, Rng::new(seed));
            let mut dcs = Dcs::with_reference_rules(
                DcsConfig::new(2).with_slice_proc(Duration::ZERO).with_batch(batch),
            );
            let mut ram = MemStore::new(LineAddr(0), 64 * 128);
            let mut rng = Rng::new(seed ^ 0xBA7C);
            for i in 0..msgs {
                let addr = LineAddr(rng.below(64));
                ing.offer(Message::coh_req(
                    ReqId(i as u32),
                    Node::Remote,
                    CohOp::ReadShared,
                    addr,
                ));
            }
            let budget = credits * NUM_VCS as u32;
            let mut now = Time(0);
            let mut serviced = 0u64;
            while serviced < msgs {
                let mut out = Vec::new();
                ing.pump(now, &mut out);
                for (at, f) in out {
                    if at > now {
                        now = at;
                    }
                    let (mut del, mut ctls) = (Vec::new(), Vec::new());
                    ing.deliver(f, &mut del, &mut ctls);
                    for c in ctls {
                        ing.on_control(now, c);
                    }
                    assert_eq!(del.len(), 1, "in-sequence frame must deliver");
                    for fr in del {
                        dcs.enqueue_frame(now, fr);
                    }
                }
                // every launched-but-unserviced frame — including the
                // ones STAGED in the batcher — still holds its credit
                assert_eq!(
                    ing.in_flight_total() as usize,
                    dcs.pending(),
                    "staged frames must hold their buffer slots"
                );
                assert!(
                    ing.in_flight_total() <= budget,
                    "in-flight {} exceeds budget {budget}",
                    ing.in_flight_total()
                );
                for s in 0..dcs.slices() {
                    while let Some(sv) = dcs.service_one(s, now, &mut ram) {
                        let SliceService::Done(_, vc, _, _) = sv else {
                            panic!("zero-occupancy slice reported busy")
                        };
                        ing.credit_return(vc);
                        serviced += 1;
                    }
                }
                now = now + Duration::from_ns(50);
            }
            assert_eq!(serviced, msgs);
            assert_eq!(ing.queued(), 0);
            assert_eq!(ing.in_flight_total(), 0);
            assert_eq!(dcs.pending(), 0);
            true
        },
    );
}

// ---------------------------------------------------------------------------
// workload-subsystem properties
// ---------------------------------------------------------------------------

/// The Zipf sampler's empirical CDF must track the analytic CDF within a
/// DKW-style tolerance at every rank, across supports and skews.
#[test]
fn zipf_empirical_cdf_matches_analytic() {
    use eci::sim::rng::Rng;
    use eci::workload::Zipf;

    Prop::new("zipf empirical CDF within tolerance of analytic")
        .cases(8)
        .check(
            |g| {
                let n = 2 + g.below(4000);
                // theta in [0, 1.625] in eighths (covers uniform .. heavy skew)
                let theta = g.below(14) as f64 / 8.0;
                let seed = g.below(1 << 32);
                (n, theta, seed)
            },
            |&(n, theta, seed)| {
                let z = Zipf::new(n, theta);
                let mut rng = Rng::new(seed);
                const DRAWS: u64 = 50_000;
                let mut counts = vec![0u64; n as usize];
                for _ in 0..DRAWS {
                    counts[z.sample(&mut rng) as usize] += 1;
                }
                // DKW: eps = sqrt(ln(2/delta) / 2N) ~ 0.012 for N=50k at
                // delta=1e-6; 0.02 leaves slack for 8 cases
                let mut acc = 0u64;
                for k in 0..n {
                    acc += counts[k as usize];
                    let emp = acc as f64 / DRAWS as f64;
                    if (emp - z.cdf(k)).abs() >= 0.02 {
                        return false;
                    }
                }
                true
            },
        );
}

/// Same seed, same draws — bit-identical, so scenario sweeps compare the
/// same traffic across slice counts.
#[test]
fn zipf_sampling_is_bit_identical_across_reruns() {
    use eci::sim::rng::Rng;
    use eci::workload::Zipf;

    let draw = || {
        let z = Zipf::new(1 << 14, 0.99);
        let mut rng = Rng::new(0x5EED);
        (0..10_000).map(|_| z.sample(&mut rng)).collect::<Vec<u64>>()
    };
    let a = draw();
    let b = draw();
    assert_eq!(a, b);
    // and a different seed must actually change the stream
    let z = Zipf::new(1 << 14, 0.99);
    let mut rng = Rng::new(0x5EEE);
    let c: Vec<u64> = (0..10_000).map(|_| z.sample(&mut rng)).collect();
    assert_ne!(a, c);
}

/// Credit-accurate admission: however hard the generator floods the
/// framed ingress, launched-but-unserviced frames never exceed the
/// per-VC credit budget, and every offered message still arrives, in
/// sequence, once the receiver drains.
#[test]
fn framed_ingress_credits_bound_in_flight_under_overload() {
    use eci::proto::messages::{CohOp, LineAddr, Message, ReqId};
    use eci::sim::rng::Rng;
    use eci::sim::time::{Duration, Time};
    use eci::transport::{Frame, FramedIngress, LinkConfig};
    use std::collections::VecDeque;

    Prop::new("link credits bound in-flight frames under overload")
        .cases(30)
        .check(
            |g| {
                let credits = 1 + g.below(6) as u32;
                let msgs = 40 + g.below(160);
                let seed = g.below(1 << 32);
                (credits, msgs, seed)
            },
            |&(credits, msgs, seed)| {
                let mut cfg = LinkConfig::eci();
                cfg.credits_per_vc = credits;
                let mut ing = FramedIngress::new(cfg, Node::Remote, Rng::new(seed));
                let mut rng = Rng::new(seed ^ 0xF00D);
                // flood: random parities, all offered up front (overload)
                for i in 0..msgs {
                    let addr = LineAddr(rng.below(64));
                    ing.offer(Message::coh_req(
                        ReqId(i as u32),
                        Node::Remote,
                        CohOp::ReadShared,
                        addr,
                    ));
                }
                let mut now = Time(0);
                let mut in_flight: VecDeque<Frame> = VecDeque::new();
                let mut outstanding = [0u32; NUM_VCS];
                let mut delivered = 0u64;
                while delivered < msgs {
                    let mut out = Vec::new();
                    ing.pump(now, &mut out);
                    for (at, f) in out {
                        let vc = f.vc.0 as usize;
                        outstanding[vc] += 1;
                        assert!(
                            outstanding[vc] <= credits,
                            "in-flight {} exceeds credit budget {credits} on vc {vc}",
                            outstanding[vc]
                        );
                        if at > now {
                            now = at;
                        }
                        in_flight.push_back(f);
                    }
                    // the receiver services a random batch, in wire order
                    let k = 1 + rng.below(1 + in_flight.len() as u64) as usize;
                    for _ in 0..k.min(in_flight.len()) {
                        let f = in_flight.pop_front().unwrap();
                        let vc = f.vc;
                        let (mut del, mut ctls) = (Vec::new(), Vec::new());
                        ing.deliver(f, &mut del, &mut ctls);
                        assert_eq!(del.len(), 1, "in-sequence frame must deliver");
                        for c in ctls {
                            ing.on_control(now, c);
                        }
                        outstanding[vc.0 as usize] -= 1;
                        ing.credit_return(vc);
                        delivered += 1;
                    }
                    now = now + Duration::from_ns(50);
                }
                assert_eq!(ing.delivered, msgs);
                assert_eq!(ing.queued(), 0);
                assert_eq!(ing.in_flight_total(), 0);
                true
            },
        );
}

// ---------------------------------------------------------------------------
// reliable-lossy-link (rel) properties
// ---------------------------------------------------------------------------

/// Credit accounting under replay: on a lossy rel link (drops, bit
/// errors, reordering), launched-but-unreturned frames never exceed the
/// credit budget at any step — a retransmission must not re-consume a
/// credit, in EITHER retransmission mode — and once everything is
/// serviced and acked, every credit is home again — a loss must not
/// leak one (and a selective-repeat receive buffer must not strand one).
#[test]
fn rel_replay_holds_credits_without_leak() {
    use eci::dcs::{Dcs, DcsConfig, SliceService};
    use eci::sim::rng::Rng;
    use eci::sim::time::{Duration, Time};
    use eci::transport::rel::{FaultConfig, FaultSpec, RelConfig, RelMode};
    use eci::transport::{FramedIngress, LinkConfig};

    Prop::new("rel replay credit conservation").cases(20).check(
        |g| {
            let credits = 2 + g.below(5) as u32;
            let msgs = 30 + g.below(90);
            let drop = g.below(8) as f64 / 100.0; // 0..0.07
            let ber = if g.chance(0.5) { 1e-3 } else { 0.0 };
            let reorder = g.below(5) as f64 / 100.0;
            let sr = g.chance(0.5);
            let adaptive = g.chance(0.5);
            let seed = g.below(1 << 32);
            (credits, msgs, drop, ber, reorder, sr, adaptive, seed)
        },
        |&(credits, msgs, drop, ber, reorder, sr, adaptive, seed)| {
            let mut cfg = LinkConfig::eci();
            cfg.credits_per_vc = credits;
            let spec = FaultSpec { ber, drop, reorder, burst_len: 1.0 };
            let mode = if sr { RelMode::SelectiveRepeat } else { RelMode::GoBackN };
            let rel = RelConfig::new(FaultConfig::new(spec, seed ^ 0xFA17))
                .with_mode(mode)
                .with_adaptive_rto(adaptive);
            let mut ing = FramedIngress::with_rel(cfg, Node::Remote, Rng::new(seed), rel);
            let mut dcs = Dcs::with_reference_rules(
                DcsConfig::new(2).with_slice_proc(Duration::ZERO),
            );
            let mut ram = MemStore::new(LineAddr(0), 64 * 128);
            let mut rng = Rng::new(seed ^ 0xF00D);
            for i in 0..msgs {
                let addr = LineAddr(rng.below(64));
                ing.offer(Message::coh_req(
                    ReqId(i as u32),
                    Node::Remote,
                    CohOp::ReadShared,
                    addr,
                ));
            }
            let budget = credits * NUM_VCS as u32;
            let mut now = Time(0);
            let mut serviced = 0u64;
            let mut idle_rounds = 0u32;
            while serviced < msgs || ing.rel_unacked() > 0 {
                let mut out = Vec::new();
                ing.pump(now, &mut out);
                // an event queue would deliver in arrival order; the
                // reordered frames carry late stamps
                out.sort_by_key(|(at, _)| *at);
                let progressed = !out.is_empty();
                for (at, f) in out {
                    if at > now {
                        now = at;
                    }
                    // replay never re-consumes a credit: the budget
                    // bounds in-flight at EVERY step, faults or not
                    assert!(
                        ing.in_flight_total() <= budget,
                        "in-flight {} exceeds budget {budget}",
                        ing.in_flight_total()
                    );
                    let (mut del, mut ctls) = (Vec::new(), Vec::new());
                    ing.deliver(f, &mut del, &mut ctls);
                    for c in ctls {
                        ing.on_control(now, c);
                    }
                    for fr in del {
                        dcs.enqueue_frame(now, fr);
                    }
                }
                // frames queued at the directory are a subset of the
                // launched-but-unreturned ones (the rest are in flight,
                // lost, or awaiting replay)
                assert!(
                    dcs.pending() <= ing.in_flight_total() as usize,
                    "dcs holds {} frames but only {} credits are out",
                    dcs.pending(),
                    ing.in_flight_total()
                );
                for s in 0..dcs.slices() {
                    while let Some(sv) = dcs.service_one(s, now, &mut ram) {
                        let SliceService::Done(_, vc, _, _) = sv else {
                            panic!("zero-occupancy slice reported busy")
                        };
                        ing.credit_return(vc);
                        serviced += 1;
                    }
                }
                if progressed {
                    idle_rounds = 0;
                } else {
                    // tail loss / unflushed acks: the retransmit timeout
                    idle_rounds += 1;
                    assert!(
                        idle_rounds < 500,
                        "rel link wedged: {serviced}/{msgs} serviced, {} unacked",
                        ing.rel_unacked()
                    );
                    ing.rel_force_replay();
                }
                now = now + Duration::from_ns(200);
            }
            assert_eq!(serviced, msgs, "every message must be serviced exactly once");
            assert_eq!(ing.queued(), 0);
            assert_eq!(
                ing.in_flight_total(),
                0,
                "a replayed loss must not leak a credit"
            );
            assert_eq!(dcs.pending(), 0);
            true
        },
    );
}

/// Selective repeat delivers every frame exactly once and in per-VC
/// send order, under ARBITRARY interleavings of drops, corruption, and
/// wire reordering (the in-flight pool is shuffled before every
/// delivery round, so frames overtake each other freely).
#[test]
fn sr_delivery_is_exactly_once_in_order_under_arbitrary_interleavings() {
    use eci::sim::rng::Rng;
    use eci::sim::time::Time;
    use eci::transport::rel::{RelMode, RelRx, RelTx};
    use eci::transport::{vc_for, Frame};

    Prop::new("selective-repeat exactly-once in-order delivery").cases(25).check(
        |g| {
            let msgs = 200 + g.below(600);
            let drop = g.below(15) as f64 / 100.0; // 0..0.14
            let corrupt = g.below(10) as f64 / 100.0;
            let seed = g.below(1 << 32);
            (msgs, drop, corrupt, seed)
        },
        |&(msgs, drop, corrupt, seed)| {
            let mut rng = Rng::new(seed ^ 0x5E1E);
            let mut tx = RelTx::new(RelMode::SelectiveRepeat);
            let mut rx = RelRx::new(RelMode::SelectiveRepeat, 64);
            let mut inflight: Vec<Frame> = Vec::new();
            let mut sent_order: Vec<Vec<u32>> = vec![Vec::new(); NUM_VCS];
            let mut got_order: Vec<Vec<u32>> = vec![Vec::new(); NUM_VCS];
            let mut next = 0u64;
            let mut idle = 0u32;
            let now = Time(0);
            while got_order.iter().map(Vec::len).sum::<usize>() < msgs as usize {
                // launch a burst: resends first, then fresh traffic
                for _ in 0..(1 + rng.below(8)) {
                    let f = if let Some(f) = tx.next_resend() {
                        f
                    } else if next < msgs {
                        let m = Message::coh_req(
                            ReqId(next as u32),
                            Node::Remote,
                            CohOp::ReadShared,
                            LineAddr(rng.below(1 << 16)),
                        );
                        next += 1;
                        let vc = vc_for(&m);
                        sent_order[vc.0 as usize].push(m.id.0);
                        tx.frame(now, vc, m)
                    } else {
                        break;
                    };
                    if rng.chance(drop) {
                        continue; // swallowed by the wire
                    }
                    let mut f = f;
                    if rng.chance(corrupt) {
                        f.intact = false;
                    }
                    inflight.push(f);
                }
                // deliver a random subset in arbitrary order
                rng.shuffle(&mut inflight);
                let k = rng.below(1 + inflight.len() as u64) as usize;
                let mut progressed = false;
                for f in inflight.drain(..k) {
                    let (mut del, mut ctls) = (Vec::new(), Vec::new());
                    rx.on_frame(f, &mut del, &mut ctls);
                    for g in del {
                        got_order[g.vc.0 as usize].push(g.msg.id.0);
                        progressed = true;
                    }
                    for c in ctls {
                        tx.on_control(now, c);
                    }
                }
                if progressed || next < msgs {
                    idle = 0;
                } else {
                    // tail loss: the retransmit timeout
                    idle += 1;
                    assert!(idle < 400, "selective repeat wedged");
                    tx.force_replay_all();
                }
            }
            assert_eq!(
                got_order, sent_order,
                "delivery must be exactly-once, in per-VC send order"
            );
            true
        },
    );
}

/// Flush-on-slice-dry ordering: with ingress batching on, a batch
/// staged when its slice runs dry is delivered before any
/// later-sequenced frame for that slice — per slice, the serviced order
/// is exactly the arrival order, under arbitrary interleavings of
/// arrivals and service pumping (today only batch-full and transparency
/// are pinned; this pins the dry-flush path).
#[test]
fn batch_flush_on_slice_dry_preserves_arrival_order() {
    use eci::dcs::{Dcs, DcsConfig, SliceService};
    use eci::sim::time::{Duration, Time};
    use eci::transport::Frame;

    #[derive(Clone, Debug)]
    enum Act {
        /// Admit the next sequentially-addressed frame.
        Arrive,
        /// Pump one slice until it runs dry (pulls in staged batches).
        Pump(usize),
    }

    fn service_dry(
        dcs: &mut Dcs,
        s: usize,
        ram: &mut MemStore,
        serviced: &mut [Vec<u64>; 2],
    ) {
        while let Some(sv) = dcs.service_one(s, Time(0), ram) {
            let SliceService::Done(_, _, _, fx) = sv else {
                panic!("zero-occupancy slice reported busy")
            };
            for e in fx {
                if let HomeEffect::Respond { msg, .. } = e {
                    serviced[s].push(msg.addr.0);
                }
            }
        }
    }

    Prop::new("dry-flushed batches precede later-sequenced frames")
        .cases(30)
        .max_size(120)
        .check_vec(
            |g| match g.below(4) {
                0 | 1 => Act::Arrive,
                2 => Act::Pump(0),
                _ => Act::Pump(1),
            },
            |acts| {
                let mut dcs = Dcs::with_reference_rules(
                    DcsConfig::new(2).with_slice_proc(Duration::ZERO).with_batch(3),
                );
                let mut ram = MemStore::new(LineAddr(0), 1024 * 128);
                let mut arrivals: [Vec<u64>; 2] = [Vec::new(), Vec::new()];
                let mut serviced: [Vec<u64>; 2] = [Vec::new(), Vec::new()];
                let mut next = 0u64;
                let mut seq = 0u64;
                for act in acts {
                    match act {
                        Act::Arrive => {
                            // distinct lines: each request is serviced
                            // exactly once and is identified by its addr
                            let addr = next;
                            next += 1;
                            let m = Message::coh_req(
                                ReqId(addr as u32),
                                Node::Remote,
                                CohOp::ReadShared,
                                LineAddr(addr),
                            );
                            let s = dcs.enqueue_frame(Time(0), Frame::new(seq, m));
                            seq += 1;
                            arrivals[s].push(addr);
                        }
                        Act::Pump(s) => service_dry(&mut dcs, *s, &mut ram, &mut serviced),
                    }
                }
                service_dry(&mut dcs, 0, &mut ram, &mut serviced);
                service_dry(&mut dcs, 1, &mut ram, &mut serviced);
                assert_eq!(dcs.pending(), 0, "trace must quiesce");
                // per slice, service order == arrival order: a staged
                // batch can never be overtaken by a later frame
                serviced == arrivals
            },
        );
}
