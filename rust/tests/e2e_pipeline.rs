//! End-to-end integration: the AOT XLA kernels (Layer 1/2) composed with
//! the full machine (Layer 3) — a test-sized version of
//! `examples/e2e_select_serve.rs`. Skipped when artifacts are missing
//! (run `make artifacts`).

use std::cell::RefCell;
use std::rc::Rc;

use eci::agents::dram::MemStore;
use eci::machine::{map, FpgaApp, Machine, MachineConfig, Workload};
use eci::memctl::{regex_row_cycles, FifoServer, ScanTiming};
use eci::operators::redfa::compile_regex;
use eci::operators::regex_op::{cpu_regex_scan, fpga_regex_scan};
use eci::operators::select::{cpu_select_scan, fpga_select_scan};
use eci::operators::table::{build_table, row_str, select_params, TableSpec};
use eci::proto::messages::{LineAddr, LINE_BYTES};
use eci::runtime::{Manifest, Runtime, DFA_STATES};
use eci::sim::time::Duration;

fn runtime() -> Option<Runtime> {
    // the native executor (default build) needs no artifacts; the PJRT
    // executor behind `--features xla` does
    if cfg!(feature = "xla") && !Manifest::default_dir().join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::load_default().unwrap())
}

#[test]
fn select_pushdown_serves_exactly_the_matching_rows() {
    let Some(mut rt) = runtime() else { return };
    let rows = 50_000u64;
    let spec = TableSpec::new(rows, 0.07);
    let mut store = MemStore::new(map::TABLE_BASE, rows as usize * LINE_BYTES);
    build_table(&spec, &mut store);
    let (x, y) = select_params(0.07);
    let matches = fpga_select_scan(&mut rt, &store, map::TABLE_BASE, rows, x, y).unwrap();
    assert_eq!(matches, cpu_select_scan(&store, map::TABLE_BASE, rows, x, y));
    let n = matches.len();
    let payloads: Vec<_> = matches
        .iter()
        .map(|&i| Box::new(store.read_line(LineAddr(map::TABLE_BASE.0 + i))))
        .collect();
    // every served payload must be one of the matched rows, in order
    let expected: Vec<[u8; 8]> = payloads.iter().map(|p| p[0..8].try_into().unwrap()).collect();
    let fifo = FifoServer::new(rows, matches, payloads, |_| 1, ScanTiming::enzian(8), 4096);

    let mut m = Machine::new(
        MachineConfig::test_small(),
        FpgaApp::Fifo(fifo),
        store,
        MemStore::new(LineAddr(0), 1 << 20),
    );
    let order = Rc::new(RefCell::new(Vec::<[u8; 8]>::new()));
    {
        let order = Rc::clone(&order);
        m.verify_fill = Some(Box::new(move |_a, data| {
            if !(data[0] == 0xFF && data[..8].iter().all(|&b| b == 0xFF)) {
                order.borrow_mut().push(data[0..8].try_into().unwrap());
            }
        }));
    }
    m.set_workload(Workload::FifoConsume { think: Duration::from_ns(5) }, 4);
    let r = m.run();
    assert_eq!(r.results as usize, n);
    assert_eq!(*order.borrow(), expected, "results must arrive complete and in scan order");
}

#[test]
fn regex_pushdown_end_to_end_with_engine_timing() {
    let Some(mut rt) = runtime() else { return };
    let rows = 30_000u64;
    let spec = TableSpec::new(rows, 0.12);
    let mut store = MemStore::new(map::TABLE_BASE, rows as usize * LINE_BYTES);
    build_table(&spec, &mut store);
    let dfa = compile_regex(&spec.needle, DFA_STATES).unwrap();
    let matches = fpga_regex_scan(&mut rt, &store, map::TABLE_BASE, rows, &dfa).unwrap();
    assert_eq!(matches, cpu_regex_scan(&store, map::TABLE_BASE, rows, &dfa));
    assert_eq!(matches.len(), (rows as f64 * 0.12).round() as usize);
    let payloads: Vec<_> = matches
        .iter()
        .map(|&i| Box::new(store.read_line(LineAddr(map::TABLE_BASE.0 + i))))
        .collect();
    let cycles: Vec<u64> = (0..rows)
        .map(|i| regex_row_cycles(&dfa, row_str(&store.read_line(LineAddr(map::TABLE_BASE.0 + i)))))
        .collect();
    let n = matches.len();
    let fifo = FifoServer::new(rows, matches, payloads, move |r| cycles[r as usize], ScanTiming::enzian(48), 4096);
    let mut m = Machine::new(
        MachineConfig::test_small(),
        FpgaApp::Fifo(fifo),
        store,
        MemStore::new(LineAddr(0), 1 << 20),
    );
    m.set_workload(Workload::FifoConsume { think: Duration::from_ns(5) }, 4);
    let r = m.run();
    assert_eq!(r.results as usize, n);
    assert!(r.sim_time.as_secs() > 0.0);
}

#[test]
fn kvs_requests_resolve_through_engine_pool() {
    let Some(mut rt) = runtime() else { return };
    use eci::memctl::KvsService;
    use eci::operators::kvs::{fpga_hash_batch, lookup};
    use eci::operators::table::{build_kvs, KvsSpec};

    let spec = KvsSpec { entries: 32_768, chain_len: 4, seed: 3 };
    let mut store = MemStore::new(map::TABLE_BASE, 2 * 32_768 * LINE_BYTES);
    let layout = build_kvs(&spec, &mut store);
    let keys: Vec<i32> = layout.tail_keys.iter().copied().take(2_000).collect();
    // hash through the XLA kernel and verify routing agrees with builder
    let buckets = fpga_hash_batch(&mut rt, &keys, layout.bucket_mask).unwrap();
    for (i, &k) in keys.iter().enumerate() {
        assert_eq!(buckets[i], eci::runtime::hash_bucket_ref(k, layout.bucket_mask));
    }
    let requests: Vec<(u64, Box<eci::proto::messages::Line>)> = keys
        .iter()
        .map(|&k| {
            let r = lookup(&store, &layout, k);
            assert!(r.found);
            (r.hops, Box::new([k as u8; 128]))
        })
        .collect();
    let lookups = requests.len() as u64;
    let mut m = Machine::new(
        MachineConfig::test_small(),
        FpgaApp::Kvs { svc: KvsService::new(32), requests },
        store,
        MemStore::new(LineAddr(0), 1 << 20),
    );
    m.set_workload(Workload::KvsRemote { lookups }, 4);
    let r = m.run();
    assert_eq!(r.results, lookups);
    // each lookup = 1 bucket + 4 entries of dependent DRAM work
    assert!(r.mean_load_ns() > 400.0, "chains must cost real latency: {}", r.mean_load_ns());
}
