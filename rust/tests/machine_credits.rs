//! Regression tests for the machine-path dcs ingress credit semantics.
//!
//! PR 2 made the *workload engine* hold request credits until the owning
//! directory slice services a message. The machine model, however, kept
//! returning credits at frame ARRIVAL, so under overload the dcs ingress
//! queues could grow far past the link's credit budget — backpressure
//! the real transaction layer would exert simply vanished. These tests
//! pin the fix (credits now flow back at `SliceService::Done`) and would
//! fail under the old hold-until-arrival behavior, where the ingress
//! high-water mark tracks the number of requesting cores instead of the
//! credit budget.

use eci::agents::dram::MemStore;
use eci::machine::{map, Machine, MachineConfig, Op, Workload};
use eci::proto::messages::LineAddr;
use eci::sim::time::Duration;

fn mems() -> (MemStore, MemStore) {
    let mut fpga = MemStore::new(map::TABLE_BASE, 4 << 20);
    for i in 0..4096u64 {
        let mut l = [0u8; 128];
        l[0..8].copy_from_slice(&i.to_le_bytes());
        fpga.write_line(LineAddr(map::TABLE_BASE.0 + i), &l);
    }
    let cpu = MemStore::new(LineAddr(0), 1 << 20);
    (fpga, cpu)
}

fn a(i: u64) -> LineAddr {
    LineAddr(map::TABLE_BASE.0 + i)
}

/// Mirror of the workload-path credit property: a single slow slice
/// flooded by many streaming cores. In-flight (= ingress-held) frames
/// ride two request VCs (even/odd lines), so the ingress high-water mark
/// is bounded by twice the per-VC credit budget — NOT by the 48 cores
/// that are all trying to issue at once.
#[test]
fn overloaded_dcs_ingress_is_bounded_by_request_credits() {
    let mut cfg = MachineConfig::test_small();
    cfg.cpu.cores = 48;
    // freeze the directory relative to the link: every arrival piles up
    cfg.home_proc = Duration::from_us(2);
    let (fpga, cpu) = mems();
    let mut m = Machine::dcs_node(cfg, 1, fpga, cpu);
    // 2000 lines fit the 2048-line LLC: pure read traffic, no writebacks
    m.set_workload(Workload::StreamRemote { lines: 2000 }, 48);
    let r = m.run();
    let peak = r.counters.get("dcs_ingress_peak");
    let per_vc = cfg.link.credits_per_vc as u64;
    assert!(
        peak >= per_vc,
        "overload never pressed the ingress (peak {peak}, credits/VC {per_vc})"
    );
    assert!(
        peak <= 2 * per_vc,
        "ingress peak {peak} exceeds the 2-request-VC credit budget {} — \
         credits are being returned before slice service",
        2 * per_vc
    );
}

/// The old hold-until-arrival behavior is gone: with every request on
/// ONE VC (even lines only) and the slice pipeline frozen, at most
/// `credits_per_vc` messages can ever sit at the dcs ingress. Under the
/// old semantics the queue grew to one entry per requesting core (24
/// here), because arrival recycled the credit immediately.
#[test]
fn single_vc_ingress_peak_stops_at_the_credit_budget() {
    let mut cfg = MachineConfig::test_small();
    cfg.cpu.cores = 24;
    cfg.home_proc = Duration::from_us(2);
    let (fpga, cpu) = mems();
    let mut m = Machine::dcs_node(cfg, 1, fpga, cpu);
    // one load per core, all even lines -> all on the even request VC
    let programs: Vec<Vec<Op>> = (0..24u64).map(|c| vec![Op::Load(a(2 * c))]).collect();
    m.set_workload(Workload::Script { programs }, 24);
    let r = m.run();
    let peak = r.counters.get("dcs_ingress_peak");
    let per_vc = cfg.link.credits_per_vc as u64;
    assert!(peak >= per_vc.saturating_sub(2), "expected credit-limit pressure, peak {peak}");
    assert!(
        peak <= per_vc,
        "ingress peak {peak} exceeds the single-VC budget {per_vc}: \
         the old return-at-arrival behavior is back"
    );
    // every core still completed its load (backpressure, not starvation)
    assert_eq!(r.load_lat.count(), 24);
}

/// Credit deferral must not change what the machine computes: the same
/// stream delivers the same bytes, and a cached sliced node at default
/// timing still completes with bounded ingress occupancy.
#[test]
fn bounded_ingress_still_delivers_correct_data() {
    let cfg = MachineConfig::test_small();
    let (fpga, cpu) = mems();
    let mut m = Machine::dcs_cached_node(cfg, 2, fpga, cpu);
    let bad = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
    {
        let bad2 = std::sync::Arc::clone(&bad);
        m.verify_fill = Some(Box::new(move |addr, data| {
            let i = addr.0 - map::TABLE_BASE.0;
            let got = u64::from_le_bytes(data[0..8].try_into().unwrap());
            if got != i {
                bad2.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }
        }));
    }
    m.set_workload(Workload::StreamRemote { lines: 1500 }, 4);
    let r = m.run();
    assert_eq!(bad.load(std::sync::atomic::Ordering::Relaxed), 0, "payload corruption");
    assert_eq!(r.remote_bytes, 1500 * 128);
    let peak = r.counters.get("dcs_ingress_peak");
    assert!(peak >= 1);
    // 4 closed-loop cores can never hold more than 4 reads + their
    // release traffic; far below the budget, but still bounded by it
    let budget = (cfg.link.credits_per_vc as u64) * eci::transport::NUM_VCS as u64;
    assert!(peak <= budget, "peak {peak} vs budget {budget}");
}
