//! Full-system coherence litmus tests: scripted core programs running on
//! the complete stack (caches + agents + transport + home node), checking
//! the invariants the protocol exists to provide — data-value coherence,
//! store visibility through writebacks, recall correctness.

use eci::agents::dram::MemStore;
use eci::machine::{map, Machine, MachineConfig, Op, Workload};
use eci::proto::messages::{LineAddr, LINE_BYTES};
use eci::sim::time::Duration;

fn machine() -> Machine {
    let cfg = MachineConfig::test_small();
    let mut fpga = MemStore::new(map::TABLE_BASE, 1 << 20);
    for i in 0..1024u64 {
        let mut l = [0u8; LINE_BYTES];
        l[0..8].copy_from_slice(&(1000 + i).to_le_bytes());
        fpga.write_line(LineAddr(map::TABLE_BASE.0 + i), &l);
    }
    let cpu = MemStore::new(LineAddr(0), 1 << 20);
    Machine::memory_node(cfg, fpga, cpu)
}

fn a(i: u64) -> LineAddr {
    LineAddr(map::TABLE_BASE.0 + i)
}

#[test]
fn store_then_evict_reaches_fpga_memory() {
    // Core 0 dirties a remote line, then touches enough conflicting lines
    // to evict it; the dirty writeback must land in FPGA memory.
    let mut m = machine();
    let target = a(0);
    let mut prog = vec![Op::Store(target, 0xDEAD_BEEF)];
    // the test LLC is 256 KiB 16-way = 128 sets; lines at stride 128
    // (set 0) conflict; 20 fills overflow the 16 ways
    for k in 1..=20u64 {
        prog.push(Op::Load(a(k * 128)));
    }
    prog.push(Op::Think(Duration::from_us(2)));
    m.set_workload(Workload::Script { programs: vec![prog] }, 1);
    let r = m.run();
    assert!(r.counters.get("end_marker_seen") == 0);
    let line = m.fpga_mem.read_line(target);
    assert_eq!(
        u64::from_le_bytes(line[0..8].try_into().unwrap()),
        0xDEAD_BEEF,
        "dirty writeback must reach the home's backing store"
    );
}

#[test]
fn store_visibility_across_cores_through_shared_llc() {
    // Core 0 stores; core 1 loads the same line later (think delay).
    // Both share the LLC, so the load must see the store (single socket,
    // but the line is REMOTE — exercising the E/M fill path).
    let mut m = machine();
    let target = a(7);
    let p0 = vec![Op::Store(target, 42)];
    let p1 = vec![Op::Think(Duration::from_us(10)), Op::Load(target)];
    m.set_workload(Workload::Script { programs: vec![p0, p1] }, 2);
    m.run();
    // the LLC copy must be M with the stored value
    // (end state visible via a third read through fpga memory writeback:
    //  force writeback by dropping the machine's LLC — instead assert via
    //  a follow-up machine run: simpler: check it did NOT write back and
    //  the line is dirty in cache semantics by reading fpga mem: must
    //  still hold the ORIGINAL value)
    let line = m.fpga_mem.read_line(target);
    assert_eq!(
        u64::from_le_bytes(line[0..8].try_into().unwrap()),
        1007,
        "no writeback happened; home copy is stale by design (single-writer)"
    );
}

#[test]
fn read_after_remote_write_round_trip() {
    // Store to remote line, evict (writeback), then read it back:
    // the read must observe the stored value after the full round trip.
    let mut m = machine();
    let target = a(3);
    let mut prog = vec![Op::Store(target, 0xC0FFEE)];
    for k in 1..=20u64 {
        prog.push(Op::Load(a(k * 128 + 3))); // same set as target (stride 128)
    }
    prog.push(Op::Load(target));
    m.set_workload(Workload::Script { programs: vec![prog] }, 1);
    let seen_value = std::rc::Rc::new(std::cell::RefCell::new(None::<u64>));
    {
        let seen = std::rc::Rc::clone(&seen_value);
        m.verify_fill = Some(Box::new(move |addr, data| {
            if addr == LineAddr(map::TABLE_BASE.0 + 3) {
                *seen.borrow_mut() = Some(u64::from_le_bytes(data[0..8].try_into().unwrap()));
            }
        }));
    }
    m.run();
    let got = *seen_value.borrow();
    // either the final fill carried the written value, or the line never
    // left the cache (no eviction) — in both cases fpga_mem or cache must
    // hold 0xC0FFEE; check the authoritative copy:
    let line_mem = m.fpga_mem.read_line(target);
    let mem_val = u64::from_le_bytes(line_mem[0..8].try_into().unwrap());
    if let Some(v) = got {
        assert_eq!(v, 0xC0FFEE, "re-read must observe the written value");
        assert_eq!(mem_val, 0xC0FFEE);
    } else {
        // never evicted: memory may be stale but the LLC holds M data.
        // Force the invariant check through memory: eviction must have
        // happened given 21 same-set fills vs 16 ways:
        panic!("expected the target line to be evicted and re-fetched");
    }
}

#[test]
fn many_cores_hammering_one_line_stay_coherent() {
    // 4 cores interleave loads of one line; MSHR merging must produce one
    // remote transaction wave, and everyone sees the same data.
    let mut m = machine();
    let target = a(11);
    let progs: Vec<Vec<Op>> = (0..4)
        .map(|_| (0..16).map(|_| Op::Load(target)).collect())
        .collect();
    m.set_workload(Workload::Script { programs: progs }, 4);
    let bad = std::rc::Rc::new(std::cell::RefCell::new(0u32));
    {
        let bad2 = std::rc::Rc::clone(&bad);
        m.verify_fill = Some(Box::new(move |_addr, data| {
            let v = u64::from_le_bytes(data[0..8].try_into().unwrap());
            if v != 1011 {
                *bad2.borrow_mut() += 1;
            }
        }));
    }
    let r = m.run();
    assert_eq!(*bad.borrow(), 0);
    // one ReadShared should have been enough (MSHR merge): the counter is
    // on the remote agent; check via requests observed at the home
    assert!(
        r.counters.get("fifo_reads") == 0,
        "memory-node config should not touch the fifo path"
    );
}

#[test]
fn io_config_round_trip_over_protocol() {
    // Write the SELECT parameters through ECI I/O messages, read back.
    let mut m = machine();
    let x = 0.25f32.to_bits() as u64;
    let y = 0.75f32.to_bits() as u64;
    use eci::memctl::config_block::regs;
    let prog = vec![
        Op::IoWrite(regs::SELECT_X, x),
        Op::IoWrite(regs::SELECT_Y, y),
        Op::IoRead(regs::SELECT_X),
        Op::IoRead(regs::SELECT_Y),
    ];
    m.set_workload(Workload::Script { programs: vec![prog] }, 1);
    m.run();
    let (gx, gy) = m.config_block.select_params();
    assert_eq!((gx, gy), (0.25, 0.75));
    assert_eq!(m.config_block.writes, 2);
    assert!(m.config_block.reads >= 2);
}

#[test]
fn deterministic_replay_same_seed_same_timeline() {
    let run = || {
        let mut m = machine();
        m.set_workload(Workload::StreamRemote { lines: 500 }, 3);
        let r = m.run();
        (r.sim_time, r.events, r.remote_bytes)
    };
    assert_eq!(run(), run(), "simulation must be bit-reproducible");
}
