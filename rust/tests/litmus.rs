//! Full-system coherence litmus tests: scripted core programs running on
//! the complete stack (caches + agents + transport + home node), checking
//! the invariants the protocol exists to provide — data-value coherence,
//! store visibility through writebacks, recall correctness.
//!
//! Every scenario runs against FOUR home-side configurations: the
//! monolithic `Machine::memory_node` (the paper's symmetric baseline)
//! and the sliced cached `Machine::dcs_cached_node` at 1, 2 and 4
//! slices. Sharding the directory and giving each slice a home-cache
//! partition must be invisible to protocol outcomes — every observable
//! (writeback bytes in FPGA memory, fill payloads seen by cores, I/O
//! round trips) is asserted identical across all configurations.

use eci::agents::dram::MemStore;
use eci::machine::{map, Machine, MachineConfig, Op, Workload};
use eci::proto::messages::{LineAddr, LINE_BYTES};
use eci::sim::time::Duration;
use eci::transport::rel::{FaultConfig, FaultSpec, RelConfig, RelMode};

/// Home-side configurations under test: `None` = monolithic memory
/// node, `Some(n)` = sliced cached directory with `n` slices.
const CONFIGS: [Option<usize>; 4] = [None, Some(1), Some(2), Some(4)];

fn config_name(c: Option<usize>) -> String {
    match c {
        None => "memory_node".into(),
        Some(n) => format!("dcs_cached_node x{n}"),
    }
}

/// The lossy-link configuration the environment asks for, if any (see
/// `machine_with`).
fn rel_from_env() -> Option<RelConfig> {
    let v = std::env::var("ECI_LITMUS_FAULTS").ok()?;
    if v.is_empty() || v == "off" {
        return None;
    }
    let ber: f64 = v.parse().expect("ECI_LITMUS_FAULTS must be a bit-error rate (or `off`)");
    let spec = FaultSpec {
        ber,
        drop: (ber * 20.0).min(0.05),
        reorder: (ber * 20.0).min(0.05),
        burst_len: 1.0,
    };
    let mut rel = RelConfig::new(FaultConfig::new(spec, 7));
    match std::env::var("ECI_LITMUS_REL_MODE").ok().filter(|m| !m.is_empty()) {
        None => {}
        Some(m) => match RelMode::parse(&m) {
            Some(RelMode::GoBackN) => {}
            Some(RelMode::SelectiveRepeat) => {
                rel = rel.with_mode(RelMode::SelectiveRepeat).with_adaptive_rto(true);
            }
            None => panic!("ECI_LITMUS_REL_MODE must be gbn or sr, got {m:?}"),
        },
    }
    Some(rel)
}

fn machine_with(config: Option<usize>) -> Machine {
    let mut cfg = MachineConfig::test_small();
    // Loss-transparency gate: `ECI_LITMUS_FAULTS=<ber>` reruns the whole
    // suite over the reliable lossy link (`transport::rel`; drops and
    // reordering derive from the one knob) — every assertion must hold
    // unchanged, because loss changes timing, never semantics. The
    // retransmission discipline is part of the gate:
    // `ECI_LITMUS_REL_MODE=sr` runs selective repeat (with the adaptive
    // RTO, gating both new knobs at once); the default is go-back-N.
    // CI runs the suite clean, then faulted under BOTH modes. Empty /
    // "off" values mean unset, so a CI matrix can pass them literally.
    if let Some(rel) = rel_from_env() {
        cfg.rel = Some(rel);
    }
    let mut fpga = MemStore::new(map::TABLE_BASE, 1 << 20);
    for i in 0..1024u64 {
        let mut l = [0u8; LINE_BYTES];
        l[0..8].copy_from_slice(&(1000 + i).to_le_bytes());
        fpga.write_line(LineAddr(map::TABLE_BASE.0 + i), &l);
    }
    let cpu = MemStore::new(LineAddr(0), 1 << 20);
    match config {
        None => Machine::memory_node(cfg, fpga, cpu),
        Some(n) => Machine::dcs_cached_node(cfg, n, fpga, cpu),
    }
}

fn a(i: u64) -> LineAddr {
    LineAddr(map::TABLE_BASE.0 + i)
}

#[test]
fn store_then_evict_reaches_fpga_memory() {
    // Core 0 dirties a remote line, then touches enough conflicting lines
    // to evict it; the dirty writeback must land in FPGA memory — also
    // through a cached slice (`cache_writebacks` stays off: the backing
    // store remains authoritative for dirty data).
    for config in CONFIGS {
        let name = config_name(config);
        let mut m = machine_with(config);
        let target = a(0);
        let mut prog = vec![Op::Store(target, 0xDEAD_BEEF)];
        // the test LLC is 256 KiB 16-way = 128 sets; lines at stride 128
        // (set 0) conflict; 20 fills overflow the 16 ways
        for k in 1..=20u64 {
            prog.push(Op::Load(a(k * 128)));
        }
        prog.push(Op::Think(Duration::from_us(2)));
        m.set_workload(Workload::Script { programs: vec![prog] }, 1);
        let r = m.run();
        // settle in-flight writebacks (under fault injection the final
        // replay can outlive the cores)
        m.drain();
        assert!(r.counters.get("end_marker_seen") == 0, "{name}");
        let line = m.fpga_mem.read_line(target);
        assert_eq!(
            u64::from_le_bytes(line[0..8].try_into().unwrap()),
            0xDEAD_BEEF,
            "{name}: dirty writeback must reach the home's backing store"
        );
    }
}

#[test]
fn store_visibility_across_cores_through_shared_llc() {
    // Core 0 stores; core 1 loads the same line later (think delay).
    // Both share the LLC, so the load must see the store (single socket,
    // but the line is REMOTE — exercising the E/M fill path).
    for config in CONFIGS {
        let name = config_name(config);
        let mut m = machine_with(config);
        let target = a(7);
        let p0 = vec![Op::Store(target, 42)];
        let p1 = vec![Op::Think(Duration::from_us(10)), Op::Load(target)];
        m.set_workload(Workload::Script { programs: vec![p0, p1] }, 2);
        m.run();
        // no writeback happened; the home copy is stale by design
        // (single-writer) — in EVERY configuration
        let line = m.fpga_mem.read_line(target);
        assert_eq!(
            u64::from_le_bytes(line[0..8].try_into().unwrap()),
            1007,
            "{name}: home copy must be untouched while the remote owns the line"
        );
    }
}

#[test]
fn read_after_remote_write_round_trip() {
    // Store to remote line, evict (writeback), then read it back:
    // the read must observe the stored value after the full round trip —
    // in the cached configurations the re-read refills the home cache
    // from the POST-writeback bytes, so a stale-cache bug shows here.
    for config in CONFIGS {
        let name = config_name(config);
        let mut m = machine_with(config);
        let target = a(3);
        let mut prog = vec![Op::Store(target, 0xC0FFEE)];
        for k in 1..=20u64 {
            prog.push(Op::Load(a(k * 128 + 3))); // same set as target (stride 128)
        }
        prog.push(Op::Load(target));
        m.set_workload(Workload::Script { programs: vec![prog] }, 1);
        let seen_value = std::rc::Rc::new(std::cell::RefCell::new(None::<u64>));
        {
            let seen = std::rc::Rc::clone(&seen_value);
            m.verify_fill = Some(Box::new(move |addr, data| {
                if addr == LineAddr(map::TABLE_BASE.0 + 3) {
                    *seen.borrow_mut() =
                        Some(u64::from_le_bytes(data[0..8].try_into().unwrap()));
                }
            }));
        }
        m.run();
        m.drain();
        let got = *seen_value.borrow();
        let line_mem = m.fpga_mem.read_line(target);
        let mem_val = u64::from_le_bytes(line_mem[0..8].try_into().unwrap());
        match got {
            Some(v) => {
                assert_eq!(v, 0xC0FFEE, "{name}: re-read must observe the written value");
                assert_eq!(mem_val, 0xC0FFEE, "{name}");
            }
            None => panic!("{name}: expected the target line to be evicted and re-fetched"),
        }
    }
}

#[test]
fn many_cores_hammering_one_line_stay_coherent() {
    // 4 cores interleave loads of one line; MSHR merging must produce one
    // remote transaction wave, and everyone sees the same data.
    for config in CONFIGS {
        let name = config_name(config);
        let mut m = machine_with(config);
        let target = a(11);
        let progs: Vec<Vec<Op>> = (0..4)
            .map(|_| (0..16).map(|_| Op::Load(target)).collect())
            .collect();
        m.set_workload(Workload::Script { programs: progs }, 4);
        let bad = std::rc::Rc::new(std::cell::RefCell::new(0u32));
        {
            let bad2 = std::rc::Rc::clone(&bad);
            m.verify_fill = Some(Box::new(move |_addr, data| {
                let v = u64::from_le_bytes(data[0..8].try_into().unwrap());
                if v != 1011 {
                    *bad2.borrow_mut() += 1;
                }
            }));
        }
        let r = m.run();
        assert_eq!(*bad.borrow(), 0, "{name}");
        assert!(
            r.counters.get("fifo_reads") == 0,
            "{name}: home-node configs should not touch the fifo path"
        );
    }
}

#[test]
fn io_config_round_trip_over_protocol() {
    // Write the SELECT parameters through ECI I/O messages, read back.
    // I/O rides its own VCs and must bypass the sliced directory (and
    // its deferred credit return) in every configuration.
    for config in CONFIGS {
        let name = config_name(config);
        let mut m = machine_with(config);
        let x = 0.25f32.to_bits() as u64;
        let y = 0.75f32.to_bits() as u64;
        use eci::memctl::config_block::regs;
        let prog = vec![
            Op::IoWrite(regs::SELECT_X, x),
            Op::IoWrite(regs::SELECT_Y, y),
            Op::IoRead(regs::SELECT_X),
            Op::IoRead(regs::SELECT_Y),
        ];
        m.set_workload(Workload::Script { programs: vec![prog] }, 1);
        m.run();
        let (gx, gy) = m.config_block.select_params();
        assert_eq!((gx, gy), (0.25, 0.75), "{name}");
        assert_eq!(m.config_block.writes, 2, "{name}");
        assert!(m.config_block.reads >= 2, "{name}");
    }
}

#[test]
fn deterministic_replay_same_seed_same_timeline() {
    for config in CONFIGS {
        let name = config_name(config);
        let run = || {
            let mut m = machine_with(config);
            m.set_workload(Workload::StreamRemote { lines: 500 }, 3);
            let r = m.run();
            (r.sim_time, r.events, r.remote_bytes)
        };
        assert_eq!(run(), run(), "{name}: simulation must be bit-reproducible");
    }
}

#[test]
fn stream_fill_payloads_identical_across_configurations() {
    // The same streamed region must deliver byte-identical fill payloads
    // on every configuration — the end-to-end "sharded + cached home is
    // protocol-invisible" check, including the home-cache hit path
    // (lines evicted from the LLC and re-read under capacity pressure).
    let run = |config: Option<usize>| {
        let mut m = machine_with(config);
        let sums = std::rc::Rc::new(std::cell::RefCell::new(std::collections::BTreeMap::new()));
        {
            let sums2 = std::rc::Rc::clone(&sums);
            m.verify_fill = Some(Box::new(move |addr, data| {
                let v = u64::from_le_bytes(data[0..8].try_into().unwrap());
                *sums2.borrow_mut().entry(addr.0).or_insert(0u64) += v;
            }));
        }
        m.set_workload(Workload::StreamRemote { lines: 1024 }, 4);
        let r = m.run();
        assert_eq!(r.remote_bytes, 1024 * 128);
        let out = sums.borrow().clone();
        out
    };
    let baseline = run(None);
    for config in [Some(1), Some(2), Some(4)] {
        let got = run(config);
        assert_eq!(
            got,
            baseline,
            "{}: fill payloads diverge from memory_node",
            config_name(config)
        );
    }
}
