//! AOT artifact manifest: locates `artifacts/*.hlo.txt` and validates the
//! shapes the Python side baked in (`python/compile/aot.py`).

use std::path::{Path, PathBuf};

use crate::anyhow::{self, bail, Context, Result};

use crate::trace::json::{parse, Json};

/// Geometry constants mirrored from `python/compile/kernels/ref.py`.
/// Checked against the manifest at load time.
pub const BATCH: usize = 4096;
pub const ROW_WORDS: usize = 32;
pub const STR_LEN: usize = 62;
pub const DFA_STATES: usize = 32;

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .context("missing shape")?
            .iter()
            .map(|v| v.as_u64().map(|x| x as usize).context("bad dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j.get("dtype").and_then(Json::as_str).context("missing dtype")?.to_string();
        Ok(TensorSpec { shape, dtype })
    }
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct OpArtifact {
    pub name: String,
    pub hlo_path: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub ops: Vec<OpArtifact>,
}

impl Manifest {
    /// Load `<dir>/manifest.json` and validate geometry.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let j = parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;

        let geo = j.get("geometry").context("missing geometry")?;
        let batch = geo.get("batch").and_then(Json::as_u64).context("batch")? as usize;
        if batch != BATCH {
            bail!("manifest batch {batch} != compiled-in {BATCH}");
        }
        for (key, want) in [
            ("row_words", ROW_WORDS),
            ("str_len", STR_LEN),
            ("dfa_states", DFA_STATES),
        ] {
            let got = geo.get(key).and_then(Json::as_u64).context(key)? as usize;
            if got != want {
                bail!("manifest {key} {got} != compiled-in {want}");
            }
        }

        let mut ops = Vec::new();
        let Json::Obj(map) = j.get("ops").context("missing ops")? else {
            bail!("ops is not an object");
        };
        for (name, op) in map {
            let file = op.get("file").and_then(Json::as_str).context("file")?;
            let hlo_path = dir.join(file);
            if !hlo_path.exists() {
                bail!("artifact {} missing (run `make artifacts`)", hlo_path.display());
            }
            let inputs = op
                .get("inputs")
                .and_then(Json::as_arr)
                .context("inputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = op
                .get("outputs")
                .and_then(Json::as_arr)
                .context("outputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            ops.push(OpArtifact { name: name.clone(), hlo_path, inputs, outputs });
        }
        Ok(Manifest { dir, ops })
    }

    pub fn op(&self, name: &str) -> Option<&OpArtifact> {
        self.ops.iter().find(|o| o.name == name)
    }

    /// Default artifact directory: `$ECI_ARTIFACTS` or `<repo>/artifacts`.
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("ECI_ARTIFACTS") {
            return PathBuf::from(p);
        }
        // rust/ crate root -> repo root
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_manifest_when_built() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.ops.len(), 3);
        let select = m.op("select").unwrap();
        assert_eq!(select.inputs[0].shape, vec![BATCH, ROW_WORDS]);
        assert_eq!(select.inputs[0].dtype, "float32");
        assert_eq!(select.outputs.len(), 2);
        let regex = m.op("regex").unwrap();
        assert_eq!(regex.inputs[1].shape, vec![256, DFA_STATES, DFA_STATES]);
        let hash = m.op("hash").unwrap();
        assert_eq!(hash.outputs[0].shape, vec![BATCH]);
    }
}
