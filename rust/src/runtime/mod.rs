//! Layer-3 ⇄ Layer-2 bridge: load the AOT-compiled operator graphs
//! (HLO text, produced once by `python/compile/aot.py`) into a PJRT CPU
//! client and execute them from the coordinator's hot path. Python is
//! never on the request path.

pub mod artifacts;
pub mod pjrt;

pub use artifacts::{Manifest, OpArtifact, TensorSpec, BATCH, DFA_STATES, ROW_WORDS, STR_LEN};
pub use pjrt::{hash_bucket_ref, Runtime};
