//! Layer-3 ⇄ Layer-2 bridge: the operator batch calls behind the
//! coordinator's hot path.
//!
//! Two interchangeable executors provide the same [`Runtime`] API:
//!
//! * [`native`] (default) — a pure-Rust implementation of the kernel
//!   semantics pinned by `python/compile/kernels/ref.py`. Used whenever
//!   the vendored `xla` crate is unavailable (the offline registry).
//! * [`pjrt`] (`--features xla`) — loads the AOT-compiled operator
//!   graphs (HLO text, produced once by `python/compile/aot.py`) into a
//!   PJRT CPU client and executes them per batch. Python is never on the
//!   request path.

pub mod artifacts;
#[cfg(not(feature = "xla"))]
pub mod native;
#[cfg(feature = "xla")]
pub mod pjrt;

pub use artifacts::{Manifest, OpArtifact, TensorSpec, BATCH, DFA_STATES, ROW_WORDS, STR_LEN};
#[cfg(not(feature = "xla"))]
pub use native::Runtime;
#[cfg(feature = "xla")]
pub use pjrt::Runtime;

/// Reference hash, bit-identical to the AOT kernel (`HASH_MULT` fold in
/// `python/compile/kernels/ref.py`) — the single copy both executors and
/// the KVS builder/CPU baseline share, so bucket placement can never
/// drift between build modes.
#[inline]
pub fn hash_bucket_ref(key: i32, bucket_mask: i32) -> i32 {
    let h = key.wrapping_mul(-1640531527i32);
    let h = h ^ ((h as u32) >> 16) as i32;
    h & bucket_mask
}
