//! Native operator executor: the default (offline) implementation of the
//! Layer-2 operator batch calls. Bit-identical to the AOT XLA kernels
//! (semantics pinned by `python/compile/kernels/ref.py`; the integer ops
//! are exact and the f32 comparisons involve no arithmetic, so there is
//! no float drift to worry about). The real PJRT executor lives in
//! [`super::pjrt`] behind the `xla` feature; everything above this module
//! sees the same [`Runtime`] API either way.

use crate::anyhow::{bail, Result};

use super::artifacts::{Manifest, BATCH, DFA_STATES, ROW_WORDS, STR_LEN};
use super::hash_bucket_ref;

/// Dense DFA ready for table-walk evaluation, derived from the one-hot
/// transition tensors the kernel ABI uses.
struct Dfa {
    /// `next[c * DFA_STATES + s]` = successor of state `s` on byte `c`.
    next: Vec<u16>,
    accept: Vec<bool>,
}

/// The native runtime: mirrors the PJRT executor's API and counters.
pub struct Runtime {
    dfa: Option<Dfa>,
    select_invocations: u64,
    regex_invocations: u64,
    hash_invocations: u64,
}

impl Runtime {
    fn native() -> Runtime {
        Runtime {
            dfa: None,
            select_invocations: 0,
            regex_invocations: 0,
            hash_invocations: 0,
        }
    }

    /// Load from the default artifact directory. The native executor
    /// needs no artifacts; when a manifest *is* present it is still
    /// parsed and geometry-validated, so ABI drift between the Python
    /// pipeline and this crate is caught in either mode.
    pub fn load_default() -> Result<Runtime> {
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir)?;
            return Self::load(&m);
        }
        Ok(Runtime::native())
    }

    pub fn load(_manifest: &Manifest) -> Result<Runtime> {
        Ok(Runtime::native())
    }

    /// SELECT pushdown batch: `rows` is `BATCH x ROW_WORDS` f32
    /// (row-major). Returns (mask, count). Predicate: `a > x && b < y`
    /// with `a` = word 0, `b` = word 1 (paper §5.4).
    pub fn select(&mut self, rows: &[f32], x: f32, y: f32) -> Result<(Vec<i32>, i32)> {
        if rows.len() != BATCH * ROW_WORDS {
            bail!("select: rows len {} != {}", rows.len(), BATCH * ROW_WORDS);
        }
        self.select_invocations += 1;
        let mut mask = vec![0i32; BATCH];
        let mut count = 0i32;
        for (r, m) in mask.iter_mut().enumerate() {
            let a = rows[r * ROW_WORDS];
            let b = rows[r * ROW_WORDS + 1];
            if a > x && b < y {
                *m = 1;
                count += 1;
            }
        }
        Ok((mask, count))
    }

    /// Install a DFA for subsequent [`Runtime::regex_batch`] calls.
    /// `tmat` is `256 x S x S` f32 one-hot transition matrices; `accept`
    /// is `S` f32. The one-hot form is collapsed to a dense next-state
    /// table once per install (the kernel pays the matrix products per
    /// batch instead; same function, different hardware shape).
    pub fn set_dfa(&mut self, tmat: &[f32], accept: &[f32]) -> Result<()> {
        if tmat.len() != 256 * DFA_STATES * DFA_STATES || accept.len() != DFA_STATES {
            bail!("regex: bad dfa tensor sizes");
        }
        let mut next = vec![0u16; 256 * DFA_STATES];
        for c in 0..256 {
            for s in 0..DFA_STATES {
                let row = &tmat[c * DFA_STATES * DFA_STATES + s * DFA_STATES..];
                // one-hot row: the set column is the successor; a
                // malformed all-zero row degrades to a self-loop.
                let mut succ = s as u16;
                for (t, &v) in row[..DFA_STATES].iter().enumerate() {
                    if v > 0.5 {
                        succ = t as u16;
                        break;
                    }
                }
                next[c * DFA_STATES + s] = succ;
            }
        }
        let accept = accept.iter().map(|&v| v > 0.5).collect();
        self.dfa = Some(Dfa { next, accept });
        Ok(())
    }

    /// Regex pushdown batch against the installed DFA: `chars` is
    /// `BATCH x STR_LEN` i32 character codes. Returns (mask, count).
    pub fn regex_batch(&mut self, chars: &[i32]) -> Result<(Vec<i32>, i32)> {
        if chars.len() != BATCH * STR_LEN {
            bail!("regex: chars len {} != {}", chars.len(), BATCH * STR_LEN);
        }
        let Some(dfa) = self.dfa.as_ref() else {
            bail!("regex: no DFA installed (call set_dfa)");
        };
        self.regex_invocations += 1;
        let mut mask = vec![0i32; BATCH];
        let mut count = 0i32;
        for (r, m) in mask.iter_mut().enumerate() {
            let mut state = 0usize;
            for &c in &chars[r * STR_LEN..(r + 1) * STR_LEN] {
                let c = (c as u32 as usize) % 256;
                state = dfa.next[c * DFA_STATES + state] as usize;
            }
            if dfa.accept[state] {
                *m = 1;
                count += 1;
            }
        }
        Ok((mask, count))
    }

    /// One-shot convenience: install the DFA and run a single batch.
    pub fn regex(
        &mut self,
        chars: &[i32],
        tmat: &[f32],
        accept: &[f32],
    ) -> Result<(Vec<i32>, i32)> {
        self.set_dfa(tmat, accept)?;
        self.regex_batch(chars)
    }

    /// Hash batch: `keys` is `BATCH` i32; `bucket_mask` = nbuckets-1.
    pub fn hash(&mut self, keys: &[i32], bucket_mask: i32) -> Result<Vec<i32>> {
        if keys.len() != BATCH {
            bail!("hash: keys len {} != {BATCH}", keys.len());
        }
        self.hash_invocations += 1;
        Ok(keys.iter().map(|&k| hash_bucket_ref(k, bucket_mask)).collect())
    }

    pub fn invocations(&self) -> (u64, u64, u64) {
        (self.select_invocations, self.regex_invocations, self.hash_invocations)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn select_matches_scalar_reference() {
        let mut rt = Runtime::native();
        let mut rows = vec![0f32; BATCH * ROW_WORDS];
        let mut s = 1u32;
        for r in 0..BATCH {
            for w in 0..2 {
                s = s.wrapping_mul(1664525).wrapping_add(1013904223);
                rows[r * ROW_WORDS + w] = (s >> 8) as f32 / (1 << 16) as f32 - 128.0;
            }
        }
        let (x, y) = (-20.0f32, 35.0f32);
        let (mask, count) = rt.select(&rows, x, y).unwrap();
        let mut want = 0;
        for r in 0..BATCH {
            let m = (rows[r * ROW_WORDS] > x && rows[r * ROW_WORDS + 1] < y) as i32;
            assert_eq!(mask[r], m, "row {r}");
            want += m;
        }
        assert_eq!(count, want);
        assert!(count > 0 && count < BATCH as i32, "degenerate test data");
    }

    #[test]
    fn regex_finds_planted_strings() {
        let mut rt = Runtime::native();
        // 2-state DFA for "contains byte 'z'": state 0 -'z'-> 1, state 1
        // absorbing; every other state self-loops.
        let mut tmat = vec![0f32; 256 * DFA_STATES * DFA_STATES];
        let mut accept = vec![0f32; DFA_STATES];
        accept[1] = 1.0;
        for c in 0..256 {
            let s0_next = if c == b'z' as usize { 1 } else { 0 };
            tmat[c * DFA_STATES * DFA_STATES + s0_next] = 1.0;
            for s in 1..DFA_STATES {
                tmat[c * DFA_STATES * DFA_STATES + s * DFA_STATES + s] = 1.0;
            }
        }
        let mut chars = vec![0i32; BATCH * STR_LEN];
        for r in (0..BATCH).step_by(7) {
            chars[r * STR_LEN + (r % STR_LEN)] = b'z' as i32;
        }
        let (mask, count) = rt.regex(&chars, &tmat, &accept).unwrap();
        assert_eq!(count as usize, BATCH.div_ceil(7));
        for r in 0..BATCH {
            assert_eq!(mask[r], (r % 7 == 0) as i32, "row {r}");
        }
    }

    #[test]
    fn hash_matches_reference_function() {
        let mut rt = Runtime::native();
        let keys: Vec<i32> =
            (0..BATCH as i32).map(|i| i.wrapping_mul(2654435761u32 as i32) ^ 77).collect();
        let got = rt.hash(&keys, 1023).unwrap();
        for (i, &k) in keys.iter().enumerate() {
            assert_eq!(got[i], hash_bucket_ref(k, 1023), "key {k}");
        }
    }

    #[test]
    fn shape_errors_are_reported() {
        let mut rt = Runtime::native();
        assert!(rt.select(&[0.0; 3], 0.0, 0.0).is_err());
        assert!(rt.regex_batch(&[0; 3]).is_err());
        assert!(rt.hash(&[0; 3], 1023).is_err());
    }
}
