//! PJRT executor: loads the AOT HLO-text artifacts and runs them from the
//! Rust hot path. This is the only place the `xla` crate is touched; the
//! rest of the coordinator sees typed batch calls.
//!
//! Python never runs here — `make artifacts` produced the HLO once at
//! build time; this module compiles it on the PJRT CPU client at startup
//! and executes it per batch.

use crate::anyhow::{bail, Context, Result};
use xla::{ElementType, HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::artifacts::{Manifest, OpArtifact, BATCH, DFA_STATES, ROW_WORDS, STR_LEN};
#[cfg(test)]
use super::hash_bucket_ref;

/// Build a shaped literal in ONE copy (PERF: `vec1().reshape()` copies the
/// buffer twice; per-batch marshalling dominated the Rust-side operator
/// throughput — see DESIGN.md §Perf).
fn literal_f32(dims: &[usize], data: &[f32]) -> Result<Literal> {
    debug_assert_eq!(dims.iter().product::<usize>(), data.len());
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::F32, dims, bytes)?)
}

fn literal_i32(dims: &[usize], data: &[i32]) -> Result<Literal> {
    debug_assert_eq!(dims.iter().product::<usize>(), data.len());
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::S32, dims, bytes)?)
}

/// A loaded operator executable.
pub struct OpExe {
    pub artifact: OpArtifact,
    exe: PjRtLoadedExecutable,
    /// Executions so far (perf accounting).
    pub invocations: u64,
}

/// The runtime: one PJRT CPU client + all operator executables.
pub struct Runtime {
    #[allow(dead_code)]
    client: PjRtClient,
    select: OpExe,
    regex: OpExe,
    hash: OpExe,
    /// Cached DFA tensors (PERF: the 1 MiB transition tensor is identical
    /// across every batch of a scan; building its Literal once per *scan*
    /// instead of once per 4096-row *batch* — see DESIGN.md §Perf).
    dfa_cache: Option<(Literal, Literal)>,
}

fn load_op(client: &PjRtClient, m: &Manifest, name: &str) -> Result<OpExe> {
    let artifact = m.op(name).with_context(|| format!("op {name} not in manifest"))?.clone();
    let proto = HloModuleProto::from_text_file(
        artifact.hlo_path.to_str().context("non-utf8 path")?,
    )?;
    let comp = XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp)?;
    Ok(OpExe { artifact, exe, invocations: 0 })
}

impl Runtime {
    /// Load every artifact from the default directory.
    pub fn load_default() -> Result<Runtime> {
        Self::load(&Manifest::load(Manifest::default_dir())?)
    }

    pub fn load(manifest: &Manifest) -> Result<Runtime> {
        let client = PjRtClient::cpu()?;
        let select = load_op(&client, manifest, "select")?;
        let regex = load_op(&client, manifest, "regex")?;
        let hash = load_op(&client, manifest, "hash")?;
        Ok(Runtime { client, select, regex, hash, dfa_cache: None })
    }

    /// SELECT pushdown batch: `rows` is `BATCH x ROW_WORDS` f32 (row-major).
    /// Returns (mask, count).
    pub fn select(&mut self, rows: &[f32], x: f32, y: f32) -> Result<(Vec<i32>, i32)> {
        if rows.len() != BATCH * ROW_WORDS {
            bail!("select: rows len {} != {}", rows.len(), BATCH * ROW_WORDS);
        }
        let rows_l = literal_f32(&[BATCH, ROW_WORDS], rows)?;
        let x_l = Literal::vec1(&[x]);
        let y_l = Literal::vec1(&[y]);
        self.select.invocations += 1;
        let out = self.select.exe.execute::<Literal>(&[rows_l, x_l, y_l])?[0][0]
            .to_literal_sync()?;
        let (mask, count) = out.to_tuple2()?;
        Ok((mask.to_vec::<i32>()?, count.get_first_element::<i32>()?))
    }

    /// Install a DFA for subsequent [`Runtime::regex_batch`] calls. `tmat`
    /// is `256 x S x S` f32 one-hot transition matrices; `accept` is `S`
    /// f32.
    pub fn set_dfa(&mut self, tmat: &[f32], accept: &[f32]) -> Result<()> {
        if tmat.len() != 256 * DFA_STATES * DFA_STATES || accept.len() != DFA_STATES {
            bail!("regex: bad dfa tensor sizes");
        }
        let tmat_l = literal_f32(&[256, DFA_STATES, DFA_STATES], tmat)?;
        let accept_l = Literal::vec1(accept);
        self.dfa_cache = Some((tmat_l, accept_l));
        Ok(())
    }

    /// Regex pushdown batch against the installed DFA: `chars` is
    /// `BATCH x STR_LEN` i32. Returns (mask, count).
    pub fn regex_batch(&mut self, chars: &[i32]) -> Result<(Vec<i32>, i32)> {
        if chars.len() != BATCH * STR_LEN {
            bail!("regex: chars len {} != {}", chars.len(), BATCH * STR_LEN);
        }
        let Some((tmat_l, accept_l)) = self.dfa_cache.as_ref() else {
            bail!("regex: no DFA installed (call set_dfa)");
        };
        let chars_l = literal_i32(&[BATCH, STR_LEN], chars)?;
        self.regex.invocations += 1;
        let out = self.regex.exe.execute::<&Literal>(&[&chars_l, tmat_l, accept_l])?[0][0]
            .to_literal_sync()?;
        let (mask, count) = out.to_tuple2()?;
        Ok((mask.to_vec::<i32>()?, count.get_first_element::<i32>()?))
    }

    /// One-shot convenience: install the DFA and run a single batch.
    pub fn regex(
        &mut self,
        chars: &[i32],
        tmat: &[f32],
        accept: &[f32],
    ) -> Result<(Vec<i32>, i32)> {
        self.set_dfa(tmat, accept)?;
        self.regex_batch(chars)
    }

    /// Hash batch: `keys` is `BATCH` i32; `bucket_mask` = nbuckets-1.
    pub fn hash(&mut self, keys: &[i32], bucket_mask: i32) -> Result<Vec<i32>> {
        if keys.len() != BATCH {
            bail!("hash: keys len {} != {BATCH}", keys.len());
        }
        let keys_l = Literal::vec1(keys);
        let mask_l = Literal::vec1(&[bucket_mask]);
        self.hash.invocations += 1;
        let out = self.hash.exe.execute::<Literal>(&[keys_l, mask_l])?[0][0]
            .to_literal_sync()?;
        let b = out.to_tuple1()?;
        Ok(b.to_vec::<i32>()?)
    }

    pub fn invocations(&self) -> (u64, u64, u64) {
        (self.select.invocations, self.regex.invocations, self.hash.invocations)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(Runtime::load_default().expect("runtime load"))
    }

    #[test]
    fn select_matches_scalar_reference() {
        let Some(mut rt) = runtime() else { return };
        let mut rows = vec![0f32; BATCH * ROW_WORDS];
        // deterministic pseudo-data
        let mut s = 1u32;
        for r in 0..BATCH {
            for w in 0..2 {
                s = s.wrapping_mul(1664525).wrapping_add(1013904223);
                rows[r * ROW_WORDS + w] = (s >> 8) as f32 / (1 << 16) as f32 - 128.0;
            }
        }
        let (x, y) = (-20.0f32, 35.0f32);
        let (mask, count) = rt.select(&rows, x, y).unwrap();
        let mut want_count = 0;
        for r in 0..BATCH {
            let a = rows[r * ROW_WORDS];
            let b = rows[r * ROW_WORDS + 1];
            let m = (a > x && b < y) as i32;
            assert_eq!(mask[r], m, "row {r}");
            if m == 1 {
                want_count += 1;
            }
        }
        assert_eq!(count, want_count);
        assert!(count > 0 && count < BATCH as i32, "degenerate test data");
    }

    #[test]
    fn hash_matches_reference_function() {
        let Some(mut rt) = runtime() else { return };
        let keys: Vec<i32> = (0..BATCH as i32).map(|i| i.wrapping_mul(2654435761u32 as i32) ^ 77).collect();
        let mask = 1023;
        let got = rt.hash(&keys, mask).unwrap();
        for (i, &k) in keys.iter().enumerate() {
            assert_eq!(got[i], hash_bucket_ref(k, mask), "key {k}");
        }
    }

    #[test]
    fn regex_finds_planted_strings() {
        let Some(mut rt) = runtime() else { return };
        // trivial 2-state DFA for "contains byte 'z'": built by hand here;
        // the full compiler path is exercised in operators::regex_op tests.
        let mut tmat = vec![0f32; 256 * DFA_STATES * DFA_STATES];
        let mut accept = vec![0f32; DFA_STATES];
        accept[1] = 1.0;
        for c in 0..256 {
            // state 0: 'z' -> 1 else stay; state 1 absorbing; pads self-loop
            let s0_next = if c == b'z' as usize { 1 } else { 0 };
            tmat[c * DFA_STATES * DFA_STATES + s0_next] = 1.0;
            for s in 1..DFA_STATES {
                tmat[c * DFA_STATES * DFA_STATES + s * DFA_STATES + s] = 1.0;
            }
        }
        let mut chars = vec![0i32; BATCH * STR_LEN];
        for r in (0..BATCH).step_by(7) {
            chars[r * STR_LEN + (r % STR_LEN)] = b'z' as i32;
        }
        let (mask, count) = rt.regex(&chars, &tmat, &accept).unwrap();
        let want = BATCH.div_ceil(7);
        assert_eq!(count as usize, want);
        for r in 0..BATCH {
            assert_eq!(mask[r], (r % 7 == 0) as i32, "row {r}");
        }
    }
}
