//! Minimal property-testing harness (`proptest` is unavailable in the
//! offline registry — see DESIGN.md). Provides seeded random generation,
//! a fixed case budget, and greedy input shrinking for `Vec`-shaped
//! inputs. Properties used across the crate live next to their modules;
//! the coordinator-invariant suites are in `rust/tests/props.rs`.

use crate::sim::rng::Rng;

/// Generation context handed to value generators.
pub struct Gen {
    pub rng: Rng,
    /// Current size hint (grows over the case budget).
    pub size: usize,
}

impl Gen {
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range(lo, hi)
    }
    pub fn below(&mut self, n: u64) -> u64 {
        self.rng.below(n)
    }
    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }
    /// A vector whose length scales with the size hint.
    pub fn vec<T>(&mut self, mut item: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = self.rng.below(self.size as u64 + 1) as usize;
        (0..len).map(|_| item(self)).collect()
    }
}

/// A property runner.
pub struct Prop {
    name: &'static str,
    cases: usize,
    seed: u64,
    max_size: usize,
}

impl Prop {
    pub fn new(name: &'static str) -> Prop {
        // allow deterministic override for reproduction
        let seed = std::env::var("ECI_PTEST_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0x9E37_79B9_7F4A_7C15);
        Prop { name, cases: 100, seed, max_size: 64 }
    }

    pub fn cases(mut self, n: usize) -> Prop {
        self.cases = n;
        self
    }
    pub fn seed(mut self, s: u64) -> Prop {
        self.seed = s;
        self
    }
    pub fn max_size(mut self, s: usize) -> Prop {
        self.max_size = s;
        self
    }

    /// Check a property over generated values. Panics (with the seed and
    /// case index) on the first failure.
    pub fn check<T: std::fmt::Debug>(
        self,
        mut gen: impl FnMut(&mut Gen) -> T,
        mut prop: impl FnMut(&T) -> bool,
    ) {
        let mut rng = Rng::new(self.seed);
        for case in 0..self.cases {
            let size = 1 + self.max_size * case / self.cases.max(1);
            let mut g = Gen { rng: rng.fork(case as u64), size };
            let value = gen(&mut g);
            if !prop(&value) {
                panic!(
                    "property {:?} failed at case {case} (seed {:#x}, set ECI_PTEST_SEED to reproduce)\ninput: {value:?}",
                    self.name, self.seed
                );
            }
        }
    }

    /// Check a property over generated `Vec`s, greedily shrinking a
    /// failing input (halving + element dropping) before reporting.
    pub fn check_vec<T: Clone + std::fmt::Debug>(
        self,
        mut item: impl FnMut(&mut Gen) -> T,
        mut prop: impl FnMut(&[T]) -> bool,
    ) {
        let mut rng = Rng::new(self.seed);
        for case in 0..self.cases {
            let size = 1 + self.max_size * case / self.cases.max(1);
            let mut g = Gen { rng: rng.fork(case as u64), size };
            let value = g.vec(&mut item);
            if !prop(&value) {
                let shrunk = shrink(&value, &mut prop);
                panic!(
                    "property {:?} failed at case {case} (seed {:#x})\nshrunk input ({} of {} elems): {shrunk:?}",
                    self.name,
                    self.seed,
                    shrunk.len(),
                    value.len()
                );
            }
        }
    }
}

/// Greedy shrink: repeatedly try halves, then single-element removals.
fn shrink<T: Clone>(input: &[T], prop: &mut impl FnMut(&[T]) -> bool) -> Vec<T> {
    let mut cur: Vec<T> = input.to_vec();
    loop {
        let mut progressed = false;
        // halves
        if cur.len() >= 2 {
            let half = cur.len() / 2;
            for cand in [cur[..half].to_vec(), cur[half..].to_vec()] {
                if !prop(&cand) {
                    cur = cand;
                    progressed = true;
                    break;
                }
            }
        }
        if progressed {
            continue;
        }
        // single removals
        let mut i = 0;
        while i < cur.len() {
            let mut cand = cur.clone();
            cand.remove(i);
            if !prop(&cand) {
                cur = cand;
                progressed = true;
            } else {
                i += 1;
            }
        }
        if !progressed {
            return cur;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        Prop::new("reverse involutive").cases(50).check_vec(
            |g| g.range(0, 100),
            |xs| {
                let mut a = xs.to_vec();
                a.reverse();
                a.reverse();
                a == xs
            },
        );
    }

    #[test]
    fn failing_property_shrinks() {
        let r = std::panic::catch_unwind(|| {
            Prop::new("no sevens").cases(300).seed(42).check_vec(
                |g| g.range(0, 10),
                |xs| !xs.contains(&7),
            );
        });
        let msg = match r {
            Err(e) => *e.downcast::<String>().expect("panic message"),
            Ok(()) => panic!("property should have failed"),
        };
        // the shrunk counterexample is exactly [7]
        assert!(msg.contains("[7]"), "shrunk message: {msg}");
    }

    #[test]
    fn scalar_check_reports_input() {
        let r = std::panic::catch_unwind(|| {
            Prop::new("always small").cases(500).seed(1).check(|g| g.range(0, 1000), |&x| x < 990);
        });
        assert!(r.is_err());
    }
}
