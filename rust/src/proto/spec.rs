//! Spec-generated agent state machines, **including the intermediate
//! (transient) states that handle message reordering and races**.
//!
//! Paper §3.2: "the protocol envelope does not specify additional
//! intermediate states (and associated messages) needed to handle message
//! reordering and races. ... our reference implementation implements all
//! intermediate states for CPU interoperability, but the user need only
//! consider the specified stable states." And §4.2: "The directory-
//! controller's entire state machine, including intermediate states to
//! handle race conditions, is generated automatically from a formal
//! specification."
//!
//! This module is that generator. The *formal specification* is the
//! machine-readable transition table of [`super::transitions`] plus the
//! race-resolution policies documented below; [`generate_remote`] and
//! [`generate_home`] expand it into complete, explicit `(state, event) ->
//! (state', actions)` rule maps which the agents in [`crate::agents`]
//! interpret at runtime. Nothing in the agents hand-codes a transition;
//! they only execute rules from these maps, so the envelope checks in
//! [`super::envelope`] plus the closure tests below carry over to the
//! running system.
//!
//! ## Race policies (the ones the ThunderX-1 VCs force us to handle)
//!
//! VCs have **no cross-VC ordering** (§4.2), so:
//!
//! * **Fwd overtakes fill / fwd meets a stalled request** — a
//!   home-initiated downgrade can arrive while the remote is still
//!   waiting for a fill, either because the fwd overtook the grant on a
//!   different VC, or because the home issued it while *stalling* the
//!   remote's own request (eviction + re-request race). Deferring the
//!   answer until the fill lands deadlocks the second case (the fill
//!   never comes while the home waits). Policy (the gem5 `IS_I`-style
//!   resolution): the remote answers the fwd **immediately** from its
//!   current possession (clean — it holds nothing yet) and marks the
//!   transaction *use-once*: when the fill lands it satisfies the
//!   waiting core and is immediately dropped (or demoted to S for a
//!   fwd-to-S), with a writeback if the grant carried dirty ownership.
//!   The value the core observes is the pre-downgrade value — coherent,
//!   since its load was ordered before the downgrade at the home.
//! * **Upgrade races with invalidation** — remote sends `UpgradeS2E` while
//!   the home's `FwdDowngradeI` is in flight. The remote answers the fwd
//!   (it must, R7), dropping to `I`, and parks in a transient; the home,
//!   seeing `UpgradeS2E` from a requester its directory now records as
//!   `I`, **converts** the upgrade to a full `ReadExclusive` and responds
//!   with data (the response carries `op = ReadExclusive`, which is how
//!   the remote learns of the conversion). This keeps Table 1 intact at
//!   the stable level — the conversion is exactly the kind of
//!   intermediate-state machinery §3.2 licenses.
//! * **Request overtakes voluntary downgrade** — the remote volunteers a
//!   downgrade (no response required) and immediately re-requests the
//!   line; the request can overtake the downgrade. The home detects the
//!   impossibility (a request from a node its directory believes holds
//!   E/M) and *stalls* the request until the in-flight downgrade arrives.

use crate::rustc_hash::FxHashMap as HashMap;

use super::messages::CohOp;
use super::states::CacheState;
use super::transitions::Transition;

// ===========================================================================
// Remote agent (caching agent; the CPU in the paper's smart-memory use case)
// ===========================================================================

/// What a pending remote transaction is waiting for.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum WaitKind {
    /// Sent `ReadShared`, awaiting data.
    FillS,
    /// Sent `ReadExclusive`, awaiting data.
    FillE,
    /// Sent `UpgradeS2E`, awaiting ack (or converted data).
    UpgAck,
}

/// A home-initiated downgrade answered mid-transaction: the in-flight
/// fill becomes use-once (dropped or demoted the instant it lands).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum DeferredFwd {
    None,
    /// demote to S when the fill lands
    ToS,
    /// drop (with writeback if dirty) when the fill lands
    ToI,
}

/// Remote-agent per-line state: four stable states plus transients.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum RemoteSt {
    Stable(CacheState),
    Wait { kind: WaitKind, deferred: DeferredFwd },
}

impl RemoteSt {
    pub const fn stable(s: CacheState) -> RemoteSt {
        RemoteSt::Stable(s)
    }
    pub fn is_transient(self) -> bool {
        matches!(self, RemoteSt::Wait { .. })
    }
    /// All reachable remote states (enumerated; used by closure tests).
    pub fn all() -> Vec<RemoteSt> {
        let mut v: Vec<RemoteSt> =
            CacheState::ALL.iter().map(|&s| RemoteSt::Stable(s)).collect();
        for kind in [WaitKind::FillS, WaitKind::FillE, WaitKind::UpgAck] {
            for deferred in [DeferredFwd::None, DeferredFwd::ToS, DeferredFwd::ToI] {
                v.push(RemoteSt::Wait { kind, deferred });
            }
        }
        v
    }
}

/// Events at the remote agent, per line.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum REvent {
    /// Local processor load touching the line.
    Read,
    /// Local processor store touching the line.
    Write,
    /// Local cache wants the line gone (capacity/conflict), dropping to I.
    Evict,
    /// Local cache demotes to shared (keeps read-only copy).
    Demote,
    /// Response arrived granting `op` (with data unless `UpgradeS2E`).
    Rsp { granted: CohOp, dirty: bool },
    /// Home-initiated downgrade arrived.
    Fwd { op: CohOp },
}

/// Actions the remote agent must perform, in order.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum RAction {
    /// Emit a coherence request to home.
    SendReq(CohOp),
    /// Emit the response to a home-initiated downgrade.
    /// `with_data`: attach the (dirty) line.
    RspToFwd { op: CohOp, with_data: bool },
    /// Install the received line with the given stable state.
    Fill(CacheState),
    /// Promote the already-resident (shared) line to E — the dataless
    /// UpgradeS2E grant.
    PromoteToE,
    /// Mark the cached line dirty (silent IE -> IM upgrade).
    MarkDirty,
    /// Downgrade the local copy to S (keep data, clean).
    DowngradeToS,
    /// Drop the local copy.
    DropLine,
    /// The local access must wait; retry when the line settles.
    StallLocal,
    /// The fill was use-once (a fwd-to-I was answered mid-transaction):
    /// drop it now, writing back first if it carried dirty ownership.
    DropAfterFill,
    /// The fill was demoted mid-transaction (fwd-to-S answered): keep it
    /// as S, writing dirty ownership back via VolDowngradeS if needed.
    DemoteAfterFill,
    /// Voluntary downgrade message carries the dirty payload.
    AttachDirtyData,
}

/// One rule: next state + action list.
#[derive(Clone, Debug)]
pub struct RRule {
    pub next: RemoteSt,
    pub actions: Vec<RAction>,
}

pub type RemoteRules = HashMap<(RemoteSt, REvent), RRule>;

/// Generate the complete remote-agent rule map from the transition spec.
pub fn generate_remote(spec: &[Transition]) -> RemoteRules {
    use CacheState::*;
    use CohOp::*;
    use RAction as A;
    use REvent as E;
    use RemoteSt as R;

    let mut rules: RemoteRules = HashMap::default();
    let mut add = |st: RemoteSt, ev: REvent, next: RemoteSt, actions: Vec<RAction>| {
        let prev = rules.insert((st, ev), RRule { next, actions });
        assert!(prev.is_none(), "duplicate rule for {st:?} x {ev:?}");
    };

    // Helper: does the spec allow the remote to signal `op` from remote
    // state `s`? (Consult every joint state with that remote component.)
    let remote_may = |op: CohOp, s: CacheState| -> bool {
        spec.iter().any(|t| {
            t.by == super::states::Node::Remote && t.op == Some(op) && t.from.remote == s
        })
    };

    // ---- stable states: local accesses --------------------------------
    // I: a read misses -> ReadShared; a write misses -> ReadExclusive.
    if remote_may(ReadShared, I) {
        add(R::Stable(I), E::Read, R::Wait { kind: WaitKind::FillS, deferred: DeferredFwd::None }, vec![A::SendReq(ReadShared)]);
    }
    if remote_may(ReadExclusive, I) {
        add(R::Stable(I), E::Write, R::Wait { kind: WaitKind::FillE, deferred: DeferredFwd::None }, vec![A::SendReq(ReadExclusive)]);
    }
    // I: evict/demote of an invalid line is a no-op.
    add(R::Stable(I), E::Evict, R::Stable(I), vec![]);
    add(R::Stable(I), E::Demote, R::Stable(I), vec![]);

    // S: reads hit; writes upgrade.
    add(R::Stable(S), E::Read, R::Stable(S), vec![]);
    if remote_may(UpgradeS2E, S) {
        add(R::Stable(S), E::Write, R::Wait { kind: WaitKind::UpgAck, deferred: DeferredFwd::None }, vec![A::SendReq(UpgradeS2E)]);
    }
    // S: voluntary drop (transition 6) — clean, no payload, no response.
    add(R::Stable(S), E::Evict, R::Stable(I), vec![A::SendReq(VolDowngradeI), A::DropLine]);
    add(R::Stable(S), E::Demote, R::Stable(S), vec![]);

    // E: reads/writes hit; a write silently dirties (local IE -> IM).
    add(R::Stable(E), E::Read, R::Stable(E), vec![]);
    add(R::Stable(E), E::Write, R::Stable(M), vec![A::MarkDirty]);
    // E: voluntary downgrades (transitions 5/7), clean so no payload.
    add(R::Stable(E), E::Evict, R::Stable(I), vec![A::SendReq(VolDowngradeI), A::DropLine]);
    add(R::Stable(E), E::Demote, R::Stable(S), vec![A::SendReq(VolDowngradeS), A::DowngradeToS]);

    // M: reads/writes hit.
    add(R::Stable(M), E::Read, R::Stable(M), vec![]);
    add(R::Stable(M), E::Write, R::Stable(M), vec![]);
    // M: voluntary downgrades carry the dirty payload (transitions 4/7).
    add(R::Stable(M), E::Evict, R::Stable(I), vec![A::AttachDirtyData, A::SendReq(VolDowngradeI), A::DropLine]);
    add(R::Stable(M), E::Demote, R::Stable(S), vec![A::AttachDirtyData, A::SendReq(VolDowngradeS), A::DowngradeToS]);

    // ---- stable states: home-initiated downgrades ---------------------
    // From S: home may invalidate (8). Response required, never dirty.
    add(R::Stable(S), E::Fwd { op: FwdDowngradeI }, R::Stable(I), vec![A::RspToFwd { op: FwdDowngradeI, with_data: false }, A::DropLine]);
    // FwdDowngradeS to an S holder is a protocol error (home only demotes
    // E/M holders) — intentionally no rule; the checker flags it.
    // From E: clean responses.
    add(R::Stable(E), E::Fwd { op: FwdDowngradeI }, R::Stable(I), vec![A::RspToFwd { op: FwdDowngradeI, with_data: false }, A::DropLine]);
    add(R::Stable(E), E::Fwd { op: FwdDowngradeS }, R::Stable(S), vec![A::RspToFwd { op: FwdDowngradeS, with_data: false }, A::DowngradeToS]);
    // From M: dirty responses (data returns to home).
    add(R::Stable(M), E::Fwd { op: FwdDowngradeI }, R::Stable(I), vec![A::RspToFwd { op: FwdDowngradeI, with_data: true }, A::DropLine]);
    add(R::Stable(M), E::Fwd { op: FwdDowngradeS }, R::Stable(S), vec![A::RspToFwd { op: FwdDowngradeS, with_data: true }, A::DowngradeToS]);
    // From I: a fwd can cross with our voluntary downgrade; the line is
    // already gone, answer "clean, no data" so the home can proceed.
    add(R::Stable(I), E::Fwd { op: FwdDowngradeI }, R::Stable(I), vec![A::RspToFwd { op: FwdDowngradeI, with_data: false }]);
    add(R::Stable(I), E::Fwd { op: FwdDowngradeS }, R::Stable(I), vec![A::RspToFwd { op: FwdDowngradeS, with_data: false }]);

    // Extension: FwdSharedInvalidate behaves like FwdDowngradeI at the
    // remote but always returns the line (even clean), if the subset
    // enables it.
    if spec.iter().any(|t| t.op == Some(FwdSharedInvalidate)) {
        add(R::Stable(S), E::Fwd { op: FwdSharedInvalidate }, R::Stable(I), vec![A::RspToFwd { op: FwdSharedInvalidate, with_data: true }, A::DropLine]);
        add(R::Stable(I), E::Fwd { op: FwdSharedInvalidate }, R::Stable(I), vec![A::RspToFwd { op: FwdSharedInvalidate, with_data: false }]);
    }

    // ---- transient states ----------------------------------------------
    for kind in [WaitKind::FillS, WaitKind::FillE, WaitKind::UpgAck] {
        for deferred in [DeferredFwd::None, DeferredFwd::ToS, DeferredFwd::ToI] {
            let st = R::Wait { kind, deferred };

            // Local accesses stall while a transaction is outstanding
            // (one outstanding transaction per line; the L2 MSHR blocks).
            add(st, E::Read, st, vec![A::StallLocal]);
            add(st, E::Write, st, vec![A::StallLocal]);
            add(st, E::Evict, st, vec![A::StallLocal]);
            add(st, E::Demote, st, vec![A::StallLocal]);

            // A fwd arriving mid-transaction is answered IMMEDIATELY from
            // current possession (clean — the fill hasn't landed), and
            // the transaction becomes use-once/demoted. Deferring instead
            // deadlocks when the home issued the fwd while stalling our
            // own request (see the race policy in the module docs).
            match kind {
                WaitKind::FillS | WaitKind::FillE => {
                    add(st, E::Fwd { op: FwdDowngradeI }, R::Wait { kind, deferred: DeferredFwd::ToI }, vec![A::RspToFwd { op: FwdDowngradeI, with_data: false }]);
                    add(st, E::Fwd { op: FwdDowngradeS }, R::Wait { kind, deferred: DeferredFwd::ToS }, vec![A::RspToFwd { op: FwdDowngradeS, with_data: false }]);
                }
                WaitKind::UpgAck => {
                    if deferred == DeferredFwd::None {
                        // Upgrade lost the race: answer the invalidation
                        // (we held S = clean), drop, and wait for the
                        // converted ReadExclusive response.
                        add(st, E::Fwd { op: FwdDowngradeI }, R::Wait { kind: WaitKind::FillE, deferred: DeferredFwd::None }, vec![A::RspToFwd { op: FwdDowngradeI, with_data: false }, A::DropLine]);
                        // A demote-to-S can race ahead of the upgrade ack
                        // (home acked, app read, fwd overtook the ack):
                        // we hold clean S — answer clean; when the ack
                        // lands, the promotion is immediately demoted.
                        add(st, E::Fwd { op: FwdDowngradeS }, R::Wait { kind: WaitKind::UpgAck, deferred: DeferredFwd::ToS }, vec![A::RspToFwd { op: FwdDowngradeS, with_data: false }]);
                    }
                }
            }

            // Response arrival completes the transaction.
            match kind {
                WaitKind::FillS => {
                    add(st, E::Rsp { granted: ReadShared, dirty: false }, R::Stable(S), fill_then_replay(S, deferred));
                }
                WaitKind::FillE => {
                    add(st, E::Rsp { granted: ReadExclusive, dirty: false }, R::Stable(E), fill_then_replay(E, deferred));
                    // Home may forward a dirty line on ReadExclusive
                    // (MI -> IM): we inherit the dirty data as M.
                    add(st, E::Rsp { granted: ReadExclusive, dirty: true }, R::Stable(M), fill_then_replay(M, deferred));
                    // A plain UpgradeS2E ack can reach a FillE transient:
                    // we were converted here by answering an invalidation
                    // mid-upgrade, then the (unconverted) ack overtook or
                    // trailed the fwd. The ack grants exclusivity over
                    // data we already surrendered — start a fresh
                    // transaction instead.
                    add(st, E::Rsp { granted: UpgradeS2E, dirty: false }, R::Wait { kind: WaitKind::FillE, deferred: DeferredFwd::None }, vec![A::SendReq(ReadExclusive)]);
                }
                WaitKind::UpgAck => {
                    // dataless ack: the line is already resident as S
                    let mut acts = vec![A::PromoteToE];
                    match deferred {
                        DeferredFwd::None => {}
                        DeferredFwd::ToI => acts.push(A::DropAfterFill),
                        DeferredFwd::ToS => acts.push(A::DemoteAfterFill),
                    }
                    add(st, E::Rsp { granted: UpgradeS2E, dirty: false }, R::Stable(E), acts);
                    // Conversion: the home answered our upgrade with a
                    // full exclusive fill (we had been invalidated).
                    add(st, E::Rsp { granted: ReadExclusive, dirty: false }, R::Stable(E), fill_then_replay(E, deferred));
                    add(st, E::Rsp { granted: ReadExclusive, dirty: true }, R::Stable(M), fill_then_replay(M, deferred));
                }
            }
        }
    }

    rules
}

/// After a fill, apply the mid-transaction downgrade (if one was
/// answered): use-once drop for fwd-to-I, demotion to S for fwd-to-S.
fn fill_then_replay(fill: CacheState, deferred: DeferredFwd) -> Vec<RAction> {
    let mut v = vec![RAction::Fill(fill)];
    match deferred {
        DeferredFwd::None => {}
        DeferredFwd::ToI => v.push(RAction::DropAfterFill),
        DeferredFwd::ToS => v.push(RAction::DemoteAfterFill),
    }
    v
}

// ===========================================================================
// Home agent (directory controller on the FPGA)
// ===========================================================================

/// What the home's directory believes the remote holds. `EorM` because the
/// IE -> IM upgrade is silent (the paper: home cannot distinguish them).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum RemoteView {
    I,
    S,
    EorM,
}

/// Home-agent per-line state.
///
/// `own` is the home's own cached state; `own_dirty` realizes the hidden
/// **O** state: `own = S && own_dirty` means MOESI-owned (dirty + shared),
/// which must remain invisible to the remote (requirement 4).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct HomeSt {
    pub own: CacheState,
    pub own_dirty: bool,
    pub view: RemoteView,
    /// A home-initiated downgrade is outstanding; further requests for the
    /// line stall until its response arrives.
    pub pending_fwd: Option<PendingFwd>,
}

#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum PendingFwd {
    ToS,
    ToI,
    /// Waiting for a voluntary downgrade that *must* be in flight
    /// (request-overtakes-downgrade race): stall until it lands.
    AwaitVolDowngrade,
}

impl HomeSt {
    pub const fn idle() -> HomeSt {
        HomeSt { own: CacheState::I, own_dirty: false, view: RemoteView::I, pending_fwd: None }
    }
    /// Is this a coherent, stable (non-pending) configuration?
    pub fn is_stable(self) -> bool {
        self.pending_fwd.is_none() && self.coherent()
    }
    pub fn coherent(self) -> bool {
        use CacheState::*;
        // own_dirty only meaningful on S (hidden O) or implied by M.
        if self.own_dirty && !matches!(self.own, S | M) {
            return false;
        }
        match (self.own, self.view) {
            (I, _) => true,
            (_, RemoteView::I) => true,
            (S, RemoteView::S) => true,
            _ => false,
        }
    }
}

/// Events at the home agent, per line.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum HEvent {
    /// A coherence request arrived from the remote.
    Req { op: CohOp, with_data: bool },
    /// The response to our outstanding fwd arrived.
    FwdRsp { dirty: bool },
    /// The home-side application (memory controller / accelerator) reads.
    LocalRead,
    /// The home-side application writes.
    LocalWrite,
    /// Home cache evicts the line (capacity).
    LocalEvict,
    /// Home-side application needs the remote's copy gone (e.g. before an
    /// in-place update of operator results).
    RecallI,
}

/// Actions the home agent must perform, in order.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum HAction {
    /// Respond to the remote. `from_ram`: read the line from backing
    /// store first; otherwise serve from the home cache. `dirty` marks
    /// the forwarded data as superseding RAM (hidden-O forwarding).
    SendRsp { op: CohOp, with_data: bool, from_ram: bool, dirty: bool },
    /// Issue a home-initiated downgrade.
    SendFwd { op: CohOp },
    /// Write the (received or cached) dirty line to backing store.
    WriteRam,
    /// Read the line into the home cache.
    FillOwn { state: CacheState, dirty: bool },
    /// Drop the home's own copy.
    DropOwn,
    /// Update the dirty flag of the home copy.
    SetOwnDirty(bool),
    /// Stall this event until the pending transaction resolves.
    Stall,
    /// Record the incoming voluntary-downgrade payload into the home
    /// cache/RAM path (the agent decides cache vs RAM via policy).
    AcceptWriteback,
}

#[derive(Clone, Debug)]
pub struct HRule {
    pub next: HomeSt,
    pub actions: Vec<HAction>,
}

/// Home policy knobs that select among the multi-outcome transitions of
/// the envelope (all outcomes legal; the choice is invisible to the
/// remote, requirement 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HomePolicy {
    /// On transition 10 (read-shared of a home-dirty line): keep the line
    /// dirty+shared (hidden O, MOESI concession — recommended) instead of
    /// writing back and dropping to IS.
    pub hidden_o: bool,
    /// On receiving a dirty writeback / fwd response: cache it (MI) rather
    /// than writing straight to RAM (II).
    pub cache_writebacks: bool,
    /// On granting a shared copy from an idle home (`own = I`): also fill
    /// the home's own cache with a clean S copy, so repeat reads of the
    /// line are served slice-locally instead of paying a backing-store
    /// round trip. This is the symmetric-configuration fill path for the
    /// sliced home caches (`crate::dcs`); it is invisible to the remote
    /// (requirement 4 — home local states are silent) and must only be
    /// enabled on agents that actually carry a [`crate::agents::cache::Cache`].
    pub cache_fills: bool,
}

impl Default for HomePolicy {
    fn default() -> Self {
        HomePolicy { hidden_o: true, cache_writebacks: false, cache_fills: false }
    }
}

pub type HomeRules = HashMap<(HomeSt, HEvent), HRule>;

/// Enumerate the home states reachable under `policy`.
pub fn home_states() -> Vec<HomeSt> {
    use CacheState::*;
    let mut v = Vec::new();
    for own in [I, S, E, M] {
        for own_dirty in [false, true] {
            for view in [RemoteView::I, RemoteView::S, RemoteView::EorM] {
                for pending in [
                    None,
                    Some(PendingFwd::ToS),
                    Some(PendingFwd::ToI),
                    Some(PendingFwd::AwaitVolDowngrade),
                ] {
                    // A pending fwd only exists toward a remote that holds
                    // something: ToI targets S or E/M holders; ToS and the
                    // await-writeback stall target E/M holders only.
                    let plausible = match pending {
                        None => true,
                        Some(PendingFwd::ToI) => {
                            matches!(view, RemoteView::S | RemoteView::EorM)
                        }
                        Some(PendingFwd::ToS) | Some(PendingFwd::AwaitVolDowngrade) => {
                            view == RemoteView::EorM
                        }
                    };
                    if !plausible {
                        continue;
                    }
                    let st = HomeSt { own, own_dirty, view, pending_fwd: pending };
                    if !st.coherent() {
                        continue;
                    }
                    // dirty flag only on S (hidden O) or M (implied);
                    // normalize: M is always dirty, E/I never.
                    let normalized = match own {
                        M => own_dirty,  // require own_dirty = true for M
                        S => true,       // both allowed
                        _ => !own_dirty, // require false
                    };
                    if !normalized {
                        continue;
                    }
                    v.push(st);
                }
            }
        }
    }
    v
}

/// Generate the complete home-agent rule map.
pub fn generate_home(spec: &[Transition], policy: HomePolicy) -> HomeRules {
    use CacheState::*;
    use CohOp::*;
    use HAction as A;
    use HEvent as E;

    let has_ext = spec.iter().any(|t| t.op == Some(FwdSharedInvalidate));
    let mut rules: HomeRules = HashMap::default();
    let mut add = |st: HomeSt, ev: HEvent, next: HomeSt, actions: Vec<HAction>| {
        assert!(st.coherent(), "incoherent source state {st:?}");
        assert!(next.coherent(), "incoherent next state {next:?} from {st:?} on {ev:?}");
        let prev = rules.insert((st, ev), HRule { next, actions });
        assert!(prev.is_none(), "duplicate home rule for {st:?} x {ev:?}");
    };

    for st in home_states() {
        let HomeSt { own, own_dirty, view, pending_fwd } = st;

        // ---- pending transactions: everything else stalls --------------
        if let Some(p) = pending_fwd {
            for ev in [
                E::Req { op: ReadShared, with_data: false },
                E::Req { op: ReadExclusive, with_data: false },
                E::Req { op: UpgradeS2E, with_data: false },
                E::LocalRead,
                E::LocalWrite,
                E::LocalEvict,
                E::RecallI,
            ] {
                add(st, ev, st, vec![A::Stall]);
            }
            // Voluntary downgrades never stall (they're the thing a
            // pending AwaitVolDowngrade is waiting for, and they resolve
            // fwd races by emptying the remote).
            match p {
                PendingFwd::AwaitVolDowngrade => {
                    // The in-flight voluntary downgrade arrives: record it
                    // and clear the stall; the agent replays queued events.
                    // (view was EorM, so own = I here by coherence.)
                    let settle = |new_view: RemoteView, with_data: bool| {
                        if !with_data {
                            return (HomeSt { own, own_dirty, view: new_view, pending_fwd: None }, vec![]);
                        }
                        if policy.cache_writebacks {
                            let nown = if new_view == RemoteView::S { S } else { M };
                            (
                                HomeSt { own: nown, own_dirty: true, view: new_view, pending_fwd: None },
                                vec![A::AcceptWriteback, A::FillOwn { state: nown, dirty: true }],
                            )
                        } else {
                            (
                                HomeSt { own, own_dirty, view: new_view, pending_fwd: None },
                                vec![A::AcceptWriteback, A::WriteRam],
                            )
                        }
                    };
                    for (op, nv) in [(VolDowngradeI, RemoteView::I), (VolDowngradeS, RemoteView::S)] {
                        for wd in [false, true] {
                            let (n, acts) = settle(nv, wd);
                            add(st, E::Req { op, with_data: wd }, n, acts);
                        }
                    }
                }
                PendingFwd::ToS | PendingFwd::ToI => {
                    // A voluntary downgrade can cross with our fwd. Accept
                    // the payload (it is the freshest copy) but leave the
                    // directory view untouched until the fwd's response
                    // arrives — the view may then *overestimate* the
                    // remote (believing S/EorM while the remote is I),
                    // which is benign: a later fwd to an I remote is
                    // answered "clean, no data" and re-grants proceed
                    // normally.
                    for op in [VolDowngradeI, VolDowngradeS] {
                        add(st, E::Req { op, with_data: false }, st, vec![]);
                        add(st, E::Req { op, with_data: true }, st, vec![A::AcceptWriteback, A::WriteRam]);
                    }
                    // The fwd response itself:
                    let target_view = if p == PendingFwd::ToS { RemoteView::S } else { RemoteView::I };
                    // clean response
                    add(st, E::FwdRsp { dirty: false }, HomeSt { own, own_dirty, view: target_view, pending_fwd: None }, vec![]);
                    // dirty response: data returns home.
                    let (nown, ndirty, acts) = if p == PendingFwd::ToI {
                        if policy.cache_writebacks {
                            (M, true, vec![A::FillOwn { state: M, dirty: true }])
                        } else {
                            (own, own_dirty, vec![A::WriteRam])
                        }
                    } else {
                        // remote keeps S: home holds the dirty line as
                        // hidden O (own S + dirty) or writes RAM.
                        if policy.hidden_o {
                            (S, true, vec![A::FillOwn { state: S, dirty: true }])
                        } else {
                            (own, own_dirty, vec![A::WriteRam])
                        }
                    };
                    add(st, E::FwdRsp { dirty: true }, HomeSt { own: nown, own_dirty: ndirty, view: target_view, pending_fwd: None }, acts);
                }
            }
            continue;
        }

        // ---- no pending transaction ------------------------------------

        // Remote requests.
        match view {
            RemoteView::I | RemoteView::S => {
                // ReadShared: grant S.
                if view == RemoteView::I || view == RemoteView::S {
                    // (a remote that already holds S re-requesting shared is
                    //  a protocol error; with view=S only *another* core
                    //  behind the remote node would do this — the ThunderX
                    //  L2 aggregates, so treat as re-grant, idempotent)
                    let (acts, next) = grant_shared(st, policy);
                    add(st, E::Req { op: ReadShared, with_data: false }, next, acts);
                }
                // ReadExclusive: invalidate our copy, grant E (or M if we
                // held it dirty — ownership transfer).
                let (acts, next) = grant_exclusive(st);
                add(st, E::Req { op: ReadExclusive, with_data: false }, next, acts);
                // UpgradeS2E: ack without data if the directory agrees the
                // remote holds S; if our directory says I the remote lost
                // an invalidation race -> convert to a full exclusive fill.
                if view == RemoteView::S {
                    let mut acts = vec![];
                    if own_dirty {
                        // we hold it dirty+shared (hidden O): write back
                        // before surrendering exclusivity to remain clean.
                        acts.push(A::WriteRam);
                    }
                    if own != I {
                        acts.push(A::DropOwn);
                    }
                    acts.push(A::SendRsp { op: UpgradeS2E, with_data: false, from_ram: false, dirty: false });
                    add(st, E::Req { op: UpgradeS2E, with_data: false }, HomeSt { own: I, own_dirty: false, view: RemoteView::EorM, pending_fwd: None }, acts);
                } else {
                    let (acts, next) = grant_exclusive(st);
                    add(st, E::Req { op: UpgradeS2E, with_data: false }, next, acts);
                }
                // Voluntary downgrades from a remote we believe I/S: the
                // remote knows best (its message may have been reordered
                // behind a grant) — accept idempotently.
                for (op, new_view) in [(VolDowngradeI, RemoteView::I), (VolDowngradeS, RemoteView::S)] {
                    let nv = if view == RemoteView::I { RemoteView::I } else { new_view };
                    add(st, E::Req { op, with_data: false }, HomeSt { own, own_dirty, view: nv, pending_fwd: None }, vec![]);
                    let (nown, ndirty, acts) = if policy.cache_writebacks {
                        (M, true, vec![A::AcceptWriteback, A::FillOwn { state: M, dirty: true }])
                    } else {
                        (own, own_dirty, vec![A::AcceptWriteback, A::WriteRam])
                    };
                    // dirty payload arriving from a view=I/S remote means
                    // reordering; data is still the freshest copy.
                    let nown2 = if nv == RemoteView::S && policy.cache_writebacks { S } else { nown };
                    let ndirty2 = if nown2 == S { true } else { ndirty };
                    add(st, E::Req { op, with_data: true }, HomeSt { own: nown2, own_dirty: ndirty2 && nown2 != I, view: nv, pending_fwd: None }, acts);
                }
            }
            RemoteView::EorM => {
                // Any new request from a remote we believe E/M implies an
                // in-flight voluntary downgrade (request-overtakes-
                // downgrade race): stall until it lands.
                for op in [ReadShared, ReadExclusive, UpgradeS2E] {
                    add(st, E::Req { op, with_data: false }, HomeSt { pending_fwd: Some(PendingFwd::AwaitVolDowngrade), ..st }, vec![A::Stall]);
                }
                // Voluntary downgrades from E/M (transitions 4-7).
                for (op, new_view) in [(VolDowngradeI, RemoteView::I), (VolDowngradeS, RemoteView::S)] {
                    // clean (remote held E)
                    add(st, E::Req { op, with_data: false }, HomeSt { own, own_dirty, view: new_view, pending_fwd: None }, vec![]);
                    // dirty (remote held M) — home writes RAM or caches.
                    let (nown, ndirty, acts) = if policy.cache_writebacks {
                        if new_view == RemoteView::S {
                            (S, true, vec![A::AcceptWriteback, A::FillOwn { state: S, dirty: true }])
                        } else {
                            (M, true, vec![A::AcceptWriteback, A::FillOwn { state: M, dirty: true }])
                        }
                    } else {
                        (own, own_dirty, vec![A::AcceptWriteback, A::WriteRam])
                    };
                    add(st, E::Req { op, with_data: true }, HomeSt { own: nown, own_dirty: ndirty, view: new_view, pending_fwd: None }, acts);
                }
            }
        }

        // Local (home-side application) accesses.
        match view {
            RemoteView::I | RemoteView::S => {
                // Reads: hit if cached, else fill shared-style (home local
                // states are silent — any of the local chain is fine).
                if own == I {
                    add(st, E::LocalRead, HomeSt { own: if view == RemoteView::S { S } else { E }, own_dirty: false, view, pending_fwd: None }, vec![A::FillOwn { state: if view == RemoteView::S { S } else { E }, dirty: false }]);
                } else {
                    add(st, E::LocalRead, st, vec![]);
                }
                // Writes: need exclusivity; if the remote shares, recall it.
                if view == RemoteView::S {
                    add(st, E::LocalWrite, HomeSt { own, own_dirty, view, pending_fwd: Some(PendingFwd::ToI) }, vec![A::SendFwd { op: FwdDowngradeI }, A::Stall]);
                } else if own.writable() {
                    add(st, E::LocalWrite, HomeSt { own: M, own_dirty: true, view, pending_fwd: None }, vec![A::SetOwnDirty(true)]);
                } else {
                    // own is I or S with remote I: silent local upgrade.
                    add(st, E::LocalWrite, HomeSt { own: M, own_dirty: true, view, pending_fwd: None }, vec![A::FillOwn { state: M, dirty: true }]);
                }
                // Evict own copy: write back if dirty.
                if own == I {
                    add(st, E::LocalEvict, st, vec![]);
                } else {
                    let acts = if own_dirty || own == M { vec![A::WriteRam, A::DropOwn] } else { vec![A::DropOwn] };
                    add(st, E::LocalEvict, HomeSt { own: I, own_dirty: false, view, pending_fwd: None }, acts);
                }
                // Recall (application wants remote copy gone).
                if view == RemoteView::S {
                    add(st, E::RecallI, HomeSt { own, own_dirty, view, pending_fwd: Some(PendingFwd::ToI) }, vec![A::SendFwd { op: FwdDowngradeI }]);
                } else {
                    add(st, E::RecallI, st, vec![]); // nothing to recall
                }
            }
            RemoteView::EorM => {
                // Home-side access to a remotely-owned line: recall first.
                add(st, E::LocalRead, HomeSt { own, own_dirty, view, pending_fwd: Some(PendingFwd::ToS) }, vec![A::SendFwd { op: FwdDowngradeS }, A::Stall]);
                add(st, E::LocalWrite, HomeSt { own, own_dirty, view, pending_fwd: Some(PendingFwd::ToI) }, vec![A::SendFwd { op: FwdDowngradeI }, A::Stall]);
                add(st, E::LocalEvict, st, vec![]); // nothing cached locally
                add(st, E::RecallI, HomeSt { own, own_dirty, view, pending_fwd: Some(PendingFwd::ToI) }, vec![A::SendFwd { op: FwdDowngradeI }]);
            }
        }

        let _ = has_ext; // extension is remote-side; home issues it via RecallI variants in subsets
    }

    rules
}

/// Grant a shared copy from home state `st` (transitions 1 and 10).
fn grant_shared(st: HomeSt, policy: HomePolicy) -> (Vec<HAction>, HomeSt) {
    use CacheState::*;
    use HAction as A;
    match st.own {
        I => {
            if policy.cache_fills {
                // symmetric sliced-home configuration: the grant's RAM
                // read also fills the home cache (clean S), so repeat
                // reads are served slice-locally (from_ram = false).
                (
                    vec![
                        A::FillOwn { state: S, dirty: false },
                        A::SendRsp { op: CohOp::ReadShared, with_data: true, from_ram: true, dirty: false },
                    ],
                    HomeSt { own: S, own_dirty: false, view: RemoteView::S, pending_fwd: None },
                )
            } else {
                (
                    vec![A::SendRsp { op: CohOp::ReadShared, with_data: true, from_ram: true, dirty: false }],
                    HomeSt { own: I, own_dirty: false, view: RemoteView::S, pending_fwd: None },
                )
            }
        }
        S | E => (
            vec![A::SendRsp { op: CohOp::ReadShared, with_data: true, from_ram: false, dirty: false }],
            HomeSt { own: S, own_dirty: st.own_dirty, view: RemoteView::S, pending_fwd: None },
        ),
        M => {
            if policy.hidden_o {
                // Transition 10, hidden-O outcome: forward dirty data,
                // keep it dirty+shared at home; strictly invisible to the
                // remote (the response is NOT marked dirty — only
                // exclusive transfers hand over dirtiness).
                (
                    vec![A::SendRsp { op: CohOp::ReadShared, with_data: true, from_ram: false, dirty: false }],
                    HomeSt { own: S, own_dirty: true, view: RemoteView::S, pending_fwd: None },
                )
            } else {
                // Minimal-MESI outcome: write back, drop, serve from RAM.
                (
                    vec![A::WriteRam, A::DropOwn, A::SendRsp { op: CohOp::ReadShared, with_data: true, from_ram: true, dirty: false }],
                    HomeSt { own: I, own_dirty: false, view: RemoteView::S, pending_fwd: None },
                )
            }
        }
    }
}

/// Grant an exclusive copy (transition 2; from M this is the MI -> IM
/// dirty-ownership transfer).
fn grant_exclusive(st: HomeSt) -> (Vec<HAction>, HomeSt) {
    use CacheState::*;
    use HAction as A;
    let next = HomeSt { own: I, own_dirty: false, view: RemoteView::EorM, pending_fwd: None };
    match st.own {
        I => (
            vec![A::SendRsp { op: CohOp::ReadExclusive, with_data: true, from_ram: true, dirty: false }],
            next,
        ),
        S | E => {
            let mut acts = vec![];
            if st.own_dirty {
                // hidden O: we must not leak dirtiness; transfer it.
                acts.push(A::DropOwn);
                acts.push(A::SendRsp { op: CohOp::ReadExclusive, with_data: true, from_ram: false, dirty: true });
            } else {
                acts.push(A::DropOwn);
                acts.push(A::SendRsp { op: CohOp::ReadExclusive, with_data: true, from_ram: false, dirty: false });
            }
            (acts, next)
        }
        M => (
            vec![A::DropOwn, A::SendRsp { op: CohOp::ReadExclusive, with_data: true, from_ram: false, dirty: true }],
            next,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::states::Node;
    use crate::proto::transitions::reference_transitions;

    fn remote_rules() -> RemoteRules {
        generate_remote(&reference_transitions())
    }
    fn home_rules() -> HomeRules {
        generate_home(&reference_transitions(), HomePolicy::default())
    }

    #[test]
    fn remote_machine_is_closed_over_possible_events() {
        // Every stable state must handle every event the home may send
        // given some directory view (R7 at the machine level), and every
        // local event.
        let rules = remote_rules();
        use CacheState::*;
        for s in [I, S, E, M] {
            for ev in [REvent::Read, REvent::Write, REvent::Evict, REvent::Demote] {
                assert!(
                    rules.contains_key(&(RemoteSt::Stable(s), ev)),
                    "missing rule {s:?} x {ev:?}"
                );
            }
            // home-initiated invalidation must be handled everywhere
            assert!(rules.contains_key(&(RemoteSt::Stable(s), REvent::Fwd { op: CohOp::FwdDowngradeI })));
        }
        // demote-to-S only targets E/M holders (+ I for races)
        for s in [I, E, M] {
            assert!(rules.contains_key(&(RemoteSt::Stable(s), REvent::Fwd { op: CohOp::FwdDowngradeS })));
        }
    }

    #[test]
    fn remote_transients_answer_fwds_immediately_and_mark_use_once() {
        let rules = remote_rules();
        let st = RemoteSt::Wait { kind: WaitKind::FillS, deferred: DeferredFwd::None };
        let r = &rules[&(st, REvent::Fwd { op: CohOp::FwdDowngradeI })];
        assert_eq!(r.next, RemoteSt::Wait { kind: WaitKind::FillS, deferred: DeferredFwd::ToI });
        // the fwd is answered NOW (clean): deferring deadlocks the
        // eviction + re-request race where the home stalled our fill
        assert!(
            r.actions
                .contains(&RAction::RspToFwd { op: CohOp::FwdDowngradeI, with_data: false }),
            "{:?}",
            r.actions
        );
        // the fill is then use-once: install + drop
        let r2 = &rules[&(r.next, REvent::Rsp { granted: CohOp::ReadShared, dirty: false })];
        assert_eq!(r2.next, RemoteSt::Stable(CacheState::S));
        assert!(r2.actions.contains(&RAction::DropAfterFill), "{:?}", r2.actions);
    }

    #[test]
    fn upgrade_race_converts_to_exclusive_fill() {
        let rules = remote_rules();
        let st = RemoteSt::Wait { kind: WaitKind::UpgAck, deferred: DeferredFwd::None };
        let r = &rules[&(st, REvent::Fwd { op: CohOp::FwdDowngradeI })];
        assert_eq!(r.next, RemoteSt::Wait { kind: WaitKind::FillE, deferred: DeferredFwd::None });
        assert!(r.actions.contains(&RAction::RspToFwd { op: CohOp::FwdDowngradeI, with_data: false }));
        // the converted response then fills E
        let r2 = &rules[&(r.next, REvent::Rsp { granted: CohOp::ReadExclusive, dirty: false })];
        assert_eq!(r2.next, RemoteSt::Stable(CacheState::E));
    }

    #[test]
    fn dirty_eviction_attaches_data() {
        let rules = remote_rules();
        let r = &rules[&(RemoteSt::Stable(CacheState::M), REvent::Evict)];
        assert!(r.actions.contains(&RAction::AttachDirtyData));
        assert!(r.actions.contains(&RAction::SendReq(CohOp::VolDowngradeI)));
        // clean eviction must not
        let r = &rules[&(RemoteSt::Stable(CacheState::E), REvent::Evict)];
        assert!(!r.actions.contains(&RAction::AttachDirtyData));
    }

    #[test]
    fn home_machine_covers_all_requests_in_all_states() {
        let rules = home_rules();
        for st in home_states() {
            for op in CohOp::TABLE1 {
                if op.initiator() != Node::Remote {
                    continue;
                }
                let with_data_variants: &[bool] = match op.request_payload() {
                    crate::proto::messages::Payload::IfDirty => &[false, true],
                    _ => &[false],
                };
                for &wd in with_data_variants {
                    assert!(
                        rules.contains_key(&(st, HEvent::Req { op, with_data: wd })),
                        "home missing rule {st:?} x {op:?} data={wd}"
                    );
                }
            }
        }
    }

    #[test]
    fn home_transition_10_keeps_hidden_o_and_never_marks_rsp_dirty() {
        let rules = home_rules();
        let st = HomeSt { own: CacheState::M, own_dirty: true, view: RemoteView::I, pending_fwd: None };
        let r = &rules[&(st, HEvent::Req { op: CohOp::ReadShared, with_data: false })];
        // hidden O: home retains S + dirty
        assert_eq!(r.next.own, CacheState::S);
        assert!(r.next.own_dirty);
        assert_eq!(r.next.view, RemoteView::S);
        // requirement 4: the ReadShared response must not expose dirtiness
        for a in &r.actions {
            if let HAction::SendRsp { op, dirty, .. } = a {
                assert_eq!(*op, CohOp::ReadShared);
                assert!(!dirty, "hidden O leaked to remote");
            }
        }
    }

    #[test]
    fn home_without_hidden_o_writes_back_first() {
        let rules = generate_home(
            &reference_transitions(),
            HomePolicy { hidden_o: false, ..HomePolicy::default() },
        );
        let st = HomeSt { own: CacheState::M, own_dirty: true, view: RemoteView::I, pending_fwd: None };
        let r = &rules[&(st, HEvent::Req { op: CohOp::ReadShared, with_data: false })];
        assert!(r.actions.contains(&HAction::WriteRam));
        assert_eq!(r.next.own, CacheState::I);
        assert_eq!(r.next.view, RemoteView::S);
    }

    #[test]
    fn cache_fills_policy_fills_home_cache_on_shared_grant() {
        let rules = generate_home(
            &reference_transitions(),
            HomePolicy { cache_fills: true, ..HomePolicy::default() },
        );
        let st = HomeSt::idle();
        let r = &rules[&(st, HEvent::Req { op: CohOp::ReadShared, with_data: false })];
        // the first grant reads RAM and installs a clean home copy ...
        assert!(r
            .actions
            .contains(&HAction::FillOwn { state: CacheState::S, dirty: false }));
        assert_eq!(r.next.own, CacheState::S);
        assert_eq!(r.next.view, RemoteView::S);
        // ... so the NEXT shared grant is served from the home cache.
        let r2 = &rules[&(r.next, HEvent::Req { op: CohOp::ReadShared, with_data: false })];
        let from_ram = r2.actions.iter().any(
            |a| matches!(a, HAction::SendRsp { from_ram, .. } if *from_ram),
        );
        assert!(!from_ram, "repeat grant must be slice-local: {:?}", r2.actions);
        // an exclusive grant must surrender the home copy (single writer)
        let r3 = &rules[&(r.next, HEvent::Req { op: CohOp::ReadExclusive, with_data: false })];
        assert_eq!(r3.next.own, CacheState::I);
        assert!(r3.actions.contains(&HAction::DropOwn));
        // default policy tables are unchanged by the new knob
        let plain = generate_home(&reference_transitions(), HomePolicy::default());
        let p = &plain[&(HomeSt::idle(), HEvent::Req { op: CohOp::ReadShared, with_data: false })];
        assert_eq!(p.next.own, CacheState::I);
    }

    #[test]
    fn home_stalls_requests_from_supposed_owner() {
        // request-overtakes-downgrade race
        let rules = home_rules();
        let st = HomeSt { own: CacheState::I, own_dirty: false, view: RemoteView::EorM, pending_fwd: None };
        let r = &rules[&(st, HEvent::Req { op: CohOp::ReadShared, with_data: false })];
        assert_eq!(r.next.pending_fwd, Some(PendingFwd::AwaitVolDowngrade));
        assert!(r.actions.contains(&HAction::Stall));
        // and the arriving writeback releases it
        let r2 = &rules[&(r.next, HEvent::Req { op: CohOp::VolDowngradeI, with_data: true })];
        assert_eq!(r2.next.pending_fwd, None);
        assert_eq!(r2.next.view, RemoteView::I);
    }

    #[test]
    fn home_exclusive_grant_from_m_transfers_dirtiness() {
        let rules = home_rules();
        let st = HomeSt { own: CacheState::M, own_dirty: true, view: RemoteView::I, pending_fwd: None };
        let r = &rules[&(st, HEvent::Req { op: CohOp::ReadExclusive, with_data: false })];
        assert_eq!(r.next.view, RemoteView::EorM);
        assert_eq!(r.next.own, CacheState::I);
        let mut saw_dirty_rsp = false;
        for a in &r.actions {
            if let HAction::SendRsp { dirty, .. } = a {
                saw_dirty_rsp = *dirty;
            }
        }
        assert!(saw_dirty_rsp, "MI -> IM must hand dirtiness to the remote");
    }

    #[test]
    fn stable_projection_matches_envelope_transitions() {
        // Every remote-initiated signalled transition in the envelope must
        // be realizable as: remote rule emits SendReq(op) from the stable
        // source, home rule accepts it and lands in a home state whose
        // (own-visible, view) projection matches one of the envelope
        // outcomes.
        let spec = reference_transitions();
        let rrules = remote_rules();
        let hrules = home_rules();
        for tr in spec.iter().filter(|t| t.by == Node::Remote && t.op.is_some()) {
            let op = tr.op.unwrap();
            // find a remote rule emitting this op from the source's remote state
            let src_remote = RemoteSt::Stable(tr.from.remote);
            let emits = rrules.iter().any(|((st, _), rule)| {
                *st == src_remote && rule.actions.iter().any(|a| *a == RAction::SendReq(op))
            });
            assert!(emits, "no remote rule emits {op:?} from {:?}", tr.from.remote);
            // home must accept it in matching directory states
            let view = match tr.from.remote {
                CacheState::I => RemoteView::I,
                CacheState::S => RemoteView::S,
                _ => RemoteView::EorM,
            };
            let matching_home: Vec<&HomeSt> = home_states()
                .iter()
                .filter(|h| h.view == view && h.own == tr.from.home && h.pending_fwd.is_none())
                .cloned()
                .map(|h| Box::leak(Box::new(h)) as &HomeSt)
                .collect();
            for h in matching_home {
                let wd_variants: &[bool] = match op.request_payload() {
                    crate::proto::messages::Payload::IfDirty => {
                        if tr.from.remote.dirty() { &[true] } else { &[false] }
                    }
                    _ => &[false],
                };
                for &wd in wd_variants {
                    assert!(
                        hrules.contains_key(&(*h, HEvent::Req { op, with_data: wd })),
                        "home cannot receive {op:?} in {h:?}"
                    );
                }
            }
        }
    }
}
