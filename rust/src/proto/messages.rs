//! ECI message vocabulary (paper Table 1 plus the non-coherence traffic the
//! protocol also carries: "Non-cacheable I/O accesses, memory barriers, and
//! interprocessor-interrupts are all carried via this protocol" — §4.1).
//!
//! Messages are transport-agnostic here; the byte-accurate encoding lives
//! in [`crate::trace::ewf`] (ECI Wire Format) and VC assignment in
//! [`crate::transport::vc`].

use std::fmt;

use super::states::Node;

/// Cache-line size on the ThunderX-1 / Enzian: 128 bytes.
pub const LINE_BYTES: usize = 128;

/// A cache-line payload.
pub type Line = [u8; LINE_BYTES];

/// Cache-line address: byte address >> 7. The low bit selects the odd/even
/// VC set ("separate sets of VCs for odd and even cache lines enabling
/// simpler load-balancing", §4.2).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LineAddr(pub u64);

impl LineAddr {
    #[inline]
    pub fn from_byte_addr(addr: u64) -> LineAddr {
        LineAddr(addr >> 7)
    }
    #[inline]
    pub fn byte_addr(self) -> u64 {
        self.0 << 7
    }
    /// Odd/even parity used for VC selection.
    #[inline]
    pub fn parity(self) -> u8 {
        (self.0 & 1) as u8
    }
}

impl fmt::Debug for LineAddr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "L{:#x}", self.0)
    }
}
impl fmt::Display for LineAddr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:?}")
    }
}

/// Transaction id: correlates a request with its response. 10 bits on the
/// wire (per-direction, per-parity), which bounds outstanding transactions
/// at 1024 per request VC — matching the credit budget.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ReqId(pub u32);

impl fmt::Debug for ReqId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "#{}", self.0)
    }
}

/// Transition class (paper Table 1, column 2).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Class {
    Upgrade,
    Downgrade,
}

/// The signalled coherence operations — exactly the rows of Table 1, plus
/// the extension op `FwdShared` discussed in §3.3 ("downgrade remote to
/// invalid and forward", not in the minimal protocol; gated behind
/// [`crate::proto::subset::Feature::ForwardOnInvalidate`]).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum CohOp {
    // -- remote-initiated upgrades ------------------------------------
    /// Remote wants a read-only copy (transition 1 / 10).
    ReadShared,
    /// Remote wants an exclusive copy (transition 2).
    ReadExclusive,
    /// Remote holds S, wants E without data transfer (transition 3).
    UpgradeS2E,
    // -- remote-initiated (voluntary) downgrades ----------------------
    /// Remote drops to S; carries data iff the line was dirty (trans. 7).
    VolDowngradeS,
    /// Remote drops to I; carries data iff the line was dirty (4, 5, 6).
    VolDowngradeI,
    // -- home-initiated downgrades ------------------------------------
    /// Home forces remote to S (transition 9).
    FwdDowngradeS,
    /// Home forces remote to I (transition 8).
    FwdDowngradeI,
    // -- envelope extension (not minimal; not on the ThunderX-1) -------
    /// Home forces remote to I *and* asks the line forwarded even if
    /// clean, avoiding a RAM read (the IS -> SI extension of §3.3).
    FwdSharedInvalidate,
}

impl CohOp {
    /// Table 1: which node initiates this operation.
    pub fn initiator(self) -> Node {
        match self {
            CohOp::ReadShared
            | CohOp::ReadExclusive
            | CohOp::UpgradeS2E
            | CohOp::VolDowngradeS
            | CohOp::VolDowngradeI => Node::Remote,
            CohOp::FwdDowngradeS | CohOp::FwdDowngradeI | CohOp::FwdSharedInvalidate => Node::Home,
        }
    }

    /// Table 1: transition class.
    pub fn class(self) -> Class {
        match self {
            CohOp::ReadShared | CohOp::ReadExclusive | CohOp::UpgradeS2E => Class::Upgrade,
            _ => Class::Downgrade,
        }
    }

    /// Table 1: does the *request* carry a payload?
    /// `Conditional` = "Yes if dirty".
    pub fn request_payload(self) -> Payload {
        match self {
            CohOp::VolDowngradeS | CohOp::VolDowngradeI => Payload::IfDirty,
            _ => Payload::Never,
        }
    }

    /// Table 1: is a response from the partner required?
    pub fn needs_response(self) -> bool {
        match self {
            CohOp::ReadShared | CohOp::ReadExclusive | CohOp::UpgradeS2E => true,
            CohOp::VolDowngradeS | CohOp::VolDowngradeI => false,
            CohOp::FwdDowngradeS | CohOp::FwdDowngradeI | CohOp::FwdSharedInvalidate => true,
        }
    }

    /// Table 1: does the *response* carry a payload?
    pub fn response_payload(self) -> Payload {
        match self {
            CohOp::ReadShared | CohOp::ReadExclusive => Payload::Always,
            CohOp::UpgradeS2E => Payload::Never,
            CohOp::VolDowngradeS | CohOp::VolDowngradeI => Payload::Never, // no response at all
            CohOp::FwdDowngradeS | CohOp::FwdDowngradeI => Payload::IfDirty,
            CohOp::FwdSharedInvalidate => Payload::Always,
        }
    }

    pub const ALL: [CohOp; 8] = [
        CohOp::ReadShared,
        CohOp::ReadExclusive,
        CohOp::UpgradeS2E,
        CohOp::VolDowngradeS,
        CohOp::VolDowngradeI,
        CohOp::FwdDowngradeS,
        CohOp::FwdDowngradeI,
        CohOp::FwdSharedInvalidate,
    ];

    /// The seven rows of the paper's Table 1 (the minimal envelope).
    pub const TABLE1: [CohOp; 7] = [
        CohOp::ReadShared,
        CohOp::ReadExclusive,
        CohOp::UpgradeS2E,
        CohOp::VolDowngradeS,
        CohOp::VolDowngradeI,
        CohOp::FwdDowngradeS,
        CohOp::FwdDowngradeI,
    ];
}

/// Payload rule for a message slot.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Payload {
    Never,
    IfDirty,
    Always,
}

/// Everything that travels on the link.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum MsgKind {
    /// A coherence request (Table 1 rows).
    CohReq { op: CohOp },
    /// A coherence response. `dirty` tells the requester whether the data
    /// it receives supersedes RAM (only meaningful home-bound).
    /// `had_copy` (home-bound fwd responses only) tells the directory
    /// whether the responder actually surrendered a copy — intermediate-
    /// state machinery for exact possession accounting under crossed
    /// downgrades (§3.2 licenses such additions; always true elsewhere).
    CohRsp { op: CohOp, dirty: bool, had_copy: bool },
    /// Non-cacheable I/O read (config space, CSRs) — 8-byte granule.
    IoRead { offset: u64 },
    IoReadRsp { offset: u64, value: u64 },
    /// Non-cacheable I/O write.
    IoWrite { offset: u64, value: u64 },
    IoWriteAck,
    /// Memory barrier marker (fence completion handshake).
    Barrier,
    BarrierAck,
    /// Inter-processor interrupt.
    Ipi { vector: u8 },
}

impl MsgKind {
    pub fn is_coherence(&self) -> bool {
        matches!(self, MsgKind::CohReq { .. } | MsgKind::CohRsp { .. })
    }
    pub fn is_request(&self) -> bool {
        matches!(
            self,
            MsgKind::CohReq { .. }
                | MsgKind::IoRead { .. }
                | MsgKind::IoWrite { .. }
                | MsgKind::Barrier
                | MsgKind::Ipi { .. }
        )
    }
}

/// A complete ECI message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Message {
    /// Transaction id correlating request and response.
    pub id: ReqId,
    /// Which node sent it.
    pub from: Node,
    pub kind: MsgKind,
    /// Target cache line (coherence) or register block (I/O: the line
    /// address of the 128-byte window containing the register).
    pub addr: LineAddr,
    /// Optional 128-byte data payload.
    pub payload: Option<Box<Line>>,
}

impl Message {
    pub fn coh_req(id: ReqId, from: Node, op: CohOp, addr: LineAddr) -> Message {
        Message { id, from, kind: MsgKind::CohReq { op }, addr, payload: None }
    }

    pub fn coh_req_data(id: ReqId, from: Node, op: CohOp, addr: LineAddr, data: Box<Line>) -> Message {
        Message { id, from, kind: MsgKind::CohReq { op }, addr, payload: Some(data) }
    }

    pub fn coh_rsp(
        id: ReqId,
        from: Node,
        op: CohOp,
        addr: LineAddr,
        dirty: bool,
        data: Option<Box<Line>>,
    ) -> Message {
        Message { id, from, kind: MsgKind::CohRsp { op, dirty, had_copy: true }, addr, payload: data }
    }

    /// A fwd response from a node that held no copy (the downgrade
    /// crossed with its own surrender or arrived mid-fill).
    pub fn coh_rsp_nocopy(id: ReqId, from: Node, op: CohOp, addr: LineAddr) -> Message {
        Message { id, from, kind: MsgKind::CohRsp { op, dirty: false, had_copy: false }, addr, payload: None }
    }

    /// Wire size in bytes: 16-byte EWF header + optional 128-byte payload
    /// (+ payload CRC handled at the transaction layer). Kept in sync with
    /// [`crate::trace::ewf`] by a test there.
    pub fn wire_bytes(&self) -> u64 {
        16 + if self.payload.is_some() { LINE_BYTES as u64 } else { 0 }
    }

    /// Check the payload against the op's payload rule.
    pub fn payload_ok(&self) -> bool {
        let rule = match &self.kind {
            MsgKind::CohReq { op } => op.request_payload(),
            MsgKind::CohRsp { op, dirty, .. } => match op.response_payload() {
                Payload::IfDirty => {
                    return if *dirty { self.payload.is_some() } else { self.payload.is_none() }
                }
                r => r,
            },
            MsgKind::IoRead { .. }
            | MsgKind::IoReadRsp { .. }
            | MsgKind::IoWrite { .. }
            | MsgKind::IoWriteAck
            | MsgKind::Barrier
            | MsgKind::BarrierAck
            | MsgKind::Ipi { .. } => Payload::Never,
        };
        match rule {
            Payload::Never => self.payload.is_none(),
            Payload::Always => self.payload.is_some(),
            Payload::IfDirty => true, // either is legal; dirtiness checked by caller
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Table 1, row by row:
    /// (op, initiator, class, request-payload, response?, response-payload)
    #[test]
    fn table1_rows_match_paper() {
        use CohOp::*;
        use Payload::*;
        let rows: [(CohOp, Node, Class, Payload, bool, Payload); 7] = [
            (ReadShared, Node::Remote, Class::Upgrade, Never, true, Always),
            (ReadExclusive, Node::Remote, Class::Upgrade, Never, true, Always),
            (UpgradeS2E, Node::Remote, Class::Upgrade, Never, true, Never),
            (VolDowngradeS, Node::Remote, Class::Downgrade, IfDirty, false, Never),
            (VolDowngradeI, Node::Remote, Class::Downgrade, IfDirty, false, Never),
            (FwdDowngradeS, Node::Home, Class::Downgrade, Never, true, IfDirty),
            (FwdDowngradeI, Node::Home, Class::Downgrade, Never, true, IfDirty),
        ];
        for (op, init, class, reqp, rsp, rspp) in rows {
            assert_eq!(op.initiator(), init, "{op:?} initiator");
            assert_eq!(op.class(), class, "{op:?} class");
            assert_eq!(op.request_payload(), reqp, "{op:?} request payload");
            assert_eq!(op.needs_response(), rsp, "{op:?} response required");
            assert_eq!(op.response_payload(), rspp, "{op:?} response payload");
        }
    }

    #[test]
    fn line_addr_round_trip_and_parity() {
        let a = LineAddr::from_byte_addr(0x1000);
        assert_eq!(a.0, 0x20);
        assert_eq!(a.byte_addr(), 0x1000);
        assert_eq!(a.parity(), 0);
        assert_eq!(LineAddr::from_byte_addr(0x1080).parity(), 1);
        // sub-line bits are dropped
        assert_eq!(LineAddr::from_byte_addr(0x1007).byte_addr(), 0x1000);
    }

    #[test]
    fn payload_rules_enforced() {
        let id = ReqId(1);
        let a = LineAddr(2);
        // ReadShared request: never a payload
        let m = Message::coh_req(id, Node::Remote, CohOp::ReadShared, a);
        assert!(m.payload_ok());
        let m_bad = Message::coh_req_data(id, Node::Remote, CohOp::ReadShared, a, Box::new([0; 128]));
        assert!(!m_bad.payload_ok());
        // ReadShared response: always a payload
        let r = Message::coh_rsp(id, Node::Home, CohOp::ReadShared, a, false, Some(Box::new([0; 128])));
        assert!(r.payload_ok());
        let r_bad = Message::coh_rsp(id, Node::Home, CohOp::ReadShared, a, false, None);
        assert!(!r_bad.payload_ok());
        // FwdDowngradeI response: payload iff dirty
        let r = Message::coh_rsp(id, Node::Remote, CohOp::FwdDowngradeI, a, true, Some(Box::new([0; 128])));
        assert!(r.payload_ok());
        let r = Message::coh_rsp(id, Node::Remote, CohOp::FwdDowngradeI, a, false, None);
        assert!(r.payload_ok());
        let r = Message::coh_rsp(id, Node::Remote, CohOp::FwdDowngradeI, a, true, None);
        assert!(!r.payload_ok());
    }

    #[test]
    fn wire_size_accounts_payload() {
        let m = Message::coh_req(ReqId(0), Node::Remote, CohOp::ReadShared, LineAddr(0));
        assert_eq!(m.wire_bytes(), 16);
        let m = Message::coh_rsp(
            ReqId(0),
            Node::Home,
            CohOp::ReadShared,
            LineAddr(0),
            false,
            Some(Box::new([0xAB; 128])),
        );
        assert_eq!(m.wire_bytes(), 144);
    }
}
