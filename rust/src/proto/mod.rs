//! The ECI protocol: states, messages, envelope rules, spec-generated
//! state machines, and application-specific subsets (paper §3).

pub mod envelope;
pub mod messages;
pub mod spec;
pub mod states;
pub mod subset;
pub mod transitions;

pub use messages::{CohOp, Line, LineAddr, Message, MsgKind, ReqId, LINE_BYTES};
pub use states::{CacheState, DistanceOrder, Joint, Node};
