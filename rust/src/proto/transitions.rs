//! The joint-state transition graph of Fig. 1, with the paper's numbering.
//!
//! Each [`Transition`] names a *source* joint state, the initiating node,
//! the signalled operation (or `None` for silent/local transitions), and
//! the set of legal *outcome* joint states. Several transitions have more
//! than one outcome because the home node's internal policy (cache the
//! returned line vs. write it straight to RAM) is, by requirement 4,
//! invisible to the remote — both results are legal, and which one occurs
//! is an agent policy, not a protocol question.
//!
//! [`reference_transitions`] returns the full envelope (minimal protocol +
//! the transition-10 MOESI concession + local transitions + the §3.3
//! forward extension, flagged). [`crate::proto::envelope`] validates the
//! paper's seven requirements against this table; [`crate::proto::spec`]
//! compiles it (plus transient states) into the runtime state machines.

use super::messages::CohOp;
use super::states::{Joint, Node};

/// Classification labels used for reporting and for subsetting.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Tag {
    /// Numbered transition from Fig. 1 (1..=10).
    Numbered(u8),
    /// Silent local transition (dotted edge).
    Local,
    /// Envelope extension (allowed by the rules, absent on the ThunderX-1).
    Extension,
}

/// One row of the transition relation.
#[derive(Clone, Debug)]
pub struct Transition {
    pub from: Joint,
    /// Signalled operation; `None` for silent/local transitions.
    pub op: Option<CohOp>,
    /// Which node initiates (for local transitions: which node moves).
    pub by: Node,
    /// Legal outcome joint states (non-empty).
    pub outcomes: Vec<Joint>,
    pub tag: Tag,
    /// Human-readable note for the dissector/docs.
    pub note: &'static str,
}

impl Transition {
    fn new(
        from: Joint,
        op: Option<CohOp>,
        by: Node,
        outcomes: &[Joint],
        tag: Tag,
        note: &'static str,
    ) -> Transition {
        Transition { from, op, by, outcomes: outcomes.to_vec(), tag, note }
    }
    pub fn is_signalled(&self) -> bool {
        self.op.is_some()
    }
}

/// The reference transition relation (the full envelope of Fig. 1).
pub fn reference_transitions() -> Vec<Transition> {
    use CohOp::*;
    use Joint as J;
    use Node::*;
    use Tag::*;

    let t = Transition::new;
    vec![
        // ---- remote-initiated upgrades (signalled) --------------------
        t(J::II, Some(ReadShared), Remote, &[J::IS], Numbered(1), "read-shared, home I: fill from RAM"),
        t(J::SI, Some(ReadShared), Remote, &[J::SS], Numbered(1), "read-shared, home S: share home copy"),
        t(J::EI, Some(ReadShared), Remote, &[J::SS], Numbered(1), "read-shared, home E: demote home to S, share"),
        // Transition 10 — the MOESI concession: remote reads a line the
        // home holds dirty. Home may keep a hidden-dirty copy (external
        // SS; internal O) or silently write back and drop (external IS).
        // Which happens must be invisible to the remote (requirement 4).
        t(J::MI, Some(ReadShared), Remote, &[J::SS, J::IS], Numbered(10), "read-shared of home-dirty line (hidden O or silent writeback)"),
        t(J::II, Some(ReadExclusive), Remote, &[J::IE], Numbered(2), "read-exclusive, home I"),
        t(J::SI, Some(ReadExclusive), Remote, &[J::IE], Numbered(2), "read-exclusive, home S: home invalidates own copy"),
        t(J::EI, Some(ReadExclusive), Remote, &[J::IE], Numbered(2), "read-exclusive, home E: home invalidates own copy"),
        t(J::MI, Some(ReadExclusive), Remote, &[J::IM], Numbered(2), "read-exclusive of home-dirty line: dirty ownership moves across"),
        t(J::IS, Some(UpgradeS2E), Remote, &[J::IE], Numbered(3), "upgrade shared-to-exclusive, no data"),
        t(J::SS, Some(UpgradeS2E), Remote, &[J::IE], Numbered(3), "upgrade shared-to-exclusive: home invalidates own copy"),
        // ---- remote-initiated voluntary downgrades (signalled, no rsp) -
        t(J::IM, Some(VolDowngradeI), Remote, &[J::II, J::MI], Numbered(4), "writeback: home writes RAM (II) or caches dirty (MI)"),
        t(J::IE, Some(VolDowngradeI), Remote, &[J::II, J::EI], Numbered(5), "drop exclusive clean"),
        t(J::IS, Some(VolDowngradeI), Remote, &[J::II, J::SI], Numbered(6), "drop shared clean, home had no copy"),
        t(J::SS, Some(VolDowngradeI), Remote, &[J::SI, J::EI], Numbered(6), "drop shared clean; home may promote its copy"),
        t(J::IM, Some(VolDowngradeS), Remote, &[J::SS, J::IS], Numbered(7), "demote dirty to shared: home takes dirty data (hidden O) or writes RAM"),
        t(J::IE, Some(VolDowngradeS), Remote, &[J::IS, J::SS], Numbered(7), "demote exclusive clean to shared"),
        // ---- home-initiated downgrades (signalled, response required) --
        t(J::IS, Some(FwdDowngradeI), Home, &[J::II], Numbered(8), "invalidate remote shared copy (home had none)"),
        t(J::SS, Some(FwdDowngradeI), Home, &[J::EI], Numbered(8), "invalidate remote shared copy; home now sole owner"),
        t(J::IE, Some(FwdDowngradeI), Home, &[J::II], Numbered(8), "invalidate remote exclusive (clean response)"),
        t(J::IM, Some(FwdDowngradeI), Home, &[J::MI, J::II], Numbered(8), "invalidate remote modified: dirty data returns"),
        t(J::IE, Some(FwdDowngradeS), Home, &[J::IS], Numbered(9), "demote remote exclusive to shared (clean response)"),
        t(J::IM, Some(FwdDowngradeS), Home, &[J::SS, J::IS], Numbered(9), "demote remote modified to shared: dirty data returns"),
        // ---- envelope extension (§3.3, not on the ThunderX-1) ----------
        // R7 forces a row for SS too (the remote cannot distinguish IS
        // from SS): there the forwarded line is redundant at home, which
        // simply ends up sole owner.
        t(J::IS, Some(FwdSharedInvalidate), Home, &[J::SI], Extension, "invalidate remote and forward clean line, avoiding a RAM read"),
        t(J::SS, Some(FwdSharedInvalidate), Home, &[J::EI], Extension, "invalidate-and-forward when home already shares the line"),
        // ---- silent local transitions (dotted edges) --------------------
        // Remote dirties its exclusive copy. By requirement 3 this edge is
        // one-way: IM may never silently become IE.
        t(J::IE, None, Remote, &[J::IM], Local, "remote write to E: silent upgrade to M"),
        // Home caching its own memory (the other node cannot tell).
        t(J::II, None, Home, &[J::SI], Local, "home reads own line (shared)"),
        t(J::II, None, Home, &[J::EI], Local, "home reads own line (exclusive)"),
        t(J::SI, None, Home, &[J::EI], Local, "home promotes its sole shared copy"),
        t(J::EI, None, Home, &[J::MI], Local, "home writes its exclusive copy"),
        t(J::MI, None, Home, &[J::EI], Local, "home writes back locally, keeps clean copy"),
        t(J::EI, None, Home, &[J::SI], Local, "home demotes its copy"),
        t(J::SI, None, Home, &[J::II], Local, "home drops its clean copy"),
        t(J::IS, None, Home, &[J::SS], Local, "home picks up a clean copy of a remote-shared line"),
        t(J::SS, None, Home, &[J::IS], Local, "home drops its clean copy of a remote-shared line"),
    ]
}

/// Look up the signalled transitions available to `by` at joint state
/// `from` in a transition table.
pub fn signalled_ops_at(table: &[Transition], by: Node, from: Joint) -> Vec<CohOp> {
    let mut ops: Vec<CohOp> = table
        .iter()
        .filter(|t| t.by == by && t.from == from)
        .filter_map(|t| t.op)
        .collect();
    ops.sort_by_key(|o| *o as u8);
    ops.dedup();
    ops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::states::DistanceOrder;

    #[test]
    fn all_endpoints_are_valid_joint_states() {
        for tr in reference_transitions() {
            assert!(tr.from.is_valid(), "{tr:?}");
            assert!(!tr.outcomes.is_empty());
            for &o in &tr.outcomes {
                assert!(o.is_valid(), "{tr:?} -> {o}");
            }
        }
    }

    #[test]
    fn transitions_never_self_loop() {
        for tr in reference_transitions() {
            for &o in &tr.outcomes {
                assert_ne!(tr.from, o, "self-loop in {tr:?}");
            }
        }
    }

    #[test]
    fn upgrades_go_up_downgrades_go_down_except_10() {
        let ord = DistanceOrder::new();
        for tr in reference_transitions() {
            for &o in &tr.outcomes {
                if matches!(tr.tag, Tag::Numbered(10)) {
                    // the sanctioned exception: between unrelated states
                    if !ord.related(tr.from, o) {
                        continue;
                    }
                }
                assert!(
                    ord.related(tr.from, o),
                    "{:?}: {} -> {} between unrelated states",
                    tr,
                    tr.from,
                    o
                );
            }
        }
    }

    #[test]
    fn paper_counts_three_fwd_invalidate_sources_for_home_visibility() {
        // "the three transitions labeled 8": from home's view IE and IM are
        // one state, so sources {IS, SS, IE/IM} = 3 distinguishable cases.
        let table = reference_transitions();
        let sources: Vec<Joint> = table
            .iter()
            .filter(|t| matches!(t.tag, Tag::Numbered(8)))
            .map(|t| t.from)
            .collect();
        assert_eq!(sources.len(), 4); // IS, SS, IE, IM rows
        let mut classes = vec![];
        for s in sources {
            let cls = crate::proto::states::visibility_class(Node::Home, s);
            if !classes.contains(&cls) {
                classes.push(cls);
            }
        }
        assert_eq!(classes.len(), 3, "home distinguishes exactly 3 source classes");
    }

    #[test]
    fn transition_10_exists_and_is_read_shared_from_mi() {
        let table = reference_transitions();
        let t10: Vec<&Transition> =
            table.iter().filter(|t| matches!(t.tag, Tag::Numbered(10))).collect();
        assert_eq!(t10.len(), 1);
        assert_eq!(t10[0].from, Joint::MI);
        assert_eq!(t10[0].op, Some(CohOp::ReadShared));
        assert_eq!(t10[0].outcomes, vec![Joint::SS, Joint::IS]);
    }

    #[test]
    fn no_silent_dirty_to_clean_for_remote() {
        // Requirement 3 structural check at the table level.
        for tr in reference_transitions() {
            if tr.op.is_none() && tr.by == Node::Remote {
                for &o in &tr.outcomes {
                    assert!(
                        !(tr.from.remote.dirty() && !o.remote.dirty()),
                        "silent remote dirty->clean: {tr:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn signalled_ops_uniform_within_fig1b_star_i() {
        // Remote must be able to issue the same requests in every *I state
        // (requirement 6) — here just sanity-check ReadShared/ReadExclusive
        // exist in all four.
        let table = reference_transitions();
        for j in [Joint::II, Joint::SI, Joint::EI, Joint::MI] {
            let ops = signalled_ops_at(&table, Node::Remote, j);
            assert!(ops.contains(&CohOp::ReadShared), "{j}");
            assert!(ops.contains(&CohOp::ReadExclusive), "{j}");
        }
    }
}
