//! Protocol specialization (paper §3.4, Fig. 2).
//!
//! ECI's point is that the coherence protocol can be *subset* per
//! application. A [`Subset`] is a filtered transition table plus agent
//! capability flags; [`validate`] proves (by the envelope rules plus a
//! reachability argument) that a subset interoperates with a partner that
//! speaks the full protocol — formalizing the paper's §3.4 narrative that
//! walks from full MESI down to the stateless read-only home.
//!
//! The four reference instances:
//!
//! * [`Subset::full_symmetric`] — Fig. 2(b): CPU and FPGA as peers, the
//!   complete envelope.
//! * [`Subset::asymmetric_accelerator`] — Fig. 2(a): the FPGA as a caching
//!   agent / DMA initiator; home-side logic stays on the CPU.
//! * [`Subset::cpu_initiator_readonly`] — Fig. 2(c) with a read-only
//!   workload: the two-state `{II, IS}` protocol (home still invalidates
//!   to evict clean data).
//! * [`Subset::stateless_readonly`] — the paper's headline optimization:
//!   the FPGA home answers `ReadShared` and silently ignores voluntary
//!   downgrades, tracking **no state at all** per line (`I*`). Used by all
//!   three operator workloads of §5.

use super::envelope::{check_envelope, check_interop, Violation};
use super::messages::CohOp;
use super::states::{Joint, Node};
use super::transitions::{reference_transitions, Tag, Transition};

/// Optional protocol features beyond the minimal envelope.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Feature {
    /// Transition 10 / hidden O (MOESI concession). On the ThunderX-1.
    HiddenO,
    /// "Downgrade remote to invalid and forward" (IS -> SI). *Not* on the
    /// ThunderX-1; legal under the envelope (§3.3).
    ForwardOnInvalidate,
}

/// A protocol subset: the transitions an implementation supports, plus
/// capability flags that the agents and the resource model consume.
#[derive(Clone, Debug)]
pub struct Subset {
    pub name: &'static str,
    pub transitions: Vec<Transition>,
    /// Does the home node keep per-line directory state?
    pub home_tracks_state: bool,
    /// Does the home node cache data lines?
    pub home_caches: bool,
    /// Does the remote node cache data lines? (always true for the CPU)
    pub remote_caches: bool,
    pub features: Vec<Feature>,
}

impl Subset {
    /// Fig. 2(b): fully-coherent symmetric two-node system.
    pub fn full_symmetric() -> Subset {
        let transitions = reference_transitions()
            .into_iter()
            .filter(|t| !matches!(t.tag, Tag::Extension))
            .collect();
        Subset {
            name: "full-symmetric",
            transitions,
            home_tracks_state: true,
            home_caches: true,
            remote_caches: true,
            features: vec![Feature::HiddenO],
        }
    }

    /// Fig. 2(a): the accelerator as caching agent/DMA initiator. The
    /// FPGA plays the *remote* role against the CPU's home; the subset
    /// drops home-side local caching transitions (the accelerator homes
    /// no memory).
    pub fn asymmetric_accelerator() -> Subset {
        let transitions = reference_transitions()
            .into_iter()
            .filter(|t| !matches!(t.tag, Tag::Extension))
            // no home-local caching on the accelerator side
            .filter(|t| !(t.tag == Tag::Local && t.by == Node::Home))
            .collect();
        Subset {
            name: "asymmetric-accelerator",
            transitions,
            home_tracks_state: true,
            home_caches: false,
            remote_caches: true,
            features: vec![Feature::HiddenO],
        }
    }

    /// Fig. 2(c) + read-only workload, first simplification step of §3.4:
    /// states {II, IS}; home-initiated invalidation retained only to evict
    /// clean data; remote keeps ReadShared + voluntary invalidation.
    pub fn cpu_initiator_readonly() -> Subset {
        let keep_states = [Joint::II, Joint::IS];
        // Keep only the rows among {II, IS} for the three surviving ops,
        // trimming multi-outcome rows to the outcomes inside the subset
        // (the trimmed outcomes are home policies the subset forgoes,
        // e.g. caching a returning line — dropping them is always legal).
        let transitions: Vec<Transition> = reference_transitions()
            .into_iter()
            .filter_map(|mut t| {
                if !keep_states.contains(&t.from)
                    || !matches!(
                        t.op,
                        Some(CohOp::ReadShared)
                            | Some(CohOp::VolDowngradeI)
                            | Some(CohOp::FwdDowngradeI)
                    )
                {
                    return None;
                }
                t.outcomes.retain(|o| keep_states.contains(o));
                if t.outcomes.is_empty() {
                    None
                } else {
                    Some(t)
                }
            })
            .collect();
        Subset {
            name: "cpu-initiator-readonly",
            transitions,
            home_tracks_state: true,
            home_caches: false,
            remote_caches: true,
            features: vec![],
        }
    }

    /// The paper's fully-degenerate endpoint: "the FPGA need track no
    /// state at all for a cache line". Home answers `ReadShared` with
    /// data and silently ignores voluntary downgrades; there are **no**
    /// home-initiated transitions. Externally the line lives in the
    /// combined state `I*`.
    pub fn stateless_readonly() -> Subset {
        let transitions: Vec<Transition> = reference_transitions()
            .into_iter()
            .filter_map(|mut t| {
                if !matches!(t.op, Some(CohOp::ReadShared) | Some(CohOp::VolDowngradeI))
                    || t.from.home != super::states::CacheState::I
                {
                    return None;
                }
                // the stateless home never caches: trim outcomes that
                // would put data in the home cache
                t.outcomes.retain(|o| o.home == super::states::CacheState::I);
                if t.outcomes.is_empty() {
                    None
                } else {
                    Some(t)
                }
            })
            .collect();
        Subset {
            name: "stateless-readonly",
            transitions,
            home_tracks_state: false,
            home_caches: false,
            remote_caches: true,
            features: vec![],
        }
    }

    /// Full protocol plus the §3.3 forward extension.
    pub fn extended() -> Subset {
        let mut s = Subset::full_symmetric();
        s.name = "extended-forward";
        s.transitions = reference_transitions(); // includes the extension row
        s.features.push(Feature::ForwardOnInvalidate);
        s
    }

    /// Joint states reachable from `II` under this subset's transitions.
    pub fn reachable_states(&self) -> Vec<Joint> {
        let mut reach = vec![Joint::II];
        let mut frontier = vec![Joint::II];
        while let Some(j) = frontier.pop() {
            for t in &self.transitions {
                if t.from == j {
                    for &o in &t.outcomes {
                        if !reach.contains(&o) {
                            reach.push(o);
                            frontier.push(o);
                        }
                    }
                }
            }
        }
        reach.sort_by_key(|j| Joint::ALL.iter().position(|k| k == j));
        reach
    }

    /// The ops `node` may emit within this subset (over reachable states).
    pub fn emittable_ops(&self, node: Node) -> Vec<CohOp> {
        let reach = self.reachable_states();
        let mut ops: Vec<CohOp> = self
            .transitions
            .iter()
            .filter(|t| t.by == node && reach.contains(&t.from))
            .filter_map(|t| t.op)
            .collect();
        ops.sort_by_key(|o| *o as u8);
        ops.dedup();
        ops
    }

    /// Number of distinguishable states the home must track per line under
    /// this subset (the paper's space argument: 1 for stateless-readonly).
    pub fn home_state_count(&self) -> usize {
        if !self.home_tracks_state {
            return 1; // the combined I* state
        }
        let reach = self.reachable_states();
        // home distinguishes states up to its own indistinguishability
        let mut classes: Vec<Vec<Joint>> = Vec::new();
        for &j in &reach {
            let cls: Vec<Joint> = reach
                .iter()
                .copied()
                .filter(|&k| super::states::indistinguishable(Node::Home, j, k))
                .collect();
            if !classes.contains(&cls) {
                classes.push(cls);
            }
        }
        classes.len()
    }
}

/// Validate a subset against a partner implementation (requirement 5 and
/// envelope conformance on the subset's own table), assuming the partner
/// may emit any op in its table.
pub fn validate(subset: &Subset, partner: &Subset) -> Vec<Violation> {
    validate_with_workload(subset, partner, &CohOp::ALL)
}

/// Like [`validate`] but restricting the partner's emissions to
/// `workload_ops` — the paper's R5 escape hatch: "an implementation must
/// support all transitions the partner may signal, **unless it can be
/// guaranteed these won't be generated (e.g. with a read-only workload)**".
pub fn validate_with_workload(
    subset: &Subset,
    partner: &Subset,
    workload_ops: &[CohOp],
) -> Vec<Violation> {
    let mut v = Vec::new();
    // The subset's own table must respect the envelope on its reachable
    // fragment. R1–R4 are structural and always apply; R6/R7 quantify over
    // states, and a subset legitimately drops whole states, so re-run them
    // restricted to the subset's reachable fragment.
    let reach = subset.reachable_states();
    for viol in check_envelope(&subset.transitions) {
        if !matches!(viol.requirement, 6 | 7) {
            v.push(viol);
        }
    }
    // R6 over reachable states only.
    for node in [Node::Home, Node::Remote] {
        for &a in &reach {
            for &b in &reach {
                if a != b && super::states::indistinguishable(node, a, b) {
                    let ops_a = super::transitions::signalled_ops_at(&subset.transitions, node, a);
                    let ops_b = super::transitions::signalled_ops_at(&subset.transitions, node, b);
                    for op in &ops_a {
                        if !ops_b.contains(op) {
                            v.push(Violation {
                                requirement: 6,
                                detail: format!(
                                    "[{}] {node:?} may signal {op:?} in {a} but not in indistinguishable reachable {b}",
                                    subset.name
                                ),
                            });
                        }
                    }
                }
            }
        }
    }
    // R7 over reachable states only: the receiver must have a row for any
    // op in every reachable state indistinguishable (to it) from a state
    // where the op can occur.
    for node in [Node::Home, Node::Remote] {
        let receiver = node.other();
        for op in CohOp::ALL {
            let sources: Vec<Joint> = subset
                .transitions
                .iter()
                .filter(|t| t.by == node && t.op == Some(op))
                .map(|t| t.from)
                .collect();
            for &s in &sources {
                for &j in &reach {
                    if super::states::indistinguishable(receiver, s, j)
                        && !sources.contains(&j)
                        && subset.transitions.iter().any(|t| t.by == node && t.from == j)
                    {
                        v.push(Violation {
                            requirement: 7,
                            detail: format!(
                                "[{}] {receiver:?} must handle {op:?} in reachable {j} (indistinguishable from {s})",
                                subset.name
                            ),
                        });
                    }
                }
            }
        }
    }
    // R5 both ways, restricted to the *reachable* fragment of the subset.
    // (check_interop is table-global; filter to rows whose source state is
    // reachable in this subset.)
    for node in [Node::Home, Node::Remote] {
        for viol in check_interop(&subset.transitions, node, &partner.transitions) {
            v.push(viol);
        }
        // partner may emit only what we can receive — over our reachable
        // states (e.g. a read-only home never sees ReadExclusive because
        // IE is unreachable) and within the declared workload.
        let partner_node = node.other();
        for t in partner.transitions.iter().filter(|t| t.by == partner_node && t.op.is_some()) {
            if !reach.contains(&t.from) {
                continue; // unreachable under this subset's workload
            }
            if !workload_ops.contains(&t.op.unwrap()) {
                continue; // the workload guarantees this is never emitted
            }
            let op = t.op.unwrap();
            let handled = subset
                .transitions
                .iter()
                .any(|s| s.by == partner_node && s.op == Some(op) && s.from == t.from);
            if !handled {
                v.push(Violation {
                    requirement: 5,
                    detail: format!(
                        "[{}] partner may signal {op:?} from reachable {} but subset has no row",
                        subset.name, t.from
                    ),
                });
            }
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_symmetric_validates_against_itself() {
        let s = Subset::full_symmetric();
        let v = validate(&s, &s);
        assert!(v.is_empty(), "{v:?}");
        assert_eq!(s.reachable_states().len(), 8, "full protocol reaches all 8 joint states");
    }

    #[test]
    fn readonly_subset_reaches_exactly_ii_and_is() {
        // §3.4: "leaving only a two-state protocol consisting of IS and II"
        let s = Subset::cpu_initiator_readonly();
        assert_eq!(s.reachable_states(), vec![Joint::II, Joint::IS]);
    }

    #[test]
    fn readonly_subset_home_sees_one_invalidate_transition() {
        // "The only reason for this one remaining home-visible transition
        // is to evict data known to be clean"
        let s = Subset::cpu_initiator_readonly();
        let home_ops = s.emittable_ops(Node::Home);
        assert_eq!(home_ops, vec![CohOp::FwdDowngradeI]);
    }

    #[test]
    fn stateless_readonly_tracks_one_state_and_initiates_nothing() {
        // "the FPGA need track no state at all for a cache line"
        let s = Subset::stateless_readonly();
        assert_eq!(s.home_state_count(), 1);
        assert!(s.emittable_ops(Node::Home).is_empty(), "no home-initiated transitions");
        // remote may still read and voluntarily drop
        let r = s.emittable_ops(Node::Remote);
        assert_eq!(r, vec![CohOp::ReadShared, CohOp::VolDowngradeI]);
    }

    #[test]
    fn stateless_readonly_interoperates_with_full_partner() {
        // The CPU speaks the full protocol; under a read-only workload the
        // stateless home must interoperate flawlessly (§5's claim). The
        // workload guarantee is exactly R5's escape hatch.
        let s = Subset::stateless_readonly();
        let full = Subset::full_symmetric();
        let v = validate_with_workload(&s, &full, &[CohOp::ReadShared, CohOp::VolDowngradeI]);
        assert!(v.is_empty(), "stateless subset should validate: {v:?}");
        // ...but WITHOUT the workload guarantee, validation correctly
        // reports that a writing CPU would break it.
        let v = validate(&s, &full);
        assert!(
            v.iter().any(|x| x.requirement == 5),
            "a writing workload must be flagged: {v:?}"
        );
    }

    #[test]
    fn asymmetric_subset_validates() {
        let s = Subset::asymmetric_accelerator();
        let full = Subset::full_symmetric();
        let v = validate(&s, &full);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn extended_subset_includes_forward() {
        let s = Subset::extended();
        assert!(s.transitions.iter().any(|t| t.op == Some(CohOp::FwdSharedInvalidate)));
        assert!(s.features.contains(&Feature::ForwardOnInvalidate));
        // still envelope-clean
        let v = check_envelope(&s.transitions);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn state_count_shrinks_down_the_specialization_ladder() {
        // the paper's space argument, quantified
        let full = Subset::full_symmetric().home_state_count();
        let ro = Subset::cpu_initiator_readonly().home_state_count();
        let stateless = Subset::stateless_readonly().home_state_count();
        assert!(full > ro, "full {full} vs readonly {ro}");
        assert!(ro > stateless || (ro == 2 && stateless == 1));
        assert_eq!(stateless, 1);
    }
}
