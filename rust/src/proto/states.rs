//! ECI protocol states and the "distance" partial order (paper Fig. 1).
//!
//! The paper abstracts the ThunderX-1's native MOESI into an *enhanced MESI*
//! over **joint states**: the pair `(home, remote)` of per-node stable
//! states for one cache line. Validity, the partial order by distance of
//! the data from its at-rest position, and the local-transition
//! (indistinguishability) groups are all encoded here, and everything the
//! paper states in prose about Fig. 1 is asserted by the unit tests below.
//!
//! Naming convention follows the paper: `IS` means home = I, remote = S.
//!
//! The hidden **O** state (home holds the line dirty while the remote holds
//! it shared — MOESI's "owned") is deliberately *not* a joint state: the
//! paper requires it to be externally indistinguishable from `SS`
//! (requirement 4). Agents carry a private `dirty` bit instead; see
//! [`crate::agents::home`].

use std::fmt;

/// Per-node stable cache state (MESI; `O` exists only as home-internal
/// dirtiness, see module docs).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub enum CacheState {
    /// Invalid — no copy.
    I,
    /// Shared — read-only copy; other copies may exist.
    S,
    /// Exclusive — the only copy, clean.
    E,
    /// Modified — the only copy, dirty.
    M,
}

impl CacheState {
    pub const ALL: [CacheState; 4] = [CacheState::I, CacheState::S, CacheState::E, CacheState::M];

    /// May the node read the line without a coherence action?
    #[inline]
    pub fn readable(self) -> bool {
        self != CacheState::I
    }
    /// May the node write the line without a coherence action?
    /// (A write to `E` silently upgrades to `M` — a *local* transition.)
    #[inline]
    pub fn writable(self) -> bool {
        matches!(self, CacheState::E | CacheState::M)
    }
    #[inline]
    pub fn dirty(self) -> bool {
        self == CacheState::M
    }
    /// Single-letter name as used in the paper.
    pub fn letter(self) -> char {
        match self {
            CacheState::I => 'I',
            CacheState::S => 'S',
            CacheState::E => 'E',
            CacheState::M => 'M',
        }
    }
}

/// A joint (home, remote) state for one cache line.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Joint {
    pub home: CacheState,
    pub remote: CacheState,
}

#[allow(non_upper_case_globals)]
impl Joint {
    pub const II: Joint = Joint::new(CacheState::I, CacheState::I);
    pub const IS: Joint = Joint::new(CacheState::I, CacheState::S);
    pub const IE: Joint = Joint::new(CacheState::I, CacheState::E);
    pub const IM: Joint = Joint::new(CacheState::I, CacheState::M);
    pub const SI: Joint = Joint::new(CacheState::S, CacheState::I);
    pub const SS: Joint = Joint::new(CacheState::S, CacheState::S);
    pub const EI: Joint = Joint::new(CacheState::E, CacheState::I);
    pub const MI: Joint = Joint::new(CacheState::M, CacheState::I);

    pub const fn new(home: CacheState, remote: CacheState) -> Joint {
        Joint { home, remote }
    }

    /// The eight externally-visible joint states of Fig. 1(c), in the
    /// paper's reading order.
    pub const ALL: [Joint; 8] = [
        Joint::II,
        Joint::IS,
        Joint::IE,
        Joint::IM,
        Joint::SI,
        Joint::SS,
        Joint::EI,
        Joint::MI,
    ];

    /// Is this pair of per-node states coherent?
    ///
    /// Single-writer / multiple-reader: `E`/`M` on either side excludes any
    /// copy on the other; `S` may pair only with `I` or `S`.
    pub fn is_valid(self) -> bool {
        use CacheState::*;
        match (self.home, self.remote) {
            (I, _) | (_, I) => true,
            (S, S) => true,
            _ => false,
        }
    }
}

impl fmt::Debug for Joint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}{}", self.home.letter(), self.remote.letter())
    }
}
impl fmt::Display for Joint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:?}")
    }
}

/// Covering edges of the distance partial order (Hasse diagram of
/// Fig. 1(a)): `(lower, higher)`. "Higher" = data farther from its at-rest
/// position (remote-ness, then dirtiness).
///
/// * home-local chain `II < SI < EI < MI` — the home node caching its own
///   memory, increasingly exclusively/dirtily; all local (dotted) edges.
/// * `II < IS`: read-shared (transition 1).
/// * `SI < SS`, `EI < SS`: data also granted to the remote.
/// * `SS < IS`: home drops its clean copy while remote still shares (the
///   dotted edge inside the `*S` group of Fig. 1(b)).
/// * `IS < IE`, `SS < IE`: upgrade shared-to-exclusive (transition 3).
/// * `IE < IM`: the remote dirties its exclusive copy — local (dotted),
///   and by requirement 3 traversable only upward.
/// * `MI < IM`: read-exclusive of a home-dirty line moves the dirty data
///   across the link.
pub const COVERING_EDGES: [(Joint, Joint); 9] = [
    (Joint::II, Joint::SI),
    (Joint::SI, Joint::EI),
    (Joint::EI, Joint::MI),
    (Joint::II, Joint::IS),
    (Joint::SI, Joint::SS),
    (Joint::EI, Joint::SS),
    (Joint::SS, Joint::IS),
    (Joint::IS, Joint::IE),
    (Joint::IE, Joint::IM),
];

/// Extra covering edge: `MI < IM` (read-exclusive forwards home-dirty data).
pub const COVERING_EDGE_MI_IM: (Joint, Joint) = (Joint::MI, Joint::IM);

fn idx(j: Joint) -> usize {
    Joint::ALL.iter().position(|&k| k == j).expect("not a stable joint state")
}

/// The distance partial order as a reachability matrix (transitive closure
/// of the covering edges). `le(a, b)` means `a` is at or below `b`.
pub struct DistanceOrder {
    le: [[bool; 8]; 8],
}

impl Default for DistanceOrder {
    fn default() -> Self {
        Self::new()
    }
}

impl DistanceOrder {
    pub fn new() -> Self {
        let mut le = [[false; 8]; 8];
        for i in 0..8 {
            le[i][i] = true;
        }
        let mut edges: Vec<(Joint, Joint)> = COVERING_EDGES.to_vec();
        edges.push(COVERING_EDGE_MI_IM);
        for (a, b) in edges {
            le[idx(a)][idx(b)] = true;
        }
        // Floyd-Warshall closure.
        for k in 0..8 {
            for i in 0..8 {
                if le[i][k] {
                    for j in 0..8 {
                        if le[k][j] {
                            le[i][j] = true;
                        }
                    }
                }
            }
        }
        DistanceOrder { le }
    }

    #[inline]
    pub fn le(&self, a: Joint, b: Joint) -> bool {
        self.le[idx(a)][idx(b)]
    }
    #[inline]
    pub fn lt(&self, a: Joint, b: Joint) -> bool {
        a != b && self.le(a, b)
    }
    /// Comparable under the distance order?
    #[inline]
    pub fn related(&self, a: Joint, b: Joint) -> bool {
        self.le(a, b) || self.le(b, a)
    }
}

/// Which node observes a state/transition.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Node {
    Home,
    Remote,
}

impl Node {
    pub fn other(self) -> Node {
        match self {
            Node::Home => Node::Remote,
            Node::Remote => Node::Home,
        }
    }
    /// The component of a joint state this node *is*.
    pub fn own_state(self, j: Joint) -> CacheState {
        match self {
            Node::Home => j.home,
            Node::Remote => j.remote,
        }
    }
    /// The component of a joint state this node *sees at the partner*.
    pub fn partner_state(self, j: Joint) -> CacheState {
        self.other().own_state(j)
    }
}

/// Are two joint states indistinguishable to `observer`?
///
/// Fig. 1(b): to the **remote**, `{II, SI, EI, MI}` collapse to `*I` and
/// `{IS, SS}` to `*S` (the home side must keep its dirtiness invisible,
/// requirement 4). To the **home**, `{IE, IM}` collapse (the upgrade to
/// `IM` is silent — the paper: "The home node cannot distinguish IM and
/// IE").
pub fn indistinguishable(observer: Node, a: Joint, b: Joint) -> bool {
    match observer {
        Node::Remote => a.remote == b.remote,
        Node::Home => {
            a.home == b.home
                && matches!(
                    (a.remote, b.remote),
                    (x, y) if x == y
                        || matches!((x, y), (CacheState::E, CacheState::M) | (CacheState::M, CacheState::E))
                )
        }
    }
}

/// The equivalence class of `j` as seen by `observer`, over stable states.
pub fn visibility_class(observer: Node, j: Joint) -> Vec<Joint> {
    Joint::ALL
        .iter()
        .copied()
        .filter(|&k| indistinguishable(observer, j, k))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use CacheState::*;

    #[test]
    fn exactly_eight_valid_joint_states() {
        let mut valid = Vec::new();
        for &h in &CacheState::ALL {
            for &r in &CacheState::ALL {
                let j = Joint::new(h, r);
                if j.is_valid() {
                    valid.push(j);
                }
            }
        }
        assert_eq!(valid.len(), 8);
        for j in Joint::ALL {
            assert!(valid.contains(&j));
        }
        // and the single-writer violations are rejected
        assert!(!Joint::new(M, M).is_valid());
        assert!(!Joint::new(E, S).is_valid());
        assert!(!Joint::new(S, M).is_valid());
        assert!(!Joint::new(E, E).is_valid());
    }

    #[test]
    fn paper_example_im_above_ii() {
        // "the order is transitive, and thus IM ... compares higher than II"
        let ord = DistanceOrder::new();
        assert!(ord.lt(Joint::II, Joint::IM));
    }

    #[test]
    fn order_is_a_partial_order() {
        let ord = DistanceOrder::new();
        // reflexive
        for a in Joint::ALL {
            assert!(ord.le(a, a));
        }
        // antisymmetric
        for a in Joint::ALL {
            for b in Joint::ALL {
                if a != b {
                    assert!(!(ord.le(a, b) && ord.le(b, a)), "{a} and {b} form a cycle");
                }
            }
        }
        // transitive (by construction, but verify)
        for a in Joint::ALL {
            for b in Joint::ALL {
                for c in Joint::ALL {
                    if ord.le(a, b) && ord.le(b, c) {
                        assert!(ord.le(a, c));
                    }
                }
            }
        }
    }

    #[test]
    fn mi_unrelated_to_is_and_ss_the_transition_10_exception() {
        // Transition 10 (MI -> SS or IS on a remote read of a home-dirty
        // line) is called out as the one exception to requirement 1, so MI
        // must be *unrelated* to both targets.
        let ord = DistanceOrder::new();
        assert!(!ord.related(Joint::MI, Joint::SS));
        assert!(!ord.related(Joint::MI, Joint::IS));
    }

    #[test]
    fn ie_and_mi_unrelated_paper_example() {
        // "Transitions between unrelated states e.g. (IE and MI) are
        // forbidden" — so they must indeed be unrelated.
        let ord = DistanceOrder::new();
        assert!(!ord.related(Joint::IE, Joint::MI));
    }

    #[test]
    fn ii_is_bottom_im_is_top() {
        let ord = DistanceOrder::new();
        for j in Joint::ALL {
            assert!(ord.le(Joint::II, j), "II should be below {j}");
            assert!(ord.le(j, Joint::IM), "{j} should be below IM");
        }
    }

    #[test]
    fn remote_visibility_groups_match_fig_1b() {
        // *I = {II, SI, EI, MI}
        let star_i = visibility_class(Node::Remote, Joint::II);
        assert_eq!(star_i.len(), 4);
        for j in [Joint::II, Joint::SI, Joint::EI, Joint::MI] {
            assert!(star_i.contains(&j));
        }
        // *S = {IS, SS}
        let star_s = visibility_class(Node::Remote, Joint::IS);
        assert_eq!(star_s, vec![Joint::IS, Joint::SS]);
        // IE and IM are their own classes for the remote
        assert_eq!(visibility_class(Node::Remote, Joint::IE), vec![Joint::IE]);
        assert_eq!(visibility_class(Node::Remote, Joint::IM), vec![Joint::IM]);
    }

    #[test]
    fn home_cannot_distinguish_ie_from_im() {
        assert!(indistinguishable(Node::Home, Joint::IE, Joint::IM));
        let class = visibility_class(Node::Home, Joint::IE);
        assert_eq!(class, vec![Joint::IE, Joint::IM]);
        // but home distinguishes everything else
        assert!(!indistinguishable(Node::Home, Joint::IS, Joint::SS));
        assert!(!indistinguishable(Node::Home, Joint::II, Joint::SI));
    }

    #[test]
    fn readable_writable_dirty() {
        assert!(!I.readable());
        assert!(S.readable() && !S.writable());
        assert!(E.writable() && !E.dirty());
        assert!(M.writable() && M.dirty());
    }
}
