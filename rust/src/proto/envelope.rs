//! The protocol *envelope*: the paper's seven requirements and two
//! recommendations (§3.3), as executable checks over a transition table.
//!
//! The paper derives these rules from the distance order and uses them to
//! argue that subsets (§3.4) remain interoperable. Here they are machine-
//! checkable: [`check_envelope`] validates any transition table (the
//! reference table, a subset, or a user extension) and returns every
//! violation found. The reference table must pass with zero violations
//! (asserted in tests); mutation tests in `rust/tests/` assert that
//! deliberately-broken tables are caught.

use std::fmt;

use super::messages::CohOp;
use super::states::{indistinguishable, DistanceOrder, Joint, Node};
use super::transitions::{signalled_ops_at, Tag, Transition};

/// A violation of one of the envelope requirements.
#[derive(Clone, Debug)]
pub struct Violation {
    /// Requirement number (1..=7) from §3.3.
    pub requirement: u8,
    pub detail: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "R{}: {}", self.requirement, self.detail)
    }
}

/// Check a transition table against requirements 1–7.
pub fn check_envelope(table: &[Transition]) -> Vec<Violation> {
    let mut v = Vec::new();
    let ord = DistanceOrder::new();

    // R1: transitions only between order-related states (up or down),
    //     except the sanctioned transition 10.
    for tr in table {
        for &o in &tr.outcomes {
            if tr.from == o {
                v.push(Violation {
                    requirement: 1,
                    detail: format!("self-loop at {} ({})", tr.from, tr.note),
                });
                continue;
            }
            if !ord.related(tr.from, o) && !matches!(tr.tag, Tag::Numbered(10)) {
                v.push(Violation {
                    requirement: 1,
                    detail: format!(
                        "transition {} -> {} between unrelated states ({})",
                        tr.from, o, tr.note
                    ),
                });
            }
        }
    }

    // R2: any transition between states distinguishable to the *other*
    //     node must be signalled; silent transitions must stay within the
    //     partner's indistinguishability class.
    for tr in table {
        if tr.op.is_none() {
            let partner = tr.by.other();
            for &o in &tr.outcomes {
                if !indistinguishable(partner, tr.from, o) {
                    v.push(Violation {
                        requirement: 2,
                        detail: format!(
                            "silent transition {} -> {} is visible to {:?} ({})",
                            tr.from, o, partner, tr.note
                        ),
                    });
                }
            }
        }
    }

    // R3: moving from a dirty to a clean *remote* state must signal home.
    //     (I.e. IE -> IM is one-way silent; the only downgrade path from
    //     remote-dirty is a signalled one.)
    for tr in table {
        if tr.op.is_none() && tr.by == Node::Remote {
            for &o in &tr.outcomes {
                if tr.from.remote.dirty() && !o.remote.dirty() {
                    v.push(Violation {
                        requirement: 3,
                        detail: format!("silent remote dirty->clean {} -> {} ({})", tr.from, o, tr.note),
                    });
                }
            }
        }
    }

    // R4: where the remote holds a clean shared copy, the home's dirtiness
    //     must be invisible: any op available in one of the remote's *S
    //     states must yield remotely-indistinguishable outcome sets across
    //     all *S states. Structurally: outcomes of transitions that differ
    //     only in home state must agree on the remote component.
    //     We check the IS/SS pair (the *S class).
    for op in CohOp::ALL {
        let r_is = remote_outcomes(table, op, Joint::IS);
        let r_ss = remote_outcomes(table, op, Joint::SS);
        if let (Some(a), Some(b)) = (&r_is, &r_ss) {
            if a != b {
                v.push(Violation {
                    requirement: 4,
                    detail: format!(
                        "{op:?} from IS yields remote states {a:?} but from SS yields {b:?} — home state leaks"
                    ),
                });
            }
        }
    }

    // R6: any op a node may request in a state must be available in every
    //     state indistinguishable *to that node* (silent moves of the
    //     partner must not invalidate a node's legal requests).
    for node in [Node::Home, Node::Remote] {
        for a in Joint::ALL {
            for b in Joint::ALL {
                if a != b && indistinguishable(node, a, b) {
                    let ops_a = signalled_ops_at(table, node, a);
                    let ops_b = signalled_ops_at(table, node, b);
                    for op in &ops_a {
                        if !ops_b.contains(op) {
                            v.push(Violation {
                                requirement: 6,
                                detail: format!(
                                    "{node:?} may signal {op:?} in {a} but not in indistinguishable {b}"
                                ),
                            });
                        }
                    }
                }
            }
        }
    }

    // R7: a node must accept in state j any message it must accept in any
    //     state indistinguishable to it. Receiving-side dual of R6: for
    //     each op initiated by the partner, the set of source states with
    //     that op must be closed under the *receiver's* indistinguishability.
    for node in [Node::Home, Node::Remote] {
        let receiver = node.other();
        for op in CohOp::ALL {
            let sources: Vec<Joint> = table
                .iter()
                .filter(|t| t.by == node && t.op == Some(op))
                .map(|t| t.from)
                .collect();
            if sources.is_empty() {
                continue;
            }
            for &s in &sources {
                for j in Joint::ALL {
                    if indistinguishable(receiver, s, j)
                        && j.is_valid()
                        && reachable_as_source_of(table, node, j)
                        && !sources.contains(&j)
                    {
                        v.push(Violation {
                            requirement: 7,
                            detail: format!(
                                "{receiver:?} must handle {op:?} in {j} (indistinguishable from {s}) but the table has no row"
                            ),
                        });
                    }
                }
            }
        }
    }

    v
}

/// R5 operates between *implementations*: an implementation must not signal
/// transitions its partner does not support. Given the table implemented by
/// `us` for ops we may *send*, and the table of the `partner` for ops it
/// can *receive*, report every op we could emit that the partner lacks.
pub fn check_interop(
    us: &[Transition],
    us_node: Node,
    partner: &[Transition],
) -> Vec<Violation> {
    let mut v = Vec::new();
    for tr in us {
        if tr.by != us_node {
            continue;
        }
        if let Some(op) = tr.op {
            let handled = partner
                .iter()
                .any(|p| p.by == us_node && p.op == Some(op) && p.from == tr.from);
            if !handled {
                v.push(Violation {
                    requirement: 5,
                    detail: format!(
                        "we may signal {op:?} from {} but the partner table cannot receive it there",
                        tr.from
                    ),
                });
            }
        }
    }
    v
}

/// The set of remote-state components reachable by `op` from `from`
/// (None if the op is not available there).
fn remote_outcomes(table: &[Transition], op: CohOp, from: Joint) -> Option<Vec<char>> {
    let mut out: Vec<char> = table
        .iter()
        .filter(|t| t.from == from && t.op == Some(op))
        .flat_map(|t| t.outcomes.iter().map(|o| o.remote.letter()))
        .collect();
    if out.is_empty() {
        return None;
    }
    out.sort();
    out.dedup();
    Some(out)
}

/// Does state `j` appear as the source of any transition by `node`, or as
/// an outcome anywhere? (Used to ignore vacuous R7 cases for states a
/// given table never inhabits.)
fn reachable_as_source_of(table: &[Transition], node: Node, j: Joint) -> bool {
    table.iter().any(|t| t.by == node && t.from == j)
        || table.iter().any(|t| t.outcomes.contains(&j))
        || j == Joint::II
}

/// The two performance *recommendations* of §3.3 (advisory, reported
/// separately from violations).
pub fn check_recommendations(table: &[Transition]) -> Vec<String> {
    let mut notes = Vec::new();
    // Rec 1: internal transitions should not be signalled — in particular
    // the upgrade to a dirty state (IE -> IM) should be silent.
    let ie_im_signalled = table.iter().any(|t| {
        t.from == Joint::IE && t.outcomes.contains(&Joint::IM) && t.op.is_some()
    });
    if ie_im_signalled {
        notes.push("rec 1: IE->IM (remote dirtying) is signalled; should be silent".into());
    }
    // Rec 2: the home should be able to share a dirty line without writing
    // it back first — i.e. transition 10 with an SS outcome should exist.
    let t10_keeps_dirty = table.iter().any(|t| {
        matches!(t.tag, Tag::Numbered(10)) && t.outcomes.contains(&Joint::SS)
    });
    if !t10_keeps_dirty {
        notes.push(
            "rec 2: no hidden-O path (MI -ReadShared-> SS); home will write dirty lines before sharing"
                .into(),
        );
    }
    notes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::transitions::reference_transitions;

    #[test]
    fn reference_table_satisfies_all_requirements() {
        let table = reference_transitions();
        let violations = check_envelope(&table);
        assert!(
            violations.is_empty(),
            "reference table violates the envelope:\n{}",
            violations
                .iter()
                .map(|v| v.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
    }

    #[test]
    fn reference_table_satisfies_recommendations() {
        assert!(check_recommendations(&reference_transitions()).is_empty());
    }

    #[test]
    fn reference_table_interoperates_with_itself() {
        let t = reference_transitions();
        assert!(check_interop(&t, Node::Remote, &t).is_empty());
        assert!(check_interop(&t, Node::Home, &t).is_empty());
    }

    #[test]
    fn silent_visible_transition_is_caught_r2() {
        let mut table = reference_transitions();
        // Make ReadShared from II silent: II -> IS changes the remote state,
        // which home... wait, by=Remote so partner=Home; home distinguishes
        // IS from II, so this must violate R2.
        for t in &mut table {
            if t.from == Joint::II && t.op == Some(CohOp::ReadShared) {
                t.op = None;
            }
        }
        let v = check_envelope(&table);
        assert!(v.iter().any(|x| x.requirement == 2), "expected R2 violation, got {v:?}");
    }

    #[test]
    fn silent_dirty_to_clean_is_caught_r3() {
        let mut table = reference_transitions();
        table.push(Transition {
            from: Joint::IM,
            op: None,
            by: Node::Remote,
            outcomes: vec![Joint::IE],
            tag: Tag::Local,
            note: "illegal silent clean",
        });
        let v = check_envelope(&table);
        assert!(v.iter().any(|x| x.requirement == 3), "expected R3 violation, got {v:?}");
    }

    #[test]
    fn unrelated_transition_is_caught_r1() {
        let mut table = reference_transitions();
        table.push(Transition {
            from: Joint::IE,
            op: Some(CohOp::VolDowngradeI),
            by: Node::Remote,
            outcomes: vec![Joint::MI], // IE and MI are unrelated
            tag: Tag::Local,
            note: "illegal jump",
        });
        let v = check_envelope(&table);
        assert!(v.iter().any(|x| x.requirement == 1), "expected R1 violation, got {v:?}");
    }

    #[test]
    fn asymmetric_ops_within_class_caught_r6() {
        let mut table = reference_transitions();
        // Remove ReadExclusive from EI only: remote can't tell EI from II,
        // so R6 must fire.
        table.retain(|t| !(t.from == Joint::EI && t.op == Some(CohOp::ReadExclusive)));
        let v = check_envelope(&table);
        assert!(v.iter().any(|x| x.requirement == 6), "expected R6 violation, got {v:?}");
    }

    #[test]
    fn missing_receive_row_caught_r5_interop() {
        let full = reference_transitions();
        let mut partner = reference_transitions();
        partner.retain(|t| t.op != Some(CohOp::UpgradeS2E));
        let v = check_interop(&full, Node::Remote, &partner);
        assert!(!v.is_empty());
        assert!(v.iter().all(|x| x.requirement == 5));
    }

    #[test]
    fn home_dirtiness_leak_caught_r4() {
        let mut table = reference_transitions();
        // Make UpgradeS2E from SS land in IS (remote stays S) instead of IE:
        // now IS and SS yield remotely-distinguishable outcomes for the op.
        for t in &mut table {
            if t.from == Joint::SS && t.op == Some(CohOp::UpgradeS2E) {
                t.outcomes = vec![Joint::IS];
            }
        }
        let v = check_envelope(&table);
        assert!(v.iter().any(|x| x.requirement == 4), "expected R4 violation, got {v:?}");
    }
}
