//! End-to-end observability: span tracing, time-series telemetry, and
//! machine-readable export.
//!
//! The paper's §4.1 toolkit (trace capture, dissector, online checker)
//! makes the *protocol* observable; this module does the same for the
//! *simulator's own runtime*. Three parts:
//!
//! - [`span`]: sampled per-transaction lifecycle tracking feeding
//!   per-stage histograms — the latency waterfall
//!   (`eci bench workload|fabric --spans`), local and cross-node
//!   (remote-fill) span classes each telescoping exactly to their
//!   end-to-end mean.
//! - [`ticker`] + [`registry`]: a simulated-time ticker snapshotting
//!   counter deltas and gauges into JSON-lines (`--obs-out run.jsonl`)
//!   via a unified metric registry with stable dotted names.
//! - [`flight`]: a bounded per-node ring of recent protocol/channel
//!   events, dumped as structured JSON on the fabric deadlock panic, on
//!   `declare_dead`, and on demand (`--flight-dump post.json`).
//! - [`chrome`]: Chrome trace-event (Perfetto-loadable) export of an
//!   observed run (`--trace-out run.trace.json`).
//! - [`json`]: the dependency-free serializer/parser behind every
//!   machine-readable artifact (JSONL, `--json` tables, selfperf
//!   baselines).
//!
//! The cardinal rule, enforced by `tests/obs_transparency.rs`: obs is
//! *passive*. It owns no RNG, schedules no events, and only reads
//! simulation state — runs with observability on and off produce
//! identical settled digests and identical observables.

pub mod chrome;
pub mod flight;
pub mod json;
pub mod registry;
pub mod span;
pub mod ticker;

pub use chrome::ChromeTrace;
pub use flight::{FlightEvent, FlightKind, FlightRecorder};
pub use json::Json;
pub use registry::Registry;
pub use span::{SpanRecord, SpanTracer, Stage, Waterfall, WaterfallRow, REMOTE_STAGE_NAMES, STAGE_NAMES};
pub use ticker::Ticker;

use crate::sim::time::{Duration, Time};

/// What to observe. Deliberately *not* part of the simulation configs
/// (which are `Copy` and digest-relevant); hosts carry an `Option<Obs>`
/// alongside their state instead.
#[derive(Clone, Debug, Default)]
pub struct ObsConfig {
    /// Enable sampled span tracing.
    pub spans: bool,
    /// Trace every N-th issued transaction (0/1 = all).
    pub span_sample_every: u32,
    /// Per-issue-stream sampling phases (one per node; empty = single
    /// stream, phase 0). Multi-node hosts pass pairwise-distinct phases
    /// so the cells don't sample lockstep-correlated arrivals.
    pub span_phases: Vec<u32>,
    /// Retain completed spans verbatim for trace export (`--trace-out`).
    pub record_spans: bool,
    /// Telemetry snapshot interval in simulated time (`None` = off).
    pub tick: Option<Duration>,
    /// Flight recorder: per-node ring capacity (`None` = off).
    pub flight: Option<usize>,
    /// Where flight dumps go. The deadlock panic path writes here
    /// *synchronously before unwinding*; a completed run writes all
    /// accumulated dumps here at the end.
    pub flight_path: Option<String>,
}

impl ObsConfig {
    /// Span tracing at the default 1-in-8 sampling rate.
    pub fn with_spans() -> ObsConfig {
        ObsConfig { spans: true, span_sample_every: 8, ..ObsConfig::default() }
    }

    /// Telemetry ticker at the given simulated-time interval.
    pub fn with_tick(every: Duration) -> ObsConfig {
        ObsConfig { tick: Some(every), ..ObsConfig::default() }
    }

    pub fn enabled(&self) -> bool {
        self.spans || self.tick.is_some() || self.flight.is_some()
    }
}

/// Live observability state a host carries while running.
pub struct Obs {
    pub registry: Registry,
    pub spans: Option<SpanTracer>,
    pub ticker: Option<Ticker>,
    pub flight: Option<FlightRecorder>,
    /// Destination for flight dumps (see [`ObsConfig::flight_path`]).
    pub flight_path: Option<String>,
}

impl Obs {
    pub fn new(cfg: &ObsConfig) -> Obs {
        let spans = cfg.spans.then(|| {
            let mut sp = if cfg.span_phases.is_empty() {
                SpanTracer::new(cfg.span_sample_every.max(1))
            } else {
                SpanTracer::with_phases(cfg.span_sample_every.max(1), &cfg.span_phases)
            };
            sp.record_spans(cfg.record_spans);
            sp
        });
        Obs {
            registry: Registry::new(),
            spans,
            ticker: cfg.tick.map(Ticker::new),
            flight: cfg.flight.map(FlightRecorder::new),
            flight_path: cfg.flight_path.clone(),
        }
    }

    /// Record a flight event (no-op when the recorder is off).
    #[inline]
    pub fn flight_record(&mut self, now: Time, node: u32, kind: FlightKind, a: u64, b: u64) {
        if let Some(fl) = &mut self.flight {
            fl.record(now, node, kind, a, b);
        }
    }

    /// Fast-path check: should the host refresh the registry and tick
    /// now? Keeps the per-event overhead to one comparison when no
    /// snapshot is due.
    #[inline]
    pub fn tick_due(&self, now: Time) -> bool {
        self.ticker.as_ref().is_some_and(|t| t.due(now))
    }

    /// Emit a telemetry record (the host refreshes the registry first).
    pub fn tick(&mut self, now: Time) {
        if let Some(t) = &mut self.ticker {
            t.tick(now, &mut self.registry);
        }
    }

    /// Seal in-flight spans and produce the final report. `now` is the
    /// run's final simulated time (stamped on the end-of-run flight
    /// dump).
    pub fn finish_at(mut self, now: Time) -> ObsReport {
        if let Some(sp) = &mut self.spans {
            sp.seal();
        }
        let (flight_dumps, flight_events) = match &mut self.flight {
            Some(fl) => {
                fl.dump("end_of_run", now);
                (fl.take_dumps(), fl.events_chrono())
            }
            None => (Vec::new(), Vec::new()),
        };
        ObsReport {
            waterfall: self.spans.as_ref().map(|s| s.waterfall()),
            span_records: self.spans.as_mut().map(|s| s.take_records()).unwrap_or_default(),
            jsonl: self.ticker.map(Ticker::into_lines).unwrap_or_default(),
            registry: self.registry,
            flight_dumps,
            flight_events,
            flight_path: self.flight_path,
        }
    }

    /// [`Obs::finish_at`] without a final timestamp (single-cell hosts
    /// that don't run a flight recorder).
    pub fn finish(self) -> ObsReport {
        self.finish_at(Time(0))
    }
}

/// Everything observability collected over one run.
pub struct ObsReport {
    /// Latency waterfall (present when span tracing was on).
    pub waterfall: Option<Waterfall>,
    /// Completed spans retained verbatim (when `record_spans` was on).
    pub span_records: Vec<SpanRecord>,
    /// Telemetry JSONL records (present when the ticker was on).
    pub jsonl: Vec<String>,
    /// Final registry snapshot.
    pub registry: Registry,
    /// Flight-recorder dumps accumulated over the run
    /// (`declare_dead` triggers plus the final `end_of_run` snapshot).
    pub flight_dumps: Vec<(String, String)>,
    /// Final flight-recorder contents, merged chronologically (feeds
    /// the trace export's instant events).
    pub flight_events: Vec<FlightEvent>,
    /// Configured flight dump destination, if any.
    pub flight_path: Option<String>,
}

impl ObsReport {
    /// Write the telemetry records to a JSONL file.
    pub fn write_jsonl(&self, path: &str) -> std::io::Result<()> {
        let mut out = String::new();
        for line in &self.jsonl {
            out.push_str(line);
            out.push('\n');
        }
        std::fs::write(path, out)
    }

    /// Write the accumulated flight dumps as one JSON array.
    pub fn write_flight(&self, path: &str) -> std::io::Result<()> {
        let mut out = String::from("[");
        for (i, (_, dump)) in self.flight_dumps.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(dump);
        }
        out.push_str("]\n");
        std::fs::write(path, out)
    }

    /// Render the run as Chrome trace-event JSON and write it.
    /// `node_shift` recovers the node from span keys (see
    /// [`chrome::build`]); single-cell hosts pass 0.
    pub fn write_trace(&self, path: &str, node_shift: u32) -> std::io::Result<()> {
        let tr = chrome::build(&self.span_records, &self.flight_events, node_shift);
        std::fs::write(path, tr.render())
    }

    /// Machine-readable summary: registry dump plus waterfall.
    pub fn to_json(&self) -> Json {
        let mut members = vec![("registry".to_string(), self.registry.to_json())];
        if let Some(w) = &self.waterfall {
            members.push(("waterfall".to_string(), w.to_json()));
        }
        members.push(("telemetry_records".to_string(), Json::u(self.jsonl.len() as u64)));
        members.push(("flight_dumps".to_string(), Json::u(self.flight_dumps.len() as u64)));
        Json::Obj(members)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_gates_components() {
        let off = Obs::new(&ObsConfig::default());
        assert!(off.spans.is_none() && off.ticker.is_none());
        assert!(!ObsConfig::default().enabled());

        let spans = Obs::new(&ObsConfig::with_spans());
        assert!(spans.spans.is_some() && spans.ticker.is_none());

        let tick = Obs::new(&ObsConfig::with_tick(Duration::from_ns(500)));
        assert!(tick.spans.is_none() && tick.ticker.is_some());
        assert!(ObsConfig::with_tick(Duration::from_ns(500)).enabled());
    }

    #[test]
    fn finish_seals_spans_and_reports() {
        let mut obs =
            Obs::new(&ObsConfig { spans: true, span_sample_every: 1, ..ObsConfig::default() });
        let sp = obs.spans.as_mut().unwrap();
        sp.on_issue(Time(0), 1);
        sp.mark(Time(1_000), 1, Stage::Launch);
        // never completed -> sealed as incomplete
        let report = obs.finish();
        let w = report.waterfall.unwrap();
        assert_eq!(w.sampled, 1);
        assert_eq!(w.completed, 0);
        assert_eq!(w.incomplete, 1);
        assert!(report.jsonl.is_empty());
    }

    #[test]
    fn flight_and_trace_surface_through_the_report() {
        let mut obs = Obs::new(&ObsConfig { flight: Some(4), ..ObsConfig::default() });
        assert!(ObsConfig { flight: Some(4), ..ObsConfig::default() }.enabled());
        obs.flight_record(Time(10), 0, FlightKind::Kill, 1, 0);
        if let Some(fl) = &mut obs.flight {
            fl.dump("declare_dead", Time(15));
        }
        let report = obs.finish_at(Time(20));
        assert_eq!(report.flight_dumps.len(), 2); // declare_dead + end_of_run
        assert_eq!(report.flight_dumps[0].0, "declare_dead");
        assert_eq!(report.flight_dumps[1].0, "end_of_run");
        assert_eq!(report.flight_events.len(), 1);
        assert_eq!(report.to_json().get("flight_dumps").and_then(|v| v.as_u64()), Some(2));
    }

    #[test]
    fn tick_due_fast_path() {
        let mut obs = Obs::new(&ObsConfig::with_tick(Duration::from_ns(100)));
        assert!(obs.tick_due(Time(0)));
        obs.registry.set("m.x", 1);
        obs.tick(Time(0));
        assert!(!obs.tick_due(Time(50_000)));
        assert!(obs.tick_due(Time(100_000)));
        let report = obs.finish();
        assert_eq!(report.jsonl.len(), 1);
        assert_eq!(report.to_json().get("telemetry_records").and_then(|v| v.as_u64()), Some(1));
    }
}
