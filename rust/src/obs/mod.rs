//! End-to-end observability: span tracing, time-series telemetry, and
//! machine-readable export.
//!
//! The paper's §4.1 toolkit (trace capture, dissector, online checker)
//! makes the *protocol* observable; this module does the same for the
//! *simulator's own runtime*. Three parts:
//!
//! - [`span`]: sampled per-transaction lifecycle tracking feeding
//!   per-stage histograms — the latency waterfall
//!   (`eci bench workload --spans`).
//! - [`ticker`] + [`registry`]: a simulated-time ticker snapshotting
//!   counter deltas and gauges into JSON-lines (`--obs-out run.jsonl`)
//!   via a unified metric registry with stable dotted names.
//! - [`json`]: the dependency-free serializer/parser behind every
//!   machine-readable artifact (JSONL, `--json` tables, selfperf
//!   baselines).
//!
//! The cardinal rule, enforced by `tests/obs_transparency.rs`: obs is
//! *passive*. It owns no RNG, schedules no events, and only reads
//! simulation state — runs with observability on and off produce
//! identical settled digests and identical observables.

pub mod json;
pub mod registry;
pub mod span;
pub mod ticker;

pub use json::Json;
pub use registry::Registry;
pub use span::{SpanTracer, Stage, Waterfall, WaterfallRow, STAGE_NAMES};
pub use ticker::Ticker;

use crate::sim::time::{Duration, Time};

/// What to observe. Deliberately *not* part of the simulation configs
/// (which are `Copy` and digest-relevant); hosts carry an `Option<Obs>`
/// alongside their state instead.
#[derive(Clone, Debug, Default)]
pub struct ObsConfig {
    /// Enable sampled span tracing.
    pub spans: bool,
    /// Trace every N-th issued transaction (0/1 = all).
    pub span_sample_every: u32,
    /// Telemetry snapshot interval in simulated time (`None` = off).
    pub tick: Option<Duration>,
}

impl ObsConfig {
    /// Span tracing at the default 1-in-8 sampling rate.
    pub fn with_spans() -> ObsConfig {
        ObsConfig { spans: true, span_sample_every: 8, ..ObsConfig::default() }
    }

    /// Telemetry ticker at the given simulated-time interval.
    pub fn with_tick(every: Duration) -> ObsConfig {
        ObsConfig { tick: Some(every), ..ObsConfig::default() }
    }

    pub fn enabled(&self) -> bool {
        self.spans || self.tick.is_some()
    }
}

/// Live observability state a host carries while running.
pub struct Obs {
    pub registry: Registry,
    pub spans: Option<SpanTracer>,
    pub ticker: Option<Ticker>,
}

impl Obs {
    pub fn new(cfg: &ObsConfig) -> Obs {
        Obs {
            registry: Registry::new(),
            spans: cfg.spans.then(|| SpanTracer::new(cfg.span_sample_every.max(1))),
            ticker: cfg.tick.map(Ticker::new),
        }
    }

    /// Fast-path check: should the host refresh the registry and tick
    /// now? Keeps the per-event overhead to one comparison when no
    /// snapshot is due.
    #[inline]
    pub fn tick_due(&self, now: Time) -> bool {
        self.ticker.as_ref().is_some_and(|t| t.due(now))
    }

    /// Emit a telemetry record (the host refreshes the registry first).
    pub fn tick(&mut self, now: Time) {
        if let Some(t) = &mut self.ticker {
            t.tick(now, &mut self.registry);
        }
    }

    /// Seal in-flight spans and produce the final report.
    pub fn finish(mut self) -> ObsReport {
        if let Some(sp) = &mut self.spans {
            sp.seal();
        }
        ObsReport {
            waterfall: self.spans.as_ref().map(|s| s.waterfall()),
            jsonl: self.ticker.map(Ticker::into_lines).unwrap_or_default(),
            registry: self.registry,
        }
    }
}

/// Everything observability collected over one run.
pub struct ObsReport {
    /// Latency waterfall (present when span tracing was on).
    pub waterfall: Option<Waterfall>,
    /// Telemetry JSONL records (present when the ticker was on).
    pub jsonl: Vec<String>,
    /// Final registry snapshot.
    pub registry: Registry,
}

impl ObsReport {
    /// Write the telemetry records to a JSONL file.
    pub fn write_jsonl(&self, path: &str) -> std::io::Result<()> {
        let mut out = String::new();
        for line in &self.jsonl {
            out.push_str(line);
            out.push('\n');
        }
        std::fs::write(path, out)
    }

    /// Machine-readable summary: registry dump plus waterfall.
    pub fn to_json(&self) -> Json {
        let mut members = vec![("registry".to_string(), self.registry.to_json())];
        if let Some(w) = &self.waterfall {
            members.push(("waterfall".to_string(), w.to_json()));
        }
        members.push(("telemetry_records".to_string(), Json::u(self.jsonl.len() as u64)));
        Json::Obj(members)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_gates_components() {
        let off = Obs::new(&ObsConfig::default());
        assert!(off.spans.is_none() && off.ticker.is_none());
        assert!(!ObsConfig::default().enabled());

        let spans = Obs::new(&ObsConfig::with_spans());
        assert!(spans.spans.is_some() && spans.ticker.is_none());

        let tick = Obs::new(&ObsConfig::with_tick(Duration::from_ns(500)));
        assert!(tick.spans.is_none() && tick.ticker.is_some());
        assert!(ObsConfig::with_tick(Duration::from_ns(500)).enabled());
    }

    #[test]
    fn finish_seals_spans_and_reports() {
        let mut obs = Obs::new(&ObsConfig { spans: true, span_sample_every: 1, tick: None });
        let sp = obs.spans.as_mut().unwrap();
        sp.on_issue(Time(0), 1);
        sp.mark(Time(1_000), 1, Stage::Launch);
        // never completed -> sealed as incomplete
        let report = obs.finish();
        let w = report.waterfall.unwrap();
        assert_eq!(w.sampled, 1);
        assert_eq!(w.completed, 0);
        assert_eq!(w.incomplete, 1);
        assert!(report.jsonl.is_empty());
    }

    #[test]
    fn tick_due_fast_path() {
        let mut obs = Obs::new(&ObsConfig::with_tick(Duration::from_ns(100)));
        assert!(obs.tick_due(Time(0)));
        obs.registry.set("m.x", 1);
        obs.tick(Time(0));
        assert!(!obs.tick_due(Time(50_000)));
        assert!(obs.tick_due(Time(100_000)));
        let report = obs.finish();
        assert_eq!(report.jsonl.len(), 1);
        assert_eq!(report.to_json().get("telemetry_records").and_then(|v| v.as_u64()), Some(1));
    }
}
