//! Post-mortem flight recorder: bounded per-node rings of recent
//! protocol/channel events.
//!
//! The fabric's deadlock panic used to destroy the evidence needed to
//! debug it — by the time the event queue is empty short of the
//! completion target, the interesting history (the last channel
//! launches, parks, replays, death declarations) is gone. The flight
//! recorder keeps the last `cap` events per node in a fixed ring:
//! pre-allocated, overwritten in place once full, so the steady state
//! allocates nothing and recording is a couple of stores.
//!
//! Dumps are structured JSON snapshots taken at three triggers:
//! the fabric **deadlock panic** (written synchronously to the
//! `--flight-dump` path *before* the panic unwinds, so the post-mortem
//! survives the process), **`declare_dead`** (the state of the world at
//! the moment a node's death was declared), and **on demand** at end of
//! run when `--flight-dump <path>` is given. Like the rest of `obs`,
//! the recorder is passive — it owns no RNG and schedules nothing, so
//! the transparency gate covers it.

use crate::sim::time::Time;

use super::json::Json;

/// What happened. `a`/`b` are kind-specific operands (ids, node or
/// channel indices, counts) kept as raw integers so an event is `Copy`
/// and fixed-size.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlightKind {
    /// Frame launched on an inter-node channel (a = channel, b = msg id).
    ChanLaunch,
    /// Frame landed off an inter-node channel (a = channel, b = msg id).
    ChanLand,
    /// Forced retransmission on a channel (a = channel, b = barren streak).
    ChanRetx,
    /// Request translated and forwarded toward a remote home
    /// (a = original id, b = home node).
    FwdOut,
    /// Remote request admitted into the home dcs (a = id, b = source node).
    Admit,
    /// Request parked by an in-flight migration (a = id, b = line).
    Park,
    /// Parked/pending request re-injected toward a (new) home
    /// (a = id, b = home node).
    Replay,
    /// Home migration began (a = line, b = target node).
    MigBegin,
    /// Home migration committed (a = line, b = new home).
    MigCommit,
    /// Home migration aborted (a = line, b = old home).
    MigAbort,
    /// Scripted fail-stop fired (a = killed node).
    Kill,
    /// A channel's barren-retx detector suspects its peer
    /// (a = suspected node, b = barren streak).
    Suspect,
    /// Death declared; recovery ran (a = dead node, b = replayed count).
    DeclareDead,
    /// Lines re-homed off a dead node (a = dead node, b = line count).
    Rehome,
    /// Grant epoch reclaimed from a dead node (a = line, b = dead node).
    EpochReclaim,
    /// Live reconfiguration began quiescing (a = transition ordinal,
    /// b = arrivals parked so far).
    ReconfigQuiesce,
    /// Quiesced; the shape handoff executed (a = transition ordinal,
    /// b = lines moved).
    ReconfigHandoff,
    /// Parked traffic released; the data plane resumed
    /// (a = transition ordinal, b = arrivals released).
    ReconfigResume,
    /// A scripted reconfig event fired after the run's completion
    /// target and was skipped (a = transition ordinal).
    ReconfigSkipped,
}

impl FlightKind {
    pub fn name(self) -> &'static str {
        match self {
            FlightKind::ChanLaunch => "chan_launch",
            FlightKind::ChanLand => "chan_land",
            FlightKind::ChanRetx => "chan_retx",
            FlightKind::FwdOut => "fwd_out",
            FlightKind::Admit => "admit",
            FlightKind::Park => "park",
            FlightKind::Replay => "replay",
            FlightKind::MigBegin => "mig_begin",
            FlightKind::MigCommit => "mig_commit",
            FlightKind::MigAbort => "mig_abort",
            FlightKind::Kill => "kill",
            FlightKind::Suspect => "suspect",
            FlightKind::DeclareDead => "declare_dead",
            FlightKind::Rehome => "rehome",
            FlightKind::EpochReclaim => "epoch_reclaim",
            FlightKind::ReconfigQuiesce => "reconfig_quiesce",
            FlightKind::ReconfigHandoff => "reconfig_handoff",
            FlightKind::ReconfigResume => "reconfig_resume",
            FlightKind::ReconfigSkipped => "reconfig_skipped",
        }
    }
}

/// One recorded event: fixed-size, `Copy`, no heap.
#[derive(Clone, Copy, Debug)]
pub struct FlightEvent {
    pub t_ps: u64,
    pub node: u32,
    pub kind: FlightKind,
    pub a: u64,
    pub b: u64,
}

struct Ring {
    buf: Vec<FlightEvent>,
    head: usize, // next overwrite position once the ring is full
    total: u64,  // events ever recorded on this node
}

impl Ring {
    fn new(cap: usize) -> Ring {
        Ring { buf: Vec::with_capacity(cap), head: 0, total: 0 }
    }

    fn push(&mut self, cap: usize, ev: FlightEvent) {
        self.total += 1;
        if self.buf.len() < cap {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % cap;
        }
    }

    /// Events oldest-first.
    fn chrono(&self) -> impl Iterator<Item = &FlightEvent> {
        self.buf[self.head..].iter().chain(self.buf[..self.head].iter())
    }
}

/// Default per-node ring capacity (events).
pub const DEFAULT_FLIGHT_CAP: usize = 256;

/// Per-node bounded rings of recent events plus accumulated dumps.
pub struct FlightRecorder {
    cap: usize,
    rings: Vec<Ring>,
    dumps: Vec<(String, String)>, // (trigger, compact JSON)
}

impl FlightRecorder {
    /// `cap` = events retained per node (0 coerces to 1).
    pub fn new(cap: usize) -> FlightRecorder {
        FlightRecorder { cap: cap.max(1), rings: Vec::new(), dumps: Vec::new() }
    }

    /// Record one event on `node`'s ring. Rings materialize on a node's
    /// first event (one allocation per node, ever); after that the ring
    /// overwrites in place.
    pub fn record(&mut self, now: Time, node: u32, kind: FlightKind, a: u64, b: u64) {
        let n = node as usize;
        while self.rings.len() <= n {
            self.rings.push(Ring::new(self.cap));
        }
        self.rings[n].push(self.cap, FlightEvent { t_ps: now.ps(), node, kind, a, b });
    }

    /// Events ever recorded (all nodes).
    pub fn total(&self) -> u64 {
        self.rings.iter().map(|r| r.total).sum()
    }

    /// All retained events, merged across nodes, oldest-first.
    pub fn events_chrono(&self) -> Vec<FlightEvent> {
        let mut out: Vec<FlightEvent> = Vec::with_capacity(self.rings.iter().map(|r| r.buf.len()).sum());
        for r in &self.rings {
            out.extend(r.chrono().copied());
        }
        out.sort_by_key(|e| (e.t_ps, e.node));
        out
    }

    /// Structured snapshot of every ring: per node the retained events
    /// oldest-first, how many were ever recorded, and how many the ring
    /// dropped.
    pub fn snapshot(&self, trigger: &str, now: Time) -> Json {
        let nodes = self
            .rings
            .iter()
            .enumerate()
            .map(|(n, r)| {
                let events = r
                    .chrono()
                    .map(|e| {
                        Json::Obj(vec![
                            ("t_ps".into(), Json::u(e.t_ps)),
                            ("kind".into(), Json::s(e.kind.name())),
                            ("a".into(), Json::u(e.a)),
                            ("b".into(), Json::u(e.b)),
                        ])
                    })
                    .collect();
                Json::Obj(vec![
                    ("node".into(), Json::u(n as u64)),
                    ("recorded".into(), Json::u(r.total)),
                    ("dropped".into(), Json::u(r.total - r.buf.len() as u64)),
                    ("events".into(), Json::Arr(events)),
                ])
            })
            .collect();
        Json::Obj(vec![
            ("trigger".into(), Json::s(trigger)),
            ("t_ps".into(), Json::u(now.ps())),
            ("cap_per_node".into(), Json::u(self.cap as u64)),
            ("nodes".into(), Json::Arr(nodes)),
        ])
    }

    /// Snapshot as compact JSON text — the panic path uses this to
    /// write the dump synchronously before unwinding.
    pub fn dump_string(&self, trigger: &str, now: Time) -> String {
        self.snapshot(trigger, now).compact()
    }

    /// Take a snapshot and keep it with the recorder (surfaced through
    /// the obs report at end of run).
    pub fn dump(&mut self, trigger: &str, now: Time) {
        let s = self.dump_string(trigger, now);
        self.dumps.push((trigger.to_string(), s));
    }

    /// Accumulated dumps, in trigger order.
    pub fn dumps(&self) -> &[(String, String)] {
        &self.dumps
    }

    pub fn take_dumps(&mut self) -> Vec<(String, String)> {
        std::mem::take(&mut self.dumps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_overwrites_oldest_once_full() {
        let mut fl = FlightRecorder::new(4);
        for i in 0..10u64 {
            fl.record(Time(i * 100), 0, FlightKind::ChanLaunch, i, 0);
        }
        assert_eq!(fl.total(), 10);
        let evs = fl.events_chrono();
        assert_eq!(evs.len(), 4);
        // the last four, oldest-first
        assert_eq!(evs.iter().map(|e| e.a).collect::<Vec<_>>(), vec![6, 7, 8, 9]);
        assert!(evs.windows(2).all(|w| w[0].t_ps <= w[1].t_ps));
    }

    #[test]
    fn per_node_rings_are_independent() {
        let mut fl = FlightRecorder::new(2);
        fl.record(Time(1), 0, FlightKind::Park, 10, 0);
        fl.record(Time(2), 2, FlightKind::Kill, 2, 0);
        fl.record(Time(3), 0, FlightKind::Replay, 10, 1);
        fl.record(Time(4), 0, FlightKind::Admit, 11, 0); // evicts Park on node 0
        let evs = fl.events_chrono();
        assert_eq!(evs.len(), 3);
        assert!(evs.iter().all(|e| e.kind != FlightKind::Park));
        assert!(evs.iter().any(|e| e.kind == FlightKind::Kill && e.node == 2));
    }

    #[test]
    fn snapshot_json_is_well_formed_and_counts_drops() {
        let mut fl = FlightRecorder::new(2);
        for i in 0..5u64 {
            fl.record(Time(i), 1, FlightKind::ChanLand, i, i + 1);
        }
        fl.dump("declare_dead", Time(99));
        assert_eq!(fl.dumps().len(), 1);
        let (trigger, text) = &fl.dumps()[0];
        assert_eq!(trigger, "declare_dead");
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("trigger").and_then(|v| v.as_str()), Some("declare_dead"));
        let nodes = j.get("nodes").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(nodes.len(), 2); // node 0 ring exists (empty), node 1 full
        assert_eq!(nodes[1].get("recorded").and_then(|v| v.as_u64()), Some(5));
        assert_eq!(nodes[1].get("dropped").and_then(|v| v.as_u64()), Some(3));
        assert_eq!(nodes[1].get("events").and_then(|v| v.as_arr()).map(|a| a.len()), Some(2));
    }
}
