//! Chrome trace-event (Perfetto-loadable) export of an observed run.
//!
//! Renders retained spans ([`super::span::SpanRecord`]) and flight
//! recorder events ([`super::flight::FlightEvent`]) as the Trace Event
//! Format JSON that `chrome://tracing` and <https://ui.perfetto.dev>
//! open directly: `{"traceEvents": [...], "displayTimeUnit": "ns"}`.
//!
//! Track layout: one *process* per simulated node (`pid` = node,
//! named `node<N>`), and per node:
//!
//! * `tid 1` (`events`) — instant events for protocol milestones: kill,
//!   suspect, declare_dead, rehome, epoch reclaim, migration
//!   begin/commit/abort, park, replay;
//! * `tid 2` (`channels`) — instant events for inter-node channel
//!   activity (launch/land/retx, forwards, admits), `args.a` carrying
//!   the channel or id operand;
//! * `tid 10+k` (`spans.k`) — the span waterfall: one duration (`"X"`)
//!   slice per telescoping stage interval of each retained span. Spans
//!   overlap in time, so each is greedily packed onto the first lane
//!   whose previous span already ended — lanes are non-overlapping and
//!   the lane count is the node's concurrency high-water mark.
//!
//! Chrome timestamps are microseconds; simulated picoseconds divide by
//! `1e6` into fractional µs, preserving ps resolution (the format takes
//! doubles).

use super::flight::{FlightEvent, FlightKind};
use super::json::Json;
use super::span::SpanRecord;

/// Incremental trace-event builder.
pub struct ChromeTrace {
    events: Vec<Json>,
}

const TID_EVENTS: u64 = 1;
const TID_CHANNELS: u64 = 2;
const TID_SPAN_BASE: u64 = 10;

fn us(ps: u64) -> Json {
    Json::f(ps as f64 / 1e6)
}

impl ChromeTrace {
    pub fn new() -> ChromeTrace {
        ChromeTrace { events: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Name the process (node) `pid`.
    pub fn process_name(&mut self, pid: u64, name: &str) {
        self.metadata("process_name", pid, None, name);
    }

    /// Name thread `tid` of process `pid`.
    pub fn thread_name(&mut self, pid: u64, tid: u64, name: &str) {
        self.metadata("thread_name", pid, Some(tid), name);
    }

    fn metadata(&mut self, what: &str, pid: u64, tid: Option<u64>, name: &str) {
        let mut m = vec![
            ("name".to_string(), Json::s(what)),
            ("ph".to_string(), Json::s("M")),
            ("pid".to_string(), Json::u(pid)),
        ];
        if let Some(t) = tid {
            m.push(("tid".to_string(), Json::u(t)));
        }
        m.push(("args".to_string(), Json::Obj(vec![("name".to_string(), Json::s(name))])));
        self.events.push(Json::Obj(m));
    }

    /// A complete duration slice (`ph: "X"`), timestamps in ps.
    pub fn slice(
        &mut self,
        name: &str,
        pid: u64,
        tid: u64,
        start_ps: u64,
        end_ps: u64,
        args: Vec<(String, Json)>,
    ) {
        let mut m = vec![
            ("name".to_string(), Json::s(name)),
            ("ph".to_string(), Json::s("X")),
            ("pid".to_string(), Json::u(pid)),
            ("tid".to_string(), Json::u(tid)),
            ("ts".to_string(), us(start_ps)),
            ("dur".to_string(), us(end_ps.saturating_sub(start_ps))),
        ];
        if !args.is_empty() {
            m.push(("args".to_string(), Json::Obj(args)));
        }
        self.events.push(Json::Obj(m));
    }

    /// A thread-scoped instant event (`ph: "i"`), timestamp in ps.
    pub fn instant(
        &mut self,
        name: &str,
        pid: u64,
        tid: u64,
        at_ps: u64,
        args: Vec<(String, Json)>,
    ) {
        let mut m = vec![
            ("name".to_string(), Json::s(name)),
            ("ph".to_string(), Json::s("i")),
            ("s".to_string(), Json::s("t")),
            ("pid".to_string(), Json::u(pid)),
            ("tid".to_string(), Json::u(tid)),
            ("ts".to_string(), us(at_ps)),
        ];
        if !args.is_empty() {
            m.push(("args".to_string(), Json::Obj(args)));
        }
        self.events.push(Json::Obj(m));
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("traceEvents".to_string(), Json::Arr(self.events.clone())),
            ("displayTimeUnit".to_string(), Json::s("ns")),
        ])
    }

    /// The complete trace as compact JSON text (the `--trace-out` file).
    pub fn render(&self) -> String {
        self.to_json().compact()
    }
}

impl Default for ChromeTrace {
    fn default() -> Self {
        ChromeTrace::new()
    }
}

/// Is this flight event a channel-activity event (→ `channels` track)
/// rather than a protocol milestone (→ `events` track)?
fn is_channel_kind(k: FlightKind) -> bool {
    matches!(
        k,
        FlightKind::ChanLaunch
            | FlightKind::ChanLand
            | FlightKind::ChanRetx
            | FlightKind::FwdOut
            | FlightKind::Admit
    )
}

/// Build a trace from an observed run's retained spans and flight
/// events. `node_shift` recovers the issuing node from a span key
/// (`fabric::span_key` packs it in the high bits — pass
/// `fabric::SPAN_NODE_SHIFT`); pass 0 for single-cell hosts, mapping
/// every span to node 0.
pub fn build(records: &[SpanRecord], flight: &[FlightEvent], node_shift: u32) -> ChromeTrace {
    let mut tr = ChromeTrace::new();
    let node_of = |id: u32| -> u64 {
        if node_shift == 0 || node_shift >= 32 {
            0
        } else {
            (id >> node_shift) as u64
        }
    };

    // -- discover the node set so every process gets named ------------
    let mut max_node: u64 = 0;
    for r in records {
        max_node = max_node.max(node_of(r.id));
    }
    for e in flight {
        max_node = max_node.max(e.node as u64);
    }
    if records.is_empty() && flight.is_empty() {
        return tr; // an empty but valid trace
    }
    for n in 0..=max_node {
        tr.process_name(n, &format!("node{}", n));
        tr.thread_name(n, TID_EVENTS, "events");
        tr.thread_name(n, TID_CHANNELS, "channels");
    }

    // -- span waterfall: greedy lane packing per node -----------------
    // lanes[node] = per-lane end-of-last-span (ps)
    let mut lanes: Vec<Vec<u64>> = vec![Vec::new(); max_node as usize + 1];
    let mut sorted: Vec<&SpanRecord> = records.iter().collect();
    sorted.sort_by_key(|r| r.t[0]);
    for r in sorted {
        let node = node_of(r.id);
        let iv = r.intervals();
        let (start, end) = (iv.first().map_or(0, |i| i.1), iv.last().map_or(0, |i| i.2));
        let ls = &mut lanes[node as usize];
        let lane = match ls.iter().position(|&e| e <= start) {
            Some(k) => k,
            None => {
                ls.push(0);
                tr.thread_name(node, TID_SPAN_BASE + (ls.len() - 1) as u64, &format!(
                    "spans.{}",
                    ls.len() - 1
                ));
                ls.len() - 1
            }
        };
        ls[lane] = end.max(start);
        let tid = TID_SPAN_BASE + lane as u64;
        for (k, (name, a, b)) in iv.iter().enumerate() {
            let mut args = vec![("id".to_string(), Json::u(r.id as u64))];
            if k == 0 {
                args.push(("remote".to_string(), Json::u(r.remote as u64)));
                args.push(("launches".to_string(), Json::u(r.launches as u64)));
                if r.parks > 0 {
                    args.push(("parks".to_string(), Json::u(r.parks as u64)));
                }
                if r.replays > 0 {
                    args.push(("replays".to_string(), Json::u(r.replays as u64)));
                }
            }
            tr.slice(name, node, tid, *a, *b, args);
        }
    }

    // -- flight events as instants ------------------------------------
    for e in flight {
        let tid = if is_channel_kind(e.kind) { TID_CHANNELS } else { TID_EVENTS };
        let args = vec![
            ("a".to_string(), Json::u(e.a)),
            ("b".to_string(), Json::u(e.b)),
        ];
        tr.instant(e.kind.name(), e.node as u64, tid, e.t_ps, args);
    }
    tr
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::time::Time;

    fn rec(id: u32, base: u64, remote: bool) -> SpanRecord {
        use crate::obs::span::{SpanTracer, Stage};
        let mut sp = SpanTracer::new(1);
        sp.record_spans(true);
        sp.on_issue(Time(base), id);
        sp.mark(Time(base + 10), id, Stage::Launch);
        if remote {
            sp.mark(Time(base + 20), id, Stage::FwdOut);
        }
        sp.mark(Time(base + 30), id, Stage::Deliver);
        sp.mark(Time(base + 35), id, Stage::SvcStart);
        sp.mark(Time(base + 40), id, Stage::SvcDone);
        sp.mark(Time(base + 45), id, Stage::Reply);
        if remote {
            sp.mark(Time(base + 50), id, Stage::RspLaunch);
        }
        sp.complete(Time(base + 60), id);
        sp.take_records().pop().expect("span completed")
    }

    #[test]
    fn trace_renders_valid_json_with_expected_phases() {
        let records = [rec(1, 100, false), rec(2, 120, true)];
        let mut fl = crate::obs::flight::FlightRecorder::new(8);
        fl.record(Time(50), 0, FlightKind::Kill, 1, 0);
        fl.record(Time(60), 1, FlightKind::ChanLaunch, 0, 2);
        let tr = build(&records, &fl.events_chrono(), 0);
        let text = tr.render();
        let j = Json::parse(&text).unwrap();
        let evs = j.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        assert!(!evs.is_empty());
        // every event has a phase and a pid
        for e in evs {
            assert!(e.get("ph").and_then(|v| v.as_str()).is_some());
            assert!(e.get("pid").and_then(|v| v.as_u64()).is_some());
        }
        // 6 local + 8 remote duration slices
        let slices = evs.iter().filter(|e| e.get("ph").and_then(|v| v.as_str()) == Some("X"));
        assert_eq!(slices.count(), 14);
        // both flight instants present
        let instants: Vec<_> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(|v| v.as_str()) == Some("i"))
            .collect();
        assert_eq!(instants.len(), 2);
        assert!(instants.iter().any(|e| e.get("name").and_then(|v| v.as_str()) == Some("kill")));
    }

    #[test]
    fn node_shift_routes_spans_to_their_node_track() {
        let shift = 26;
        let mut r = rec(5, 0, false);
        r.id |= 3 << shift; // node 3's span key
        let tr = build(&[r], &[], shift);
        let j = Json::parse(&tr.render()).unwrap();
        let evs = j.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        let pid_of_slices: Vec<u64> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(|v| v.as_str()) == Some("X"))
            .filter_map(|e| e.get("pid").and_then(|v| v.as_u64()))
            .collect();
        assert!(!pid_of_slices.is_empty());
        assert!(pid_of_slices.iter().all(|&p| p == 3));
        // processes node0..node3 all got named
        let names = evs
            .iter()
            .filter(|e| e.get("name").and_then(|v| v.as_str()) == Some("process_name"))
            .count();
        assert_eq!(names, 4);
    }

    #[test]
    fn overlapping_spans_pack_onto_distinct_lanes() {
        // two spans overlapping in time must land on different tids
        let a = rec(1, 0, false);
        let b = rec(2, 30, false); // starts before a (0..60) ends
        let tr = build(&[a, b], &[], 0);
        let j = Json::parse(&tr.render()).unwrap();
        let evs = j.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        let mut tids: Vec<(u64, u64)> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(|v| v.as_str()) == Some("X"))
            .map(|e| {
                (
                    e.get("args").and_then(|a| a.get("id")).and_then(|v| v.as_u64()).unwrap(),
                    e.get("tid").and_then(|v| v.as_u64()).unwrap(),
                )
            })
            .collect();
        tids.dedup();
        let tid_of = |id: u64| {
            tids.iter().find(|(i, _)| *i == id).map(|(_, t)| *t).unwrap()
        };
        assert_ne!(tid_of(1), tid_of(2));
    }

    #[test]
    fn empty_observation_renders_an_empty_valid_trace() {
        let tr = build(&[], &[], 0);
        let j = Json::parse(&tr.render()).unwrap();
        assert_eq!(j.get("traceEvents").and_then(|v| v.as_arr()).map(|a| a.len()), Some(0));
    }
}
