//! Minimal JSON tree, writer, and parser.
//!
//! The crate is dependency-free (no serde), so every machine-readable
//! export — JSONL telemetry lines, `--json` bench tables, waterfall
//! figures, selfperf baselines — and the baseline *reader* behind the CI
//! regression gate share this hand-rolled implementation, the same way
//! [`crate::trace::json`] hand-rolls the trace export. Output is
//! deterministic: object keys keep insertion order and numbers that are
//! mathematically integers print without a fractional part.

use std::fmt;

/// A JSON value. Objects preserve insertion order (we never need map
/// lookup at scale, and stable output matters more for diffable
/// baselines and golden files).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Unsigned counter value. Exact for everything the simulator
    /// produces (counters stay far below 2^53).
    pub fn u(v: u64) -> Json {
        Json::Num(v as f64)
    }
    pub fn f(v: f64) -> Json {
        Json::Num(v)
    }
    pub fn s(v: &str) -> Json {
        Json::Str(v.to_string())
    }

    /// Member lookup on an object (first match; `None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 => Some(*v as u64),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Compact single-line rendering (JSONL-friendly).
    pub fn compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Indented rendering for committed artifacts (baselines, figures)
    /// so diffs stay reviewable.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => write_num(*v, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                write_seq(out, indent, depth, '[', ']', items.len(), |out, i, d| {
                    items[i].write(out, indent, d);
                });
            }
            Json::Obj(members) => {
                write_seq(out, indent, depth, '{', '}', members.len(), |out, i, d| {
                    let (k, v) = &members[i];
                    write_escaped(k, out);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, d);
                });
            }
        }
    }

    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing input at byte {}", p.i));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.compact())
    }
}

fn write_num(v: f64, out: &mut String) {
    use fmt::Write;
    if !v.is_finite() {
        out.push_str("null"); // JSON has no NaN/Inf; never emitted on purpose
    } else if v.fract() == 0.0 && v.abs() < 9e15 {
        let _ = write!(out, "{}", v as i64);
    } else {
        let _ = write!(out, "{}", v); // shortest round-trip
    }
}

fn write_escaped(s: &str, out: &mut String) {
    use fmt::Write;
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_seq(
    out: &mut String,
    indent: Option<usize>,
    depth: usize,
    open: char,
    close: char,
    len: usize,
    mut item: impl FnMut(&mut String, usize, usize),
) {
    out.push(open);
    if len == 0 {
        out.push(close);
        return;
    }
    for i in 0..len {
        if i > 0 {
            out.push(',');
        }
        if let Some(w) = indent {
            out.push('\n');
            for _ in 0..w * (depth + 1) {
                out.push(' ');
            }
        }
        item(out, i, depth + 1);
    }
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
    out.push(close);
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected input at byte {}", self.i)),
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{}' at byte {}", text, start))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|e| e.to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("bad \\u escape '{}'", hex))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance one full UTF-8 scalar, not one byte
                    let rest = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut members = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(members));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            members.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(members));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_compact_and_pretty() {
        let v = Json::Obj(vec![
            ("name".into(), Json::s("eci")),
            ("n".into(), Json::u(42)),
            ("rate".into(), Json::f(1.5)),
            ("ok".into(), Json::Bool(true)),
            ("tags".into(), Json::Arr(vec![Json::u(1), Json::u(2)])),
        ]);
        assert_eq!(
            v.compact(),
            r#"{"name":"eci","n":42,"rate":1.5,"ok":true,"tags":[1,2]}"#
        );
        assert!(v.pretty().contains("\n  \"name\": \"eci\""));
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::u(0).compact(), "0");
        assert_eq!(Json::u(1_000_000_000_000).compact(), "1000000000000");
        assert_eq!(Json::f(0.25).compact(), "0.25");
        assert_eq!(Json::f(f64::NAN).compact(), "null");
    }

    #[test]
    fn escapes_strings() {
        let v = Json::s("a\"b\\c\nd\u{1}");
        let text = v.compact();
        assert_eq!(text, r#""a\"b\\c\nd\u0001""#);
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn round_trips_nested_values() {
        let v = Json::Obj(vec![
            ("a".into(), Json::Arr(vec![Json::Null, Json::Bool(false), Json::f(-2.5)])),
            ("b".into(), Json::Obj(vec![("x".into(), Json::u(7))])),
            ("empty_arr".into(), Json::Arr(vec![])),
            ("empty_obj".into(), Json::Obj(vec![])),
        ]);
        let parsed = Json::parse(&v.pretty()).unwrap();
        assert_eq!(parsed, v);
        let parsed = Json::parse(&v.compact()).unwrap();
        assert_eq!(parsed, v);
    }

    #[test]
    fn parses_numbers() {
        assert_eq!(Json::parse("-3").unwrap().as_f64(), Some(-3.0));
        assert_eq!(Json::parse("2.5e3").unwrap().as_f64(), Some(2500.0));
        assert_eq!(Json::parse("18").unwrap().as_u64(), Some(18));
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
        assert_eq!(Json::parse("0.5").unwrap().as_u64(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
        assert!(Json::parse("{\"a\"").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn member_lookup() {
        let v = Json::parse(r#"{"a":{"b":[1,"two"]}}"#).unwrap();
        let arr = v.get("a").and_then(|a| a.get("b")).and_then(|b| b.as_arr()).unwrap();
        assert_eq!(arr[0].as_u64(), Some(1));
        assert_eq!(arr[1].as_str(), Some("two"));
        assert!(v.get("missing").is_none());
    }
}
