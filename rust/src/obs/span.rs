//! Sampled per-transaction span tracing.
//!
//! A *span* follows one response-needing coherence request from the
//! moment the client issues it until its response lands back, keyed by
//! the transaction id ([`crate::proto::messages::ReqId`]) which the
//! stack carries intact from request to response. Each span records a
//! timestamp at every lifecycle stage; on completion the deltas between
//! consecutive stages feed per-stage [`Histogram`]s, so an end-to-end
//! p99 decomposes into queueing vs wire/replay vs service vs memory
//! time — the latency waterfall.
//!
//! Stages telescope: `issue → launch → deliver → svc_start → svc_done →
//! reply → complete`, so the per-span stage intervals sum *exactly* to
//! the span's end-to-end latency, and stage means sum to the e2e mean
//! (quantiles agree within histogram binning error only, since
//! quantiles don't add).
//!
//! Sampling is deterministic — every `sample_every`-th issued
//! transaction, no RNG — and the tracer is passive: it never schedules
//! events or perturbs simulation state, which the obs transparency gate
//! checks.

use crate::rustc_hash::FxHashMap as HashMap;
use crate::sim::stats::Histogram;
use crate::sim::time::Time;

use super::json::Json;

/// Lifecycle checkpoints of a traced transaction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Client handed the request to the home-bound framed ingress.
    Issue = 0,
    /// Request frame left the ingress mux onto the wire (first launch;
    /// later launches of the same id are retransmit episodes).
    Launch = 1,
    /// Request frame delivered at the home side and enqueued on its
    /// directory slice FIFO.
    Deliver = 2,
    /// Home agent began servicing the request (slice grant).
    SvcStart = 3,
    /// Directory/home produced the response message.
    SvcDone = 4,
    /// Response ready to send after the memory/KVS backend.
    Reply = 5,
    /// Response landed back at the client.
    Complete = 6,
}

const NUM_STAGES: usize = 7;
const UNSET: u64 = u64::MAX;

/// Names of the six telescoping intervals between consecutive stages,
/// in order. These are the waterfall rows and the JSONL/JSON keys.
pub const STAGE_NAMES: [&str; NUM_STAGES - 1] = [
    "ingress_wait",   // issue   -> launch : VC/credit + mux queueing
    "wire_transit",   // launch  -> deliver: flight time incl. replay episodes
    "slice_queue",    // deliver -> svc_start: directory slice FIFO wait
    "home_service",   // svc_start -> svc_done: home-agent occupancy
    "memory_backend", // svc_done -> reply : DRAM / KVS backend
    "reply_delivery", // reply   -> complete: response wire + client ingress
];

struct Span {
    t: [u64; NUM_STAGES], // ps; UNSET until the stage is marked
    launches: u32,
}

/// Tracks sampled in-flight spans and accumulates per-stage histograms.
pub struct SpanTracer {
    every: u64,
    seen: u64,
    live: HashMap<u32, Span>,
    /// One histogram per entry of [`STAGE_NAMES`] (picoseconds).
    pub stages: Vec<Histogram>,
    /// End-to-end latency of completed sampled spans (picoseconds).
    pub e2e: Histogram,
    /// Spans selected for tracing.
    pub sampled: u64,
    /// Sampled spans that completed with a full, monotone stage record.
    pub completed: u64,
    /// Extra launches of an already-launched traced request — each one
    /// is a retransmission episode the span sat through.
    pub retx_episodes: u64,
    /// Sampled spans that finished with a missing or non-monotone stage
    /// (or never finished — see [`SpanTracer::seal`]). Excluded from the
    /// histograms so stage sums stay consistent with e2e.
    pub incomplete: u64,
}

impl SpanTracer {
    /// `sample_every` = N traces every N-th issued transaction (1 = all).
    pub fn new(sample_every: u32) -> SpanTracer {
        SpanTracer {
            every: sample_every.max(1) as u64,
            seen: 0,
            live: HashMap::default(),
            stages: (0..NUM_STAGES - 1).map(|_| Histogram::new()).collect(),
            e2e: Histogram::new(),
            sampled: 0,
            completed: 0,
            retx_episodes: 0,
            incomplete: 0,
        }
    }

    /// Offer an issued transaction for sampling. Call exactly once per
    /// response-needing request, at issue time.
    pub fn on_issue(&mut self, now: Time, id: u32) {
        let pick = self.seen % self.every == 0;
        self.seen += 1;
        if !pick {
            return;
        }
        self.sampled += 1;
        let mut t = [UNSET; NUM_STAGES];
        t[Stage::Issue as usize] = now.ps();
        self.live.insert(id, Span { t, launches: 0 });
    }

    /// Record a lifecycle checkpoint for `id` (no-op unless sampled).
    /// The first `Launch` stamps the span; every further `Launch` of the
    /// same id counts as a retransmission episode.
    pub fn mark(&mut self, now: Time, id: u32, stage: Stage) {
        let Some(sp) = self.live.get_mut(&id) else {
            return;
        };
        if stage == Stage::Launch {
            sp.launches += 1;
            if sp.launches > 1 {
                self.retx_episodes += 1;
                return; // keep the first launch time: transit absorbs replay
            }
        }
        let slot = &mut sp.t[stage as usize];
        if *slot == UNSET {
            *slot = now.ps();
        }
    }

    /// Complete the span for `id`: stamp `Complete`, fold its intervals
    /// into the histograms, and retire it.
    pub fn complete(&mut self, now: Time, id: u32) {
        let Some(mut sp) = self.live.remove(&id) else {
            return;
        };
        if sp.t[Stage::Complete as usize] == UNSET {
            sp.t[Stage::Complete as usize] = now.ps();
        }
        let full_and_monotone =
            sp.t.iter().all(|&t| t != UNSET) && sp.t.windows(2).all(|w| w[0] <= w[1]);
        if !full_and_monotone {
            self.incomplete += 1;
            return;
        }
        for (i, h) in self.stages.iter_mut().enumerate() {
            h.record(sp.t[i + 1] - sp.t[i]);
        }
        self.e2e.record(sp.t[Stage::Complete as usize] - sp.t[Stage::Issue as usize]);
        self.completed += 1;
    }

    /// End of run: every span still live (issued but never completed —
    /// e.g. the run drained before its reply) counts as incomplete.
    pub fn seal(&mut self) {
        self.incomplete += self.live.len() as u64;
        self.live.clear();
    }

    /// Spans currently in flight (a telemetry gauge).
    pub fn live_spans(&self) -> usize {
        self.live.len()
    }

    /// Summarize into waterfall rows (ns).
    pub fn waterfall(&self) -> Waterfall {
        let row = |name: &'static str, h: &Histogram| WaterfallRow {
            stage: name,
            count: h.count(),
            mean_ns: h.mean() / 1e3,
            p50_ns: h.p50() as f64 / 1e3,
            p99_ns: h.p99() as f64 / 1e3,
        };
        Waterfall {
            rows: STAGE_NAMES
                .iter()
                .zip(self.stages.iter())
                .map(|(name, h)| row(name, h))
                .collect(),
            e2e: row("end_to_end", &self.e2e),
            sampled: self.sampled,
            completed: self.completed,
            retx_episodes: self.retx_episodes,
            incomplete: self.incomplete,
        }
    }
}

/// One waterfall line: a stage interval's distribution in nanoseconds.
#[derive(Clone, Debug)]
pub struct WaterfallRow {
    pub stage: &'static str,
    pub count: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

/// The latency waterfall: per-stage rows plus the end-to-end line they
/// telescope into. Stage `mean_ns` values sum to `e2e.mean_ns` exactly
/// (modulo ps→ns float division); p50/p99 columns are per-stage
/// distributions and do not add.
#[derive(Clone, Debug)]
pub struct Waterfall {
    pub rows: Vec<WaterfallRow>,
    pub e2e: WaterfallRow,
    pub sampled: u64,
    pub completed: u64,
    pub retx_episodes: u64,
    pub incomplete: u64,
}

impl Waterfall {
    /// Sum of per-stage means — equals `e2e.mean_ns` for full spans.
    pub fn stage_mean_sum_ns(&self) -> f64 {
        self.rows.iter().map(|r| r.mean_ns).sum()
    }

    pub fn to_json(&self) -> Json {
        let row_json = |r: &WaterfallRow| {
            Json::Obj(vec![
                ("stage".into(), Json::s(r.stage)),
                ("count".into(), Json::u(r.count)),
                ("mean_ns".into(), Json::f(r.mean_ns)),
                ("p50_ns".into(), Json::f(r.p50_ns)),
                ("p99_ns".into(), Json::f(r.p99_ns)),
            ])
        };
        Json::Obj(vec![
            ("stages".into(), Json::Arr(self.rows.iter().map(row_json).collect())),
            ("end_to_end".into(), row_json(&self.e2e)),
            ("stage_mean_sum_ns".into(), Json::f(self.stage_mean_sum_ns())),
            ("sampled".into(), Json::u(self.sampled)),
            ("completed".into(), Json::u(self.completed)),
            ("retx_episodes".into(), Json::u(self.retx_episodes)),
            ("incomplete".into(), Json::u(self.incomplete)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(ns: u64) -> Time {
        Time(ns * 1000)
    }

    fn drive_span(tr: &mut SpanTracer, id: u32, base_ns: u64) {
        tr.on_issue(t(base_ns), id);
        tr.mark(t(base_ns + 10), id, Stage::Launch);
        tr.mark(t(base_ns + 30), id, Stage::Deliver);
        tr.mark(t(base_ns + 35), id, Stage::SvcStart);
        tr.mark(t(base_ns + 75), id, Stage::SvcDone);
        tr.mark(t(base_ns + 95), id, Stage::Reply);
        tr.complete(t(base_ns + 120), id);
    }

    #[test]
    fn stage_intervals_telescope_to_e2e() {
        let mut tr = SpanTracer::new(1);
        for i in 0..50u32 {
            drive_span(&mut tr, i, 1000 + 7 * i as u64);
        }
        assert_eq!(tr.sampled, 50);
        assert_eq!(tr.completed, 50);
        assert_eq!(tr.incomplete, 0);
        let w = tr.waterfall();
        // identical spans: every stage mean is exact, sum == e2e mean
        assert!((w.stage_mean_sum_ns() - w.e2e.mean_ns).abs() < 1e-6);
        assert!((w.e2e.mean_ns - 120.0).abs() < 1e-6);
        assert_eq!(w.rows[0].stage, "ingress_wait");
        assert!((w.rows[0].mean_ns - 10.0).abs() < 1e-6);
        assert!((w.rows[3].mean_ns - 40.0).abs() < 1e-6);
    }

    #[test]
    fn sampling_is_deterministic_every_nth() {
        let mut tr = SpanTracer::new(4);
        for i in 0..40u32 {
            tr.on_issue(t(i as u64), i);
        }
        assert_eq!(tr.sampled, 10);
        // ids 0, 4, 8, ... are the tracked ones
        assert_eq!(tr.live_spans(), 10);
        tr.mark(t(100), 4, Stage::Launch);
        tr.mark(t(100), 5, Stage::Launch); // not sampled: ignored
        tr.complete(t(200), 4);
        assert_eq!(tr.incomplete, 1); // id 4 lacked middle stages
    }

    #[test]
    fn relaunches_count_retx_episodes_and_keep_first_time() {
        let mut tr = SpanTracer::new(1);
        tr.on_issue(t(0), 9);
        tr.mark(t(10), 9, Stage::Launch);
        tr.mark(t(50), 9, Stage::Launch); // replay
        tr.mark(t(60), 9, Stage::Launch); // replay again
        tr.mark(t(80), 9, Stage::Deliver);
        tr.mark(t(80), 9, Stage::SvcStart);
        tr.mark(t(90), 9, Stage::SvcDone);
        tr.mark(t(90), 9, Stage::Reply);
        tr.complete(t(100), 9);
        assert_eq!(tr.retx_episodes, 2);
        assert_eq!(tr.completed, 1);
        // wire_transit = deliver - first launch = 70ns (replay included)
        let w = tr.waterfall();
        assert!((w.rows[1].mean_ns - 70.0).abs() < 1e-6);
    }

    #[test]
    fn seal_retires_unfinished_spans() {
        let mut tr = SpanTracer::new(1);
        tr.on_issue(t(0), 1);
        tr.on_issue(t(1), 2);
        tr.complete(t(50), 1); // incomplete: middle stages missing
        tr.seal();
        assert_eq!(tr.incomplete, 2);
        assert_eq!(tr.live_spans(), 0);
        assert_eq!(tr.completed, 0);
    }

    #[test]
    fn waterfall_json_is_well_formed() {
        let mut tr = SpanTracer::new(1);
        drive_span(&mut tr, 1, 0);
        let j = tr.waterfall().to_json();
        let text = j.compact();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("completed").and_then(|v| v.as_u64()), Some(1));
        assert_eq!(back.get("stages").and_then(|v| v.as_arr()).map(|a| a.len()), Some(6));
    }
}
