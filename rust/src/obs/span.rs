//! Sampled per-transaction span tracing, fabric-aware.
//!
//! A *span* follows one response-needing coherence request from the
//! moment the client issues it until its response lands back, keyed by
//! the transaction id ([`crate::proto::messages::ReqId`]) which the
//! stack carries intact from request to response (the fabric widens the
//! key with the issuing node: `fabric::span_key`). Each span records a
//! timestamp at every lifecycle stage; on completion the deltas between
//! consecutive stages feed per-stage [`Histogram`]s, so an end-to-end
//! p99 decomposes into queueing vs wire/replay vs hop vs service vs
//! memory time — the latency waterfall.
//!
//! Spans come in two classes that are told apart *at completion*:
//!
//! * **local** — the request was served by the issuing cell's own
//!   directory. Six telescoping intervals:
//!   `issue → launch → deliver → svc_start → svc_done → reply →
//!   complete` ([`STAGE_NAMES`]).
//! * **remote** — the request crossed the fabric to another node's
//!   home. Two extra checkpoints split the journey per hop:
//!   [`Stage::FwdOut`] (the source router translated the id and put the
//!   request on the inter-node channel) and [`Stage::RspLaunch`] (the
//!   response frame left the home on the return channel), giving eight
//!   telescoping intervals ([`REMOTE_STAGE_NAMES`]).
//!
//! Within each class the per-span stage intervals sum *exactly* to the
//! span's end-to-end latency, so each class's stage means sum to that
//! class's e2e mean (quantiles agree within histogram binning error
//! only, since quantiles don't add). A span that marked `FwdOut` is
//! remote; one that never did is local — a single tracer serves a whole
//! fabric without pre-declaring which requests will travel.
//!
//! Sampling is deterministic — every `sample_every`-th issued
//! transaction per issue *stream*, no RNG. A stream is one issuing
//! cell: multi-node hosts give each node its own stream with its own
//! counter phase ([`SpanTracer::with_phases`]) so the cells don't all
//! sample the lockstep-correlated k·N-th transactions. The tracer is
//! passive: it never schedules events or perturbs simulation state,
//! which the obs transparency gate checks.

use crate::rustc_hash::FxHashMap as HashMap;
use crate::sim::stats::Histogram;
use crate::sim::time::Time;

use super::json::Json;

/// Lifecycle checkpoints of a traced transaction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Client handed the request to the home-bound framed ingress.
    Issue = 0,
    /// Request frame left the ingress mux onto the wire (first launch;
    /// later launches of the same id are retransmit episodes).
    Launch = 1,
    /// Remote only: the source node's router translated the request id
    /// and offered the frame to the inter-node request channel.
    FwdOut = 2,
    /// Request frame delivered at the home side and enqueued on its
    /// directory slice FIFO.
    Deliver = 3,
    /// Home agent began servicing the request (slice grant).
    SvcStart = 4,
    /// Directory/home produced the response message.
    SvcDone = 5,
    /// Response ready to send after the memory/KVS backend.
    Reply = 6,
    /// Remote only: the response frame left the home node on the
    /// inter-node response channel back toward the source.
    RspLaunch = 7,
    /// Response landed back at the client.
    Complete = 8,
}

const NUM_STAGES: usize = 9;
const UNSET: u64 = u64::MAX;

/// Names of the six telescoping intervals of a *local* span, in order.
/// These are the waterfall rows and the JSONL/JSON keys.
pub const STAGE_NAMES: [&str; 6] = [
    "ingress_wait",   // issue   -> launch : VC/credit + mux queueing
    "wire_transit",   // launch  -> deliver: flight time incl. replay episodes
    "slice_queue",    // deliver -> svc_start: directory slice FIFO wait
    "home_service",   // svc_start -> svc_done: home-agent occupancy
    "memory_backend", // svc_done -> reply : DRAM / KVS backend
    "reply_delivery", // reply   -> complete: response wire + client ingress
];

/// Names of the eight telescoping intervals of a *remote* (cross-node)
/// span, in order.
pub const REMOTE_STAGE_NAMES: [&str; 8] = [
    "ingress_wait",   // issue    -> launch  : VC/credit + mux queueing
    "wire_transit",   // launch   -> fwd_out : local CPU->FPGA wire to the router
    "hop_request",    // fwd_out  -> deliver : inter-node request channel hop
    "slice_queue",    // deliver  -> svc_start: home slice FIFO wait
    "home_service",   // svc_start-> svc_done: home-agent occupancy
    "memory_backend", // svc_done -> reply   : DRAM / KVS backend
    "hop_rsp_wait",   // reply    -> rsp_launch: response channel queue + credit
    "reply_delivery", // rsp_launch -> complete: response hop + source delivery
];

/// Consecutive-stage index pairs of a local span's six intervals.
const LOCAL_PAIRS: [(usize, usize); 6] = [(0, 1), (1, 3), (3, 4), (4, 5), (5, 6), (6, 8)];
/// Consecutive-stage index pairs of a remote span's eight intervals.
const REMOTE_PAIRS: [(usize, usize); 8] =
    [(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 6), (6, 7), (7, 8)];

struct Span {
    t: [u64; NUM_STAGES], // ps; UNSET until the stage is marked
    launches: u32,
    parks: u32,
    replays: u32,
}

/// A completed span retained verbatim for trace export
/// ([`crate::obs::chrome`]): stage timestamps plus detour annotations.
#[derive(Clone, Copy, Debug)]
pub struct SpanRecord {
    /// The (possibly node-widened) transaction key.
    pub id: u32,
    /// Per-stage timestamps in picoseconds; `u64::MAX` = never marked.
    pub t: [u64; NUM_STAGES],
    /// Total wire launches (1 + retransmission episodes).
    pub launches: u32,
    /// Migration park episodes the request sat through.
    pub parks: u32,
    /// Re-injection replays (migration handoff or failover).
    pub replays: u32,
    /// Crossed the fabric to a remote home.
    pub remote: bool,
}

impl SpanRecord {
    /// Picosecond timestamp of `stage`, if it was marked.
    pub fn at(&self, stage: Stage) -> Option<u64> {
        let v = self.t[stage as usize];
        (v != UNSET).then_some(v)
    }

    /// The record's telescoping intervals as
    /// `(stage name, start_ps, end_ps)`, local or remote as classified
    /// at completion. Records only ever hold well-formed spans, so
    /// every interval is present and monotone.
    pub fn intervals(&self) -> Vec<(&'static str, u64, u64)> {
        let (pairs, names): (&[(usize, usize)], &[&'static str]) = if self.remote {
            (&REMOTE_PAIRS, &REMOTE_STAGE_NAMES)
        } else {
            (&LOCAL_PAIRS, &STAGE_NAMES)
        };
        pairs
            .iter()
            .zip(names.iter())
            .map(|(&(a, b), &name)| (name, self.t[a], self.t[b]))
            .collect()
    }
}

struct IssueStream {
    seen: u64,
    phase: u64,
}

/// Tracks sampled in-flight spans and accumulates per-stage histograms,
/// split into local and remote classes.
pub struct SpanTracer {
    every: u64,
    streams: Vec<IssueStream>,
    live: HashMap<u32, Span>,
    /// One histogram per entry of [`STAGE_NAMES`] (ps), local spans.
    pub stages: Vec<Histogram>,
    /// One histogram per entry of [`REMOTE_STAGE_NAMES`] (ps), remote spans.
    pub remote_stages: Vec<Histogram>,
    /// End-to-end latency of completed local sampled spans (ps).
    pub e2e: Histogram,
    /// End-to-end latency of completed remote sampled spans (ps).
    pub e2e_remote: Histogram,
    /// Spans selected for tracing.
    pub sampled: u64,
    /// Sampled spans that completed with a full, monotone stage record
    /// (local + remote).
    pub completed: u64,
    /// Of `completed`, those that crossed the fabric.
    pub remote_completed: u64,
    /// Extra launches of an already-launched traced request — each one
    /// is a retransmission episode the span sat through.
    pub retx_episodes: u64,
    /// Migration park episodes observed on traced requests.
    pub park_episodes: u64,
    /// Replay (re-injection) episodes observed on traced requests —
    /// migration handoffs and failover replays.
    pub replay_episodes: u64,
    /// Sampled spans that finished with a missing or non-monotone stage
    /// (or never finished — see [`SpanTracer::seal`]). Excluded from the
    /// histograms so stage sums stay consistent with e2e.
    pub incomplete: u64,
    record: bool,
    records_cap: usize,
    records: Vec<SpanRecord>,
}

/// Default cap on retained [`SpanRecord`]s when recording is on.
pub const DEFAULT_RECORDS_CAP: usize = 65_536;

impl SpanTracer {
    /// `sample_every` = N traces every N-th issued transaction (1 = all).
    /// Single issue stream, phase 0.
    pub fn new(sample_every: u32) -> SpanTracer {
        SpanTracer::with_phases(sample_every, &[0])
    }

    /// Multi-stream tracer: stream `s` picks the transactions where
    /// `(seen_s + phases[s]) % sample_every == 0`. Hosts with several
    /// issuing cells (the fabric) pass one pairwise-distinct phase per
    /// node so the cells don't sample lockstep-correlated arrivals.
    pub fn with_phases(sample_every: u32, phases: &[u32]) -> SpanTracer {
        let every = sample_every.max(1) as u64;
        let streams = if phases.is_empty() { &[0][..] } else { phases };
        SpanTracer {
            every,
            streams: streams
                .iter()
                .map(|&p| IssueStream { seen: 0, phase: p as u64 % every })
                .collect(),
            live: HashMap::default(),
            stages: (0..STAGE_NAMES.len()).map(|_| Histogram::new()).collect(),
            remote_stages: (0..REMOTE_STAGE_NAMES.len()).map(|_| Histogram::new()).collect(),
            e2e: Histogram::new(),
            e2e_remote: Histogram::new(),
            sampled: 0,
            completed: 0,
            remote_completed: 0,
            retx_episodes: 0,
            park_episodes: 0,
            replay_episodes: 0,
            incomplete: 0,
            record: false,
            records_cap: DEFAULT_RECORDS_CAP,
            records: Vec::new(),
        }
    }

    /// Retain completed spans verbatim (capped) for trace export.
    pub fn record_spans(&mut self, on: bool) {
        self.record = on;
    }

    /// The per-stream sampling phases (for tests and diagnostics).
    pub fn phases(&self) -> Vec<u32> {
        self.streams.iter().map(|s| s.phase as u32).collect()
    }

    /// Offer an issued transaction for sampling on stream 0. Call
    /// exactly once per response-needing request, at issue time.
    pub fn on_issue(&mut self, now: Time, id: u32) {
        self.on_issue_stream(now, id, 0);
    }

    /// Offer an issued transaction for sampling on issue stream
    /// `stream` (one stream per issuing cell; out-of-range streams fold
    /// onto stream 0 defensively).
    pub fn on_issue_stream(&mut self, now: Time, id: u32, stream: usize) {
        let s = &mut self.streams[if stream < self.streams.len() { stream } else { 0 }];
        let pick = (s.seen + s.phase) % self.every == 0;
        s.seen += 1;
        if !pick {
            return;
        }
        self.sampled += 1;
        let mut t = [UNSET; NUM_STAGES];
        t[Stage::Issue as usize] = now.ps();
        self.live.insert(id, Span { t, launches: 0, parks: 0, replays: 0 });
    }

    /// Record a lifecycle checkpoint for `id` (no-op unless sampled).
    /// The first `Launch` stamps the span; every further `Launch` of the
    /// same id counts as a retransmission episode. All other stages are
    /// first-write-wins, so a replayed request keeps its original
    /// timeline and the replay cost lands in the enclosing interval.
    pub fn mark(&mut self, now: Time, id: u32, stage: Stage) {
        let Some(sp) = self.live.get_mut(&id) else {
            return;
        };
        if stage == Stage::Launch {
            sp.launches += 1;
            if sp.launches > 1 {
                self.retx_episodes += 1;
                return; // keep the first launch time: transit absorbs replay
            }
        }
        let slot = &mut sp.t[stage as usize];
        if *slot == UNSET {
            *slot = now.ps();
        }
    }

    /// Annotate a traced request parked by a home migration (no-op
    /// unless sampled). The park shows up as an episode count — the
    /// wait itself stays inside the interval it interrupted.
    pub fn note_park(&mut self, id: u32) {
        if let Some(sp) = self.live.get_mut(&id) {
            sp.parks += 1;
            self.park_episodes += 1;
        }
    }

    /// Annotate a traced request replayed (re-injected) toward a new
    /// home — migration handoff or failover replay (no-op unless
    /// sampled).
    pub fn note_replay(&mut self, id: u32) {
        if let Some(sp) = self.live.get_mut(&id) {
            sp.replays += 1;
            self.replay_episodes += 1;
        }
    }

    /// Complete the span for `id`: stamp `Complete`, classify it local
    /// or remote (did it mark `FwdOut`?), fold its intervals into that
    /// class's histograms, and retire it.
    pub fn complete(&mut self, now: Time, id: u32) {
        let Some(mut sp) = self.live.remove(&id) else {
            return;
        };
        if sp.t[Stage::Complete as usize] == UNSET {
            sp.t[Stage::Complete as usize] = now.ps();
        }
        let remote = sp.t[Stage::FwdOut as usize] != UNSET;
        let pairs: &[(usize, usize)] = if remote { &REMOTE_PAIRS } else { &LOCAL_PAIRS };
        let well_formed = pairs
            .iter()
            .all(|&(a, b)| sp.t[a] != UNSET && sp.t[b] != UNSET && sp.t[a] <= sp.t[b])
            // a local span must not carry a stray response-hop mark
            && (remote || sp.t[Stage::RspLaunch as usize] == UNSET);
        if !well_formed {
            self.incomplete += 1;
            return;
        }
        if remote {
            for (h, &(a, b)) in self.remote_stages.iter_mut().zip(REMOTE_PAIRS.iter()) {
                h.record(sp.t[b] - sp.t[a]);
            }
            self.e2e_remote
                .record(sp.t[Stage::Complete as usize] - sp.t[Stage::Issue as usize]);
            self.remote_completed += 1;
        } else {
            for (h, &(a, b)) in self.stages.iter_mut().zip(LOCAL_PAIRS.iter()) {
                h.record(sp.t[b] - sp.t[a]);
            }
            self.e2e.record(sp.t[Stage::Complete as usize] - sp.t[Stage::Issue as usize]);
        }
        self.completed += 1;
        if self.record && self.records.len() < self.records_cap {
            self.records.push(SpanRecord {
                id,
                t: sp.t,
                launches: sp.launches,
                parks: sp.parks,
                replays: sp.replays,
                remote,
            });
        }
    }

    /// End of run: every span still live (issued but never completed —
    /// e.g. the run drained before its reply) counts as incomplete.
    pub fn seal(&mut self) {
        self.incomplete += self.live.len() as u64;
        self.live.clear();
    }

    /// Spans currently in flight (a telemetry gauge).
    pub fn live_spans(&self) -> usize {
        self.live.len()
    }

    /// Retained completed spans (empty unless `record_spans(true)`).
    pub fn records(&self) -> &[SpanRecord] {
        &self.records
    }

    /// Take the retained spans out of the tracer.
    pub fn take_records(&mut self) -> Vec<SpanRecord> {
        std::mem::take(&mut self.records)
    }

    /// Summarize into waterfall rows (ns).
    pub fn waterfall(&self) -> Waterfall {
        let row = |name: &'static str, h: &Histogram| WaterfallRow {
            stage: name,
            count: h.count(),
            mean_ns: h.mean() / 1e3,
            p50_ns: h.p50() as f64 / 1e3,
            p99_ns: h.p99() as f64 / 1e3,
        };
        Waterfall {
            rows: STAGE_NAMES
                .iter()
                .zip(self.stages.iter())
                .map(|(name, h)| row(name, h))
                .collect(),
            e2e: row("end_to_end", &self.e2e),
            remote_rows: if self.remote_completed > 0 {
                REMOTE_STAGE_NAMES
                    .iter()
                    .zip(self.remote_stages.iter())
                    .map(|(name, h)| row(name, h))
                    .collect()
            } else {
                Vec::new()
            },
            e2e_remote: (self.remote_completed > 0)
                .then(|| row("end_to_end_remote", &self.e2e_remote)),
            sampled: self.sampled,
            completed: self.completed,
            remote_completed: self.remote_completed,
            retx_episodes: self.retx_episodes,
            park_episodes: self.park_episodes,
            replay_episodes: self.replay_episodes,
            incomplete: self.incomplete,
        }
    }
}

/// One waterfall line: a stage interval's distribution in nanoseconds.
#[derive(Clone, Debug)]
pub struct WaterfallRow {
    pub stage: &'static str,
    pub count: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

/// The latency waterfall: per-stage rows plus the end-to-end line they
/// telescope into, per span class. `rows`/`e2e` cover local spans;
/// `remote_rows`/`e2e_remote` (empty/`None` when no span crossed the
/// fabric) cover remote fills. Within each class, stage `mean_ns`
/// values sum to that class's e2e mean exactly (modulo ps→ns float
/// division); p50/p99 columns are per-stage distributions and do not
/// add.
#[derive(Clone, Debug)]
pub struct Waterfall {
    pub rows: Vec<WaterfallRow>,
    pub e2e: WaterfallRow,
    pub remote_rows: Vec<WaterfallRow>,
    pub e2e_remote: Option<WaterfallRow>,
    pub sampled: u64,
    pub completed: u64,
    pub remote_completed: u64,
    pub retx_episodes: u64,
    pub park_episodes: u64,
    pub replay_episodes: u64,
    pub incomplete: u64,
}

impl Waterfall {
    /// Sum of local per-stage means — equals `e2e.mean_ns` for full spans.
    pub fn stage_mean_sum_ns(&self) -> f64 {
        self.rows.iter().map(|r| r.mean_ns).sum()
    }

    /// Sum of remote per-stage means — equals `e2e_remote.mean_ns` when
    /// any remote span completed (0.0 otherwise).
    pub fn remote_stage_mean_sum_ns(&self) -> f64 {
        self.remote_rows.iter().map(|r| r.mean_ns).sum()
    }

    pub fn to_json(&self) -> Json {
        let row_json = |r: &WaterfallRow| {
            Json::Obj(vec![
                ("stage".into(), Json::s(r.stage)),
                ("count".into(), Json::u(r.count)),
                ("mean_ns".into(), Json::f(r.mean_ns)),
                ("p50_ns".into(), Json::f(r.p50_ns)),
                ("p99_ns".into(), Json::f(r.p99_ns)),
            ])
        };
        let mut members = vec![
            ("stages".into(), Json::Arr(self.rows.iter().map(row_json).collect())),
            ("end_to_end".into(), row_json(&self.e2e)),
            ("stage_mean_sum_ns".into(), Json::f(self.stage_mean_sum_ns())),
        ];
        if let Some(r) = &self.e2e_remote {
            members.push((
                "remote_stages".into(),
                Json::Arr(self.remote_rows.iter().map(row_json).collect()),
            ));
            members.push(("end_to_end_remote".into(), row_json(r)));
            members
                .push(("remote_stage_mean_sum_ns".into(), Json::f(self.remote_stage_mean_sum_ns())));
        }
        members.extend([
            ("sampled".to_string(), Json::u(self.sampled)),
            ("completed".to_string(), Json::u(self.completed)),
            ("remote_completed".to_string(), Json::u(self.remote_completed)),
            ("retx_episodes".to_string(), Json::u(self.retx_episodes)),
            ("park_episodes".to_string(), Json::u(self.park_episodes)),
            ("replay_episodes".to_string(), Json::u(self.replay_episodes)),
            ("incomplete".to_string(), Json::u(self.incomplete)),
        ]);
        Json::Obj(members)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(ns: u64) -> Time {
        Time(ns * 1000)
    }

    fn drive_span(tr: &mut SpanTracer, id: u32, base_ns: u64) {
        tr.on_issue(t(base_ns), id);
        tr.mark(t(base_ns + 10), id, Stage::Launch);
        tr.mark(t(base_ns + 30), id, Stage::Deliver);
        tr.mark(t(base_ns + 35), id, Stage::SvcStart);
        tr.mark(t(base_ns + 75), id, Stage::SvcDone);
        tr.mark(t(base_ns + 95), id, Stage::Reply);
        tr.complete(t(base_ns + 120), id);
    }

    fn drive_remote_span(tr: &mut SpanTracer, id: u32, base_ns: u64) {
        tr.on_issue(t(base_ns), id);
        tr.mark(t(base_ns + 10), id, Stage::Launch);
        tr.mark(t(base_ns + 30), id, Stage::FwdOut);
        tr.mark(t(base_ns + 80), id, Stage::Deliver);
        tr.mark(t(base_ns + 85), id, Stage::SvcStart);
        tr.mark(t(base_ns + 125), id, Stage::SvcDone);
        tr.mark(t(base_ns + 145), id, Stage::Reply);
        tr.mark(t(base_ns + 150), id, Stage::RspLaunch);
        tr.complete(t(base_ns + 220), id);
    }

    #[test]
    fn stage_intervals_telescope_to_e2e() {
        let mut tr = SpanTracer::new(1);
        for i in 0..50u32 {
            drive_span(&mut tr, i, 1000 + 7 * i as u64);
        }
        assert_eq!(tr.sampled, 50);
        assert_eq!(tr.completed, 50);
        assert_eq!(tr.incomplete, 0);
        let w = tr.waterfall();
        // identical spans: every stage mean is exact, sum == e2e mean
        assert!((w.stage_mean_sum_ns() - w.e2e.mean_ns).abs() < 1e-6);
        assert!((w.e2e.mean_ns - 120.0).abs() < 1e-6);
        assert_eq!(w.rows[0].stage, "ingress_wait");
        assert!((w.rows[0].mean_ns - 10.0).abs() < 1e-6);
        assert!((w.rows[3].mean_ns - 40.0).abs() < 1e-6);
        // no remote spans: the remote side stays empty
        assert_eq!(w.remote_completed, 0);
        assert!(w.remote_rows.is_empty());
        assert!(w.e2e_remote.is_none());
    }

    #[test]
    fn remote_stage_intervals_telescope_to_remote_e2e() {
        let mut tr = SpanTracer::new(1);
        for i in 0..20u32 {
            drive_remote_span(&mut tr, i, 500 + 11 * i as u64);
        }
        // and a few locals interleaved: the classes must not bleed
        for i in 100..110u32 {
            drive_span(&mut tr, i, 2000 + 3 * i as u64);
        }
        assert_eq!(tr.completed, 30);
        assert_eq!(tr.remote_completed, 20);
        assert_eq!(tr.incomplete, 0);
        let w = tr.waterfall();
        assert_eq!(w.remote_rows.len(), REMOTE_STAGE_NAMES.len());
        let r = w.e2e_remote.as_ref().expect("remote spans completed");
        assert!((w.remote_stage_mean_sum_ns() - r.mean_ns).abs() < 1e-6);
        assert!((r.mean_ns - 220.0).abs() < 1e-6);
        // hop_request = fwd_out -> deliver = 50ns
        assert_eq!(w.remote_rows[2].stage, "hop_request");
        assert!((w.remote_rows[2].mean_ns - 50.0).abs() < 1e-6);
        // hop_rsp_wait = reply -> rsp_launch = 5ns
        assert_eq!(w.remote_rows[6].stage, "hop_rsp_wait");
        assert!((w.remote_rows[6].mean_ns - 5.0).abs() < 1e-6);
        // the local class is untouched by remote traffic
        assert!((w.e2e.mean_ns - 120.0).abs() < 1e-6);
        assert!((w.stage_mean_sum_ns() - w.e2e.mean_ns).abs() < 1e-6);
    }

    #[test]
    fn sampling_is_deterministic_every_nth() {
        let mut tr = SpanTracer::new(4);
        for i in 0..40u32 {
            tr.on_issue(t(i as u64), i);
        }
        assert_eq!(tr.sampled, 10);
        // ids 0, 4, 8, ... are the tracked ones
        assert_eq!(tr.live_spans(), 10);
        tr.mark(t(100), 4, Stage::Launch);
        tr.mark(t(100), 5, Stage::Launch); // not sampled: ignored
        tr.complete(t(200), 4);
        assert_eq!(tr.incomplete, 1); // id 4 lacked middle stages
    }

    #[test]
    fn per_stream_phases_decorrelate_sampling() {
        // two streams, every=4, phases 0 and 1: stream 0 picks its
        // arrivals 0,4,8,...; stream 1 picks 3,7,11,... — never the
        // same ordinal, which is the point of the per-node offsets.
        let mut tr = SpanTracer::with_phases(4, &[0, 1]);
        let mut picked = [Vec::new(), Vec::new()];
        for k in 0..16u32 {
            for s in 0..2usize {
                let before = tr.sampled;
                let id = k * 2 + s as u32;
                tr.on_issue_stream(t(k as u64), id, s);
                if tr.sampled > before {
                    picked[s].push(k);
                }
            }
        }
        assert_eq!(picked[0], vec![0, 4, 8, 12]);
        assert_eq!(picked[1], vec![3, 7, 11, 15]);
        assert_eq!(tr.phases(), vec![0, 1]);
    }

    #[test]
    fn relaunches_count_retx_episodes_and_keep_first_time() {
        let mut tr = SpanTracer::new(1);
        tr.on_issue(t(0), 9);
        tr.mark(t(10), 9, Stage::Launch);
        tr.mark(t(50), 9, Stage::Launch); // replay
        tr.mark(t(60), 9, Stage::Launch); // replay again
        tr.mark(t(80), 9, Stage::Deliver);
        tr.mark(t(80), 9, Stage::SvcStart);
        tr.mark(t(90), 9, Stage::SvcDone);
        tr.mark(t(90), 9, Stage::Reply);
        tr.complete(t(100), 9);
        assert_eq!(tr.retx_episodes, 2);
        assert_eq!(tr.completed, 1);
        // wire_transit = deliver - first launch = 70ns (replay included)
        let w = tr.waterfall();
        assert!((w.rows[1].mean_ns - 70.0).abs() < 1e-6);
    }

    #[test]
    fn park_and_replay_annotations_count_episodes() {
        let mut tr = SpanTracer::new(1);
        tr.on_issue(t(0), 3);
        tr.mark(t(5), 3, Stage::Launch);
        tr.note_park(3);
        tr.note_replay(3);
        tr.note_replay(42); // not sampled: ignored
        tr.mark(t(40), 3, Stage::Deliver);
        tr.mark(t(41), 3, Stage::SvcStart);
        tr.mark(t(50), 3, Stage::SvcDone);
        tr.mark(t(50), 3, Stage::Reply);
        tr.complete(t(60), 3);
        assert_eq!(tr.park_episodes, 1);
        assert_eq!(tr.replay_episodes, 1);
        let w = tr.waterfall();
        assert_eq!(w.park_episodes, 1);
        assert_eq!(w.replay_episodes, 1);
    }

    #[test]
    fn seal_retires_unfinished_spans() {
        let mut tr = SpanTracer::new(1);
        tr.on_issue(t(0), 1);
        tr.on_issue(t(1), 2);
        tr.complete(t(50), 1); // incomplete: middle stages missing
        tr.seal();
        assert_eq!(tr.incomplete, 2);
        assert_eq!(tr.live_spans(), 0);
        assert_eq!(tr.completed, 0);
    }

    #[test]
    fn recorded_spans_round_trip_their_timeline() {
        let mut tr = SpanTracer::new(1);
        tr.record_spans(true);
        drive_span(&mut tr, 7, 100);
        drive_remote_span(&mut tr, 8, 100);
        let recs = tr.records();
        assert_eq!(recs.len(), 2);
        assert!(!recs[0].remote);
        assert!(recs[1].remote);
        assert_eq!(recs[0].at(Stage::Issue), Some(t(100).ps()));
        assert_eq!(recs[0].at(Stage::FwdOut), None);
        assert_eq!(recs[1].at(Stage::RspLaunch), Some(t(250).ps()));
    }

    #[test]
    fn waterfall_json_is_well_formed() {
        let mut tr = SpanTracer::new(1);
        drive_span(&mut tr, 1, 0);
        drive_remote_span(&mut tr, 2, 0);
        let j = tr.waterfall().to_json();
        let text = j.compact();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("completed").and_then(|v| v.as_u64()), Some(2));
        assert_eq!(back.get("stages").and_then(|v| v.as_arr()).map(|a| a.len()), Some(6));
        assert_eq!(back.get("remote_stages").and_then(|v| v.as_arr()).map(|a| a.len()), Some(8));
        assert_eq!(back.get("remote_completed").and_then(|v| v.as_u64()), Some(1));
    }
}
