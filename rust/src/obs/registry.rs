//! Unified metric registry with stable, namespaced names.
//!
//! Before this module the simulator had three ad-hoc counter surfaces —
//! [`crate::sim::stats::Counters`] blocks on the machine/workload,
//! [`crate::transport::rel::RelStats`] snapshots per link direction, and
//! per-slice dcs stats — each with its own key scheme. The registry
//! absorbs all of them under dotted names (`machine.*`, `workload.*`,
//! `dcs.*`, `rel.*`, `checker.*`, `ingress.*`) so the telemetry ticker,
//! the `--json` emitters, and future QoS triggers read one surface.
//!
//! Absorption is *snapshot-style*: sources keep owning their counters and
//! the host re-absorbs current values whenever a consumer needs them
//! (`set` overwrites). Counters are monotone u64s; gauges are
//! instantaneous f64s (queue depths, credit occupancy, effective RTO).
//! The registry is purely passive — it never touches simulation state,
//! holds no RNG, and schedules no events, which is what the obs
//! transparency gate relies on.

use std::collections::BTreeMap;

use crate::sim::stats::Counters;
use crate::transport::RelStats;

use super::json::Json;

#[derive(Default, Clone)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    /// Counter values at the last `deltas()` call (ticker baselines).
    last: BTreeMap<String, u64>,
    /// Names written since the last `begin_refresh` (debug builds
    /// only): two sources landing on the same dotted name within one
    /// refresh is a silent last-writer-wins collision — made loud here,
    /// since `node<N>.` prefixing makes such collisions easy to
    /// reintroduce.
    #[cfg(debug_assertions)]
    fresh: std::collections::BTreeSet<String>,
    #[cfg(debug_assertions)]
    guarding: bool,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Start a refresh epoch: hosts call this at the top of their
    /// `refresh_registry`, and every metric name may then be written at
    /// most once until the next `begin_refresh` (debug builds panic on
    /// a duplicate). Without any `begin_refresh` call the guard stays
    /// off — snapshot-style overwrites across ticks are the norm.
    pub fn begin_refresh(&mut self) {
        #[cfg(debug_assertions)]
        {
            self.fresh.clear();
            self.guarding = true;
        }
    }

    #[cfg(debug_assertions)]
    fn guard(&mut self, name: &str) {
        if self.guarding && !self.fresh.insert(name.to_string()) {
            panic!("duplicate metric registration within one refresh: {name}");
        }
    }

    #[cfg(not(debug_assertions))]
    #[inline(always)]
    fn guard(&mut self, _name: &str) {}

    /// Retire every metric whose name starts with `prefix` — counters,
    /// gauges, delta baselines, and (in debug builds) the current
    /// refresh epoch's duplicate-name guard. A topology change (live
    /// re-slicing, slice drain) legitimately re-registers per-slice
    /// names like `dcs.slice3.depth` within the same refresh epoch it
    /// retires the old shape's names in; without this the dotted-name
    /// guard reports a false collision. Returns how many counters +
    /// gauges were removed.
    pub fn retire_prefix(&mut self, prefix: &str) -> usize {
        let before = self.counters.len() + self.gauges.len();
        self.counters.retain(|k, _| !k.starts_with(prefix));
        self.gauges.retain(|k, _| !k.starts_with(prefix));
        self.last.retain(|k, _| !k.starts_with(prefix));
        #[cfg(debug_assertions)]
        self.fresh.retain(|k| !k.starts_with(prefix));
        before - (self.counters.len() + self.gauges.len())
    }

    /// Set a counter to its current absolute value.
    pub fn set(&mut self, name: &str, v: u64) {
        self.guard(name);
        match self.counters.get_mut(name) {
            Some(slot) => *slot = v,
            None => {
                self.counters.insert(name.to_string(), v);
            }
        }
    }

    /// Set an instantaneous gauge.
    pub fn gauge(&mut self, name: &str, v: f64) {
        self.guard(name);
        match self.gauges.get_mut(name) {
            Some(slot) => *slot = v,
            None => {
                self.gauges.insert(name.to_string(), v);
            }
        }
    }

    pub fn get(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn get_gauge(&self, name: &str) -> f64 {
        self.gauges.get(name).copied().unwrap_or(0.0)
    }

    pub fn len(&self) -> usize {
        self.counters.len() + self.gauges.len()
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty()
    }

    /// Absorb a [`Counters`] block under `ns.`-prefixed names.
    pub fn absorb(&mut self, ns: &str, c: &Counters) {
        for (k, v) in c.iter() {
            self.set(&format!("{ns}.{k}"), v);
        }
    }

    /// Absorb a reliability snapshot: monotone fields become counters,
    /// instantaneous estimates (srtt/rto) and high-water marks become
    /// gauges under the same namespace.
    pub fn absorb_rel(&mut self, ns: &str, s: &RelStats) {
        self.set(&format!("{ns}.sent"), s.sent);
        self.set(&format!("{ns}.sent_bytes"), s.sent_bytes);
        self.set(&format!("{ns}.retransmitted"), s.retransmitted);
        self.set(&format!("{ns}.retransmitted_bytes"), s.retransmitted_bytes);
        self.set(&format!("{ns}.timeouts"), s.timeouts);
        self.set(&format!("{ns}.accepted"), s.accepted);
        self.set(&format!("{ns}.accepted_bytes"), s.accepted_bytes);
        self.set(&format!("{ns}.dropped_corrupt"), s.dropped_corrupt);
        self.set(&format!("{ns}.dropped_out_of_order"), s.dropped_out_of_order);
        self.set(&format!("{ns}.buffered_out_of_order"), s.buffered_out_of_order);
        self.set(&format!("{ns}.sacks"), s.sacks);
        self.set(&format!("{ns}.injected_drops"), s.injected_drops);
        self.set(&format!("{ns}.injected_corrupts"), s.injected_corrupts);
        self.set(&format!("{ns}.injected_reorders"), s.injected_reorders);
        self.set(&format!("{ns}.piggybacked_acks"), s.piggybacked_acks);
        self.set(&format!("{ns}.rtt_samples"), s.rtt_samples);
        self.gauge(&format!("{ns}.peak_buffered"), s.peak_buffered as f64);
        self.gauge(&format!("{ns}.peak_replay"), s.peak_replay as f64);
        self.gauge(&format!("{ns}.srtt_ns"), s.srtt_ns);
        self.gauge(&format!("{ns}.rto_ns"), s.rto_ns);
    }

    /// Counter deltas since the previous call (zero-delta metrics are
    /// skipped so JSONL lines stay small), then advance the baseline.
    pub fn deltas(&mut self) -> Vec<(String, u64)> {
        let mut out = Vec::new();
        for (k, &v) in &self.counters {
            let prev = self.last.get(k).copied().unwrap_or(0);
            if v != prev {
                out.push((k.clone(), v.saturating_sub(prev)));
            }
        }
        for (k, _) in &out {
            let cur = self.counters[k];
            self.last.insert(k.clone(), cur);
        }
        out
    }

    /// Full dump: `{"counters": {...}, "gauges": {...}}`.
    pub fn to_json(&self) -> Json {
        let counters = self.counters.iter().map(|(k, &v)| (k.clone(), Json::u(v))).collect();
        let gauges = self.gauges.iter().map(|(k, &v)| (k.clone(), Json::f(v))).collect();
        Json::Obj(vec![
            ("counters".into(), Json::Obj(counters)),
            ("gauges".into(), Json::Obj(gauges)),
        ])
    }

    /// Iterate current counter values (name-sorted, stable).
    pub fn iter_counters(&self) -> impl Iterator<Item = (&str, u64)> + '_ {
        self.counters.iter().map(|(k, &v)| (k.as_str(), v))
    }

    /// Iterate current gauge values (name-sorted, stable).
    pub fn iter_gauges(&self) -> impl Iterator<Item = (&str, f64)> + '_ {
        self.gauges.iter().map(|(k, &v)| (k.as_str(), v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absorb_namespaces_counters() {
        let mut c = Counters::new();
        c.add("ops", 7);
        c.add("bytes", 128);
        let mut r = Registry::new();
        r.absorb("workload", &c);
        assert_eq!(r.get("workload.ops"), 7);
        assert_eq!(r.get("workload.bytes"), 128);
        assert_eq!(r.get("workload.missing"), 0);
    }

    #[test]
    fn set_overwrites_snapshot_style() {
        let mut r = Registry::new();
        r.set("a.x", 3);
        r.set("a.x", 10);
        assert_eq!(r.get("a.x"), 10);
        r.gauge("a.depth", 4.0);
        r.gauge("a.depth", 2.0);
        assert!((r.get_gauge("a.depth") - 2.0).abs() < 1e-12);
    }

    #[test]
    fn deltas_advance_baseline_and_skip_quiet_metrics() {
        let mut r = Registry::new();
        r.set("a.x", 5);
        r.set("a.y", 0);
        let d1 = r.deltas();
        assert_eq!(d1, vec![("a.x".to_string(), 5)]);
        // no movement -> empty
        assert!(r.deltas().is_empty());
        r.set("a.x", 8);
        r.set("a.y", 2);
        let mut d2 = r.deltas();
        d2.sort();
        assert_eq!(d2, vec![("a.x".to_string(), 3), ("a.y".to_string(), 2)]);
    }

    #[test]
    fn rel_snapshot_splits_counters_and_gauges() {
        let s = RelStats {
            sent: 10,
            retransmitted: 2,
            peak_buffered: 6,
            rto_ns: 2000.0,
            ..RelStats::default()
        };
        let mut r = Registry::new();
        r.absorb_rel("rel", &s);
        assert_eq!(r.get("rel.sent"), 10);
        assert_eq!(r.get("rel.retransmitted"), 2);
        assert!((r.get_gauge("rel.peak_buffered") - 6.0).abs() < 1e-12);
        assert!((r.get_gauge("rel.rto_ns") - 2000.0).abs() < 1e-12);
    }

    #[test]
    fn refresh_epochs_allow_overwrites_across_ticks() {
        let mut r = Registry::new();
        r.begin_refresh();
        r.set("node0.ops", 1);
        r.gauge("node0.depth", 2.0);
        r.begin_refresh();
        r.set("node0.ops", 5); // same name, next epoch: fine
        r.gauge("node0.depth", 1.0);
        assert_eq!(r.get("node0.ops"), 5);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "duplicate metric registration")]
    fn duplicate_name_within_one_refresh_panics() {
        let mut r = Registry::new();
        r.begin_refresh();
        r.set("node1.dcs.ops", 1);
        r.set("node1.dcs.ops", 2); // two sources on one dotted name
    }

    #[test]
    fn retire_prefix_allows_reregistration_within_one_refresh() {
        // a live topology change retires the old shape's per-slice names
        // and re-registers the new shape's inside the SAME refresh epoch
        let mut r = Registry::new();
        r.begin_refresh();
        r.set("dcs.slice0_served", 10);
        r.gauge("dcs.slice1.depth", 3.0);
        r.set("workload.issued", 7);
        let _ = r.deltas(); // baseline the old names
        let removed = r.retire_prefix("dcs.");
        assert_eq!(removed, 2);
        assert_eq!(r.get("dcs.slice0_served"), 0, "retired counters read as absent");
        // re-registering a retired name in the same epoch must NOT trip
        // the dotted-name guard (this is the re-slicing regression)
        r.set("dcs.slice0_served", 0);
        r.gauge("dcs.slice3.depth", 1.0);
        assert_eq!(r.get("dcs.slice0_served"), 0);
        assert_eq!(r.get("workload.issued"), 7, "other namespaces untouched");
        // the delta baseline was retired too: the re-registered counter
        // reports from scratch, not against the old shape's baseline
        r.begin_refresh();
        r.set("dcs.slice0_served", 4);
        let d = r.deltas();
        assert!(d.contains(&("dcs.slice0_served".to_string(), 4)), "{d:?}");
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "duplicate metric registration")]
    fn duplicate_without_retire_still_panics_after_a_retire_elsewhere() {
        let mut r = Registry::new();
        r.begin_refresh();
        r.set("dcs.pending", 1);
        let _ = r.retire_prefix("fabric."); // unrelated retire
        r.set("dcs.pending", 2); // still a collision
    }

    #[test]
    fn json_dump_has_both_sections() {
        let mut r = Registry::new();
        r.set("m.ops", 3);
        r.gauge("m.q", 1.5);
        let j = r.to_json();
        assert_eq!(j.get("counters").and_then(|c| c.get("m.ops")).and_then(|v| v.as_u64()), Some(3));
        assert_eq!(j.get("gauges").and_then(|g| g.get("m.q")).and_then(|v| v.as_f64()), Some(1.5));
    }
}
