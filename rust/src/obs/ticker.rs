//! Simulated-time telemetry ticker.
//!
//! Emits one JSON-lines record per telemetry interval: counter *deltas*
//! since the previous tick (from the [`Registry`] baseline) plus all
//! current gauge values. The ticker owns no events — hosts call
//! [`Ticker::tick`] opportunistically after each dispatched event, and
//! the due-check runs on simulated time, so enabling telemetry changes
//! neither the event count nor the event order (the obs transparency
//! gate depends on this). Lines are buffered in memory and written to
//! the `--obs-out` path after the run, keeping I/O out of the hot loop.

use crate::sim::time::{Duration, Time};

use super::json::Json;
use super::registry::Registry;

pub struct Ticker {
    every_ps: u64,
    next: u64,
    seq: u64,
    lines: Vec<String>,
}

impl Ticker {
    pub fn new(every: Duration) -> Ticker {
        Ticker {
            every_ps: every.ps().max(1),
            next: 0, // first due tick snapshots the initial state
            seq: 0,
            lines: Vec::new(),
        }
    }

    #[inline]
    pub fn due(&self, now: Time) -> bool {
        now.ps() >= self.next
    }

    /// Snapshot a telemetry record if the interval has elapsed. The host
    /// is expected to have refreshed `reg` (absorbed current counters,
    /// set gauges) before calling. Skips ahead past `now` so a long
    /// event gap yields one record, not a catch-up burst.
    pub fn tick(&mut self, now: Time, reg: &mut Registry) {
        if !self.due(now) {
            return;
        }
        let behind = (now.ps() - self.next) / self.every_ps + 1;
        self.next += behind * self.every_ps;

        let deltas = reg.deltas();
        let mut members = vec![
            ("t_ps".to_string(), Json::u(now.ps())),
            ("seq".to_string(), Json::u(self.seq)),
        ];
        members.push((
            "deltas".to_string(),
            Json::Obj(deltas.into_iter().map(|(k, v)| (k, Json::u(v))).collect()),
        ));
        members.push((
            "gauges".to_string(),
            Json::Obj(reg.iter_gauges().map(|(k, v)| (k.to_string(), Json::f(v))).collect()),
        ));
        self.lines.push(Json::Obj(members).compact());
        self.seq += 1;
    }

    pub fn ticks(&self) -> u64 {
        self.seq
    }

    pub fn lines(&self) -> &[String] {
        &self.lines
    }

    pub fn into_lines(self) -> Vec<String> {
        self.lines
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ticks_at_interval_and_skips_gaps() {
        let mut reg = Registry::new();
        let mut tk = Ticker::new(Duration::from_ns(100));
        reg.set("m.ops", 1);
        tk.tick(Time(0), &mut reg); // due at t=0
        tk.tick(Time(50_000), &mut reg); // 50ns: not due
        assert_eq!(tk.ticks(), 1);
        reg.set("m.ops", 5);
        tk.tick(Time(100_000), &mut reg); // 100ns: due
        assert_eq!(tk.ticks(), 2);
        // long gap: one record, next aligned beyond now
        reg.set("m.ops", 9);
        tk.tick(Time(1_000_000), &mut reg); // 1us
        tk.tick(Time(1_000_001), &mut reg); // not due again
        assert_eq!(tk.ticks(), 3);
    }

    #[test]
    fn lines_carry_deltas_and_gauges() {
        let mut reg = Registry::new();
        let mut tk = Ticker::new(Duration::from_ns(10));
        reg.set("w.completed", 3);
        reg.gauge("w.queue_depth", 2.0);
        tk.tick(Time(0), &mut reg);
        reg.set("w.completed", 10);
        reg.gauge("w.queue_depth", 5.0);
        tk.tick(Time(10_000), &mut reg);
        let lines = tk.lines();
        assert_eq!(lines.len(), 2);
        let first = Json::parse(&lines[0]).unwrap();
        assert_eq!(
            first.get("deltas").and_then(|d| d.get("w.completed")).and_then(|v| v.as_u64()),
            Some(3)
        );
        let second = Json::parse(&lines[1]).unwrap();
        assert_eq!(second.get("seq").and_then(|v| v.as_u64()), Some(1));
        assert_eq!(
            second.get("deltas").and_then(|d| d.get("w.completed")).and_then(|v| v.as_u64()),
            Some(7)
        );
        assert_eq!(
            second.get("gauges").and_then(|g| g.get("w.queue_depth")).and_then(|v| v.as_f64()),
            Some(5.0)
        );
    }
}
