//! The two-node machine model: a ThunderX-1 CPU socket (48 in-order
//! cores, private L1d, shared 16 MiB LLC) talking over the full ECI
//! transport to an FPGA socket running either a plain home-memory node
//! (Table 3 microbenchmarks, symmetric configurations) or the smart
//! memory controller with one of the paper's three operators (§5.4–5.7).
//!
//! Everything observable in the paper's evaluation is produced by running
//! this machine: cores execute [`Workload`] programs op by op; misses
//! travel core → L1 → LLC → [`RemoteAgent`] → VC/link/transaction/phys
//! layers → FPGA service → back. The simulation is execution-driven:
//! response payloads are real bytes (operator results computed by the AOT
//! XLA kernels), so end-to-end data integrity is asserted in tests, not
//! assumed.

pub mod config;

use crate::rustc_hash::FxHashMap as HashMap;

use crate::agents::cache::{Cache, Victim};
use crate::agents::dram::{Dram, MemStore};
use crate::agents::home::{HomeAgent, HomeEffect};
use crate::agents::remote::{RemoteAgent, RemoteEffect};
use crate::dcs::{Dcs, SliceService};
use crate::obs::{Obs, ObsConfig, ObsReport, Registry};
use crate::trace::checker::OnlineChecker;
use crate::memctl::{ComputeRegion, ConfigBlock, FifoServer, KvsService};
use crate::proto::messages::{CohOp, Line, LineAddr, Message, MsgKind, ReqId};
use crate::proto::spec::{generate_home, generate_remote, HomePolicy};
use crate::proto::states::{CacheState, Node};
use crate::proto::transitions::reference_transitions;
use crate::sim::engine::Engine;
use crate::sim::rng::Rng;
use crate::sim::stats::{Counters, Histogram, Meter};
use crate::sim::time::{Duration, Time};
use crate::transport::{Control, Frame, LinkDir, VcId};

pub use config::{map, CpuConfig, MachineConfig};

// ---------------------------------------------------------------------------
// Workloads
// ---------------------------------------------------------------------------

/// One core-visible operation.
#[derive(Clone, Debug)]
pub enum Op {
    Load(LineAddr),
    /// Store `value` into the first 8 bytes of the line (the value is the
    /// observable for data-value litmus tests).
    Store(LineAddr, u64),
    /// Pure compute.
    Think(Duration),
    /// Non-cacheable I/O against the config block.
    IoRead(u64),
    IoWrite(u64, u64),
    Done,
}

/// The experiment workloads (one machine runs one workload at a time).
pub enum Workload {
    /// No cores active (protocol driven externally; tests).
    Idle,
    /// Table 3 throughput: stream remote reads over `lines` lines of the
    /// table region (shared work queue across threads).
    StreamRemote { lines: u64 },
    /// Table 3 latency: core 0 performs `count` dependent reads at random
    /// lines of the table region; other threads idle.
    ChaseRemote { count: u64, region_lines: u64 },
    /// Fig 5/7 FPGA path: consume the result FIFO until the end marker;
    /// `think` models per-result processing on the core.
    FifoConsume { think: Duration },
    /// Fig 5/7 CPU baseline: each core scans its partition of a local
    /// table; `cycles_per_row` of compute per row plus `match_extra`
    /// cycles for rows flagged in `matches` (result materialization).
    LocalScan { rows: u64, cycles_per_row: u64, match_extra: u64, matches: Vec<bool> },
    /// Fig 6 FPGA path: issue `lookups` KVS requests via the request
    /// window (shared queue; each core blocks on its own request).
    KvsRemote { lookups: u64 },
    /// Fig 6 CPU baseline: walk precomputed per-lookup chains in local
    /// memory (`chains[i]` = line addresses of lookup i's dependent
    /// accesses).
    KvsLocal { chains: Vec<Vec<LineAddr>>, lookups: u64 },
    /// Fig 8: core 0 reads result N, then re-reads N-D, N-2D, ... within
    /// a `window` of lines (≈ cache capacity), for N in 0..results.
    ReuseScan { results: u64, stride: u64, window: u64, think: Duration },
    /// Scripted per-core op sequences (litmus tests, symmetric-protocol
    /// exercises, I/O config flows).
    Script { programs: Vec<Vec<Op>> },
}

/// Per-core workload cursor.
#[derive(Clone, Debug, Default)]
struct CoreState {
    done: bool,
    /// FIFO end-marker seen: finish on next step.
    terminate: bool,
    /// a Think to run before the next op
    pending_think: Option<Duration>,
    /// issue time/addr of the outstanding load (latency accounting)
    issued_at: Option<Time>,
    issued_addr: Option<LineAddr>,
    /// LocalScan cursor
    scan_next: u64,
    scan_end: u64,
    /// local KVS chase
    chain: Vec<LineAddr>,
    chain_pos: usize,
    /// ReuseScan state
    reuse_n: u64,
    reuse_k: u64,
    /// remote-chase remaining
    chase_left: u64,
    /// a parked access to re-issue after its fill arrives
    replay: Option<(LineAddr, bool, u64)>,
    /// Script cursor
    script_pos: usize,
}

// ---------------------------------------------------------------------------
// FPGA applications
// ---------------------------------------------------------------------------

/// What runs behind the FPGA's ECI endpoint.
pub enum FpgaApp {
    /// Spec-generated directory controller over FPGA DRAM (full
    /// protocol; Table 3 and the symmetric configurations).
    Memory(HomeAgent),
    /// Sharded directory controller: N address-interleaved slices, each
    /// a serial directory pipeline behind a VC-disciplined ingress FIFO
    /// (see [`crate::dcs`]).
    Dcs(Dcs),
    /// Stateless read-only smart memory controller (§3.4) serving a
    /// result FIFO (SELECT / regex operators).
    Fifo(FifoServer),
    /// KVS pointer-chase engine pool behind the request window;
    /// `requests[i]` = (hops, value line) for request slot i.
    Kvs { svc: KvsService, requests: Vec<(u64, Box<Line>)> },
    /// Addressable recompute-on-read region (§5.7).
    Result { region: ComputeRegion, lines: Vec<Box<Line>> },
}

// ---------------------------------------------------------------------------
// Events
// ---------------------------------------------------------------------------

#[derive(Debug)]
enum Ev {
    /// Core is ready to issue its next op.
    CoreNext(u32),
    /// A local (CPU-homed) DRAM fill completed.
    LocalFill { addr: LineAddr },
    /// Try to drain a link direction's send queue. 0: cpu->fpga.
    KickTx(u8),
    /// Frame arrival at the far end of direction `dir` (boxed: keeps the
    /// heap element small — see DESIGN.md §Perf).
    Arrive { dir: u8, frame: Box<Frame> },
    /// Credit return reaches the sender of direction `dir`.
    CreditRet { dir: u8, vc: VcId },
    /// Ack/nack control frame reaches the sender of direction `dir`.
    Ctl { dir: u8, ctl: Control },
    /// The FPGA finished servicing and enqueues a message toward the CPU.
    FpgaSend(Box<Message>),
    /// Retry servicing dcs slice `s` (its pipeline was busy).
    DcsPoll(u32),
    /// Retransmit-timeout check on direction `dir` (rel links only):
    /// with frames unacked and no ack progress since arming, the sender
    /// rewinds its replay buffers (tail-loss recovery).
    RelRetx(u8),
    /// Delayed-ack flush on direction `dir`'s receiver (rel links
    /// only): ack debt that found no reverse frame to piggyback on goes
    /// out as explicit controls.
    RelAckFlush(u8),
}

// ---------------------------------------------------------------------------
// Reports
// ---------------------------------------------------------------------------

/// Summary of one run.
#[derive(Debug, Clone)]
pub struct Report {
    pub sim_time: Time,
    /// Remote-load latency histogram (ps).
    pub load_lat: Histogram,
    /// Payload bytes delivered to cores from the FPGA node.
    pub remote_bytes: u64,
    /// Results consumed (FIFO pops / KVS lookups / scan matches / reuse reads).
    pub results: u64,
    /// Rows scanned (LocalScan) for scan-rate reporting.
    pub rows_scanned: u64,
    pub counters: Counters,
    pub events: u64,
    pub llc_hits: u64,
    pub llc_misses: u64,
    pub l1_hits: u64,
    pub l1_misses: u64,
    pub fpga_dram_bytes: u64,
    pub cpu_dram_bytes: u64,
    pub link_bytes_to_cpu: u64,
}

impl Report {
    pub fn remote_gib_per_s(&self) -> f64 {
        if self.sim_time.ps() == 0 {
            return 0.0;
        }
        self.remote_bytes as f64 / self.sim_time.as_secs() / (1u64 << 30) as f64
    }
    pub fn results_per_s(&self) -> f64 {
        if self.sim_time.ps() == 0 {
            return 0.0;
        }
        self.results as f64 / self.sim_time.as_secs()
    }
    pub fn rows_per_s(&self) -> f64 {
        if self.sim_time.ps() == 0 {
            return 0.0;
        }
        self.rows_scanned as f64 / self.sim_time.as_secs()
    }
    pub fn mean_load_ns(&self) -> f64 {
        self.load_lat.mean() / 1000.0
    }
    pub fn llc_miss_rate(&self) -> f64 {
        let t = self.llc_hits + self.llc_misses;
        if t == 0 {
            0.0
        } else {
            self.llc_misses as f64 / t as f64
        }
    }
}

// ---------------------------------------------------------------------------
// Machine
// ---------------------------------------------------------------------------

pub struct Machine {
    pub cfg: MachineConfig,
    eng: Engine<Ev>,
    rng: Rng,

    // CPU socket
    threads: usize,
    cores: Vec<CoreState>,
    l1s: Vec<Cache>,
    llc: Cache,
    remote: RemoteAgent,
    cpu_dram: Dram,
    pub cpu_mem: MemStore,
    /// Parked cores per line (local and remote misses, MSHR-merged).
    waiters: HashMap<LineAddr, Vec<u32>>,
    /// Outstanding local fills.
    local_pending: HashMap<LineAddr, ()>,
    /// Outstanding I/O requests.
    io_pending: HashMap<ReqId, u32>,
    next_io_id: u32,

    // link: dir 0 = cpu->fpga, dir 1 = fpga->cpu
    to_fpga: LinkDir,
    to_cpu: LinkDir,
    /// A `RelRetx` event is already scheduled per direction (dedup).
    retx_pending: [bool; 2],
    /// Ack progress seen when the pending retx was armed.
    retx_seen_acked: [u64; 2],
    /// A `RelAckFlush` event is already scheduled per direction.
    ack_flush_pending: [bool; 2],
    /// Reused receive buffers for `arrive` (a selective-repeat delivery
    /// can release several frames at once; a fresh Vec per arrival is
    /// pure churn — see DESIGN.md §Perf).
    rx_frames: Vec<Frame>,
    rx_ctls: Vec<Control>,

    // FPGA socket
    pub app: FpgaApp,
    pub config_block: ConfigBlock,
    fpga_dram: Dram,
    pub fpga_mem: MemStore,
    /// Link-frame sequence counter for the framed dcs ingress.
    dcs_seq: u64,
    /// High-water mark of messages held at the dcs ingress (queued +
    /// staged). With credits held until slice service this is bounded
    /// by the credit budget of the VCs in use; see `tests/machine_credits.rs`.
    dcs_ingress_peak: usize,

    // workload
    workload: Workload,
    shared_cursor: u64,
    shared_limit: u64,

    // measurement
    pub counters: Counters,
    load_lat: Histogram,
    remote_meter: Meter,
    results: u64,
    rows_scanned: u64,
    /// Payload integrity checker: called on every remote fill
    /// (addr, data) — installed by tests/harnesses.
    pub verify_fill: Option<Box<dyn FnMut(LineAddr, &Line)>>,
    /// Message tap for the trace toolkit: called for every delivered
    /// message with (time, to_fpga, message).
    pub tap: Option<Box<dyn FnMut(Time, bool, &Message)>>,
    /// Online protocol checker ([`crate::trace::checker`]): observes
    /// every delivered message; its accept/violation counts surface in
    /// [`Machine::report`] and the telemetry registry.
    pub checker: Option<OnlineChecker>,
    /// Runtime observability (telemetry ticker + metric registry);
    /// passive — never schedules events. Attach with
    /// [`Machine::attach_obs`], collect with [`Machine::finish_obs`].
    obs: Option<Obs>,
}

impl Machine {
    /// Build a machine with the given FPGA application and memories.
    pub fn new(cfg: MachineConfig, app: FpgaApp, fpga_mem: MemStore, cpu_mem: MemStore) -> Machine {
        let mut seed_rng = Rng::new(cfg.seed);
        let spec = reference_transitions();
        let remote_rules = generate_remote(&spec);
        let cpu = cfg.cpu;
        Machine {
            cfg,
            eng: Engine::new(),
            rng: seed_rng.fork(1),
            threads: 0,
            cores: vec![CoreState::default(); cpu.cores],
            l1s: (0..cpu.cores).map(|_| Cache::new(cpu.l1_bytes, cpu.l1_ways)).collect(),
            llc: Cache::new(cpu.llc_bytes, cpu.llc_ways),
            remote: RemoteAgent::new(
                Node::Remote,
                remote_rules,
                map::FPGA_BASE,
                u64::MAX - map::FPGA_BASE.0,
            ),
            cpu_dram: Dram::new(cpu.dram),
            cpu_mem,
            waiters: HashMap::default(),
            local_pending: HashMap::default(),
            io_pending: HashMap::default(),
            next_io_id: 1 << 20,
            to_fpga: match cfg.rel {
                Some(rc) => LinkDir::new_rel(cfg.link, Node::Remote, seed_rng.fork(2), rc),
                None => LinkDir::new(cfg.link, Node::Remote, seed_rng.fork(2)),
            },
            to_cpu: match cfg.rel {
                // the reverse direction draws an independent fault stream
                Some(mut rc) => {
                    rc.faults.seed = rc.faults.seed.wrapping_add(1);
                    LinkDir::new_rel(cfg.link, Node::Home, seed_rng.fork(3), rc)
                }
                None => LinkDir::new(cfg.link, Node::Home, seed_rng.fork(3)),
            },
            retx_pending: [false; 2],
            retx_seen_acked: [0; 2],
            ack_flush_pending: [false; 2],
            rx_frames: Vec::new(),
            rx_ctls: Vec::new(),
            app,
            config_block: ConfigBlock::new(),
            fpga_dram: Dram::new(cfg.fpga_dram),
            fpga_mem,
            dcs_seq: 0,
            dcs_ingress_peak: 0,
            workload: Workload::Idle,
            shared_cursor: 0,
            shared_limit: 0,
            counters: Counters::new(),
            load_lat: Histogram::new(),
            remote_meter: Meter::new(),
            results: 0,
            rows_scanned: 0,
            verify_fill: None,
            tap: None,
            checker: None,
            obs: None,
        }
    }

    /// Attach runtime observability. On the machine only the ticker and
    /// registry are meaningful (span tracing lives in the workload
    /// engine, where the request lifecycle is visible end to end).
    pub fn attach_obs(&mut self, ocfg: &ObsConfig) {
        self.obs = ocfg.enabled().then(|| Obs::new(ocfg));
    }

    /// Install the online protocol checker on the delivery tap point.
    pub fn attach_checker(&mut self, checker: OnlineChecker) {
        self.checker = Some(checker);
    }

    /// A machine whose FPGA is a plain (full-protocol) home memory node.
    pub fn memory_node(cfg: MachineConfig, fpga_mem: MemStore, cpu_mem: MemStore) -> Machine {
        let home = HomeAgent::new(
            generate_home(&reference_transitions(), HomePolicy::default()),
            HomePolicy::default(),
            None,
        );
        Machine::new(cfg, FpgaApp::Memory(home), fpga_mem, cpu_mem)
    }

    /// A machine whose FPGA runs the sharded directory controller:
    /// `slices` address-interleaved directory pipelines, each costing
    /// `home_proc` of occupancy per message (the monolithic
    /// [`Machine::memory_node`] services messages with the same latency
    /// but unbounded concurrency — the dcs is the finite-throughput
    /// model).
    pub fn dcs_node(
        cfg: MachineConfig,
        slices: usize,
        fpga_mem: MemStore,
        cpu_mem: MemStore,
    ) -> Machine {
        let dcs = Dcs::with_reference_rules(cfg.dcs_config(slices));
        Machine::new(cfg, FpgaApp::Dcs(dcs), fpga_mem, cpu_mem)
    }

    /// The *cached* sliced machine: the sharded directory controller
    /// with a slice-local partition of the machine's home-cache budget
    /// on every slice (`MachineConfig::dcs_cached_config`) — the
    /// symmetric configuration as a first-class machine. Protocol
    /// outcomes are identical to [`Machine::memory_node`] (pinned by the
    /// litmus suite in `rust/tests/litmus.rs`); repeat shared reads are
    /// served slice-locally instead of from FPGA DRAM.
    pub fn dcs_cached_node(
        cfg: MachineConfig,
        slices: usize,
        fpga_mem: MemStore,
        cpu_mem: MemStore,
    ) -> Machine {
        let dcs = Dcs::with_reference_rules(cfg.dcs_cached_config(slices));
        Machine::new(cfg, FpgaApp::Dcs(dcs), fpga_mem, cpu_mem)
    }

    /// Install a workload and the number of active threads (cores).
    pub fn set_workload(&mut self, workload: Workload, threads: usize) {
        assert!(threads <= self.cores.len() && threads > 0);
        self.threads = threads;
        for st in &mut self.cores {
            *st = CoreState::default();
        }
        if let Workload::LocalScan { rows, .. } = &workload {
            let per = rows / threads as u64;
            for c in 0..threads {
                self.cores[c].scan_next = c as u64 * per;
                self.cores[c].scan_end =
                    if c == threads - 1 { *rows } else { (c as u64 + 1) * per };
            }
        }
        if let Workload::ChaseRemote { count, .. } = &workload {
            self.cores[0].chase_left = *count;
        }
        if let Workload::Script { programs } = &workload {
            assert!(programs.len() >= threads, "need one program per thread");
        }
        self.shared_cursor = 0;
        self.shared_limit = match &workload {
            Workload::StreamRemote { lines } => *lines,
            Workload::KvsRemote { lookups } => *lookups,
            Workload::KvsLocal { lookups, .. } => *lookups,
            _ => u64::MAX,
        };
        self.workload = workload;
    }

    /// Run the installed workload to completion.
    pub fn run(&mut self) -> Report {
        for c in 0..self.threads as u32 {
            self.eng.schedule(Duration::ZERO, Ev::CoreNext(c));
        }
        let mut active = self.threads;
        while active > 0 {
            let Some((_, ev)) = self.eng.pop() else {
                panic!(
                    "event queue drained with {active} cores outstanding — deadlock \
                     (waiters: {:?})",
                    self.waiters.keys().take(8).collect::<Vec<_>>()
                );
            };
            match ev {
                Ev::CoreNext(c) => {
                    if self.step_core(c) {
                        active -= 1;
                    }
                }
                other => self.dispatch(other),
            }
            self.obs_tick();
        }
        self.report()
    }

    pub fn now(&self) -> Time {
        self.eng.now()
    }

    /// Settle the machine after [`Machine::run`]: process every event
    /// still queued (in-flight writebacks, replay retransmissions, ack
    /// and credit returns) so the protocol state is final. Used by
    /// tests that compare end states — e.g. the loss-transparency gate,
    /// where FPGA memory must be bit-identical with fault injection on
    /// vs off. Terminates because retransmit timers re-arm only while
    /// frames stay unacked, and stale duplicates re-ack.
    pub fn drain(&mut self) {
        while let Some((_, ev)) = self.eng.pop() {
            match ev {
                // cores are done; their wakeups are no-ops
                Ev::CoreNext(_) => {}
                other => self.dispatch(other),
            }
            self.obs_tick();
        }
    }

    /// Emit a telemetry record if one is due (piggybacks on the event
    /// loop — obs never schedules events of its own, so runs with the
    /// ticker on and off are event-for-event identical).
    fn obs_tick(&mut self) {
        let now = self.eng.now();
        if !self.obs.as_ref().is_some_and(|o| o.tick_due(now)) {
            return;
        }
        let mut obs = self.obs.take().expect("checked above");
        self.refresh_registry(&mut obs.registry);
        obs.tick(now);
        self.obs = Some(obs);
    }

    /// Snapshot every counter surface and live queue depth into the
    /// unified registry (dotted names; see DESIGN.md §obs).
    fn refresh_registry(&self, reg: &mut Registry) {
        reg.begin_refresh();
        reg.absorb("machine", &self.counters);
        reg.set("machine.results", self.results);
        reg.set("machine.rows_scanned", self.rows_scanned);
        reg.set("machine.events", self.eng.dispatched);
        reg.set("machine.llc_hits", self.llc.hits);
        reg.set("machine.llc_misses", self.llc.misses);
        if let FpgaApp::Dcs(dcs) = &self.app {
            reg.absorb("dcs", &dcs.counters());
            dcs.observe_gauges("dcs", reg);
            reg.gauge("dcs.ingress_peak", self.dcs_ingress_peak as f64);
        }
        reg.gauge("link.to_fpga.queued", self.to_fpga.mux.pending() as f64);
        reg.gauge("link.to_cpu.queued", self.to_cpu.mux.pending() as f64);
        reg.gauge("link.to_fpga.unacked", self.to_fpga.rel_unacked() as f64);
        reg.gauge("link.to_cpu.unacked", self.to_cpu.rel_unacked() as f64);
        if let Some(rel) = self.to_fpga.rel.as_ref() {
            let mut s = rel.stats();
            if let Some(r2) = self.to_cpu.rel.as_ref() {
                s.merge(&r2.stats());
            }
            reg.absorb_rel("rel", &s);
        }
        if let Some(ck) = self.checker.as_ref() {
            reg.set("checker.messages_checked", ck.messages_checked);
            reg.set("checker.violations", ck.violations.len() as u64);
        }
    }

    /// Take the observability report (final registry refresh + closing
    /// telemetry record). Panics if no obs was attached.
    pub fn finish_obs(&mut self) -> ObsReport {
        let mut obs = self.obs.take().expect("attach obs with attach_obs first");
        self.refresh_registry(&mut obs.registry);
        obs.tick(self.eng.now());
        obs.finish()
    }

    pub fn report(&self) -> Report {
        let mut counters = self.counters.clone();
        counters.add("dcs_ingress_peak", self.dcs_ingress_peak as u64);
        if let FpgaApp::Dcs(dcs) = &self.app {
            for (k, v) in dcs.counters().iter() {
                counters.add(k, v);
            }
        }
        if let Some(rel) = self.to_fpga.rel.as_ref() {
            let mut s = rel.stats();
            if let Some(r2) = self.to_cpu.rel.as_ref() {
                s.merge(&r2.stats());
            }
            s.add_to(&mut counters);
        }
        if let Some(ck) = self.checker.as_ref() {
            counters.add("checker_messages", ck.messages_checked);
            counters.add("checker_violations", ck.violations.len() as u64);
        }
        Report {
            sim_time: self.eng.now(),
            load_lat: self.load_lat.clone(),
            remote_bytes: self.remote_meter.total,
            results: self.results,
            rows_scanned: self.rows_scanned,
            counters,
            events: self.eng.dispatched,
            llc_hits: self.llc.hits,
            llc_misses: self.llc.misses,
            l1_hits: self.l1s.iter().map(|c| c.hits).sum(),
            l1_misses: self.l1s.iter().map(|c| c.misses).sum(),
            fpga_dram_bytes: self.fpga_dram.bytes_moved(),
            cpu_dram_bytes: self.cpu_dram.bytes_moved(),
            link_bytes_to_cpu: self.to_cpu.phys.bytes_sent(),
        }
    }

    // -- workload program ----------------------------------------------------

    /// Produce core `c`'s next op.
    fn next_op(&mut self, c: u32) -> Op {
        if let Some(d) = self.cores[c as usize].pending_think.take() {
            return Op::Think(d);
        }
        let clock = self.cfg.cpu.clock;
        match &mut self.workload {
            Workload::Idle => Op::Done,
            Workload::StreamRemote { .. } => {
                if self.shared_cursor >= self.shared_limit {
                    return Op::Done;
                }
                let i = self.shared_cursor;
                self.shared_cursor += 1;
                Op::Load(LineAddr(map::TABLE_BASE.0 + i))
            }
            Workload::ChaseRemote { region_lines, .. } => {
                if c != 0 {
                    return Op::Done;
                }
                if self.cores[0].chase_left == 0 {
                    return Op::Done;
                }
                self.cores[0].chase_left -= 1;
                let off = self.rng.below(*region_lines);
                Op::Load(LineAddr(map::TABLE_BASE.0 + off))
            }
            Workload::FifoConsume { think } => {
                let think = *think;
                let i = self.shared_cursor;
                self.shared_cursor += 1;
                self.cores[c as usize].pending_think =
                    (think > Duration::ZERO).then_some(think);
                Op::Load(LineAddr(map::FIFO_BASE.0 + (i % map::FIFO_LINES)))
            }
            Workload::LocalScan { cycles_per_row, match_extra, matches, .. } => {
                let st = &mut self.cores[c as usize];
                if st.scan_next >= st.scan_end {
                    return Op::Done;
                }
                let row = st.scan_next;
                st.scan_next += 1;
                let mut cycles = *cycles_per_row;
                let hit = matches.get(row as usize).copied().unwrap_or(false);
                if hit {
                    cycles += *match_extra;
                }
                st.pending_think = Some(clock.cycles(cycles));
                self.rows_scanned += 1;
                if hit {
                    self.results += 1;
                }
                Op::Load(LineAddr(row))
            }
            Workload::KvsRemote { .. } => {
                if self.shared_cursor >= self.shared_limit {
                    return Op::Done;
                }
                let i = self.shared_cursor;
                self.shared_cursor += 1;
                Op::Load(LineAddr(map::KVS_WIN_BASE.0 + (i % map::KVS_WIN_LINES)))
            }
            Workload::KvsLocal { chains, .. } => {
                let st = &mut self.cores[c as usize];
                if st.chain_pos < st.chain.len() {
                    let a = st.chain[st.chain_pos];
                    st.chain_pos += 1;
                    return Op::Load(a);
                }
                if self.shared_cursor >= self.shared_limit {
                    return Op::Done;
                }
                let i = self.shared_cursor;
                self.shared_cursor += 1;
                self.results += 1;
                let chain = chains[(i % chains.len() as u64) as usize].clone();
                let st = &mut self.cores[c as usize];
                st.chain = chain;
                st.chain_pos = 1;
                Op::Load(st.chain[0])
            }
            Workload::Script { programs } => {
                let st = &mut self.cores[c as usize];
                let prog = &programs[c as usize];
                if st.script_pos >= prog.len() {
                    return Op::Done;
                }
                let op = prog[st.script_pos].clone();
                st.script_pos += 1;
                op
            }
            Workload::ReuseScan { results, stride, window, think } => {
                if c != 0 {
                    return Op::Done;
                }
                let think = *think;
                let st = &mut self.cores[0];
                if st.reuse_n >= *results {
                    return Op::Done;
                }
                st.pending_think = (think > Duration::ZERO).then_some(think);
                // every read (hit or miss) is one application-level use
                self.results += 1;
                // re-read phase: N-1 - k*stride while within the window
                if st.reuse_n > 0 && *stride > 0 {
                    let k = st.reuse_k + 1;
                    let back = k * *stride;
                    if back <= *window && back < st.reuse_n {
                        st.reuse_k = k;
                        let n = (st.reuse_n - 1) - back;
                        return Op::Load(LineAddr(map::RESULT_BASE.0 + n));
                    }
                }
                // leading read
                st.reuse_k = 0;
                let n = st.reuse_n;
                st.reuse_n += 1;
                Op::Load(LineAddr(map::RESULT_BASE.0 + n))
            }
        }
    }

    /// Advance core `c`; returns true when the core finishes.
    fn step_core(&mut self, c: u32) -> bool {
        let st = &mut self.cores[c as usize];
        if st.done {
            return false;
        }
        if st.terminate {
            st.done = true;
            return true;
        }
        if let Some((addr, write, val)) = st.replay.take() {
            self.access_val(c, addr, write, val);
            return false;
        }
        match self.next_op(c) {
            Op::Done => {
                self.cores[c as usize].done = true;
                true
            }
            Op::Think(d) => {
                self.eng.schedule(d, Ev::CoreNext(c));
                false
            }
            Op::Load(addr) => {
                self.access(c, addr, false);
                false
            }
            Op::Store(addr, val) => {
                self.access_val(c, addr, true, val);
                false
            }
            Op::IoRead(off) => {
                self.send_io(c, MsgKind::IoRead { offset: off });
                false
            }
            Op::IoWrite(off, val) => {
                self.send_io(c, MsgKind::IoWrite { offset: off, value: val });
                false
            }
        }
    }

    fn send_io(&mut self, c: u32, kind: MsgKind) {
        let id = ReqId(self.next_io_id);
        self.next_io_id += 1;
        self.io_pending.insert(id, c);
        self.to_fpga.send(Message {
            id,
            from: Node::Remote,
            kind,
            addr: map::CONFIG_BASE,
            payload: None,
        });
        self.kick(0);
    }

    /// Core memory access through L1 -> LLC -> (DRAM | remote agent).
    fn access(&mut self, c: u32, addr: LineAddr, write: bool) {
        self.access_val(c, addr, write, 0)
    }

    fn access_val(&mut self, c: u32, addr: LineAddr, write: bool, val: u64) {
        let cpu = self.cfg.cpu;
        // L1
        if let Some(e) = self.l1s[c as usize].lookup(addr) {
            if !write || e.state.writable() {
                if write {
                    e.state = CacheState::M;
                    e.data[0..8].copy_from_slice(&val.to_le_bytes());
                    if let Some(le) = self.llc.lookup(addr) {
                        le.state = CacheState::M;
                        le.data[0..8].copy_from_slice(&val.to_le_bytes());
                    }
                }
                self.eng.schedule(cpu.l1_hit, Ev::CoreNext(c));
                return;
            }
        }
        // LLC
        let llc_state = self.llc.state_of(addr);
        if llc_state.readable() && (!write || llc_state.writable()) {
            let data = {
                let e = self.llc.lookup(addr).unwrap();
                if write {
                    e.state = CacheState::M;
                    e.data[0..8].copy_from_slice(&val.to_le_bytes());
                }
                e.data.clone()
            };
            let state = if write { CacheState::M } else { CacheState::S };
            self.fill_l1(c, addr, state, data);
            self.eng.schedule(cpu.l1_hit + cpu.llc_hit, Ev::CoreNext(c));
            return;
        }
        // miss
        self.llc.misses += 1;
        self.cores[c as usize].issued_at = Some(self.eng.now());
        self.cores[c as usize].issued_addr = Some(addr);
        if write {
            // the access replays (and completes) once the fill arrives
            self.cores[c as usize].replay = Some((addr, true, val));
        }
        if map::is_fpga(addr) {
            let lat = cpu.l1_hit + cpu.llc_hit + self.cfg.remote_proc;
            let (_acc, fx) = self.remote.local_access(addr, write, &mut self.llc);
            self.waiters.entry(addr).or_default().push(c);
            let mut kicked = false;
            for e in fx {
                match e {
                    RemoteEffect::Send(m) => {
                        self.to_fpga.send(m);
                        kicked = true;
                    }
                    RemoteEffect::Stalled | RemoteEffect::Filled { .. } => {}
                    RemoteEffect::ForeignVictim(v) => self.local_writeback(v),
                }
            }
            if kicked {
                let at = self.eng.now() + lat;
                self.eng.schedule_at(at, Ev::KickTx(0));
            }
        } else {
            if self.local_pending.contains_key(&addr) {
                self.waiters.entry(addr).or_default().push(c);
                return;
            }
            self.local_pending.insert(addr, ());
            self.waiters.entry(addr).or_default().push(c);
            let start = self.eng.now() + cpu.l1_hit + cpu.llc_hit;
            let done = self.cpu_dram.read(start, addr);
            self.eng.schedule_at(done, Ev::LocalFill { addr });
        }
    }

    fn fill_l1(&mut self, c: u32, addr: LineAddr, state: CacheState, data: Box<Line>) {
        if let Some(v) = self.l1s[c as usize].insert(addr, state, data) {
            if v.state == CacheState::M {
                self.llc.set_state(v.addr, CacheState::M);
            }
        }
    }

    /// A CPU-homed line fell out of the LLC (or a foreign victim from the
    /// remote agent's fills).
    fn local_writeback(&mut self, v: Victim) {
        for l1 in &mut self.l1s {
            l1.remove(v.addr); // inclusive back-invalidate
        }
        if v.state == CacheState::M && self.cpu_mem.contains(v.addr) {
            self.cpu_mem.write_line(v.addr, &v.data);
            let now = self.eng.now();
            self.cpu_dram.write(now, v.addr);
        }
    }

    fn handle_llc_victim(&mut self, v: Victim) {
        if map::is_fpga(v.addr) {
            let fx = self.remote.downgrade_evicted(v);
            let mut kicked = false;
            for e in fx {
                if let RemoteEffect::Send(m) = e {
                    self.to_fpga.send(m);
                    kicked = true;
                }
            }
            if kicked {
                self.kick(0);
            }
        } else {
            self.local_writeback(v);
        }
    }

    // -- event dispatch --------------------------------------------------------

    fn dispatch(&mut self, ev: Ev) {
        match ev {
            Ev::CoreNext(_) => unreachable!("handled in run()"),
            Ev::LocalFill { addr } => {
                self.local_pending.remove(&addr);
                let data = Box::new(self.cpu_mem.read_line(addr));
                if let Some(v) = self.llc.insert(addr, CacheState::E, data.clone()) {
                    self.handle_llc_victim(v);
                }
                self.wake(addr, data);
            }
            Ev::KickTx(dir) => self.kick(dir),
            Ev::Arrive { dir, frame } => self.arrive(dir, frame),
            Ev::CreditRet { dir, vc } => {
                let link = if dir == 0 { &mut self.to_fpga } else { &mut self.to_cpu };
                link.credit_return(vc);
                self.kick(dir);
            }
            Ev::Ctl { dir, ctl } => {
                let now = self.eng.now();
                let link = if dir == 0 { &mut self.to_fpga } else { &mut self.to_cpu };
                link.on_control(now, ctl);
                self.kick(dir);
            }
            Ev::FpgaSend(msg) => {
                self.to_cpu.send(*msg);
                self.kick(1);
            }
            Ev::DcsPoll(s) => self.pump_dcs_slice(s as usize),
            Ev::RelRetx(dir) => {
                self.retx_pending[dir as usize] = false;
                let link = if dir == 0 { &mut self.to_fpga } else { &mut self.to_cpu };
                if link.rel_unacked() > 0 {
                    if link.rel_acked() == self.retx_seen_acked[dir as usize] {
                        // no ack progress for a full RTO: rewind and replay
                        link.rel_force_replay();
                    }
                    // pump the resends; kick re-arms the timer while
                    // anything stays unacked
                    self.kick(dir);
                }
            }
            Ev::RelAckFlush(dir) => {
                self.ack_flush_pending[dir as usize] = false;
                let ctrl = self.cfg.ctrl_latency;
                loop {
                    let link = if dir == 0 { &mut self.to_fpga } else { &mut self.to_cpu };
                    let Some((vc, seq)) = link.rel_take_piggy_ack() else { break };
                    self.eng.schedule(ctrl, Ev::Ctl { dir, ctl: Control::VcAck(vc, seq) });
                }
            }
        }
    }

    /// Drain one dcs slice as far as its pipeline allows right now,
    /// scheduling the produced messages and a re-poll if it is busy.
    fn pump_dcs_slice(&mut self, s: usize) {
        let now = self.eng.now();
        let FpgaApp::Dcs(dcs) = &mut self.app else { return };
        loop {
            match dcs.service_one(s, now, &mut self.fpga_mem) {
                None => break,
                Some(SliceService::Busy(t)) => {
                    self.eng.schedule_at(t, Ev::DcsPoll(s as u32));
                    break;
                }
                Some(SliceService::Done(ready, vc, _, fx)) => {
                    // the slice consumed the message: only now does its
                    // link-buffer slot free up (credits are held until
                    // slice service, not frame arrival — the same
                    // semantics as the workload engine's framed ingress)
                    self.eng.schedule_at(
                        ready + self.cfg.ctrl_latency,
                        Ev::CreditRet { dir: 0, vc },
                    );
                    for e in fx {
                        match e {
                            HomeEffect::Respond { msg, from_ram } => {
                                let at = if from_ram {
                                    self.fpga_dram.read(ready, msg.addr)
                                } else {
                                    ready
                                };
                                self.eng.schedule_at(at, Ev::FpgaSend(Box::new(msg)));
                            }
                            HomeEffect::Fwd { msg } => {
                                self.eng.schedule_at(ready, Ev::FpgaSend(Box::new(msg)));
                            }
                            HomeEffect::RamWrite { addr } => {
                                self.fpga_dram.write(ready, addr);
                            }
                            HomeEffect::LocalDone { .. } => {}
                        }
                    }
                }
            }
        }
    }

    /// Drain a direction's transmit queue onto the wire. On rel links
    /// the launched frames may be swallowed by the fault injector (no
    /// arrival is scheduled — replay recovers them), outgoing frames
    /// piggyback the opposite direction's cumulative acks, and a
    /// retransmit timer is armed while frames stay unacked.
    fn kick(&mut self, dir: u8) {
        let now = self.eng.now();
        let (link, other) = if dir == 0 {
            (&mut self.to_fpga, &mut self.to_cpu)
        } else {
            (&mut self.to_cpu, &mut self.to_fpga)
        };
        // This sender and the opposite direction's receiver share a
        // node: its ack debt rides our frames' ack envelope. Steal debt
        // only when a frame will actually launch — otherwise leave it
        // for the delayed-ack flush.
        if link.rel.is_some() && link.can_launch() {
            if let Some(a) = other.rel_take_piggy_ack() {
                link.stage_piggy_ack(a);
            }
        }
        while let Some((arrival, frame)) = link.try_launch(now) {
            if frame.lost {
                continue;
            }
            self.eng.schedule_at(arrival, Ev::Arrive { dir, frame: Box::new(frame) });
        }
        self.arm_retx(dir);
    }

    /// Arm the retransmit timer for `dir` if frames are unacked and no
    /// check is pending.
    fn arm_retx(&mut self, dir: u8) {
        let link = if dir == 0 { &self.to_fpga } else { &self.to_cpu };
        let Some(rto) = link.rel_rto() else { return };
        if link.rel_unacked() == 0 || self.retx_pending[dir as usize] {
            return;
        }
        self.retx_seen_acked[dir as usize] = link.rel_acked();
        self.retx_pending[dir as usize] = true;
        self.eng.schedule(rto, Ev::RelRetx(dir));
    }

    /// Arm the delayed-ack flush for `dir`'s receiver when it carries
    /// unflushed cumulative-ack debt.
    fn arm_ack_flush(&mut self, dir: u8) {
        let link = if dir == 0 { &self.to_fpga } else { &self.to_cpu };
        if self.ack_flush_pending[dir as usize] || !link.rel_has_ack_debt() {
            return;
        }
        self.ack_flush_pending[dir as usize] = true;
        self.eng.schedule(crate::transport::rel::ACK_FLUSH_DELAY, Ev::RelAckFlush(dir));
    }

    /// Frame arrival at the receiving end of `dir`.
    fn arrive(&mut self, dir: u8, frame: Box<Frame>) {
        let now = self.eng.now();
        // A piggybacked cumulative ack belongs to the *opposite*
        // direction's sender, which lives at this receiving node.
        if let Some((avc, seq)) = frame.ack {
            let other = if dir == 0 { &mut self.to_cpu } else { &mut self.to_fpga };
            other.on_control(now, Control::VcAck(avc, seq));
        }
        // A selective-repeat link may release several frames at once (a
        // hole-filling retransmission frees its buffered successors);
        // go-back-N and plain links deliver at most one.
        let mut delivered = std::mem::take(&mut self.rx_frames);
        let mut ctls = std::mem::take(&mut self.rx_ctls);
        let link = if dir == 0 { &mut self.to_fpga } else { &mut self.to_cpu };
        link.receive(*frame, &mut delivered, &mut ctls);
        for c in ctls.drain(..) {
            self.eng.schedule_at(now + self.cfg.ctrl_latency, Ev::Ctl { dir, ctl: c });
        }
        self.rx_ctls = ctls;
        // ack debt accrued by this delivery is piggybacked by the next
        // reverse-direction launch or flushed explicitly after a delay
        self.arm_ack_flush(dir);
        for f in delivered.drain(..) {
            let vc = f.vc;
            let msg = f.msg;
            if let Some(tap) = self.tap.as_mut() {
                tap(now, dir == 0, &msg);
            }
            if let Some(ck) = self.checker.as_mut() {
                ck.observe(now, &msg);
            }
            // Receiver consumed the frame: its buffer slot flows back —
            // with one exception. A coherence message bound for the
            // sliced directory occupies its slot until the owning slice
            // *services* it; `pump_dcs_slice` returns that credit at
            // `SliceService::Done`. (I/O messages sink at the config
            // block and free up here.)
            let defer_credit = dir == 0
                && matches!(self.app, FpgaApp::Dcs(_))
                && matches!(msg.kind, MsgKind::CohReq { .. } | MsgKind::CohRsp { .. });
            if !defer_credit {
                self.eng.schedule_at(now + self.cfg.ctrl_latency, Ev::CreditRet { dir, vc });
            }
            if dir == 0 {
                self.fpga_receive(msg);
            } else {
                self.cpu_receive(msg);
            }
        }
        self.rx_frames = delivered;
    }

    /// CPU socket receives a message from the FPGA.
    fn cpu_receive(&mut self, msg: Message) {
        match &msg.kind {
            MsgKind::IoReadRsp { .. } | MsgKind::IoWriteAck => {
                if let Some(c) = self.io_pending.remove(&msg.id) {
                    self.eng.schedule(Duration::from_ns(1), Ev::CoreNext(c));
                }
                return;
            }
            _ => {}
        }
        let addr = msg.addr;
        let payload = msg.payload.clone();
        let fx = self.remote.on_message(msg, &mut self.llc);
        let mut filled = false;
        let mut kicked = false;
        for e in fx {
            match e {
                RemoteEffect::Send(m) => {
                    self.to_fpga.send(m);
                    kicked = true;
                }
                RemoteEffect::Filled { addr: a } if a == addr => filled = true,
                RemoteEffect::Filled { .. } => {}
                RemoteEffect::Stalled => {}
                RemoteEffect::ForeignVictim(v) => self.local_writeback(v),
            }
        }
        if kicked {
            self.kick(0);
        }
        if filled {
            let data = payload.unwrap_or_else(|| Box::new([0u8; 128]));
            if let Some(vf) = self.verify_fill.as_mut() {
                vf(addr, &data);
            }
            self.remote_meter.add(self.eng.now(), 128);
            self.wake(addr, data);
        }
    }

    /// Wake every core parked on `addr`.
    fn wake(&mut self, addr: LineAddr, data: Box<Line>) {
        let cpu = self.cfg.cpu;
        let Some(cores) = self.waiters.remove(&addr) else { return };
        let is_marker = data[0] == 0xFF && data[..8].iter().all(|&b| b == 0xFF);
        for c in cores {
            self.fill_l1(c, addr, CacheState::S, data.clone());
            let st = &mut self.cores[c as usize];
            if let (Some(t0), Some(a)) = (st.issued_at.take(), st.issued_addr.take()) {
                if a == addr {
                    let d = self.eng.now().since(t0);
                    self.load_lat.record(d.ps());
                }
            }
            if matches!(self.workload, Workload::FifoConsume { .. }) && is_marker {
                self.counters.inc("end_marker_seen");
                self.cores[c as usize].terminate = true;
                self.eng.schedule(Duration::ZERO, Ev::CoreNext(c));
                continue;
            }
            match &self.workload {
                Workload::FifoConsume { .. } | Workload::KvsRemote { .. } => {
                    self.results += 1;
                }
                _ => {}
            }
            self.eng.schedule(cpu.l1_hit, Ev::CoreNext(c));
        }
    }

    /// FPGA socket receives a message from the CPU.
    fn fpga_receive(&mut self, msg: Message) {
        let now = self.eng.now();
        let proc = self.cfg.home_proc;
        match &msg.kind {
            MsgKind::IoRead { offset } => {
                let v = self.config_block.read(*offset);
                let rsp = Message {
                    id: msg.id,
                    from: Node::Home,
                    kind: MsgKind::IoReadRsp { offset: *offset, value: v },
                    addr: msg.addr,
                    payload: None,
                };
                self.eng.schedule_at(now + proc, Ev::FpgaSend(Box::new(rsp)));
                return;
            }
            MsgKind::IoWrite { offset, value } => {
                self.config_block.write(*offset, *value);
                let rsp = Message {
                    id: msg.id,
                    from: Node::Home,
                    kind: MsgKind::IoWriteAck,
                    addr: msg.addr,
                    payload: None,
                };
                self.eng.schedule_at(now + proc, Ev::FpgaSend(Box::new(rsp)));
                return;
            }
            _ => {}
        }

        if let FpgaApp::Dcs(dcs) = &mut self.app {
            // hand the message to the framed dcs ingress (staging it
            // into a cross-slice batch when `ingress_batch > 1`), then
            // drain whatever that slice's pipeline can service right now
            let f = Frame::new(self.dcs_seq, msg);
            self.dcs_seq += 1;
            let s = dcs.enqueue_frame(now, f);
            self.dcs_ingress_peak = self.dcs_ingress_peak.max(dcs.pending());
            self.pump_dcs_slice(s);
            return;
        }

        match &mut self.app {
            FpgaApp::Memory(home) => {
                let fx = home.on_message(msg, &mut self.fpga_mem);
                for e in fx {
                    match e {
                        HomeEffect::Respond { msg, from_ram } => {
                            let ready = if from_ram {
                                self.fpga_dram.read(now + proc, msg.addr)
                            } else {
                                now + proc
                            };
                            self.eng.schedule_at(ready, Ev::FpgaSend(Box::new(msg)));
                        }
                        HomeEffect::Fwd { msg } => {
                            self.eng.schedule_at(now + proc, Ev::FpgaSend(Box::new(msg)));
                        }
                        HomeEffect::RamWrite { addr } => {
                            self.fpga_dram.write(now, addr);
                        }
                        HomeEffect::LocalDone { .. } => {}
                    }
                }
            }
            FpgaApp::Fifo(fifo) => match &msg.kind {
                MsgKind::CohReq { op: CohOp::ReadShared } => {
                    self.counters.inc("fifo_reads");
                    let (ready, line) = match fifo.pop(now + proc) {
                        Some((t, l)) => (t, l),
                        None => (now + proc, FifoServer::end_marker()),
                    };
                    let rsp = Message::coh_rsp(msg.id, Node::Home, CohOp::ReadShared, msg.addr, false, Some(line));
                    self.eng.schedule_at(ready.max(now + proc), Ev::FpgaSend(Box::new(rsp)));
                }
                MsgKind::CohReq { op: CohOp::VolDowngradeI } => {
                    // stateless home: silently ignored (§3.4)
                    self.counters.inc("vol_downgrades_ignored");
                }
                k => panic!("stateless FIFO home cannot handle {k:?}"),
            },
            FpgaApp::Kvs { svc, requests } => match &msg.kind {
                MsgKind::CohReq { op: CohOp::ReadShared } => {
                    let slot = map::kvs_slot(msg.addr).expect("KVS request outside window");
                    let (hops, value) = requests[(slot as usize) % requests.len()].clone();
                    let ready = svc.submit(now + proc, hops, &mut self.fpga_dram);
                    let rsp = Message::coh_rsp(msg.id, Node::Home, CohOp::ReadShared, msg.addr, false, Some(value));
                    self.eng.schedule_at(ready, Ev::FpgaSend(Box::new(rsp)));
                }
                MsgKind::CohReq { op: CohOp::VolDowngradeI } => {
                    self.counters.inc("vol_downgrades_ignored");
                }
                k => panic!("KVS home cannot handle {k:?}"),
            },
            FpgaApp::Result { region, lines } => match &msg.kind {
                MsgKind::CohReq { op: CohOp::ReadShared } => {
                    let slot = map::result_slot(msg.addr).expect("read outside result region");
                    let line = lines[(slot as usize) % lines.len()].clone();
                    let ready = region.submit(now + proc, &mut self.fpga_dram, msg.addr);
                    let rsp = Message::coh_rsp(msg.id, Node::Home, CohOp::ReadShared, msg.addr, false, Some(line));
                    self.eng.schedule_at(ready, Ev::FpgaSend(Box::new(rsp)));
                }
                MsgKind::CohReq { op: CohOp::VolDowngradeI } => {
                    self.counters.inc("vol_downgrades_ignored");
                }
                k => panic!("result-region home cannot handle {k:?}"),
            },
            FpgaApp::Dcs(_) => unreachable!("dcs traffic handled above"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_mem() -> (MemStore, MemStore) {
        let fpga = MemStore::new(map::TABLE_BASE, 4 << 20);
        let cpu = MemStore::new(LineAddr(0), 4 << 20);
        (fpga, cpu)
    }

    #[test]
    fn remote_stream_delivers_correct_data() {
        let cfg = MachineConfig::test_small();
        let (mut fpga, cpu) = small_mem();
        // distinctive pattern per line
        for i in 0..1024u64 {
            let mut l = [0u8; 128];
            l[0..8].copy_from_slice(&(i * 7 + 3).to_le_bytes());
            fpga.write_line(LineAddr(map::TABLE_BASE.0 + i), &l);
        }
        let mut m = Machine::memory_node(cfg, fpga, cpu);
        let bad = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
        {
            let bad2 = std::sync::Arc::clone(&bad);
            m.verify_fill = Some(Box::new(move |addr, data| {
                let i = addr.0 - map::TABLE_BASE.0;
                let got = u64::from_le_bytes(data[0..8].try_into().unwrap());
                if got != i * 7 + 3 {
                    bad2.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            }));
        }
        m.set_workload(Workload::StreamRemote { lines: 1024 }, 4);
        let r = m.run();
        assert_eq!(bad.load(std::sync::atomic::Ordering::Relaxed), 0, "payload corruption");
        assert_eq!(r.remote_bytes, 1024 * 128);
        assert!(r.load_lat.count() >= 1024);
        assert!(r.sim_time > Time(0));
    }

    #[test]
    fn remote_chase_latency_in_expected_band() {
        let cfg = MachineConfig::enzian_eci();
        let (fpga, cpu) = small_mem();
        let mut m = Machine::memory_node(cfg, fpga, cpu);
        m.set_workload(Workload::ChaseRemote { count: 2_000, region_lines: 16 << 10 }, 1);
        let r = m.run();
        let mean = r.mean_load_ns();
        // dependent remote load on the ECI config: roughly 250-450 ns
        assert!((250.0..450.0).contains(&mean), "remote load {mean} ns");
    }

    #[test]
    fn native_config_is_faster_than_eci() {
        let run = |cfg: MachineConfig| {
            let (fpga, cpu) = small_mem();
            let mut m = Machine::memory_node(cfg, fpga, cpu);
            m.set_workload(Workload::ChaseRemote { count: 1_000, region_lines: 16 << 10 }, 1);
            m.run().mean_load_ns()
        };
        let eci = run(MachineConfig::enzian_eci());
        let native = run(MachineConfig::native_2socket());
        assert!(native < eci, "native {native} ns !< eci {eci} ns");
        let ratio = eci / native;
        assert!((1.5..3.5).contains(&ratio), "latency ratio {ratio}");
    }

    #[test]
    fn stream_throughput_scales_with_threads() {
        let thr = |threads: usize| {
            let cfg = MachineConfig::enzian_eci();
            let (fpga, cpu) = small_mem();
            let mut m = Machine::memory_node(cfg, fpga, cpu);
            m.set_workload(Workload::StreamRemote { lines: 20_000 }, threads);
            m.run().remote_gib_per_s()
        };
        let t1 = thr(1);
        let t8 = thr(8);
        let t32 = thr(32);
        assert!(t8 > 3.0 * t1, "8 threads {t8} vs 1 {t1}");
        assert!(t32 >= t8 * 0.9, "32 threads {t32} vs 8 {t8}");
    }

    #[test]
    fn local_scan_is_dram_bandwidth_bound() {
        let mut cfg = MachineConfig::test_small();
        cfg.cpu.cores = 16;
        let (fpga, mut cpu) = small_mem();
        for i in 0..(4 << 20) / 128 {
            cpu.write_line(LineAddr(i as u64), &[1u8; 128]);
        }
        let mut m = Machine::memory_node(cfg, fpga, cpu);
        let rows = 30_000u64;
        m.set_workload(
            Workload::LocalScan { rows, cycles_per_row: 8, match_extra: 4, matches: vec![false; rows as usize] },
            16,
        );
        let r = m.run();
        let gbps = r.rows_per_s() * 128.0 / 1e9;
        // 2ch DDR4-2133 = 34 GB/s peak; blocking in-order cores with one
        // outstanding miss each land within ~2x of peak
        assert!(gbps > 14.0 && gbps < 34.2, "local scan {gbps} GB/s");
    }

    #[test]
    fn dcs_node_delivers_correct_data_across_slices() {
        let cfg = MachineConfig::test_small();
        let (mut fpga, cpu) = small_mem();
        for i in 0..1024u64 {
            let mut l = [0u8; 128];
            l[0..8].copy_from_slice(&(i * 13 + 1).to_le_bytes());
            fpga.write_line(LineAddr(map::TABLE_BASE.0 + i), &l);
        }
        let mut m = Machine::dcs_node(cfg, 4, fpga, cpu);
        let bad = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
        {
            let bad2 = std::sync::Arc::clone(&bad);
            m.verify_fill = Some(Box::new(move |addr, data| {
                let i = addr.0 - map::TABLE_BASE.0;
                let got = u64::from_le_bytes(data[0..8].try_into().unwrap());
                if got != i * 13 + 1 {
                    bad2.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            }));
        }
        m.set_workload(Workload::StreamRemote { lines: 1024 }, 4);
        let r = m.run();
        assert_eq!(bad.load(std::sync::atomic::Ordering::Relaxed), 0, "payload corruption");
        assert_eq!(r.remote_bytes, 1024 * 128);
        assert!(r.sim_time > Time(0));
    }

    #[test]
    fn dcs_cached_node_serves_repeat_reads_from_home_cache() {
        let cfg = MachineConfig::test_small();
        let (mut fpga, cpu) = small_mem();
        for i in 0..512u64 {
            let mut l = [0u8; 128];
            l[0..8].copy_from_slice(&(i * 3 + 5).to_le_bytes());
            fpga.write_line(LineAddr(map::TABLE_BASE.0 + i), &l);
        }
        let mut m = Machine::dcs_cached_node(cfg, 2, fpga, cpu);
        let bad = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
        {
            let bad2 = std::sync::Arc::clone(&bad);
            m.verify_fill = Some(Box::new(move |addr, data| {
                let i = addr.0 - map::TABLE_BASE.0;
                let got = u64::from_le_bytes(data[0..8].try_into().unwrap());
                if got != i * 3 + 5 {
                    bad2.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            }));
        }
        m.set_workload(Workload::StreamRemote { lines: 512 }, 4);
        let r = m.run();
        assert_eq!(bad.load(std::sync::atomic::Ordering::Relaxed), 0, "payload corruption");
        assert_eq!(r.remote_bytes, 512 * 128);
        // every line was granted once and filled the home cache
        assert_eq!(r.counters.get("home_cache_fill"), 512, "{:?}", r.counters);
    }

    #[test]
    fn dcs_cached_node_cuts_dependent_read_latency() {
        // dependent random reads over a region several times the (small)
        // LLC: re-reads keep falling out of the LLC and go back to the
        // directory, where the cached node serves them slice-locally
        // instead of paying the FPGA-DRAM round trip
        let run = |cached: bool| {
            let cfg = MachineConfig::test_small(); // 256 KiB LLC
            let (fpga, cpu) = small_mem();
            let mut m = if cached {
                Machine::dcs_cached_node(cfg, 2, fpga, cpu)
            } else {
                Machine::dcs_node(cfg, 2, fpga, cpu)
            };
            // 8192 lines = 1 MiB: heavily over-subscribes the LLC (so
            // re-reads keep going back to the directory) while fitting
            // the 1 MiB home-cache budget entirely
            m.set_workload(Workload::ChaseRemote { count: 10_000, region_lines: 8 << 10 }, 1);
            let r = m.run();
            (r.mean_load_ns(), r.counters.get("home_cache_hit"))
        };
        let (plain_ns, plain_hits) = run(false);
        let (cached_ns, cached_hits) = run(true);
        assert_eq!(plain_hits, 0);
        assert!(cached_hits > 0, "random re-touches must hit the home cache");
        assert!(
            cached_ns < plain_ns,
            "cached {cached_ns} ns must beat cache-less {plain_ns} ns"
        );
    }

    #[test]
    fn dcs_single_outstanding_latency_matches_memory_node() {
        // one outstanding load at a time: the slice pipeline never
        // queues, so the sharded directory must look like the monolith
        let run = |dcs: Option<usize>| {
            let cfg = MachineConfig::enzian_eci();
            let (fpga, cpu) = small_mem();
            let mut m = match dcs {
                Some(n) => Machine::dcs_node(cfg, n, fpga, cpu),
                None => Machine::memory_node(cfg, fpga, cpu),
            };
            m.set_workload(Workload::ChaseRemote { count: 1_000, region_lines: 8 << 10 }, 1);
            m.run().mean_load_ns()
        };
        let mono = run(None);
        let sliced = run(Some(2));
        let ratio = sliced / mono;
        assert!((0.9..1.1).contains(&ratio), "dcs {sliced} ns vs memory {mono} ns");
    }

    #[test]
    fn io_round_trip_reaches_config_block() {
        let cfg = MachineConfig::test_small();
        let (fpga, cpu) = small_mem();
        let mut m = Machine::memory_node(cfg, fpga, cpu);
        // drive I/O through the protocol manually via a tiny workload:
        m.config_block.set_select_params(1.5, 2.5);
        let (x, y) = m.config_block.select_params();
        assert_eq!((x, y), (1.5, 2.5));
    }
}
