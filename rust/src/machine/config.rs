//! Machine configurations: the Enzian + ECI testbed of §5.1 and the
//! native 2-socket ThunderX-1 baseline of Table 3.
//!
//! Calibration discipline (DESIGN.md §1): these are *physical* parameters
//! (clocks, geometries, per-hop pipeline depths, credit budgets); the
//! paper's headline numbers are emergent, not hard-coded. The two
//! interconnect parameter sets differ exactly where the hardware differs:
//! the FPGA's protocol engines run at 300 MHz fabric clock (deep
//! pipeline, higher per-hop latency) and its transaction-layer buffers
//! are block-RAM-bounded (fewer credits), while the native socket's
//! coherence engines run at CPU speed.

use crate::agents::dram::DramConfig;
use crate::dcs::DcsConfig;
use crate::sim::time::{Clock, Duration};
use crate::transport::{LinkConfig, RelConfig};

/// CPU-socket parameters (Marvell ThunderX-1, §5.1).
#[derive(Clone, Copy, Debug)]
pub struct CpuConfig {
    pub cores: usize,
    pub clock: Clock,
    pub l1_bytes: usize,
    pub l1_ways: usize,
    /// L1 hit (load-to-use).
    pub l1_hit: Duration,
    pub llc_bytes: usize,
    pub llc_ways: usize,
    /// LLC hit beyond L1.
    pub llc_hit: Duration,
    pub dram: DramConfig,
}

impl CpuConfig {
    pub fn thunderx1() -> CpuConfig {
        CpuConfig {
            cores: 48,
            clock: Clock::from_ghz(2.0),
            l1_bytes: 32 << 10,
            l1_ways: 4,
            l1_hit: Duration::from_ns(2), // 4 cycles
            llc_bytes: 16 << 20,
            llc_ways: 16,
            llc_hit: Duration::from_ns(13), // ~26 cycles
            dram: DramConfig::cpu_enzian(),
        }
    }
}

/// Full two-node machine configuration.
#[derive(Clone, Copy, Debug)]
pub struct MachineConfig {
    pub cpu: CpuConfig,
    pub link: LinkConfig,
    pub fpga_dram: DramConfig,
    /// Per-message processing latency in the home node's protocol engine
    /// (directory lookup + datapath dispatch).
    pub home_proc: Duration,
    /// Per-message processing latency in the CPU-side coherence engine.
    pub remote_proc: Duration,
    /// Reverse-path latency of credit returns / ack control frames.
    pub ctrl_latency: Duration,
    /// Total home-cache capacity of the symmetric sliced configuration
    /// (split across slices by [`MachineConfig::dcs_cached_config`];
    /// BRAM-bounded on the FPGA).
    pub home_cache_bytes: usize,
    /// Home-cache associativity.
    pub home_cache_ways: usize,
    /// Framed-ingress batch size at the dcs (1 = batching off): how many
    /// same-slice frames one delivery may coalesce into a single
    /// VC-disciplined hand-off.
    pub ingress_batch: usize,
    /// Reliable-lossy link extension ([`crate::transport::rel`]):
    /// `Some` runs both link directions with per-VC sequencing/replay
    /// and the configured deterministic fault injector (the reverse
    /// direction derives its injector seed from the forward one).
    /// `None` (default) = the seed's perfect wire.
    pub rel: Option<RelConfig>,
    pub seed: u64,
}

impl MachineConfig {
    /// Enzian with the ECI stack on the FPGA (§5.1).
    pub fn enzian_eci() -> MachineConfig {
        let mut link = LinkConfig::eci();
        // FPGA transaction-layer buffers: BRAM-bounded; 9 credits per
        // coherence VC (x2 parities = 18 outstanding line requests).
        link.credits_per_vc = 9;
        link.phys.pipeline_latency = Duration::from_ns(80);
        MachineConfig {
            cpu: CpuConfig::thunderx1(),
            link,
            fpga_dram: DramConfig::fpga_enzian(),
            // ~12 fabric cycles at 300 MHz through the directory +
            // dispatch pipeline
            home_proc: Duration::from_ns(40),
            remote_proc: Duration::from_ns(10),
            ctrl_latency: Duration::from_ns(80),
            home_cache_bytes: crate::dcs::DEFAULT_HOME_CACHE_BYTES,
            home_cache_ways: crate::dcs::DEFAULT_HOME_CACHE_WAYS,
            ingress_batch: 1,
            rel: None,
            seed: 0xEC1,
        }
    }

    /// Native 2-socket ThunderX-1 server (Table 3 baseline): same CPU,
    /// CPU-speed coherence engines on both ends, deeper credit budget.
    pub fn native_2socket() -> MachineConfig {
        let mut link = LinkConfig::native();
        link.credits_per_vc = 6;
        link.phys.pipeline_latency = Duration::from_ns(8);
        MachineConfig {
            cpu: CpuConfig::thunderx1(),
            // the second socket's memory is the same CPU DRAM config
            fpga_dram: DramConfig::cpu_enzian(),
            link,
            home_proc: Duration::from_ns(5),
            remote_proc: Duration::from_ns(5),
            ctrl_latency: Duration::from_ns(8),
            home_cache_bytes: crate::dcs::DEFAULT_HOME_CACHE_BYTES,
            home_cache_ways: crate::dcs::DEFAULT_HOME_CACHE_WAYS,
            ingress_batch: 1,
            rel: None,
            seed: 0xEC1,
        }
    }

    /// Small configuration for fast unit/integration tests: 4 cores,
    /// small caches, low DRAM latency variance.
    pub fn test_small() -> MachineConfig {
        let mut c = MachineConfig::enzian_eci();
        c.cpu.cores = 4;
        c.cpu.l1_bytes = 8 << 10;
        c.cpu.llc_bytes = 256 << 10;
        c
    }

    /// The sliced-directory shape this machine implies: `slices`
    /// address-interleaved pipelines, each costing `home_proc` of
    /// occupancy per message. Single source of truth for
    /// [`crate::machine::Machine::dcs_node`] and for the `workload`
    /// subsystem's scenario nodes, so a scenario run and a machine run
    /// against the same configuration exercise the same directory.
    pub fn dcs_config(&self, slices: usize) -> DcsConfig {
        DcsConfig::new(slices).with_slice_proc(self.home_proc).with_batch(self.ingress_batch)
    }

    /// The *cached* sliced-directory shape: same pipelines, plus a
    /// slice-local partition of the machine's home-cache budget per
    /// slice — the symmetric configuration, sharded. Used by
    /// [`crate::machine::Machine::dcs_cached_node`] and the workload
    /// subsystem's `home_cached` runs.
    pub fn dcs_cached_config(&self, slices: usize) -> DcsConfig {
        self.dcs_config(slices).with_home_cache(self.home_cache_bytes, self.home_cache_ways)
    }
}

/// Line-address windows of the simulated physical address map.
pub mod map {
    use crate::proto::messages::LineAddr;

    /// CPU-homed DRAM starts at line 0.
    pub const CPU_BASE: LineAddr = LineAddr(0);
    /// FPGA-homed region base (byte 2^34).
    pub const FPGA_BASE: LineAddr = LineAddr(1 << 27);
    /// Table region (operator input data) within the FPGA region.
    pub const TABLE_BASE: LineAddr = LineAddr(FPGA_BASE.0 + (1 << 10));
    /// Result-FIFO window: any read here pops the next result.
    pub const FIFO_BASE: LineAddr = LineAddr(FPGA_BASE.0 + (1 << 25));
    pub const FIFO_LINES: u64 = 1 << 24;
    /// KVS request window: line offset encodes the request index.
    pub const KVS_WIN_BASE: LineAddr = LineAddr(FPGA_BASE.0 + (3 << 25));
    pub const KVS_WIN_LINES: u64 = 1 << 24;
    /// Addressable result region (§5.7): line offset = result index.
    pub const RESULT_BASE: LineAddr = LineAddr(FPGA_BASE.0 + (5 << 25));
    pub const RESULT_LINES: u64 = 1 << 24;
    /// Config block (I/O space, one line window).
    pub const CONFIG_BASE: LineAddr = LineAddr(FPGA_BASE.0 + (7 << 25));

    pub fn is_fpga(addr: LineAddr) -> bool {
        addr >= FPGA_BASE
    }
    pub fn fifo_slot(addr: LineAddr) -> Option<u64> {
        (addr >= FIFO_BASE && addr.0 < FIFO_BASE.0 + FIFO_LINES).then(|| addr.0 - FIFO_BASE.0)
    }
    pub fn kvs_slot(addr: LineAddr) -> Option<u64> {
        (addr >= KVS_WIN_BASE && addr.0 < KVS_WIN_BASE.0 + KVS_WIN_LINES)
            .then(|| addr.0 - KVS_WIN_BASE.0)
    }
    pub fn result_slot(addr: LineAddr) -> Option<u64> {
        (addr >= RESULT_BASE && addr.0 < RESULT_BASE.0 + RESULT_LINES)
            .then(|| addr.0 - RESULT_BASE.0)
    }
}
