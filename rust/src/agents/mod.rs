//! Coherence agents and machine-component models: the spec-driven home
//! (directory) and remote (caching) agents, the set-associative cache
//! arrays, and the DDR4 channel model. The CPU-socket composition (cores +
//! L1s + LLC) lives in [`crate::machine`].

pub mod cache;
pub mod dram;
pub mod home;
pub mod remote;

pub use cache::{Cache, Entry, Victim};
pub use dram::{Dram, DramConfig, MemStore};
pub use home::{HomeAgent, HomeEffect};
pub use remote::{Access, RemoteAgent, RemoteEffect};
