//! DDR4 channel model.
//!
//! First-order DRAM behaviour, which is all the paper's curves depend on:
//!
//! * **Bandwidth**: each channel moves `8 B × MT/s` peak; a line transfer
//!   occupies the channel's data bus serially (the 512-bit controller
//!   interface the paper cites limits one pointer-chase engine to
//!   ~640 MB/s at ~100 ns latency — §5.3.2).
//! * **Latency**: a fixed controller+array access time, lower on a
//!   row-buffer hit (sequential streams) than on a row miss (random
//!   access, the pointer-chasing case).
//! * **Channel interleave** by line address.
//!
//! The model is execution-agnostic: it returns completion times; data
//! itself lives in [`MemStore`].

use crate::proto::messages::{Line, LineAddr, LINE_BYTES};
use crate::sim::bw::SerialPort;
use crate::sim::time::{Duration, Time};

/// Configuration of a socket's DRAM subsystem.
#[derive(Clone, Copy, Debug)]
pub struct DramConfig {
    pub channels: u32,
    /// Mega-transfers per second (DDR4-2133 -> 2133).
    pub mt_per_s: u32,
    /// Row-buffer hit latency (controller + CAS).
    pub hit_latency: Duration,
    /// Row miss latency (precharge + activate + CAS) — the paper's
    /// ~100 ns random-access number.
    pub miss_latency: Duration,
    /// Row size in bytes (for hit/miss classification).
    pub row_bytes: u64,
}

impl DramConfig {
    /// Enzian CPU memory: 2 channels DDR4-2133 used (of 4 fitted) — §5.1.
    pub fn cpu_enzian() -> DramConfig {
        DramConfig {
            channels: 2,
            mt_per_s: 2133,
            hit_latency: Duration::from_ns(45),
            miss_latency: Duration::from_ns(100),
            row_bytes: 8192,
        }
    }
    /// Enzian FPGA memory: 2 channels DDR4-2400 used (of 4 fitted) — §5.1.
    pub fn fpga_enzian() -> DramConfig {
        DramConfig {
            channels: 2,
            mt_per_s: 2400,
            hit_latency: Duration::from_ns(45),
            miss_latency: Duration::from_ns(100),
            row_bytes: 8192,
        }
    }
    /// Peak bytes/second over all channels.
    pub fn peak_bytes_per_sec(&self) -> f64 {
        self.channels as f64 * self.mt_per_s as f64 * 1e6 * 8.0
    }
}

/// One socket's DRAM: per-channel occupancy + row-buffer tracking.
pub struct Dram {
    pub cfg: DramConfig,
    ports: Vec<SerialPort>,
    open_row: Vec<Option<u64>>,
    /// Stats.
    pub reads: u64,
    pub writes: u64,
    pub row_hits: u64,
}

impl Dram {
    pub fn new(cfg: DramConfig) -> Dram {
        let per_ch = cfg.peak_bytes_per_sec() / cfg.channels as f64;
        Dram {
            cfg,
            ports: (0..cfg.channels).map(|_| SerialPort::new(per_ch, Duration::ZERO)).collect(),
            open_row: vec![None; cfg.channels as usize],
            reads: 0,
            writes: 0,
            row_hits: 0,
        }
    }

    #[inline]
    fn channel_of(&self, addr: LineAddr) -> usize {
        (addr.0 % self.cfg.channels as u64) as usize
    }

    /// Completion time of a line access starting at `now`.
    fn access(&mut self, now: Time, addr: LineAddr) -> Time {
        let ch = self.channel_of(addr);
        let row = addr.byte_addr() / self.cfg.row_bytes;
        let lat = if self.open_row[ch] == Some(row) {
            self.row_hits += 1;
            self.cfg.hit_latency
        } else {
            self.open_row[ch] = Some(row);
            self.cfg.miss_latency
        };
        // array access, then the burst occupies the channel bus
        self.ports[ch].occupy(now + lat, LINE_BYTES as u64)
    }

    /// Read a line; returns completion time.
    pub fn read(&mut self, now: Time, addr: LineAddr) -> Time {
        self.reads += 1;
        self.access(now, addr)
    }

    /// Write a line; returns completion time.
    pub fn write(&mut self, now: Time, addr: LineAddr) -> Time {
        self.writes += 1;
        self.access(now, addr)
    }

    /// Aggregate utilization (mean over channels).
    pub fn utilization(&self, now: Time) -> f64 {
        self.ports.iter().map(|p| p.utilization(now)).sum::<f64>() / self.ports.len() as f64
    }

    pub fn bytes_moved(&self) -> u64 {
        self.ports.iter().map(|p| p.bytes).sum()
    }
}

/// Flat backing store holding actual bytes (execution-driven simulation:
/// operators compute on real data).
#[derive(Clone)]
pub struct MemStore {
    base: LineAddr,
    data: Vec<u8>,
}

impl MemStore {
    /// A store of `bytes` bytes, based at line address `base`.
    pub fn new(base: LineAddr, bytes: usize) -> MemStore {
        let bytes = bytes.div_ceil(LINE_BYTES) * LINE_BYTES;
        MemStore { base, data: vec![0; bytes] }
    }

    pub fn base(&self) -> LineAddr {
        self.base
    }
    pub fn len_lines(&self) -> u64 {
        (self.data.len() / LINE_BYTES) as u64
    }
    pub fn contains(&self, addr: LineAddr) -> bool {
        addr >= self.base && addr.0 < self.base.0 + self.len_lines()
    }

    #[inline]
    fn offset(&self, addr: LineAddr) -> usize {
        assert!(self.contains(addr), "address {addr} outside store");
        ((addr.0 - self.base.0) as usize) * LINE_BYTES
    }

    pub fn read_line(&self, addr: LineAddr) -> Line {
        let o = self.offset(addr);
        let mut line = [0u8; LINE_BYTES];
        line.copy_from_slice(&self.data[o..o + LINE_BYTES]);
        line
    }

    pub fn write_line(&mut self, addr: LineAddr, line: &Line) {
        let o = self.offset(addr);
        self.data[o..o + LINE_BYTES].copy_from_slice(line);
    }

    /// Raw slice access for bulk loading (workload generators).
    pub fn bytes_mut(&mut self) -> &mut [u8] {
        &mut self.data
    }
    pub fn bytes(&self) -> &[u8] {
        &self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_bandwidth_matches_config() {
        let cfg = DramConfig::cpu_enzian();
        // 2 x 2133 MT/s x 8 B = 34.1 GB/s
        assert!((cfg.peak_bytes_per_sec() - 34.128e9).abs() < 1e7);
        let f = DramConfig::fpga_enzian();
        assert!((f.peak_bytes_per_sec() - 38.4e9).abs() < 1e7);
    }

    #[test]
    fn sequential_reads_hit_rows_and_stream_at_bandwidth() {
        let mut d = Dram::new(DramConfig::fpga_enzian());
        let n = 10_000u64;
        // open-loop stream: all requests queued up front (bandwidth-bound,
        // unlike the dependent chain of the random test below)
        let mut done = Time(0);
        for i in 0..n {
            done = done.max(d.read(Time(0), LineAddr(i * 2))); // stay on channel 0
        }
        // channel-0 bandwidth = 2400 MT/s x 8 B = 19.2 GB/s
        let gbps = (n * 128) as f64 / done.as_secs() / 1e9;
        assert!(gbps > 15.0 && gbps < 19.3, "sequential stream {gbps} GB/s");
        assert!(d.row_hits > n * 9 / 10, "row hits {} of {n}", d.row_hits);
    }

    #[test]
    fn random_reads_pay_miss_latency() {
        let mut d = Dram::new(DramConfig::fpga_enzian());
        // dependent chain of far-apart rows on one channel
        let mut t = Time(0);
        let n = 1000u64;
        for i in 0..n {
            t = d.read(t, LineAddr(i * 2 * 1024)); // new row every time
        }
        let per_access = t.as_ns() / n as f64;
        // ~100 ns miss + ~6.7 ns burst
        assert!(per_access > 100.0 && per_access < 115.0, "random access {per_access} ns");
        assert_eq!(d.row_hits, 0);
        // One dependent 128 B line per ~107 ns. (The paper's ~640 MB/s
        // per-engine bound additionally counts the 512 b = 64 B controller
        // granule — two serialized granule accesses per 128 B entry —
        // which the KVS operator model applies; see operators::kvs.)
        let mbps = (n * 128) as f64 / t.as_secs() / 1e6;
        assert!(mbps > 1000.0 && mbps < 1300.0, "chase rate {mbps} MB/s");
    }

    #[test]
    fn channels_interleave_by_line() {
        let d = Dram::new(DramConfig::cpu_enzian());
        assert_ne!(d.channel_of(LineAddr(0)), d.channel_of(LineAddr(1)));
        assert_eq!(d.channel_of(LineAddr(0)), d.channel_of(LineAddr(2)));
    }

    #[test]
    fn memstore_round_trip() {
        let mut m = MemStore::new(LineAddr(100), 1024);
        assert_eq!(m.len_lines(), 8);
        assert!(m.contains(LineAddr(100)));
        assert!(m.contains(LineAddr(107)));
        assert!(!m.contains(LineAddr(108)));
        let mut line = [0u8; LINE_BYTES];
        line[0] = 0xAB;
        line[127] = 0xCD;
        m.write_line(LineAddr(103), &line);
        assert_eq!(m.read_line(LineAddr(103)), line);
        assert_eq!(m.read_line(LineAddr(104))[0], 0);
    }

    #[test]
    #[should_panic]
    fn memstore_out_of_range_panics() {
        let m = MemStore::new(LineAddr(0), 128);
        m.read_line(LineAddr(1));
    }
}
