//! Set-associative cache array with MESI line states and true-LRU
//! replacement. Used for the per-core L1d models and the shared 16 MiB
//! 16-way LLC of the ThunderX-1 socket model, and (optionally) for a
//! home-side cache on the FPGA in symmetric configurations.
//!
//! The array is execution-driven: entries carry the actual 128-byte line
//! so results delivered through the coherence protocol are checkable
//! against the CPU baselines.

use crate::proto::messages::{Line, LineAddr};
use crate::proto::states::CacheState;

/// One resident line.
#[derive(Clone, Debug)]
pub struct Entry {
    pub addr: LineAddr,
    pub state: CacheState,
    pub data: Box<Line>,
    lru: u64,
}

/// Geometry + replacement state.
pub struct Cache {
    sets: usize,
    ways: usize,
    /// Line-address stride between consecutive sets. 1 for a normal
    /// cache; N for a cache fronting one slice of an N-way
    /// address-interleaved directory (the slice only ever sees lines with
    /// `addr % N == i`, so indexing by `addr / N` keeps every set
    /// reachable instead of wasting all but every N-th).
    interleave: u64,
    entries: Vec<Option<Entry>>, // sets x ways
    tick: u64,
    /// Stats.
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// What `insert` displaced.
#[derive(Debug)]
pub struct Victim {
    pub addr: LineAddr,
    pub state: CacheState,
    pub data: Box<Line>,
}

impl Cache {
    /// `capacity_bytes` / 128-byte lines / `ways` associativity. Sets must
    /// come out a power of two.
    pub fn new(capacity_bytes: usize, ways: usize) -> Cache {
        Cache::interleaved(capacity_bytes, ways, 1)
    }

    /// A cache indexing by `addr / interleave`: the shape used for the
    /// per-slice home caches of [`crate::dcs`] (interleave = slice
    /// count), where plain modulo indexing would leave most sets
    /// unreachable.
    pub fn interleaved(capacity_bytes: usize, ways: usize, interleave: u64) -> Cache {
        assert!(interleave >= 1, "interleave must be >= 1");
        let lines = capacity_bytes / crate::proto::messages::LINE_BYTES;
        assert!(lines >= ways && lines % ways == 0);
        let sets = lines / ways;
        assert!(sets.is_power_of_two(), "sets must be a power of two, got {sets}");
        Cache {
            sets,
            ways,
            interleave,
            entries: vec![None; sets * ways],
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn sets(&self) -> usize {
        self.sets
    }
    pub fn ways(&self) -> usize {
        self.ways
    }
    pub fn capacity_lines(&self) -> usize {
        self.sets * self.ways
    }

    #[inline]
    fn set_of(&self, addr: LineAddr) -> usize {
        let index = if self.interleave == 1 { addr.0 } else { addr.0 / self.interleave };
        (index as usize) & (self.sets - 1)
    }
    #[inline]
    fn slot_range(&self, set: usize) -> std::ops::Range<usize> {
        set * self.ways..(set + 1) * self.ways
    }

    /// Look up a line, updating LRU on hit.
    pub fn lookup(&mut self, addr: LineAddr) -> Option<&mut Entry> {
        self.tick += 1;
        let tick = self.tick;
        let range = self.slot_range(self.set_of(addr));
        let slot = self.entries[range.clone()]
            .iter()
            .position(|e| e.as_ref().is_some_and(|e| e.addr == addr));
        match slot {
            Some(i) => {
                self.hits += 1;
                let e = self.entries[range.start + i].as_mut().unwrap();
                e.lru = tick;
                Some(e)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Peek without touching LRU or stats.
    pub fn peek(&self, addr: LineAddr) -> Option<&Entry> {
        let range = self.slot_range(self.set_of(addr));
        self.entries[range].iter().flatten().find(|e| e.addr == addr)
    }

    /// Current state (I if absent).
    pub fn state_of(&self, addr: LineAddr) -> CacheState {
        self.peek(addr).map(|e| e.state).unwrap_or(CacheState::I)
    }

    /// Insert (or overwrite) a line; returns the evicted victim if the
    /// set was full. The victim is chosen LRU among the set.
    pub fn insert(&mut self, addr: LineAddr, state: CacheState, data: Box<Line>) -> Option<Victim> {
        assert_ne!(state, CacheState::I, "inserting an invalid line");
        self.tick += 1;
        let tick = self.tick;
        let range = self.slot_range(self.set_of(addr));

        // overwrite in place if resident
        for i in range.clone() {
            if self.entries[i].as_ref().is_some_and(|e| e.addr == addr) {
                let e = self.entries[i].as_mut().unwrap();
                e.state = state;
                e.data = data;
                e.lru = tick;
                return None;
            }
        }
        // free slot?
        for i in range.clone() {
            if self.entries[i].is_none() {
                self.entries[i] = Some(Entry { addr, state, data, lru: tick });
                return None;
            }
        }
        // evict LRU
        let lru_slot = range
            .clone()
            .min_by_key(|&i| self.entries[i].as_ref().unwrap().lru)
            .unwrap();
        let old = self.entries[lru_slot].take().unwrap();
        self.entries[lru_slot] = Some(Entry { addr, state, data, lru: tick });
        self.evictions += 1;
        Some(Victim { addr: old.addr, state: old.state, data: old.data })
    }

    /// Remove a line (invalidation), returning it.
    pub fn remove(&mut self, addr: LineAddr) -> Option<Victim> {
        let range = self.slot_range(self.set_of(addr));
        for i in range {
            if self.entries[i].as_ref().is_some_and(|e| e.addr == addr) {
                let e = self.entries[i].take().unwrap();
                return Some(Victim { addr: e.addr, state: e.state, data: e.data });
            }
        }
        None
    }

    /// Update a resident line's state (e.g. downgrade M -> S on a fwd).
    pub fn set_state(&mut self, addr: LineAddr, state: CacheState) -> bool {
        let range = self.slot_range(self.set_of(addr));
        for i in range {
            if let Some(e) = self.entries[i].as_mut() {
                if e.addr == addr {
                    e.state = state;
                    return true;
                }
            }
        }
        false
    }

    pub fn resident_lines(&self) -> usize {
        self.entries.iter().flatten().count()
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Clear stats (between experiment phases).
    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
        self.evictions = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::messages::LINE_BYTES;

    fn line(v: u8) -> Box<Line> {
        Box::new([v; LINE_BYTES])
    }

    #[test]
    fn geometry_thunderx_llc() {
        // 16 MiB, 16-way, 128 B lines -> 8192 sets
        let c = Cache::new(16 << 20, 16);
        assert_eq!(c.sets(), 8192);
        assert_eq!(c.capacity_lines(), 131072);
    }

    #[test]
    fn hit_and_miss_accounting() {
        let mut c = Cache::new(4096, 2); // 32 lines, 16 sets
        assert!(c.lookup(LineAddr(5)).is_none());
        c.insert(LineAddr(5), CacheState::S, line(1));
        assert!(c.lookup(LineAddr(5)).is_some());
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
        assert_eq!(c.state_of(LineAddr(5)), CacheState::S);
        assert_eq!(c.state_of(LineAddr(6)), CacheState::I);
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut c = Cache::new(512, 2); // 4 lines, 2 sets; set = addr & 1
        // fill set 0 (even addrs)
        assert!(c.insert(LineAddr(0), CacheState::S, line(0)).is_none());
        assert!(c.insert(LineAddr(2), CacheState::S, line(2)).is_none());
        // touch 0 so 2 becomes LRU
        assert!(c.lookup(LineAddr(0)).is_some());
        let v = c.insert(LineAddr(4), CacheState::S, line(4)).expect("eviction");
        assert_eq!(v.addr, LineAddr(2));
        assert!(c.peek(LineAddr(0)).is_some());
        assert!(c.peek(LineAddr(4)).is_some());
    }

    #[test]
    fn insert_same_addr_overwrites_without_eviction() {
        let mut c = Cache::new(512, 2);
        c.insert(LineAddr(0), CacheState::S, line(1));
        let v = c.insert(LineAddr(0), CacheState::M, line(2));
        assert!(v.is_none());
        assert_eq!(c.state_of(LineAddr(0)), CacheState::M);
        assert_eq!(c.peek(LineAddr(0)).unwrap().data[0], 2);
        assert_eq!(c.resident_lines(), 1);
    }

    #[test]
    fn remove_and_set_state() {
        let mut c = Cache::new(512, 2);
        c.insert(LineAddr(3), CacheState::E, line(7));
        assert!(c.set_state(LineAddr(3), CacheState::S));
        assert_eq!(c.state_of(LineAddr(3)), CacheState::S);
        let v = c.remove(LineAddr(3)).unwrap();
        assert_eq!(v.data[0], 7);
        assert_eq!(c.state_of(LineAddr(3)), CacheState::I);
        assert!(c.remove(LineAddr(3)).is_none());
    }

    #[test]
    fn interleaved_indexing_uses_every_set() {
        // a 4-way-sliced directory's slice-0 cache sees only addresses
        // ≡ 0 (mod 4); with interleave = 4 those must spread over ALL
        // sets, not pile onto every fourth one.
        let mut c = Cache::interleaved(4096, 2, 4); // 32 lines, 16 sets
        for i in 0..16u64 {
            c.insert(LineAddr(i * 4), CacheState::S, line(i as u8));
        }
        assert_eq!(c.resident_lines(), 16, "16 slice-local lines must not conflict");
        assert_eq!(c.evictions, 0);
        for i in 0..16u64 {
            assert!(c.peek(LineAddr(i * 4)).is_some());
        }
        // plain indexing of the same stream collides 4:1 on 2 ways
        let mut p = Cache::new(4096, 2);
        for i in 0..16u64 {
            p.insert(LineAddr(i * 4), CacheState::S, line(i as u8));
        }
        assert!(p.evictions > 0, "the control must actually conflict");
    }

    #[test]
    fn working_set_larger_than_capacity_thrashes() {
        let mut c = Cache::new(4096, 2); // 32 lines
        for round in 0..3 {
            for i in 0..64u64 {
                if c.lookup(LineAddr(i)).is_none() {
                    c.insert(LineAddr(i), CacheState::S, line(i as u8));
                }
            }
            let _ = round;
        }
        // every access in rounds 2-3 should still miss (LRU + working set 2x)
        assert_eq!(c.hits, 0);
        assert_eq!(c.misses, 192);
    }
}
