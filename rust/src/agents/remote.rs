//! The remote (caching) agent: interprets the spec-generated
//! [`RemoteRules`] against a line store. In the paper's smart-memory
//! configuration this is the role the **CPU socket** plays toward
//! FPGA-homed memory; in the Fig. 2(a) accelerator configuration the FPGA
//! plays it toward CPU memory. The agent is role-agnostic: it owns
//! transaction state (MSHRs, transient line states) and drives a
//! [`Cache`] supplied by its host socket.
//!
//! No transition is hand-coded here: every state change executes a rule
//! from [`generate_remote`], so the envelope checks of
//! [`crate::proto::envelope`] apply to the running agent.

use crate::rustc_hash::FxHashMap as HashMap;

use crate::proto::messages::{CohOp, Line, LineAddr, Message, MsgKind, ReqId};
use crate::proto::spec::{DeferredFwd, RAction, REvent, RRule, RemoteRules, RemoteSt};
use crate::proto::states::{CacheState, Node};
use crate::sim::stats::Counters;

use super::cache::{Cache, Victim};

/// Effects for the host (socket model / machine) to act on.
#[derive(Debug)]
pub enum RemoteEffect {
    /// Put this message on the link.
    Send(Message),
    /// A response was installed for `addr`: waiters can be retried.
    Filled { addr: LineAddr },
    /// The local access could not complete; park it and retry on `Filled`.
    Stalled,
    /// The fill displaced a victim line belonging to *this* home —
    /// already handled (a voluntary downgrade was emitted). Victims of
    /// other regions are returned for the host to route.
    ForeignVictim(Victim),
}

/// Outcome of a local access attempt.
#[derive(Debug, PartialEq, Eq)]
pub enum Access {
    /// Hit: data available in the cache now.
    Hit,
    /// Transaction started or in progress: retry on `Filled`.
    Pending,
}

/// The caching agent for one home region.
pub struct RemoteAgent {
    node: Node,
    rules: RemoteRules,
    /// Transient per-line states (stable states live in the cache array).
    trans: HashMap<LineAddr, RemoteSt>,
    /// Outstanding request id -> line.
    outstanding: HashMap<ReqId, LineAddr>,
    /// The home region this agent fronts.
    region_base: LineAddr,
    region_lines: u64,
    next_id: u32,
    pub stats: Counters,
}

impl RemoteAgent {
    pub fn new(node: Node, rules: RemoteRules, region_base: LineAddr, region_lines: u64) -> Self {
        RemoteAgent {
            node,
            rules,
            trans: HashMap::default(),
            outstanding: HashMap::default(),
            region_base,
            region_lines,
            next_id: 0,
            stats: Counters::new(),
        }
    }

    pub fn owns(&self, addr: LineAddr) -> bool {
        addr >= self.region_base && addr.0 < self.region_base.0 + self.region_lines
    }

    pub fn outstanding_count(&self) -> usize {
        self.outstanding.len()
    }

    fn state_of(&self, addr: LineAddr, cache: &Cache) -> RemoteSt {
        if let Some(&t) = self.trans.get(&addr) {
            t
        } else {
            RemoteSt::Stable(cache.state_of(addr))
        }
    }

    fn rule(&self, st: RemoteSt, ev: REvent) -> &RRule {
        self.rules
            .get(&(st, ev))
            .unwrap_or_else(|| panic!("remote agent: no rule for {st:?} x {ev:?}"))
    }

    fn fresh_id(&mut self) -> ReqId {
        let id = ReqId(self.next_id);
        self.next_id = self.next_id.wrapping_add(1);
        id
    }

    /// Local processor access. Returns `Access::Hit` if the line is usable
    /// now; otherwise a transaction is outstanding.
    pub fn local_access(&mut self, addr: LineAddr, write: bool, cache: &mut Cache) -> (Access, Vec<RemoteEffect>) {
        debug_assert!(self.owns(addr));
        let ev = if write { REvent::Write } else { REvent::Read };
        let st = self.state_of(addr, cache);
        let rule = self.rule(st, ev).clone();
        let mut fx = Vec::new();
        let mut outcome = Access::Hit;
        self.apply(addr, &rule, None, cache, &mut fx, &mut outcome);
        (outcome, fx)
    }

    /// The host cache wants this line gone (capacity decision made by the
    /// host). Emits the voluntary downgrade as the rules dictate.
    pub fn evict(&mut self, addr: LineAddr, cache: &mut Cache) -> Vec<RemoteEffect> {
        let st = self.state_of(addr, cache);
        if st.is_transient() {
            // never evict a line mid-transaction (host picks another victim)
            return vec![RemoteEffect::Stalled];
        }
        let rule = self.rule(st, REvent::Evict).clone();
        let mut fx = Vec::new();
        let mut outcome = Access::Hit;
        self.apply(addr, &rule, None, cache, &mut fx, &mut outcome);
        fx
    }

    /// A message arrived from the home node.
    pub fn on_message(&mut self, msg: Message, cache: &mut Cache) -> Vec<RemoteEffect> {
        let addr = msg.addr;
        let mut fx = Vec::new();
        let mut outcome = Access::Hit;
        match msg.kind {
            MsgKind::CohRsp { op, dirty, .. } => {
                let known = self.outstanding.remove(&msg.id);
                debug_assert_eq!(known, Some(addr), "response for unknown transaction");
                let st = self.state_of(addr, cache);
                let rule = self.rule(st, REvent::Rsp { granted: op, dirty }).clone();
                self.apply(addr, &rule, msg.payload, cache, &mut fx, &mut outcome);
                self.stats.inc("rsp");
            }
            MsgKind::CohReq { op } => {
                // home-initiated downgrade (Fwd class)
                debug_assert_eq!(op.initiator(), Node::Home);
                let st = self.state_of(addr, cache);
                let rule = self.rule(st, REvent::Fwd { op }).clone();
                self.apply(addr, &rule, msg.payload, cache, &mut fx, &mut outcome);
                self.stats.inc("fwd");
            }
            ref k => panic!("remote agent: unexpected message kind {k:?}"),
        }
        fx
    }

    /// Execute one rule: state update + actions, recursing for deferred
    /// replays.
    fn apply(
        &mut self,
        addr: LineAddr,
        rule: &RRule,
        payload: Option<Box<Line>>,
        cache: &mut Cache,
        fx: &mut Vec<RemoteEffect>,
        outcome: &mut Access,
    ) {
        let prev = self.trans.remove(&addr);
        match rule.next {
            RemoteSt::Stable(_) => {}
            t @ RemoteSt::Wait { .. } => {
                self.trans.insert(addr, t);
            }
        }

        let mut attach_dirty = false;
        for act in &rule.actions {
            match *act {
                RAction::SendReq(op) => {
                    let id = self.fresh_id();
                    let msg = if attach_dirty {
                        let data = cache
                            .peek(addr)
                            .map(|e| e.data.clone())
                            .expect("dirty line must be resident");
                        attach_dirty = false;
                        Message::coh_req_data(id, self.node, op, addr, data)
                    } else {
                        Message::coh_req(id, self.node, op, addr)
                    };
                    if op.needs_response() {
                        self.outstanding.insert(id, addr);
                    }
                    self.stats.inc("req_sent");
                    fx.push(RemoteEffect::Send(msg));
                }
                RAction::AttachDirtyData => attach_dirty = true,
                RAction::RspToFwd { op, with_data } => {
                    let id = self.fresh_id();
                    // do we actually surrender a copy with this response?
                    // (false when we hold nothing: crossing with our own
                    // voluntary downgrade, or mid-fill use-once answers —
                    // the surrender signal then travels separately)
                    let had_copy = cache.state_of(addr) != CacheState::I;
                    let msg = if with_data {
                        let data = cache
                            .peek(addr)
                            .map(|e| e.data.clone())
                            .expect("responding with data for a non-resident line");
                        Message::coh_rsp(id, self.node, op, addr, true, Some(data))
                    } else if had_copy {
                        Message::coh_rsp(id, self.node, op, addr, false, None)
                    } else {
                        Message::coh_rsp_nocopy(id, self.node, op, addr)
                    };
                    self.stats.inc("fwd_rsp");
                    fx.push(RemoteEffect::Send(msg));
                }
                RAction::Fill(state) => {
                    let data = payload.clone().expect("fill without payload");
                    if let Some(v) = cache.insert(addr, state, data) {
                        // the fill displaced another line; if it belongs to
                        // this region, downgrade it through our own rules,
                        // otherwise hand it to the host.
                        if self.owns(v.addr) {
                            let vfx = self.evict_victim(v, cache);
                            fx.extend(vfx);
                        } else {
                            fx.push(RemoteEffect::ForeignVictim(v));
                        }
                    }
                    self.stats.inc("fill");
                    fx.push(RemoteEffect::Filled { addr });
                }
                RAction::PromoteToE => {
                    let ok = cache.set_state(addr, CacheState::E);
                    debug_assert!(ok, "PromoteToE on non-resident line");
                    self.stats.inc("upgrade");
                    fx.push(RemoteEffect::Filled { addr });
                }
                RAction::MarkDirty => {
                    let ok = cache.set_state(addr, CacheState::M);
                    debug_assert!(ok, "MarkDirty on non-resident line");
                }
                RAction::DowngradeToS => {
                    let ok = cache.set_state(addr, CacheState::S);
                    debug_assert!(ok, "DowngradeToS on non-resident line");
                }
                RAction::DropLine => {
                    cache.remove(addr);
                }
                RAction::StallLocal => {
                    *outcome = Access::Pending;
                    fx.push(RemoteEffect::Stalled);
                }
                RAction::DropAfterFill => {
                    // Use-once fill: the fwd-to-I was already answered
                    // (clean); surrender the line now. An EXCLUSIVE grant
                    // must notify the home (its directory recorded EorM
                    // for this fresh epoch and nothing else will clear
                    // it); a shared grant may drop silently (the home's
                    // S-view over-estimate is benign).
                    if let Some(v) = cache.remove(addr) {
                        let id = self.fresh_id();
                        match v.state {
                            CacheState::M => {
                                fx.push(RemoteEffect::Send(Message::coh_req_data(
                                    id,
                                    self.node,
                                    CohOp::VolDowngradeI,
                                    addr,
                                    v.data,
                                )));
                                self.stats.inc("useonce_wb");
                            }
                            CacheState::E => {
                                fx.push(RemoteEffect::Send(Message::coh_req(
                                    id,
                                    self.node,
                                    CohOp::VolDowngradeI,
                                    addr,
                                )));
                                self.stats.inc("useonce_drop");
                            }
                            _ => {
                                // even a shared use-once copy signals its
                                // surrender: the possession accounting at
                                // the home counts every grant epoch
                                fx.push(RemoteEffect::Send(Message::coh_req(
                                    id,
                                    self.node,
                                    CohOp::VolDowngradeI,
                                    addr,
                                )));
                                self.stats.inc("useonce_drop");
                            }
                        }
                    }
                }
                RAction::DemoteAfterFill => {
                    // Demoted fill: the fwd-to-S was already answered;
                    // keep a shared clean copy. An exclusive grant must
                    // tell the home about the demotion (dirty data rides
                    // along if the grant carried ownership).
                    if let Some(e) = cache.peek(addr) {
                        let st0 = e.state;
                        let data = e.data.clone();
                        let id = self.fresh_id();
                        match st0 {
                            CacheState::M => {
                                fx.push(RemoteEffect::Send(Message::coh_req_data(
                                    id,
                                    self.node,
                                    CohOp::VolDowngradeS,
                                    addr,
                                    data,
                                )));
                            }
                            CacheState::E => {
                                fx.push(RemoteEffect::Send(Message::coh_req(
                                    id,
                                    self.node,
                                    CohOp::VolDowngradeS,
                                    addr,
                                )));
                            }
                            _ => {}
                        }
                    }
                    cache.set_state(addr, CacheState::S);
                }
            }
        }
        debug_assert!(!attach_dirty, "AttachDirtyData without a following SendReq");
        // a local access that started a transaction is pending
        let _ = prev;
        if matches!(rule.next, RemoteSt::Wait { .. }) && self.trans.contains_key(&addr) {
            *outcome = Access::Pending;
        }
    }

    /// A line of this region was displaced from the host cache by an
    /// unrelated insertion (the entry is already gone): emit the voluntary
    /// downgrade its state requires. Public counterpart of the internal
    /// victim handling, used by the machine when *local* fills displace
    /// remote lines from the shared LLC.
    pub fn downgrade_evicted(&mut self, v: Victim) -> Vec<RemoteEffect> {
        self.evict_victim_inner(v)
    }

    /// A victim of this region evicted by a fill: run its Evict rule from
    /// the state it was in (the cache entry is already gone, so dispatch
    /// manually).
    fn evict_victim(&mut self, v: Victim, _cache: &mut Cache) -> Vec<RemoteEffect> {
        self.evict_victim_inner(v)
    }

    fn evict_victim_inner(&mut self, v: Victim) -> Vec<RemoteEffect> {
        let mut fx = Vec::new();
        match v.state {
            CacheState::I => {}
            CacheState::S | CacheState::E => {
                let id = self.fresh_id();
                fx.push(RemoteEffect::Send(Message::coh_req(id, self.node, CohOp::VolDowngradeI, v.addr)));
                self.stats.inc("evict_clean");
            }
            CacheState::M => {
                let id = self.fresh_id();
                fx.push(RemoteEffect::Send(Message::coh_req_data(
                    id,
                    self.node,
                    CohOp::VolDowngradeI,
                    v.addr,
                    v.data,
                )));
                self.stats.inc("evict_dirty");
            }
        }
        fx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::spec::generate_remote;
    use crate::proto::transitions::reference_transitions;

    fn agent() -> (RemoteAgent, Cache) {
        let rules = generate_remote(&reference_transitions());
        (
            RemoteAgent::new(Node::Remote, rules, LineAddr(0), 1 << 20),
            Cache::new(64 * 1024, 4),
        )
    }

    fn data(v: u8) -> Box<Line> {
        Box::new([v; 128])
    }

    #[test]
    fn read_miss_sends_read_shared_then_fills() {
        let (mut a, mut c) = agent();
        let (acc, fx) = a.local_access(LineAddr(7), false, &mut c);
        assert_eq!(acc, Access::Pending);
        let req = match &fx[0] {
            RemoteEffect::Send(m) => m.clone(),
            other => panic!("{other:?}"),
        };
        assert_eq!(req.kind, MsgKind::CohReq { op: CohOp::ReadShared });
        // home responds
        let rsp = Message::coh_rsp(req.id, Node::Home, CohOp::ReadShared, LineAddr(7), false, Some(data(9)));
        let fx = a.on_message(rsp, &mut c);
        assert!(fx.iter().any(|e| matches!(e, RemoteEffect::Filled { addr } if *addr == LineAddr(7))));
        assert_eq!(c.state_of(LineAddr(7)), CacheState::S);
        assert_eq!(c.peek(LineAddr(7)).unwrap().data[0], 9);
        // now it hits
        let (acc, _) = a.local_access(LineAddr(7), false, &mut c);
        assert_eq!(acc, Access::Hit);
    }

    #[test]
    fn write_miss_fills_exclusive_then_dirties_silently() {
        let (mut a, mut c) = agent();
        let (acc, fx) = a.local_access(LineAddr(3), true, &mut c);
        assert_eq!(acc, Access::Pending);
        let req = match &fx[0] {
            RemoteEffect::Send(m) => m.clone(),
            other => panic!("{other:?}"),
        };
        assert_eq!(req.kind, MsgKind::CohReq { op: CohOp::ReadExclusive });
        let rsp = Message::coh_rsp(req.id, Node::Home, CohOp::ReadExclusive, LineAddr(3), false, Some(data(1)));
        a.on_message(rsp, &mut c);
        assert_eq!(c.state_of(LineAddr(3)), CacheState::E);
        // the write that was stalled now retries: silent E -> M
        let (acc, fx) = a.local_access(LineAddr(3), true, &mut c);
        assert_eq!(acc, Access::Hit);
        assert!(fx.is_empty(), "silent upgrade must not signal: {fx:?}");
        assert_eq!(c.state_of(LineAddr(3)), CacheState::M);
    }

    #[test]
    fn dirty_eviction_carries_payload() {
        let (mut a, mut c) = agent();
        // install M line directly
        c.insert(LineAddr(5), CacheState::M, data(0xEE));
        let fx = a.evict(LineAddr(5), &mut c);
        let m = match &fx[0] {
            RemoteEffect::Send(m) => m,
            other => panic!("{other:?}"),
        };
        assert_eq!(m.kind, MsgKind::CohReq { op: CohOp::VolDowngradeI });
        assert_eq!(m.payload.as_ref().unwrap()[0], 0xEE);
        assert_eq!(c.state_of(LineAddr(5)), CacheState::I);
    }

    #[test]
    fn fwd_invalidate_of_modified_line_returns_dirty_data() {
        let (mut a, mut c) = agent();
        c.insert(LineAddr(9), CacheState::M, data(0x55));
        let fwd = Message::coh_req(ReqId(77), Node::Home, CohOp::FwdDowngradeI, LineAddr(9));
        let fx = a.on_message(fwd, &mut c);
        let rsp = match &fx[0] {
            RemoteEffect::Send(m) => m,
            other => panic!("{other:?}"),
        };
        match rsp.kind {
            MsgKind::CohRsp { op: CohOp::FwdDowngradeI, dirty: true, .. } => {}
            ref k => panic!("{k:?}"),
        }
        assert_eq!(rsp.payload.as_ref().unwrap()[0], 0x55);
        assert_eq!(c.state_of(LineAddr(9)), CacheState::I);
    }

    #[test]
    fn fwd_during_fill_is_answered_immediately_and_fill_is_use_once() {
        let (mut a, mut c) = agent();
        // start a read
        let (_, fx) = a.local_access(LineAddr(11), false, &mut c);
        let req = match &fx[0] {
            RemoteEffect::Send(m) => m.clone(),
            other => panic!("{other:?}"),
        };
        // fwd arrives before the fill (cross-VC reordering, or the home
        // issued it while stalling our request): answered NOW, clean.
        let fwd = Message::coh_req(ReqId(50), Node::Home, CohOp::FwdDowngradeI, LineAddr(11));
        let fx = a.on_message(fwd, &mut c);
        let rsp_now: Vec<&Message> = fx
            .iter()
            .filter_map(|e| match e {
                RemoteEffect::Send(m) => Some(m),
                _ => None,
            })
            .collect();
        assert_eq!(rsp_now.len(), 1);
        assert!(matches!(rsp_now[0].kind, MsgKind::CohRsp { op: CohOp::FwdDowngradeI, dirty: false, .. }));
        // fill lands; it is use-once: the waiting core is served, the
        // line is NOT retained, and the surrender is signalled (the
        // home's possession accounting counts every grant epoch).
        let rsp = Message::coh_rsp(req.id, Node::Home, CohOp::ReadShared, LineAddr(11), false, Some(data(2)));
        let fx = a.on_message(rsp, &mut c);
        assert!(fx.iter().any(|e| matches!(e, RemoteEffect::Filled { .. })));
        assert!(
            fx.iter().any(|e| matches!(e, RemoteEffect::Send(m)
                if matches!(m.kind, MsgKind::CohReq { op: CohOp::VolDowngradeI }) && m.payload.is_none())),
            "use-once drop must signal its surrender: {fx:?}"
        );
        assert_eq!(c.state_of(LineAddr(11)), CacheState::I, "line surrendered after use");
    }

    #[test]
    fn capacity_eviction_of_same_region_emits_downgrade() {
        let rules = generate_remote(&reference_transitions());
        let mut a = RemoteAgent::new(Node::Remote, rules, LineAddr(0), 1 << 20);
        // tiny cache: 2 sets x 1 way = 2 lines (256 B)
        let mut c = Cache::new(256, 1);
        // fill two same-set lines; the second fill evicts the first
        for (i, addr) in [LineAddr(0), LineAddr(2)].iter().enumerate() {
            let (_, fx) = a.local_access(*addr, false, &mut c);
            let req = match &fx[0] {
                RemoteEffect::Send(m) => m.clone(),
                other => panic!("{other:?}"),
            };
            let rsp = Message::coh_rsp(req.id, Node::Home, CohOp::ReadShared, *addr, false, Some(data(i as u8)));
            let fx = a.on_message(rsp, &mut c);
            if i == 1 {
                // eviction of line 0 must have produced a VolDowngradeI
                let downgrades: Vec<&Message> = fx
                    .iter()
                    .filter_map(|e| match e {
                        RemoteEffect::Send(m) if matches!(m.kind, MsgKind::CohReq { op: CohOp::VolDowngradeI }) => Some(m),
                        _ => None,
                    })
                    .collect();
                assert_eq!(downgrades.len(), 1);
                assert_eq!(downgrades[0].addr, LineAddr(0));
            }
        }
        assert_eq!(c.state_of(LineAddr(0)), CacheState::I);
        assert_eq!(c.state_of(LineAddr(2)), CacheState::S);
    }
}
