//! The home agent: the FPGA-side directory controller of §4.2,
//! interpreting the spec-generated [`HomeRules`]. Supports the symmetric
//! configuration (directory + optional home cache) and degrades cleanly
//! to the asymmetric configurations; the fully-stateless read-only home
//! of §3.4 bypasses this agent entirely (see [`crate::memctl`]).
//!
//! Data plane is synchronous against the backing [`MemStore`] (real
//! bytes); the timing of RAM reads is carried by the `from_ram` flag on
//! [`HomeEffect::Respond`], which the machine model turns into DRAM
//! occupancy before the response enters the link.

use std::collections::VecDeque;

use crate::rustc_hash::FxHashMap as HashMap;

use crate::proto::messages::{Line, LineAddr, Message, MsgKind, ReqId};
use crate::proto::spec::{HAction, HEvent, HRule, HomePolicy, HomeRules, HomeSt, RemoteView};
use crate::proto::states::{CacheState, Node};
use crate::sim::stats::Counters;

use super::cache::Cache;
use super::dram::MemStore;

/// Effects for the machine model to act on.
#[derive(Debug)]
pub enum HomeEffect {
    /// Send a response. `from_ram` adds backing-store read latency.
    Respond { msg: Message, from_ram: bool },
    /// Issue a home-initiated downgrade to the remote.
    Fwd { msg: Message },
    /// A (posted) RAM write happened; account DRAM occupancy.
    RamWrite { addr: LineAddr },
    /// A home-side local access completed (symmetric configurations).
    LocalDone { tag: u64, data: Box<Line> },
}

/// A stalled event waiting for the line to settle.
struct Pending {
    ev: HEvent,
    payload: Option<Box<Line>>,
    /// request id to respond to (for remote requests)
    rsp_id: Option<ReqId>,
    tag: u64,
}

/// Everything one slice knows about a line, packed for a handoff to a
/// *different* slice during live reconfiguration. Unlike
/// [`HomeAgent::surrender_copy`] (which retires the line to RAM so a cold
/// adopter rebuilds from the backing store), an export carries the exact
/// directory word, the grant-epoch count, and any cached copy with its
/// state, so the importing slice reproduces the pre-handoff shape
/// bit-for-bit — the transparency property the reconfig litmus tests gate
/// on.
#[derive(Debug)]
pub struct ExportedLine {
    /// The directory word, verbatim.
    pub st: HomeSt,
    /// Outstanding grant epochs (possession counter).
    pub holders: u32,
    /// The home-cache copy, if resident: its state and bytes.
    pub cached: Option<(CacheState, Box<Line>)>,
}

/// The directory controller. Since the dcs refactor the agent is
/// *slice-local*: it fronts the lines whose address satisfies
/// `addr % slice_count == slice_index` and nothing else — there is no
/// global address map anywhere in the directory. A standalone agent is
/// simply the 1-slice special case (`slice_count == 1` owns every line),
/// so [`HomeAgent::new`] keeps its original meaning; the sharded
/// composition lives in [`crate::dcs`].
pub struct HomeAgent {
    rules: HomeRules,
    policy: HomePolicy,
    /// This agent's slice of the address-interleaved directory.
    slice_index: u64,
    slice_count: u64,
    /// A sibling slice that has gone dark (drain/failover): lines whose
    /// natural owner is the dead slice re-home across the survivors by a
    /// deterministic spread, mirrored exactly by `Dcs::slice_of`.
    dead_sibling: Option<u64>,
    /// Per-line directory state; absent = idle (I/I, no pending).
    dir: HashMap<LineAddr, HomeSt>,
    /// Grant-epoch possession counter per line: grants of a copy
    /// increment, surrenders (voluntary invalidations, fwd-to-I
    /// responses) decrement. A voluntary downgrade arriving while the
    /// count stays positive is a *stale epoch* (the remote re-requested
    /// before its downgrade landed) and must not clear the view.
    possession: HashMap<LineAddr, u32>,
    /// Stalled events per line.
    stalled: HashMap<LineAddr, VecDeque<Pending>>,
    /// Optional home-side cache (symmetric config).
    pub cache: Option<Cache>,
    next_id: u32,
    pub stats: Counters,
}

impl HomeAgent {
    /// A whole-directory agent: the 1-slice special case.
    pub fn new(rules: HomeRules, policy: HomePolicy, cache: Option<Cache>) -> HomeAgent {
        HomeAgent::new_slice(rules, policy, cache, 0, 1)
    }

    /// Slice `slice_index` of a `slice_count`-way address-interleaved
    /// directory (line-address modulo mapping; 2 slices = the paper's
    /// even/odd split).
    pub fn new_slice(
        rules: HomeRules,
        policy: HomePolicy,
        cache: Option<Cache>,
        slice_index: u64,
        slice_count: u64,
    ) -> HomeAgent {
        assert!(slice_count > 0 && slice_index < slice_count, "bad slice {slice_index}/{slice_count}");
        assert!(
            !(policy.cache_fills || policy.cache_writebacks) || cache.is_some(),
            "cache-filling home policies need an actual home cache"
        );
        HomeAgent {
            rules,
            policy,
            slice_index,
            slice_count,
            dead_sibling: None,
            dir: HashMap::default(),
            possession: HashMap::default(),
            stalled: HashMap::default(),
            cache,
            next_id: 0,
            stats: Counters::new(),
        }
    }

    pub fn policy(&self) -> HomePolicy {
        self.policy
    }

    /// Does this slice front `addr`? (Always true for a 1-slice agent.)
    /// While a sibling is drained, its natural lines spread across the
    /// survivors: line `a` with natural owner `d` re-homes to
    /// `(d + 1 + (a/n) % (n-1)) % n`, which never lands back on `d` and
    /// distributes the orphaned range evenly.
    #[inline]
    pub fn owns(&self, addr: LineAddr) -> bool {
        let n = self.slice_count;
        let natural = addr.0 % n;
        if self.dead_sibling == Some(natural) {
            let k = (addr.0 / n) % (n - 1);
            return (natural + 1 + k) % n == self.slice_index;
        }
        natural == self.slice_index
    }

    /// Mark a sibling slice dark (or clear the mark). While set, this
    /// slice adopts its deterministic share of the dead slice's address
    /// range — see [`HomeAgent::owns`].
    pub fn set_dead_sibling(&mut self, dead: Option<u64>) {
        if let Some(d) = dead {
            assert!(self.slice_count >= 2, "draining the only slice");
            assert!(d < self.slice_count, "bad dead slice {d}/{}", self.slice_count);
            assert_ne!(d, self.slice_index, "a drained slice cannot re-home to itself");
        }
        self.dead_sibling = dead;
    }

    pub fn slice_index(&self) -> u64 {
        self.slice_index
    }
    pub fn slice_count(&self) -> u64 {
        self.slice_count
    }

    pub fn state_of(&self, addr: LineAddr) -> HomeSt {
        self.dir.get(&addr).copied().unwrap_or(HomeSt::idle())
    }

    /// Directory footprint (lines tracked) — the §3.4 space argument.
    pub fn tracked_lines(&self) -> usize {
        self.dir.len()
    }

    /// Outstanding grant-epochs for a line (diagnostics).
    pub fn possession_count(&self, addr: LineAddr) -> u32 {
        self.possession.get(&addr).copied().unwrap_or(0)
    }

    fn fresh_id(&mut self) -> ReqId {
        let id = ReqId(self.next_id);
        self.next_id = self.next_id.wrapping_add(1);
        id
    }

    fn set_state(&mut self, addr: LineAddr, st: HomeSt) {
        if st == HomeSt::idle() {
            self.dir.remove(&addr);
        } else {
            self.dir.insert(addr, st);
        }
    }

    /// A coherence message arrived from the remote.
    pub fn on_message(&mut self, msg: Message, ram: &mut MemStore) -> Vec<HomeEffect> {
        let addr = msg.addr;
        match msg.kind {
            MsgKind::CohReq { op } => {
                debug_assert_eq!(op.initiator(), Node::Remote);
                let with_data = msg.payload.is_some();
                if op == crate::proto::messages::CohOp::VolDowngradeI {
                    // epoch check: a surrender for a copy we have since
                    // re-granted must not clear the fresh epoch's view.
                    let cnt = self.possession.entry(addr).or_insert(0);
                    *cnt = cnt.saturating_sub(1);
                    if *cnt > 0 {
                        // stale epoch: only clean surrenders can be stale
                        // (dirty owners are stalled at the home until
                        // their downgrade lands)
                        debug_assert!(!with_data, "stale dirty downgrade");
                        self.stats.inc("stale_downgrade_ignored");
                        return Vec::new();
                    }
                    self.possession.remove(&addr);
                }
                self.dispatch(addr, HEvent::Req { op, with_data }, msg.payload, Some(msg.id), 0, ram)
            }
            MsgKind::CohRsp { op, dirty, had_copy } => {
                debug_assert_eq!(op.initiator(), Node::Home, "unexpected response {op:?}");
                if matches!(op, crate::proto::messages::CohOp::FwdDowngradeI) && had_copy {
                    let cnt = self.possession.entry(addr).or_insert(0);
                    *cnt = cnt.saturating_sub(1);
                    if *cnt == 0 {
                        self.possession.remove(&addr);
                    }
                }
                self.dispatch(addr, HEvent::FwdRsp { dirty }, msg.payload, None, 0, ram)
            }
            ref k => panic!("home agent: unexpected message kind {k:?}"),
        }
    }

    /// Home-side application access (symmetric configurations). `tag`
    /// correlates the eventual `LocalDone`.
    pub fn local_access(&mut self, addr: LineAddr, write: bool, tag: u64, ram: &mut MemStore) -> Vec<HomeEffect> {
        let ev = if write { HEvent::LocalWrite } else { HEvent::LocalRead };
        self.dispatch(addr, ev, None, None, tag, ram)
    }

    /// Application wants the remote's copy recalled (e.g. before an
    /// in-place result update).
    pub fn recall(&mut self, addr: LineAddr, ram: &mut MemStore) -> Vec<HomeEffect> {
        self.dispatch(addr, HEvent::RecallI, None, None, 0, ram)
    }

    /// Hand the line off entirely: flush any cached copy to `ram` and drop
    /// the directory entry, so a *different* home agent can adopt the line
    /// cold from the backing store (the handoff step of a fabric home
    /// migration). Only legal while the line is quiescent — no remote
    /// possession, no pending forward, no stalled events. Returns `false`
    /// (and changes nothing) otherwise.
    pub fn surrender_copy(&mut self, addr: LineAddr, ram: &mut MemStore) -> bool {
        let st = self.state_of(addr);
        if st.view != RemoteView::I
            || st.pending_fwd.is_some()
            || self.stalled.contains_key(&addr)
        {
            return false;
        }
        if let Some(c) = self.cache.as_mut() {
            if let Some(v) = c.remove(addr) {
                if v.state == CacheState::M || st.own_dirty {
                    ram.write_line(addr, &v.data);
                    self.stats.inc("ram_write");
                }
            }
        }
        self.possession.remove(&addr);
        self.set_state(addr, HomeSt::idle());
        self.stats.inc("surrendered");
        true
    }

    /// The inverse of [`HomeAgent::surrender_copy`] for failover: adopt a
    /// line whose previous home died while a remote node still holds a
    /// copy. The surviving holder's cache is ground truth, so the
    /// directory entry is rebuilt directly — `view` reflects the holder's
    /// cached state, `holders` seeds the grant-epoch counter — without
    /// replaying the grant that produced it. Only legal on a line this
    /// slice owns and currently tracks nothing about.
    pub fn adopt_remote(&mut self, addr: LineAddr, view: RemoteView, holders: u32) {
        debug_assert!(self.owns(addr), "adopting a line outside this slice");
        debug_assert!(self.state_of(addr) == HomeSt::idle(), "adopting a tracked line");
        debug_assert!(!self.stalled.contains_key(&addr), "adopting a line with stalled events");
        debug_assert!(
            matches!(view, RemoteView::S | RemoteView::EorM),
            "adoption is only meaningful for a held line"
        );
        self.set_state(addr, HomeSt { own: CacheState::I, own_dirty: false, view, pending_fwd: None });
        self.possession.insert(addr, holders);
        self.stats.inc("adopted");
    }

    /// Pack up everything this slice knows about `addr` for a handoff to
    /// another slice (live reconfiguration). Returns `None` when there is
    /// nothing to move (idle, no epochs, no cached copy). Only legal on a
    /// quiescent line — the control plane quiesces the whole data plane
    /// before calling this, so a pending forward or stalled event here is
    /// a protocol bug.
    pub fn export_line(&mut self, addr: LineAddr) -> Option<ExportedLine> {
        let st = self.state_of(addr);
        debug_assert!(st.pending_fwd.is_none(), "exporting {addr} mid-transaction");
        debug_assert!(!self.stalled.contains_key(&addr), "exporting {addr} with stalled events");
        let cached = self
            .cache
            .as_mut()
            .and_then(|c| c.remove(addr))
            .map(|v| (v.state, v.data));
        debug_assert!(
            st.own == CacheState::I || cached.is_some(),
            "directory says own={:?} but no cached copy for {addr}",
            st.own
        );
        let holders = self.possession.remove(&addr).unwrap_or(0);
        self.set_state(addr, HomeSt::idle());
        if st == HomeSt::idle() && holders == 0 && cached.is_none() {
            return None;
        }
        self.stats.inc("exported");
        Some(ExportedLine { st, holders, cached })
    }

    /// The inverse of [`HomeAgent::export_line`]: install a handed-off
    /// line verbatim. If the export carried a cached copy it is inserted
    /// into this slice's cache (victims follow the same freshest-copy
    /// writeback rule as `FillOwn`); when this slice has *no* cache (a
    /// shrink-to-uncached resize) the copy retires to RAM if it was the
    /// freshest version and the directory's own-state clears. Returns the
    /// number of cache victims (incl. retired copies) for bookkeeping.
    pub fn import_line(&mut self, addr: LineAddr, ex: ExportedLine, ram: &mut MemStore) -> u64 {
        debug_assert!(self.owns(addr), "importing a line outside this slice");
        debug_assert!(self.state_of(addr) == HomeSt::idle(), "importing over a tracked line");
        debug_assert!(!self.stalled.contains_key(&addr), "importing over stalled events");
        let mut victims = 0;
        let mut st = ex.st;
        if let Some((cst, data)) = ex.cached {
            match self.cache.as_mut() {
                Some(c) => {
                    if let Some(v) = c.insert(addr, cst, data) {
                        let mut vst = self.state_of(v.addr);
                        if v.state == CacheState::M || vst.own_dirty {
                            ram.write_line(v.addr, &v.data);
                            self.stats.inc("ram_write");
                        }
                        vst.own = CacheState::I;
                        vst.own_dirty = false;
                        self.set_state(v.addr, vst);
                        victims += 1;
                    }
                }
                None => {
                    if cst == CacheState::M || st.own_dirty {
                        ram.write_line(addr, &data);
                        self.stats.inc("ram_write");
                    }
                    st.own = CacheState::I;
                    st.own_dirty = false;
                    victims += 1;
                }
            }
        }
        if st != HomeSt::idle() {
            self.set_state(addr, st);
        }
        if ex.holders > 0 {
            self.possession.insert(addr, ex.holders);
        }
        self.stats.inc("imported");
        victims
    }

    fn rule(&self, st: HomeSt, ev: HEvent) -> HRule {
        self.rules
            .get(&(st, ev))
            .unwrap_or_else(|| panic!("home agent: no rule for {st:?} x {ev:?}"))
            .clone()
    }

    fn dispatch(
        &mut self,
        addr: LineAddr,
        ev: HEvent,
        payload: Option<Box<Line>>,
        rsp_id: Option<ReqId>,
        tag: u64,
        ram: &mut MemStore,
    ) -> Vec<HomeEffect> {
        debug_assert!(
            self.owns(addr),
            "slice {}/{} dispatched foreign line {addr}",
            self.slice_index,
            self.slice_count
        );
        let mut fx = Vec::new();
        let st = self.state_of(addr);
        let rule = self.rule(st, ev);
        let stalled = rule.actions.contains(&HAction::Stall);
        self.set_state(addr, rule.next);
        self.run_actions(addr, &rule, &ev, payload.clone(), rsp_id, tag, ram, &mut fx);
        if stalled {
            self.stalled
                .entry(addr)
                .or_default()
                .push_back(Pending { ev, payload, rsp_id, tag });
            self.stats.inc("stalled");
        } else if st.pending_fwd.is_some() && rule.next.pending_fwd.is_none() {
            // the line settled: replay stalled events in arrival order
            if let Some(mut q) = self.stalled.remove(&addr) {
                while let Some(p) = q.pop_front() {
                    let more = self.dispatch(addr, p.ev, p.payload, p.rsp_id, p.tag, ram);
                    fx.extend(more);
                    // if the replayed event stalled again, the rest of the
                    // queue was re-queued behind it by the recursion; stop.
                    if self.state_of(addr).pending_fwd.is_some() {
                        if let Some(rest) = self.stalled.get_mut(&addr) {
                            while let Some(r) = q.pop_front() {
                                rest.push_back(r);
                            }
                        }
                        break;
                    }
                }
            }
        }
        fx
    }

    #[allow(clippy::too_many_arguments)]
    fn run_actions(
        &mut self,
        addr: LineAddr,
        rule: &HRule,
        ev: &HEvent,
        payload: Option<Box<Line>>,
        rsp_id: Option<ReqId>,
        tag: u64,
        ram: &mut MemStore,
        fx: &mut Vec<HomeEffect>,
    ) {
        for act in &rule.actions {
            match *act {
                HAction::SendRsp { op, with_data, from_ram, dirty } => {
                    let id = rsp_id.expect("response without a request id");
                    if matches!(
                        op,
                        crate::proto::messages::CohOp::ReadShared
                            | crate::proto::messages::CohOp::ReadExclusive
                    ) {
                        // a copy is being granted: open a possession epoch
                        *self.possession.entry(addr).or_insert(0) += 1;
                    }
                    let data = if with_data {
                        let line = if from_ram {
                            ram.read_line(addr)
                        } else {
                            match self.cached_line(addr) {
                                Some(l) => {
                                    self.stats.inc("home_cache_hit");
                                    l
                                }
                                None => ram.read_line(addr),
                            }
                        };
                        Some(Box::new(line))
                    } else {
                        None
                    };
                    self.stats.inc("rsp_sent");
                    fx.push(HomeEffect::Respond {
                        msg: Message::coh_rsp(id, Node::Home, op, addr, dirty, data),
                        from_ram,
                    });
                }
                HAction::SendFwd { op } => {
                    let id = self.fresh_id();
                    self.stats.inc("fwd_sent");
                    fx.push(HomeEffect::Fwd { msg: Message::coh_req(id, Node::Home, op, addr) });
                }
                HAction::WriteRam => {
                    // the freshest copy is the payload (writeback / fwd
                    // response) or our own cached line
                    let line = payload
                        .as_deref()
                        .copied()
                        .or_else(|| self.cached_line(addr))
                        .expect("WriteRam without a data source");
                    ram.write_line(addr, &line);
                    self.stats.inc("ram_write");
                    fx.push(HomeEffect::RamWrite { addr });
                }
                HAction::FillOwn { state, dirty } => {
                    let line = payload
                        .as_deref()
                        .copied()
                        .unwrap_or_else(|| ram.read_line(addr));
                    if let Some(c) = self.cache.as_mut() {
                        self.stats.inc("home_cache_fill");
                        if let Some(v) = c.insert(addr, state, Box::new(line)) {
                            // home-cache victims write back if they carry
                            // the freshest copy: cached M, or hidden-O
                            // (own = S with the directory dirty bit set)
                            let mut vst = self.state_of(v.addr);
                            if v.state == CacheState::M || vst.own_dirty {
                                ram.write_line(v.addr, &v.data);
                                fx.push(HomeEffect::RamWrite { addr: v.addr });
                            }
                            // directory entry for the victim's own state
                            vst.own = CacheState::I;
                            vst.own_dirty = false;
                            self.set_state(v.addr, vst);
                        }
                    }
                    let _ = dirty;
                }
                HAction::DropOwn => {
                    if let Some(c) = self.cache.as_mut() {
                        c.remove(addr);
                    }
                }
                HAction::SetOwnDirty(d) => {
                    if let Some(c) = self.cache.as_mut() {
                        if d {
                            c.set_state(addr, CacheState::M);
                        }
                    }
                }
                HAction::Stall => { /* queued by dispatch() */ }
                HAction::AcceptWriteback => {
                    debug_assert!(payload.is_some(), "AcceptWriteback without payload");
                    self.stats.inc("writeback");
                }
            }
        }
        // local accesses complete when not stalled
        if matches!(ev, HEvent::LocalRead | HEvent::LocalWrite)
            && !rule.actions.contains(&HAction::Stall)
        {
            let line = self
                .cached_line(addr)
                .unwrap_or_else(|| ram.read_line(addr));
            fx.push(HomeEffect::LocalDone { tag, data: Box::new(line) });
        }
    }

    fn cached_line(&self, addr: LineAddr) -> Option<Line> {
        self.cache.as_ref().and_then(|c| c.peek(addr).map(|e| *e.data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::messages::CohOp;
    use crate::proto::spec::{generate_home, PendingFwd, RemoteView};
    use crate::proto::transitions::reference_transitions;

    fn mk(cache: bool) -> (HomeAgent, MemStore) {
        let rules = generate_home(&reference_transitions(), HomePolicy::default());
        let agent = HomeAgent::new(
            rules,
            HomePolicy::default(),
            cache.then(|| Cache::new(64 * 1024, 4)),
        );
        let mut ram = MemStore::new(LineAddr(0), 1 << 20);
        for i in 0..64 {
            let mut l = [0u8; 128];
            l[0] = i as u8;
            ram.write_line(LineAddr(i), &l);
        }
        (agent, ram)
    }

    #[test]
    fn read_shared_served_from_ram() {
        let (mut a, mut ram) = mk(false);
        let req = Message::coh_req(ReqId(1), Node::Remote, CohOp::ReadShared, LineAddr(5));
        let fx = a.on_message(req, &mut ram);
        assert_eq!(fx.len(), 1);
        match &fx[0] {
            HomeEffect::Respond { msg, from_ram } => {
                assert!(from_ram);
                assert_eq!(msg.id, ReqId(1));
                assert_eq!(msg.payload.as_ref().unwrap()[0], 5);
                assert!(matches!(msg.kind, MsgKind::CohRsp { op: CohOp::ReadShared, dirty: false, .. }));
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(a.state_of(LineAddr(5)).view, RemoteView::S);
    }

    #[test]
    fn exclusive_then_writeback_round_trip() {
        let (mut a, mut ram) = mk(false);
        let req = Message::coh_req(ReqId(2), Node::Remote, CohOp::ReadExclusive, LineAddr(7));
        let fx = a.on_message(req, &mut ram);
        assert!(matches!(&fx[0], HomeEffect::Respond { .. }));
        assert_eq!(a.state_of(LineAddr(7)).view, RemoteView::EorM);
        // dirty writeback returns
        let mut dirty = [0u8; 128];
        dirty[0] = 0xFF;
        let wb = Message::coh_req_data(ReqId(3), Node::Remote, CohOp::VolDowngradeI, LineAddr(7), Box::new(dirty));
        let fx = a.on_message(wb, &mut ram);
        assert!(fx.iter().any(|e| matches!(e, HomeEffect::RamWrite { .. })));
        assert_eq!(ram.read_line(LineAddr(7))[0], 0xFF, "writeback must reach RAM");
        assert_eq!(a.state_of(LineAddr(7)), HomeSt::idle());
        assert_eq!(a.tracked_lines(), 0, "idle lines are not tracked");
    }

    #[test]
    fn request_overtaking_downgrade_stalls_then_replays() {
        let (mut a, mut ram) = mk(false);
        // remote takes the line exclusive
        let fx = a.on_message(
            Message::coh_req(ReqId(1), Node::Remote, CohOp::ReadExclusive, LineAddr(9)),
            &mut ram,
        );
        assert_eq!(fx.len(), 1);
        // a new ReadShared arrives while the directory still says EorM
        // (the voluntary downgrade is in flight): must stall, no response.
        let fx = a.on_message(
            Message::coh_req(ReqId(2), Node::Remote, CohOp::ReadShared, LineAddr(9)),
            &mut ram,
        );
        assert!(fx.is_empty(), "{fx:?}");
        assert_eq!(a.state_of(LineAddr(9)).pending_fwd, Some(PendingFwd::AwaitVolDowngrade));
        // the in-flight downgrade lands: the stalled read replays and is
        // answered.
        let mut dirty = [0u8; 128];
        dirty[0] = 0xAB;
        let fx = a.on_message(
            Message::coh_req_data(ReqId(3), Node::Remote, CohOp::VolDowngradeI, LineAddr(9), Box::new(dirty)),
            &mut ram,
        );
        let rsp: Vec<_> = fx
            .iter()
            .filter_map(|e| match e {
                HomeEffect::Respond { msg, .. } => Some(msg),
                _ => None,
            })
            .collect();
        assert_eq!(rsp.len(), 1);
        assert_eq!(rsp[0].id, ReqId(2));
        assert_eq!(rsp[0].payload.as_ref().unwrap()[0], 0xAB, "replayed read sees the writeback");
        assert_eq!(a.state_of(LineAddr(9)).view, RemoteView::S);
    }

    #[test]
    fn local_write_recalls_shared_copy_then_completes() {
        let (mut a, mut ram) = mk(true);
        // remote shares the line
        a.on_message(Message::coh_req(ReqId(1), Node::Remote, CohOp::ReadShared, LineAddr(4)), &mut ram);
        // home-side app writes it: must recall first
        let fx = a.local_access(LineAddr(4), true, 42, &mut ram);
        let fwd: Vec<_> = fx
            .iter()
            .filter_map(|e| match e {
                HomeEffect::Fwd { msg } => Some(msg),
                _ => None,
            })
            .collect();
        assert_eq!(fwd.len(), 1);
        assert!(matches!(fwd[0].kind, MsgKind::CohReq { op: CohOp::FwdDowngradeI }));
        assert!(!fx.iter().any(|e| matches!(e, HomeEffect::LocalDone { .. })));
        // the remote's (clean) response settles the line; the local write
        // replays and completes.
        let fx = a.on_message(
            Message::coh_rsp(ReqId(9), Node::Remote, CohOp::FwdDowngradeI, LineAddr(4), false, None),
            &mut ram,
        );
        assert!(
            fx.iter().any(|e| matches!(e, HomeEffect::LocalDone { tag: 42, .. })),
            "{fx:?}"
        );
        assert_eq!(a.state_of(LineAddr(4)).view, RemoteView::I);
    }

    #[test]
    fn cache_fills_serves_repeat_reads_slice_locally() {
        let policy = HomePolicy { cache_fills: true, ..HomePolicy::default() };
        let rules = generate_home(&reference_transitions(), policy);
        let mut a = HomeAgent::new(rules, policy, Some(Cache::new(64 * 1024, 4)));
        let mut ram = MemStore::new(LineAddr(0), 1 << 20);
        let mut l = [0u8; 128];
        l[0] = 0x5A;
        ram.write_line(LineAddr(6), &l);
        // first read: from RAM, and the home keeps a clean S copy
        let fx = a.on_message(
            Message::coh_req(ReqId(1), Node::Remote, CohOp::ReadShared, LineAddr(6)),
            &mut ram,
        );
        let HomeEffect::Respond { from_ram, msg } = &fx[0] else { panic!("{fx:?}") };
        assert!(*from_ram);
        assert_eq!(msg.payload.as_ref().unwrap()[0], 0x5A);
        assert_eq!(a.state_of(LineAddr(6)).own, CacheState::S);
        assert_eq!(a.stats.get("home_cache_fill"), 1);
        // remote releases, then re-reads: served from the home cache
        a.on_message(
            Message::coh_req(ReqId(2), Node::Remote, CohOp::VolDowngradeI, LineAddr(6)),
            &mut ram,
        );
        let fx = a.on_message(
            Message::coh_req(ReqId(3), Node::Remote, CohOp::ReadShared, LineAddr(6)),
            &mut ram,
        );
        let HomeEffect::Respond { from_ram, msg } = &fx[0] else { panic!("{fx:?}") };
        assert!(!*from_ram, "repeat read must be slice-local");
        assert_eq!(msg.payload.as_ref().unwrap()[0], 0x5A);
        assert_eq!(a.stats.get("home_cache_hit"), 1);
        // an exclusive writer drops the home copy, and its dirty
        // writeback lands in RAM (cache_writebacks stays off), so the
        // next read refills from the fresh bytes.
        a.on_message(
            Message::coh_req(ReqId(9), Node::Remote, CohOp::VolDowngradeI, LineAddr(6)),
            &mut ram,
        );
        a.on_message(
            Message::coh_req(ReqId(4), Node::Remote, CohOp::ReadExclusive, LineAddr(6)),
            &mut ram,
        );
        assert_eq!(a.state_of(LineAddr(6)).own, CacheState::I);
        let mut dirty = [0u8; 128];
        dirty[0] = 0x77;
        a.on_message(
            Message::coh_req_data(ReqId(5), Node::Remote, CohOp::VolDowngradeI, LineAddr(6), Box::new(dirty)),
            &mut ram,
        );
        assert_eq!(ram.read_line(LineAddr(6))[0], 0x77);
        let fx = a.on_message(
            Message::coh_req(ReqId(6), Node::Remote, CohOp::ReadShared, LineAddr(6)),
            &mut ram,
        );
        let HomeEffect::Respond { msg, .. } = &fx[0] else { panic!("{fx:?}") };
        assert_eq!(msg.payload.as_ref().unwrap()[0], 0x77, "stale home copy served");
    }

    #[test]
    fn surrender_copy_refuses_active_lines_then_flushes_dirty_copy() {
        let policy = HomePolicy { cache_writebacks: true, ..HomePolicy::default() };
        let rules = generate_home(&reference_transitions(), policy);
        let mut a = HomeAgent::new(rules, policy, Some(Cache::new(64 * 1024, 4)));
        let mut ram = MemStore::new(LineAddr(0), 1 << 20);
        // remote takes the line exclusive: surrender must refuse mid-flight
        a.on_message(
            Message::coh_req(ReqId(1), Node::Remote, CohOp::ReadExclusive, LineAddr(11)),
            &mut ram,
        );
        assert!(!a.surrender_copy(LineAddr(11), &mut ram), "line is remotely owned");
        // the dirty writeback lands in the home cache (cache_writebacks),
        // deliberately NOT in RAM — the handoff must not lose those bytes
        let mut dirty = [0u8; 128];
        dirty[0] = 0xCD;
        a.on_message(
            Message::coh_req_data(ReqId(2), Node::Remote, CohOp::VolDowngradeI, LineAddr(11), Box::new(dirty)),
            &mut ram,
        );
        assert_ne!(ram.read_line(LineAddr(11))[0], 0xCD, "writeback was cached, not stored");
        // quiescent now: surrender flushes the dirty copy and drops tracking
        assert!(a.surrender_copy(LineAddr(11), &mut ram));
        assert_eq!(a.state_of(LineAddr(11)), HomeSt::idle());
        assert_eq!(a.tracked_lines(), 0, "surrendered line must be untracked");
        assert_eq!(ram.read_line(LineAddr(11))[0], 0xCD, "dirty bytes must survive the handoff");
        assert_eq!(a.stats.get("surrendered"), 1);
        // an untouched line surrenders trivially (nothing to flush)
        assert!(a.surrender_copy(LineAddr(12), &mut ram));
    }

    #[test]
    fn adopt_remote_rebuilds_view_and_accepts_the_give_back() {
        let (mut a, mut ram) = mk(false);
        // failover: the previous home died while a remote held line 7
        // exclusive — the new home adopts the holder's view directly.
        a.adopt_remote(LineAddr(7), RemoteView::EorM, 1);
        let st = a.state_of(LineAddr(7));
        assert_eq!(st.view, RemoteView::EorM);
        assert_eq!(st.own, CacheState::I);
        assert_eq!(st.pending_fwd, None);
        assert_eq!(a.possession_count(LineAddr(7)), 1);
        assert_eq!(a.stats.get("adopted"), 1);
        // the adopted state is live protocol state: a dirty give-back
        // from the holder lands like any other and the line goes idle.
        let mut dirty = [0u8; 128];
        dirty[0] = 0xEE;
        a.on_message(
            Message::coh_req_data(ReqId(1), Node::Remote, CohOp::VolDowngradeI, LineAddr(7), Box::new(dirty)),
            &mut ram,
        );
        assert_eq!(a.state_of(LineAddr(7)), HomeSt::idle());
        assert_eq!(a.possession_count(LineAddr(7)), 0);
        assert_eq!(ram.read_line(LineAddr(7))[0], 0xEE, "adopted line's writeback must land");
    }

    #[test]
    fn export_import_roundtrip_is_state_exact() {
        let policy = HomePolicy { cache_fills: true, ..HomePolicy::default() };
        let rules = generate_home(&reference_transitions(), policy);
        let mut a = HomeAgent::new(rules.clone(), policy, Some(Cache::new(64 * 1024, 4)));
        let mut ram = MemStore::new(LineAddr(0), 1 << 20);
        let mut l = [0u8; 128];
        l[0] = 0x42;
        ram.write_line(LineAddr(3), &l);
        // remote shares line 3; the home keeps a clean S copy in-cache
        a.on_message(
            Message::coh_req(ReqId(1), Node::Remote, CohOp::ReadShared, LineAddr(3)),
            &mut ram,
        );
        let before = a.state_of(LineAddr(3));
        assert_eq!(before.own, CacheState::S);
        assert_eq!(a.possession_count(LineAddr(3)), 1);
        // export: the source slice forgets the line entirely
        let ex = a.export_line(LineAddr(3)).expect("tracked line must export");
        assert_eq!(a.state_of(LineAddr(3)), HomeSt::idle());
        assert_eq!(a.possession_count(LineAddr(3)), 0);
        assert!(a.cached_line(LineAddr(3)).is_none());
        // import into a fresh agent: directory word, epochs and cached
        // bytes all reappear verbatim
        let mut b = HomeAgent::new(rules, policy, Some(Cache::new(64 * 1024, 4)));
        let victims = b.import_line(LineAddr(3), ex, &mut ram);
        assert_eq!(victims, 0);
        assert_eq!(b.state_of(LineAddr(3)), before);
        assert_eq!(b.possession_count(LineAddr(3)), 1);
        // the imported copy is live: a repeat read is served slice-locally
        b.on_message(
            Message::coh_req(ReqId(2), Node::Remote, CohOp::VolDowngradeI, LineAddr(3)),
            &mut ram,
        );
        let fx = b.on_message(
            Message::coh_req(ReqId(3), Node::Remote, CohOp::ReadShared, LineAddr(3)),
            &mut ram,
        );
        let HomeEffect::Respond { from_ram, msg } = &fx[0] else { panic!("{fx:?}") };
        assert!(!*from_ram, "imported copy must serve from the home cache");
        assert_eq!(msg.payload.as_ref().unwrap()[0], 0x42);
        // a line nobody tracks exports as None
        assert!(a.export_line(LineAddr(50)).is_none());
    }

    #[test]
    fn import_into_uncached_slice_retires_dirty_copy_to_ram() {
        // cache_writebacks parks dirty remote writebacks in the home cache
        let policy = HomePolicy { cache_writebacks: true, ..HomePolicy::default() };
        let rules = generate_home(&reference_transitions(), policy);
        let mut a = HomeAgent::new(rules, policy, Some(Cache::new(64 * 1024, 4)));
        let mut ram = MemStore::new(LineAddr(0), 1 << 20);
        a.on_message(
            Message::coh_req(ReqId(1), Node::Remote, CohOp::ReadExclusive, LineAddr(9)),
            &mut ram,
        );
        let mut dirty = [0u8; 128];
        dirty[0] = 0xD1;
        a.on_message(
            Message::coh_req_data(ReqId(2), Node::Remote, CohOp::VolDowngradeI, LineAddr(9), Box::new(dirty)),
            &mut ram,
        );
        assert_ne!(ram.read_line(LineAddr(9))[0], 0xD1, "writeback cached, not stored");
        let ex = a.export_line(LineAddr(9)).expect("cached copy must export");
        // shrink-to-uncached: the importing slice has no home cache, so
        // the freshest bytes must retire to RAM instead of vanishing
        let (mut b, _) = mk(false);
        let victims = b.import_line(LineAddr(9), ex, &mut ram);
        assert_eq!(victims, 1);
        assert_eq!(ram.read_line(LineAddr(9))[0], 0xD1, "dirty bytes must survive the shrink");
        assert_eq!(b.state_of(LineAddr(9)).own, CacheState::I);
    }

    #[test]
    fn dead_sibling_spreads_ownership_across_survivors() {
        let rules = generate_home(&reference_transitions(), HomePolicy::default());
        let n = 4u64;
        let mut slices: Vec<HomeAgent> = (0..n)
            .map(|i| {
                let mut a =
                    HomeAgent::new_slice(rules.clone(), HomePolicy::default(), None, i, n);
                if i != 1 {
                    a.set_dead_sibling(Some(1));
                }
                a
            })
            .collect();
        let mut spread = [0u64; 4];
        for addr in 0..4096u64 {
            let owners: Vec<u64> = (0..n)
                .filter(|&i| i != 1 && slices[i as usize].owns(LineAddr(addr)))
                .collect();
            if addr % n == 1 {
                // orphaned range: exactly one survivor adopts each line
                assert_eq!(owners.len(), 1, "addr {addr}: {owners:?}");
                assert_ne!(owners[0], 1);
                spread[owners[0] as usize] += 1;
            } else {
                assert_eq!(owners, vec![addr % n], "natural lines keep their owner");
            }
        }
        // the 1024 orphaned lines spread evenly over the 3 survivors
        assert_eq!(spread[1], 0);
        for s in [0usize, 2, 3] {
            assert!(spread[s] >= 300, "survivor {s} got {} lines", spread[s]);
        }
        // rejoin: clearing the mark restores the natural interleave
        for (i, a) in slices.iter_mut().enumerate() {
            if i != 1 {
                a.set_dead_sibling(None);
            }
        }
        for addr in 0..256u64 {
            for (i, a) in slices.iter().enumerate() {
                assert_eq!(a.owns(LineAddr(addr)), addr % n == i as u64);
            }
        }
    }

    #[test]
    fn hidden_o_shares_dirty_line_without_ram_write() {
        let (mut a, mut ram) = mk(true);
        // make the home copy dirty via a local write
        let fx = a.local_access(LineAddr(8), true, 1, &mut ram);
        assert!(fx.iter().any(|e| matches!(e, HomeEffect::LocalDone { .. })));
        assert_eq!(a.state_of(LineAddr(8)).own, CacheState::M);
        // remote reads: transition 10 with hidden_o policy
        let fx = a.on_message(
            Message::coh_req(ReqId(5), Node::Remote, CohOp::ReadShared, LineAddr(8)),
            &mut ram,
        );
        assert!(
            !fx.iter().any(|e| matches!(e, HomeEffect::RamWrite { .. })),
            "hidden O must not write RAM: {fx:?}"
        );
        let st = a.state_of(LineAddr(8));
        assert_eq!(st.own, CacheState::S);
        assert!(st.own_dirty, "home keeps the hidden-O dirty bit");
        assert_eq!(st.view, RemoteView::S);
    }
}
