//! Analytic FPGA resource model — regenerates Table 2 ("ECI hardware
//! resource consumption, percentage over the resources available in a
//! Xilinx VU9P") and quantifies the §3.4 claim that protocol subsetting
//! saves real area.
//!
//! The paper reports one aggregate row per link; the per-component
//! breakdown below is this repo's own design accounting, calibrated so a
//! full-protocol link totals close to the paper's 46,186 LUTs / 32,777
//! REGs / 112.5 BRAM36 (Table 2). Components are sized from first-order
//! structural arguments (buffer bytes -> BRAM36, datapath width x stages
//! -> LUT/FF), so configuration changes (credits, VC count, directory
//! states) move the estimate the way they would move synthesis results.

use crate::proto::subset::Subset;
use crate::transport::vc::{NUM_COHERENCE_VCS, NUM_VCS};

/// Xilinx XCVU9P capacity (UltraScale+ data sheet).
pub const VU9P_LUTS: u64 = 1_182_240;
pub const VU9P_REGS: u64 = 2_364_480;
pub const VU9P_BRAM36: f64 = 2_160.0;

/// One RTL component's estimated cost.
#[derive(Clone, Debug)]
pub struct Component {
    pub name: String,
    pub luts: u64,
    pub regs: u64,
    pub bram36: f64,
}

/// Stack configuration knobs that affect area.
#[derive(Clone, Copy, Debug)]
pub struct StackConfig {
    /// Receiver buffer slots per VC (credits).
    pub credits_per_vc: u32,
    /// Serial lanes in the link.
    pub lanes: u32,
    /// Number of home-directory states the protocol subset needs
    /// (1 for the stateless read-only home).
    pub home_states: usize,
    /// Does the home track per-line directory state at all?
    pub tracks_state: bool,
    /// Directory-cache entries (the directory is a BRAM cache backed by
    /// DRAM, as in real home-node designs) when tracking.
    pub dir_cache_entries: u64,
}

impl StackConfig {
    pub fn reference() -> StackConfig {
        StackConfig {
            credits_per_vc: 16,
            lanes: 24,
            home_states: 8,
            tracks_state: true,
            // 128K-entry directory cache in BRAM, DRAM-backed
            dir_cache_entries: 128 << 10,
        }
    }

    pub fn for_subset(subset: &Subset) -> StackConfig {
        let mut c = StackConfig::reference();
        c.home_states = subset.home_state_count();
        c.tracks_state = subset.home_tracks_state;
        if !subset.home_tracks_state {
            c.dir_cache_entries = 0;
        }
        c
    }
}

/// BRAM36 blocks for `bytes` of buffering spread over `buffers` physical
/// FIFOs (36 Kb = 4.5 KiB per block; width-constrained buffers round up
/// to halves).
fn brams_for(bytes: u64, buffers: u64) -> f64 {
    let per = ((bytes as f64 / buffers as f64) / 4608.0).ceil().max(0.5);
    per * buffers as f64
}

/// Estimate the per-link ECI stack (VC + link + transaction + phys +
/// protocol engine) for a given configuration.
pub fn eci_stack(cfg: StackConfig) -> Vec<Component> {
    let mut v = Vec::new();

    // --- VC layer: per-VC ingress/egress buffering + arbitration -------
    // Each VC buffers `credits` frames of up to 160 B each direction.
    let vc_buf_bytes = cfg.credits_per_vc as u64 * 160 * 2;
    v.push(Component {
        name: format!("vc layer ({NUM_VCS} VCs, {} credits)", cfg.credits_per_vc),
        // mux/demux + rank-RR arbiter + credit counters: ~600 LUT/VC
        luts: 600 * NUM_VCS as u64 + 1_800,
        regs: 380 * NUM_VCS as u64,
        bram36: brams_for(vc_buf_bytes * NUM_VCS as u64, NUM_VCS as u64),
    });

    // --- link layer: framing, packing, header build/parse ---------------
    v.push(Component {
        name: "link layer (framer/parser)".into(),
        luts: 7_200,
        regs: 5_400,
        bram36: 4.0,
    });

    // --- transaction layer: credits, CRC, replay buffer ------------------
    // go-back-N replay buffer: one ack window (16) x worst-case frame per
    // coherence VC.
    let replay_bytes = 16 * 160 * NUM_COHERENCE_VCS as u64;
    v.push(Component {
        name: "transaction layer (CRC + replay)".into(),
        luts: 6_400,
        regs: 4_800,
        bram36: brams_for(replay_bytes, 10),
    });

    // --- physical layer: lane bonding, gearboxes, CDC fifos --------------
    v.push(Component {
        name: format!("physical layer ({} lanes)", cfg.lanes),
        luts: 420 * cfg.lanes as u64,
        regs: 300 * cfg.lanes as u64,
        bram36: cfg.lanes as f64 * 0.5, // CDC fifo per lane
    });

    // --- protocol engine: the (generated) state machine ------------------
    // LUT cost grows with the number of distinguishable states.
    let states = cfg.home_states.max(1) as u64;
    v.push(Component {
        name: format!("protocol engine ({states} home states)"),
        luts: 2_600 + 900 * states,
        regs: 1_900 + 560 * states,
        bram36: 0.0,
    });

    // --- directory cache: BRAM-resident, DRAM-backed (real home-node
    // designs cache the directory; a flat directory for gigabytes of
    // exported memory would not fit on-chip) ------------------------------
    if cfg.tracks_state && cfg.dir_cache_entries > 0 {
        let state_bits = (64 - (states - 1).leading_zeros().min(63) as u64).max(1);
        let tag_bits = 13;
        let bits = cfg.dir_cache_entries * (state_bits + tag_bits);
        v.push(Component {
            name: format!("directory cache ({} entries)", cfg.dir_cache_entries),
            luts: 2_500,
            regs: 3_600,
            bram36: bits as f64 / 36_864.0,
        });
    }

    v
}

/// Aggregate totals.
pub fn totals(components: &[Component]) -> Component {
    Component {
        name: "ECI per link".into(),
        luts: components.iter().map(|c| c.luts).sum(),
        regs: components.iter().map(|c| c.regs).sum(),
        bram36: components.iter().map(|c| c.bram36).sum(),
    }
}

/// Percentages against the VU9P.
pub fn percentages(t: &Component) -> (f64, f64, f64) {
    (
        t.luts as f64 / VU9P_LUTS as f64 * 100.0,
        t.regs as f64 / VU9P_REGS as f64 * 100.0,
        t.bram36 / VU9P_BRAM36 * 100.0,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_stack_lands_near_paper_table2() {
        let t = totals(&eci_stack(StackConfig::reference()));
        // paper: 46,186 LUTs / 32,777 REGs / 112.5 BRAM36 per link
        let lut_err = (t.luts as f64 - 46_186.0).abs() / 46_186.0;
        let reg_err = (t.regs as f64 - 32_777.0).abs() / 32_777.0;
        let bram_err = (t.bram36 - 112.5).abs() / 112.5;
        assert!(lut_err < 0.15, "LUTs {} vs 46186", t.luts);
        assert!(reg_err < 0.15, "REGs {} vs 32777", t.regs);
        assert!(bram_err < 0.20, "BRAM {} vs 112.5", t.bram36);
        // and the paper's percentages
        let (pl, pr, pb) = percentages(&t);
        assert!((pl - 3.91).abs() < 0.6, "LUT% {pl}");
        assert!((pr - 1.39).abs() < 0.3, "REG% {pr}");
        assert!((pb - 5.23).abs() < 1.1, "BRAM% {pb}");
    }

    #[test]
    fn stateless_subset_saves_directory_bram_and_engine_luts() {
        let full = totals(&eci_stack(StackConfig::for_subset(&Subset::full_symmetric())));
        let stateless =
            totals(&eci_stack(StackConfig::for_subset(&Subset::stateless_readonly())));
        assert!(stateless.bram36 < full.bram36 * 0.7, "{} vs {}", stateless.bram36, full.bram36);
        assert!(stateless.luts < full.luts);
    }

    #[test]
    fn credits_move_vc_buffer_brams() {
        let mut small = StackConfig::reference();
        small.credits_per_vc = 4;
        let mut big = StackConfig::reference();
        big.credits_per_vc = 64;
        let ts = totals(&eci_stack(small));
        let tb = totals(&eci_stack(big));
        assert!(tb.bram36 > ts.bram36);
    }
}
