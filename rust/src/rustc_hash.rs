//! Vendored minimal `rustc_hash` shim (the offline registry has no
//! third-party crates — same policy as [`crate::sim::rng`] and
//! [`crate::ptest`]). Provides the Fx multiply-rotate hasher behind the
//! usual `FxHashMap`/`FxHashSet` aliases; the keys hashed in this crate
//! are small fixed-size types ([`crate::proto::messages::LineAddr`],
//! [`crate::proto::messages::ReqId`], spec state tuples), exactly the
//! regime Fx-style hashing is built for.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// A `HashMap` using [`FxHasher`].
pub type FxHashMap<K, V> = HashMap<K, V, BuildHasherDefault<FxHasher>>;
/// A `HashSet` using [`FxHasher`].
pub type FxHashSet<T> = HashSet<T, BuildHasherDefault<FxHasher>>;

/// Fast non-cryptographic hasher: per-word multiply-rotate mixing.
/// Deterministic (no per-process seed), which also keeps simulation
/// iteration order stable run to run for a given map population order.
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher {
    #[inline]
    fn mix(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in chunks.by_ref() {
            self.mix(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut tail = [0u8; 8];
            tail[..rem.len()].copy_from_slice(rem);
            // length in the top byte so "ab" and "ab\0" differ
            tail[7] = rem.len() as u8;
            self.mix(u64::from_le_bytes(tail));
        }
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.mix(v as u64);
    }
    #[inline]
    fn write_u16(&mut self, v: u16) {
        self.mix(v as u64);
    }
    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.mix(v as u64);
    }
    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.mix(v);
    }
    #[inline]
    fn write_u128(&mut self, v: u128) {
        self.mix(v as u64);
        self.mix((v >> 64) as u64);
    }
    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.mix(v as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_discriminating() {
        let h = |bytes: &[u8]| {
            let mut x = FxHasher::default();
            x.write(bytes);
            x.finish()
        };
        assert_eq!(h(b"hello"), h(b"hello"));
        assert_ne!(h(b"hello"), h(b"hellp"));
        assert_ne!(h(b"ab"), h(b"ab\0"));
        assert_ne!(h(b""), h(b"\0"));
    }

    #[test]
    fn map_and_set_work_with_crate_key_types() {
        use crate::proto::messages::LineAddr;
        let mut m: FxHashMap<LineAddr, u32> = FxHashMap::default();
        for i in 0..1000 {
            m.insert(LineAddr(i), i as u32);
        }
        assert_eq!(m.len(), 1000);
        assert_eq!(m.get(&LineAddr(77)), Some(&77));
        let mut s: FxHashSet<u64> = FxHashSet::default();
        s.insert(42);
        assert!(s.contains(&42));
    }

    #[test]
    fn u64_keys_spread_over_buckets() {
        // sanity: sequential keys must not collapse to one hash
        let mut seen = FxHashSet::default();
        for i in 0..256u64 {
            let mut x = FxHasher::default();
            x.write_u64(i);
            seen.insert(x.finish());
        }
        assert_eq!(seen.len(), 256);
    }
}
