//! Transaction layer: link state, credit-based flow control bookkeeping,
//! and the error/replay machinery (paper §4.2: "The transaction layer
//! manages link state, credit based flow control, and error and replay
//! mechanisms to ensure delivery of messages").
//!
//! Reliability is go-back-N: the sender keeps transmitted frames in a
//! replay buffer until cumulatively acked; the receiver accepts frames
//! strictly in sequence, dropping corrupted or out-of-order frames and
//! requesting retransmission with a `Nack(expected)`. Acks piggyback
//! every `ACK_INTERVAL` frames (and on every nack).

use std::collections::VecDeque;

use super::link::{Control, Frame, Seq};

/// Cumulative-ack cadence (frames).
pub const ACK_INTERVAL: u64 = 16;

/// Link-state of one direction's sender.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum LinkState {
    /// Training/alignment (we start Up; Down is reachable via `reset`).
    Down,
    Up,
}

/// Sender half: sequence numbering + replay buffer.
pub struct TxState {
    pub state: LinkState,
    next_seq: Seq,
    /// Frames sent but not yet cumulatively acked, oldest first.
    replay: VecDeque<Frame>,
    /// Pending retransmissions (rewound from the replay buffer).
    resend: VecDeque<Frame>,
    /// Stats.
    pub sent: u64,
    pub retransmitted: u64,
}

impl Default for TxState {
    fn default() -> Self {
        Self::new()
    }
}

impl TxState {
    pub fn new() -> TxState {
        TxState {
            state: LinkState::Up,
            next_seq: 0,
            replay: VecDeque::new(),
            resend: VecDeque::new(),
            sent: 0,
            retransmitted: 0,
        }
    }

    /// Frame a fresh message (or pull a pending retransmission, which has
    /// priority). Returns the frame to put on the wire.
    pub fn next_frame(&mut self, fresh: Option<crate::proto::messages::Message>) -> Option<Frame> {
        assert_eq!(self.state, LinkState::Up, "link is down");
        if let Some(f) = self.resend.pop_front() {
            self.retransmitted += 1;
            self.sent += 1;
            return Some(f);
        }
        let msg = fresh?;
        let f = Frame::new(self.next_seq, msg);
        self.next_seq += 1;
        self.replay.push_back(f.clone());
        self.sent += 1;
        Some(f)
    }

    /// Is a retransmission queued? (Retransmissions don't consume fresh
    /// messages or credits — the credit was spent on first transmission.)
    pub fn has_resend(&self) -> bool {
        !self.resend.is_empty()
    }

    /// Handle a control frame from the receiver.
    pub fn on_control(&mut self, c: Control) {
        match c {
            // per-VC controls belong to the rel layer's sequencing
            Control::VcAck(..) | Control::VcNack(..) | Control::VcSack(..) => {
                debug_assert!(false, "rel-layer control routed to the transaction layer: {c:?}");
            }
            Control::Ack(upto) => {
                while let Some(f) = self.replay.front() {
                    if f.seq <= upto {
                        self.replay.pop_front();
                    } else {
                        break;
                    }
                }
            }
            Control::Nack(from) => {
                // ack everything before `from`, rewind the rest
                while let Some(f) = self.replay.front() {
                    if f.seq < from {
                        self.replay.pop_front();
                    } else {
                        break;
                    }
                }
                self.resend.clear();
                for f in self.replay.iter() {
                    // retransmitted copies are fresh (uncorrupted) frames
                    let mut g = f.clone();
                    g.intact = true;
                    self.resend.push_back(g);
                }
            }
        }
    }

    pub fn unacked(&self) -> usize {
        self.replay.len()
    }

    /// Drop link (for failure-injection tests); clears nothing — replay
    /// buffer survives a link bounce, exactly so no message is lost.
    pub fn reset(&mut self) {
        self.state = LinkState::Down;
    }
    pub fn bring_up(&mut self) {
        self.state = LinkState::Up;
    }
}

/// Receiver half: in-order acceptance + ack/nack generation.
pub struct RxState {
    expected: Seq,
    /// A nack for this seq was already issued; suppress duplicates until
    /// progress resumes.
    nacked: Option<Seq>,
    frames_since_ack: u64,
    /// Stats.
    pub accepted: u64,
    pub dropped_corrupt: u64,
    pub dropped_out_of_order: u64,
}

/// Result of processing one arriving frame.
#[derive(Debug, PartialEq, Eq)]
pub enum RxResult {
    /// Deliver the message upward; optionally send a control frame back.
    Deliver(Option<Control>),
    /// Frame dropped; optionally send a control frame back.
    Drop(Option<Control>),
}

impl Default for RxState {
    fn default() -> Self {
        Self::new()
    }
}

impl RxState {
    pub fn new() -> RxState {
        RxState {
            expected: 0,
            nacked: None,
            frames_since_ack: 0,
            accepted: 0,
            dropped_corrupt: 0,
            dropped_out_of_order: 0,
        }
    }

    pub fn on_frame(&mut self, f: &Frame) -> RxResult {
        if !f.intact {
            self.dropped_corrupt += 1;
            // corruption always renews the nack — a corrupted
            // *retransmission* must not be silently absorbed by the
            // duplicate-suppression below, or the link deadlocks (both
            // ends waiting). Out-of-order drops keep the suppression.
            self.nacked = Some(self.expected);
            return RxResult::Drop(Some(Control::Nack(self.expected)));
        }
        if f.seq != self.expected {
            // duplicate (already delivered) or gap (a corrupted frame was
            // dropped earlier): go-back-N discards either way.
            self.dropped_out_of_order += 1;
            if f.seq > self.expected {
                return RxResult::Drop(self.nack());
            }
            return RxResult::Drop(None); // stale duplicate, already acked
        }
        self.expected += 1;
        self.nacked = None;
        self.accepted += 1;
        self.frames_since_ack += 1;
        let ctl = if self.frames_since_ack >= ACK_INTERVAL {
            self.frames_since_ack = 0;
            Some(Control::Ack(self.expected - 1))
        } else {
            None
        };
        RxResult::Deliver(ctl)
    }

    fn nack(&mut self) -> Option<Control> {
        if self.nacked == Some(self.expected) {
            None // already requested this replay
        } else {
            self.nacked = Some(self.expected);
            Some(Control::Nack(self.expected))
        }
    }

    pub fn expected_seq(&self) -> Seq {
        self.expected
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::messages::{CohOp, LineAddr, Message, ReqId};
    use crate::proto::states::Node;

    fn msg(i: u64) -> Message {
        Message::coh_req(ReqId(i as u32), Node::Remote, CohOp::ReadShared, LineAddr(i))
    }

    #[test]
    fn in_order_delivery_and_periodic_acks() {
        let mut tx = TxState::new();
        let mut rx = RxState::new();
        let mut acks = 0;
        for i in 0..64 {
            let f = tx.next_frame(Some(msg(i))).unwrap();
            match rx.on_frame(&f) {
                RxResult::Deliver(ctl) => {
                    if let Some(Control::Ack(upto)) = ctl {
                        acks += 1;
                        tx.on_control(Control::Ack(upto));
                    }
                }
                r => panic!("unexpected {r:?}"),
            }
        }
        assert_eq!(rx.accepted, 64);
        assert_eq!(acks, 64 / ACK_INTERVAL);
        assert!(tx.unacked() < ACK_INTERVAL as usize);
    }

    #[test]
    fn corrupted_frame_triggers_go_back_n() {
        let mut tx = TxState::new();
        let mut rx = RxState::new();
        // send 0,1,2; corrupt 1 in flight
        let f0 = tx.next_frame(Some(msg(0))).unwrap();
        let mut f1 = tx.next_frame(Some(msg(1))).unwrap();
        let f2 = tx.next_frame(Some(msg(2))).unwrap();
        f1.intact = false;

        assert!(matches!(rx.on_frame(&f0), RxResult::Deliver(_)));
        // corrupt frame: dropped + nack(1)
        match rx.on_frame(&f1) {
            RxResult::Drop(Some(Control::Nack(1))) => {}
            r => panic!("unexpected {r:?}"),
        }
        // f2 arrives out of order: dropped, nack suppressed (same seq)
        match rx.on_frame(&f2) {
            RxResult::Drop(None) => {}
            r => panic!("unexpected {r:?}"),
        }
        // sender rewinds from 1
        tx.on_control(Control::Nack(1));
        assert!(tx.has_resend());
        let r1 = tx.next_frame(None).unwrap();
        assert_eq!(r1.seq, 1);
        assert!(r1.intact);
        let r2 = tx.next_frame(None).unwrap();
        assert_eq!(r2.seq, 2);
        assert!(matches!(rx.on_frame(&r1), RxResult::Deliver(_)));
        assert!(matches!(rx.on_frame(&r2), RxResult::Deliver(_)));
        assert_eq!(rx.expected_seq(), 3);
        assert_eq!(tx.retransmitted, 2);
    }

    #[test]
    fn stale_duplicates_are_dropped_silently() {
        let mut tx = TxState::new();
        let mut rx = RxState::new();
        let f0 = tx.next_frame(Some(msg(0))).unwrap();
        assert!(matches!(rx.on_frame(&f0), RxResult::Deliver(_)));
        // replayed copy of an already-delivered frame
        match rx.on_frame(&f0) {
            RxResult::Drop(None) => {}
            r => panic!("unexpected {r:?}"),
        }
    }

    #[test]
    fn ack_trims_replay_buffer() {
        let mut tx = TxState::new();
        for i in 0..10 {
            tx.next_frame(Some(msg(i)));
        }
        assert_eq!(tx.unacked(), 10);
        tx.on_control(Control::Ack(6));
        assert_eq!(tx.unacked(), 3);
    }

    #[test]
    fn no_message_lost_under_random_corruption() {
        // property-style: random 5% corruption; every message must arrive
        // exactly once, in order.
        use crate::sim::rng::Rng;
        let mut rng = Rng::new(42);
        let mut tx = TxState::new();
        let mut rx = RxState::new();
        let total = 2_000u64;
        let mut next_fresh = 0u64;
        let mut delivered: Vec<u64> = Vec::new();
        // simple half-duplex loop: one frame at a time, immediate control
        while (delivered.len() as u64) < total {
            let fresh = if !tx.has_resend() && next_fresh < total {
                let m = msg(next_fresh);
                next_fresh += 1;
                Some(m)
            } else {
                None
            };
            let Some(mut f) = tx.next_frame(fresh) else {
                // nothing to send but not done: we must be waiting on a
                // nack that was suppressed — force one (timeout model)
                tx.on_control(Control::Nack(rx.expected_seq()));
                continue;
            };
            if rng.chance(0.05) {
                f.intact = false;
            }
            match rx.on_frame(&f) {
                RxResult::Deliver(ctl) => {
                    delivered.push(f.msg.addr.0);
                    if let Some(c) = ctl {
                        tx.on_control(c);
                    }
                }
                RxResult::Drop(ctl) => {
                    if let Some(c) = ctl {
                        tx.on_control(c);
                    }
                }
            }
        }
        assert_eq!(delivered, (0..total).collect::<Vec<_>>());
    }
}
