//! The layered ECI transport (paper §4.2): virtual channels ([`vc`]),
//! link framing ([`link`]), reliable delivery with credits and replay
//! ([`transaction`]), the serial-lane physical model ([`phys`]), and the
//! framed admission adapter for generator traffic ([`ingress`]).
//!
//! [`LinkDir`] composes the four layers for one direction of the link;
//! the full-duplex link is two `LinkDir`s cross-wired by the machine
//! model ([`crate::machine`]), which also carries credit returns and
//! ack/nack control frames on the reverse direction.

pub mod ingress;
pub mod link;
pub mod phys;
pub mod transaction;
pub mod vc;

use crate::proto::messages::Message;
use crate::proto::states::Node;
use crate::sim::rng::Rng;
use crate::sim::time::Time;

pub use ingress::{FramedIngress, IngressBatcher};
pub use link::{Control, Frame, CONTROL_BYTES};
pub use phys::{PhysConfig, PhysDir};
pub use transaction::{RxResult, RxState, TxState};
pub use vc::{class_of_vc, vc_for, Credits, VcClass, VcId, VcMux, NUM_COHERENCE_VCS, NUM_VCS};

/// Full configuration of one link direction.
#[derive(Clone, Copy, Debug)]
pub struct LinkConfig {
    pub phys: PhysConfig,
    /// Receiver buffer slots per VC (= sender credits). This bounds the
    /// number of in-flight messages per VC and is the first-order knob
    /// behind the throughput gap of Table 3 (throughput ≈ in-flight ×
    /// line / round-trip latency).
    pub credits_per_vc: u32,
}

impl LinkConfig {
    /// Enzian + ECI as evaluated in the paper.
    pub fn eci() -> LinkConfig {
        LinkConfig { phys: PhysConfig::eci(), credits_per_vc: 40 }
    }
    /// Native 2-socket ThunderX-1 server (Table 3 baseline).
    pub fn native() -> LinkConfig {
        LinkConfig { phys: PhysConfig::native(), credits_per_vc: 40 }
    }
}

/// One direction of the link: everything between `send()` at one node and
/// message delivery at the other.
pub struct LinkDir {
    pub cfg: LinkConfig,
    pub mux: VcMux,
    /// Credits available for transmitting toward the peer.
    pub credits: Credits,
    pub tx: TxState,
    pub rx: RxState,
    pub phys: PhysDir,
}

impl LinkDir {
    pub fn new(cfg: LinkConfig, owner: Node, rng: Rng) -> LinkDir {
        LinkDir {
            cfg,
            mux: VcMux::new(owner),
            credits: Credits::new(cfg.credits_per_vc),
            tx: TxState::new(),
            rx: RxState::new(),
            phys: PhysDir::new(cfg.phys, rng),
        }
    }

    /// Queue a message for transmission.
    pub fn send(&mut self, msg: Message) {
        self.mux.enqueue(msg);
    }

    /// Attempt to put the next frame on the wire at `now`. Returns the
    /// frame and its arrival time at the peer. Retransmissions have
    /// priority and do not consume credits (their credit is still held —
    /// the receiver never freed the original slot).
    pub fn try_launch(&mut self, now: Time) -> Option<(Time, Frame)> {
        if self.tx.has_resend() {
            let f = self.tx.next_frame(None).expect("resend queued");
            let (arrival, intact) = self.phys.transmit(now, f.wire_bytes());
            let mut f = f;
            f.intact = intact;
            return Some((arrival, f));
        }
        let (vc, msg) = self.mux.arbitrate(&self.credits)?;
        let consumed = self.credits.consume(vc);
        debug_assert!(consumed, "arbiter returned a creditless VC");
        let f = self.tx.next_frame(Some(msg)).expect("fresh message");
        let (arrival, intact) = self.phys.transmit(now, f.wire_bytes());
        let mut f = f;
        f.intact = intact;
        Some((arrival, f))
    }

    /// Anything transmittable right now?
    pub fn can_launch(&self) -> bool {
        if self.tx.has_resend() {
            return true;
        }
        (0..NUM_VCS as u8).any(|vc| {
            self.mux.pending_on(VcId(vc)) > 0 && self.credits.available(VcId(vc)) > 0
        })
    }

    /// Process an arriving frame (receiver side of this direction).
    pub fn receive(&mut self, frame: Frame) -> (Option<Message>, Option<Control>) {
        match self.rx.on_frame(&frame) {
            RxResult::Deliver(ctl) => (Some(frame.msg), ctl),
            RxResult::Drop(ctl) => (None, ctl),
        }
    }

    /// Control frame came back from the peer.
    pub fn on_control(&mut self, c: Control) {
        self.tx.on_control(c);
    }

    /// Peer consumed a message from `vc`: its buffer slot is free again.
    pub fn credit_return(&mut self, vc: VcId) {
        self.credits.restore(vc);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::messages::{CohOp, LineAddr, ReqId};
    use crate::sim::time::Duration;

    fn mk(owner: Node) -> LinkDir {
        LinkDir::new(LinkConfig::eci(), owner, Rng::new(3))
    }

    #[test]
    fn single_message_latency_is_pipeline_plus_serialization() {
        let mut d = mk(Node::Remote);
        d.send(Message::coh_req(ReqId(0), Node::Remote, CohOp::ReadShared, LineAddr(0)));
        let (arrival, frame) = d.try_launch(Time(0)).unwrap();
        assert!(frame.intact);
        // 32B at ~29 GB/s ~ 1.1ns + 120ns pipeline
        assert!(arrival.as_ns() > 120.0 && arrival.as_ns() < 122.0, "{arrival}");
        let (msg, _) = d.receive(frame);
        assert!(msg.is_some());
    }

    #[test]
    fn credits_bound_in_flight_messages() {
        let mut d = mk(Node::Remote);
        let per_vc = d.cfg.credits_per_vc;
        // flood one VC (even requests)
        for i in 0..(per_vc + 10) {
            d.send(Message::coh_req(ReqId(i), Node::Remote, CohOp::ReadShared, LineAddr(2 * i as u64)));
        }
        let mut launched = 0;
        while d.try_launch(Time(0)).is_some() {
            launched += 1;
        }
        assert_eq!(launched, per_vc, "launches must stop at the credit limit");
        // returning one credit allows exactly one more
        d.credit_return(VcId(0));
        assert!(d.can_launch());
        assert!(d.try_launch(Time(0)).is_some());
        assert!(d.try_launch(Time(0)).is_none());
    }

    #[test]
    fn end_to_end_replay_over_lossy_phys() {
        let mut cfg = LinkConfig::eci();
        cfg.phys.frame_error_rate = 0.10;
        let mut dir = LinkDir::new(cfg, Node::Remote, Rng::new(11));
        let total = 500u32;
        for i in 0..total {
            dir.send(Message::coh_req(ReqId(i), Node::Remote, CohOp::ReadShared, LineAddr(i as u64)));
        }
        let mut now = Time(0);
        let mut got: Vec<u32> = Vec::new();
        let mut stall = 0;
        while (got.len() as u32) < total {
            // return credits promptly so flow control never starves
            match dir.try_launch(now) {
                Some((arrival, frame)) => {
                    now = arrival;
                    let vc = frame.vc;
                    let (msg, ctl) = dir.receive(frame);
                    if let Some(m) = msg {
                        got.push(m.id.0);
                        dir.credit_return(vc);
                    }
                    if let Some(c) = ctl {
                        dir.on_control(c);
                    }
                    stall = 0;
                }
                None => {
                    // suppressed nack after a drop: timeout-driven replay
                    stall += 1;
                    assert!(stall < 3, "link deadlocked");
                    let exp = dir.rx.expected_seq();
                    dir.on_control(Control::Nack(exp));
                    now = now + Duration::from_ns(100);
                }
            }
        }
        assert_eq!(got, (0..total).collect::<Vec<_>>());
        assert!(dir.phys.injected_errors > 0, "the test should have exercised replay");
        assert!(dir.tx.retransmitted as u64 >= dir.phys.injected_errors);
    }
}
