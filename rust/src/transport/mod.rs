//! The layered ECI transport (paper §4.2): virtual channels ([`vc`]),
//! link framing ([`link`]), reliable delivery with credits and replay
//! ([`transaction`]), the serial-lane physical model ([`phys`]), and the
//! framed admission adapter for generator traffic ([`ingress`]).
//!
//! [`LinkDir`] composes the four layers for one direction of the link;
//! the full-duplex link is two `LinkDir`s cross-wired by the machine
//! model ([`crate::machine`]), which also carries credit returns and
//! ack/nack control frames on the reverse direction.

pub mod ingress;
pub mod link;
pub mod phys;
pub mod rel;
pub mod transaction;
pub mod vc;

use crate::proto::messages::Message;
use crate::proto::states::Node;
use crate::sim::rng::Rng;
use crate::sim::time::Time;

pub use ingress::{FramedIngress, IngressBatcher};
pub use link::{Control, Frame, CONTROL_BYTES};
pub use phys::{PhysConfig, PhysDir};
pub use rel::{FaultConfig, FaultSpec, RelConfig, RelMode, RelState, RelStats};
pub use transaction::{RxResult, RxState, TxState};
pub use vc::{class_of_vc, vc_for, Credits, VcClass, VcId, VcMux, NUM_COHERENCE_VCS, NUM_VCS};

/// Full configuration of one link direction.
#[derive(Clone, Copy, Debug)]
pub struct LinkConfig {
    pub phys: PhysConfig,
    /// Receiver buffer slots per VC (= sender credits). This bounds the
    /// number of in-flight messages per VC and is the first-order knob
    /// behind the throughput gap of Table 3 (throughput ≈ in-flight ×
    /// line / round-trip latency).
    pub credits_per_vc: u32,
}

impl LinkConfig {
    /// Enzian + ECI as evaluated in the paper.
    pub fn eci() -> LinkConfig {
        LinkConfig { phys: PhysConfig::eci(), credits_per_vc: 40 }
    }
    /// Native 2-socket ThunderX-1 server (Table 3 baseline).
    pub fn native() -> LinkConfig {
        LinkConfig { phys: PhysConfig::native(), credits_per_vc: 40 }
    }
}

/// One direction of the link: everything between `send()` at one node and
/// message delivery at the other.
pub struct LinkDir {
    pub cfg: LinkConfig,
    pub mux: VcMux,
    /// Credits available for transmitting toward the peer.
    pub credits: Credits,
    pub tx: TxState,
    pub rx: RxState,
    pub phys: PhysDir,
    /// Reliable-lossy extension ([`rel`]): per-VC sequencing/replay plus
    /// a deterministic fault injector. `None` = the link-global
    /// transaction layer above does the sequencing and the wire only
    /// corrupts (never drops or reorders) frames.
    pub rel: Option<RelState>,
    /// A cumulative ack staged by the host for piggybacking on the next
    /// launched frame (rel links only; see [`LinkDir::stage_piggy_ack`]).
    staged_ack: Option<(VcId, link::Seq)>,
}

impl LinkDir {
    pub fn new(cfg: LinkConfig, owner: Node, rng: Rng) -> LinkDir {
        LinkDir {
            cfg,
            mux: VcMux::new(owner),
            credits: Credits::new(cfg.credits_per_vc),
            tx: TxState::new(),
            rx: RxState::new(),
            phys: PhysDir::new(cfg.phys, rng),
            rel: None,
            staged_ack: None,
        }
    }

    /// A link direction with the reliable-lossy extension: frames are
    /// subject to `rel.faults` at launch, and sequencing/ack/replay run
    /// per VC ([`rel::seqrep`]) instead of link-globally.
    pub fn new_rel(cfg: LinkConfig, owner: Node, rng: Rng, rel: RelConfig) -> LinkDir {
        let mut d = LinkDir::new(cfg, owner, rng);
        // the selective-repeat receive buffer is bounded by the replay
        // window: every buffered frame still holds its per-VC credit
        d.rel = Some(RelState::new(rel, cfg.credits_per_vc as u64));
        d
    }

    /// Queue a message for transmission.
    pub fn send(&mut self, msg: Message) {
        self.mux.enqueue(msg);
    }

    /// Stage a cumulative ack (for the *opposite* direction's traffic)
    /// to ride the next launched frame's ack envelope. Cumulative, so a
    /// newer ack simply replaces a staged older one.
    pub fn stage_piggy_ack(&mut self, ack: (VcId, link::Seq)) {
        debug_assert!(self.rel.is_some(), "piggy acks need the rel layer");
        self.staged_ack = Some(ack);
    }

    /// Attempt to put the next frame on the wire at `now`. Returns the
    /// frame and its arrival time at the peer. Retransmissions have
    /// priority and do not consume credits (their credit is still held —
    /// the receiver never freed the original slot). On rel links the
    /// returned frame may be marked `lost` (the caller must discard it
    /// instead of scheduling an arrival) or arrive late (reordered).
    pub fn try_launch(&mut self, now: Time) -> Option<(Time, Frame)> {
        if self.rel.is_some() {
            return self.try_launch_rel(now);
        }
        if self.tx.has_resend() {
            let f = self.tx.next_frame(None).expect("resend queued");
            let (arrival, intact) = self.phys.transmit(now, f.wire_bytes());
            let mut f = f;
            f.intact = intact;
            return Some((arrival, f));
        }
        let (vc, msg) = self.mux.arbitrate(&self.credits)?;
        let consumed = self.credits.consume(vc);
        debug_assert!(consumed, "arbiter returned a creditless VC");
        let f = self.tx.next_frame(Some(msg)).expect("fresh message");
        let (arrival, intact) = self.phys.transmit(now, f.wire_bytes());
        let mut f = f;
        f.intact = intact;
        Some((arrival, f))
    }

    fn try_launch_rel(&mut self, now: Time) -> Option<(Time, Frame)> {
        let rel = self.rel.as_mut().expect("rel launch on a plain link");
        let mut f = match rel.tx.next_resend() {
            Some(f) => f,
            None => {
                let (vc, msg) = self.mux.arbitrate(&self.credits)?;
                let consumed = self.credits.consume(vc);
                debug_assert!(consumed, "arbiter returned a creditless VC");
                rel.tx.frame(now, vc, msg)
            }
        };
        // attach a staged cumulative ack (the ack envelope bit) — also
        // to retransmissions; acks are cumulative, duplicates are free
        if let Some(a) = self.staged_ack.take() {
            f.ack = Some(a);
            rel.piggybacked_acks += 1;
        }
        let (arrival, phys_intact) = self.phys.transmit(now, f.wire_bytes());
        if !phys_intact {
            f.intact = false;
        }
        match rel.faults.apply(f.vc, f.wire_bytes()) {
            rel::FaultAction::Deliver => Some((arrival, f)),
            rel::FaultAction::Corrupt => {
                f.intact = false;
                Some((arrival, f))
            }
            rel::FaultAction::Drop => {
                f.lost = true;
                Some((arrival, f))
            }
            rel::FaultAction::Reorder(extra) => Some((arrival + extra, f)),
        }
    }

    /// Anything transmittable right now?
    pub fn can_launch(&self) -> bool {
        if match &self.rel {
            Some(r) => r.tx.has_resend(),
            None => self.tx.has_resend(),
        } {
            return true;
        }
        (0..NUM_VCS as u8).any(|vc| {
            self.mux.pending_on(VcId(vc)) > 0 && self.credits.available(VcId(vc)) > 0
        })
    }

    /// Process an arriving frame (receiver side of this direction).
    /// Frames accepted for the consumer are appended to `delivered` —
    /// possibly several on selective-repeat links, where a hole-filling
    /// retransmission releases its buffered successors — exactly once
    /// and in per-VC order; ack/nack/sack controls for the reverse path
    /// go to `ctls`. Piggybacked acks are NOT handled here — they
    /// belong to the opposite direction, which only the host can reach.
    pub fn receive(&mut self, frame: Frame, delivered: &mut Vec<Frame>, ctls: &mut Vec<Control>) {
        if let Some(rel) = self.rel.as_mut() {
            if frame.lost {
                // never reached the framer: no CRC check, no nack
                return;
            }
            rel.rx.on_frame(frame, delivered, ctls);
            return;
        }
        match self.rx.on_frame(&frame) {
            RxResult::Deliver(ctl) => {
                delivered.push(frame);
                if let Some(c) = ctl {
                    ctls.push(c);
                }
            }
            RxResult::Drop(ctl) => {
                if let Some(c) = ctl {
                    ctls.push(c);
                }
            }
        }
    }

    /// Control frame came back from the peer at `now` (the timestamp
    /// feeds the rel layer's RTT estimators).
    pub fn on_control(&mut self, now: Time, c: Control) {
        match self.rel.as_mut() {
            Some(rel) => rel.tx.on_control(now, c),
            None => self.tx.on_control(c),
        }
    }

    /// Peer consumed a message from `vc`: its buffer slot is free again.
    pub fn credit_return(&mut self, vc: VcId) {
        self.credits.restore(vc);
    }

    // -- rel-layer host hooks ------------------------------------------------

    /// Frames launched but not yet cumulatively acked (rel links; 0 on
    /// plain links — the transaction layer tracks its own unacked set).
    pub fn rel_unacked(&self) -> usize {
        self.rel.as_ref().map_or(0, |r| r.tx.unacked_total())
    }

    /// Cumulative acked-frame count — the retransmit timer's progress
    /// signal: if it has not moved for a full RTO, the link rewinds.
    pub fn rel_acked(&self) -> u64 {
        self.rel.as_ref().map_or(0, |r| r.tx.acked)
    }

    /// The retransmit timeout in force, when this is a rel link: the
    /// configured fixed value, or the clamped adaptive estimate
    /// ([`RelState::effective_rto`]) — re-read at every arming, so the
    /// timer tracks the measured RTT as samples land.
    pub fn rel_rto(&self) -> Option<crate::sim::time::Duration> {
        self.rel.as_ref().map(|r| r.effective_rto())
    }

    /// Retransmit-timeout expiry: rewind every VC with unacked frames.
    /// Returns true when a replay was queued (the caller should pump).
    pub fn rel_force_replay(&mut self) -> bool {
        self.rel.as_mut().is_some_and(|r| r.tx.force_replay_all())
    }

    /// Pull one piggyback-able cumulative ack from this direction's
    /// receiver (to stage on the opposite direction's sender).
    pub fn rel_take_piggy_ack(&mut self) -> Option<(VcId, link::Seq)> {
        self.rel.as_mut().and_then(|r| r.rx.piggy_ack())
    }

    /// Unflushed cumulative-ack debt at this direction's receiver
    /// (drives the host's delayed-ack flush, [`rel::ACK_FLUSH_DELAY`]).
    pub fn rel_has_ack_debt(&self) -> bool {
        self.rel.as_ref().is_some_and(|r| r.rx.has_debt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::messages::{CohOp, LineAddr, ReqId};
    use crate::sim::time::Duration;

    fn mk(owner: Node) -> LinkDir {
        LinkDir::new(LinkConfig::eci(), owner, Rng::new(3))
    }

    /// Feed one frame, returning (delivered, controls).
    fn recv(d: &mut LinkDir, f: Frame) -> (Vec<Frame>, Vec<Control>) {
        let mut del = Vec::new();
        let mut ctls = Vec::new();
        d.receive(f, &mut del, &mut ctls);
        (del, ctls)
    }

    #[test]
    fn single_message_latency_is_pipeline_plus_serialization() {
        let mut d = mk(Node::Remote);
        d.send(Message::coh_req(ReqId(0), Node::Remote, CohOp::ReadShared, LineAddr(0)));
        let (arrival, frame) = d.try_launch(Time(0)).unwrap();
        assert!(frame.intact);
        // 32B at ~29 GB/s ~ 1.1ns + 120ns pipeline
        assert!(arrival.as_ns() > 120.0 && arrival.as_ns() < 122.0, "{arrival}");
        let (del, _) = recv(&mut d, frame);
        assert_eq!(del.len(), 1);
    }

    #[test]
    fn credits_bound_in_flight_messages() {
        let mut d = mk(Node::Remote);
        let per_vc = d.cfg.credits_per_vc;
        // flood one VC (even requests)
        for i in 0..(per_vc + 10) {
            d.send(Message::coh_req(ReqId(i), Node::Remote, CohOp::ReadShared, LineAddr(2 * i as u64)));
        }
        let mut launched = 0;
        while d.try_launch(Time(0)).is_some() {
            launched += 1;
        }
        assert_eq!(launched, per_vc, "launches must stop at the credit limit");
        // returning one credit allows exactly one more
        d.credit_return(VcId(0));
        assert!(d.can_launch());
        assert!(d.try_launch(Time(0)).is_some());
        assert!(d.try_launch(Time(0)).is_none());
    }

    #[test]
    fn end_to_end_replay_over_lossy_phys() {
        let mut cfg = LinkConfig::eci();
        cfg.phys.frame_error_rate = 0.10;
        let mut dir = LinkDir::new(cfg, Node::Remote, Rng::new(11));
        let total = 500u32;
        for i in 0..total {
            dir.send(Message::coh_req(ReqId(i), Node::Remote, CohOp::ReadShared, LineAddr(i as u64)));
        }
        let mut now = Time(0);
        let mut got: Vec<u32> = Vec::new();
        let mut stall = 0;
        while (got.len() as u32) < total {
            // return credits promptly so flow control never starves
            match dir.try_launch(now) {
                Some((arrival, frame)) => {
                    now = arrival;
                    let (del, ctls) = recv(&mut dir, frame);
                    for f in del {
                        got.push(f.msg.id.0);
                        dir.credit_return(f.vc);
                    }
                    for c in ctls {
                        dir.on_control(now, c);
                    }
                    stall = 0;
                }
                None => {
                    // suppressed nack after a drop: timeout-driven replay
                    stall += 1;
                    assert!(stall < 3, "link deadlocked");
                    let exp = dir.rx.expected_seq();
                    dir.on_control(now, Control::Nack(exp));
                    now = now + Duration::from_ns(100);
                }
            }
        }
        assert_eq!(got, (0..total).collect::<Vec<_>>());
        assert!(dir.phys.injected_errors > 0, "the test should have exercised replay");
        assert!(dir.tx.retransmitted as u64 >= dir.phys.injected_errors);
    }

    #[test]
    fn rel_link_delivers_everything_under_drop_corrupt_reorder() {
        for mode in [RelMode::GoBackN, RelMode::SelectiveRepeat] {
            rel_link_delivers_everything(mode);
        }
    }

    fn rel_link_delivers_everything(mode: RelMode) {
        let mut cfg = LinkConfig::eci();
        cfg.credits_per_vc = 8;
        let spec = rel::FaultSpec { ber: 1e-4, drop: 0.05, reorder: 0.05, burst_len: 1.0 };
        let relcfg = RelConfig::new(rel::FaultConfig::new(spec, 5)).with_mode(mode);
        let mut d = LinkDir::new_rel(cfg, Node::Remote, Rng::new(3), relcfg);
        let total = 400u32;
        for i in 0..total {
            d.send(Message::coh_req(ReqId(i), Node::Remote, CohOp::ReadShared, LineAddr(i as u64)));
        }
        let mut now = Time(0);
        let mut got = 0u32;
        let mut stall = 0;
        loop {
            // launch everything the credits allow; lost frames vanish
            let mut inflight: Vec<(Time, Frame)> = Vec::new();
            while let Some((at, f)) = d.try_launch(now) {
                if !f.lost {
                    inflight.push((at, f));
                }
            }
            if inflight.is_empty() {
                if got >= total && d.rel_unacked() == 0 {
                    break;
                }
                // tail loss / unflushed acks: the retransmit timeout
                stall += 1;
                assert!(stall < 300, "rel link deadlocked at {got}/{total}");
                d.rel_force_replay();
                now = now + Duration::from_ns(2_000);
                continue;
            }
            stall = 0;
            // reordered frames carry late arrival stamps: deliver in
            // arrival order, exactly as an event queue would
            inflight.sort_by_key(|(t, _)| *t);
            for (at, f) in inflight {
                now = Time(now.0.max(at.0));
                let (del, ctls) = recv(&mut d, f);
                for g in del {
                    got += 1;
                    d.credit_return(g.vc);
                }
                for c in ctls {
                    d.on_control(now, c);
                }
            }
        }
        assert_eq!(got, total);
        let stats = d.rel.as_ref().unwrap().stats();
        assert!(stats.injected_drops > 0, "drops must have been injected: {stats:?}");
        assert!(stats.retransmitted > 0, "replay must have run: {stats:?}");
        assert_eq!(stats.accepted, total as u64);
    }

    #[test]
    fn staged_piggy_ack_rides_the_next_frame_once() {
        let relcfg = RelConfig::from_ber(0.0, 1);
        let mut d = LinkDir::new_rel(LinkConfig::eci(), Node::Remote, Rng::new(4), relcfg);
        d.send(Message::coh_req(ReqId(0), Node::Remote, CohOp::ReadShared, LineAddr(0)));
        d.send(Message::coh_req(ReqId(1), Node::Remote, CohOp::ReadShared, LineAddr(2)));
        d.stage_piggy_ack((VcId(6), 17));
        let (_, f0) = d.try_launch(Time(0)).unwrap();
        assert_eq!(f0.ack, Some((VcId(6), 17)), "first launch carries the staged ack");
        let (_, f1) = d.try_launch(Time(0)).unwrap();
        assert_eq!(f1.ack, None, "the envelope is consumed");
        assert_eq!(d.rel.as_ref().unwrap().piggybacked_acks, 1);
    }
}
