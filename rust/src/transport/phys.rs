//! Physical layer: serial-lane model.
//!
//! The Enzian ECI link is 12 lanes at 10 Gb/s with 64b/66b-style encoding
//! ("reducing the number of 10 Gb/s lanes used by the coherence protocol"
//! is how the paper's authors captured traces; §5.1 gives ~30 GiB/s
//! theoretical including overheads; §4.1 quotes the full link rate as
//! 240 Gb/s). We model a lane group as an aggregate serial resource with
//! an encoding efficiency factor, a fixed pipeline latency (SerDes + CDC +
//! protocol-engine pipeline depth), and an optional frame-error injector.

use crate::sim::bw::SerialPort;
use crate::sim::rng::Rng;
use crate::sim::time::{Duration, Time};

/// Configuration of one link direction's lanes.
#[derive(Clone, Copy, Debug)]
pub struct PhysConfig {
    pub lanes: u32,
    /// Per-lane raw rate, bits per second.
    pub lane_gbps: f64,
    /// Encoding efficiency (64/66 ≈ 0.97).
    pub encoding: f64,
    /// Fixed one-way latency: SerDes, clock-domain crossings, and the
    /// protocol-engine pipeline. This is the dominant term in the paper's
    /// 320 ns remote-load latency (the FPGA runs at 300 MHz).
    pub pipeline_latency: Duration,
    /// Probability a frame arrives corrupted (exercises replay).
    pub frame_error_rate: f64,
}

impl PhysConfig {
    /// The Enzian ECI link as evaluated in the paper (one direction).
    pub fn eci() -> PhysConfig {
        PhysConfig {
            lanes: 24,
            lane_gbps: 10.0,
            encoding: 64.0 / 66.0,
            // FPGA protocol stack @ 300 MHz: ~30 fabric cycles of VC/link/
            // transaction pipeline + SerDes ~= 120 ns one way.
            pipeline_latency: Duration::from_ns(120),
            frame_error_rate: 0.0,
        }
    }
    /// A native CPU-CPU interconnect direction (2-socket ThunderX-1).
    pub fn native() -> PhysConfig {
        PhysConfig {
            lanes: 24,
            lane_gbps: 10.0,
            encoding: 64.0 / 66.0,
            // CPU-speed coherence engines: ~40 ns one way.
            pipeline_latency: Duration::from_ns(40),
            frame_error_rate: 0.0,
        }
    }
    /// Aggregate usable bytes/second.
    pub fn bytes_per_sec(&self) -> f64 {
        self.lanes as f64 * self.lane_gbps * 1e9 / 8.0 * self.encoding
    }
}

/// One direction of the physical link.
pub struct PhysDir {
    pub cfg: PhysConfig,
    port: SerialPort,
    rng: Rng,
    /// Frames corrupted by the injector (stats).
    pub injected_errors: u64,
    /// Total frames transmitted.
    pub frames: u64,
}

impl PhysDir {
    pub fn new(cfg: PhysConfig, rng: Rng) -> PhysDir {
        PhysDir {
            port: SerialPort::new(cfg.bytes_per_sec(), Duration::ZERO),
            cfg,
            rng,
            injected_errors: 0,
            frames: 0,
        }
    }

    /// Serialize `bytes` starting no earlier than `now`; returns
    /// `(arrival_time, intact)`. Arrival = serialization done + pipeline.
    pub fn transmit(&mut self, now: Time, bytes: u64) -> (Time, bool) {
        let done = self.port.occupy(now, bytes);
        self.frames += 1;
        let intact = if self.cfg.frame_error_rate > 0.0 {
            let corrupt = self.rng.chance(self.cfg.frame_error_rate);
            if corrupt {
                self.injected_errors += 1;
            }
            !corrupt
        } else {
            true
        };
        (done + self.cfg.pipeline_latency, intact)
    }

    /// When the serializer next idles (for pull-based arbitration).
    pub fn free_at(&self) -> Time {
        self.port.free_at()
    }
    pub fn utilization(&self, now: Time) -> f64 {
        self.port.utilization(now)
    }
    pub fn bytes_sent(&self) -> u64 {
        self.port.bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eci_raw_rate_matches_paper() {
        // 240 Gb/s raw -> 30 GB/s; with 64/66 encoding ~29.1 GB/s usable.
        let cfg = PhysConfig::eci();
        let raw_gbps = cfg.lanes as f64 * cfg.lane_gbps;
        assert_eq!(raw_gbps, 240.0);
        let usable = cfg.bytes_per_sec();
        assert!((usable - 30e9 * 64.0 / 66.0).abs() < 1e6);
    }

    #[test]
    fn serialization_and_pipeline_latency() {
        let mut cfg = PhysConfig::eci();
        cfg.frame_error_rate = 0.0;
        let mut phys = PhysDir::new(cfg, Rng::new(1));
        let (arrival, intact) = phys.transmit(Time(0), 160);
        assert!(intact);
        // 160 B at ~29.09 GB/s ~= 5.5 ns, plus 120 ns pipeline
        let ns = arrival.as_ns();
        assert!(ns > 125.0 && ns < 126.0, "arrival {ns}ns");
        // back-to-back frames serialize
        let (arrival2, _) = phys.transmit(Time(0), 160);
        assert!(arrival2 > arrival);
    }

    #[test]
    fn error_injection_is_probabilistic_and_counted() {
        let mut cfg = PhysConfig::eci();
        cfg.frame_error_rate = 0.25;
        let mut phys = PhysDir::new(cfg, Rng::new(7));
        let mut bad = 0;
        for _ in 0..10_000 {
            let (_, intact) = phys.transmit(Time(0), 32);
            if !intact {
                bad += 1;
            }
        }
        assert_eq!(bad, phys.injected_errors);
        assert!((2_000..3_000).contains(&bad), "error count {bad}");
    }
}
