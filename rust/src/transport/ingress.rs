//! Framed-ingress adapter: the public admission point for generator
//! traffic (the `workload` subsystem's open-loop engine, or any other
//! external driver) into the layered transport.
//!
//! The dcs load generators historically bypassed link framing and
//! injected [`Message`]s straight into the directory's VC FIFOs, which
//! makes overload invisible: an open-loop generator can park an
//! unbounded number of messages in flight. [`FramedIngress`] closes that
//! hole by pushing every offered message through the real
//! [`LinkDir`] — VC arbitration, per-VC credits, frame
//! sequencing/replay, and serial-lane occupancy — so that overload
//! manifests exactly the way it does on hardware: credits exhaust,
//! frames queue at the transmitter, and queueing delay climbs the
//! latency distribution from p999 downward.
//!
//! The adapter is deliberately thin: it owns one [`LinkDir`] (one
//! direction), adds offered/delivered/stall accounting, and exposes a
//! pull-based `pump` the host event loop drains. Credit *returns* stay
//! with the caller: the receiver decides when a buffer slot is free (the
//! dcs frees a slot when a slice pipeline consumes the message, not at
//! frame arrival), which is what makes the backpressure credit-accurate.

use crate::proto::messages::Message;
use crate::proto::states::Node;
use crate::sim::rng::Rng;
use crate::sim::time::Time;

use super::link::{Control, Frame};
use super::transaction::RxResult;
use super::vc::{VcId, NUM_VCS};
use super::{LinkConfig, LinkDir};

/// One direction of framed generator admission: a [`LinkDir`] plus
/// offered-load accounting.
pub struct FramedIngress {
    pub link: LinkDir,
    /// Messages offered (accepted into the transmit queue — the queue is
    /// unbounded; *launching* is what credits gate).
    pub offered: u64,
    /// Frames delivered intact and in sequence to the receiver.
    pub delivered: u64,
    /// High-water mark of the transmit queue (frames waiting for credits
    /// or serialization). Queue growth here is the open-loop overload
    /// signal.
    pub peak_queue: usize,
    /// Pump invocations that left traffic queued purely for lack of
    /// credits (the wire was willing, the receiver was not).
    pub credit_stalls: u64,
}

impl FramedIngress {
    pub fn new(cfg: LinkConfig, owner: Node, rng: Rng) -> FramedIngress {
        FramedIngress {
            link: LinkDir::new(cfg, owner, rng),
            offered: 0,
            delivered: 0,
            peak_queue: 0,
            credit_stalls: 0,
        }
    }

    /// Accept a message into the transmit queue. Never refuses — the
    /// generator is open-loop; admission to the *wire* is what credits
    /// and framing control.
    pub fn offer(&mut self, msg: Message) {
        self.link.send(msg);
        self.offered += 1;
        self.peak_queue = self.peak_queue.max(self.link.mux.pending());
    }

    /// Launch every frame the credits and the serial lanes allow at
    /// `now`, appending `(arrival_time, frame)` pairs for the host to
    /// schedule. Counts a credit stall when traffic remains queued but
    /// nothing could launch.
    pub fn pump(&mut self, now: Time, out: &mut Vec<(Time, Frame)>) {
        while let Some((at, frame)) = self.link.try_launch(now) {
            out.push((at, frame));
        }
        if self.link.mux.pending() > 0 && !self.link.can_launch() {
            self.credit_stalls += 1;
        }
    }

    /// Receiver side: process one arriving frame. Returns the frame if
    /// it was accepted in sequence (ready to hand to the consumer — e.g.
    /// [`crate::dcs::Dcs::enqueue_frame`]) plus any control frame for
    /// the reverse direction. The caller must route the control frame
    /// back via [`FramedIngress::on_control`] and return the frame's
    /// credit via [`FramedIngress::credit_return`] once the receiver
    /// frees the buffer slot.
    pub fn deliver(&mut self, frame: Frame) -> (Option<Frame>, Option<Control>) {
        match self.link.rx.on_frame(&frame) {
            RxResult::Deliver(ctl) => {
                self.delivered += 1;
                (Some(frame), ctl)
            }
            RxResult::Drop(ctl) => (None, ctl),
        }
    }

    /// Apply an ack/nack control frame to the transmit state.
    pub fn on_control(&mut self, c: Control) {
        self.link.on_control(c);
    }

    /// The receiver freed the buffer slot of a frame on `vc`.
    pub fn credit_return(&mut self, vc: VcId) {
        self.link.credit_return(vc);
    }

    /// Frames queued at the transmitter right now.
    pub fn queued(&self) -> usize {
        self.link.mux.pending()
    }

    /// Launched-but-unreturned frames on one VC (credit conservation).
    pub fn in_flight(&self, vc: VcId) -> u32 {
        self.link.credits.in_flight(vc)
    }

    /// Launched-but-unreturned frames across all VCs.
    pub fn in_flight_total(&self) -> u32 {
        (0..NUM_VCS as u8).map(|vc| self.link.credits.in_flight(VcId(vc))).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::messages::{CohOp, LineAddr, Message, ReqId};

    fn req(i: u32, addr: u64) -> Message {
        Message::coh_req(ReqId(i), Node::Remote, CohOp::ReadShared, LineAddr(addr))
    }

    #[test]
    fn credits_gate_launches_and_stalls_are_counted() {
        let mut cfg = LinkConfig::eci();
        cfg.credits_per_vc = 4;
        let mut ing = FramedIngress::new(cfg, Node::Remote, Rng::new(5));
        // flood the even Req VC well past its credits
        for i in 0..10 {
            ing.offer(req(i, 2 * i as u64));
        }
        assert_eq!(ing.offered, 10);
        assert_eq!(ing.peak_queue, 10);
        let mut out = Vec::new();
        ing.pump(Time(0), &mut out);
        assert_eq!(out.len(), 4, "launches must stop at the credit budget");
        assert_eq!(ing.in_flight(VcId(0)), 4);
        assert_eq!(ing.queued(), 6);
        assert!(ing.credit_stalls > 0, "the starved queue must be counted");
        // no credit returned -> nothing more launches
        let mut out2 = Vec::new();
        ing.pump(Time(0), &mut out2);
        assert!(out2.is_empty());
        // one slot freed -> exactly one more frame
        ing.credit_return(VcId(0));
        let mut out3 = Vec::new();
        ing.pump(Time(0), &mut out3);
        assert_eq!(out3.len(), 1);
    }

    #[test]
    fn delivery_accounts_and_surfaces_controls() {
        let mut ing = FramedIngress::new(LinkConfig::eci(), Node::Remote, Rng::new(9));
        for i in 0..20 {
            ing.offer(req(i, i as u64));
        }
        let mut out = Vec::new();
        ing.pump(Time(0), &mut out);
        assert_eq!(out.len(), 20);
        let mut acks = 0;
        for (_, f) in out {
            let vc = f.vc;
            let (fr, ctl) = ing.deliver(f);
            let fr = fr.expect("in-sequence frame must deliver");
            assert!(fr.intact);
            if let Some(c) = ctl {
                acks += 1;
                ing.on_control(c);
            }
            ing.credit_return(vc);
        }
        assert_eq!(ing.delivered, 20);
        assert!(acks >= 1, "periodic cumulative acks must flow");
        assert_eq!(ing.in_flight_total(), 0);
    }
}
