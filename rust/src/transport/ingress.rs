//! Framed-ingress adapter: the public admission point for generator
//! traffic (the `workload` subsystem's open-loop engine, or any other
//! external driver) into the layered transport.
//!
//! The dcs load generators historically bypassed link framing and
//! injected [`Message`]s straight into the directory's VC FIFOs, which
//! makes overload invisible: an open-loop generator can park an
//! unbounded number of messages in flight. [`FramedIngress`] closes that
//! hole by pushing every offered message through the real
//! [`LinkDir`] — VC arbitration, per-VC credits, frame
//! sequencing/replay, and serial-lane occupancy — so that overload
//! manifests exactly the way it does on hardware: credits exhaust,
//! frames queue at the transmitter, and queueing delay climbs the
//! latency distribution from p999 downward.
//!
//! The adapter is deliberately thin: it owns one [`LinkDir`] (one
//! direction), adds offered/delivered/stall accounting, and exposes a
//! pull-based `pump` the host event loop drains. Credit *returns* stay
//! with the caller: the receiver decides when a buffer slot is free (the
//! dcs frees a slot when a slice pipeline consumes the message, not at
//! frame arrival), which is what makes the backpressure credit-accurate.

use crate::proto::messages::Message;
use crate::proto::states::Node;
use crate::sim::rng::Rng;
use crate::sim::time::Time;

use super::link::{Control, Frame, Seq};
use super::rel::{RelConfig, RelStats};
use super::transaction::RxResult;
use super::vc::{VcId, NUM_VCS};
use super::{LinkConfig, LinkDir};

/// Cross-slice ingress batching: groups frames delivered by the link
/// (already sequenced by the transaction layer) into per-consumer
/// batches, so a sliced directory hands each slice ONE VC-disciplined
/// delivery instead of one per frame.
///
/// Staging is strictly *post-sequencing, pre-slice-FIFO*: frames enter
/// in wire order and leave toward each consumer in that same order, so
/// per-VC FIFO order is preserved — the only reordering a batch
/// introduces is the rank-then-round-robin arbitration the slice's own
/// [`super::vc::VcMux`] applies to everything it holds anyway. A batch
/// is released when it reaches `batch` frames; the consumer flushes the
/// remainder whenever it runs dry (see `Dcs::service_one`), so no frame
/// is ever held indefinitely. Credits stay with the frames: a staged
/// frame has NOT been consumed, so its credit is returned only when the
/// slice services it — staging can therefore never leak buffer slots
/// past the credit budget.
pub struct IngressBatcher {
    batch: usize,
    staged: Vec<Vec<(Time, Frame)>>,
    /// Batches handed to consumers.
    pub deliveries: u64,
    /// Frames that passed through the batcher.
    pub frames: u64,
    /// Largest batch delivered.
    pub max_batch: usize,
}

impl IngressBatcher {
    /// `batch` frames per delivery (1 = batching off), one staging lane
    /// per consumer (directory slice).
    pub fn new(batch: usize, consumers: usize) -> IngressBatcher {
        assert!(batch >= 1, "batch size must be >= 1");
        assert!(consumers >= 1, "need at least one consumer");
        IngressBatcher {
            batch,
            staged: (0..consumers).map(|_| Vec::new()).collect(),
            deliveries: 0,
            frames: 0,
            max_batch: 0,
        }
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Stage one sequenced frame for consumer `c`; returns `true` when
    /// the lane reached the batch size and must be flushed now.
    pub fn stage(&mut self, c: usize, at: Time, frame: Frame) -> bool {
        let lane = &mut self.staged[c];
        lane.push((at, frame));
        lane.len() >= self.batch
    }

    /// Frames currently staged for consumer `c`.
    pub fn pending(&self, c: usize) -> usize {
        self.staged[c].len()
    }

    /// Frames staged across all consumers.
    pub fn total_pending(&self) -> usize {
        self.staged.iter().map(|l| l.len()).sum()
    }

    /// Hand consumer `c` its batch (possibly short, if the consumer ran
    /// dry before the lane filled). Frames come out in arrival order.
    pub fn take(&mut self, c: usize) -> Vec<(Time, Frame)> {
        let out = std::mem::take(&mut self.staged[c]);
        if !out.is_empty() {
            self.deliveries += 1;
            self.frames += out.len() as u64;
            self.max_batch = self.max_batch.max(out.len());
        }
        out
    }

    /// Mean frames per delivery so far (1.0 when batching is off).
    pub fn mean_batch(&self) -> f64 {
        if self.deliveries == 0 {
            0.0
        } else {
            self.frames as f64 / self.deliveries as f64
        }
    }
}

/// One direction of framed generator admission: a [`LinkDir`] plus
/// offered-load accounting.
pub struct FramedIngress {
    pub link: LinkDir,
    /// Messages offered (accepted into the transmit queue — the queue is
    /// unbounded; *launching* is what credits gate).
    pub offered: u64,
    /// Frames delivered intact and in sequence to the receiver.
    pub delivered: u64,
    /// High-water mark of the transmit queue (frames waiting for credits
    /// or serialization). Queue growth here is the open-loop overload
    /// signal.
    pub peak_queue: usize,
    /// Pump invocations that left traffic queued purely for lack of
    /// credits (the wire was willing, the receiver was not).
    pub credit_stalls: u64,
}

impl FramedIngress {
    pub fn new(cfg: LinkConfig, owner: Node, rng: Rng) -> FramedIngress {
        FramedIngress {
            link: LinkDir::new(cfg, owner, rng),
            offered: 0,
            delivered: 0,
            peak_queue: 0,
            credit_stalls: 0,
        }
    }

    /// A framed ingress over a reliable *lossy* link
    /// ([`crate::transport::rel`]): launched frames pass the direction's
    /// fault injector, and sequencing/ack/replay run per VC.
    pub fn with_rel(cfg: LinkConfig, owner: Node, rng: Rng, rel: RelConfig) -> FramedIngress {
        FramedIngress {
            link: LinkDir::new_rel(cfg, owner, rng, rel),
            offered: 0,
            delivered: 0,
            peak_queue: 0,
            credit_stalls: 0,
        }
    }

    /// Accept a message into the transmit queue. Never refuses — the
    /// generator is open-loop; admission to the *wire* is what credits
    /// and framing control.
    pub fn offer(&mut self, msg: Message) {
        self.link.send(msg);
        self.offered += 1;
        self.peak_queue = self.peak_queue.max(self.link.mux.pending());
    }

    /// Launch every frame the credits and the serial lanes allow at
    /// `now`, appending `(arrival_time, frame)` pairs for the host to
    /// schedule. Counts a credit stall when traffic remains queued but
    /// nothing could launch. Frames the fault injector swallowed are
    /// NOT appended — they burned wire time and hold their credit, but
    /// no arrival ever happens; recovery is the rel layer's job.
    pub fn pump(&mut self, now: Time, out: &mut Vec<(Time, Frame)>) {
        while let Some((at, frame)) = self.link.try_launch(now) {
            if frame.lost {
                continue;
            }
            out.push((at, frame));
        }
        if self.link.mux.pending() > 0 && !self.link.can_launch() {
            self.credit_stalls += 1;
        }
    }

    /// Receiver side: process one arriving frame. Frames accepted in
    /// sequence (ready to hand to the consumer — e.g.
    /// [`crate::dcs::Dcs::enqueue_frame`]) are appended to `out` —
    /// possibly several on a selective-repeat link, where a hole-filling
    /// retransmission releases its buffered successors — and controls
    /// for the reverse direction to `ctls`. The caller must route the
    /// control frames back via [`FramedIngress::on_control`] and return
    /// each delivered frame's credit via
    /// [`FramedIngress::credit_return`] once the receiver frees the
    /// buffer slot.
    pub fn deliver(&mut self, frame: Frame, out: &mut Vec<Frame>, ctls: &mut Vec<Control>) {
        debug_assert!(!frame.lost, "lost frames are discarded at the pump, not delivered");
        let before = out.len();
        if let Some(rel) = self.link.rel.as_mut() {
            rel.rx.on_frame(frame, out, ctls);
        } else {
            match self.link.rx.on_frame(&frame) {
                RxResult::Deliver(ctl) => {
                    out.push(frame);
                    if let Some(c) = ctl {
                        ctls.push(c);
                    }
                }
                RxResult::Drop(ctl) => {
                    if let Some(c) = ctl {
                        ctls.push(c);
                    }
                }
            }
        }
        self.delivered += (out.len() - before) as u64;
    }

    /// Apply an ack/sack/nack control frame to the transmit state at
    /// `now` (the timestamp feeds the rel layer's RTT estimators).
    pub fn on_control(&mut self, now: Time, c: Control) {
        self.link.on_control(now, c);
    }

    /// The receiver freed the buffer slot of a frame on `vc`.
    pub fn credit_return(&mut self, vc: VcId) {
        self.link.credit_return(vc);
    }

    // -- rel-layer host hooks ------------------------------------------------

    /// Reliability counters of this direction, when it runs the rel
    /// layer.
    pub fn rel_stats(&self) -> Option<RelStats> {
        self.link.rel.as_ref().map(|r| r.stats())
    }

    /// Pull one piggyback-able cumulative ack from this direction's
    /// receiver (stage it on the opposite direction's sender).
    pub fn take_piggy_ack(&mut self) -> Option<(VcId, Seq)> {
        self.link.rel_take_piggy_ack()
    }

    /// Stage an ack from the opposite direction onto this sender's next
    /// frame.
    pub fn stage_piggy_ack(&mut self, ack: (VcId, Seq)) {
        self.link.stage_piggy_ack(ack);
    }

    /// Piggyback one pending ack from the opposite-direction ingress `rx`
    /// onto this sender's next frame — but only when a frame can actually
    /// launch now, so an ack is never stranded on a stalled sender. The
    /// shared half of every paired-link pump loop (machine, open-loop
    /// host, fabric links).
    pub fn steal_piggy_from(&mut self, rx: &mut FramedIngress) {
        if self.link.can_launch() {
            if let Some(a) = rx.take_piggy_ack() {
                self.stage_piggy_ack(a);
            }
        }
    }

    /// Launched-but-unacked frames (rel links; drives the host's
    /// retransmit timer).
    pub fn rel_unacked(&self) -> usize {
        self.link.rel_unacked()
    }

    /// Ack progress signal for the retransmit timer.
    pub fn rel_acked(&self) -> u64 {
        self.link.rel_acked()
    }

    /// Retransmit-timeout expiry: rewind unacked frames for replay.
    pub fn rel_force_replay(&mut self) -> bool {
        self.link.rel_force_replay()
    }

    /// Unflushed cumulative-ack debt at this receiver (delayed-ack
    /// flush trigger).
    pub fn rel_has_ack_debt(&self) -> bool {
        self.link.rel_has_ack_debt()
    }

    /// Live rel-mode swap (control plane): retarget this direction's
    /// sequencing/replay discipline. No-op on a loss-free link (no rel
    /// layer to retarget); asserts the replay window is drained — the
    /// quiesce that precedes every reconfiguration guarantees it.
    /// Returns `true` when a rel layer was actually swapped.
    pub fn set_rel_mode(&mut self, mode: super::rel::RelMode) -> bool {
        match self.link.rel.as_mut() {
            Some(r) => {
                r.set_mode(mode);
                true
            }
            None => false,
        }
    }

    /// The retransmission discipline in force (rel links).
    pub fn rel_mode(&self) -> Option<super::rel::RelMode> {
        self.link.rel.as_ref().map(|r| r.mode)
    }

    /// Frames queued at the transmitter right now.
    pub fn queued(&self) -> usize {
        self.link.mux.pending()
    }

    /// Launched-but-unreturned frames on one VC (credit conservation).
    pub fn in_flight(&self, vc: VcId) -> u32 {
        self.link.credits.in_flight(vc)
    }

    /// Launched-but-unreturned frames across all VCs.
    pub fn in_flight_total(&self) -> u32 {
        (0..NUM_VCS as u8).map(|vc| self.link.credits.in_flight(VcId(vc))).sum()
    }

    /// Publish this direction's admission counters and instantaneous
    /// link gauges (transmit-queue depth, credit occupancy) into an obs
    /// registry under `ns.*` names — the telemetry ticker's view of
    /// link-level backpressure.
    pub fn observe(&self, ns: &str, reg: &mut crate::obs::Registry) {
        reg.set(&format!("{ns}.offered"), self.offered);
        reg.set(&format!("{ns}.delivered"), self.delivered);
        reg.set(&format!("{ns}.credit_stalls"), self.credit_stalls);
        reg.gauge(&format!("{ns}.queued"), self.queued() as f64);
        reg.gauge(&format!("{ns}.in_flight"), self.in_flight_total() as f64);
        reg.gauge(&format!("{ns}.peak_queue"), self.peak_queue as f64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::messages::{CohOp, LineAddr, Message, ReqId};

    fn req(i: u32, addr: u64) -> Message {
        Message::coh_req(ReqId(i), Node::Remote, CohOp::ReadShared, LineAddr(addr))
    }

    #[test]
    fn credits_gate_launches_and_stalls_are_counted() {
        let mut cfg = LinkConfig::eci();
        cfg.credits_per_vc = 4;
        let mut ing = FramedIngress::new(cfg, Node::Remote, Rng::new(5));
        // flood the even Req VC well past its credits
        for i in 0..10 {
            ing.offer(req(i, 2 * i as u64));
        }
        assert_eq!(ing.offered, 10);
        assert_eq!(ing.peak_queue, 10);
        let mut out = Vec::new();
        ing.pump(Time(0), &mut out);
        assert_eq!(out.len(), 4, "launches must stop at the credit budget");
        assert_eq!(ing.in_flight(VcId(0)), 4);
        assert_eq!(ing.queued(), 6);
        assert!(ing.credit_stalls > 0, "the starved queue must be counted");
        // no credit returned -> nothing more launches
        let mut out2 = Vec::new();
        ing.pump(Time(0), &mut out2);
        assert!(out2.is_empty());
        // one slot freed -> exactly one more frame
        ing.credit_return(VcId(0));
        let mut out3 = Vec::new();
        ing.pump(Time(0), &mut out3);
        assert_eq!(out3.len(), 1);
    }

    #[test]
    fn batcher_releases_on_full_and_flushes_short_batches() {
        let mut b = IngressBatcher::new(3, 2);
        let f = |i: u32, addr: u64| Frame::new(i as u64, req(i, addr));
        assert!(!b.stage(0, Time(0), f(0, 0)));
        assert!(!b.stage(0, Time(1), f(1, 2)));
        assert!(!b.stage(1, Time(1), f(2, 1)), "lanes fill independently");
        assert!(b.stage(0, Time(2), f(3, 4)), "third frame fills lane 0");
        let full = b.take(0);
        assert_eq!(full.len(), 3);
        // arrival order preserved within the batch
        assert_eq!(full.iter().map(|(_, f)| f.seq).collect::<Vec<_>>(), vec![0, 1, 3]);
        assert_eq!(b.pending(0), 0);
        assert_eq!(b.pending(1), 1);
        // a short flush (consumer ran dry) still counts as one delivery
        let short = b.take(1);
        assert_eq!(short.len(), 1);
        assert!(b.take(1).is_empty(), "empty take is free");
        assert_eq!(b.deliveries, 2);
        assert_eq!(b.frames, 4);
        assert_eq!(b.max_batch, 3);
        assert!((b.mean_batch() - 2.0).abs() < 1e-9);
        assert_eq!(b.total_pending(), 0);
    }

    #[test]
    fn delivery_accounts_and_surfaces_controls() {
        let mut ing = FramedIngress::new(LinkConfig::eci(), Node::Remote, Rng::new(9));
        for i in 0..20 {
            ing.offer(req(i, i as u64));
        }
        let mut out = Vec::new();
        ing.pump(Time(0), &mut out);
        assert_eq!(out.len(), 20);
        let mut acks = 0;
        for (_, f) in out {
            let vc = f.vc;
            let (mut del, mut ctls) = (Vec::new(), Vec::new());
            ing.deliver(f, &mut del, &mut ctls);
            assert_eq!(del.len(), 1, "in-sequence frame must deliver");
            assert!(del[0].intact);
            for c in ctls {
                acks += 1;
                ing.on_control(Time(0), c);
            }
            ing.credit_return(vc);
        }
        assert_eq!(ing.delivered, 20);
        assert!(acks >= 1, "periodic cumulative acks must flow");
        assert_eq!(ing.in_flight_total(), 0);
    }
}
