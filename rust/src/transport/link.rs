//! Link layer: framing and packing (paper §4.2: "The link layer formats
//! coherence messages and efficiently packs them for transport through
//! lower layers").
//!
//! A frame carries one ECI message plus link-level metadata:
//!
//! ```text
//! | 8B link header (seq:48, vc:4, len:12) | EWF message (16B or 144B) | 4B CRC | pad to 8B |
//! ```
//!
//! The CRC here is modelled (a boolean validity flag flipped by the error
//! injector) — the *byte-accurate* message encoding, including a real
//! CRC-32, lives in [`crate::trace::ewf`]; this layer only needs correct
//! *sizes* for timing plus a detectable-corruption bit for the replay
//! machinery. A unit test in `trace::ewf` pins the two size computations
//! together.

use crate::proto::messages::Message;

use super::vc::{vc_for, VcId};

/// Link-level frame sequence number (per direction).
pub type Seq = u64;

/// Frame overheads, bytes.
pub const LINK_HEADER_BYTES: u64 = 8;
pub const CRC_BYTES: u64 = 4;

/// A framed message in flight.
#[derive(Clone, Debug)]
pub struct Frame {
    pub seq: Seq,
    pub vc: VcId,
    pub msg: Message,
    /// Cleared by the error injector; checked by the receiver.
    pub intact: bool,
}

impl Frame {
    pub fn new(seq: Seq, msg: Message) -> Frame {
        let vc = vc_for(&msg);
        Frame { seq, vc, msg, intact: true }
    }

    /// Bytes on the wire: header + EWF body + CRC, padded to 8 bytes.
    pub fn wire_bytes(&self) -> u64 {
        let raw = LINK_HEADER_BYTES + self.msg.wire_bytes() + CRC_BYTES;
        raw.div_ceil(8) * 8
    }
}

/// A control frame (ack/nack) on the reverse direction. Fixed 16 bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Control {
    /// Cumulative ack: everything <= seq received intact.
    Ack(Seq),
    /// Go-back-N request: retransmit starting from seq.
    Nack(Seq),
}

pub const CONTROL_BYTES: u64 = 16;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::messages::{CohOp, LineAddr, Message, ReqId};
    use crate::proto::states::Node;

    #[test]
    fn frame_sizes() {
        let hdr_only = Frame::new(0, Message::coh_req(ReqId(0), Node::Remote, CohOp::ReadShared, LineAddr(0)));
        // 8 + 16 + 4 = 28 -> padded 32
        assert_eq!(hdr_only.wire_bytes(), 32);
        let with_data = Frame::new(
            1,
            Message::coh_rsp(ReqId(0), Node::Home, CohOp::ReadShared, LineAddr(0), false, Some(Box::new([0; 128]))),
        );
        // 8 + 144 + 4 = 156 -> padded 160
        assert_eq!(with_data.wire_bytes(), 160);
    }

    #[test]
    fn frame_takes_vc_from_message() {
        let f = Frame::new(0, Message::coh_req(ReqId(0), Node::Remote, CohOp::ReadShared, LineAddr(3)));
        assert_eq!(f.vc, VcId(1)); // odd request
        assert!(f.intact);
    }
}
