//! Link layer: framing and packing (paper §4.2: "The link layer formats
//! coherence messages and efficiently packs them for transport through
//! lower layers").
//!
//! A frame carries one ECI message plus link-level metadata:
//!
//! ```text
//! | 8B link header (seq:48, vc:4, len:11, ack:1) | [8B piggy ack] | EWF message (16B or 144B) | 4B CRC | pad to 8B |
//! ```
//!
//! The header's **ack envelope bit** marks a piggybacked cumulative ack
//! for the *reverse* direction (the rel layer's per-VC sequencing,
//! [`crate::transport::rel`]): when set, an 8-byte `(vc, seq)` ack word
//! follows the header, and return traffic acknowledges forward traffic
//! without spending a 16-byte control frame.
//!
//! The CRC here is modelled (a boolean validity flag flipped by the error
//! injector) — the *byte-accurate* message encoding, including a real
//! CRC-32, lives in [`crate::trace::ewf`]; this layer only needs correct
//! *sizes* for timing plus a detectable-corruption bit for the replay
//! machinery. A unit test in `trace::ewf` pins the two size computations
//! together.

use crate::proto::messages::Message;

use super::vc::{vc_for, VcId};

/// Link-level frame sequence number (per direction).
pub type Seq = u64;

/// Frame overheads, bytes.
pub const LINK_HEADER_BYTES: u64 = 8;
pub const CRC_BYTES: u64 = 4;
/// The piggybacked cumulative-ack word (present iff the header's ack
/// envelope bit is set).
pub const PIGGY_ACK_BYTES: u64 = 8;

/// A framed message in flight.
#[derive(Clone, Debug)]
pub struct Frame {
    /// Sequence number: link-global under the transaction layer,
    /// per-`vc` under the rel layer ([`crate::transport::rel`]).
    pub seq: Seq,
    pub vc: VcId,
    pub msg: Message,
    /// Cleared by the error injector; checked by the receiver.
    pub intact: bool,
    /// Set by the fault injector: the frame never reaches the peer's
    /// framer (hosts discard it instead of scheduling an arrival).
    pub lost: bool,
    /// Piggybacked cumulative ack for the reverse direction (the ack
    /// envelope bit + ack word): everything `<= seq` on `vc` of the
    /// *opposite* link direction arrived intact and in sequence. The
    /// header (and so the ack word) carries its own CRC, so hosts apply
    /// it even when the body CRC fails; a *lost* frame takes its ack
    /// down with it (recovered by the stale-duplicate re-ack resync).
    pub ack: Option<(VcId, Seq)>,
}

impl Frame {
    pub fn new(seq: Seq, msg: Message) -> Frame {
        let vc = vc_for(&msg);
        Frame::new_on(seq, vc, msg)
    }

    /// Frame with an explicit VC (the rel layer stamps per-VC
    /// sequences, so the VC is chosen before the sequence number).
    pub fn new_on(seq: Seq, vc: VcId, msg: Message) -> Frame {
        Frame { seq, vc, msg, intact: true, lost: false, ack: None }
    }

    /// Bytes on the wire: header + optional piggy-ack word + EWF body +
    /// CRC, padded to 8 bytes.
    pub fn wire_bytes(&self) -> u64 {
        let piggy = if self.ack.is_some() { PIGGY_ACK_BYTES } else { 0 };
        let raw = LINK_HEADER_BYTES + piggy + self.msg.wire_bytes() + CRC_BYTES;
        raw.div_ceil(8) * 8
    }

    /// Bytes on the wire excluding the optional piggybacked ack word —
    /// the frame's *own* cost. The rel layer's byte accounting
    /// (sent/retransmitted/accepted bytes) uses this on both ends so
    /// the replay-overhead ratio is not skewed by which copies happened
    /// to carry an opportunistic ack envelope.
    pub fn own_wire_bytes(&self) -> u64 {
        let raw = LINK_HEADER_BYTES + self.msg.wire_bytes() + CRC_BYTES;
        raw.div_ceil(8) * 8
    }
}

/// A control frame (ack/nack) on the reverse direction. Fixed 16 bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Control {
    /// Cumulative ack: everything <= seq received intact.
    Ack(Seq),
    /// Go-back-N request: retransmit starting from seq.
    Nack(Seq),
    /// Per-VC cumulative ack (rel layer): everything <= seq on the VC
    /// received intact and in sequence.
    VcAck(VcId, Seq),
    /// Per-VC retransmit request (rel layer). Go-back-N reads it as
    /// "rewind the VC from seq"; selective repeat as "retransmit exactly
    /// seq" (one nack per missing frame, the out-of-order receive buffer
    /// keeps everything after the hole).
    VcNack(VcId, Seq),
    /// Per-VC selective ack (rel layer, selective repeat only): exactly
    /// seq arrived intact and is buffered out of order — do not replay
    /// it on nack or timeout. Cumulative trimming still rides `VcAck`.
    VcSack(VcId, Seq),
}

pub const CONTROL_BYTES: u64 = 16;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::messages::{CohOp, LineAddr, Message, ReqId};
    use crate::proto::states::Node;

    #[test]
    fn frame_sizes() {
        let hdr_only = Frame::new(0, Message::coh_req(ReqId(0), Node::Remote, CohOp::ReadShared, LineAddr(0)));
        // 8 + 16 + 4 = 28 -> padded 32
        assert_eq!(hdr_only.wire_bytes(), 32);
        let with_data = Frame::new(
            1,
            Message::coh_rsp(ReqId(0), Node::Home, CohOp::ReadShared, LineAddr(0), false, Some(Box::new([0; 128]))),
        );
        // 8 + 144 + 4 = 156 -> padded 160
        assert_eq!(with_data.wire_bytes(), 160);
    }

    #[test]
    fn piggy_ack_costs_one_word_on_the_wire() {
        let mut f = Frame::new(0, Message::coh_req(ReqId(0), Node::Remote, CohOp::ReadShared, LineAddr(0)));
        assert_eq!(f.wire_bytes(), 32);
        f.ack = Some((VcId(6), 41));
        // 8 + 8 + 16 + 4 = 36 -> padded 40; half a control frame's cost
        assert_eq!(f.wire_bytes(), 40);
        assert!(f.wire_bytes() - 32 < CONTROL_BYTES);
        // the frame's own cost ignores the envelope either way
        assert_eq!(f.own_wire_bytes(), 32);
        f.ack = None;
        assert_eq!(f.own_wire_bytes(), f.wire_bytes());
    }

    #[test]
    fn frame_takes_vc_from_message() {
        let f = Frame::new(0, Message::coh_req(ReqId(0), Node::Remote, CohOp::ReadShared, LineAddr(3)));
        assert_eq!(f.vc, VcId(1)); // odd request
        assert!(f.intact);
    }
}
