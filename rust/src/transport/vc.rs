//! Virtual-channel layer (paper §4.2).
//!
//! "The VC layer implements 14 different virtual channels that expose
//! Input/Output (IO) and coherence operations to the FPGA, of which 10 are
//! for coherence traffic, with separate sets of VCs for odd and even cache
//! lines enabling simpler load-balancing."
//!
//! The 14 channels, mirroring the ThunderX-1 message classes:
//!
//! | VC    | class       | parity | carries                              |
//! |-------|-------------|--------|--------------------------------------|
//! | 0/1   | `Req`       | e/o    | coherence requests (upgrades)        |
//! | 2/3   | `Fwd`       | e/o    | home-initiated downgrades            |
//! | 4/5   | `RspNoData` | e/o    | dataless responses (acks)            |
//! | 6/7   | `RspData`   | e/o    | data-carrying responses              |
//! | 8/9   | `WbData`    | e/o    | voluntary downgrades (± data)        |
//! | 10    | `IoReq`     | –      | non-cacheable I/O requests           |
//! | 11    | `IoRsp`     | –      | I/O responses                        |
//! | 12    | `Ipi`       | –      | inter-processor interrupts           |
//! | 13    | `Barrier`   | –      | memory-barrier handshakes            |
//!
//! Deadlock freedom uses the standard message-class hierarchy: a message
//! may only wait on strictly *higher*-ranked classes, and the top classes
//! (responses) are guaranteed sinkable — receivers always eventually drain
//! them without generating new messages. The arbiter therefore serves
//! higher ranks first; credits make the discipline quantitative.

use crate::proto::messages::{Message, MsgKind};
use crate::proto::states::Node;
use std::collections::VecDeque;

pub const NUM_VCS: usize = 14;
pub const NUM_COHERENCE_VCS: usize = 10;

/// Virtual-channel identifier (0..14).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct VcId(pub u8);

/// Message class, determining VC (with parity) and deadlock rank.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum VcClass {
    Req,
    Fwd,
    RspNoData,
    RspData,
    WbData,
    IoReq,
    IoRsp,
    Ipi,
    Barrier,
}

impl VcClass {
    /// Deadlock rank: a message of class X may block only on classes with
    /// strictly greater rank. Responses and writebacks are sinks.
    pub fn rank(self) -> u8 {
        match self {
            VcClass::IoReq => 0,
            VcClass::Req => 1,
            VcClass::Fwd => 2,
            VcClass::WbData => 3,
            VcClass::RspNoData => 4,
            VcClass::RspData => 4,
            VcClass::IoRsp => 4,
            VcClass::Ipi => 5,
            VcClass::Barrier => 5,
        }
    }
    /// Is this class a guaranteed sink (consumable without generating new
    /// traffic)?
    pub fn is_sink(self) -> bool {
        self.rank() >= 3
    }
}

/// Classify a message.
pub fn class_of(msg: &Message) -> VcClass {
    use crate::proto::messages::CohOp::*;
    match &msg.kind {
        MsgKind::CohReq { op } => match op {
            ReadShared | ReadExclusive | UpgradeS2E => VcClass::Req,
            VolDowngradeS | VolDowngradeI => VcClass::WbData,
            FwdDowngradeS | FwdDowngradeI | FwdSharedInvalidate => VcClass::Fwd,
        },
        MsgKind::CohRsp { .. } => {
            if msg.payload.is_some() {
                VcClass::RspData
            } else {
                VcClass::RspNoData
            }
        }
        MsgKind::IoRead { .. } | MsgKind::IoWrite { .. } => VcClass::IoReq,
        MsgKind::IoReadRsp { .. } | MsgKind::IoWriteAck => VcClass::IoRsp,
        MsgKind::Ipi { .. } => VcClass::Ipi,
        MsgKind::Barrier | MsgKind::BarrierAck => VcClass::Barrier,
    }
}

/// Map a message to its VC (coherence classes split by line parity).
pub fn vc_for(msg: &Message) -> VcId {
    let parity = msg.addr.parity();
    match class_of(msg) {
        VcClass::Req => VcId(parity),
        VcClass::Fwd => VcId(2 + parity),
        VcClass::RspNoData => VcId(4 + parity),
        VcClass::RspData => VcId(6 + parity),
        VcClass::WbData => VcId(8 + parity),
        VcClass::IoReq => VcId(10),
        VcClass::IoRsp => VcId(11),
        VcClass::Ipi => VcId(12),
        VcClass::Barrier => VcId(13),
    }
}

/// The class a VC carries.
pub fn class_of_vc(vc: VcId) -> VcClass {
    match vc.0 {
        0 | 1 => VcClass::Req,
        2 | 3 => VcClass::Fwd,
        4 | 5 => VcClass::RspNoData,
        6 | 7 => VcClass::RspData,
        8 | 9 => VcClass::WbData,
        10 => VcClass::IoReq,
        11 => VcClass::IoRsp,
        12 => VcClass::Ipi,
        13 => VcClass::Barrier,
        _ => panic!("invalid VC {vc:?}"),
    }
}

/// Per-VC credit counters for one link direction (credits = receiver
/// buffer slots).
#[derive(Clone, Debug)]
pub struct Credits {
    avail: [u32; NUM_VCS],
    max: [u32; NUM_VCS],
}

impl Credits {
    pub fn new(per_vc: u32) -> Credits {
        Credits { avail: [per_vc; NUM_VCS], max: [per_vc; NUM_VCS] }
    }
    pub fn with_limits(limits: [u32; NUM_VCS]) -> Credits {
        Credits { avail: limits, max: limits }
    }
    #[inline]
    pub fn available(&self, vc: VcId) -> u32 {
        self.avail[vc.0 as usize]
    }
    /// Consume one credit to transmit on `vc`.
    #[inline]
    pub fn consume(&mut self, vc: VcId) -> bool {
        let a = &mut self.avail[vc.0 as usize];
        if *a == 0 {
            false
        } else {
            *a -= 1;
            true
        }
    }
    /// Receiver freed a buffer slot.
    #[inline]
    pub fn restore(&mut self, vc: VcId) {
        let i = vc.0 as usize;
        assert!(self.avail[i] < self.max[i], "credit overflow on {vc:?}");
        self.avail[i] += 1;
    }
    /// Credit-conservation invariant: in-flight = max - avail.
    pub fn in_flight(&self, vc: VcId) -> u32 {
        self.max[vc.0 as usize] - self.avail[vc.0 as usize]
    }
}

/// Static arbitration order: VC groups by deadlock rank, highest first
/// (PERF: building this per `arbitrate` call dominated the simulation's
/// profile — 15% direct + most of the allocator time; see DESIGN.md
/// §Perf).
const RANK_GROUPS: [&[usize]; 6] = [
    &[12, 13],          // Ipi, Barrier          (rank 5)
    &[4, 5, 6, 7, 11],  // RspNoData/RspData/IoRsp (rank 4)
    &[8, 9],            // WbData                (rank 3)
    &[2, 3],            // Fwd                   (rank 2)
    &[0, 1],            // Req                   (rank 1)
    &[10],              // IoReq                 (rank 0)
];

/// Per-direction VC multiplexer: 14 FIFO queues plus a rank-then-
/// round-robin arbiter.
pub struct VcMux {
    queues: [VecDeque<Message>; NUM_VCS],
    /// Round-robin pointer per rank-group for fairness.
    rr: [usize; RANK_GROUPS.len()],
    /// Bit per VC with pending messages (skip empty groups cheaply).
    pending_mask: u16,
    /// Total messages enqueued (stats).
    pub enqueued: u64,
    /// Which end of the link this mux transmits *from*.
    pub owner: Node,
}

impl VcMux {
    pub fn new(owner: Node) -> VcMux {
        VcMux {
            queues: Default::default(),
            rr: [0; RANK_GROUPS.len()],
            pending_mask: 0,
            enqueued: 0,
            owner,
        }
    }

    /// Queue a message on its VC.
    pub fn enqueue(&mut self, msg: Message) {
        debug_assert_eq!(msg.from, self.owner, "message from the wrong node");
        let vc = vc_for(&msg);
        self.queues[vc.0 as usize].push_back(msg);
        self.pending_mask |= 1 << vc.0;
        self.enqueued += 1;
    }

    /// Pick the next transmittable message: highest deadlock rank first,
    /// round-robin within a rank, skipping VCs without credits.
    /// Allocation-free (hot path).
    pub fn arbitrate(&mut self, credits: &Credits) -> Option<(VcId, Message)> {
        if self.pending_mask == 0 {
            return None;
        }
        for (g, vcs) in RANK_GROUPS.iter().enumerate() {
            let n = vcs.len();
            let start = self.rr[g] % n;
            for k in 0..n {
                let vc = vcs[(start + k) % n];
                if self.pending_mask & (1 << vc) == 0 || credits.available(VcId(vc as u8)) == 0 {
                    continue;
                }
                self.rr[g] = (start + k + 1) % n;
                let msg = self.queues[vc].pop_front().unwrap();
                if self.queues[vc].is_empty() {
                    self.pending_mask &= !(1 << vc);
                }
                return Some((VcId(vc as u8), msg));
            }
        }
        None
    }

    pub fn pending(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }
    pub fn pending_on(&self, vc: VcId) -> usize {
        self.queues[vc.0 as usize].len()
    }
    pub fn is_empty(&self) -> bool {
        self.pending() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::messages::{CohOp, LineAddr, Message, ReqId};

    fn req(addr: u64) -> Message {
        Message::coh_req(ReqId(1), Node::Remote, CohOp::ReadShared, LineAddr(addr))
    }
    fn rsp(addr: u64) -> Message {
        Message::coh_rsp(ReqId(1), Node::Remote, CohOp::FwdDowngradeI, LineAddr(addr), false, None)
    }

    #[test]
    fn fourteen_vcs_ten_coherence() {
        assert_eq!(NUM_VCS, 14);
        assert_eq!(NUM_COHERENCE_VCS, 10);
        for vc in 0..NUM_COHERENCE_VCS {
            let c = class_of_vc(VcId(vc as u8));
            assert!(
                matches!(c, VcClass::Req | VcClass::Fwd | VcClass::RspNoData | VcClass::RspData | VcClass::WbData)
            );
        }
    }

    #[test]
    fn parity_splits_coherence_vcs() {
        assert_eq!(vc_for(&req(0)), VcId(0));
        assert_eq!(vc_for(&req(1)), VcId(1));
        let m_even = Message::coh_rsp(
            ReqId(0),
            Node::Home,
            CohOp::ReadShared,
            LineAddr(4),
            false,
            Some(Box::new([0; 128])),
        );
        assert_eq!(vc_for(&m_even), VcId(6));
        let m_odd = Message::coh_rsp(
            ReqId(0),
            Node::Home,
            CohOp::ReadShared,
            LineAddr(5),
            false,
            Some(Box::new([0; 128])),
        );
        assert_eq!(vc_for(&m_odd), VcId(7));
    }

    #[test]
    fn responses_outrank_requests() {
        assert!(VcClass::RspData.rank() > VcClass::Req.rank());
        assert!(VcClass::RspData.rank() > VcClass::Fwd.rank());
        assert!(VcClass::Fwd.rank() > VcClass::Req.rank());
        assert!(VcClass::WbData.rank() > VcClass::Fwd.rank());
        assert!(VcClass::RspData.is_sink());
        assert!(!VcClass::Req.is_sink());
    }

    #[test]
    fn arbiter_prefers_higher_rank() {
        let mut mux = VcMux::new(Node::Remote);
        let credits = Credits::new(8);
        mux.enqueue(req(0)); // Req, rank 1
        mux.enqueue(rsp(0)); // RspNoData, rank 4
        let (vc, _) = mux.arbitrate(&credits).unwrap();
        assert_eq!(class_of_vc(vc), VcClass::RspNoData);
        let (vc, _) = mux.arbitrate(&credits).unwrap();
        assert_eq!(class_of_vc(vc), VcClass::Req);
        assert!(mux.arbitrate(&credits).is_none());
    }

    #[test]
    fn arbiter_skips_creditless_vcs() {
        let mut mux = VcMux::new(Node::Remote);
        let mut limits = [8u32; NUM_VCS];
        limits[0] = 0; // no credits on even Req VC
        let credits = Credits::with_limits(limits);
        mux.enqueue(req(0)); // even -> VC0, blocked
        mux.enqueue(req(1)); // odd -> VC1, ok
        let (vc, msg) = mux.arbitrate(&credits).unwrap();
        assert_eq!(vc, VcId(1));
        assert_eq!(msg.addr, LineAddr(1));
        assert!(mux.arbitrate(&credits).is_none(), "VC0 message must stay queued");
        assert_eq!(mux.pending_on(VcId(0)), 1);
    }

    #[test]
    fn round_robin_within_rank() {
        let mut mux = VcMux::new(Node::Remote);
        let credits = Credits::new(8);
        // two even + two odd requests: arbitration should alternate VCs
        mux.enqueue(req(0));
        mux.enqueue(req(2));
        mux.enqueue(req(1));
        mux.enqueue(req(3));
        let order: Vec<u8> = std::iter::from_fn(|| mux.arbitrate(&credits).map(|(vc, _)| vc.0)).collect();
        assert_eq!(order.len(), 4);
        assert_ne!(order[0], order[1], "round robin should alternate: {order:?}");
        assert_ne!(order[1], order[2], "round robin should alternate: {order:?}");
    }

    #[test]
    fn credit_conservation() {
        let mut c = Credits::new(4);
        let vc = VcId(0);
        assert!(c.consume(vc));
        assert!(c.consume(vc));
        assert_eq!(c.in_flight(vc), 2);
        c.restore(vc);
        assert_eq!(c.in_flight(vc), 1);
        assert!(c.consume(vc));
        assert!(c.consume(vc));
        assert!(c.consume(vc));
        assert!(!c.consume(vc), "credits exhausted");
        assert_eq!(c.in_flight(vc), 4);
    }

    #[test]
    #[should_panic]
    fn credit_overflow_panics() {
        let mut c = Credits::new(1);
        c.restore(VcId(0));
    }
}
