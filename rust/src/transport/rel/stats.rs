//! Reliability observables of one link direction, snapshot-able into the
//! harness counter namespace (`rel_*` keys) and the goodput figure.

use crate::sim::stats::Counters;

use super::RelState;

/// Snapshot of one direction's reliability counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct RelStats {
    /// Frames put on the wire (fresh + retransmissions).
    pub sent: u64,
    pub retransmitted: u64,
    /// Timeout-driven full rewinds.
    pub timeouts: u64,
    /// Frames accepted in sequence by the receiver.
    pub accepted: u64,
    pub dropped_corrupt: u64,
    pub dropped_out_of_order: u64,
    /// High-water mark of the replay-buffer occupancy (frames parked
    /// awaiting cumulative ack, across all VCs).
    pub peak_replay: usize,
    /// Faults the wire injected on this direction.
    pub injected_drops: u64,
    pub injected_corrupts: u64,
    pub injected_reorders: u64,
    /// Cumulative acks that rode the reverse direction's frames instead
    /// of costing an explicit control frame.
    pub piggybacked_acks: u64,
}

impl RelStats {
    pub fn of(rel: &RelState) -> RelStats {
        RelStats {
            sent: rel.tx.sent,
            retransmitted: rel.tx.retransmitted,
            timeouts: rel.tx.timeouts,
            accepted: rel.rx.accepted,
            dropped_corrupt: rel.rx.dropped_corrupt,
            dropped_out_of_order: rel.rx.dropped_out_of_order,
            peak_replay: rel.tx.peak_replay,
            injected_drops: rel.faults.stats.dropped,
            injected_corrupts: rel.faults.stats.corrupted,
            injected_reorders: rel.faults.stats.reordered,
            piggybacked_acks: rel.piggybacked_acks,
        }
    }

    /// Merge another direction's counters (both link directions report
    /// as one stack in the harness).
    pub fn merge(&mut self, o: &RelStats) {
        self.sent += o.sent;
        self.retransmitted += o.retransmitted;
        self.timeouts += o.timeouts;
        self.accepted += o.accepted;
        self.dropped_corrupt += o.dropped_corrupt;
        self.dropped_out_of_order += o.dropped_out_of_order;
        self.peak_replay = self.peak_replay.max(o.peak_replay);
        self.injected_drops += o.injected_drops;
        self.injected_corrupts += o.injected_corrupts;
        self.injected_reorders += o.injected_reorders;
        self.piggybacked_acks += o.piggybacked_acks;
    }

    /// Fraction of transmitted frames that were useful (accepted in
    /// sequence): 1.0 on a clean link, sinking as replays burn
    /// bandwidth. This is the *link* goodput; the figure-level goodput
    /// (completed operations/s) is reported by the open-loop engine.
    pub fn frame_goodput(&self) -> f64 {
        if self.sent == 0 {
            1.0
        } else {
            self.accepted as f64 / self.sent as f64
        }
    }

    /// Add the snapshot into a harness counter block under `rel_*` keys.
    pub fn add_to(&self, c: &mut Counters) {
        c.add("rel_sent", self.sent);
        c.add("rel_retransmitted", self.retransmitted);
        c.add("rel_timeouts", self.timeouts);
        c.add("rel_accepted", self.accepted);
        c.add("rel_dropped_corrupt", self.dropped_corrupt);
        c.add("rel_dropped_out_of_order", self.dropped_out_of_order);
        c.add("rel_peak_replay", self.peak_replay as u64);
        c.add("rel_injected_drops", self.injected_drops);
        c.add("rel_injected_corrupts", self.injected_corrupts);
        c.add("rel_injected_reorders", self.injected_reorders);
        c.add("rel_piggybacked_acks", self.piggybacked_acks);
    }
}
