//! Reliability observables of one link direction, snapshot-able into the
//! harness counter namespace (`rel_*` keys), the goodput figure, and the
//! replay-bandwidth (retransmission-ablation) figure.

use crate::sim::stats::Counters;

use super::RelState;

/// Snapshot of one direction's reliability counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct RelStats {
    /// Frames put on the wire (fresh + retransmissions).
    pub sent: u64,
    /// Wire bytes put on the wire (fresh + retransmissions).
    pub sent_bytes: u64,
    pub retransmitted: u64,
    /// Wire bytes burned on retransmissions — the replay-bandwidth
    /// figure's numerator.
    pub retransmitted_bytes: u64,
    /// Timeout-driven rewinds.
    pub timeouts: u64,
    /// Frames accepted and delivered in sequence by the receiver.
    pub accepted: u64,
    /// Wire bytes delivered to the consumer — the replay-bandwidth
    /// figure's denominator.
    pub accepted_bytes: u64,
    pub dropped_corrupt: u64,
    pub dropped_out_of_order: u64,
    /// Frames parked out of order awaiting a hole fill (selective
    /// repeat only).
    pub buffered_out_of_order: u64,
    /// High-water mark of the out-of-order receive buffer (frames held
    /// across all VCs; bounded by the replay window — sizes the SR
    /// buffering a hardware port would need).
    pub peak_buffered: usize,
    /// Selective acks applied at the sender (selective repeat only).
    pub sacks: u64,
    /// High-water mark of the replay-buffer occupancy (frames parked
    /// awaiting cumulative ack, across all VCs).
    pub peak_replay: usize,
    /// Faults the wire injected on this direction.
    pub injected_drops: u64,
    pub injected_corrupts: u64,
    pub injected_reorders: u64,
    /// Cumulative acks that rode the reverse direction's frames instead
    /// of costing an explicit control frame.
    pub piggybacked_acks: u64,
    /// Karn-filtered RTT samples absorbed by the estimators.
    pub rtt_samples: u64,
    /// Widest per-VC smoothed RTT, ns (0 until a sample lands).
    pub srtt_ns: f64,
    /// The retransmit timeout in force at snapshot time, ns (fixed
    /// value, or the clamped adaptive estimate).
    pub rto_ns: f64,
}

impl RelStats {
    pub fn of(rel: &RelState) -> RelStats {
        RelStats {
            sent: rel.tx.sent,
            sent_bytes: rel.tx.sent_bytes,
            retransmitted: rel.tx.retransmitted,
            retransmitted_bytes: rel.tx.retransmitted_bytes,
            timeouts: rel.tx.timeouts,
            accepted: rel.rx.accepted,
            accepted_bytes: rel.rx.accepted_bytes,
            dropped_corrupt: rel.rx.dropped_corrupt,
            dropped_out_of_order: rel.rx.dropped_out_of_order,
            buffered_out_of_order: rel.rx.buffered_out_of_order,
            peak_buffered: rel.rx.peak_buffered,
            sacks: rel.tx.sacked,
            peak_replay: rel.tx.peak_replay,
            injected_drops: rel.faults.stats.dropped,
            injected_corrupts: rel.faults.stats.corrupted,
            injected_reorders: rel.faults.stats.reordered,
            piggybacked_acks: rel.piggybacked_acks,
            rtt_samples: rel.tx.rtt_samples,
            srtt_ns: rel.tx.srtt().map_or(0.0, |d| d.as_ns()),
            rto_ns: rel.effective_rto().as_ns(),
        }
    }

    /// Merge another direction's counters (both link directions report
    /// as one stack in the harness).
    pub fn merge(&mut self, o: &RelStats) {
        self.sent += o.sent;
        self.sent_bytes += o.sent_bytes;
        self.retransmitted += o.retransmitted;
        self.retransmitted_bytes += o.retransmitted_bytes;
        self.timeouts += o.timeouts;
        self.accepted += o.accepted;
        self.accepted_bytes += o.accepted_bytes;
        self.dropped_corrupt += o.dropped_corrupt;
        self.dropped_out_of_order += o.dropped_out_of_order;
        self.buffered_out_of_order += o.buffered_out_of_order;
        self.peak_buffered = self.peak_buffered.max(o.peak_buffered);
        self.sacks += o.sacks;
        self.peak_replay = self.peak_replay.max(o.peak_replay);
        self.injected_drops += o.injected_drops;
        self.injected_corrupts += o.injected_corrupts;
        self.injected_reorders += o.injected_reorders;
        self.piggybacked_acks += o.piggybacked_acks;
        self.rtt_samples += o.rtt_samples;
        self.srtt_ns = self.srtt_ns.max(o.srtt_ns);
        self.rto_ns = self.rto_ns.max(o.rto_ns);
    }

    /// Fraction of transmitted link frames that were useful (accepted in
    /// sequence): 1.0 on a clean link, sinking as replays burn
    /// bandwidth. This is the *link* goodput; the figure-level goodput
    /// (completed operations/s) is reported by the open-loop engine.
    pub fn frame_goodput(&self) -> f64 {
        if self.sent == 0 {
            1.0
        } else {
            self.accepted as f64 / self.sent as f64
        }
    }

    /// Replay bytes per delivered byte — the retransmission-ablation
    /// figure's headline metric: how much wire bandwidth the discipline
    /// burns re-sending per byte it actually delivers. 0 on a clean
    /// link; go-back-N amplifies it at exactly the BERs where goodput
    /// matters, selective repeat pays one frame per hole.
    pub fn replay_overhead(&self) -> f64 {
        if self.accepted_bytes == 0 {
            0.0
        } else {
            self.retransmitted_bytes as f64 / self.accepted_bytes as f64
        }
    }

    /// Add the snapshot into a harness counter block under `rel_*` keys.
    pub fn add_to(&self, c: &mut Counters) {
        c.add("rel_sent", self.sent);
        c.add("rel_sent_bytes", self.sent_bytes);
        c.add("rel_retransmitted", self.retransmitted);
        c.add("rel_retransmitted_bytes", self.retransmitted_bytes);
        c.add("rel_timeouts", self.timeouts);
        c.add("rel_accepted", self.accepted);
        c.add("rel_accepted_bytes", self.accepted_bytes);
        c.add("rel_dropped_corrupt", self.dropped_corrupt);
        c.add("rel_dropped_out_of_order", self.dropped_out_of_order);
        c.add("rel_buffered_out_of_order", self.buffered_out_of_order);
        c.add("rel_peak_buffered", self.peak_buffered as u64);
        c.add("rel_sacks", self.sacks);
        c.add("rel_peak_replay", self.peak_replay as u64);
        c.add("rel_injected_drops", self.injected_drops);
        c.add("rel_injected_corrupts", self.injected_corrupts);
        c.add("rel_injected_reorders", self.injected_reorders);
        c.add("rel_piggybacked_acks", self.piggybacked_acks);
        c.add("rel_rtt_samples", self.rtt_samples);
        c.add("rel_rto_ns", self.rto_ns as u64);
    }
}
