//! Per-VC sequencing, acknowledgment, and replay — go-back-N or
//! selective repeat ([`RelMode`]).
//!
//! The link-global transaction layer ([`crate::transport::transaction`])
//! runs ONE sequence space across all 14 VCs: a single corrupted frame
//! rewinds every channel behind it, so a data-response error forces
//! retransmission of unrelated request traffic (head-of-line blocking in
//! the replay machinery itself). This layer refines reliability to the
//! VC granularity — each VC carries its own sequence numbers, replay
//! buffer, cumulative acks, and nack state — so a loss on one channel
//! replays only that channel.
//!
//! Two retransmission disciplines share the sender/receiver pair, keyed
//! by [`RelMode`]:
//!
//! * **Go-back-N** (`RelMode::GoBackN`): the receiver accepts each VC
//!   strictly in sequence and drops everything after a hole; a nack (or
//!   the retransmit timeout) rewinds the sender to the hole and replays
//!   the whole tail — simple, buffer-free, and wasteful exactly when
//!   loss is frequent.
//! * **Selective repeat** (`RelMode::SelectiveRepeat`): the receiver
//!   buffers out-of-order frames (bounded by the replay window), sacks
//!   each buffered frame (`Control::VcSack`) so the sender will not
//!   replay it, and nacks each missing sequence exactly once; delivery
//!   to the consumer stays exactly-once and in per-VC order — buffered
//!   frames release only when the hole fills. Replay bandwidth is one
//!   frame per hole instead of the whole tail.
//!
//! In both modes: corrupted frames renew their nack (a corrupted
//! retransmission must not be absorbed by duplicate suppression, or both
//! ends deadlock), stale duplicates re-ack (`VcAck`) so a timeout-driven
//! replay always resynchronizes the sender, and intact accepted frames
//! accrue *ack debt*: paid either piggybacked on a reverse-direction
//! frame ([`RelRx::piggy_ack`], the link header's ack envelope bit) or
//! as an explicit cumulative-ack control every [`ACK_INTERVAL`] frames.
//! Credits never travel here: a retransmission re-sends a frame whose
//! credit is still held (the receiver never freed the slot), so replay
//! can neither double-consume nor leak a credit — property-tested in
//! `rust/tests/props.rs` (`rel_replay_holds_credits_without_leak`, both
//! modes), with the machine-level overload bound in
//! `rust/tests/rel_faults.rs`.
//!
//! The sender also feeds the adaptive retransmit timer ([`super::rto`]):
//! every ack of a never-retransmitted frame (Karn's rule) contributes a
//! launch→ack RTT sample to that VC's [`RttEstimator`].

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use crate::proto::messages::Message;
use crate::sim::time::{Duration, Time};

use super::super::link::{Control, Frame, Seq};
use super::super::transaction::ACK_INTERVAL;
use super::super::vc::{VcId, NUM_VCS};
use super::rto::RttEstimator;
use super::RelMode;

/// One sent-but-unacked frame parked in a VC's replay buffer.
struct Slot {
    /// Pristine copy: intact, no piggyback.
    frame: Frame,
    /// First-launch time (RTT sampling).
    launched_at: Time,
    /// Ever retransmitted? Karn's rule: acks of such frames are
    /// ambiguous and never contribute RTT samples.
    retransmitted: bool,
    /// Selectively acked (SR): skip on nack rewind and timeout replay;
    /// removed when the cumulative ack sweeps past.
    sacked: bool,
    /// Sitting in the resend FIFO already (dedup).
    queued: bool,
}

/// Sender half: per-VC sequence numbering + replay buffers, shared
/// retransmission FIFO, per-VC RTT estimators.
pub struct RelTx {
    mode: RelMode,
    next_seq: [Seq; NUM_VCS],
    /// Sent-but-unacked slots per VC, seq-ascending.
    replay: [VecDeque<Slot>; NUM_VCS],
    /// Pending retransmissions by reference; entries whose slot was
    /// acked in the meantime are skipped lazily.
    resend: VecDeque<(VcId, Seq)>,
    /// Slots with `queued == true` (= live, replayable resend entries).
    /// [`RelTx::has_resend`] sits on the per-event pump path, so it must
    /// be O(1); this counter tracks every queued-flag transition and
    /// every trim of a still-queued slot.
    queued_live: usize,
    /// Per-VC RTT estimators (adaptive RTO).
    rtt: [RttEstimator; NUM_VCS],
    // stats
    pub sent: u64,
    pub sent_bytes: u64,
    pub retransmitted: u64,
    /// Wire bytes burned on retransmissions (the replay-bandwidth
    /// figure's numerator).
    pub retransmitted_bytes: u64,
    /// Frames acked (cumulative trims + selective acks) — the progress
    /// signal for the retransmit timeout.
    pub acked: u64,
    /// Selective acks applied (SR).
    pub sacked: u64,
    /// Timeout-driven rewinds.
    pub timeouts: u64,
    /// RTT samples fed to the estimators (Karn-filtered).
    pub rtt_samples: u64,
    /// High-water mark of frames parked across all replay buffers.
    pub peak_replay: usize,
}

impl Default for RelTx {
    fn default() -> Self {
        Self::new(RelMode::GoBackN)
    }
}

impl RelTx {
    pub fn new(mode: RelMode) -> RelTx {
        RelTx {
            mode,
            next_seq: [0; NUM_VCS],
            replay: std::array::from_fn(|_| VecDeque::new()),
            resend: VecDeque::new(),
            queued_live: 0,
            rtt: [RttEstimator::new(); NUM_VCS],
            sent: 0,
            sent_bytes: 0,
            retransmitted: 0,
            retransmitted_bytes: 0,
            acked: 0,
            sacked: 0,
            timeouts: 0,
            rtt_samples: 0,
            peak_replay: 0,
        }
    }

    pub fn mode(&self) -> RelMode {
        self.mode
    }

    /// Swap the retransmission discipline in place (live
    /// reconfiguration). Only legal with the replay machinery empty —
    /// every frame acked, nothing queued for resend — which the control
    /// plane's quiesce guarantees. Sequence numbers continue across the
    /// swap, so the peer's receiver state stays valid; RTT estimators
    /// persist (the channel did not change, only the replay discipline).
    pub fn set_mode(&mut self, mode: RelMode) {
        assert_eq!(self.unacked_total(), 0, "rel-mode swap with unacked frames in replay");
        assert!(!self.has_resend(), "rel-mode swap with queued retransmissions");
        self.mode = mode;
    }

    /// Frame a fresh message on `vc` at `now`, parking a pristine copy
    /// in the VC's replay buffer until it is cumulatively acked.
    pub fn frame(&mut self, now: Time, vc: VcId, msg: Message) -> Frame {
        let i = vc.0 as usize;
        let f = Frame::new_on(self.next_seq[i], vc, msg);
        self.next_seq[i] += 1;
        self.sent_bytes += f.own_wire_bytes();
        self.replay[i].push_back(Slot {
            frame: f.clone(),
            launched_at: now,
            retransmitted: false,
            sacked: false,
            queued: false,
        });
        self.peak_replay = self.peak_replay.max(self.unacked_total());
        self.sent += 1;
        f
    }

    fn slot_mut(&mut self, vc: VcId, seq: Seq) -> Option<&mut Slot> {
        let q = &mut self.replay[vc.0 as usize];
        let at = q.binary_search_by_key(&seq, |s| s.frame.seq).ok()?;
        q.get_mut(at)
    }

    /// Pull the next queued retransmission, if any (retransmissions have
    /// launch priority and never consume credits — the original
    /// transmission's credit is still held). Entries acked since they
    /// were queued are skipped.
    pub fn next_resend(&mut self) -> Option<Frame> {
        while let Some((vc, seq)) = self.resend.pop_front() {
            // a stale entry — slot trimmed, or un-queued by a sack —
            // was already removed from `queued_live` at that transition
            let Some(slot) = self.slot_mut(vc, seq) else { continue };
            if !slot.queued {
                continue;
            }
            slot.queued = false;
            slot.retransmitted = true;
            let f = slot.frame.clone();
            self.queued_live -= 1;
            self.retransmitted += 1;
            self.retransmitted_bytes += f.own_wire_bytes();
            self.sent += 1;
            self.sent_bytes += f.own_wire_bytes();
            return Some(f);
        }
        None
    }

    /// Anything replayable queued? O(1) — called from every host pump.
    pub fn has_resend(&self) -> bool {
        self.queued_live > 0
    }

    /// Apply a VC-scoped ack/sack/nack control frame at `now` (the
    /// timestamp feeds RTT sampling).
    pub fn on_control(&mut self, now: Time, c: Control) {
        match c {
            Control::VcAck(vc, upto) => self.trim(now, vc, upto + 1),
            Control::VcSack(vc, seq) => self.on_sack(now, vc, seq),
            Control::VcNack(vc, from) => match self.mode {
                RelMode::GoBackN => {
                    self.trim(now, vc, from);
                    // rewind this VC only: requeue everything still
                    // unacked, replacing any stale resends (already-
                    // queued slots keep their live count — exactly one
                    // entry per queued slot survives the swap)
                    self.resend.retain(|&(v, _)| v != vc);
                    for s in self.replay[vc.0 as usize].iter_mut() {
                        if !s.queued {
                            s.queued = true;
                            self.queued_live += 1;
                        }
                        self.resend.push_back((vc, s.frame.seq));
                    }
                }
                RelMode::SelectiveRepeat => {
                    // retransmit exactly `from` — a nack names one hole,
                    // and says nothing about delivery below it
                    let queue = match self.slot_mut(vc, from) {
                        Some(s) if !s.sacked && !s.queued => {
                            s.queued = true;
                            true
                        }
                        _ => false,
                    };
                    if queue {
                        self.queued_live += 1;
                        self.resend.push_back((vc, from));
                    }
                }
            },
            // link-global controls belong to the transaction layer
            Control::Ack(_) | Control::Nack(_) => {
                debug_assert!(false, "global control routed to the rel layer: {c:?}");
            }
        }
    }

    /// Selective ack: exactly `seq` arrived and is buffered at the
    /// receiver — never replay it again.
    fn on_sack(&mut self, now: Time, vc: VcId, seq: Seq) {
        debug_assert!(
            self.mode == RelMode::SelectiveRepeat,
            "sack reached a go-back-N sender"
        );
        let i = vc.0 as usize;
        let Some(s) = self.slot_mut(vc, seq) else { return };
        if s.sacked {
            return;
        }
        s.sacked = true;
        // a queued resend of this slot is now pointless: un-queue it
        // (its FIFO entry goes stale and is skipped on pop)
        let was_queued = s.queued;
        s.queued = false;
        let sample = (!s.retransmitted && now >= s.launched_at).then(|| now.since(s.launched_at));
        if was_queued {
            self.queued_live -= 1;
        }
        self.sacked += 1;
        self.acked += 1;
        if let Some(rtt) = sample {
            self.rtt[i].observe(rtt);
            self.rtt_samples += 1;
        }
    }

    /// Cumulatively ack `vc` below `below`.
    fn trim(&mut self, now: Time, vc: VcId, below: Seq) {
        let i = vc.0 as usize;
        let mut sample: Option<Duration> = None;
        let mut acked = 0u64;
        let mut unqueued = 0usize;
        let q = &mut self.replay[i];
        while q.front().is_some_and(|s| s.frame.seq < below) {
            let s = q.pop_front().expect("front checked");
            if !s.sacked {
                // sacked slots already counted toward ack progress
                acked += 1;
            }
            if s.queued {
                // its resend entry just went stale
                unqueued += 1;
            }
            // Karn: the newest never-retransmitted frame in the trim
            // provides the freshest unambiguous RTT sample
            if !s.retransmitted && now >= s.launched_at {
                sample = Some(now.since(s.launched_at));
            }
        }
        self.acked += acked;
        self.queued_live -= unqueued;
        if let Some(rtt) = sample {
            self.rtt[i].observe(rtt);
            self.rtt_samples += 1;
        }
    }

    /// Timeout expiry with no ack progress: queue every replayable
    /// unacked frame (go-back-N: all of them; selective repeat: the
    /// un-sacked ones only). Returns true when anything was queued.
    pub fn force_replay_all(&mut self) -> bool {
        self.resend.clear();
        let sr = self.mode == RelMode::SelectiveRepeat;
        let mut live = 0usize;
        for (i, q) in self.replay.iter_mut().enumerate() {
            for s in q.iter_mut() {
                if sr && s.sacked {
                    s.queued = false;
                    continue;
                }
                s.queued = true;
                live += 1;
                self.resend.push_back((VcId(i as u8), s.frame.seq));
            }
        }
        self.queued_live = live;
        let any = !self.resend.is_empty();
        if any {
            self.timeouts += 1;
        }
        any
    }

    pub fn unacked(&self, vc: VcId) -> usize {
        self.replay[vc.0 as usize].len()
    }

    pub fn unacked_total(&self) -> usize {
        self.replay.iter().map(|q| q.len()).sum()
    }

    /// Widest per-VC RTO estimate `srtt + 4·rttvar` (unclamped), if any
    /// VC has absorbed a sample. The per-direction retransmit timer
    /// takes the maximum so the slowest channel sets the pace — a
    /// premature rewind costs replay bandwidth on every VC.
    pub fn measured_rto(&self) -> Option<Duration> {
        self.rtt.iter().filter_map(|e| e.rto()).max()
    }

    /// Widest per-VC smoothed RTT (reporting).
    pub fn srtt(&self) -> Option<Duration> {
        self.rtt.iter().filter_map(|e| e.srtt()).max()
    }
}

/// Receiver half: per-VC in-order acceptance (go-back-N) or windowed
/// out-of-order buffering (selective repeat), plus ack/nack/sack
/// generation with piggyback-able cumulative-ack debt.
pub struct RelRx {
    mode: RelMode,
    /// Out-of-order buffering window (SR), in frames past `expected`.
    /// Sized to the replay window: each buffered frame still holds its
    /// link credit, so the sender can never legally exceed it.
    window: u64,
    expected: [Seq; NUM_VCS],
    /// GBN: a nack for this seq was already issued on the VC; suppress
    /// duplicates until progress resumes.
    nacked: [Option<Seq>; NUM_VCS],
    /// SR: per-VC set of outstanding nacked holes (dedup per seq).
    nacked_sr: [BTreeSet<Seq>; NUM_VCS],
    /// SR: per-VC out-of-order receive buffer.
    ooo: [BTreeMap<Seq, Frame>; NUM_VCS],
    since_ack: [u64; NUM_VCS],
    /// Cumulative-ack debt per VC, available for piggybacking.
    debt: [bool; NUM_VCS],
    /// Piggyback round-robin cursor.
    rr: usize,
    // stats
    pub accepted: u64,
    /// Wire bytes of frames delivered to the consumer (the
    /// replay-bandwidth figure's denominator).
    pub accepted_bytes: u64,
    pub dropped_corrupt: u64,
    pub dropped_out_of_order: u64,
    /// Frames parked out of order awaiting a hole fill (SR).
    pub buffered_out_of_order: u64,
    /// High-water mark of the out-of-order buffer (SR, all VCs).
    pub peak_buffered: usize,
    /// Stale duplicates re-acked / re-sacked (timeout resync).
    pub reacked: u64,
}

impl Default for RelRx {
    fn default() -> Self {
        Self::new(RelMode::GoBackN, 64)
    }
}

impl RelRx {
    pub fn new(mode: RelMode, window: u64) -> RelRx {
        RelRx {
            mode,
            window: window.max(1),
            expected: [0; NUM_VCS],
            nacked: [None; NUM_VCS],
            nacked_sr: std::array::from_fn(|_| BTreeSet::new()),
            ooo: std::array::from_fn(|_| BTreeMap::new()),
            since_ack: [0; NUM_VCS],
            debt: [false; NUM_VCS],
            rr: 0,
            accepted: 0,
            accepted_bytes: 0,
            dropped_corrupt: 0,
            dropped_out_of_order: 0,
            buffered_out_of_order: 0,
            peak_buffered: 0,
            reacked: 0,
        }
    }

    /// Process one arriving frame. Frames delivered to the consumer —
    /// possibly several: a hole-filling retransmission releases its
    /// buffered successors — are appended to `delivered`, exactly once
    /// and in per-VC sequence order; controls for the reverse path go
    /// to `ctls`.
    pub fn on_frame(&mut self, f: Frame, delivered: &mut Vec<Frame>, ctls: &mut Vec<Control>) {
        match self.mode {
            RelMode::GoBackN => self.on_frame_gbn(f, delivered, ctls),
            RelMode::SelectiveRepeat => self.on_frame_sr(f, delivered, ctls),
        }
    }

    fn on_frame_gbn(&mut self, f: Frame, delivered: &mut Vec<Frame>, ctls: &mut Vec<Control>) {
        let vc = f.vc;
        let i = vc.0 as usize;
        if !f.intact {
            self.dropped_corrupt += 1;
            // corruption always renews the nack — a corrupted
            // retransmission must not be absorbed by duplicate
            // suppression, or both ends deadlock
            self.nacked[i] = Some(self.expected[i]);
            ctls.push(Control::VcNack(vc, self.expected[i]));
            return;
        }
        if f.seq != self.expected[i] {
            self.dropped_out_of_order += 1;
            if f.seq > self.expected[i] {
                // gap: an earlier frame was lost/corrupted in flight
                if let Some(c) = self.nack_gbn(vc) {
                    ctls.push(c);
                }
                return;
            }
            // stale duplicate (already delivered): re-ack so a
            // timeout-driven replay of acked-but-untrimmed frames always
            // resynchronizes the sender instead of looping forever
            self.reacked += 1;
            self.since_ack[i] = 0;
            self.debt[i] = false;
            ctls.push(Control::VcAck(vc, self.expected[i] - 1));
            return;
        }
        self.expected[i] += 1;
        self.nacked[i] = None;
        self.accept(&f);
        delivered.push(f);
        if let Some(c) = self.ack_cadence(vc, 1) {
            ctls.push(c);
        }
    }

    fn on_frame_sr(&mut self, f: Frame, delivered: &mut Vec<Frame>, ctls: &mut Vec<Control>) {
        let vc = f.vc;
        let i = vc.0 as usize;
        if !f.intact {
            self.dropped_corrupt += 1;
            if f.seq < self.expected[i] {
                // stale duplicate arriving corrupted: re-ack resync
                self.reacked += 1;
                self.since_ack[i] = 0;
                self.debt[i] = false;
                ctls.push(Control::VcAck(vc, self.expected[i] - 1));
            } else if self.ooo[i].contains_key(&f.seq) {
                // an intact copy is already buffered: the sack was lost
                // on the sender side of the story — repeat it
                self.reacked += 1;
                ctls.push(Control::VcSack(vc, f.seq));
            } else {
                // renewed per-seq nack (never suppressed: a corrupted
                // retransmission must re-request itself)
                self.nacked_sr[i].insert(f.seq);
                ctls.push(Control::VcNack(vc, f.seq));
            }
            return;
        }
        if f.seq < self.expected[i] {
            // stale duplicate (already delivered): re-ack resync
            self.dropped_out_of_order += 1;
            self.reacked += 1;
            self.since_ack[i] = 0;
            self.debt[i] = false;
            ctls.push(Control::VcAck(vc, self.expected[i] - 1));
            return;
        }
        if f.seq == self.expected[i] {
            self.expected[i] += 1;
            self.accept(&f);
            delivered.push(f);
            // the hole filled: release every consecutive buffered
            // successor, still exactly-once and in sequence
            let mut n = 1u64;
            while let Some(g) = self.ooo[i].remove(&self.expected[i]) {
                self.expected[i] += 1;
                self.accept(&g);
                delivered.push(g);
                n += 1;
            }
            // nacks for holes now behind us are satisfied
            let live = self.nacked_sr[i].split_off(&self.expected[i]);
            self.nacked_sr[i] = live;
            if let Some(c) = self.ack_cadence(vc, n) {
                ctls.push(c);
            }
            return;
        }
        // out of order, ahead of the hole
        if f.seq >= self.expected[i] + self.window {
            // beyond the buffering window (cannot happen under credit
            // flow control; guard against a misconfigured peer)
            self.dropped_out_of_order += 1;
            return;
        }
        if self.ooo[i].contains_key(&f.seq) {
            // duplicate of a buffered frame: the sender missed the sack
            self.reacked += 1;
            ctls.push(Control::VcSack(vc, f.seq));
            return;
        }
        let seq = f.seq;
        self.nacked_sr[i].remove(&seq);
        self.ooo[i].insert(seq, f);
        self.buffered_out_of_order += 1;
        let held: usize = self.ooo.iter().map(|m| m.len()).sum();
        self.peak_buffered = self.peak_buffered.max(held);
        ctls.push(Control::VcSack(vc, seq));
        // nack every unrequested hole below the newcomer, once each
        let newest = self.ooo[i].keys().next_back().copied().expect("just inserted");
        for s in self.expected[i]..newest {
            if !self.ooo[i].contains_key(&s) && self.nacked_sr[i].insert(s) {
                ctls.push(Control::VcNack(vc, s));
            }
        }
    }

    fn accept(&mut self, f: &Frame) {
        self.accepted += 1;
        // exclude any piggybacked ack word: sender-side byte counters
        // are taken from the pristine copy, and the replay-overhead
        // ratio must compare like with like
        self.accepted_bytes += f.own_wire_bytes();
    }

    /// Account `n` deliveries on `vc` against the explicit-ack cadence.
    fn ack_cadence(&mut self, vc: VcId, n: u64) -> Option<Control> {
        let i = vc.0 as usize;
        self.since_ack[i] += n;
        self.debt[i] = true;
        if self.since_ack[i] >= ACK_INTERVAL {
            self.since_ack[i] = 0;
            self.debt[i] = false;
            Some(Control::VcAck(vc, self.expected[i] - 1))
        } else {
            None
        }
    }

    fn nack_gbn(&mut self, vc: VcId) -> Option<Control> {
        let i = vc.0 as usize;
        if self.nacked[i] == Some(self.expected[i]) {
            None // this replay was already requested
        } else {
            self.nacked[i] = Some(self.expected[i]);
            Some(Control::VcNack(vc, self.expected[i]))
        }
    }

    /// Any cumulative-ack debt outstanding? (Drives the host's
    /// delayed-ack flush: debt that finds no reverse frame to ride
    /// within [`super::ACK_FLUSH_DELAY`] goes out as an explicit
    /// control, so a quiet link never mistakes ack delay for loss.)
    pub fn has_debt(&self) -> bool {
        self.debt.iter().any(|d| *d)
    }

    /// Take one VC's cumulative ack for piggybacking on a
    /// reverse-direction frame (round-robin across indebted VCs).
    /// Clears that VC's debt — the explicit-ack cadence restarts.
    pub fn piggy_ack(&mut self) -> Option<(VcId, Seq)> {
        for k in 0..NUM_VCS {
            let i = (self.rr + k) % NUM_VCS;
            if self.debt[i] {
                self.rr = (i + 1) % NUM_VCS;
                self.debt[i] = false;
                self.since_ack[i] = 0;
                return Some((VcId(i as u8), self.expected[i] - 1));
            }
        }
        None
    }

    /// Receiver half of the live rel-mode swap: only legal with the
    /// out-of-order buffer empty (the quiesced link has no holes).
    /// `expected` continues, so in-flight sequence spaces stay aligned;
    /// stale nack-dedup state is cleared — every hole it described has
    /// drained.
    pub fn set_mode(&mut self, mode: RelMode) {
        assert_eq!(self.buffered(), 0, "rel-mode swap with out-of-order frames buffered");
        self.mode = mode;
        self.nacked = [None; NUM_VCS];
        for s in self.nacked_sr.iter_mut() {
            s.clear();
        }
    }

    pub fn expected_seq(&self, vc: VcId) -> Seq {
        self.expected[vc.0 as usize]
    }

    /// Frames currently parked out of order (SR).
    pub fn buffered(&self) -> usize {
        self.ooo.iter().map(|m| m.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::messages::{CohOp, LineAddr, ReqId};
    use crate::proto::states::Node;

    fn req(i: u64, addr: u64) -> Message {
        Message::coh_req(ReqId(i as u32), Node::Remote, CohOp::ReadShared, LineAddr(addr))
    }

    const T0: Time = Time(0);

    /// Feed one frame, returning (delivered, controls).
    fn rx1(rx: &mut RelRx, f: Frame) -> (Vec<Frame>, Vec<Control>) {
        let mut d = Vec::new();
        let mut c = Vec::new();
        rx.on_frame(f, &mut d, &mut c);
        (d, c)
    }

    #[test]
    fn per_vc_sequences_are_independent() {
        let mut tx = RelTx::new(RelMode::GoBackN);
        let f0 = tx.frame(T0, VcId(0), req(0, 0));
        let f1 = tx.frame(T0, VcId(1), req(1, 1));
        let f2 = tx.frame(T0, VcId(0), req(2, 2));
        assert_eq!((f0.seq, f1.seq, f2.seq), (0, 0, 1), "each VC counts from 0");
        assert_eq!(tx.unacked(VcId(0)), 2);
        assert_eq!(tx.unacked(VcId(1)), 1);
    }

    #[test]
    fn nack_rewinds_only_its_vc() {
        let mut tx = RelTx::new(RelMode::GoBackN);
        for i in 0..4u64 {
            tx.frame(T0, VcId(0), req(i, 2 * i));
            tx.frame(T0, VcId(1), req(10 + i, 2 * i + 1));
        }
        tx.on_control(T0, Control::VcNack(VcId(0), 1));
        // seq 0 on VC0 is implicitly acked; 1..3 rewound; VC1 untouched
        assert_eq!(tx.unacked(VcId(0)), 3);
        assert_eq!(tx.unacked(VcId(1)), 4);
        let mut resent = Vec::new();
        while let Some(f) = tx.next_resend() {
            resent.push((f.vc, f.seq));
        }
        assert_eq!(resent, vec![(VcId(0), 1), (VcId(0), 2), (VcId(0), 3)]);
        assert_eq!(tx.retransmitted, 3);
        assert!(tx.retransmitted_bytes > 0);
        assert_eq!(tx.acked, 1);
    }

    #[test]
    fn cumulative_ack_trims_and_counts() {
        let mut tx = RelTx::new(RelMode::GoBackN);
        for i in 0..6u64 {
            tx.frame(T0, VcId(6), req(i, 2 * i));
        }
        tx.on_control(T0, Control::VcAck(VcId(6), 3));
        assert_eq!(tx.unacked(VcId(6)), 2);
        assert_eq!(tx.acked, 4);
        assert_eq!(tx.peak_replay, 6);
    }

    #[test]
    fn receiver_is_in_order_per_vc_with_gap_nacks() {
        let mut tx = RelTx::new(RelMode::GoBackN);
        let mut rx = RelRx::new(RelMode::GoBackN, 64);
        let a = tx.frame(T0, VcId(0), req(0, 0));
        let b = tx.frame(T0, VcId(0), req(1, 2));
        let c = tx.frame(T0, VcId(1), req(2, 1));
        assert_eq!(rx1(&mut rx, a).0.len(), 1);
        // b lost in flight; c (a different VC) is NOT disturbed
        assert_eq!(rx1(&mut rx, c).0.len(), 1);
        // next VC0 frame reveals the gap -> nack(1), once
        let d = tx.frame(T0, VcId(0), req(3, 4));
        let (del, ctl) = rx1(&mut rx, d.clone());
        assert!(del.is_empty());
        assert_eq!(ctl, vec![Control::VcNack(VcId(0), 1)]);
        let (del, ctl) = rx1(&mut rx, d);
        assert!(del.is_empty() && ctl.is_empty(), "dup nack suppressed");
        // replay from 1 delivers b then d
        tx.on_control(T0, Control::VcNack(VcId(0), 1));
        let rb = tx.next_resend().unwrap();
        assert_eq!((rb.vc, rb.seq), (b.vc, b.seq));
        assert_eq!(rx1(&mut rx, rb).0.len(), 1);
        let rd = tx.next_resend().unwrap();
        assert_eq!(rx1(&mut rx, rd).0.len(), 1);
        assert_eq!(rx.accepted, 4);
    }

    #[test]
    fn stale_duplicate_reacks_for_timeout_resync() {
        let mut tx = RelTx::new(RelMode::GoBackN);
        let mut rx = RelRx::new(RelMode::GoBackN, 64);
        let a = tx.frame(T0, VcId(4), req(0, 0));
        assert_eq!(rx1(&mut rx, a).0.len(), 1);
        // ack lost conceptually; sender times out and replays
        assert!(tx.force_replay_all());
        assert_eq!(tx.timeouts, 1);
        let ra = tx.next_resend().unwrap();
        let (del, ctl) = rx1(&mut rx, ra);
        assert!(del.is_empty());
        assert_eq!(ctl, vec![Control::VcAck(VcId(4), 0)], "expected a re-ack");
        tx.on_control(T0, Control::VcAck(VcId(4), 0));
        assert_eq!(tx.unacked_total(), 0, "resync must drain the replay buffer");
        assert!(!tx.force_replay_all(), "nothing left to replay");
        assert_eq!(tx.timeouts, 1, "an empty rewind is not a timeout");
    }

    #[test]
    fn corruption_renews_the_nack() {
        let mut tx = RelTx::new(RelMode::GoBackN);
        let mut rx = RelRx::new(RelMode::GoBackN, 64);
        let mut a = tx.frame(T0, VcId(8), req(0, 0));
        a.intact = false;
        let (_, ctl) = rx1(&mut rx, a.clone());
        assert_eq!(ctl, vec![Control::VcNack(VcId(8), 0)]);
        // the corrupted RETRANSMISSION must nack again (no suppression)
        let (_, ctl) = rx1(&mut rx, a);
        assert_eq!(ctl, vec![Control::VcNack(VcId(8), 0)]);
        assert_eq!(rx.dropped_corrupt, 2);
    }

    #[test]
    fn explicit_acks_flow_every_interval_and_piggyback_clears_debt() {
        let mut tx = RelTx::new(RelMode::GoBackN);
        let mut rx = RelRx::new(RelMode::GoBackN, 64);
        let mut explicit = 0;
        for i in 0..(ACK_INTERVAL - 1) {
            let f = tx.frame(T0, VcId(0), req(i, 2 * i));
            if !rx1(&mut rx, f).1.is_empty() {
                explicit += 1;
            }
        }
        assert_eq!(explicit, 0);
        // debt is piggyback-able before the interval fills
        let (vc, upto) = rx.piggy_ack().expect("ack debt pending");
        assert_eq!((vc, upto), (VcId(0), ACK_INTERVAL - 2));
        assert!(rx.piggy_ack().is_none(), "debt cleared");
        tx.on_control(T0, Control::VcAck(vc, upto));
        assert_eq!(tx.unacked_total(), 0, "all acked");
        // after piggyback the explicit cadence restarts from zero
        for i in 0..ACK_INTERVAL {
            let f = tx.frame(T0, VcId(0), req(100 + i, 2 * i));
            let (_, ctl) = rx1(&mut rx, f);
            if ctl.iter().any(|c| matches!(c, Control::VcAck(..))) {
                explicit += 1;
            }
        }
        assert_eq!(explicit, 1, "one explicit ack per full interval");
    }

    #[test]
    fn sr_buffers_out_of_order_and_releases_in_sequence() {
        let mut tx = RelTx::new(RelMode::SelectiveRepeat);
        let mut rx = RelRx::new(RelMode::SelectiveRepeat, 64);
        let a = tx.frame(T0, VcId(0), req(0, 0));
        let _b = tx.frame(T0, VcId(0), req(1, 2));
        let c = tx.frame(T0, VcId(0), req(2, 4));
        assert_eq!(rx1(&mut rx, a).0.len(), 1);
        // b lost; c arrives out of order: buffered + sacked + nack(1)
        let (del, ctl) = rx1(&mut rx, c);
        assert!(del.is_empty(), "out-of-order frames are held, not delivered");
        assert_eq!(
            ctl,
            vec![Control::VcSack(VcId(0), 2), Control::VcNack(VcId(0), 1)]
        );
        assert_eq!(rx.buffered(), 1);
        // sender learns: sack parks seq 2, nack queues exactly seq 1
        tx.on_control(T0, Control::VcSack(VcId(0), 2));
        tx.on_control(T0, Control::VcNack(VcId(0), 1));
        let rb = tx.next_resend().unwrap();
        assert_eq!((rb.vc, rb.seq), (VcId(0), 1));
        assert!(tx.next_resend().is_none(), "only the hole is replayed");
        assert_eq!(tx.retransmitted, 1);
        // the hole fills: b AND the buffered c release, in order
        let (del, _) = rx1(&mut rx, rb);
        assert_eq!(del.iter().map(|f| f.seq).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(rx.buffered(), 0);
        assert_eq!(rx.accepted, 3);
        // cumulative ack trims everything, sacked slot included
        tx.on_control(T0, Control::VcAck(VcId(0), 2));
        assert_eq!(tx.unacked_total(), 0);
        assert_eq!(tx.acked, 3, "sacked frames count ack progress once");
    }

    #[test]
    fn sr_timeout_replays_only_unsacked_frames() {
        let mut tx = RelTx::new(RelMode::SelectiveRepeat);
        for i in 0..4u64 {
            tx.frame(T0, VcId(3), req(i, 2 * i));
        }
        tx.on_control(T0, Control::VcSack(VcId(3), 1));
        tx.on_control(T0, Control::VcSack(VcId(3), 3));
        assert!(tx.force_replay_all());
        let mut resent = Vec::new();
        while let Some(f) = tx.next_resend() {
            resent.push(f.seq);
        }
        assert_eq!(resent, vec![0, 2], "sacked frames must not replay");
        assert_eq!(tx.timeouts, 1);
    }

    #[test]
    fn sr_nack_dedups_but_corruption_renews() {
        let mut tx = RelTx::new(RelMode::SelectiveRepeat);
        let mut rx = RelRx::new(RelMode::SelectiveRepeat, 64);
        let _a = tx.frame(T0, VcId(0), req(0, 0));
        let b = tx.frame(T0, VcId(0), req(1, 2));
        let c = tx.frame(T0, VcId(0), req(2, 4));
        // a lost; b arrives: sack(1) + nack(0)
        let (_, ctl) = rx1(&mut rx, b);
        assert_eq!(
            ctl,
            vec![Control::VcSack(VcId(0), 1), Control::VcNack(VcId(0), 0)]
        );
        // c arrives: sack(2) only — the hole at 0 was already nacked
        let (_, ctl) = rx1(&mut rx, c);
        assert_eq!(ctl, vec![Control::VcSack(VcId(0), 2)]);
        // a corrupted replay of 0 renews the nack (never suppressed)
        let mut ra = Frame::new_on(0, VcId(0), req(0, 0));
        ra.intact = false;
        let (_, ctl) = rx1(&mut rx, ra);
        assert_eq!(ctl, vec![Control::VcNack(VcId(0), 0)]);
    }

    #[test]
    fn sr_duplicate_of_buffered_frame_resacks() {
        let mut rx = RelRx::new(RelMode::SelectiveRepeat, 64);
        let f = Frame::new_on(2, VcId(0), req(2, 4));
        let (_, ctl) = rx1(&mut rx, f.clone());
        assert!(ctl.contains(&Control::VcSack(VcId(0), 2)));
        let (del, ctl) = rx1(&mut rx, f);
        assert!(del.is_empty());
        assert_eq!(ctl, vec![Control::VcSack(VcId(0), 2)], "dup re-sacks");
        assert_eq!(rx.buffered(), 1, "no double buffering");
    }

    #[test]
    fn sr_stale_duplicate_reacks_for_resync() {
        let mut tx = RelTx::new(RelMode::SelectiveRepeat);
        let mut rx = RelRx::new(RelMode::SelectiveRepeat, 64);
        let a = tx.frame(T0, VcId(4), req(0, 0));
        assert_eq!(rx1(&mut rx, a).0.len(), 1);
        assert!(tx.force_replay_all());
        let ra = tx.next_resend().unwrap();
        let (del, ctl) = rx1(&mut rx, ra);
        assert!(del.is_empty());
        assert_eq!(ctl, vec![Control::VcAck(VcId(4), 0)]);
        tx.on_control(T0, Control::VcAck(VcId(4), 0));
        assert_eq!(tx.unacked_total(), 0);
    }

    #[test]
    fn sr_window_bounds_the_receive_buffer() {
        let mut rx = RelRx::new(RelMode::SelectiveRepeat, 4);
        // seq 0 missing; 1..=3 buffer (within expected+4), 7 is out
        for s in 1..=3u64 {
            let (_, ctl) = rx1(&mut rx, Frame::new_on(s, VcId(0), req(s, 2 * s)));
            assert!(ctl.contains(&Control::VcSack(VcId(0), s)));
        }
        let (del, ctl) = rx1(&mut rx, Frame::new_on(7, VcId(0), req(7, 14)));
        assert!(del.is_empty() && ctl.is_empty(), "out-of-window frame dropped");
        assert_eq!(rx.buffered(), 3);
        assert_eq!(rx.dropped_out_of_order, 1);
    }

    #[test]
    fn rtt_samples_feed_the_estimator_and_karn_excludes_replays() {
        let mut tx = RelTx::new(RelMode::GoBackN);
        tx.frame(Time(0), VcId(0), req(0, 0));
        tx.on_control(Time(500_000), Control::VcAck(VcId(0), 0));
        assert_eq!(tx.rtt_samples, 1);
        assert_eq!(tx.srtt().unwrap(), Duration::from_ns(500));
        // a retransmitted frame must not sample (Karn)
        tx.frame(Time(1_000_000), VcId(0), req(1, 2));
        tx.on_control(Time(1_000_000), Control::VcNack(VcId(0), 1));
        let _ = tx.next_resend().unwrap();
        tx.on_control(Time(9_000_000), Control::VcAck(VcId(0), 1));
        assert_eq!(tx.rtt_samples, 1, "ambiguous sample excluded");
        assert_eq!(tx.srtt().unwrap(), Duration::from_ns(500));
        assert!(tx.measured_rto().is_some());
    }

    #[test]
    fn random_per_vc_loss_delivers_everything_in_order_both_modes() {
        use crate::sim::rng::Rng;
        for mode in [RelMode::GoBackN, RelMode::SelectiveRepeat] {
            let mut rng = Rng::new(77);
            let mut tx = RelTx::new(mode);
            let mut rx = RelRx::new(mode, 64);
            let total = 3_000u64;
            let mut next = 0u64;
            let mut delivered: Vec<Vec<u64>> = vec![Vec::new(); NUM_VCS];
            let mut idle = 0;
            while delivered.iter().map(|v| v.len() as u64).sum::<u64>() < total {
                let f = if let Some(f) = tx.next_resend() {
                    f
                } else if next < total {
                    let addr = rng.below(1 << 20);
                    let m = req(next, addr);
                    next += 1;
                    let vc = super::super::super::vc::vc_for(&m);
                    tx.frame(T0, vc, m)
                } else {
                    // tail loss: model the timeout
                    idle += 1;
                    assert!(idle < 50, "{mode:?} seqrep deadlocked");
                    tx.force_replay_all();
                    continue;
                };
                idle = 0;
                if rng.chance(0.10) {
                    continue; // dropped on the wire
                }
                let mut f = f;
                if rng.chance(0.05) {
                    f.intact = false;
                }
                let (del, ctls) = rx1(&mut rx, f);
                for g in del {
                    delivered[g.vc.0 as usize].push(g.msg.addr.0);
                }
                for c in ctls {
                    tx.on_control(T0, c);
                }
            }
            // drain remaining acks so the replay buffers empty
            for vc in 0..NUM_VCS {
                if rx.expected_seq(VcId(vc as u8)) > 0 {
                    tx.on_control(
                        T0,
                        Control::VcAck(VcId(vc as u8), rx.expected_seq(VcId(vc as u8)) - 1),
                    );
                }
            }
            assert_eq!(tx.unacked_total(), 0, "{mode:?}");
            assert!(tx.retransmitted > 0, "{mode:?} should have exercised replay");
            let n: u64 = delivered.iter().map(|v| v.len() as u64).sum();
            assert_eq!(n, total, "{mode:?}: exactly-once delivery");
            // per-VC delivery must be exactly-once in per-VC send order;
            // this traffic's addresses are drawn fresh per message, so
            // equality of counts plus in-order release (asserted by the
            // SR unit tests) pins it — additionally check SR released
            // nothing out of buffered order
            if mode == RelMode::SelectiveRepeat {
                assert!(rx.buffered_out_of_order > 0, "SR must have buffered");
                assert_eq!(rx.buffered(), 0, "no stragglers in the OOO buffer");
            }
        }
    }

    #[test]
    fn mode_swap_on_drained_pair_keeps_sequences_continuous() {
        let mut tx = RelTx::new(RelMode::GoBackN);
        let mut rx = RelRx::new(RelMode::GoBackN, 64);
        // traffic in GBN, fully acked
        for i in 0..3u64 {
            let f = tx.frame(T0, VcId(0), req(i, 2 * i));
            assert_eq!(rx1(&mut rx, f).0.len(), 1);
        }
        tx.on_control(T0, Control::VcAck(VcId(0), 2));
        assert_eq!(tx.unacked_total(), 0);
        // live swap to selective repeat on the drained pair
        tx.set_mode(RelMode::SelectiveRepeat);
        rx.set_mode(RelMode::SelectiveRepeat);
        assert_eq!(tx.mode(), RelMode::SelectiveRepeat);
        // sequences continue where GBN left off, and the new discipline
        // is live: a hole buffers + sacks instead of dropping the tail
        let _d = tx.frame(T0, VcId(0), req(3, 6));
        let e = tx.frame(T0, VcId(0), req(4, 8));
        assert_eq!(e.seq, 4, "sequence space must survive the swap");
        let (del, ctl) = rx1(&mut rx, e);
        assert!(del.is_empty(), "SR holds out-of-order frames");
        assert_eq!(ctl, vec![Control::VcSack(VcId(0), 4), Control::VcNack(VcId(0), 3)]);
        tx.on_control(T0, Control::VcSack(VcId(0), 4));
        tx.on_control(T0, Control::VcNack(VcId(0), 3));
        let rd = tx.next_resend().unwrap();
        assert_eq!(rd.seq, 3, "only the hole replays after the swap");
        let (del, _) = rx1(&mut rx, rd);
        assert_eq!(del.iter().map(|f| f.seq).collect::<Vec<_>>(), vec![3, 4]);
    }

    #[test]
    #[should_panic(expected = "rel-mode swap with unacked frames")]
    fn mode_swap_refuses_an_undrained_sender() {
        let mut tx = RelTx::new(RelMode::GoBackN);
        tx.frame(T0, VcId(0), req(0, 0));
        tx.set_mode(RelMode::SelectiveRepeat);
    }

    /// The headline economics: under the same loss pattern, selective
    /// repeat replays strictly fewer bytes than go-back-N.
    #[test]
    fn sr_replays_fewer_bytes_than_gbn_under_identical_loss() {
        use std::collections::HashSet;
        let run = |mode: RelMode| {
            let mut tx = RelTx::new(mode);
            let mut rx = RelRx::new(mode, 64);
            let total = 2_000u64;
            let mut next = 0u64;
            let mut got = 0u64;
            let mut idle = 0;
            // the loss pattern is a pure function of the frame identity
            // (first copy of every hash-selected seq is dropped, replays
            // get through), so both modes see identical wires
            let mut dropped_once: HashSet<Seq> = HashSet::new();
            while got < total {
                let f = if let Some(f) = tx.next_resend() {
                    f
                } else if next < total {
                    let m = req(next, 2 * next);
                    next += 1;
                    tx.frame(T0, VcId(0), m)
                } else {
                    idle += 1;
                    assert!(idle < 50, "{mode:?} deadlocked");
                    tx.force_replay_all();
                    continue;
                };
                idle = 0;
                if (f.seq.wrapping_mul(2_654_435_761)) % 100 < 8 && dropped_once.insert(f.seq) {
                    continue; // dropped on the wire
                }
                let (del, ctls) = rx1(&mut rx, f);
                got += del.len() as u64;
                for c in ctls {
                    tx.on_control(T0, c);
                }
            }
            assert!(tx.retransmitted > 0, "{mode:?} must have replayed");
            tx.retransmitted_bytes
        };
        let gbn = run(RelMode::GoBackN);
        let sr = run(RelMode::SelectiveRepeat);
        assert!(
            sr < gbn,
            "selective repeat must replay strictly fewer bytes: sr {sr} vs gbn {gbn}"
        );
    }
}
