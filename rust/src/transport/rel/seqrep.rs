//! Per-VC sequencing, acknowledgment, and go-back-N replay.
//!
//! The link-global transaction layer ([`crate::transport::transaction`])
//! runs ONE sequence space across all 14 VCs: a single corrupted frame
//! rewinds every channel behind it, so a data-response error forces
//! retransmission of unrelated request traffic (head-of-line blocking in
//! the replay machinery itself). This layer refines reliability to the
//! VC granularity — each VC carries its own sequence numbers, replay
//! buffer, cumulative acks, and nack state — so a loss on one channel
//! replays only that channel.
//!
//! Protocol: the receiver accepts each VC strictly in sequence;
//! corrupted frames renew a `VcNack(vc, expected)`, gaps nack once per
//! expected sequence (duplicate suppression), stale duplicates re-ack
//! (`VcAck`) so a timeout-driven replay always resynchronizes the
//! sender, and intact in-sequence frames deliver and accrue *ack debt*:
//! paid either piggybacked on a reverse-direction frame
//! ([`RelRx::piggy_ack`], the link header's ack envelope bit) or as an
//! explicit cumulative-ack control every [`ACK_INTERVAL`] frames.
//! Credits never travel here: a retransmission re-sends a frame whose
//! credit is still held (the receiver never freed the slot), so replay
//! can neither double-consume nor leak a credit — property-tested in
//! `rust/tests/props.rs` (`rel_replay_holds_credits_without_leak`),
//! with the machine-level overload bound in `rust/tests/rel_faults.rs`.

use std::collections::VecDeque;

use crate::proto::messages::Message;

use super::super::link::{Control, Frame, Seq};
use super::super::transaction::{RxResult, ACK_INTERVAL};
use super::super::vc::{VcId, NUM_VCS};

/// Sender half: per-VC sequence numbering + replay buffers, shared
/// retransmission FIFO.
pub struct RelTx {
    next_seq: [Seq; NUM_VCS],
    /// Sent-but-unacked frames per VC, oldest first (pristine copies:
    /// intact, no piggyback).
    replay: [VecDeque<Frame>; NUM_VCS],
    /// Pending retransmissions (rewound from the replay buffers).
    resend: VecDeque<Frame>,
    // stats
    pub sent: u64,
    pub retransmitted: u64,
    /// Frames cumulatively acked (progress signal for the timeout).
    pub acked: u64,
    /// Timeout-driven full rewinds.
    pub timeouts: u64,
    /// High-water mark of frames parked across all replay buffers.
    pub peak_replay: usize,
}

impl Default for RelTx {
    fn default() -> Self {
        Self::new()
    }
}

impl RelTx {
    pub fn new() -> RelTx {
        RelTx {
            next_seq: [0; NUM_VCS],
            replay: Default::default(),
            resend: VecDeque::new(),
            sent: 0,
            retransmitted: 0,
            acked: 0,
            timeouts: 0,
            peak_replay: 0,
        }
    }

    /// Frame a fresh message on `vc`, parking a pristine copy in the
    /// VC's replay buffer until it is cumulatively acked.
    pub fn frame(&mut self, vc: VcId, msg: Message) -> Frame {
        let i = vc.0 as usize;
        let f = Frame::new_on(self.next_seq[i], vc, msg);
        self.next_seq[i] += 1;
        self.replay[i].push_back(f.clone());
        self.peak_replay = self.peak_replay.max(self.unacked_total());
        self.sent += 1;
        f
    }

    /// Pull the next queued retransmission, if any (retransmissions have
    /// launch priority and never consume credits — the original
    /// transmission's credit is still held).
    pub fn next_resend(&mut self) -> Option<Frame> {
        let f = self.resend.pop_front()?;
        self.retransmitted += 1;
        self.sent += 1;
        Some(f)
    }

    pub fn has_resend(&self) -> bool {
        !self.resend.is_empty()
    }

    /// Apply a VC-scoped ack/nack control frame.
    pub fn on_control(&mut self, c: Control) {
        match c {
            Control::VcAck(vc, upto) => self.trim(vc, upto + 1),
            Control::VcNack(vc, from) => {
                self.trim(vc, from);
                // rewind this VC only: requeue pristine copies of
                // everything still unacked, replacing any stale resends
                self.resend.retain(|f| f.vc != vc);
                for f in self.replay[vc.0 as usize].iter() {
                    self.resend.push_back(f.clone());
                }
            }
            // link-global controls belong to the transaction layer
            Control::Ack(_) | Control::Nack(_) => {
                debug_assert!(false, "global control routed to the rel layer: {c:?}");
            }
        }
    }

    /// Cumulatively ack `vc` below `below`.
    fn trim(&mut self, vc: VcId, below: Seq) {
        let q = &mut self.replay[vc.0 as usize];
        while q.front().is_some_and(|f| f.seq < below) {
            q.pop_front();
            self.acked += 1;
        }
    }

    /// Timeout expiry with no ack progress: rewind every VC with
    /// unacked frames (go-back-N from each VC's oldest unacked).
    /// Returns true when anything was queued for retransmission.
    pub fn force_replay_all(&mut self) -> bool {
        self.resend.clear();
        for q in &self.replay {
            for f in q {
                self.resend.push_back(f.clone());
            }
        }
        let any = !self.resend.is_empty();
        if any {
            self.timeouts += 1;
        }
        any
    }

    pub fn unacked(&self, vc: VcId) -> usize {
        self.replay[vc.0 as usize].len()
    }

    pub fn unacked_total(&self) -> usize {
        self.replay.iter().map(|q| q.len()).sum()
    }
}

/// Receiver half: per-VC in-order acceptance + ack/nack generation with
/// piggyback-able ack debt.
pub struct RelRx {
    expected: [Seq; NUM_VCS],
    /// A nack for this seq was already issued on the VC; suppress
    /// duplicates until progress resumes.
    nacked: [Option<Seq>; NUM_VCS],
    since_ack: [u64; NUM_VCS],
    /// Cumulative-ack debt per VC, available for piggybacking.
    debt: [bool; NUM_VCS],
    /// Piggyback round-robin cursor.
    rr: usize,
    // stats
    pub accepted: u64,
    pub dropped_corrupt: u64,
    pub dropped_out_of_order: u64,
    /// Stale duplicates re-acked (timeout resync).
    pub reacked: u64,
}

impl Default for RelRx {
    fn default() -> Self {
        Self::new()
    }
}

impl RelRx {
    pub fn new() -> RelRx {
        RelRx {
            expected: [0; NUM_VCS],
            nacked: [None; NUM_VCS],
            since_ack: [0; NUM_VCS],
            debt: [false; NUM_VCS],
            rr: 0,
            accepted: 0,
            dropped_corrupt: 0,
            dropped_out_of_order: 0,
            reacked: 0,
        }
    }

    pub fn on_frame(&mut self, f: &Frame) -> RxResult {
        let vc = f.vc;
        let i = vc.0 as usize;
        if !f.intact {
            self.dropped_corrupt += 1;
            // corruption always renews the nack — a corrupted
            // retransmission must not be absorbed by duplicate
            // suppression, or both ends deadlock
            self.nacked[i] = Some(self.expected[i]);
            return RxResult::Drop(Some(Control::VcNack(vc, self.expected[i])));
        }
        if f.seq != self.expected[i] {
            self.dropped_out_of_order += 1;
            if f.seq > self.expected[i] {
                // gap: an earlier frame was lost/corrupted in flight
                return RxResult::Drop(self.nack(vc));
            }
            // stale duplicate (already delivered): re-ack so a
            // timeout-driven replay of acked-but-untrimmed frames always
            // resynchronizes the sender instead of looping forever
            self.reacked += 1;
            self.since_ack[i] = 0;
            self.debt[i] = false;
            return RxResult::Drop(Some(Control::VcAck(vc, self.expected[i] - 1)));
        }
        self.expected[i] += 1;
        self.nacked[i] = None;
        self.accepted += 1;
        self.since_ack[i] += 1;
        self.debt[i] = true;
        let ctl = if self.since_ack[i] >= ACK_INTERVAL {
            self.since_ack[i] = 0;
            self.debt[i] = false;
            Some(Control::VcAck(vc, self.expected[i] - 1))
        } else {
            None
        };
        RxResult::Deliver(ctl)
    }

    fn nack(&mut self, vc: VcId) -> Option<Control> {
        let i = vc.0 as usize;
        if self.nacked[i] == Some(self.expected[i]) {
            None // this replay was already requested
        } else {
            self.nacked[i] = Some(self.expected[i]);
            Some(Control::VcNack(vc, self.expected[i]))
        }
    }

    /// Any cumulative-ack debt outstanding? (Drives the host's
    /// delayed-ack flush: debt that finds no reverse frame to ride
    /// within [`super::ACK_FLUSH_DELAY`] goes out as an explicit
    /// control, so a quiet link never mistakes ack delay for loss.)
    pub fn has_debt(&self) -> bool {
        self.debt.iter().any(|d| *d)
    }

    /// Take one VC's cumulative ack for piggybacking on a
    /// reverse-direction frame (round-robin across indebted VCs).
    /// Clears that VC's debt — the explicit-ack cadence restarts.
    pub fn piggy_ack(&mut self) -> Option<(VcId, Seq)> {
        for k in 0..NUM_VCS {
            let i = (self.rr + k) % NUM_VCS;
            if self.debt[i] {
                self.rr = (i + 1) % NUM_VCS;
                self.debt[i] = false;
                self.since_ack[i] = 0;
                return Some((VcId(i as u8), self.expected[i] - 1));
            }
        }
        None
    }

    pub fn expected_seq(&self, vc: VcId) -> Seq {
        self.expected[vc.0 as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::messages::{CohOp, LineAddr, ReqId};
    use crate::proto::states::Node;

    fn req(i: u64, addr: u64) -> Message {
        Message::coh_req(ReqId(i as u32), Node::Remote, CohOp::ReadShared, LineAddr(addr))
    }

    #[test]
    fn per_vc_sequences_are_independent() {
        let mut tx = RelTx::new();
        let f0 = tx.frame(VcId(0), req(0, 0));
        let f1 = tx.frame(VcId(1), req(1, 1));
        let f2 = tx.frame(VcId(0), req(2, 2));
        assert_eq!((f0.seq, f1.seq, f2.seq), (0, 0, 1), "each VC counts from 0");
        assert_eq!(tx.unacked(VcId(0)), 2);
        assert_eq!(tx.unacked(VcId(1)), 1);
    }

    #[test]
    fn nack_rewinds_only_its_vc() {
        let mut tx = RelTx::new();
        for i in 0..4u64 {
            tx.frame(VcId(0), req(i, 2 * i));
            tx.frame(VcId(1), req(10 + i, 2 * i + 1));
        }
        tx.on_control(Control::VcNack(VcId(0), 1));
        // seq 0 on VC0 is implicitly acked; 1..3 rewound; VC1 untouched
        assert_eq!(tx.unacked(VcId(0)), 3);
        assert_eq!(tx.unacked(VcId(1)), 4);
        let mut resent = Vec::new();
        while let Some(f) = tx.next_resend() {
            resent.push((f.vc, f.seq));
        }
        assert_eq!(resent, vec![(VcId(0), 1), (VcId(0), 2), (VcId(0), 3)]);
        assert_eq!(tx.retransmitted, 3);
        assert_eq!(tx.acked, 1);
    }

    #[test]
    fn cumulative_ack_trims_and_counts() {
        let mut tx = RelTx::new();
        for i in 0..6u64 {
            tx.frame(VcId(6), req(i, 2 * i));
        }
        tx.on_control(Control::VcAck(VcId(6), 3));
        assert_eq!(tx.unacked(VcId(6)), 2);
        assert_eq!(tx.acked, 4);
        assert_eq!(tx.peak_replay, 6);
    }

    #[test]
    fn receiver_is_in_order_per_vc_with_gap_nacks() {
        let mut tx = RelTx::new();
        let mut rx = RelRx::new();
        let a = tx.frame(VcId(0), req(0, 0));
        let b = tx.frame(VcId(0), req(1, 2));
        let c = tx.frame(VcId(1), req(2, 1));
        assert!(matches!(rx.on_frame(&a), RxResult::Deliver(None)));
        // b lost in flight; c (a different VC) is NOT disturbed
        assert!(matches!(rx.on_frame(&c), RxResult::Deliver(None)));
        // next VC0 frame reveals the gap -> nack(1), once
        let d = tx.frame(VcId(0), req(3, 4));
        match rx.on_frame(&d) {
            RxResult::Drop(Some(Control::VcNack(VcId(0), 1))) => {}
            r => panic!("unexpected {r:?}"),
        }
        assert!(matches!(rx.on_frame(&d), RxResult::Drop(None)), "dup nack suppressed");
        // replay from 1 delivers b then d
        tx.on_control(Control::VcNack(VcId(0), 1));
        let rb = tx.next_resend().unwrap();
        assert_eq!((rb.vc, rb.seq), (b.vc, b.seq));
        assert!(matches!(rx.on_frame(&rb), RxResult::Deliver(_)));
        let rd = tx.next_resend().unwrap();
        assert!(matches!(rx.on_frame(&rd), RxResult::Deliver(_)));
        assert_eq!(rx.accepted, 4);
    }

    #[test]
    fn stale_duplicate_reacks_for_timeout_resync() {
        let mut tx = RelTx::new();
        let mut rx = RelRx::new();
        let a = tx.frame(VcId(4), req(0, 0));
        assert!(matches!(rx.on_frame(&a), RxResult::Deliver(_)));
        // ack lost conceptually; sender times out and replays
        assert!(tx.force_replay_all());
        assert_eq!(tx.timeouts, 1);
        let ra = tx.next_resend().unwrap();
        match rx.on_frame(&ra) {
            RxResult::Drop(Some(Control::VcAck(VcId(4), 0))) => {}
            r => panic!("expected a re-ack, got {r:?}"),
        }
        tx.on_control(Control::VcAck(VcId(4), 0));
        assert_eq!(tx.unacked_total(), 0, "resync must drain the replay buffer");
        assert!(!tx.force_replay_all(), "nothing left to replay");
        assert_eq!(tx.timeouts, 1, "an empty rewind is not a timeout");
    }

    #[test]
    fn corruption_renews_the_nack() {
        let mut tx = RelTx::new();
        let mut rx = RelRx::new();
        let mut a = tx.frame(VcId(8), req(0, 0));
        a.intact = false;
        assert!(matches!(
            rx.on_frame(&a),
            RxResult::Drop(Some(Control::VcNack(VcId(8), 0)))
        ));
        // the corrupted RETRANSMISSION must nack again (no suppression)
        assert!(matches!(
            rx.on_frame(&a),
            RxResult::Drop(Some(Control::VcNack(VcId(8), 0)))
        ));
        assert_eq!(rx.dropped_corrupt, 2);
    }

    #[test]
    fn explicit_acks_flow_every_interval_and_piggyback_clears_debt() {
        let mut tx = RelTx::new();
        let mut rx = RelRx::new();
        let mut explicit = 0;
        for i in 0..(ACK_INTERVAL - 1) {
            let f = tx.frame(VcId(0), req(i, 2 * i));
            if let RxResult::Deliver(Some(_)) = rx.on_frame(&f) {
                explicit += 1;
            }
        }
        assert_eq!(explicit, 0);
        // debt is piggyback-able before the interval fills
        let (vc, upto) = rx.piggy_ack().expect("ack debt pending");
        assert_eq!((vc, upto), (VcId(0), ACK_INTERVAL - 2));
        assert!(rx.piggy_ack().is_none(), "debt cleared");
        tx.on_control(Control::VcAck(vc, upto));
        assert_eq!(tx.unacked_total(), 0, "all acked");
        // after piggyback the explicit cadence restarts from zero
        for i in 0..ACK_INTERVAL {
            let f = tx.frame(VcId(0), req(100 + i, 2 * i));
            if let RxResult::Deliver(Some(Control::VcAck(..))) = rx.on_frame(&f) {
                explicit += 1;
            }
        }
        assert_eq!(explicit, 1, "one explicit ack per full interval");
    }

    #[test]
    fn random_per_vc_loss_delivers_everything_in_order() {
        use crate::sim::rng::Rng;
        let mut rng = Rng::new(77);
        let mut tx = RelTx::new();
        let mut rx = RelRx::new();
        let total = 3_000u64;
        let mut next = 0u64;
        let mut delivered: Vec<Vec<u64>> = vec![Vec::new(); NUM_VCS];
        let mut idle = 0;
        while delivered.iter().map(|v| v.len() as u64).sum::<u64>() < total {
            let f = if let Some(f) = tx.next_resend() {
                f
            } else if next < total {
                let addr = rng.below(1 << 20);
                let m = req(next, addr);
                next += 1;
                let vc = super::super::super::vc::vc_for(&m);
                tx.frame(vc, m)
            } else {
                // tail loss: model the timeout
                idle += 1;
                assert!(idle < 50, "seqrep deadlocked");
                tx.force_replay_all();
                continue;
            };
            idle = 0;
            if rng.chance(0.10) {
                continue; // dropped on the wire
            }
            let mut f = f;
            if rng.chance(0.05) {
                f.intact = false;
            }
            match rx.on_frame(&f) {
                RxResult::Deliver(ctl) => {
                    delivered[f.vc.0 as usize].push(f.msg.addr.0);
                    if let Some(c) = ctl {
                        tx.on_control(c);
                    }
                }
                RxResult::Drop(ctl) => {
                    if let Some(c) = ctl {
                        tx.on_control(c);
                    }
                }
            }
        }
        // drain remaining acks so the replay buffers empty
        for vc in 0..NUM_VCS {
            if rx.expected_seq(VcId(vc as u8)) > 0 {
                tx.on_control(Control::VcAck(VcId(vc as u8), rx.expected_seq(VcId(vc as u8)) - 1));
            }
        }
        assert_eq!(tx.unacked_total(), 0);
        assert!(tx.retransmitted > 0, "the test should have exercised replay");
        // per-VC delivery must be exactly-once, in per-VC send order —
        // which for this traffic is ascending ReqId order per VC; verify
        // via the expected counts
        let n: u64 = delivered.iter().map(|v| v.len() as u64).sum();
        assert_eq!(n, total);
    }
}
