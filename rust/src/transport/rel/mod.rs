//! rel — reliable transport over a lossy link.
//!
//! The seed stack delivered frames perfectly (the phys layer could flip
//! a corruption bit, but nothing was ever *lost* or *reordered*, and the
//! only replay machinery ran one sequence space for the whole link).
//! This subsystem makes loss a first-class, measurable condition:
//!
//! * [`fault`] — a seeded, deterministic fault injector, configurable
//!   per VC with drop / bit-error / reorder probabilities and a
//!   Gilbert–Elliott burst mode, interposed on the framed path (both
//!   the workload engine's [`crate::transport::FramedIngress`] and the
//!   machine's link directions consult it at launch time);
//! * [`seqrep`] — per-VC sequencing/ack/replay in one of two
//!   retransmission disciplines ([`RelMode`]): **go-back-N** (strictly
//!   in-order receive, a hole rewinds the whole VC tail) or **selective
//!   repeat** (out-of-order receive buffer bounded by the replay
//!   window, per-seq sack/nack, exactly-once in-order delivery, one
//!   replayed frame per hole). Cumulative acks ride piggybacked on
//!   reverse-direction frames (the link header's ack envelope bit) or
//!   as explicit controls; link credits are held across replays either
//!   way: a replayed frame neither re-consumes nor leaks a credit;
//! * [`rto`] — adaptive retransmit timeout: per-VC srtt/rttvar EWMAs
//!   over launch→ack RTT samples (Karn-filtered), clamped to
//!   [`RTO_FLOOR`], [`RTO_CEIL`] — tail loss recovers at the measured
//!   round trip instead of the worst-case fixed timer;
//! * [`stats`] — retransmission / goodput / replay-bandwidth counters,
//!   surfaced through the machine report, the
//!   `workload::OpenLoopReport`, `harness::fig_goodput`, and the
//!   GBN-vs-SR ablation figure `harness::fig_retx`.
//!
//! The invariant everything here defends: **loss changes timing, never
//! semantics.** Litmus scenarios and final directory state are
//! bit-identical with fault injection on vs off and across both
//! retransmission modes (pinned in `rust/tests/rel_faults.rs` and, via
//! `ECI_LITMUS_FAULTS` × `ECI_LITMUS_REL_MODE`, by the full litmus
//! suite in CI).

pub mod fault;
pub mod rto;
pub mod seqrep;
pub mod stats;

pub use fault::{FaultAction, FaultConfig, FaultInjector, FaultSpec, FaultStats};
pub use rto::RttEstimator;
pub use seqrep::{RelRx, RelTx};
pub use stats::RelStats;

use crate::sim::time::Duration;

/// Retransmission discipline of one link direction (both ends of a
/// direction must agree, which the machine/workload wiring guarantees).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RelMode {
    /// Strictly in-order receive; a hole rewinds and replays the whole
    /// VC tail. Buffer-free, replay-hungry.
    GoBackN,
    /// Out-of-order receive buffer (bounded by the replay window) with
    /// per-seq sack/nack; exactly one frame replays per hole. Delivery
    /// to the consumer stays exactly-once, in per-VC order.
    SelectiveRepeat,
}

impl RelMode {
    pub fn name(self) -> &'static str {
        match self {
            RelMode::GoBackN => "gbn",
            RelMode::SelectiveRepeat => "sr",
        }
    }

    /// Parse a CLI/env spelling (`gbn` | `sr`, with a few aliases).
    pub fn parse(s: &str) -> Option<RelMode> {
        match s.to_ascii_lowercase().as_str() {
            "gbn" | "go-back-n" | "goback" => Some(RelMode::GoBackN),
            "sr" | "selective-repeat" | "selective" => Some(RelMode::SelectiveRepeat),
            _ => None,
        }
    }
}

/// Reliability configuration of one (or both) link directions.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RelConfig {
    pub faults: FaultConfig,
    /// Retransmission discipline (default go-back-N, the PR 4 behavior).
    pub mode: RelMode,
    /// Base retransmit timeout: with frames unacked and no ack progress
    /// for this long, the sender replays (go-back-N rewinds every VC;
    /// selective repeat re-sends the un-sacked frames only). The default
    /// comfortably exceeds the ECI round trip (~0.5 µs) — tail losses
    /// cost a timeout, everything else recovers via gap nacks. With
    /// [`RelConfig::adaptive_rto`] set this is only the *initial* value,
    /// used until RTT samples land.
    pub rto: Duration,
    /// Derive the effective RTO from measured per-VC RTT EWMAs
    /// (srtt + 4·rttvar, Karn-filtered samples, clamped to
    /// [`RTO_FLOOR`], [`RTO_CEIL`]) instead of the fixed timer.
    pub adaptive_rto: bool,
}

/// Default retransmit timeout (see [`RelConfig::rto`]).
pub const DEFAULT_RTO: Duration = Duration::from_us(2);

/// Floor of the adaptive RTO: above the worst clean-link ack delay
/// (delayed-ack flush + control latency + flight), so an adaptive timer
/// can never fire on a link that is merely quiet. Pinned by
/// `adaptive_rto_never_fires_below_the_floor_on_a_clean_link` in
/// `rust/tests/rel_faults.rs`.
pub const RTO_FLOOR: Duration = Duration::from_ns(1_000);

/// Ceiling of the adaptive RTO: bounds tail-loss recovery latency under
/// pathological RTT estimates.
pub const RTO_CEIL: Duration = Duration::from_us(32);

/// Delayed-ack flush window: cumulative-ack debt that finds no
/// reverse-direction frame to piggyback on within this delay is sent as
/// an explicit control frame. Well below [`RTO_FLOOR`] (and
/// [`DEFAULT_RTO`]), so on a clean link the sender always sees ack
/// progress before its retransmit timer can mistake ack delay for loss
/// (timeout replays then mean *actual* tail loss).
pub const ACK_FLUSH_DELAY: Duration = Duration::from_ns(400);

impl RelConfig {
    pub fn new(faults: FaultConfig) -> RelConfig {
        RelConfig { faults, mode: RelMode::GoBackN, rto: DEFAULT_RTO, adaptive_rto: false }
    }

    /// Uniform bit-error rate on every VC (the `--ber` CLI knob).
    pub fn from_ber(ber: f64, seed: u64) -> RelConfig {
        RelConfig::new(FaultConfig::from_ber(ber, seed))
    }

    pub fn with_rto(mut self, rto: Duration) -> RelConfig {
        self.rto = rto;
        self
    }

    pub fn with_mode(mut self, mode: RelMode) -> RelConfig {
        self.mode = mode;
        self
    }

    pub fn with_adaptive_rto(mut self, adaptive: bool) -> RelConfig {
        self.adaptive_rto = adaptive;
        self
    }
}

/// Per-direction reliability state, carried by a
/// [`crate::transport::LinkDir`] when the link is configured lossy.
pub struct RelState {
    pub tx: RelTx,
    pub rx: RelRx,
    pub faults: FaultInjector,
    pub mode: RelMode,
    /// Configured base/initial RTO (see [`RelConfig::rto`]).
    pub rto: Duration,
    pub adaptive_rto: bool,
    /// Acks that rode a reverse-direction frame (stats).
    pub piggybacked_acks: u64,
}

impl RelState {
    /// `window`: the selective-repeat receive-buffer bound, in frames
    /// per VC — sized to the replay window (the per-VC credit budget:
    /// every buffered frame still holds its credit, so a correct peer
    /// can never exceed it).
    pub fn new(cfg: RelConfig, window: u64) -> RelState {
        RelState {
            tx: RelTx::new(cfg.mode),
            rx: RelRx::new(cfg.mode, window),
            faults: FaultInjector::new(cfg.faults),
            mode: cfg.mode,
            rto: cfg.rto,
            adaptive_rto: cfg.adaptive_rto,
            piggybacked_acks: 0,
        }
    }

    /// The retransmit timeout in force right now: the configured fixed
    /// value, or — when adaptive — the widest per-VC `srtt + 4·rttvar`
    /// clamped to [[`RTO_FLOOR`], [`RTO_CEIL`]] (the initial value
    /// until the first sample lands).
    pub fn effective_rto(&self) -> Duration {
        if !self.adaptive_rto {
            return self.rto;
        }
        match self.tx.measured_rto() {
            Some(m) => m.clamp(RTO_FLOOR, RTO_CEIL),
            None => self.rto,
        }
    }

    /// Swap both halves of this direction to a new retransmission
    /// discipline (live reconfiguration). Asserts the replay window is
    /// fully drained — see [`RelTx::set_mode`] / [`RelRx::set_mode`];
    /// sequence spaces, RTT estimators, and fault state all persist.
    pub fn set_mode(&mut self, mode: RelMode) {
        self.tx.set_mode(mode);
        self.rx.set_mode(mode);
        self.mode = mode;
    }

    pub fn stats(&self) -> RelStats {
        RelStats::of(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::time::Time;
    use crate::transport::vc::VcId;

    #[test]
    fn mode_parses_and_names() {
        assert_eq!(RelMode::parse("gbn"), Some(RelMode::GoBackN));
        assert_eq!(RelMode::parse("SR"), Some(RelMode::SelectiveRepeat));
        assert_eq!(RelMode::parse("selective-repeat"), Some(RelMode::SelectiveRepeat));
        assert_eq!(RelMode::parse("wat"), None);
        assert_eq!(RelMode::GoBackN.name(), "gbn");
        assert_eq!(RelMode::SelectiveRepeat.name(), "sr");
    }

    #[test]
    fn default_config_is_gbn_fixed_rto() {
        let c = RelConfig::from_ber(1e-4, 1);
        assert_eq!(c.mode, RelMode::GoBackN);
        assert_eq!(c.rto, DEFAULT_RTO);
        assert!(!c.adaptive_rto);
    }

    #[test]
    fn effective_rto_is_fixed_until_adaptive_with_samples() {
        let cfg = RelConfig::from_ber(0.0, 1).with_adaptive_rto(true);
        let mut st = RelState::new(cfg, 40);
        assert_eq!(st.effective_rto(), DEFAULT_RTO, "no samples yet: initial value");
        // one 500 ns sample: rto = 500 + 4·250 = 1500 ns (above the floor)
        st.tx.frame(
            Time(0),
            VcId(0),
            crate::proto::messages::Message::coh_req(
                crate::proto::messages::ReqId(0),
                crate::proto::states::Node::Remote,
                crate::proto::messages::CohOp::ReadShared,
                crate::proto::messages::LineAddr(0),
            ),
        );
        st.tx.on_control(Time(500_000), crate::transport::Control::VcAck(VcId(0), 0));
        assert_eq!(st.effective_rto(), Duration::from_ns(1_500));
        // a fixed-timer config ignores the samples entirely
        let mut fixed = RelState::new(RelConfig::from_ber(0.0, 1), 40);
        fixed.tx.frame(
            Time(0),
            VcId(0),
            crate::proto::messages::Message::coh_req(
                crate::proto::messages::ReqId(1),
                crate::proto::states::Node::Remote,
                crate::proto::messages::CohOp::ReadShared,
                crate::proto::messages::LineAddr(2),
            ),
        );
        fixed.tx.on_control(Time(500_000), crate::transport::Control::VcAck(VcId(0), 0));
        assert_eq!(fixed.effective_rto(), DEFAULT_RTO);
    }

    #[test]
    fn effective_rto_clamps_to_floor_and_ceiling() {
        let cfg = RelConfig::from_ber(0.0, 1).with_adaptive_rto(true);
        let mut st = RelState::new(cfg, 40);
        let msg = |i: u32, a: u64| {
            crate::proto::messages::Message::coh_req(
                crate::proto::messages::ReqId(i),
                crate::proto::states::Node::Remote,
                crate::proto::messages::CohOp::ReadShared,
                crate::proto::messages::LineAddr(a),
            )
        };
        // converge the EWMA on a 50 ns RTT: unclamped rto sinks toward
        // 50 ns, far below the floor
        for i in 0..200u32 {
            st.tx.frame(Time(i as u64 * 1_000_000), VcId(0), msg(i, 2 * i as u64));
            st.tx.on_control(
                Time(i as u64 * 1_000_000 + 50_000),
                crate::transport::Control::VcAck(VcId(0), i as u64),
            );
        }
        assert!(st.tx.measured_rto().unwrap() < RTO_FLOOR);
        assert_eq!(st.effective_rto(), RTO_FLOOR, "the floor must hold");
    }
}
