//! rel — reliable transport over a lossy link.
//!
//! The seed stack delivered frames perfectly (the phys layer could flip
//! a corruption bit, but nothing was ever *lost* or *reordered*, and the
//! only replay machinery ran one sequence space for the whole link).
//! This subsystem makes loss a first-class, measurable condition:
//!
//! * [`fault`] — a seeded, deterministic fault injector, configurable
//!   per VC with drop / bit-error / reorder probabilities and a
//!   Gilbert–Elliott burst mode, interposed on the framed path (both
//!   the workload engine's [`crate::transport::FramedIngress`] and the
//!   machine's link directions consult it at launch time);
//! * [`seqrep`] — per-VC go-back-N sequencing/ack/replay: each VC keeps
//!   its own sequence numbers and replay buffer, cumulative acks ride
//!   piggybacked on reverse-direction frames (the link header's ack
//!   envelope bit) or as explicit controls, retransmission is triggered
//!   by sequence gaps, corruption nacks, or the host's retransmit
//!   timeout — and link credits are held across replays: a replayed
//!   frame neither re-consumes nor leaks a credit;
//! * [`stats`] — retransmission / goodput / replay-buffer-occupancy
//!   counters, surfaced through the machine report, the
//!   `workload::OpenLoopReport`, and `harness::fig_goodput`.
//!
//! The invariant everything here defends: **loss changes timing, never
//! semantics.** Litmus scenarios and final directory state are
//! bit-identical with fault injection on vs off (pinned in
//! `rust/tests/rel_faults.rs` and, via `ECI_LITMUS_FAULTS`, by the full
//! litmus suite in CI).

pub mod fault;
pub mod seqrep;
pub mod stats;

pub use fault::{FaultAction, FaultConfig, FaultInjector, FaultSpec, FaultStats};
pub use seqrep::{RelRx, RelTx};
pub use stats::RelStats;

use crate::sim::time::Duration;

/// Reliability configuration of one (or both) link directions.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RelConfig {
    pub faults: FaultConfig,
    /// Retransmit timeout: with frames unacked and no ack progress for
    /// this long, the sender rewinds every VC's replay buffer. The
    /// default comfortably exceeds the ECI round trip (~0.5 µs) — tail
    /// losses cost a timeout, everything else recovers via gap nacks.
    pub rto: Duration,
}

/// Default retransmit timeout (see [`RelConfig::rto`]).
pub const DEFAULT_RTO: Duration = Duration::from_us(2);

/// Delayed-ack flush window: cumulative-ack debt that finds no
/// reverse-direction frame to piggyback on within this delay is sent as
/// an explicit control frame. Well below [`DEFAULT_RTO`], so on a clean
/// link the sender always sees ack progress before its retransmit timer
/// can mistake ack delay for loss (timeout replays then mean *actual*
/// tail loss).
pub const ACK_FLUSH_DELAY: Duration = Duration::from_ns(400);

impl RelConfig {
    pub fn new(faults: FaultConfig) -> RelConfig {
        RelConfig { faults, rto: DEFAULT_RTO }
    }

    /// Uniform bit-error rate on every VC (the `--ber` CLI knob).
    pub fn from_ber(ber: f64, seed: u64) -> RelConfig {
        RelConfig::new(FaultConfig::from_ber(ber, seed))
    }

    pub fn with_rto(mut self, rto: Duration) -> RelConfig {
        self.rto = rto;
        self
    }
}

/// Per-direction reliability state, carried by a
/// [`crate::transport::LinkDir`] when the link is configured lossy.
pub struct RelState {
    pub tx: RelTx,
    pub rx: RelRx,
    pub faults: FaultInjector,
    pub rto: Duration,
    /// Acks that rode a reverse-direction frame (stats).
    pub piggybacked_acks: u64,
}

impl RelState {
    pub fn new(cfg: RelConfig) -> RelState {
        RelState {
            tx: RelTx::new(),
            rx: RelRx::new(),
            faults: FaultInjector::new(cfg.faults),
            rto: cfg.rto,
            piggybacked_acks: 0,
        }
    }

    pub fn stats(&self) -> RelStats {
        RelStats::of(self)
    }
}
