//! Deterministic link-fault injection for the reliable-transport layer.
//!
//! The existing physical-layer injector ([`crate::transport::phys`])
//! flips a single per-frame corruption coin; real lossy serial links
//! misbehave in richer ways: bit errors whose per-frame probability
//! grows with frame size, whole-frame losses (a lane glitch eats the
//! alignment word), out-of-order arrivals (skew between lane groups),
//! and *bursts* — errors that cluster while a lane re-trains instead of
//! arriving independently. [`FaultInjector`] models all four, per VC,
//! from one seed, so every lossy run is bit-reproducible and a sweep
//! can vary exactly one knob at a time.
//!
//! The injector sits on the framed path: [`crate::transport::LinkDir`]
//! consults it once per launched frame (retransmissions included — a
//! replay is just as exposed to the wire as a first transmission).

use crate::sim::rng::Rng;
use crate::sim::time::Duration;

use super::super::vc::{VcId, NUM_VCS};

/// Fault rates of one VC's share of the lanes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultSpec {
    /// Bit-error rate. The per-frame corruption probability follows the
    /// frame size — `1 - (1-ber)^bits` — so 160-byte data frames corrupt
    /// ~5x as often as 32-byte requests, exactly as on a real link.
    pub ber: f64,
    /// Per-frame whole-loss probability (the frame never reaches the
    /// peer's framer; no CRC check, no nack — only the sequence gap or a
    /// timeout reveals it).
    pub drop: f64,
    /// Per-frame probability of late delivery: the frame stays in
    /// flight long enough for later-launched frames to overtake it.
    pub reorder: f64,
    /// Mean error-burst length in frames. 1.0 = independent errors;
    /// above 1 the injector runs a two-state (Gilbert–Elliott style)
    /// chain per VC: faults only fire in the bad state, which is entered
    /// rarely and persists for `burst_len` frames on average, keeping
    /// the *marginal* drop+corrupt rate at the configured value while
    /// clustering the hits.
    pub burst_len: f64,
}

impl FaultSpec {
    pub const CLEAN: FaultSpec = FaultSpec { ber: 0.0, drop: 0.0, reorder: 0.0, burst_len: 1.0 };

    pub fn is_clean(&self) -> bool {
        self.ber <= 0.0 && self.drop <= 0.0 && self.reorder <= 0.0
    }

    /// Per-frame corruption probability for a frame of `wire_bytes`,
    /// capped so that even absurd BERs leave replay a way forward.
    pub fn corrupt_p(&self, wire_bytes: u64) -> f64 {
        if self.ber <= 0.0 {
            return 0.0;
        }
        let bits = (wire_bytes * 8) as f64;
        (1.0 - (1.0 - self.ber).powf(bits)).min(0.9)
    }
}

/// Full injector configuration: a default spec plus per-VC overrides
/// (e.g. pound the data-response VCs while leaving I/O clean).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultConfig {
    pub default: FaultSpec,
    pub per_vc: [Option<FaultSpec>; NUM_VCS],
    /// Injector PRNG seed (independent of the traffic seed, so the same
    /// workload can be replayed under different fault streams and vice
    /// versa).
    pub seed: u64,
}

impl FaultConfig {
    pub fn new(default: FaultSpec, seed: u64) -> FaultConfig {
        FaultConfig { default, per_vc: [None; NUM_VCS], seed }
    }

    /// Uniform bit-error rate on every VC.
    pub fn from_ber(ber: f64, seed: u64) -> FaultConfig {
        FaultConfig::new(FaultSpec { ber, ..FaultSpec::CLEAN }, seed)
    }

    pub fn with_vc(mut self, vc: VcId, spec: FaultSpec) -> FaultConfig {
        self.per_vc[vc.0 as usize] = Some(spec);
        self
    }

    pub fn spec_for(&self, vc: VcId) -> &FaultSpec {
        self.per_vc[vc.0 as usize].as_ref().unwrap_or(&self.default)
    }

    pub fn is_clean(&self) -> bool {
        self.default.is_clean() && self.per_vc.iter().flatten().all(|s| s.is_clean())
    }
}

/// What the wire did to one launched frame.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultAction {
    /// Arrived intact, in launch order.
    Deliver,
    /// Arrived with a failing CRC (the receiver nacks).
    Corrupt,
    /// Never arrived (recovered via sequence gap or timeout).
    Drop,
    /// Arrives late by the given extra flight time — long enough for
    /// later frames to overtake it.
    Reorder(Duration),
}

/// Injected-fault counts (per injector; one injector per direction).
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultStats {
    pub frames: u64,
    pub corrupted: u64,
    pub dropped: u64,
    pub reordered: u64,
    /// Frames launched while a VC's burst chain was in the bad state.
    pub burst_frames: u64,
}

/// Seeded, per-VC fault injector (one per link direction).
pub struct FaultInjector {
    cfg: FaultConfig,
    rng: Rng,
    /// Gilbert–Elliott chain state per VC (true = bad / bursting).
    burst_bad: [bool; NUM_VCS],
    pub stats: FaultStats,
}

impl FaultInjector {
    pub fn new(cfg: FaultConfig) -> FaultInjector {
        FaultInjector {
            rng: Rng::new(cfg.seed),
            cfg,
            burst_bad: [false; NUM_VCS],
            stats: FaultStats::default(),
        }
    }

    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    /// Roll the dice for one launched frame of `wire_bytes` on `vc`.
    /// Exactly one action is returned; drop dominates corruption (a lost
    /// frame has no CRC to fail), and reorder applies only to frames
    /// that survive intact.
    pub fn apply(&mut self, vc: VcId, wire_bytes: u64) -> FaultAction {
        self.stats.frames += 1;
        let spec = *self.cfg.spec_for(vc);
        if spec.is_clean() {
            return FaultAction::Deliver;
        }
        let corrupt_p = spec.corrupt_p(wire_bytes);
        let err_p = (spec.drop + corrupt_p).min(0.95);
        let errored = if spec.burst_len > 1.0 {
            // Two-state chain: enter the bad state with probability
            // err_p / burst_len, stay for burst_len frames on average,
            // and fault on every frame while bad. The stationary bad
            // fraction is ~err_p, so the marginal rate matches the
            // independent model while the hits cluster.
            let i = vc.0 as usize;
            if self.burst_bad[i] {
                if self.rng.chance(1.0 / spec.burst_len) {
                    self.burst_bad[i] = false;
                }
            } else if self.rng.chance((err_p / spec.burst_len).min(1.0)) {
                self.burst_bad[i] = true;
            }
            if self.burst_bad[i] {
                self.stats.burst_frames += 1;
            }
            self.burst_bad[i]
        } else {
            self.rng.chance(err_p)
        };
        if errored && err_p > 0.0 {
            // split the error between drop and corruption by their rates
            if self.rng.chance(spec.drop / err_p) {
                self.stats.dropped += 1;
                return FaultAction::Drop;
            }
            self.stats.corrupted += 1;
            return FaultAction::Corrupt;
        }
        if spec.reorder > 0.0 && self.rng.chance(spec.reorder) {
            self.stats.reordered += 1;
            // a few hundred ns of extra flight: several frame times plus
            // the pipeline latency, so successors genuinely overtake
            return FaultAction::Reorder(Duration::from_ns(self.rng.range(150, 900)));
        }
        FaultAction::Deliver
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn count(cfg: FaultConfig, vc: VcId, bytes: u64, n: u64) -> FaultStats {
        let mut inj = FaultInjector::new(cfg);
        for _ in 0..n {
            inj.apply(vc, bytes);
        }
        inj.stats
    }

    #[test]
    fn clean_config_never_faults() {
        let s = count(FaultConfig::from_ber(0.0, 1), VcId(0), 160, 10_000);
        assert_eq!((s.corrupted, s.dropped, s.reordered), (0, 0, 0));
        assert_eq!(s.frames, 10_000);
    }

    #[test]
    fn corruption_rate_tracks_ber_and_frame_size() {
        let cfg = FaultConfig::from_ber(1e-4, 42);
        let small = count(cfg, VcId(0), 32, 50_000); // p ~ 2.5%
        let large = count(cfg, VcId(6), 160, 50_000); // p ~ 12%
        let ps = small.corrupted as f64 / 50_000.0;
        let pl = large.corrupted as f64 / 50_000.0;
        assert!((0.02..0.032).contains(&ps), "small-frame rate {ps}");
        assert!((0.10..0.14).contains(&pl), "large-frame rate {pl}");
        assert!(pl > 3.0 * ps, "corruption must grow with frame size");
    }

    #[test]
    fn drop_and_reorder_rates_are_roughly_configured() {
        let spec = FaultSpec { drop: 0.05, reorder: 0.10, ..FaultSpec::CLEAN };
        let s = count(FaultConfig::new(spec, 7), VcId(1), 32, 50_000);
        let pd = s.dropped as f64 / 50_000.0;
        let pr = s.reordered as f64 / 50_000.0;
        assert!((0.04..0.06).contains(&pd), "drop rate {pd}");
        // reorder applies to the intact remainder (~0.95 of frames)
        assert!((0.08..0.11).contains(&pr), "reorder rate {pr}");
    }

    #[test]
    fn deterministic_for_seed_and_divergent_across_seeds() {
        let spec = FaultSpec { ber: 1e-4, drop: 0.02, reorder: 0.02, burst_len: 1.0 };
        let mut a = FaultInjector::new(FaultConfig::new(spec, 9));
        let mut b = FaultInjector::new(FaultConfig::new(spec, 9));
        let mut c = FaultInjector::new(FaultConfig::new(spec, 10));
        let mut diverged = false;
        for i in 0..5_000u64 {
            let vc = VcId((i % 10) as u8);
            let x = a.apply(vc, 32 + (i % 2) * 128);
            assert_eq!(x, b.apply(vc, 32 + (i % 2) * 128), "same seed must replay");
            diverged |= x != c.apply(vc, 32 + (i % 2) * 128);
        }
        assert!(diverged, "different seeds should differ somewhere");
    }

    #[test]
    fn per_vc_override_shields_other_vcs() {
        let cfg = FaultConfig::new(FaultSpec::CLEAN, 3)
            .with_vc(VcId(6), FaultSpec { drop: 0.5, ..FaultSpec::CLEAN });
        let mut inj = FaultInjector::new(cfg);
        let mut vc0_faults = 0;
        let mut vc6_drops = 0;
        for _ in 0..5_000 {
            if inj.apply(VcId(0), 32) != FaultAction::Deliver {
                vc0_faults += 1;
            }
            if inj.apply(VcId(6), 160) == FaultAction::Drop {
                vc6_drops += 1;
            }
        }
        assert_eq!(vc0_faults, 0, "clean VC must stay clean");
        assert!((2_000..3_000).contains(&vc6_drops), "overridden VC drops {vc6_drops}");
    }

    #[test]
    fn bursts_cluster_errors_without_inflating_the_marginal_rate() {
        let n = 200_000u64;
        let run = |burst_len: f64| {
            let spec = FaultSpec { drop: 0.02, burst_len, ..FaultSpec::CLEAN };
            let mut inj = FaultInjector::new(FaultConfig::new(spec, 11));
            let mut runs = 0u64; // maximal runs of consecutive drops
            let mut prev_dropped = false;
            let mut drops = 0u64;
            for _ in 0..n {
                let dropped = inj.apply(VcId(0), 32) == FaultAction::Drop;
                if dropped {
                    drops += 1;
                    if !prev_dropped {
                        runs += 1;
                    }
                }
                prev_dropped = dropped;
            }
            (drops, drops as f64 / runs.max(1) as f64)
        };
        let (ind_drops, ind_len) = run(1.0);
        let (bur_drops, bur_len) = run(8.0);
        // marginal rates agree within a factor
        let (ri, rb) = (ind_drops as f64 / n as f64, bur_drops as f64 / n as f64);
        assert!((0.015..0.025).contains(&ri), "independent rate {ri}");
        assert!((0.012..0.028).contains(&rb), "burst marginal rate {rb}");
        // but the burst chain clusters: mean error-run length ~burst_len
        assert!(ind_len < 1.3, "independent mean run {ind_len}");
        assert!(bur_len > 4.0, "burst mean run {bur_len}");
    }
}
