//! Adaptive retransmit-timeout estimation from measured round-trip
//! times (RFC 6298-style, scaled to the ECI link's nanosecond RTTs).
//!
//! The fixed 2 µs retransmit timer ([`super::DEFAULT_RTO`]) is tuned for
//! the worst case: it must comfortably exceed the ack path (flight +
//! delayed-ack flush + control latency) or a quiet link replays
//! spuriously. But a fixed worst-case timer recovers *tail loss* — the
//! one loss class only the timer can see — a full 2 µs after the frames
//! stopped making progress, even when the measured round trip says an
//! ack should have landed in a quarter of that. The estimator here
//! closes the gap: each VC tracks a smoothed RTT (`srtt`) and its mean
//! deviation (`rttvar`) over samples measured from frame launch to
//! cumulative/selective ack, and the effective RTO becomes
//!
//! ```text
//! rto = clamp(srtt + 4·rttvar, RTO_FLOOR, RTO_CEIL)
//! ```
//!
//! with the standard EWMA gains (α = 1/8 for `srtt`, β = 1/4 for
//! `rttvar`). Two guards keep the estimate honest:
//!
//! * **Karn's rule**: frames that were retransmitted never contribute a
//!   sample — an ack for such a frame is ambiguous (it may acknowledge
//!   either copy), and feeding the ambiguity into the EWMA collapses
//!   the timer under sustained loss;
//! * **floor/ceiling clamps** ([`super::RTO_FLOOR`],
//!   [`super::RTO_CEIL`]): the floor sits above the worst clean-link
//!   ack delay (delayed-ack flush + control-path latency), so the
//!   adaptive timer can never fire on a link that is merely quiet; the
//!   ceiling bounds recovery latency under pathological estimates.

use crate::sim::time::Duration;

/// EWMA gain for `srtt`: α = 1/8 (as a right-shift).
const SRTT_SHIFT: u32 = 3;
/// EWMA gain for `rttvar`: β = 1/4 (as a right-shift).
const RTTVAR_SHIFT: u32 = 2;

/// One VC's RTT estimator: srtt/rttvar EWMA over ack-measured samples.
#[derive(Clone, Copy, Debug, Default)]
pub struct RttEstimator {
    srtt_ps: u64,
    rttvar_ps: u64,
    /// Samples absorbed (0 = no estimate yet).
    pub samples: u64,
}

impl RttEstimator {
    pub fn new() -> RttEstimator {
        RttEstimator::default()
    }

    /// Absorb one RTT sample (launch → ack). The caller enforces Karn's
    /// rule: samples from retransmitted frames must not reach here.
    pub fn observe(&mut self, rtt: Duration) {
        let r = rtt.ps();
        if self.samples == 0 {
            // RFC 6298 §2.2: srtt = R, rttvar = R/2
            self.srtt_ps = r;
            self.rttvar_ps = r / 2;
        } else {
            // rttvar = (1-β)·rttvar + β·|srtt - R|; srtt = (1-α)·srtt + α·R
            let dev = self.srtt_ps.abs_diff(r);
            self.rttvar_ps =
                self.rttvar_ps - (self.rttvar_ps >> RTTVAR_SHIFT) + (dev >> RTTVAR_SHIFT);
            self.srtt_ps = self.srtt_ps - (self.srtt_ps >> SRTT_SHIFT) + (r >> SRTT_SHIFT);
        }
        self.samples += 1;
    }

    /// Smoothed RTT, once at least one sample has landed.
    pub fn srtt(&self) -> Option<Duration> {
        (self.samples > 0).then(|| Duration(self.srtt_ps))
    }

    /// Unclamped RTO estimate `srtt + 4·rttvar` (the caller applies the
    /// floor/ceiling clamps), once at least one sample has landed.
    pub fn rto(&self) -> Option<Duration> {
        (self.samples > 0).then(|| Duration(self.srtt_ps + 4 * self.rttvar_ps))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_samples_means_no_estimate() {
        let e = RttEstimator::new();
        assert_eq!(e.rto(), None);
        assert_eq!(e.srtt(), None);
    }

    #[test]
    fn first_sample_seeds_srtt_and_var() {
        let mut e = RttEstimator::new();
        e.observe(Duration::from_ns(400));
        assert_eq!(e.srtt().unwrap(), Duration::from_ns(400));
        // rto = 400 + 4·200 = 1200 ns
        assert_eq!(e.rto().unwrap(), Duration::from_ns(1200));
    }

    #[test]
    fn steady_samples_converge_and_tighten() {
        let mut e = RttEstimator::new();
        for _ in 0..200 {
            e.observe(Duration::from_ns(500));
        }
        let srtt = e.srtt().unwrap().as_ns();
        assert!((srtt - 500.0).abs() < 5.0, "srtt {srtt} should converge to 500");
        // constant samples drive rttvar toward zero: rto → srtt
        assert!(e.rto().unwrap().as_ns() < 550.0, "{:?}", e.rto());
    }

    #[test]
    fn jitter_widens_the_estimate() {
        let mut steady = RttEstimator::new();
        let mut jittery = RttEstimator::new();
        for i in 0..200u64 {
            steady.observe(Duration::from_ns(500));
            jittery.observe(Duration::from_ns(if i % 2 == 0 { 200 } else { 800 }));
        }
        assert!(
            jittery.rto().unwrap() > steady.rto().unwrap(),
            "variance must widen the RTO: {:?} vs {:?}",
            jittery.rto(),
            steady.rto()
        );
    }
}
