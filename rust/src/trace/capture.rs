//! Trace capture: a bounded ring of timestamped messages with EWF and
//! JSON dumps (the paper's block-level capture + decode pipeline, §4.1).

use crate::proto::messages::Message;
use crate::sim::time::Time;

use super::ewf;
use super::json::Json;
use super::msgjson;

/// Direction tag for captured messages.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Dir {
    CpuToFpga,
    FpgaToCpu,
}

#[derive(Clone, Debug)]
pub struct Captured {
    pub time: Time,
    pub dir: Dir,
    pub msg: Message,
}

/// Bounded capture ring (oldest entries dropped when full).
pub struct Capture {
    ring: std::collections::VecDeque<Captured>,
    cap: usize,
    pub total_seen: u64,
}

impl Capture {
    pub fn new(cap: usize) -> Capture {
        Capture { ring: std::collections::VecDeque::with_capacity(cap), cap, total_seen: 0 }
    }

    pub fn record(&mut self, time: Time, dir: Dir, msg: Message) {
        if self.ring.len() == self.cap {
            self.ring.pop_front();
        }
        self.ring.push_back(Captured { time, dir, msg });
        self.total_seen += 1;
    }

    pub fn iter(&self) -> impl Iterator<Item = &Captured> {
        self.ring.iter()
    }
    pub fn len(&self) -> usize {
        self.ring.len()
    }
    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Dump as a JSON array (the paper's trace interchange format).
    pub fn to_json(&self) -> Json {
        Json::arr(self.ring.iter().map(|c| {
            Json::obj(vec![
                ("t_ps", Json::num(c.time.ps() as f64)),
                ("dir", Json::str(match c.dir {
                    Dir::CpuToFpga => "cpu_to_fpga",
                    Dir::FpgaToCpu => "fpga_to_cpu",
                })),
                ("msg", msgjson::to_json(&c.msg)),
            ])
        }))
    }

    /// Dump as a binary EWF stream (one record per message, with a
    /// 12-byte `(t_ps: u64, dir: u8, pad[3])` preamble per record).
    pub fn to_ewf(&self) -> Vec<u8> {
        let mut out = Vec::new();
        for c in &self.ring {
            out.extend_from_slice(&c.time.ps().to_le_bytes());
            out.push(match c.dir {
                Dir::CpuToFpga => 0,
                Dir::FpgaToCpu => 1,
            });
            out.extend_from_slice(&[0, 0, 0]);
            out.extend(ewf::encode(&c.msg));
        }
        out
    }

    /// Parse a binary EWF capture stream back.
    pub fn from_ewf(data: &[u8]) -> Result<Vec<Captured>, String> {
        let mut out = Vec::new();
        let mut off = 0;
        while off < data.len() {
            if data.len() - off < 12 {
                return Err("truncated preamble".into());
            }
            let t = u64::from_le_bytes(data[off..off + 8].try_into().unwrap());
            let dir = match data[off + 8] {
                0 => Dir::CpuToFpga,
                1 => Dir::FpgaToCpu,
                d => return Err(format!("bad dir {d}")),
            };
            off += 12;
            let (msg, used) = ewf::decode(&data[off..]).map_err(|e| e.to_string())?;
            off += used;
            out.push(Captured { time: Time(t), dir, msg });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::messages::{CohOp, LineAddr, ReqId};
    use crate::proto::states::Node;

    fn msg(i: u32) -> Message {
        Message::coh_req(ReqId(i), Node::Remote, CohOp::ReadShared, LineAddr(i as u64))
    }

    #[test]
    fn ring_drops_oldest() {
        let mut c = Capture::new(3);
        for i in 0..5 {
            c.record(Time(i as u64), Dir::CpuToFpga, msg(i));
        }
        assert_eq!(c.len(), 3);
        assert_eq!(c.total_seen, 5);
        let ids: Vec<u32> = c.iter().map(|x| x.msg.id.0).collect();
        assert_eq!(ids, vec![2, 3, 4]);
    }

    #[test]
    fn ewf_capture_round_trips() {
        let mut c = Capture::new(16);
        c.record(Time(100), Dir::CpuToFpga, msg(1));
        c.record(
            Time(250),
            Dir::FpgaToCpu,
            Message::coh_rsp(ReqId(1), Node::Home, CohOp::ReadShared, LineAddr(1), false, Some(Box::new([3; 128]))),
        );
        let bytes = c.to_ewf();
        let back = Capture::from_ewf(&bytes).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].time, Time(100));
        assert_eq!(back[0].dir, Dir::CpuToFpga);
        assert_eq!(back[1].msg.payload.as_ref().unwrap()[0], 3);
    }

    #[test]
    fn json_dump_parses() {
        let mut c = Capture::new(4);
        c.record(Time(1), Dir::CpuToFpga, msg(1));
        let text = c.to_json().to_string();
        let parsed = super::super::json::parse(&text).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 1);
        assert_eq!(
            parsed.idx(0).unwrap().get("dir").unwrap().as_str(),
            Some("cpu_to_fpga")
        );
    }
}
