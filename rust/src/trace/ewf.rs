//! ECI Wire Format (EWF) — the paper's "canonical binary format ... to
//! allow the decoded traces to be used for a variety of purposes" (§4.1).
//!
//! Layout (little-endian):
//!
//! ```text
//! byte  0      opcode
//! byte  1      flags: bit0 = from-home, bit1 = dirty, bit2 = has-payload
//! bytes 2..4   reserved (0)
//! bytes 4..8   request id (u32)
//! bytes 8..16  line address (u64)
//! [16..32]     I/O extension (offset u64, value u64) — I/O opcodes only
//! [..+128]     payload (when has-payload)
//! [..+4]       CRC-32 over everything above
//! ```
//!
//! A unit test pins the coherence-message sizes to
//! [`Message::wire_bytes`] (used by the link-timing model).

use crate::proto::messages::{CohOp, Line, LineAddr, Message, MsgKind, ReqId, LINE_BYTES};
use crate::proto::states::Node;

const FLAG_FROM_HOME: u8 = 1 << 0;
const FLAG_DIRTY: u8 = 1 << 1;
const FLAG_PAYLOAD: u8 = 1 << 2;
const FLAG_NO_COPY: u8 = 1 << 3;

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum EwfError {
    #[error("truncated EWF record: need {need} bytes, have {have}")]
    Truncated { need: usize, have: usize },
    #[error("unknown opcode {0:#x}")]
    BadOpcode(u8),
    #[error("CRC mismatch (corrupted record)")]
    BadCrc,
    #[error("payload flag inconsistent with opcode")]
    BadPayload,
}

fn coh_opcode(op: CohOp) -> u8 {
    match op {
        CohOp::ReadShared => 0x10,
        CohOp::ReadExclusive => 0x11,
        CohOp::UpgradeS2E => 0x12,
        CohOp::VolDowngradeS => 0x13,
        CohOp::VolDowngradeI => 0x14,
        CohOp::FwdDowngradeS => 0x15,
        CohOp::FwdDowngradeI => 0x16,
        CohOp::FwdSharedInvalidate => 0x17,
    }
}

fn coh_op_of(code: u8) -> Option<CohOp> {
    Some(match code & 0x1F {
        0x10 => CohOp::ReadShared,
        0x11 => CohOp::ReadExclusive,
        0x12 => CohOp::UpgradeS2E,
        0x13 => CohOp::VolDowngradeS,
        0x14 => CohOp::VolDowngradeI,
        0x15 => CohOp::FwdDowngradeS,
        0x16 => CohOp::FwdDowngradeI,
        0x17 => CohOp::FwdSharedInvalidate,
        _ => return None,
    })
}

fn opcode(kind: &MsgKind) -> u8 {
    match kind {
        MsgKind::CohReq { op } => coh_opcode(*op),
        MsgKind::CohRsp { op, .. } => coh_opcode(*op) | 0x20,
        MsgKind::IoRead { .. } => 0x40,
        MsgKind::IoReadRsp { .. } => 0x41,
        MsgKind::IoWrite { .. } => 0x42,
        MsgKind::IoWriteAck => 0x43,
        MsgKind::Barrier => 0x44,
        MsgKind::BarrierAck => 0x45,
        MsgKind::Ipi { .. } => 0x46,
    }
}

/// CRC-32 (IEEE, bitwise; this is cold path — tooling, not simulation).
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Encode one message as an EWF record.
pub fn encode(msg: &Message) -> Vec<u8> {
    let mut out = Vec::with_capacity(176);
    out.push(opcode(&msg.kind));
    let mut flags = 0u8;
    if msg.from == Node::Home {
        flags |= FLAG_FROM_HOME;
    }
    if let MsgKind::CohRsp { dirty: true, .. } = msg.kind {
        flags |= FLAG_DIRTY;
    }
    if let MsgKind::CohRsp { had_copy: false, .. } = msg.kind {
        flags |= FLAG_NO_COPY;
    }
    if msg.payload.is_some() {
        flags |= FLAG_PAYLOAD;
    }
    out.push(flags);
    out.extend_from_slice(&[0, 0]);
    out.extend_from_slice(&msg.id.0.to_le_bytes());
    out.extend_from_slice(&msg.addr.0.to_le_bytes());
    match &msg.kind {
        MsgKind::IoRead { offset } => {
            out.extend_from_slice(&offset.to_le_bytes());
            out.extend_from_slice(&0u64.to_le_bytes());
        }
        MsgKind::IoReadRsp { offset, value } | MsgKind::IoWrite { offset, value } => {
            out.extend_from_slice(&offset.to_le_bytes());
            out.extend_from_slice(&value.to_le_bytes());
        }
        MsgKind::Ipi { vector } => {
            out.extend_from_slice(&(*vector as u64).to_le_bytes());
            out.extend_from_slice(&0u64.to_le_bytes());
        }
        MsgKind::IoWriteAck | MsgKind::Barrier | MsgKind::BarrierAck => {
            out.extend_from_slice(&[0u8; 16]);
        }
        _ => {}
    }
    if let Some(p) = &msg.payload {
        out.extend_from_slice(&p[..]);
    }
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Decode one EWF record; returns the message and bytes consumed.
pub fn decode(data: &[u8]) -> Result<(Message, usize), EwfError> {
    if data.len() < 20 {
        return Err(EwfError::Truncated { need: 20, have: data.len() });
    }
    let code = data[0];
    let flags = data[1];
    let is_io = (0x40..=0x46).contains(&code);
    let has_payload = flags & FLAG_PAYLOAD != 0;
    let mut len = 16;
    if is_io {
        len += 16;
    }
    if has_payload {
        len += LINE_BYTES;
    }
    let total = len + 4;
    if data.len() < total {
        return Err(EwfError::Truncated { need: total, have: data.len() });
    }
    let want_crc = u32::from_le_bytes(data[len..len + 4].try_into().unwrap());
    if crc32(&data[..len]) != want_crc {
        return Err(EwfError::BadCrc);
    }
    let id = ReqId(u32::from_le_bytes(data[4..8].try_into().unwrap()));
    let addr = LineAddr(u64::from_le_bytes(data[8..16].try_into().unwrap()));
    let from = if flags & FLAG_FROM_HOME != 0 { Node::Home } else { Node::Remote };
    let dirty = flags & FLAG_DIRTY != 0;
    let payload: Option<Box<Line>> = if has_payload {
        let off = if is_io { 32 } else { 16 };
        let mut line = [0u8; LINE_BYTES];
        line.copy_from_slice(&data[off..off + LINE_BYTES]);
        Some(Box::new(line))
    } else {
        None
    };

    let kind = if (0x10..0x18).contains(&code) {
        MsgKind::CohReq { op: coh_op_of(code).ok_or(EwfError::BadOpcode(code))? }
    } else if (0x30..0x38).contains(&code) {
        MsgKind::CohRsp {
            op: coh_op_of(code).ok_or(EwfError::BadOpcode(code))?,
            dirty,
            had_copy: flags & FLAG_NO_COPY == 0,
        }
    } else {
        let io = |i: usize| u64::from_le_bytes(data[16 + i * 8..24 + i * 8].try_into().unwrap());
        match code {
            0x40 => MsgKind::IoRead { offset: io(0) },
            0x41 => MsgKind::IoReadRsp { offset: io(0), value: io(1) },
            0x42 => MsgKind::IoWrite { offset: io(0), value: io(1) },
            0x43 => MsgKind::IoWriteAck,
            0x44 => MsgKind::Barrier,
            0x45 => MsgKind::BarrierAck,
            0x46 => MsgKind::Ipi { vector: io(0) as u8 },
            c => return Err(EwfError::BadOpcode(c)),
        }
    };
    Ok((Message { id, from, kind, addr, payload }, total))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(msg: Message) {
        let bytes = encode(&msg);
        let (back, used) = decode(&bytes).unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(back, msg);
    }

    #[test]
    fn coherence_round_trips() {
        round_trip(Message::coh_req(ReqId(7), Node::Remote, CohOp::ReadShared, LineAddr(0xABCDE)));
        round_trip(Message::coh_req_data(
            ReqId(8),
            Node::Remote,
            CohOp::VolDowngradeI,
            LineAddr(3),
            Box::new([0x5A; 128]),
        ));
        round_trip(Message::coh_rsp(
            ReqId(9),
            Node::Home,
            CohOp::FwdDowngradeI,
            LineAddr(12),
            true,
            Some(Box::new([0xA5; 128])),
        ));
        round_trip(Message::coh_rsp(ReqId(10), Node::Home, CohOp::UpgradeS2E, LineAddr(13), false, None));
    }

    #[test]
    fn io_and_misc_round_trip() {
        for kind in [
            MsgKind::IoRead { offset: 0x18 },
            MsgKind::IoReadRsp { offset: 0x18, value: 42 },
            MsgKind::IoWrite { offset: 0x08, value: 0xDEADBEEF },
            MsgKind::IoWriteAck,
            MsgKind::Barrier,
            MsgKind::BarrierAck,
            MsgKind::Ipi { vector: 5 },
        ] {
            round_trip(Message { id: ReqId(1), from: Node::Remote, kind, addr: LineAddr(0), payload: None });
        }
    }

    #[test]
    fn coherence_sizes_match_timing_model() {
        // Message::wire_bytes = 16 + payload; EWF adds the 4-byte CRC
        // which the link layer's frame accounting carries separately.
        let m = Message::coh_req(ReqId(0), Node::Remote, CohOp::ReadShared, LineAddr(0));
        assert_eq!(encode(&m).len() as u64, m.wire_bytes() + 4);
        let m = Message::coh_rsp(ReqId(0), Node::Home, CohOp::ReadShared, LineAddr(0), false, Some(Box::new([0; 128])));
        assert_eq!(encode(&m).len() as u64, m.wire_bytes() + 4);
    }

    #[test]
    fn corruption_is_detected() {
        let m = Message::coh_req(ReqId(7), Node::Remote, CohOp::ReadShared, LineAddr(0xABCDE));
        let mut bytes = encode(&m);
        bytes[9] ^= 0x40;
        assert_eq!(decode(&bytes).unwrap_err(), EwfError::BadCrc);
    }

    #[test]
    fn truncation_is_detected() {
        let m = Message::coh_req(ReqId(7), Node::Remote, CohOp::ReadShared, LineAddr(1));
        let bytes = encode(&m);
        assert!(matches!(decode(&bytes[..10]), Err(EwfError::Truncated { .. })));
    }

    #[test]
    fn stream_of_records_decodes_sequentially() {
        let msgs = vec![
            Message::coh_req(ReqId(1), Node::Remote, CohOp::ReadShared, LineAddr(2)),
            Message::coh_rsp(ReqId(1), Node::Home, CohOp::ReadShared, LineAddr(2), false, Some(Box::new([1; 128]))),
            Message::coh_req(ReqId(2), Node::Remote, CohOp::VolDowngradeI, LineAddr(2)),
        ];
        let mut stream = Vec::new();
        for m in &msgs {
            stream.extend(encode(m));
        }
        let mut off = 0;
        let mut back = Vec::new();
        while off < stream.len() {
            let (m, used) = decode(&stream[off..]).unwrap();
            back.push(m);
            off += used;
        }
        assert_eq!(back, msgs);
    }
}
