//! Wireshark-style protocol dissector (the paper wrote "a plugin for the
//! popular Wireshark protocol analysis tool for visualizing the protocol",
//! §4.1). Renders captured messages as one-line summaries and as a
//! detailed field tree; understands VC assignment and frame overheads.

use crate::proto::messages::{Message, MsgKind};
use crate::proto::states::Node;
use crate::sim::time::Time;
use crate::transport::vc::{class_of, vc_for};

/// One-line summary, `tcpdump`-style.
pub fn summary(t: Time, msg: &Message) -> String {
    let dir = match msg.from {
        Node::Remote => "CPU  -> FPGA",
        Node::Home => "FPGA -> CPU ",
    };
    let what = match &msg.kind {
        MsgKind::CohReq { op } => format!("{op:?}"),
        MsgKind::CohRsp { op, dirty, .. } => {
            format!("{op:?}.rsp{}", if *dirty { " DIRTY" } else { "" })
        }
        MsgKind::IoRead { offset } => format!("IoRead[{offset:#x}]"),
        MsgKind::IoReadRsp { offset, value } => format!("IoReadRsp[{offset:#x}]={value:#x}"),
        MsgKind::IoWrite { offset, value } => format!("IoWrite[{offset:#x}]={value:#x}"),
        MsgKind::IoWriteAck => "IoWriteAck".into(),
        MsgKind::Barrier => "Barrier".into(),
        MsgKind::BarrierAck => "BarrierAck".into(),
        MsgKind::Ipi { vector } => format!("IPI#{vector}"),
    };
    format!(
        "{t:>14}  {dir}  vc{:<2} {:<24} {} id={} {}",
        vc_for(msg).0,
        what,
        msg.addr,
        msg.id.0,
        if msg.payload.is_some() { "+128B" } else { "" }
    )
}

/// Multi-line detail tree for one message.
pub fn detail(t: Time, msg: &Message) -> String {
    let mut s = String::new();
    s.push_str(&format!("ECI Message @ {t}\n"));
    s.push_str(&format!("├─ direction : {:?} -> {:?}\n", msg.from, msg.from.other()));
    s.push_str(&format!("├─ vc        : {} (class {:?})\n", vc_for(msg).0, class_of(msg)));
    s.push_str(&format!("├─ id        : {}\n", msg.id.0));
    s.push_str(&format!("├─ line      : {} (byte {:#x}, parity {})\n", msg.addr, msg.addr.byte_addr(), msg.addr.parity()));
    s.push_str(&format!("├─ kind      : {:?}\n", msg.kind));
    s.push_str(&format!("├─ wire bytes: {}\n", msg.wire_bytes()));
    match &msg.payload {
        Some(p) => {
            s.push_str("└─ payload   : 128 B\n");
            for chunk in 0..4 {
                let row = &p[chunk * 16..chunk * 16 + 16];
                let hex: Vec<String> = row.iter().map(|b| format!("{b:02x}")).collect();
                s.push_str(&format!("     {:04x}: {}\n", chunk * 16, hex.join(" ")));
            }
            s.push_str("     ... (first 64 of 128 bytes)\n");
        }
        None => s.push_str("└─ payload   : none\n"),
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::messages::{CohOp, LineAddr, ReqId};

    #[test]
    fn summary_is_one_line_and_informative() {
        let m = Message::coh_req(ReqId(5), Node::Remote, CohOp::ReadShared, LineAddr(0x42));
        let s = summary(Time(1_500), &m);
        assert!(!s.contains('\n'));
        assert!(s.contains("ReadShared"));
        assert!(s.contains("CPU  -> FPGA"));
        assert!(s.contains("id=5"));
    }

    #[test]
    fn detail_renders_every_message_kind() {
        // totality: the dissector must never panic on any kind
        let kinds = vec![
            MsgKind::CohReq { op: CohOp::UpgradeS2E },
            MsgKind::CohRsp { op: CohOp::ReadExclusive, dirty: true, had_copy: true },
            MsgKind::IoRead { offset: 8 },
            MsgKind::IoReadRsp { offset: 8, value: 1 },
            MsgKind::IoWrite { offset: 16, value: 2 },
            MsgKind::IoWriteAck,
            MsgKind::Barrier,
            MsgKind::BarrierAck,
            MsgKind::Ipi { vector: 9 },
        ];
        for kind in kinds {
            let m = Message { id: ReqId(1), from: Node::Home, kind, addr: LineAddr(3), payload: None };
            let d = detail(Time(0), &m);
            assert!(d.contains("vc"));
        }
        // with payload
        let m = Message::coh_rsp(ReqId(1), Node::Home, CohOp::ReadShared, LineAddr(3), false, Some(Box::new([0xAB; 128])));
        let d = detail(Time(0), &m);
        assert!(d.contains("ab ab"));
    }
}
