//! The paper's JSON-based message serialization (§4.1): every decoded ECI
//! message as a JSON object, round-trippable with [`super::ewf`]. Used by
//! the capture dump and (in the paper) by the ARM Fast Models cache module
//! talking over TCP — our equivalent is the trace interchange in
//! `examples/protocol_check.rs`.

use crate::proto::messages::{CohOp, Line, LineAddr, Message, MsgKind, ReqId};
use crate::proto::states::Node;

use super::json::Json;

fn op_name(op: CohOp) -> &'static str {
    match op {
        CohOp::ReadShared => "ReadShared",
        CohOp::ReadExclusive => "ReadExclusive",
        CohOp::UpgradeS2E => "UpgradeS2E",
        CohOp::VolDowngradeS => "VolDowngradeS",
        CohOp::VolDowngradeI => "VolDowngradeI",
        CohOp::FwdDowngradeS => "FwdDowngradeS",
        CohOp::FwdDowngradeI => "FwdDowngradeI",
        CohOp::FwdSharedInvalidate => "FwdSharedInvalidate",
    }
}

fn op_of(name: &str) -> Option<CohOp> {
    Some(match name {
        "ReadShared" => CohOp::ReadShared,
        "ReadExclusive" => CohOp::ReadExclusive,
        "UpgradeS2E" => CohOp::UpgradeS2E,
        "VolDowngradeS" => CohOp::VolDowngradeS,
        "VolDowngradeI" => CohOp::VolDowngradeI,
        "FwdDowngradeS" => CohOp::FwdDowngradeS,
        "FwdDowngradeI" => CohOp::FwdDowngradeI,
        "FwdSharedInvalidate" => CohOp::FwdSharedInvalidate,
        _ => return None,
    })
}

/// Serialize a message to the JSON trace format.
pub fn to_json(msg: &Message) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![
        ("id", Json::num(msg.id.0)),
        ("from", Json::str(if msg.from == Node::Home { "home" } else { "remote" })),
        ("addr", Json::num(msg.addr.0 as f64)),
    ];
    match &msg.kind {
        MsgKind::CohReq { op } => {
            fields.push(("type", Json::str("req")));
            fields.push(("op", Json::str(op_name(*op))));
        }
        MsgKind::CohRsp { op, dirty, had_copy } => {
            fields.push(("type", Json::str("rsp")));
            fields.push(("op", Json::str(op_name(*op))));
            fields.push(("dirty", Json::Bool(*dirty)));
            if !had_copy {
                fields.push(("had_copy", Json::Bool(false)));
            }
        }
        MsgKind::IoRead { offset } => {
            fields.push(("type", Json::str("io_read")));
            fields.push(("offset", Json::num(*offset as f64)));
        }
        MsgKind::IoReadRsp { offset, value } => {
            fields.push(("type", Json::str("io_read_rsp")));
            fields.push(("offset", Json::num(*offset as f64)));
            fields.push(("value", Json::num(*value as f64)));
        }
        MsgKind::IoWrite { offset, value } => {
            fields.push(("type", Json::str("io_write")));
            fields.push(("offset", Json::num(*offset as f64)));
            fields.push(("value", Json::num(*value as f64)));
        }
        MsgKind::IoWriteAck => fields.push(("type", Json::str("io_write_ack"))),
        MsgKind::Barrier => fields.push(("type", Json::str("barrier"))),
        MsgKind::BarrierAck => fields.push(("type", Json::str("barrier_ack"))),
        MsgKind::Ipi { vector } => {
            fields.push(("type", Json::str("ipi")));
            fields.push(("vector", Json::num(*vector as u32)));
        }
    }
    if let Some(p) = &msg.payload {
        fields.push(("payload", Json::arr(p.iter().map(|&b| Json::num(b as u32)))));
    }
    Json::obj(fields)
}

/// Deserialize a message from the JSON trace format.
pub fn from_json(j: &Json) -> Result<Message, String> {
    let id = ReqId(j.get("id").and_then(Json::as_u64).ok_or("missing id")? as u32);
    let from = match j.get("from").and_then(Json::as_str) {
        Some("home") => Node::Home,
        Some("remote") => Node::Remote,
        other => return Err(format!("bad from: {other:?}")),
    };
    let addr = LineAddr(j.get("addr").and_then(Json::as_u64).ok_or("missing addr")?);
    let ty = j.get("type").and_then(Json::as_str).ok_or("missing type")?;
    let get_op = || -> Result<CohOp, String> {
        let name = j.get("op").and_then(Json::as_str).ok_or("missing op")?;
        op_of(name).ok_or_else(|| format!("unknown op {name}"))
    };
    let num = |k: &str| j.get(k).and_then(Json::as_u64).ok_or_else(|| format!("missing {k}"));
    let kind = match ty {
        "req" => MsgKind::CohReq { op: get_op()? },
        "rsp" => MsgKind::CohRsp {
            op: get_op()?,
            dirty: j.get("dirty").and_then(Json::as_bool).unwrap_or(false),
            had_copy: j.get("had_copy").and_then(Json::as_bool).unwrap_or(true),
        },
        "io_read" => MsgKind::IoRead { offset: num("offset")? },
        "io_read_rsp" => MsgKind::IoReadRsp { offset: num("offset")?, value: num("value")? },
        "io_write" => MsgKind::IoWrite { offset: num("offset")?, value: num("value")? },
        "io_write_ack" => MsgKind::IoWriteAck,
        "barrier" => MsgKind::Barrier,
        "barrier_ack" => MsgKind::BarrierAck,
        "ipi" => MsgKind::Ipi { vector: num("vector")? as u8 },
        other => return Err(format!("unknown type {other}")),
    };
    let payload: Option<Box<Line>> = match j.get("payload") {
        Some(Json::Arr(v)) => {
            if v.len() != 128 {
                return Err(format!("payload length {}", v.len()));
            }
            let mut line = [0u8; 128];
            for (i, x) in v.iter().enumerate() {
                line[i] = x.as_u64().ok_or("bad payload byte")? as u8;
            }
            Some(Box::new(line))
        }
        None => None,
        _ => return Err("payload not an array".into()),
    };
    Ok(Message { id, from, kind, addr, payload })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_all_kinds() {
        let msgs = vec![
            Message::coh_req(ReqId(1), Node::Remote, CohOp::ReadShared, LineAddr(10)),
            Message::coh_req_data(ReqId(2), Node::Remote, CohOp::VolDowngradeS, LineAddr(11), Box::new([9; 128])),
            Message::coh_rsp(ReqId(3), Node::Home, CohOp::FwdDowngradeS, LineAddr(12), true, Some(Box::new([7; 128]))),
            Message { id: ReqId(4), from: Node::Remote, kind: MsgKind::IoWrite { offset: 8, value: 99 }, addr: LineAddr(0), payload: None },
            Message { id: ReqId(5), from: Node::Home, kind: MsgKind::Ipi { vector: 3 }, addr: LineAddr(0), payload: None },
        ];
        for m in msgs {
            let j = to_json(&m);
            // and through text
            let text = j.to_string();
            let parsed = super::super::json::parse(&text).unwrap();
            let back = from_json(&parsed).unwrap();
            assert_eq!(back, m);
        }
    }

    #[test]
    fn rejects_malformed() {
        let j = super::super::json::parse(r#"{"type":"req","op":"NoSuchOp","id":1,"from":"remote","addr":2}"#).unwrap();
        assert!(from_json(&j).is_err());
    }
}
