//! Online protocol checker (paper §4.1 "Online tracing"): protocol
//! properties are written as NFAs in a simple specification language and
//! checked against live message streams at full rate, recording
//! violations — the software analogue of the paper's synthesized checker
//! circuits (which avoid hours of re-synthesis by compiling only the NFA).
//!
//! ## Specification language
//!
//! ```text
//! # every grant is answered before the line is granted again
//! nfa read_response {
//!   start idle;
//!   idle: req ReadShared -> pending;
//!   pending: rsp ReadShared -> idle;
//!   pending: rsp ReadExclusive -> idle;     # race conversion
//!   pending: req ReadShared -> error "second read while pending";
//!   default ignore;
//! }
//! ```
//!
//! * symbols are `<class> <op|*>` where class ∈ {req, fwd, wb, rsp, io}
//!   — `req` = remote-initiated upgrade requests, `fwd` = home-initiated
//!   downgrades, `wb` = voluntary downgrades, `rsp` = responses;
//! * the automaton is instantiated **per cache line**;
//! * `default ignore` skips unmatched symbols, `default error` flags them;
//! * `-> error "text"` transitions report a violation and reset the line
//!   to the start state.

use crate::rustc_hash::FxHashMap as HashMap;

use crate::proto::messages::{CohOp, LineAddr, Message, MsgKind};
use crate::sim::time::Time;

/// Symbol classes over the message stream.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SymClass {
    Req,
    Fwd,
    Wb,
    Rsp,
    Io,
}

/// Classify a message into (class, op).
pub fn classify(msg: &Message) -> (SymClass, Option<CohOp>) {
    match &msg.kind {
        MsgKind::CohReq { op } => match op {
            CohOp::ReadShared | CohOp::ReadExclusive | CohOp::UpgradeS2E => (SymClass::Req, Some(*op)),
            CohOp::VolDowngradeS | CohOp::VolDowngradeI => (SymClass::Wb, Some(*op)),
            _ => (SymClass::Fwd, Some(*op)),
        },
        MsgKind::CohRsp { op, .. } => (SymClass::Rsp, Some(*op)),
        _ => (SymClass::Io, None),
    }
}

#[derive(Clone, Debug)]
enum Target {
    State(usize),
    Error(String),
}

#[derive(Clone, Debug)]
struct Rule {
    from: usize,
    class: SymClass,
    /// None = wildcard op
    op: Option<CohOp>,
    to: Target,
}

/// A compiled NFA specification.
#[derive(Clone, Debug)]
pub struct NfaSpec {
    pub name: String,
    state_names: Vec<String>,
    start: usize,
    rules: Vec<Rule>,
    default_error: bool,
}

fn op_of(name: &str) -> Option<CohOp> {
    Some(match name {
        "ReadShared" => CohOp::ReadShared,
        "ReadExclusive" => CohOp::ReadExclusive,
        "UpgradeS2E" => CohOp::UpgradeS2E,
        "VolDowngradeS" => CohOp::VolDowngradeS,
        "VolDowngradeI" => CohOp::VolDowngradeI,
        "FwdDowngradeS" => CohOp::FwdDowngradeS,
        "FwdDowngradeI" => CohOp::FwdDowngradeI,
        "FwdSharedInvalidate" => CohOp::FwdSharedInvalidate,
        _ => return None,
    })
}

impl NfaSpec {
    /// Parse one `nfa name { ... }` block.
    pub fn parse(text: &str) -> Result<NfaSpec, String> {
        let mut name = None;
        let mut state_names: Vec<String> = Vec::new();
        let mut start = None;
        let mut rules = Vec::new();
        let mut default_error = false;

        let intern = |names: &mut Vec<String>, s: &str| -> usize {
            if let Some(i) = names.iter().position(|n| n == s) {
                i
            } else {
                names.push(s.to_string());
                names.len() - 1
            }
        };

        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap().trim();
            if line.is_empty() || line == "}" {
                continue;
            }
            let err = |m: &str| format!("line {}: {m}: {raw:?}", lineno + 1);
            if let Some(rest) = line.strip_prefix("nfa ") {
                let n = rest.trim_end_matches('{').trim();
                if n.is_empty() {
                    return Err(err("missing nfa name"));
                }
                name = Some(n.to_string());
            } else if let Some(rest) = line.strip_prefix("start ") {
                let s = rest.trim_end_matches(';').trim();
                start = Some(intern(&mut state_names, s));
            } else if let Some(rest) = line.strip_prefix("default ") {
                match rest.trim_end_matches(';').trim() {
                    "ignore" => default_error = false,
                    "error" => default_error = true,
                    other => return Err(err(&format!("bad default {other:?}"))),
                }
            } else if let Some((state, rest)) = line.split_once(':') {
                // "<state>: <class> <op|*> -> <target>;"
                let from = intern(&mut state_names, state.trim());
                let rest = rest.trim().trim_end_matches(';');
                let (sym, target) = rest.split_once("->").ok_or_else(|| err("missing ->"))?;
                let mut parts = sym.trim().split_whitespace();
                let class = match parts.next() {
                    Some("req") => SymClass::Req,
                    Some("fwd") => SymClass::Fwd,
                    Some("wb") => SymClass::Wb,
                    Some("rsp") => SymClass::Rsp,
                    Some("io") => SymClass::Io,
                    other => return Err(err(&format!("bad class {other:?}"))),
                };
                let op = match parts.next() {
                    Some("*") | None => None,
                    Some(o) => Some(op_of(o).ok_or_else(|| err(&format!("unknown op {o:?}")))?),
                };
                let target = target.trim();
                let to = if let Some(rest) = target.strip_prefix("error") {
                    let text = rest.trim().trim_matches('"').to_string();
                    Target::Error(if text.is_empty() { "violation".into() } else { text })
                } else {
                    Target::State(intern(&mut state_names, target))
                };
                rules.push(Rule { from, class, op, to });
            } else {
                return Err(err("unparseable line"));
            }
        }
        Ok(NfaSpec {
            name: name.ok_or("missing `nfa <name> {`")?,
            start: start.ok_or("missing `start <state>;`")?,
            state_names,
            rules,
            default_error,
        })
    }

    pub fn state_count(&self) -> usize {
        self.state_names.len()
    }
}

/// A detected specification violation.
#[derive(Clone, Debug)]
pub struct CheckViolation {
    pub spec: String,
    pub time: Time,
    pub addr: LineAddr,
    pub detail: String,
}

/// The online checker: per-line NFA instances over a live stream.
pub struct OnlineChecker {
    spec: NfaSpec,
    /// Active state set per line (lines at start-state-only are elided).
    lines: HashMap<LineAddr, Vec<usize>>,
    pub violations: Vec<CheckViolation>,
    pub messages_checked: u64,
}

impl OnlineChecker {
    pub fn new(spec: NfaSpec) -> OnlineChecker {
        OnlineChecker { spec, lines: HashMap::default(), violations: Vec::new(), messages_checked: 0 }
    }

    /// Feed one message (with its timestamp) through the checker.
    pub fn observe(&mut self, t: Time, msg: &Message) {
        self.messages_checked += 1;
        let (class, op) = classify(msg);
        if class == SymClass::Io {
            // still allow specs over io, but keyed per line as usual
        }
        let states = self
            .lines
            .entry(msg.addr)
            .or_insert_with(|| vec![self.spec.start]);
        let mut next: Vec<usize> = Vec::new();
        let mut violated: Option<String> = None;
        let mut any_match = false;
        for &s in states.iter() {
            let mut moved = false;
            for r in &self.spec.rules {
                if r.from != s || r.class != class {
                    continue;
                }
                if let Some(want) = r.op {
                    if op != Some(want) {
                        continue;
                    }
                }
                moved = true;
                any_match = true;
                match &r.to {
                    Target::State(t) => {
                        if !next.contains(t) {
                            next.push(*t);
                        }
                    }
                    Target::Error(text) => violated = Some(text.clone()),
                }
            }
            if !moved {
                // symbol unmatched in this state
                if self.spec.default_error {
                    violated = Some(format!(
                        "unexpected {class:?} {op:?} in state {}",
                        self.spec.state_names[s]
                    ));
                } else {
                    // ignore: stay
                    if !next.contains(&s) {
                        next.push(s);
                    }
                }
            }
        }
        let _ = any_match;
        if let Some(detail) = violated {
            self.violations.push(CheckViolation {
                spec: self.spec.name.clone(),
                time: t,
                addr: msg.addr,
                detail,
            });
            *states = vec![self.spec.start];
            return;
        }
        *states = next;
    }

    pub fn tracked_lines(&self) -> usize {
        self.lines.len()
    }
}

/// The built-in property specs shipped with the toolkit.
pub mod builtin {
    /// Every upgrade request is answered before another grant cycle
    /// starts on the same line.
    pub const READ_RESPONSE: &str = r#"
nfa read_response {
  start idle;
  idle: req ReadShared -> pending;
  idle: req ReadExclusive -> pending;
  idle: req UpgradeS2E -> pending;
  pending: rsp ReadShared -> idle;
  pending: rsp ReadExclusive -> idle;
  pending: rsp UpgradeS2E -> idle;
  pending: req ReadShared -> error "request while response pending";
  pending: req ReadExclusive -> error "request while response pending";
  default ignore;
}
"#;

    /// A home-initiated downgrade must be answered before the home issues
    /// another one for the same line.
    pub const FWD_RESPONSE: &str = r#"
nfa fwd_response {
  start idle;
  idle: fwd * -> pending;
  pending: rsp FwdDowngradeS -> idle;
  pending: rsp FwdDowngradeI -> idle;
  pending: rsp FwdSharedInvalidate -> idle;
  pending: fwd * -> error "overlapping home-initiated downgrades";
  default ignore;
}
"#;

    /// Responses never appear without a prior request (per line).
    pub const NO_SPURIOUS_RSP: &str = r#"
nfa no_spurious_rsp {
  start idle;
  idle: req * -> pending;
  idle: rsp ReadShared -> error "response without request";
  idle: rsp ReadExclusive -> error "response without request";
  idle: rsp UpgradeS2E -> error "response without request";
  pending: rsp * -> idle;
  pending: req * -> pending;
  default ignore;
}
"#;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::messages::{Message, ReqId};
    use crate::proto::states::Node;

    fn req(id: u32, op: CohOp, addr: u64) -> Message {
        Message::coh_req(ReqId(id), Node::Remote, op, LineAddr(addr))
    }
    fn rsp(id: u32, op: CohOp, addr: u64) -> Message {
        Message::coh_rsp(ReqId(id), Node::Home, op, LineAddr(addr), false, None)
    }

    #[test]
    fn parses_builtin_specs() {
        for s in [builtin::READ_RESPONSE, builtin::FWD_RESPONSE, builtin::NO_SPURIOUS_RSP] {
            let spec = NfaSpec::parse(s).unwrap();
            assert!(spec.state_count() >= 2);
        }
    }

    #[test]
    fn clean_request_response_stream_passes() {
        let spec = NfaSpec::parse(builtin::READ_RESPONSE).unwrap();
        let mut c = OnlineChecker::new(spec);
        for i in 0..100u32 {
            let addr = (i % 7) as u64;
            c.observe(Time(i as u64 * 10), &req(i, CohOp::ReadShared, addr));
            c.observe(Time(i as u64 * 10 + 5), &rsp(i, CohOp::ReadShared, addr));
        }
        assert!(c.violations.is_empty(), "{:?}", c.violations);
        assert_eq!(c.messages_checked, 200);
    }

    #[test]
    fn double_request_is_flagged() {
        let spec = NfaSpec::parse(builtin::READ_RESPONSE).unwrap();
        let mut c = OnlineChecker::new(spec);
        c.observe(Time(0), &req(1, CohOp::ReadShared, 5));
        c.observe(Time(1), &req(2, CohOp::ReadShared, 5)); // no response yet!
        assert_eq!(c.violations.len(), 1);
        assert!(c.violations[0].detail.contains("pending"));
        assert_eq!(c.violations[0].addr, LineAddr(5));
    }

    #[test]
    fn per_line_instances_are_independent() {
        let spec = NfaSpec::parse(builtin::READ_RESPONSE).unwrap();
        let mut c = OnlineChecker::new(spec);
        c.observe(Time(0), &req(1, CohOp::ReadShared, 1));
        c.observe(Time(1), &req(2, CohOp::ReadShared, 2)); // different line: fine
        assert!(c.violations.is_empty());
        assert_eq!(c.tracked_lines(), 2);
    }

    #[test]
    fn spurious_response_is_flagged() {
        let spec = NfaSpec::parse(builtin::NO_SPURIOUS_RSP).unwrap();
        let mut c = OnlineChecker::new(spec);
        c.observe(Time(0), &rsp(9, CohOp::ReadShared, 3));
        assert_eq!(c.violations.len(), 1);
    }

    #[test]
    fn race_conversion_is_accepted_by_read_response() {
        // UpgradeS2E answered by a converted ReadExclusive response
        let spec = NfaSpec::parse(builtin::READ_RESPONSE).unwrap();
        let mut c = OnlineChecker::new(spec);
        c.observe(Time(0), &req(1, CohOp::UpgradeS2E, 4));
        c.observe(Time(1), &rsp(1, CohOp::ReadExclusive, 4));
        assert!(c.violations.is_empty(), "{:?}", c.violations);
    }

    #[test]
    fn default_error_flags_unmatched() {
        let spec = NfaSpec::parse(
            "nfa strict {\n start s;\n s: req ReadShared -> s;\n default error;\n}",
        )
        .unwrap();
        let mut c = OnlineChecker::new(spec);
        c.observe(Time(0), &req(1, CohOp::ReadShared, 0));
        assert!(c.violations.is_empty());
        c.observe(Time(1), &req(2, CohOp::ReadExclusive, 0));
        assert_eq!(c.violations.len(), 1);
    }

    #[test]
    fn parser_rejects_garbage() {
        assert!(NfaSpec::parse("nfa x {").is_err()); // no start
        assert!(NfaSpec::parse("start s;").is_err()); // no name
        assert!(NfaSpec::parse("nfa x {\n start s;\n s: bogus * -> s;\n}").is_err());
        assert!(NfaSpec::parse("nfa x {\n start s;\n s: req NoOp -> s;\n}").is_err());
        assert!(NfaSpec::parse("nfa x {\n start s;\n s: req ReadShared s;\n}").is_err());
    }
}
