//! Minimal JSON reader/writer.
//!
//! The paper defines "our own JSON-based serialization format" for decoded
//! ECI messages (§4.1); this module provides the JSON layer for that
//! format, for the AOT `manifest.json`, and for the DFA exchange files —
//! `serde` is unavailable in the offline registry, and the subset of JSON
//! we need is small enough that a dependency would be overkill anyway.
//!
//! Supports the full JSON grammar except exotic number forms beyond f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use `BTreeMap` for deterministic serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("expected , or ] got {other:?} at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} got {other:?} at {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic_document() {
        let j = parse(r#"{"a": 1, "b": [true, null, "x\n"], "c": {"d": -2.5e1}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("b").unwrap().idx(0).unwrap().as_bool(), Some(true));
        assert_eq!(j.get("b").unwrap().idx(1), Some(&Json::Null));
        assert_eq!(j.get("b").unwrap().idx(2).unwrap().as_str(), Some("x\n"));
        assert_eq!(j.get("c").unwrap().get("d").unwrap().as_f64(), Some(-25.0));
    }

    #[test]
    fn round_trip() {
        let original = Json::obj(vec![
            ("msg", Json::str("Read\"Shared\"")),
            ("addr", Json::num(123456u32)),
            ("dirty", Json::Bool(false)),
            ("payload", Json::arr((0..5).map(|i| Json::num(i as u32)))),
            ("nested", Json::obj(vec![("pi", Json::num(3.25))])),
        ]);
        let text = original.to_string();
        let parsed = parse(&text).unwrap();
        assert_eq!(parsed, original);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{} extra").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn integers_serialize_without_decimal_point() {
        assert_eq!(Json::num(42u32).to_string(), "42");
        assert_eq!(Json::num(2.5f64).to_string(), "2.5");
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = parse(&text).unwrap();
            assert!(m.get("ops").unwrap().get("select").is_some());
        }
    }
}
