//! `eci trace-demo`: capture live protocol traffic from a running
//! machine, print it through the dissector, dump JSON/EWF, and run the
//! online checker — including a deliberately-injected violation so the
//! report shows what detection looks like.

use std::cell::RefCell;
use std::rc::Rc;

use crate::agents::dram::MemStore;
use crate::machine::{map, Machine, MachineConfig, Workload};
use crate::proto::messages::{CohOp, LineAddr, Message, ReqId};
use crate::proto::states::Node;
use crate::sim::time::Time;

use super::capture::{Capture, Dir};
use super::checker::{builtin, NfaSpec, OnlineChecker};
use super::dissector;

pub fn run_demo() {
    let cfg = MachineConfig::test_small();
    let fpga = MemStore::new(map::TABLE_BASE, 1 << 20);
    let cpu = MemStore::new(LineAddr(0), 1 << 20);
    let mut m = Machine::memory_node(cfg, fpga, cpu);

    let capture = Rc::new(RefCell::new(Capture::new(64)));
    let checkers = Rc::new(RefCell::new(vec![
        OnlineChecker::new(NfaSpec::parse(builtin::READ_RESPONSE).unwrap()),
        OnlineChecker::new(NfaSpec::parse(builtin::FWD_RESPONSE).unwrap()),
        OnlineChecker::new(NfaSpec::parse(builtin::NO_SPURIOUS_RSP).unwrap()),
    ]));
    {
        let capture = Rc::clone(&capture);
        let checkers = Rc::clone(&checkers);
        m.tap = Some(Box::new(move |t, to_fpga, msg: &Message| {
            let dir = if to_fpga { Dir::CpuToFpga } else { Dir::FpgaToCpu };
            capture.borrow_mut().record(t, dir, msg.clone());
            for c in checkers.borrow_mut().iter_mut() {
                c.observe(t, msg);
            }
        }));
    }

    m.set_workload(Workload::StreamRemote { lines: 24 }, 2);
    let r = m.run();

    println!("== captured trace (last {} of {} messages) ==", capture.borrow().len(), capture.borrow().total_seen);
    for c in capture.borrow().iter().take(24) {
        println!("{}", dissector::summary(c.time, &c.msg));
    }
    if let Some(first) = capture.borrow().iter().next() {
        println!("\n== dissector detail of the first captured message ==");
        println!("{}", dissector::detail(first.time, &first.msg));
    }

    let json = capture.borrow().to_json().to_string();
    let ewf = capture.borrow().to_ewf();
    println!("== dumps: {} bytes JSON, {} bytes EWF ==", json.len(), ewf.len());

    println!("\n== online checker ==");
    for c in checkers.borrow().iter() {
        println!(
            "  checked {:>5} messages over {:>3} lines, {} violations",
            c.messages_checked,
            c.tracked_lines(),
            c.violations.len()
        );
        assert!(c.violations.is_empty(), "clean run must not violate: {:?}", c.violations);
    }
    println!("  clean run: no violations (sim {} / {} events)", r.sim_time, r.events);

    // now inject a protocol violation: a response out of thin air
    let bogus = Message::coh_rsp(ReqId(0xDEAD), Node::Home, CohOp::ReadShared, LineAddr(map::TABLE_BASE.0 + 999), false, None);
    for c in checkers.borrow_mut().iter_mut() {
        c.observe(Time(r.sim_time.ps() + 1), &bogus);
    }
    let total: usize = checkers.borrow().iter().map(|c| c.violations.len()).sum();
    println!("  injected a spurious response: {total} violation(s) detected:");
    for c in checkers.borrow().iter() {
        for v in &c.violations {
            println!("    [{}] t={} {} — {}", v.spec, v.time, v.addr, v.detail);
        }
    }
    assert!(total >= 1, "the injected violation must be detected");
}
