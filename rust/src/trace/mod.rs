//! The ECI supporting toolkit (paper §4.1): trace capture, the EWF binary
//! wire format, the JSON serialization of decoded messages, a
//! Wireshark-style dissector, and the NFA-specified online protocol
//! checker. These are the tools the paper built to reverse-engineer and
//! then continuously validate the ThunderX-1 protocol; here they observe
//! the simulated link (and any EWF/JSON trace file).

pub mod capture;
pub mod checker;
pub mod demo;
pub mod dissector;
pub mod ewf;
pub mod json;
pub mod msgjson;

pub use capture::{Capture, Captured, Dir};
pub use checker::{NfaSpec, OnlineChecker};
