//! dcs — Directory Controller Slices.
//!
//! The ECI hardware does not run one monolithic directory: coherence
//! traffic is split over *address-interleaved slices* (the even/odd VC
//! sets of §4.2 are the 2-slice case), so directory throughput scales
//! with parallel protocol engines instead of being capped by one
//! pipeline. This module is that composition for the simulated stack:
//!
//! * [`Dcs`] shards the directory across N slice-local
//!   [`HomeAgent`]s (line-address modulo mapping, N configurable);
//! * each slice has its own ingress FIFO — a [`VcMux`] from
//!   [`crate::transport::vc`], so intra-slice arbitration is the same
//!   rank-then-round-robin, per-VC-FIFO discipline the link itself uses
//!   (responses and writebacks drain before new requests, which is what
//!   keeps stalled lines from wedging a slice);
//! * each slice is a serial server: one message occupies the slice's
//!   directory pipeline for [`DcsConfig::slice_proc`], and per-slice
//!   occupancy/wait/latency statistics feed [`crate::sim::stats`].
//!
//! Two orthogonal knobs extend the baseline cache-less slices:
//!
//! * **Slice-local home caches** ([`DcsConfig::with_home_cache`]): the
//!   *symmetric* configuration of the paper — the FPGA side owns home
//!   state and caches lines itself. A total capacity is split evenly
//!   across slices; each partition indexes by `addr / slices` (so the
//!   modulo-interleaved address stream reaches every set) and runs the
//!   `cache_fills` home policy: shared grants fill the slice-local
//!   cache, repeat reads skip the backing-store round trip, and victims
//!   write back through the owning slice.
//! * **Cross-slice ingress batching** ([`DcsConfig::with_batch`]):
//!   frames delivered by the link stage per slice in an
//!   [`IngressBatcher`] and reach the slice FIFOs as one VC-disciplined
//!   batch per delivery — released when the batch fills or the slice
//!   runs dry, with credits held until slice service either way.
//!
//! Per-line semantics are *identical* for any slice count: a line maps to
//! exactly one slice in every configuration and all directory state is
//! line-local (see [`HomeAgent`]); the property test in
//! `rust/tests/props.rs` pins this 1-slice ≡ N-slice equivalence on
//! randomized traces. The closed-loop load generator that drives the
//! slices at saturation lives in [`loadgen`]; the open-loop,
//! scenario-driven generator (rate-controlled arrivals, Zipf hot spots,
//! link-framed admission via [`Dcs::enqueue_frame`]) is
//! [`crate::workload`]. The slice-count sweep harnesses are
//! `harness::fig_throughput` (sustained) and `harness::fig_loadcurve`
//! (latency vs offered load).

pub mod loadgen;

use std::collections::VecDeque;

use crate::agents::cache::Cache;
use crate::agents::dram::MemStore;
use crate::agents::home::{HomeAgent, HomeEffect};
use crate::proto::messages::{LineAddr, Message, LINE_BYTES};
use crate::proto::spec::{generate_home, HomePolicy, HomeRules, HomeSt};
use crate::proto::states::Node;
use crate::proto::transitions::reference_transitions;
use crate::sim::stats::{Counters, Histogram};
use crate::sim::time::{Duration, Time};
use crate::transport::ingress::IngressBatcher;
use crate::transport::link::Frame;
use crate::transport::vc::{vc_for, Credits, VcId, VcMux, NUM_VCS};

/// Default total home-cache capacity of the symmetric sliced
/// configuration (split across slices; BRAM-bounded on the FPGA).
pub const DEFAULT_HOME_CACHE_BYTES: usize = 1 << 20;
/// Default home-cache associativity.
pub const DEFAULT_HOME_CACHE_WAYS: usize = 8;

/// Configuration of the sliced directory controller.
#[derive(Clone, Copy, Debug)]
pub struct DcsConfig {
    /// Number of address-interleaved slices (1 = the monolithic home).
    pub slices: usize,
    /// Directory-pipeline occupancy per message on one slice (lookup +
    /// datapath dispatch; `MachineConfig::home_proc` on Enzian).
    pub slice_proc: Duration,
    /// Total home-cache capacity, split evenly across slices (0 =
    /// cache-less slices, the asymmetric configuration). With a cache,
    /// each slice runs the symmetric `cache_fills` home policy: shared
    /// grants fill the slice-local cache and repeat reads skip the
    /// backing-store round trip; victims write back through the owning
    /// slice.
    pub cache_bytes: usize,
    /// Home-cache associativity.
    pub cache_ways: usize,
    /// Framed-ingress batch size: how many same-slice frames one
    /// delivery may coalesce into a single VC-disciplined hand-off
    /// (1 = batching off). See [`IngressBatcher`].
    pub batch: usize,
    /// A slice that has been drained dark by the control plane
    /// (`--reconfig drain:<s>@..`): it owns no lines and receives no
    /// traffic; its natural address range spreads deterministically over
    /// the survivors (see [`Dcs::slice_of`]). `None` = all slices live.
    pub dead_slice: Option<usize>,
}

impl DcsConfig {
    pub fn new(slices: usize) -> DcsConfig {
        assert!(slices > 0, "need at least one slice");
        DcsConfig {
            slices,
            slice_proc: Duration::from_ns(40),
            cache_bytes: 0,
            cache_ways: DEFAULT_HOME_CACHE_WAYS,
            batch: 1,
            dead_slice: None,
        }
    }

    /// The symmetric configuration: `slices` slices sharing the default
    /// home-cache budget.
    pub fn cached(slices: usize) -> DcsConfig {
        DcsConfig::new(slices).with_home_cache(DEFAULT_HOME_CACHE_BYTES, DEFAULT_HOME_CACHE_WAYS)
    }

    pub fn with_slice_proc(mut self, d: Duration) -> DcsConfig {
        self.slice_proc = d;
        self
    }

    /// Give every slice a partition of a `total_bytes` home cache.
    pub fn with_home_cache(mut self, total_bytes: usize, ways: usize) -> DcsConfig {
        assert!(ways >= 1, "home cache needs at least one way");
        self.cache_bytes = total_bytes;
        self.cache_ways = ways;
        self
    }

    /// Coalesce up to `batch` same-slice frames per framed-ingress
    /// delivery.
    pub fn with_batch(mut self, batch: usize) -> DcsConfig {
        assert!(batch >= 1, "batch size must be >= 1");
        self.batch = batch;
        self
    }

    /// Mark slice `dead` drained dark (its address range re-homes across
    /// the survivors), or clear the mark with `None`.
    pub fn with_dead_slice(mut self, dead: Option<usize>) -> DcsConfig {
        if let Some(d) = dead {
            assert!(self.slices >= 2, "draining the only slice");
            assert!(d < self.slices, "bad dead slice {d}/{}", self.slices);
        }
        self.dead_slice = dead;
        self
    }

    /// Does this configuration carry slice-local home caches?
    pub fn home_cached(&self) -> bool {
        self.cache_bytes > 0
    }

    /// Largest slice count a `total_bytes` home cache of `ways`-way sets
    /// can be split across (every partition needs at least one full set
    /// of ways). Lets callers reject an oversized `--cached-slices`
    /// cleanly instead of tripping the `slice_cache` assert mid-sweep.
    pub fn max_cached_slices(total_bytes: usize, ways: usize) -> usize {
        total_bytes / LINE_BYTES / ways.max(1)
    }

    /// Build one slice's cache partition: `cache_bytes / slices`,
    /// rounded down to a valid power-of-two set count, indexed by
    /// `addr / slices` so the slice's modulo-interleaved address stream
    /// reaches every set.
    fn slice_cache(&self) -> Option<Cache> {
        if self.cache_bytes == 0 {
            return None;
        }
        let lines = self.cache_bytes / LINE_BYTES / self.slices;
        let lpw = lines / self.cache_ways;
        assert!(
            lpw >= 1,
            "home cache too small: {} bytes over {} slices x {} ways",
            self.cache_bytes,
            self.slices,
            self.cache_ways
        );
        let mut sets = lpw.next_power_of_two();
        if sets > lpw {
            sets /= 2;
        }
        Some(Cache::interleaved(sets * self.cache_ways * LINE_BYTES, self.cache_ways, self.slices as u64))
    }
}

/// Per-slice measurement block.
#[derive(Clone, Debug)]
pub struct SliceStats {
    /// Messages serviced.
    pub served: u64,
    /// Messages routed to this slice's ingress (hot-spot accounting:
    /// under skewed line popularity, arrivals concentrate here before
    /// service does).
    pub enqueued: u64,
    /// Queue wait per message (arrival -> service start), picoseconds.
    pub wait: Histogram,
    /// Total pipeline-busy time.
    pub busy: Duration,
    /// High-water mark of the ingress queue.
    pub max_queue: usize,
}

impl SliceStats {
    fn new() -> SliceStats {
        SliceStats {
            served: 0,
            enqueued: 0,
            wait: Histogram::new(),
            busy: Duration::ZERO,
            max_queue: 0,
        }
    }

    /// Fraction of `total` this slice's pipeline was busy.
    pub fn occupancy(&self, total: Time) -> f64 {
        if total.ps() == 0 {
            0.0
        } else {
            self.busy.ps() as f64 / total.ps() as f64
        }
    }
}

/// One directory slice: a slice-local home agent behind a VC-disciplined
/// ingress queue and a serial service pipeline.
struct Slice {
    home: HomeAgent,
    /// Ingress queue, reusing the transport VC multiplexer: per-VC FIFO,
    /// deadlock-rank-then-round-robin arbitration.
    mux: VcMux,
    /// Arrival stamps, parallel to the mux's per-VC FIFOs.
    arrivals: [VecDeque<Time>; NUM_VCS],
    busy_until: Time,
    stats: SliceStats,
}

/// Outcome of one service attempt on a slice.
#[derive(Debug)]
pub enum SliceService {
    /// The slice pipeline is occupied until `t`; poll again then.
    Busy(Time),
    /// One message was serviced; its effects are ready at `t`. The
    /// serviced VC is reported so a link-framed ingress can return the
    /// frame's credit when the slice frees the buffer slot, and the
    /// serviced line address so multi-source hosts (the inter-node
    /// fabric) can attribute the service to the right ingress and track
    /// per-line in-flight work for quiesce protocols.
    Done(Time, VcId, LineAddr, Vec<HomeEffect>),
}

/// The sharded directory controller.
pub struct Dcs {
    pub cfg: DcsConfig,
    slices: Vec<Slice>,
    /// Cross-slice ingress batching for the framed path
    /// ([`Dcs::enqueue_frame`]): sequenced frames stage per slice and
    /// are handed over as one VC-disciplined batch per delivery.
    batcher: IngressBatcher,
    /// Ingress-side credit view for the mux arbiter: the dcs never
    /// throttles its own dequeue, so every VC always has a credit.
    always: Credits,
}

impl Dcs {
    /// Shard the directory described by `rules` across `cfg.slices`
    /// slice-local home agents (each with a cache partition when the
    /// configuration is cached).
    pub fn new(cfg: DcsConfig, rules: HomeRules, policy: HomePolicy) -> Dcs {
        assert!(cfg.slices > 0);
        if let Some(d) = cfg.dead_slice {
            assert!(cfg.slices >= 2 && d < cfg.slices, "bad dead slice {d}/{}", cfg.slices);
        }
        let slices = (0..cfg.slices)
            .map(|i| {
                let mut home = HomeAgent::new_slice(
                    rules.clone(),
                    policy,
                    cfg.slice_cache(),
                    i as u64,
                    cfg.slices as u64,
                );
                // survivors adopt their share of the drained range; the
                // dead slice keeps its natural view (it sees no traffic)
                if let Some(d) = cfg.dead_slice {
                    if i != d {
                        home.set_dead_sibling(Some(d as u64));
                    }
                }
                Slice {
                    home,
                    mux: VcMux::new(Node::Remote),
                    arrivals: Default::default(),
                    busy_until: Time::ZERO,
                    stats: SliceStats::new(),
                }
            })
            .collect();
        Dcs {
            slices,
            batcher: IngressBatcher::new(cfg.batch, cfg.slices),
            always: Credits::new(1),
            cfg,
        }
    }

    /// A dcs over the reference protocol. Cache-less configurations run
    /// the default home policy; cached ones enable `cache_fills` so
    /// shared grants populate the slice-local caches.
    pub fn with_reference_rules(cfg: DcsConfig) -> Dcs {
        let policy = HomePolicy { cache_fills: cfg.home_cached(), ..HomePolicy::default() };
        Dcs::new(cfg, generate_home(&reference_transitions(), policy), policy)
    }

    pub fn slices(&self) -> usize {
        self.slices.len()
    }

    /// Does this dcs run slice-local home caches?
    pub fn home_cached(&self) -> bool {
        self.cfg.home_cached()
    }

    /// Ingress-batching state (stats; staging is internal).
    pub fn batcher(&self) -> &IngressBatcher {
        &self.batcher
    }

    /// Address-interleaved slice mapping (2 slices = even/odd lines).
    /// While a slice is drained ([`DcsConfig::dead_slice`]) its natural
    /// lines redirect to a survivor: line `a` with natural owner `d`
    /// re-homes to `(d + 1 + (a/n) % (n-1)) % n` — never `d` itself, and
    /// spread evenly. The formula is mirrored by [`HomeAgent::owns`] so
    /// per-agent ownership asserts stay exact.
    #[inline]
    pub fn slice_of(&self, addr: LineAddr) -> usize {
        let n = self.slices.len() as u64;
        let natural = addr.0 % n;
        if self.cfg.dead_slice == Some(natural as usize) {
            let k = (addr.0 / n) % (n - 1);
            return ((natural + 1 + k) % n) as usize;
        }
        natural as usize
    }

    // -- timed path ---------------------------------------------------------

    /// A coherence message arrived from the remote at `now`: queue it on
    /// its slice's ingress FIFO (per-VC order preserved).
    pub fn enqueue(&mut self, now: Time, msg: Message) {
        let s = self.slice_of(msg.addr);
        let slice = &mut self.slices[s];
        let vc = vc_for(&msg);
        slice.arrivals[vc.0 as usize].push_back(now);
        slice.mux.enqueue(msg);
        slice.stats.enqueued += 1;
        slice.stats.max_queue = slice.stats.max_queue.max(slice.mux.pending());
    }

    /// Link-framed ingress: unwrap one in-sequence [`Frame`] (as handed
    /// back by [`crate::transport::FramedIngress::deliver`]) onto its
    /// owning slice's VC FIFO. Returns the slice index so the host can
    /// pump that slice — and, when the slice later reports
    /// [`SliceService::Done`], return the frame's credit on the serviced
    /// VC.
    ///
    /// With `DcsConfig::batch > 1` the frame is *staged*: same-slice
    /// frames coalesce into one VC-disciplined batch that reaches the
    /// slice's FIFOs either when it fills or when the slice runs dry
    /// (inside [`Dcs::service_one`]), whichever comes first. Staged
    /// frames still hold their link credit — it returns at slice
    /// service, exactly as for unbatched frames.
    pub fn enqueue_frame(&mut self, now: Time, frame: Frame) -> usize {
        debug_assert_eq!(frame.vc, vc_for(&frame.msg), "frame VC must match its message class");
        debug_assert!(frame.intact, "corrupt frames are dropped by the transaction layer");
        let s = self.slice_of(frame.msg.addr);
        if self.batcher.batch_size() <= 1 {
            self.enqueue(now, frame.msg);
        } else if self.batcher.stage(s, now, frame) {
            self.flush_slice(s);
        }
        s
    }

    /// Move slice `s`'s staged ingress batch onto its VC FIFOs as one
    /// delivery (arrival order preserved; the mux applies the usual
    /// rank-then-round-robin discipline across the whole batch).
    fn flush_slice(&mut self, s: usize) {
        for (at, f) in self.batcher.take(s) {
            self.enqueue(at, f.msg);
        }
    }

    /// Attempt to service one queued message on slice `s` at `now`.
    /// Returns `None` when the slice's queue is empty.
    pub fn service_one(
        &mut self,
        s: usize,
        now: Time,
        ram: &mut MemStore,
    ) -> Option<SliceService> {
        // A drained slice pulls in its staged ingress batch (short
        // batches flush here, so no frame is ever held past the slice
        // running dry). While the pipeline is still busy the stage keeps
        // accumulating — that is where batches actually form.
        if self.slices[s].mux.is_empty() && self.batcher.pending(s) > 0 {
            if self.slices[s].busy_until > now {
                return Some(SliceService::Busy(self.slices[s].busy_until));
            }
            self.flush_slice(s);
        }
        let proc = self.cfg.slice_proc;
        let slice = &mut self.slices[s];
        if slice.mux.is_empty() {
            return None;
        }
        if slice.busy_until > now {
            return Some(SliceService::Busy(slice.busy_until));
        }
        let (vc, msg) = slice
            .mux
            .arbitrate(&self.always)
            .expect("non-empty mux with free credits must arbitrate");
        let arrived = slice.arrivals[vc.0 as usize]
            .pop_front()
            .expect("arrival stamp out of sync with mux queue");
        slice.stats.wait.record(now.since(arrived).ps());
        let done = now + proc;
        slice.busy_until = done;
        slice.stats.busy += proc;
        slice.stats.served += 1;
        let addr = msg.addr;
        let fx = slice.home.on_message(msg, ram);
        Some(SliceService::Done(done, vc, addr, fx))
    }

    /// Evict the owning slice's cached copy of `addr` (writing dirty
    /// data back to `ram`) and drop the line's directory entry, provided
    /// no remote possession or pending forward is outstanding. Returns
    /// `true` when the line ends untracked — the handoff step of a home
    /// migration: after a successful surrender the line's entire state
    /// lives in the backing store and a new home node can adopt it cold.
    pub fn surrender_local(&mut self, addr: LineAddr, ram: &mut MemStore) -> bool {
        let s = self.slice_of(addr);
        self.slices[s].home.surrender_copy(addr, ram)
    }

    /// Failover adoption: rebuild the owning slice's directory entry for
    /// a line whose previous home died while a remote still holds a copy
    /// (see [`HomeAgent::adopt_remote`]).
    pub fn adopt_remote(&mut self, addr: LineAddr, view: crate::proto::spec::RemoteView, holders: u32) {
        let s = self.slice_of(addr);
        self.slices[s].home.adopt_remote(addr, view, holders);
    }

    /// Live-reconfiguration handoff, export side: pack up everything the
    /// owning slice knows about `addr` (directory word, grant epochs,
    /// cached copy) so a differently-shaped [`Dcs`] can
    /// [`Dcs::import_line`] it verbatim. `None` when nothing is tracked.
    /// Only legal on a quiesced data plane — see
    /// [`HomeAgent::export_line`].
    pub fn export_line(&mut self, addr: LineAddr) -> Option<crate::agents::home::ExportedLine> {
        let s = self.slice_of(addr);
        self.slices[s].home.export_line(addr)
    }

    /// Live-reconfiguration handoff, import side: install an exported
    /// line on the owning slice of *this* shape (cache victims follow
    /// the usual freshest-copy writeback rule). Returns the number of
    /// cache victims displaced — see [`HomeAgent::import_line`].
    pub fn import_line(
        &mut self,
        addr: LineAddr,
        ex: crate::agents::home::ExportedLine,
        ram: &mut MemStore,
    ) -> u64 {
        let s = self.slice_of(addr);
        self.slices[s].home.import_line(addr, ex, ram)
    }

    /// Total queued messages across slices (staged ingress frames
    /// included — they occupy receiver buffer slots like queued ones).
    pub fn pending(&self) -> usize {
        self.slices.iter().map(|s| s.mux.pending()).sum::<usize>() + self.batcher.total_pending()
    }

    // -- untimed (functional) path ------------------------------------------

    /// Dispatch a message straight to its owning slice, bypassing the
    /// ingress queue and pipeline timing. Per-line behaviour is identical
    /// to the timed path (same agent, same rules); used by functional
    /// tests and the 1-vs-N equivalence property.
    pub fn on_message_sync(&mut self, msg: Message, ram: &mut MemStore) -> Vec<HomeEffect> {
        let s = self.slice_of(msg.addr);
        self.slices[s].home.on_message(msg, ram)
    }

    /// Home-side application access, routed to the owning slice
    /// (symmetric configurations).
    pub fn local_access_sync(
        &mut self,
        addr: LineAddr,
        write: bool,
        tag: u64,
        ram: &mut MemStore,
    ) -> Vec<HomeEffect> {
        let s = self.slice_of(addr);
        self.slices[s].home.local_access(addr, write, tag, ram)
    }

    // -- introspection ------------------------------------------------------

    /// Directory state of a line (from its owning slice).
    pub fn state_of(&self, addr: LineAddr) -> HomeSt {
        self.slices[self.slice_of(addr)].home.state_of(addr)
    }

    /// Lines tracked across all slices (§3.4 space accounting).
    pub fn tracked_lines(&self) -> usize {
        self.slices.iter().map(|s| s.home.tracked_lines()).sum()
    }

    pub fn slice_stats(&self, s: usize) -> &SliceStats {
        &self.slices[s].stats
    }

    /// Messages serviced per slice.
    pub fn per_slice_served(&self) -> Vec<u64> {
        self.slices.iter().map(|s| s.stats.served).collect()
    }

    /// Hot-spot skew of serviced load: max over mean of per-slice served
    /// counts. 1.0 = perfectly balanced; a Zipf-skewed workload whose
    /// hottest lines land on one slice pushes this well above 1.
    pub fn served_skew(&self) -> f64 {
        skew_of(self.slices.iter().map(|s| s.stats.served as f64))
    }

    /// Hot-spot skew of pipeline occupancy over `total` simulated time
    /// (max slice occupancy over mean slice occupancy).
    pub fn occupancy_skew(&self, total: Time) -> f64 {
        skew_of(self.slices.iter().map(|s| s.stats.occupancy(total)))
    }

    /// Merged per-slice home-agent counters, a `slices_served` total,
    /// and named `slice<N>_served` counts for the first 8 slices
    /// (counter keys are `&'static str`; beyond 8, per-slice detail is
    /// available through [`Dcs::slice_stats`] and the total stays
    /// exact).
    pub fn counters(&self) -> Counters {
        let mut c = Counters::new();
        const SLICE_KEYS: [&str; 8] = [
            "slice0_served",
            "slice1_served",
            "slice2_served",
            "slice3_served",
            "slice4_served",
            "slice5_served",
            "slice6_served",
            "slice7_served",
        ];
        for (i, s) in self.slices.iter().enumerate() {
            for (k, v) in s.home.stats.iter() {
                c.add(k, v);
            }
            c.add("slices_served", s.stats.served);
            if let Some(key) = SLICE_KEYS.get(i) {
                c.add(key, s.stats.served);
            }
        }
        c.add("ingress_deliveries", self.batcher.deliveries);
        c.add("ingress_batched_frames", self.batcher.frames);
        c
    }

    /// Publish instantaneous queue-depth gauges into an obs registry:
    /// total pending plus per-slice FIFO depth and staged-batch backlog
    /// (the telemetry ticker's view of directory congestion).
    pub fn observe_gauges(&self, ns: &str, reg: &mut crate::obs::Registry) {
        reg.gauge(&format!("{ns}.pending"), self.pending() as f64);
        for (i, s) in self.slices.iter().enumerate() {
            reg.gauge(&format!("{ns}.slice{i}.depth"), s.mux.pending() as f64);
            if self.batcher.batch_size() > 1 {
                reg.gauge(&format!("{ns}.slice{i}.staged"), self.batcher.pending(i) as f64);
            }
        }
    }
}

/// Max-over-mean of a load vector (1.0 = balanced; degenerate inputs —
/// one slice, or no load at all — report 1.0 rather than NaN).
fn skew_of(loads: impl Iterator<Item = f64>) -> f64 {
    let xs: Vec<f64> = loads.collect();
    if xs.is_empty() {
        return 1.0;
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    if mean <= 0.0 {
        return 1.0;
    }
    let max = xs.iter().cloned().fold(0.0f64, f64::max);
    max / mean
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::messages::{CohOp, MsgKind, ReqId};
    use crate::proto::spec::RemoteView;

    fn mk(slices: usize) -> (Dcs, MemStore) {
        let dcs = Dcs::with_reference_rules(DcsConfig::new(slices));
        let mut ram = MemStore::new(LineAddr(0), 1 << 20);
        for i in 0..64 {
            let mut l = [0u8; 128];
            l[0] = i as u8;
            ram.write_line(LineAddr(i), &l);
        }
        (dcs, ram)
    }

    #[test]
    fn slice_mapping_is_modulo_interleaved() {
        let (dcs, _) = mk(4);
        assert_eq!(dcs.slice_of(LineAddr(0)), 0);
        assert_eq!(dcs.slice_of(LineAddr(1)), 1);
        assert_eq!(dcs.slice_of(LineAddr(6)), 2);
        assert_eq!(dcs.slice_of(LineAddr(7)), 3);
        // 2 slices = the paper's even/odd split
        let (dcs, _) = mk(2);
        assert_eq!(dcs.slice_of(LineAddr(10)), 0);
        assert_eq!(dcs.slice_of(LineAddr(11)), 1);
    }

    #[test]
    fn timed_service_serializes_one_slice() {
        let (mut dcs, mut ram) = mk(1);
        let proc = dcs.cfg.slice_proc;
        dcs.enqueue(Time(0), Message::coh_req(ReqId(1), Node::Remote, CohOp::ReadShared, LineAddr(2)));
        dcs.enqueue(Time(0), Message::coh_req(ReqId(2), Node::Remote, CohOp::ReadShared, LineAddr(4)));
        // first service completes at proc
        let Some(SliceService::Done(t1, vc1, a1, fx)) = dcs.service_one(0, Time(0), &mut ram)
        else {
            panic!("expected service");
        };
        assert_eq!(a1, LineAddr(2), "Done reports the serviced line");
        assert_eq!(vc1, VcId(0), "even request rides the even Req VC");
        assert_eq!(t1, Time(0) + proc);
        assert_eq!(fx.len(), 1);
        // pipeline busy: second attempt reports busy-until
        let Some(SliceService::Busy(t)) = dcs.service_one(0, Time(0), &mut ram) else {
            panic!("expected busy");
        };
        assert_eq!(t, t1);
        // at t1 the second message goes through
        let Some(SliceService::Done(t2, _, a2, _)) = dcs.service_one(0, t1, &mut ram) else {
            panic!("expected service");
        };
        assert_eq!(a2, LineAddr(4));
        assert_eq!(t2, t1 + proc);
        assert!(dcs.service_one(0, t2, &mut ram).is_none(), "queue drained");
        assert_eq!(dcs.slice_stats(0).served, 2);
        assert_eq!(dcs.slice_stats(0).busy, proc.times(2));
    }

    #[test]
    fn slices_service_disjoint_lines_independently() {
        let (mut dcs, mut ram) = mk(2);
        // even line -> slice 0, odd line -> slice 1
        dcs.enqueue(Time(0), Message::coh_req(ReqId(1), Node::Remote, CohOp::ReadShared, LineAddr(2)));
        dcs.enqueue(Time(0), Message::coh_req(ReqId(2), Node::Remote, CohOp::ReadShared, LineAddr(3)));
        let Some(SliceService::Done(t0, _, _, _)) = dcs.service_one(0, Time(0), &mut ram) else {
            panic!()
        };
        let Some(SliceService::Done(t1, _, _, _)) = dcs.service_one(1, Time(0), &mut ram) else {
            panic!()
        };
        // both complete after ONE service latency: true slice parallelism
        assert_eq!(t0, Time(0) + dcs.cfg.slice_proc);
        assert_eq!(t1, t0);
        assert_eq!(dcs.state_of(LineAddr(2)).view, RemoteView::S);
        assert_eq!(dcs.state_of(LineAddr(3)).view, RemoteView::S);
        assert_eq!(dcs.tracked_lines(), 2);
    }

    #[test]
    fn writebacks_outrank_requests_within_a_slice() {
        let (mut dcs, mut ram) = mk(1);
        // line 4 is held exclusive, so its writeback is protocol-legal
        dcs.on_message_sync(
            Message::coh_req(ReqId(4), Node::Remote, CohOp::ReadExclusive, LineAddr(4)),
            &mut ram,
        );
        // a request queued BEFORE a writeback: the WbData class has the
        // higher deadlock rank and must be arbitrated first.
        dcs.enqueue(Time(0), Message::coh_req(ReqId(5), Node::Remote, CohOp::ReadShared, LineAddr(2)));
        dcs.enqueue(
            Time(0),
            Message::coh_req_data(
                ReqId(6),
                Node::Remote,
                CohOp::VolDowngradeI,
                LineAddr(4),
                Box::new([7u8; 128]),
            ),
        );
        let Some(SliceService::Done(_, vc, _, fx)) = dcs.service_one(0, Time(0), &mut ram) else {
            panic!()
        };
        assert_eq!(vc, VcId(8), "writeback class, even parity");
        assert!(
            fx.iter().any(|e| matches!(e, HomeEffect::RamWrite { addr } if *addr == LineAddr(4))),
            "writeback must be arbitrated first: {fx:?}"
        );
        assert_eq!(ram.read_line(LineAddr(4))[0], 7, "writeback data must reach RAM");
    }

    #[test]
    fn framed_ingress_routes_to_owning_slice_and_tracks_hotspots() {
        let (mut dcs, mut ram) = mk(2);
        // 3 even-line requests, 1 odd: slice 0 is the hot spot
        for (i, addr) in [0u64, 2, 4, 1].iter().enumerate() {
            let m = Message::coh_req(
                ReqId(i as u32),
                Node::Remote,
                CohOp::ReadShared,
                LineAddr(*addr),
            );
            let f = Frame::new(i as u64, m);
            let s = dcs.enqueue_frame(Time(0), f);
            assert_eq!(s, (*addr % 2) as usize);
        }
        assert_eq!(dcs.slice_stats(0).enqueued, 3);
        assert_eq!(dcs.slice_stats(1).enqueued, 1);
        let mut t = Time(0);
        while let Some(sv) = dcs.service_one(0, t, &mut ram) {
            match sv {
                SliceService::Busy(at) => t = at,
                SliceService::Done(..) => {}
            }
        }
        assert!(dcs.service_one(1, t, &mut ram).is_some());
        assert_eq!(dcs.per_slice_served(), vec![3, 1]);
        // max/mean = 3/2
        assert!((dcs.served_skew() - 1.5).abs() < 1e-9, "skew {}", dcs.served_skew());
        assert!(dcs.occupancy_skew(t) > 1.0);
    }

    #[test]
    fn skew_is_one_for_balanced_or_degenerate_loads() {
        let (dcs, _) = mk(1);
        assert_eq!(dcs.served_skew(), 1.0, "single slice is balanced by definition");
        let (dcs, _) = mk(4);
        assert_eq!(dcs.served_skew(), 1.0, "no load yet -> no skew");
    }

    #[test]
    fn cached_slices_hit_after_first_grant_and_serve_identical_bytes() {
        let (mut plain, mut ram_p) = mk(2);
        let mut cached = Dcs::with_reference_rules(DcsConfig::cached(2));
        assert!(cached.home_cached() && !plain.home_cached());
        let mut ram_c = MemStore::new(LineAddr(0), 1 << 20);
        for i in 0..64 {
            let mut l = [0u8; 128];
            l[0] = i as u8;
            ram_c.write_line(LineAddr(i), &l);
        }
        // read, release, re-read a handful of lines on both parities
        let mut id = 0u32;
        for round in 0..2 {
            for addr in 0..8u64 {
                for op in [CohOp::ReadShared, CohOp::VolDowngradeI] {
                    let m = Message::coh_req(ReqId(id), Node::Remote, op, LineAddr(addr));
                    id += 1;
                    let a = plain.on_message_sync(m.clone(), &mut ram_p);
                    let b = cached.on_message_sync(m, &mut ram_c);
                    assert_eq!(a.len(), b.len(), "round {round} addr {addr}");
                    for (x, y) in a.iter().zip(&b) {
                        let (HomeEffect::Respond { msg: mx, .. }, HomeEffect::Respond { msg: my, .. }) = (x, y)
                        else {
                            panic!("unexpected effects {x:?} / {y:?}")
                        };
                        assert_eq!(mx.payload, my.payload, "cached slices must serve identical bytes");
                    }
                }
            }
        }
        // the second round was served slice-locally
        let c = cached.counters();
        assert_eq!(c.get("home_cache_fill"), 8, "one fill per line");
        assert_eq!(c.get("home_cache_hit"), 8, "round two hits the home cache");
        assert_eq!(plain.counters().get("home_cache_hit"), 0);
    }

    #[test]
    fn framed_batches_flush_on_full_and_on_drain() {
        let mut dcs = Dcs::with_reference_rules(DcsConfig::new(2).with_batch(3));
        let mut ram = MemStore::new(LineAddr(0), 1 << 20);
        for i in 0..64 {
            ram.write_line(LineAddr(i), &[i as u8; 128]);
        }
        // four even-line frames: three fill a batch (flushed at once),
        // the fourth stays staged until the slice runs dry
        for i in 0..4u64 {
            let m = Message::coh_req(ReqId(i as u32), Node::Remote, CohOp::ReadShared, LineAddr(2 * i));
            let s = dcs.enqueue_frame(Time(0), Frame::new(i, m));
            assert_eq!(s, 0);
        }
        assert_eq!(dcs.pending(), 4, "staged frames still count as pending");
        assert_eq!(dcs.slice_stats(0).enqueued, 3, "full batch reaches the FIFO at once");
        assert_eq!(dcs.batcher().pending(0), 1);
        // service everything: the mux drains first, then the short
        // remainder batch is pulled in
        let mut t = Time(0);
        let mut done = 0;
        loop {
            match dcs.service_one(0, t, &mut ram) {
                None => break,
                Some(SliceService::Busy(at)) => t = at,
                Some(SliceService::Done(..)) => done += 1,
            }
        }
        assert_eq!(done, 4);
        assert_eq!(dcs.pending(), 0);
        assert_eq!(dcs.batcher().deliveries, 2);
        assert_eq!(dcs.batcher().max_batch, 3);
        assert_eq!(dcs.slice_stats(0).served, 4);
    }

    #[test]
    fn reslice_handoff_preserves_state_and_served_bytes() {
        // build state on a 2-slice cached dcs, hand every line off to a
        // 4-slice dcs, and check the directory words and served bytes
        // survive the re-interleave exactly
        let mut old = Dcs::with_reference_rules(DcsConfig::cached(2));
        let mut ram = MemStore::new(LineAddr(0), 1 << 20);
        for i in 0..64 {
            ram.write_line(LineAddr(i), &[i as u8; 128]);
        }
        let mut id = 0u32;
        for addr in 0..16u64 {
            old.on_message_sync(
                Message::coh_req(ReqId(id), Node::Remote, CohOp::ReadShared, LineAddr(addr)),
                &mut ram,
            );
            id += 1;
        }
        let before: Vec<_> = (0..16u64).map(|a| old.state_of(LineAddr(a))).collect();
        let mut new = Dcs::with_reference_rules(DcsConfig::cached(4));
        let mut moved = 0;
        for addr in 0..16u64 {
            if let Some(ex) = old.export_line(LineAddr(addr)) {
                new.import_line(LineAddr(addr), ex, &mut ram);
                moved += 1;
            }
        }
        assert_eq!(moved, 16, "every granted line carries state");
        assert_eq!(old.tracked_lines(), 0, "the old shape forgets everything");
        for addr in 0..16u64 {
            assert_eq!(new.state_of(LineAddr(addr)), before[addr as usize], "line {addr}");
        }
        // the imported shape is live protocol state: releases and repeat
        // reads land on the new owning slices without complaint
        for addr in 0..16u64 {
            new.on_message_sync(
                Message::coh_req(ReqId(id), Node::Remote, CohOp::VolDowngradeI, LineAddr(addr)),
                &mut ram,
            );
            id += 1;
            let fx = new.on_message_sync(
                Message::coh_req(ReqId(id), Node::Remote, CohOp::ReadShared, LineAddr(addr)),
                &mut ram,
            );
            id += 1;
            let HomeEffect::Respond { msg, .. } = &fx[0] else { panic!("{fx:?}") };
            assert_eq!(msg.payload.as_ref().unwrap()[0], addr as u8);
        }
    }

    #[test]
    fn dead_slice_redirects_to_survivors_and_rejoin_restores() {
        let dcs = Dcs::with_reference_rules(DcsConfig::new(4).with_dead_slice(Some(1)));
        let mut spread = [0usize; 4];
        for addr in 0..4096u64 {
            let s = dcs.slice_of(LineAddr(addr));
            assert_ne!(s, 1, "drained slice must own nothing");
            if addr % 4 == 1 {
                spread[s] += 1;
            } else {
                assert_eq!(s, (addr % 4) as usize);
            }
        }
        for s in [0usize, 2, 3] {
            assert!(spread[s] >= 300, "survivor {s} got {}", spread[s]);
        }
        // rejoin = a dcs without the mark: natural interleave again
        let dcs = Dcs::with_reference_rules(DcsConfig::new(4));
        for addr in 0..64u64 {
            assert_eq!(dcs.slice_of(LineAddr(addr)), (addr % 4) as usize);
        }
    }

    #[test]
    fn drain_handoff_routes_orphans_through_survivor_slices() {
        // 2-slice dcs with state on both parities; drain slice 1 and hand
        // its lines to the survivors of the SAME slice count
        let mut old = Dcs::with_reference_rules(DcsConfig::new(2));
        let mut ram = MemStore::new(LineAddr(0), 1 << 20);
        for i in 0..64 {
            ram.write_line(LineAddr(i), &[i as u8; 128]);
        }
        for addr in 0..8u64 {
            old.on_message_sync(
                Message::coh_req(ReqId(addr as u32), Node::Remote, CohOp::ReadShared, LineAddr(addr)),
                &mut ram,
            );
        }
        let mut drained = Dcs::with_reference_rules(DcsConfig::new(2).with_dead_slice(Some(1)));
        for addr in 0..8u64 {
            if let Some(ex) = old.export_line(LineAddr(addr)) {
                drained.import_line(LineAddr(addr), ex, &mut ram);
            }
        }
        assert_eq!(drained.tracked_lines(), 8);
        // odd lines now live on slice 0 (the only survivor of 2)
        for addr in [1u64, 3, 5, 7] {
            assert_eq!(drained.slice_of(LineAddr(addr)), 0);
            assert_eq!(drained.state_of(LineAddr(addr)).view, RemoteView::S);
        }
        // and traffic for them is serviced by the survivor
        let mut t = Time(0);
        drained.enqueue(t, Message::coh_req(ReqId(99), Node::Remote, CohOp::VolDowngradeI, LineAddr(3)));
        let Some(SliceService::Done(_, _, a, _)) = drained.service_one(0, t, &mut ram) else {
            panic!("survivor must service the orphan")
        };
        assert_eq!(a, LineAddr(3));
        t = t + drained.cfg.slice_proc;
        assert!(drained.service_one(0, t, &mut ram).is_none());
        assert_eq!(drained.state_of(LineAddr(3)), HomeSt::idle());
    }

    #[test]
    fn sync_path_matches_direct_home_agent() {
        use crate::agents::home::HomeAgent;
        use crate::proto::spec::generate_home;
        let (mut dcs, mut ram) = mk(4);
        let mut mono = HomeAgent::new(
            generate_home(&reference_transitions(), HomePolicy::default()),
            HomePolicy::default(),
            None,
        );
        let mut ram2 = MemStore::new(LineAddr(0), 1 << 20);
        for i in 0..64 {
            let mut l = [0u8; 128];
            l[0] = i as u8;
            ram2.write_line(LineAddr(i), &l);
        }
        for i in 0..16u64 {
            let m = Message::coh_req(ReqId(i as u32), Node::Remote, CohOp::ReadShared, LineAddr(i));
            let a = dcs.on_message_sync(m.clone(), &mut ram);
            let b = mono.on_message(m, &mut ram2);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                match (x, y) {
                    (
                        HomeEffect::Respond { msg: mx, from_ram: fx },
                        HomeEffect::Respond { msg: my, from_ram: fy },
                    ) => {
                        assert_eq!(fx, fy);
                        assert_eq!(mx.addr, my.addr);
                        assert_eq!(mx.payload, my.payload);
                        assert!(matches!(mx.kind, MsgKind::CohRsp { .. }));
                    }
                    other => panic!("effect mismatch {other:?}"),
                }
            }
            assert_eq!(dcs.state_of(LineAddr(i)), mono.state_of(LineAddr(i)));
        }
    }
}
