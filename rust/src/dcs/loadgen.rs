//! Closed-loop load generator for the sliced directory controller.
//!
//! M concurrent simulated clients sit behind one shared caching
//! [`RemoteAgent`] (the CPU socket role) and drive a configurable mix of
//! read / write / pointer-chase traffic at a [`Dcs`]. The loop is
//! *closed*: each client has exactly one operation in flight and issues
//! the next the instant the previous completes, so the reported
//! requests/sec is the *sustained* service rate of the directory under
//! backpressure, not an open-loop arrival rate. Latency percentiles come
//! from the per-operation histogram (`p50`/`p99` of issue → last fill).
//!
//! Pointer-chase operations are execution-driven: chain pointers are real
//! bytes in the backing [`MemStore`] (written at setup, bytes 120..128 of
//! each line, KVS-entry layout), and each hop's next address is decoded
//! from the payload the directory actually served. On the home side a
//! chase lookup resolves through the [`KvsService`] engine pool — the
//! same dispatcher/engine model the Fig. 6 machine path uses — so
//! memctl's pointer-resolution cost rides through the dcs rather than
//! around it.

use crate::agents::cache::Cache;
use crate::agents::dram::{Dram, DramConfig, MemStore};
use crate::agents::home::HomeEffect;
use crate::agents::remote::{Access, RemoteAgent, RemoteEffect};
use crate::memctl::KvsService;
use crate::proto::messages::{LineAddr, Message, MsgKind};
use crate::proto::spec::generate_remote;
use crate::proto::states::Node;
use crate::proto::transitions::reference_transitions;
use crate::rustc_hash::{FxHashMap as HashMap, FxHashSet as HashSet};
use crate::sim::engine::Engine;
use crate::sim::rng::Rng;
use crate::sim::stats::{Counters, Histogram};
use crate::sim::time::{Duration, Time};
use crate::transport::Frame;
use crate::workload::zipf::Zipf;

use super::{Dcs, DcsConfig, SliceService};

/// Operation mix, in integer weights (need not sum to 100).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MixConfig {
    pub reads: u32,
    pub writes: u32,
    pub chases: u32,
    /// Dependent hops per pointer-chase operation.
    pub chase_hops: u64,
}

impl Default for MixConfig {
    fn default() -> MixConfig {
        MixConfig { reads: 60, writes: 20, chases: 20, chase_hops: 4 }
    }
}

impl MixConfig {
    /// Sum of the mix weights (the denominator for drawing an op kind;
    /// also used by the `workload` subsystem's per-class samplers).
    pub fn total(&self) -> u32 {
        self.reads + self.writes + self.chases
    }

    /// A read-only mix (scan-style traffic).
    pub fn read_only() -> MixConfig {
        MixConfig { reads: 100, writes: 0, chases: 0, chase_hops: 1 }
    }
}

/// Load-generator parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LoadGenConfig {
    /// Concurrent clients (one outstanding operation each).
    pub clients: usize,
    /// Total operations across all clients.
    pub ops: u64,
    /// Lines in the driven region (addresses 0..region_lines).
    pub region_lines: u64,
    pub mix: MixConfig,
    /// One-way client <-> directory latency (link + protocol engines).
    pub link_latency: Duration,
    /// Client-side processing between dependent chase hops.
    pub hop_think: Duration,
    /// KVS engine-pool size backing chase resolution at the home.
    pub kvs_engines: usize,
    /// Zipf skew of the line-popularity draw (0 = uniform). Ranks are
    /// scattered over the region by a seeded permutation, exactly like
    /// the open-loop scenario classes, so hot lines land on arbitrary
    /// slices.
    pub theta: f64,
    pub seed: u64,
}

impl Default for LoadGenConfig {
    fn default() -> LoadGenConfig {
        LoadGenConfig {
            clients: 32,
            ops: 20_000,
            region_lines: 1 << 14,
            mix: MixConfig::default(),
            link_latency: Duration::from_ns(120),
            hop_think: Duration::from_ns(2),
            kvs_engines: 8,
            theta: 0.0,
            seed: 0xDC5,
        }
    }
}

/// Results of one closed-loop run.
#[derive(Debug)]
pub struct LoadReport {
    pub sim_time: Time,
    pub completed: u64,
    /// Sustained operations per second.
    pub ops_per_s: f64,
    /// Per-operation latency (ps): issue to final fill.
    pub lat: Histogram,
    pub per_slice_served: Vec<u64>,
    pub per_slice_occupancy: Vec<f64>,
    pub counters: Counters,
}

impl LoadReport {
    pub fn p50_ns(&self) -> f64 {
        self.lat.p50() as f64 / 1000.0
    }
    pub fn p99_ns(&self) -> f64 {
        self.lat.p99() as f64 / 1000.0
    }
    /// Deep tail — the headline number of open-loop runs.
    pub fn p999_ns(&self) -> f64 {
        self.lat.p999() as f64 / 1000.0
    }
}

#[derive(Clone, Copy, Debug)]
enum OpKind {
    Read,
    Write,
    /// Remaining dependent hops.
    Chase { left: u64 },
}

#[derive(Debug)]
struct Client {
    rng: Rng,
    op: Option<OpKind>,
    addr: LineAddr,
    started: Time,
}

enum Ev {
    /// Client issues (or retries) its current access.
    Step(u32),
    ArriveHome(Box<Message>),
    ArriveCpu(Box<Message>),
    /// Service attempt on slice `s`.
    Poll(u32),
}

/// The generator: clients + shared remote agent on one side, the dcs +
/// DRAM + KVS engine pool on the other, one event engine in between.
pub struct LoadGen {
    cfg: LoadGenConfig,
    eng: Engine<Ev>,
    dcs: Dcs,
    mem: MemStore,
    dram: Dram,
    kvs: KvsService,
    remote: RemoteAgent,
    cache: Cache,
    clients: Vec<Client>,
    /// Clients parked per line awaiting a fill.
    waiters: HashMap<LineAddr, Vec<u32>>,
    /// Outstanding request ids that belong to chase hops (resolved
    /// through the KVS engine pool at the home).
    chase_ids: HashSet<u32>,
    /// Zipf line-popularity sampler (`theta > 0`) and its rank scatter.
    zipf: Option<Zipf>,
    scatter: Vec<u32>,
    /// Link-frame sequence counter for the framed dcs ingress.
    seq: u64,
    issued: u64,
    completed: u64,
    lat: Histogram,
    counters: Counters,
}

impl LoadGen {
    pub fn new(cfg: LoadGenConfig, dcs_cfg: DcsConfig) -> LoadGen {
        assert!(cfg.clients > 0 && cfg.ops > 0 && cfg.region_lines > 1);
        assert!(cfg.mix.total() > 0, "empty operation mix");
        let mut master = Rng::new(cfg.seed);
        let spec = reference_transitions();

        // Backing store: real bytes, with pointer chains for the chase
        // mix baked in (a random permutation, KVS-entry pointer slot).
        let mut mem = MemStore::new(LineAddr(0), (cfg.region_lines as usize) * 128);
        let mut perm: Vec<u64> = (0..cfg.region_lines).collect();
        master.shuffle(&mut perm);
        for i in 0..cfg.region_lines {
            let mut line = [0u8; 128];
            line[0..8].copy_from_slice(&i.to_le_bytes());
            line[120..128].copy_from_slice(&perm[i as usize].to_le_bytes());
            mem.write_line(LineAddr(i), &line);
        }

        let clients = (0..cfg.clients)
            .map(|c| Client {
                rng: master.fork(c as u64 + 1),
                op: None,
                addr: LineAddr(0),
                started: Time::ZERO,
            })
            .collect();

        let (zipf, scatter) = if cfg.theta > 0.0 {
            let mut r = master.fork(1 << 16);
            let (z, p) = Zipf::scattered(cfg.region_lines, cfg.theta, &mut r);
            (Some(z), p)
        } else {
            (None, Vec::new())
        };

        LoadGen {
            cfg,
            eng: Engine::new(),
            dcs: Dcs::with_reference_rules(dcs_cfg),
            mem,
            dram: Dram::new(DramConfig::fpga_enzian()),
            kvs: KvsService::new(cfg.kvs_engines),
            remote: RemoteAgent::new(Node::Remote, generate_remote(&spec), LineAddr(0), cfg.region_lines),
            // an LLC-like shared cache, sized well below the region so the
            // directory sees steady misses and writebacks
            cache: Cache::new(512 << 10, 8),
            clients,
            waiters: HashMap::default(),
            chase_ids: HashSet::default(),
            zipf,
            scatter,
            seq: 0,
            issued: 0,
            completed: 0,
            lat: Histogram::new(),
            counters: Counters::new(),
        }
    }

    /// Run to completion and report.
    pub fn run(mut self) -> LoadReport {
        for c in 0..self.clients.len() as u32 {
            self.eng.schedule(Duration::ZERO, Ev::Step(c));
        }
        while self.completed < self.cfg.ops {
            let Some((_, ev)) = self.eng.pop() else {
                panic!(
                    "loadgen deadlock: {} of {} ops done, {} queued at dcs, waiters {:?}",
                    self.completed,
                    self.cfg.ops,
                    self.dcs.pending(),
                    self.waiters.keys().take(8).collect::<Vec<_>>()
                );
            };
            match ev {
                Ev::Step(c) => self.step(c),
                Ev::ArriveHome(m) => self.arrive_home(*m),
                Ev::ArriveCpu(m) => self.arrive_cpu(*m),
                Ev::Poll(s) => self.pump_slice(s as usize),
            }
        }
        self.report()
    }

    fn report(mut self) -> LoadReport {
        let sim_time = self.eng.now();
        let n = self.dcs.slices();
        let per_slice_served = (0..n).map(|s| self.dcs.slice_stats(s).served).collect();
        let per_slice_occupancy =
            (0..n).map(|s| self.dcs.slice_stats(s).occupancy(sim_time)).collect();
        let mut counters = self.dcs.counters();
        for (k, v) in self.remote.stats.iter() {
            counters.add(k, v);
        }
        for (k, v) in self.counters.iter() {
            counters.add(k, v);
        }
        counters.add("kvs_lookups", self.kvs.served);
        let ops_per_s = if sim_time.ps() == 0 {
            0.0
        } else {
            self.completed as f64 / sim_time.as_secs()
        };
        LoadReport {
            sim_time,
            completed: self.completed,
            ops_per_s,
            lat: self.lat,
            per_slice_served,
            per_slice_occupancy,
            counters,
        }
    }

    // -- client side --------------------------------------------------------

    /// Draw the next operation for client `c` per the configured mix.
    fn next_op(&mut self, c: u32) {
        let mix = self.cfg.mix;
        let region = self.cfg.region_lines;
        let cl = &mut self.clients[c as usize];
        let t = cl.rng.below(mix.total() as u64) as u32;
        let kind = if t < mix.reads {
            OpKind::Read
        } else if t < mix.reads + mix.writes {
            OpKind::Write
        } else {
            OpKind::Chase { left: mix.chase_hops.max(1) }
        };
        let off = match &self.zipf {
            Some(z) => self.scatter[z.sample(&mut cl.rng) as usize] as u64,
            None => cl.rng.below(region),
        };
        cl.addr = LineAddr(off);
        cl.op = Some(kind);
        cl.started = self.eng.now();
        self.issued += 1;
    }

    /// Issue (or retry after a fill) client `c`'s current access.
    fn step(&mut self, c: u32) {
        if self.clients[c as usize].op.is_none() {
            if self.issued >= self.cfg.ops {
                return; // this client is finished
            }
            self.next_op(c);
        }
        let (addr, write, is_chase) = {
            let cl = &self.clients[c as usize];
            let k = cl.op.expect("op in progress");
            (cl.addr, matches!(k, OpKind::Write), matches!(k, OpKind::Chase { .. }))
        };
        let (acc, fx) = self.remote.local_access(addr, write, &mut self.cache);
        let mut sent = false;
        for e in fx {
            match e {
                RemoteEffect::Send(m) => {
                    if is_chase {
                        if let MsgKind::CohReq { op } = &m.kind {
                            if op.needs_response() {
                                self.chase_ids.insert(m.id.0);
                            }
                        }
                    }
                    self.send_to_home(m);
                    sent = true;
                }
                RemoteEffect::Stalled => {}
                RemoteEffect::Filled { .. } => {}
                RemoteEffect::ForeignVictim(_) => self.counters.inc("foreign_victim"),
            }
        }
        match acc {
            Access::Hit => self.access_done(c),
            Access::Pending => {
                self.waiters.entry(addr).or_default().push(c);
                if !sent {
                    self.counters.inc("mshr_merged");
                }
            }
        }
    }

    /// Client `c`'s access to its current address completed (cache hit or
    /// post-fill retry): advance the operation state machine.
    fn access_done(&mut self, c: u32) {
        let now = self.eng.now();
        let cl = &mut self.clients[c as usize];
        match cl.op.expect("op in progress") {
            OpKind::Write => {
                // dirty the line with an observable stamp (the pointer
                // slot at 120..128 is preserved so chase chains survive)
                if let Some(e) = self.cache.lookup(cl.addr) {
                    e.data[0..8].copy_from_slice(&now.ps().to_le_bytes());
                }
                self.op_done(c);
            }
            OpKind::Read => self.op_done(c),
            OpKind::Chase { left } => {
                if left <= 1 {
                    self.op_done(c);
                    return;
                }
                // decode the next hop from the bytes actually served
                let data = self
                    .cache
                    .peek(cl.addr)
                    .map(|e| *e.data)
                    .unwrap_or_else(|| self.mem.read_line(cl.addr));
                let ptr = u64::from_le_bytes(data[120..128].try_into().unwrap());
                cl.addr = LineAddr(ptr % self.cfg.region_lines);
                cl.op = Some(OpKind::Chase { left: left - 1 });
                let think = self.cfg.hop_think;
                self.eng.schedule(think, Ev::Step(c));
            }
        }
    }

    fn op_done(&mut self, c: u32) {
        let now = self.eng.now();
        let cl = &mut self.clients[c as usize];
        self.lat.record(now.since(cl.started).ps());
        cl.op = None;
        self.completed += 1;
        // closed loop: next operation immediately
        self.eng.schedule(Duration::ZERO, Ev::Step(c));
    }

    fn send_to_home(&mut self, m: Message) {
        self.eng.schedule(self.cfg.link_latency, Ev::ArriveHome(Box::new(m)));
    }

    // -- home side ----------------------------------------------------------

    fn arrive_home(&mut self, m: Message) {
        let now = self.eng.now();
        // frame the arrival so the dcs ingress (and its cross-slice
        // batching, `DcsConfig::batch`) sees the same delivery interface
        // the link-framed open-loop path uses
        let f = Frame::new(self.seq, m);
        self.seq += 1;
        let s = self.dcs.enqueue_frame(now, f);
        self.pump_slice(s);
    }

    /// Drain slice `s` as far as its pipeline allows right now.
    fn pump_slice(&mut self, s: usize) {
        let now = self.eng.now();
        loop {
            match self.dcs.service_one(s, now, &mut self.mem) {
                None => break,
                Some(SliceService::Busy(t)) => {
                    self.eng.schedule_at(t, Ev::Poll(s as u32));
                    break;
                }
                Some(SliceService::Done(ready, _, _, fx)) => self.handle_effects(ready, fx),
            }
        }
    }

    fn handle_effects(&mut self, ready: Time, fx: Vec<HomeEffect>) {
        let link = self.cfg.link_latency;
        for e in fx {
            match e {
                HomeEffect::Respond { msg, from_ram } => {
                    let t = if self.chase_ids.remove(&msg.id.0) {
                        // chase hop: pointer resolution through the KVS
                        // engine pool (dispatcher + dependent granules)
                        self.counters.inc("chase_via_kvs");
                        self.kvs.submit(ready, 1, &mut self.dram)
                    } else if from_ram {
                        self.dram.read(ready, msg.addr)
                    } else {
                        ready
                    };
                    self.eng.schedule_at(t + link, Ev::ArriveCpu(Box::new(msg)));
                }
                HomeEffect::Fwd { msg } => {
                    self.eng.schedule_at(ready + link, Ev::ArriveCpu(Box::new(msg)));
                }
                HomeEffect::RamWrite { addr } => {
                    self.dram.write(ready, addr);
                }
                HomeEffect::LocalDone { .. } => {}
            }
        }
    }

    // -- cpu side -----------------------------------------------------------

    fn arrive_cpu(&mut self, m: Message) {
        let fx = self.remote.on_message(m, &mut self.cache);
        for e in fx {
            match e {
                RemoteEffect::Send(m2) => self.send_to_home(m2),
                RemoteEffect::Filled { addr } => self.wake(addr),
                RemoteEffect::Stalled => {}
                RemoteEffect::ForeignVictim(_) => self.counters.inc("foreign_victim"),
            }
        }
    }

    fn wake(&mut self, addr: LineAddr) {
        let Some(cs) = self.waiters.remove(&addr) else { return };
        for c in cs {
            self.eng.schedule(Duration::ZERO, Ev::Step(c));
        }
    }
}

/// Convenience: run the configured workload against a fresh dcs with
/// `slices` slices.
pub fn run(cfg: LoadGenConfig, dcs_cfg: DcsConfig) -> LoadReport {
    LoadGen::new(cfg, dcs_cfg).run()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small(ops: u64, slices: usize) -> LoadReport {
        let cfg = LoadGenConfig { ops, clients: 8, region_lines: 1 << 15, ..Default::default() };
        run(cfg, DcsConfig::new(slices))
    }

    #[test]
    fn completes_every_operation_and_measures() {
        let r = small(2_000, 2);
        assert_eq!(r.completed, 2_000);
        assert_eq!(r.lat.count(), 2_000);
        assert!(r.ops_per_s > 0.0);
        assert!(r.sim_time > Time(0));
        assert!(r.p99_ns() >= r.p50_ns());
        assert!(r.p999_ns() >= r.p99_ns());
        assert_eq!(r.per_slice_served.len(), 2);
        // both parities are exercised by random addresses
        assert!(r.per_slice_served.iter().all(|&s| s > 0), "{:?}", r.per_slice_served);
    }

    #[test]
    fn deterministic_for_seed() {
        let a = small(1_000, 2);
        let b = small(1_000, 2);
        assert_eq!(a.sim_time, b.sim_time);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.per_slice_served, b.per_slice_served);
    }

    #[test]
    fn chase_hops_resolve_through_kvs_pool() {
        let cfg = LoadGenConfig {
            ops: 500,
            clients: 4,
            region_lines: 1 << 15,
            mix: MixConfig { reads: 0, writes: 0, chases: 1, chase_hops: 4 },
            ..Default::default()
        };
        let r = run(cfg, DcsConfig::new(2));
        assert_eq!(r.completed, 500);
        assert!(r.counters.get("chase_via_kvs") > 0, "{:?}", r.counters);
        assert!(r.counters.get("kvs_lookups") > 0);
        // a 4-hop dependent chase costs several directory round trips
        assert!(r.p50_ns() > 500.0, "chase p50 {}", r.p50_ns());
    }

    #[test]
    fn zipf_theta_concentrates_the_closed_loop_working_set() {
        // In the CLOSED loop the shared client cache sits in front of the
        // directory, so the signature of Zipf skew is absorption: hot
        // draws hit the client cache and far fewer operations reach the
        // slices than under a uniform draw over the same (cache-busting)
        // region. (The open-loop streaming engine, which releases every
        // line, is where skew shows as per-slice load imbalance — see
        // `harness::fig_loadcurve` tests.)
        let probe = |theta: f64| {
            let cfg = LoadGenConfig {
                ops: 4_000,
                clients: 8,
                region_lines: 1 << 14, // 4x the 4096-line client cache
                mix: MixConfig::read_only(),
                theta,
                ..Default::default()
            };
            run(cfg, DcsConfig::new(4))
        };
        let uni = probe(0.0);
        let hot = probe(1.2);
        assert_eq!(uni.completed, 4_000);
        assert_eq!(hot.completed, 4_000);
        let served = |r: &LoadReport| r.per_slice_served.iter().sum::<u64>();
        assert!(
            (served(&hot) as f64) < 0.8 * served(&uni) as f64,
            "zipf 1.2 must be absorbed by the client cache: {} vs uniform {}",
            served(&hot),
            served(&uni)
        );
        // and the same seed gives the same draw stream
        let again = probe(1.2);
        assert_eq!(again.per_slice_served, hot.per_slice_served);
    }

    #[test]
    fn ingress_batching_completes_the_same_workload() {
        let mk = |batch: usize| {
            let cfg = LoadGenConfig { ops: 2_000, clients: 8, region_lines: 1 << 15, ..Default::default() };
            run(cfg, DcsConfig::new(2).with_batch(batch))
        };
        let plain = mk(1);
        let batched = mk(4);
        assert_eq!(plain.completed, 2_000);
        assert_eq!(batched.completed, 2_000);
        // the batched run actually exercised multi-frame deliveries
        assert!(batched.counters.get("ingress_deliveries") > 0);
        assert!(
            batched.counters.get("ingress_batched_frames")
                >= batched.counters.get("ingress_deliveries"),
            "{:?}",
            batched.counters
        );
        assert_eq!(plain.counters.get("ingress_deliveries"), 0, "batch=1 bypasses staging");
    }

    #[test]
    fn cached_slices_raise_hot_read_throughput() {
        // hot-kvs-shaped closed loop at a latency-bound operating point
        // (few clients, enough slices): removing the backing-store round
        // trip from repeat reads must show up as sustained throughput
        let mk = |dcs: DcsConfig| {
            let cfg = LoadGenConfig {
                ops: 4_000,
                clients: 8,
                region_lines: 1 << 13,
                mix: MixConfig { reads: 70, writes: 10, chases: 20, chase_hops: 2 },
                theta: 0.99,
                ..Default::default()
            };
            run(cfg, dcs)
        };
        let plain = mk(DcsConfig::new(4));
        let cached = mk(DcsConfig::cached(4));
        assert!(cached.counters.get("home_cache_hit") > 0, "{:?}", cached.counters);
        assert_eq!(plain.counters.get("home_cache_hit"), 0);
        assert!(
            cached.ops_per_s > plain.ops_per_s,
            "cached slices {} ops/s must beat cache-less {} ops/s",
            cached.ops_per_s,
            plain.ops_per_s
        );
    }

    #[test]
    fn more_slices_never_slow_the_mixed_workload() {
        let rate = |slices| small(4_000, slices).ops_per_s;
        let r1 = rate(1);
        let r2 = rate(2);
        let r4 = rate(4);
        assert!(r2 >= r1 * 0.98, "2 slices {r2} vs 1 {r1}");
        assert!(r4 >= r2 * 0.98, "4 slices {r4} vs 2 {r2}");
    }
}
