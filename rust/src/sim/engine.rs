//! Deterministic discrete-event engine.
//!
//! The engine is generic over the event payload `E`. Components do not own
//! queues or threads; the whole machine is a single-threaded event loop
//! (`Machine::run` in `crate::machine`) that pops `(time, seq, E)` triples in
//! nondecreasing time order and dispatches on the payload enum. Ties are
//! broken by insertion sequence number, which makes runs bit-for-bit
//! reproducible for a given seed and configuration.
//!
//! This "enum dispatch" style (instead of `dyn Component` actors) is chosen
//! deliberately: the modelled topology is fixed (one CPU socket, one ECI
//! link, one FPGA socket), dispatch is a jump table, and the hot loop does
//! no allocation beyond what the payloads themselves carry.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::time::{Duration, Time};

/// A scheduled event: ordered by `(time, seq)`.
struct Scheduled<E> {
    time: Time,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

/// The event queue + simulation clock.
pub struct Engine<E> {
    now: Time,
    seq: u64,
    queue: BinaryHeap<Reverse<Scheduled<E>>>,
    /// Total events dispatched (host-side perf metric).
    pub dispatched: u64,
}

impl<E> Default for Engine<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Engine<E> {
    pub fn new() -> Self {
        Engine {
            now: Time::ZERO,
            seq: 0,
            queue: BinaryHeap::with_capacity(4096),
            dispatched: 0,
        }
    }

    /// Current simulation time.
    #[inline]
    pub fn now(&self) -> Time {
        self.now
    }

    /// Schedule `payload` at absolute time `at`. Panics if `at` is in the
    /// past — causality violations are bugs, not recoverable conditions.
    #[inline]
    pub fn schedule_at(&mut self, at: Time, payload: E) {
        assert!(at >= self.now, "event scheduled in the past: {at} < {}", self.now);
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(Reverse(Scheduled { time: at, seq, payload }));
    }

    /// Schedule `payload` after a delay from now.
    #[inline]
    pub fn schedule(&mut self, after: Duration, payload: E) {
        self.schedule_at(self.now + after, payload);
    }

    /// Pop the next event, advancing the clock to its timestamp.
    #[inline]
    pub fn pop(&mut self) -> Option<(Time, E)> {
        let Reverse(ev) = self.queue.pop()?;
        debug_assert!(ev.time >= self.now);
        self.now = ev.time;
        self.dispatched += 1;
        Some((ev.time, ev.payload))
    }

    /// Peek at the timestamp of the next event without popping.
    #[inline]
    pub fn peek_time(&self) -> Option<Time> {
        self.queue.peek().map(|Reverse(ev)| ev.time)
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order() {
        let mut e: Engine<u32> = Engine::new();
        e.schedule_at(Time(30), 3);
        e.schedule_at(Time(10), 1);
        e.schedule_at(Time(20), 2);
        let order: Vec<u32> = std::iter::from_fn(|| e.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec![1, 2, 3]);
        assert_eq!(e.now(), Time(30));
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut e: Engine<u32> = Engine::new();
        for i in 0..100 {
            e.schedule_at(Time(5), i);
        }
        let order: Vec<u32> = std::iter::from_fn(|| e.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn relative_scheduling_uses_current_time() {
        let mut e: Engine<&'static str> = Engine::new();
        e.schedule(Duration::from_ns(5), "a");
        let (t, _) = e.pop().unwrap();
        assert_eq!(t, Time(5_000));
        e.schedule(Duration::from_ns(5), "b");
        let (t, _) = e.pop().unwrap();
        assert_eq!(t, Time(10_000));
    }

    #[test]
    #[should_panic]
    fn scheduling_in_the_past_panics() {
        let mut e: Engine<u32> = Engine::new();
        e.schedule_at(Time(10), 1);
        e.pop();
        e.schedule_at(Time(5), 2);
    }

    #[test]
    fn dispatched_counter() {
        let mut e: Engine<u32> = Engine::new();
        e.schedule_at(Time(1), 0);
        e.schedule_at(Time(2), 0);
        while e.pop().is_some() {}
        assert_eq!(e.dispatched, 2);
    }
}
