//! Simulation time.
//!
//! Time is measured in integer **picoseconds** so that every clock domain in
//! the modelled system has an exact integer period:
//!
//! * ThunderX-1 cores @ 2.0 GHz  -> 500 ps
//! * FPGA fabric      @ 300 MHz  -> 3_333 ps (we round to 3_333; the ~0.01%
//!   error is far below the fidelity of the model)
//! * DDR4-2133 / DDR4-2400 IO clocks, ECI serial lanes, ... all fit.
//!
//! `Time` is an absolute instant, `Duration` a span. Both are thin wrappers
//! over `u64`; arithmetic saturates on overflow in release builds would be a
//! silent error, so we use checked/panicking ops (a simulation running past
//! ~213 days of simulated time is a bug).

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

pub const PS_PER_NS: u64 = 1_000;
pub const PS_PER_US: u64 = 1_000_000;
pub const PS_PER_MS: u64 = 1_000_000_000;
pub const PS_PER_S: u64 = 1_000_000_000_000;

/// An absolute simulation instant, in picoseconds since t=0.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Time(pub u64);

/// A span of simulated time, in picoseconds.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Duration(pub u64);

impl Time {
    pub const ZERO: Time = Time(0);

    #[inline]
    pub fn ps(self) -> u64 {
        self.0
    }
    #[inline]
    pub fn as_ns(self) -> f64 {
        self.0 as f64 / PS_PER_NS as f64
    }
    #[inline]
    pub fn as_us(self) -> f64 {
        self.0 as f64 / PS_PER_US as f64
    }
    #[inline]
    pub fn as_secs(self) -> f64 {
        self.0 as f64 / PS_PER_S as f64
    }
    /// Duration since an earlier instant. Panics if `earlier` is later.
    #[inline]
    pub fn since(self, earlier: Time) -> Duration {
        Duration(
            self.0
                .checked_sub(earlier.0)
                .expect("Time::since: earlier instant is in the future"),
        )
    }
}

impl Duration {
    pub const ZERO: Duration = Duration(0);

    #[inline]
    pub const fn from_ps(ps: u64) -> Duration {
        Duration(ps)
    }
    #[inline]
    pub const fn from_ns(ns: u64) -> Duration {
        Duration(ns * PS_PER_NS)
    }
    #[inline]
    pub const fn from_us(us: u64) -> Duration {
        Duration(us * PS_PER_US)
    }
    #[inline]
    pub const fn from_ms(ms: u64) -> Duration {
        Duration(ms * PS_PER_MS)
    }
    /// Duration from a (possibly fractional) nanosecond count.
    #[inline]
    pub fn from_ns_f64(ns: f64) -> Duration {
        assert!(ns >= 0.0, "negative duration");
        Duration((ns * PS_PER_NS as f64).round() as u64)
    }
    #[inline]
    pub fn ps(self) -> u64 {
        self.0
    }
    #[inline]
    pub fn as_ns(self) -> f64 {
        self.0 as f64 / PS_PER_NS as f64
    }
    #[inline]
    pub fn as_secs(self) -> f64 {
        self.0 as f64 / PS_PER_S as f64
    }
    #[inline]
    pub fn saturating_sub(self, rhs: Duration) -> Duration {
        Duration(self.0.saturating_sub(rhs.0))
    }
    /// Scale by an integer factor.
    #[inline]
    pub fn times(self, n: u64) -> Duration {
        Duration(self.0.checked_mul(n).expect("Duration overflow"))
    }
}

/// A fixed clock domain: integer period in picoseconds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Clock {
    pub period: Duration,
}

impl Clock {
    /// Clock from a frequency in Hz (rounded to the nearest picosecond).
    pub fn from_hz(hz: f64) -> Clock {
        assert!(hz > 0.0);
        Clock {
            period: Duration((PS_PER_S as f64 / hz).round() as u64),
        }
    }
    pub fn from_mhz(mhz: f64) -> Clock {
        Clock::from_hz(mhz * 1e6)
    }
    pub fn from_ghz(ghz: f64) -> Clock {
        Clock::from_hz(ghz * 1e9)
    }
    /// Span of `n` cycles.
    #[inline]
    pub fn cycles(self, n: u64) -> Duration {
        self.period.times(n)
    }
    /// The next clock edge at or after `t`.
    #[inline]
    pub fn next_edge(self, t: Time) -> Time {
        let p = self.period.0;
        let rem = t.0 % p;
        if rem == 0 {
            t
        } else {
            Time(t.0 + (p - rem))
        }
    }
    /// Frequency in Hz implied by the (rounded) period.
    pub fn hz(self) -> f64 {
        PS_PER_S as f64 / self.period.0 as f64
    }
}

impl Add<Duration> for Time {
    type Output = Time;
    #[inline]
    fn add(self, rhs: Duration) -> Time {
        Time(self.0.checked_add(rhs.0).expect("Time overflow"))
    }
}
impl AddAssign<Duration> for Time {
    #[inline]
    fn add_assign(&mut self, rhs: Duration) {
        *self = *self + rhs;
    }
}
impl Sub<Time> for Time {
    type Output = Duration;
    #[inline]
    fn sub(self, rhs: Time) -> Duration {
        self.since(rhs)
    }
}
impl Add<Duration> for Duration {
    type Output = Duration;
    #[inline]
    fn add(self, rhs: Duration) -> Duration {
        Duration(self.0.checked_add(rhs.0).expect("Duration overflow"))
    }
}
impl AddAssign<Duration> for Duration {
    #[inline]
    fn add_assign(&mut self, rhs: Duration) {
        *self = *self + rhs;
    }
}
impl Sub<Duration> for Duration {
    type Output = Duration;
    #[inline]
    fn sub(self, rhs: Duration) -> Duration {
        Duration(
            self.0
                .checked_sub(rhs.0)
                .expect("Duration underflow"),
        )
    }
}

impl fmt::Debug for Time {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}ns", self.as_ns())
    }
}
impl fmt::Display for Time {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.3}ns", self.as_ns())
    }
}
impl fmt::Debug for Duration {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}ns", self.as_ns())
    }
}
impl fmt::Display for Duration {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.3}ns", self.as_ns())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_periods_are_exact_for_model_domains() {
        assert_eq!(Clock::from_ghz(2.0).period.ps(), 500);
        assert_eq!(Clock::from_mhz(300.0).period.ps(), 3_333);
        // DDR4-2133 IO clock 1066.5 MHz — period rounds to 938 ps; the
        // sub-0.1% rounding error is far below model fidelity.
        let ddr = Clock::from_mhz(1066.5);
        assert!((ddr.hz() - 1.0665e9).abs() / 1.0665e9 < 1e-3);
    }

    #[test]
    fn next_edge_aligns() {
        let c = Clock { period: Duration(500) };
        assert_eq!(c.next_edge(Time(0)), Time(0));
        assert_eq!(c.next_edge(Time(1)), Time(500));
        assert_eq!(c.next_edge(Time(500)), Time(500));
        assert_eq!(c.next_edge(Time(501)), Time(1000));
    }

    #[test]
    fn arithmetic() {
        let t = Time(1000) + Duration::from_ns(2);
        assert_eq!(t, Time(3000));
        assert_eq!(t - Time(1000), Duration(2000));
        assert_eq!(Duration::from_ns(3).times(4), Duration(12_000));
        assert_eq!(Duration::from_ns_f64(1.5), Duration(1500));
    }

    #[test]
    #[should_panic]
    fn since_panics_on_negative() {
        let _ = Time(5).since(Time(10));
    }
}
