//! Bandwidth/occupancy modelling primitives.
//!
//! Two recurring patterns in the machine model:
//!
//! * A **serial resource** (DRAM channel data bus, ECI lane, operator
//!   pipeline issue port): requests occupy it back-to-back; the next
//!   transfer starts no earlier than the previous one finished. Modelled by
//!   [`SerialPort`], which returns the *completion time* of each transfer
//!   and accounts utilization.
//!
//! * A **token-bucket shaper** for coarse-grained rate limits where
//!   per-transfer serialization is not worth modelling.

use super::time::{Duration, Time};

/// A serially-occupied resource with a fixed per-byte cost and optional
/// fixed per-transfer overhead.
#[derive(Clone, Debug)]
pub struct SerialPort {
    /// picoseconds per byte (inverse bandwidth)
    ps_per_byte: f64,
    /// fixed serialization overhead per transfer
    overhead: Duration,
    /// the port is busy until this instant
    free_at: Time,
    /// total busy picoseconds (for utilization reporting)
    busy_ps: u64,
    /// total bytes moved
    pub bytes: u64,
}

impl SerialPort {
    /// `bytes_per_sec` is the raw bandwidth of the resource.
    pub fn new(bytes_per_sec: f64, overhead: Duration) -> Self {
        assert!(bytes_per_sec > 0.0);
        SerialPort {
            ps_per_byte: 1e12 / bytes_per_sec,
            overhead,
            free_at: Time::ZERO,
            busy_ps: 0,
            bytes: 0,
        }
    }

    pub fn bytes_per_sec(&self) -> f64 {
        1e12 / self.ps_per_byte
    }

    /// Time the port next becomes free.
    pub fn free_at(&self) -> Time {
        self.free_at
    }

    /// Occupy the port for a `len`-byte transfer arriving at `now`.
    /// Returns the completion time. The transfer begins at
    /// `max(now, free_at)` — i.e. transfers queue FIFO.
    pub fn occupy(&mut self, now: Time, len: u64) -> Time {
        let start = if now > self.free_at { now } else { self.free_at };
        let ser = Duration((len as f64 * self.ps_per_byte).round() as u64) + self.overhead;
        self.free_at = start + ser;
        self.busy_ps += ser.ps();
        self.bytes += len;
        self.free_at
    }

    /// Queueing delay a transfer arriving `now` would see before starting.
    pub fn backlog(&self, now: Time) -> Duration {
        if self.free_at > now {
            self.free_at.since(now)
        } else {
            Duration::ZERO
        }
    }

    /// Utilization over `[0, now]`.
    pub fn utilization(&self, now: Time) -> f64 {
        if now.ps() == 0 {
            0.0
        } else {
            (self.busy_ps as f64 / now.ps() as f64).min(1.0)
        }
    }
}

/// Token bucket: capacity `burst` bytes, refilled at `rate` bytes/sec.
#[derive(Clone, Debug)]
pub struct TokenBucket {
    rate: f64, // bytes per picosecond
    burst: f64,
    tokens: f64,
    last: Time,
}

impl TokenBucket {
    pub fn new(bytes_per_sec: f64, burst_bytes: f64) -> Self {
        TokenBucket {
            rate: bytes_per_sec / 1e12,
            burst: burst_bytes,
            tokens: burst_bytes,
            last: Time::ZERO,
        }
    }

    fn refill(&mut self, now: Time) {
        let dt = now.since(self.last).ps() as f64;
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        self.last = now;
    }

    /// Try to consume `n` bytes at `now`; on failure returns the earliest
    /// time at which the tokens would be available.
    pub fn consume(&mut self, now: Time, n: u64) -> Result<(), Time> {
        self.refill(now);
        let need = n as f64;
        if self.tokens >= need {
            self.tokens -= need;
            Ok(())
        } else {
            let deficit = need - self.tokens;
            let wait_ps = (deficit / self.rate).ceil() as u64;
            Err(now + Duration(wait_ps))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::time::PS_PER_S;

    #[test]
    fn serial_port_serializes() {
        // 1 GiB/s, no overhead; 1024 bytes take ~0.954 us
        let mut p = SerialPort::new((1u64 << 30) as f64, Duration::ZERO);
        let t1 = p.occupy(Time(0), 1024);
        let t2 = p.occupy(Time(0), 1024); // queued behind t1
        assert_eq!(t2.ps(), 2 * t1.ps());
        // arriving after the port idles starts immediately
        let t3 = p.occupy(Time(10 * t2.ps()), 1024);
        assert_eq!(t3.ps() - 10 * t2.ps(), t1.ps());
    }

    #[test]
    fn serial_port_overhead_and_utilization() {
        let mut p = SerialPort::new(1e9, Duration::from_ns(10));
        let done = p.occupy(Time(0), 1000); // 1 us + 10 ns
        assert_eq!(done.ps(), 1_010_000);
        let u = p.utilization(Time(2_020_000));
        assert!((u - 0.5).abs() < 1e-9);
    }

    #[test]
    fn backlog_reports_queue_delay() {
        let mut p = SerialPort::new(1e9, Duration::ZERO);
        p.occupy(Time(0), 2000); // busy 2 us
        assert_eq!(p.backlog(Time(500_000)).ps(), 1_500_000);
        assert_eq!(p.backlog(Time(3_000_000)).ps(), 0);
    }

    #[test]
    fn token_bucket_paces() {
        let mut tb = TokenBucket::new(1e9, 1000.0); // 1 GB/s, 1000-byte burst
        assert!(tb.consume(Time(0), 1000).is_ok());
        // bucket empty: 500 more bytes need 500 ns
        match tb.consume(Time(0), 500) {
            Err(at) => assert_eq!(at.ps(), 500 * 1000),
            Ok(_) => panic!("should have been rate-limited"),
        }
        // after 1 us, enough tokens again (capped at burst)
        assert!(tb.consume(Time(PS_PER_S / 1_000_000), 1000).is_ok());
    }
}
