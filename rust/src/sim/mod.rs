//! Discrete-event simulation substrate.
//!
//! The paper evaluates ECI on physical hardware (Enzian). Lacking that
//! hardware, every experiment in this repo runs on the deterministic,
//! execution-driven simulator built from these primitives: a picosecond
//! clock ([`time`]), an event engine ([`engine`]), a seedable PRNG
//! ([`rng`]), measurement types ([`stats`]), and bandwidth/occupancy models
//! ([`bw`]). See DESIGN.md §1 for the substitution argument.

pub mod bw;
pub mod engine;
pub mod rng;
pub mod stats;
pub mod time;

pub use bw::{SerialPort, TokenBucket};
pub use engine::Engine;
pub use rng::Rng;
pub use stats::{Counters, Histogram, Meter};
pub use time::{Clock, Duration, Time};
