//! Deterministic PRNG for workload generation.
//!
//! The offline registry has no `rand` crate, so we carry our own
//! xoshiro256** implementation (Blackman & Vigna). It is not cryptographic
//! and does not need to be: it drives workload generation and error
//! injection, where the requirements are reproducibility, speed, and decent
//! equidistribution.

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Derive a fault-injection seed for one directed link from a base
/// seed and the link's coordinates. `kind` tags the link family (1 =
/// node↔client links, 2 = inter-node fabric channels), `idx` the link
/// within the family, `dir` the direction (0/1). The coordinates are
/// packed into disjoint bit ranges and mixed through splitmix64 — a
/// bijection on `u64` — so for a fixed base seed, distinct
/// `(kind, idx, dir)` triples are *guaranteed* distinct seeds, unlike
/// the affine `seed + 2*idx` schemes this replaces, where different
/// families could collide and see correlated fault patterns.
#[inline]
pub fn stream_seed(base: u64, kind: u64, idx: u64, dir: u64) -> u64 {
    debug_assert!(kind > 0 && kind < 1 << 8, "kind tag out of range");
    debug_assert!(idx < 1 << 32, "link index out of range");
    debug_assert!(dir < 2, "direction must be 0 or 1");
    let mut packed = base ^ ((kind << 40) | (idx << 1) | dir);
    splitmix64(&mut packed)
}

impl Rng {
    /// Seed the generator. Any seed (including 0) is valid; the state is
    /// expanded through splitmix64 as the xoshiro authors recommend.
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-component RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` using Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        // 128-bit multiply; bias is rejected.
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Geometric-ish exponential sample with mean `mean` (for inter-arrival
    /// times in open-loop workloads).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = self.f64().max(1e-12);
        -mean * u.ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let mut buckets = [0usize; 10];
        for _ in 0..100_000 {
            let v = r.below(10);
            assert!(v < 10);
            buckets[v as usize] += 1;
        }
        for &b in &buckets {
            // each bucket expects 10_000; allow 10% slack
            assert!((9_000..=11_000).contains(&b), "bucket count {b}");
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn stream_seeds_are_distinct_across_kinds_and_indices() {
        // splitmix64 is a bijection, so distinct packed coordinates map
        // to distinct seeds — verify the packing itself is injective
        // over a realistic link population (two kinds, many indices,
        // both directions) and stable across a couple of base seeds.
        for base in [0u64, 7, u64::MAX / 3] {
            let mut seen = std::collections::HashSet::new();
            for kind in 1..=2u64 {
                for idx in 0..64u64 {
                    for dir in 0..2u64 {
                        assert!(
                            seen.insert(stream_seed(base, kind, idx, dir)),
                            "collision at base {base} kind {kind} idx {idx} dir {dir}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn exp_mean_is_close() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let sum: f64 = (0..n).map(|_| r.exp(100.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 100.0).abs() < 2.0, "mean {mean}");
    }
}
