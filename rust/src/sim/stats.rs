//! Measurement primitives: counters, histograms, rate meters.
//!
//! Every experiment in the harness reports through these types so that the
//! CSV/markdown emitters have a single source of truth. Histograms are
//! log-linear (HdrHistogram-style, base-2 buckets with 16 sub-buckets) which
//! keeps relative error under ~6% across the ns..s range without
//! preallocating millions of bins.

use std::collections::BTreeMap;
use std::fmt;

use super::time::{Duration, Time};

/// Monotonic named counters.
#[derive(Default, Clone)]
pub struct Counters {
    map: BTreeMap<&'static str, u64>,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }
    #[inline]
    pub fn add(&mut self, key: &'static str, n: u64) {
        *self.map.entry(key).or_insert(0) += n;
    }
    #[inline]
    pub fn inc(&mut self, key: &'static str) {
        self.add(key, 1);
    }
    pub fn get(&self, key: &str) -> u64 {
        self.map.get(key).copied().unwrap_or(0)
    }
    pub fn iter(&self) -> impl Iterator<Item = (&'static str, u64)> + '_ {
        self.map.iter().map(|(k, v)| (*k, *v))
    }
}

impl fmt::Debug for Counters {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_map().entries(self.map.iter()).finish()
    }
}

const SUB_BUCKET_BITS: u32 = 4; // 16 sub-buckets per power of two
const SUB_BUCKETS: u64 = 1 << SUB_BUCKET_BITS;

/// Log-linear histogram of u64 samples (typically picoseconds or bytes).
#[derive(Clone)]
pub struct Histogram {
    bins: Vec<u64>,
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

#[inline]
fn bin_index(v: u64) -> usize {
    if v < SUB_BUCKETS {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros();
    let bucket = msb - SUB_BUCKET_BITS + 1;
    let sub = (v >> (bucket - 1)) - SUB_BUCKETS;
    (SUB_BUCKETS as usize) + (bucket as usize - 1) * SUB_BUCKETS as usize + sub as usize
}

/// Lower edge of bin `i` (inverse of `bin_index`, up to bucket resolution).
fn bin_floor(i: usize) -> u64 {
    let i = i as u64;
    if i < SUB_BUCKETS {
        return i;
    }
    let rel = i - SUB_BUCKETS;
    let bucket = rel / SUB_BUCKETS + 1;
    let sub = rel % SUB_BUCKETS + SUB_BUCKETS;
    sub << (bucket - 1)
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            bins: Vec::new(),
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        let idx = bin_index(v);
        if idx >= self.bins.len() {
            self.bins.resize(idx + 1, 0);
        }
        self.bins[idx] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    #[inline]
    pub fn record_dur(&mut self, d: Duration) {
        self.record(d.ps());
    }

    pub fn count(&self) -> u64 {
        self.count
    }
    pub fn min(&self) -> u64 {
        if self.count == 0 { 0 } else { self.min }
    }
    pub fn max(&self) -> u64 {
        self.max
    }
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Approximate quantile (0.0 ..= 1.0), resolved to the *midpoint* of
    /// the winning bin (clamped to the observed min/max). The midpoint
    /// halves the worst-case bias of reporting the bin floor: samples
    /// land anywhere in `[floor(i), floor(i+1))`, so the floor
    /// systematically under-reports by up to one sub-bucket width while
    /// the midpoint is off by at most half of one.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0;
        for (i, &c) in self.bins.iter().enumerate() {
            seen += c;
            if seen >= target {
                let lo = bin_floor(i);
                let hi = bin_floor(i + 1);
                let mid = lo + (hi - lo) / 2;
                return mid.max(self.min).min(self.max);
            }
        }
        self.max
    }

    /// Occupied bins as `(lower_edge, upper_edge, count)` triples —
    /// the JSON export surface for full-distribution dumps.
    pub fn bins(&self) -> Vec<(u64, u64, u64)> {
        self.bins
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (bin_floor(i), bin_floor(i + 1), c))
            .collect()
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }
    /// Deep-tail quantile: open-loop experiments report p999 because the
    /// far tail is where queueing delay first becomes visible.
    pub fn p999(&self) -> u64 {
        self.quantile(0.999)
    }

    pub fn merge(&mut self, other: &Histogram) {
        if other.bins.len() > self.bins.len() {
            self.bins.resize(other.bins.len(), 0);
        }
        for (i, &c) in other.bins.iter().enumerate() {
            self.bins[i] += c;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

impl fmt::Debug for Histogram {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "Histogram(n={}, mean={:.1}, p50={}, p99={}, max={})",
            self.count,
            self.mean(),
            self.p50(),
            self.p99(),
            self.max
        )
    }
}

/// Accumulates (bytes | items) over simulated time and reports rates.
#[derive(Clone, Debug, Default)]
pub struct Meter {
    pub total: u64,
    start: Option<Time>,
    end: Option<Time>,
}

impl Meter {
    pub fn new() -> Self {
        Self::default()
    }
    #[inline]
    pub fn add(&mut self, now: Time, n: u64) {
        if self.start.is_none() {
            self.start = Some(now);
        }
        self.end = Some(now);
        self.total += n;
    }
    pub fn window(&self) -> Duration {
        match (self.start, self.end) {
            (Some(s), Some(e)) => e.since(s),
            _ => Duration::ZERO,
        }
    }
    /// Rate in units/second over the observed window (or over `total_time`
    /// if provided, which is correct for closed-loop experiments).
    pub fn rate(&self, over: Option<Duration>) -> f64 {
        let secs = over.unwrap_or_else(|| self.window()).as_secs();
        if secs <= 0.0 {
            0.0
        } else {
            self.total as f64 / secs
        }
    }
    /// Rate expressed in GiB/s when `total` counts bytes.
    pub fn gib_per_s(&self, over: Option<Duration>) -> f64 {
        self.rate(over) / (1u64 << 30) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters() {
        let mut c = Counters::new();
        c.inc("msgs");
        c.add("msgs", 4);
        c.inc("errs");
        assert_eq!(c.get("msgs"), 5);
        assert_eq!(c.get("errs"), 1);
        assert_eq!(c.get("nothing"), 0);
    }

    #[test]
    fn bin_index_monotone_and_invertible_enough() {
        let mut last = 0usize;
        for v in [0u64, 1, 15, 16, 17, 31, 32, 100, 1000, 1 << 20, u64::MAX >> 1] {
            let i = bin_index(v);
            assert!(i >= last, "index not monotone at {v}");
            last = i;
            let floor = bin_floor(i);
            assert!(floor <= v, "floor {floor} > value {v}");
            // relative error bounded by sub-bucket width
            if v >= 16 {
                assert!((v - floor) as f64 / v as f64 <= 1.0 / 16.0 + 1e-9);
            }
        }
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 1000);
        let p50 = h.p50();
        assert!((450..=550).contains(&p50), "p50 {p50}");
        let p99 = h.p99();
        assert!((930..=1000).contains(&p99), "p99 {p99}");
        let p999 = h.p999();
        assert!((930..=1000).contains(&p999), "p999 {p999}");
        assert!(p999 >= p99, "p999 {p999} < p99 {p99}");
        assert!((h.mean() - 500.5).abs() < 1.0);
    }

    #[test]
    fn quantiles_of_uniform_ramp_land_within_sub_bucket_tolerance() {
        // A uniform ramp has known exact quantiles; midpoint resolution
        // must land within one sub-bucket width (1/16 relative) of the
        // true value — the bin-floor behavior this replaces was biased
        // low by up to a full sub-bucket.
        let n = 100_000u64;
        let mut h = Histogram::new();
        for v in 1..=n {
            h.record(v);
        }
        for q in [0.10, 0.25, 0.50, 0.75, 0.90, 0.99, 0.999] {
            let exact = (q * n as f64).max(1.0);
            let got = h.quantile(q) as f64;
            let tol = exact / 16.0 + 1.0;
            assert!(
                (got - exact).abs() <= tol,
                "q={q}: got {got}, exact {exact}, tol {tol}"
            );
        }
        // quantiles stay within the observed range and monotone in q
        assert!(h.quantile(0.0) >= h.min());
        assert!(h.quantile(1.0) <= h.max());
        assert!(h.p50() <= h.p99() && h.p99() <= h.p999());
    }

    #[test]
    fn bins_export_covers_every_sample() {
        let mut h = Histogram::new();
        for v in [1u64, 1, 2, 100, 5000] {
            h.record(v);
        }
        let bins = h.bins();
        let total: u64 = bins.iter().map(|&(_, _, c)| c).sum();
        assert_eq!(total, h.count());
        for &(lo, hi, c) in &bins {
            assert!(lo < hi);
            assert!(c > 0);
        }
        // edges are sorted and disjoint
        for w in bins.windows(2) {
            assert!(w[0].1 <= w[1].0);
        }
        assert!(Histogram::new().bins().is_empty());
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for v in 0..500 {
            a.record(v);
        }
        for v in 500..1000 {
            b.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), 1000);
        assert_eq!(a.max(), 999);
    }

    /// merge(a, b) must be indistinguishable from a histogram built
    /// from the union of the two sample streams — every exposed
    /// statistic, including the log-linear bin contents, across
    /// mismatched bin-array lengths in both merge directions.
    #[test]
    fn histogram_merge_matches_union() {
        let small: Vec<u64> = (0..300).map(|i| 3 * i + 1).collect();
        let huge: Vec<u64> = (0..40).map(|i| (1u64 << 40) + (i << 22)).collect();
        let check = |xs: &[u64], ys: &[u64]| {
            let mut m = Histogram::new();
            let mut union = Histogram::new();
            let mut other = Histogram::new();
            for &v in xs {
                m.record(v);
                union.record(v);
            }
            for &v in ys {
                other.record(v);
                union.record(v);
            }
            m.merge(&other);
            assert_eq!(m.count(), union.count());
            assert_eq!(m.min(), union.min());
            assert_eq!(m.max(), union.max());
            assert_eq!(m.bins(), union.bins());
            assert!((m.mean() - union.mean()).abs() < 1e-9);
            for q in [0.5, 0.9, 0.99, 0.999] {
                assert_eq!(m.quantile(q), union.quantile(q), "q={q}");
            }
        };
        // small-into-large forces the bin resize; large-into-small
        // exercises the already-long side; empty on either side is the
        // per-node-report edge (a node that completed nothing)
        check(&small, &huge);
        check(&huge, &small);
        check(&small, &[]);
        check(&[], &huge);
    }

    #[test]
    fn meter_rates() {
        let mut m = Meter::new();
        m.add(Time(0), 0);
        m.add(Time(crate::sim::time::PS_PER_S), 1 << 30); // 1 GiB over 1 s
        assert!((m.gib_per_s(None) - 1.0).abs() < 1e-9);
        assert!((m.rate(Some(Duration::from_ms(500))) - 2.0 * (1u64 << 30) as f64).abs() < 1.0);
    }
}
