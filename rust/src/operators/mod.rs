//! The three near-memory operators the paper offloads (§5.4–§5.6), their
//! CPU baselines, the workload generators, and the runtime regex->DFA
//! compiler. Functional datapaths live here (execution-driven, checkable
//! results); the timing models are applied by [`crate::memctl`] and
//! [`crate::machine`].

pub mod kvs;
pub mod redfa;
pub mod regex_op;
pub mod select;
pub mod table;

pub use redfa::{compile_regex, Dfa};
pub use table::{build_kvs, build_table, select_params, KvsLayout, KvsSpec, TableSpec};
