//! Workload generators: the row table (SELECT + regex, §5.4/§5.6) and the
//! key-value store (§5.5), laid out in simulated FPGA DRAM exactly as the
//! operators and the AOT kernels expect.
//!
//! ## Row ABI (shared with `python/compile/kernels/ref.py`)
//!
//! A row is one 128-byte cache line:
//!
//! ```text
//! bytes   0..4    f32 a        (SELECT attribute)
//! bytes   4..8    f32 b        (SELECT attribute)
//! bytes   8..64   payload (deterministic filler)
//! bytes  64..126  62-byte string field (regex operator)
//! bytes 126..128  pad (zero)
//! ```
//!
//! ## KVS entry ABI
//!
//! One 128-byte line per entry: `u64 key | 112 B value | u64 next`
//! (`next` = line address of the chain successor, `NULL_PTR` ends the
//! chain). Buckets are a dense array of 8-byte head pointers at the start
//! of the region (16 per line).

use crate::proto::messages::{LineAddr, LINE_BYTES};
use crate::runtime::hash_bucket_ref;
use crate::sim::rng::Rng;

use crate::agents::dram::MemStore;

/// Paper table size: 5,120,000 rows x 128 B = 655 MB (§5.4).
pub const PAPER_ROWS: u64 = 5_120_000;

pub const STR_OFFSET: usize = 64;
pub const STR_LEN: usize = 62;

/// Table generation parameters.
#[derive(Clone, Debug)]
pub struct TableSpec {
    pub rows: u64,
    /// Fraction of rows satisfying the SELECT predicate (`a > X AND b < Y`
    /// with the canonical X=0.5, Y=0.5 — see `select_params`).
    pub select_selectivity: f64,
    /// Fraction of rows whose string field contains the planted regex
    /// needle.
    pub regex_selectivity: f64,
    /// The needle planted for the regex experiments.
    pub needle: String,
    pub seed: u64,
}

impl TableSpec {
    pub fn new(rows: u64, selectivity: f64) -> TableSpec {
        TableSpec {
            rows,
            select_selectivity: selectivity,
            regex_selectivity: selectivity,
            needle: "erro+r".into(),
            seed: 0xEC1,
        }
    }
    /// A planted string that `needle`'s canonical pattern matches.
    pub fn planted(&self) -> &'static [u8] {
        b"xjq errooor kz"
    }
}

/// Canonical SELECT parameters: with `a`, `b` uniform in [0,1), selectivity
/// s is achieved by a > X(s), b unconstrained-ish: we use
/// X = 1 - sqrt(s), Y = sqrt(s) so P(a>X) * P(b<Y) = s.
pub fn select_params(selectivity: f64) -> (f32, f32) {
    let r = selectivity.sqrt();
    ((1.0 - r) as f32, r as f32)
}

/// Build the table in `store` starting at its base. Rows are generated so
/// the *realized* selectivities equal the spec's (deterministic
/// assignment, shuffled), not merely in expectation.
pub fn build_table(spec: &TableSpec, store: &mut MemStore) {
    assert!(store.len_lines() >= spec.rows, "store too small for table");
    let mut rng = Rng::new(spec.seed);
    let (x, y) = select_params(spec.select_selectivity);

    // exact selectivity: first k rows match, then shuffle the flags
    let k_sel = (spec.rows as f64 * spec.select_selectivity).round() as u64;
    let k_re = (spec.rows as f64 * spec.regex_selectivity).round() as u64;
    let mut sel_flags: Vec<bool> = (0..spec.rows).map(|i| i < k_sel).collect();
    let mut re_flags: Vec<bool> = (0..spec.rows).map(|i| i < k_re).collect();
    rng.shuffle(&mut sel_flags);
    rng.shuffle(&mut re_flags);

    let base = store.base();
    let bytes = store.bytes_mut();
    let alphabet = b"abcdefghijklmnopqrstuvwxyz 0123456789";
    for i in 0..spec.rows {
        let off = ((LineAddr(base.0 + i).0 - base.0) as usize) * LINE_BYTES;
        let row = &mut bytes[off..off + LINE_BYTES];
        // SELECT attributes
        let (a, b) = if sel_flags[i as usize] {
            // a > x, b < y
            (
                x + rng.f64() as f32 * (1.0 - x),
                rng.f64() as f32 * y,
            )
        } else {
            // miss: force a <= x (uniform below the threshold)
            (rng.f64() as f32 * x, rng.f64() as f32)
        };
        row[0..4].copy_from_slice(&a.to_le_bytes());
        row[4..8].copy_from_slice(&b.to_le_bytes());
        // filler payload
        for w in 2..16 {
            let v = (i as u32).wrapping_mul(2654435761).wrapping_add(w as u32);
            row[w * 4..w * 4 + 4].copy_from_slice(&v.to_le_bytes());
        }
        // string field
        let s = &mut row[STR_OFFSET..STR_OFFSET + STR_LEN];
        for c in s.iter_mut() {
            *c = *rng.choose(alphabet);
        }
        if re_flags[i as usize] {
            let needle = b"xjq errooor kz";
            let pos = rng.below((STR_LEN - needle.len()) as u64 + 1) as usize;
            s[pos..pos + needle.len()].copy_from_slice(needle);
        } else {
            // ensure no accidental match: the needle family requires
            // "err"; break every occurrence of "rr"
            for j in 0..STR_LEN - 1 {
                if s[j] == b'r' && s[j + 1] == b'r' {
                    s[j + 1] = b'q';
                }
            }
        }
        row[126] = 0;
        row[127] = 0;
    }
}

/// Read row attributes (CPU-baseline scan path).
#[inline]
pub fn row_ab(line: &[u8; LINE_BYTES]) -> (f32, f32) {
    (
        f32::from_le_bytes(line[0..4].try_into().unwrap()),
        f32::from_le_bytes(line[4..8].try_into().unwrap()),
    )
}

#[inline]
pub fn row_str(line: &[u8; LINE_BYTES]) -> &[u8] {
    &line[STR_OFFSET..STR_OFFSET + STR_LEN]
}

// ---------------------------------------------------------------------------
// KVS
// ---------------------------------------------------------------------------

pub const NULL_PTR: u64 = u64::MAX;

/// KVS build parameters (paper §5.5: 5,120,000 entries, uniform buckets;
/// chain length controlled by the bucket count).
#[derive(Clone, Debug)]
pub struct KvsSpec {
    pub entries: u64,
    /// Chain length (entries / buckets); buckets forced to a power of two.
    pub chain_len: u64,
    pub seed: u64,
}

/// The built KVS: layout info + the key set for lookups.
#[derive(Clone, Debug)]
pub struct KvsLayout {
    pub base: LineAddr,
    pub n_buckets: u64,
    pub bucket_mask: i32,
    /// first entry line
    pub entries_base: LineAddr,
    pub entries: u64,
    pub chain_len: u64,
    /// For each bucket, the key of the LAST entry in its chain (the
    /// paper searches for the last key to force a known-length chase).
    pub tail_keys: Vec<i32>,
}

/// Build a separate-chaining hash table. Entries are assigned to buckets
/// by the *same* multiplicative hash the kernel computes, guaranteeing
/// agreement between the dispatcher and the data structure. Keys are
/// chosen per bucket (by rejection) so every bucket holds exactly
/// `chain_len` entries — the paper's "uniformly distributed" fill with a
/// controlled chain length.
pub fn build_kvs(spec: &KvsSpec, store: &mut MemStore) -> KvsLayout {
    let n_buckets = (spec.entries / spec.chain_len).next_power_of_two() / 2;
    let n_buckets = n_buckets.max(1);
    let bucket_mask = (n_buckets - 1) as i32;
    let bucket_lines = n_buckets.div_ceil(16);
    let total_entries = n_buckets * spec.chain_len;
    assert!(
        store.len_lines() >= bucket_lines + total_entries,
        "store too small: need {} lines",
        bucket_lines + total_entries
    );

    let base = store.base();
    let entries_base = LineAddr(base.0 + bucket_lines);
    let mut rng = Rng::new(spec.seed);
    let mut tail_keys = vec![0i32; n_buckets as usize];
    let mut next_entry = 0u64;

    // Draw-and-place: generate random keys and drop each into its natural
    // bucket until every bucket holds exactly `chain_len` keys (expected
    // O(total + B log B) draws; per-bucket rejection sampling would be
    // O(B) per key). Duplicate keys are rejected via the fill state: a
    // duplicate lands in a full... no — dedup with a HashSet, cheap at
    // this scale.
    let mut bucket_keys: Vec<Vec<i32>> = vec![Vec::with_capacity(spec.chain_len as usize); n_buckets as usize];
    let mut used_keys = std::collections::HashSet::new();
    let mut unfilled = n_buckets;
    while unfilled > 0 {
        let k = rng.next_u32() as i32;
        let b = hash_bucket_ref(k, bucket_mask) as usize;
        if bucket_keys[b].len() >= spec.chain_len as usize || !used_keys.insert(k) {
            continue;
        }
        bucket_keys[b].push(k);
        if bucket_keys[b].len() == spec.chain_len as usize {
            unfilled -= 1;
        }
    }

    for bucket in 0..n_buckets {
        let mut head = NULL_PTR;
        for (pos, &key) in bucket_keys[bucket as usize].iter().enumerate() {
            let line_no = entries_base.0 + next_entry;
            next_entry += 1;
            let mut line = [0u8; LINE_BYTES];
            line[0..8].copy_from_slice(&(key as u32 as u64).to_le_bytes());
            for (j, b) in line[8..120].iter_mut().enumerate() {
                *b = (key as u32).wrapping_add(j as u32) as u8;
            }
            line[120..128].copy_from_slice(&head.to_le_bytes());
            store.write_line(LineAddr(line_no), &line);
            head = line_no;
            // entries are prepended: the first inserted ends up at the tail
            if pos == 0 {
                tail_keys[bucket as usize] = key;
            }
        }
        // write head pointer into the bucket array
        let bline = base.0 + bucket / 16;
        let boff = ((bucket % 16) * 8) as usize;
        let mut l = store.read_line(LineAddr(bline));
        l[boff..boff + 8].copy_from_slice(&head.to_le_bytes());
        store.write_line(LineAddr(bline), &l);
    }

    KvsLayout {
        base,
        n_buckets,
        bucket_mask,
        entries_base,
        entries: total_entries,
        chain_len: spec.chain_len,
        tail_keys,
    }
}

/// Walk a chain for `key` (the functional lookup both the FPGA engines
/// and the CPU baseline perform). Returns (value-line-address, hops).
pub fn kvs_lookup(store: &MemStore, layout: &KvsLayout, key: i32) -> (Option<LineAddr>, u64) {
    let bucket = hash_bucket_ref(key, layout.bucket_mask) as u64;
    let bline = layout.base.0 + bucket / 16;
    let boff = ((bucket % 16) * 8) as usize;
    let l = store.read_line(LineAddr(bline));
    let mut ptr = u64::from_le_bytes(l[boff..boff + 8].try_into().unwrap());
    let mut hops = 1; // the bucket read
    while ptr != NULL_PTR {
        let e = store.read_line(LineAddr(ptr));
        hops += 1;
        let k = u64::from_le_bytes(e[0..8].try_into().unwrap()) as u32 as i32;
        if k == key {
            return (Some(LineAddr(ptr)), hops);
        }
        ptr = u64::from_le_bytes(e[120..128].try_into().unwrap());
    }
    (None, hops)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_realizes_exact_select_selectivity() {
        let rows = 10_000;
        let spec = TableSpec::new(rows, 0.10);
        let mut store = MemStore::new(LineAddr(1 << 20), (rows as usize) * LINE_BYTES);
        build_table(&spec, &mut store);
        let (x, y) = select_params(0.10);
        let mut hits = 0;
        for i in 0..rows {
            let l = store.read_line(LineAddr((1 << 20) + i));
            let (a, b) = row_ab(&l);
            if a > x && b < y {
                hits += 1;
            }
        }
        let realized = hits as f64 / rows as f64;
        assert!(
            (realized - 0.10).abs() < 0.02,
            "realized select selectivity {realized}"
        );
    }

    #[test]
    fn table_realizes_regex_selectivity_exactly() {
        let rows = 5_000;
        let spec = TableSpec::new(rows, 0.25);
        let mut store = MemStore::new(LineAddr(0), (rows as usize) * LINE_BYTES);
        build_table(&spec, &mut store);
        let dfa = crate::operators::redfa::compile_regex(&spec.needle, 32).unwrap();
        let mut hits = 0;
        for i in 0..rows {
            let l = store.read_line(LineAddr(i));
            if dfa.matches(row_str(&l)) {
                hits += 1;
            }
        }
        assert_eq!(hits, (rows as f64 * 0.25).round() as u64, "regex selectivity must be exact");
    }

    #[test]
    fn kvs_chains_have_exact_length_and_tails_resolve() {
        let spec = KvsSpec { entries: 4096, chain_len: 4, seed: 7 };
        let mut store = MemStore::new(LineAddr(0), 3 * 4096 * LINE_BYTES);
        let layout = build_kvs(&spec, &mut store);
        assert!(layout.n_buckets.is_power_of_two());
        // every tail key is found after exactly chain_len entry hops
        for (bucket, &key) in layout.tail_keys.iter().enumerate().step_by(17) {
            let (found, hops) = kvs_lookup(&store, &layout, key);
            assert!(found.is_some(), "bucket {bucket} tail missing");
            // hops = 1 bucket read + chain_len entries
            assert_eq!(hops, 1 + layout.chain_len, "bucket {bucket}");
        }
    }

    #[test]
    fn kvs_missing_key_walks_whole_chain() {
        let spec = KvsSpec { entries: 1024, chain_len: 2, seed: 3 };
        let mut store = MemStore::new(LineAddr(0), 2048 * LINE_BYTES);
        let layout = build_kvs(&spec, &mut store);
        // find a key that's not in the table
        let mut k = 12345i32;
        while layout.tail_keys.contains(&k) {
            k += 1;
        }
        let (found, hops) = kvs_lookup(&store, &layout, k);
        assert!(found.is_none());
        assert_eq!(hops, 1 + layout.chain_len);
    }

    #[test]
    fn select_params_hit_target_in_expectation() {
        for s in [0.01, 0.1, 1.0] {
            let (x, y) = select_params(s);
            let p = (1.0 - x as f64) * y as f64;
            assert!((p - s).abs() < 1e-6, "s={s} p={p}");
        }
    }
}
