//! SELECT pushdown operator (paper §5.4): functional datapath.
//!
//! The FPGA datapath ("data flows from FPGA DRAM through the arithmetic
//! units into the CPU LLC") is computed by the AOT-compiled XLA kernel in
//! batches of 4096 rows; the CPU baseline is the scalar scan. Timing is
//! applied by the machine/memctl models — this module computes *what* the
//! operator produces, execution-driven, so every delivered row is
//! checkable.

use crate::agents::dram::MemStore;
use crate::anyhow;
use crate::proto::messages::LineAddr;
use crate::runtime::{Runtime, BATCH, ROW_WORDS};

use super::table::row_ab;

/// Scan `[first, first+rows)` with the XLA kernel; returns indices of
/// matching rows (relative to `first`).
pub fn fpga_select_scan(
    rt: &mut Runtime,
    store: &MemStore,
    first: LineAddr,
    rows: u64,
    x: f32,
    y: f32,
) -> anyhow::Result<Vec<u64>> {
    let mut matches = Vec::new();
    let mut buf = vec![0f32; BATCH * ROW_WORDS];
    let mut base = 0u64;
    while base < rows {
        let n = (rows - base).min(BATCH as u64) as usize;
        for r in 0..n {
            let line = store.read_line(LineAddr(first.0 + base + r as u64));
            for w in 0..ROW_WORDS {
                buf[r * ROW_WORDS + w] =
                    f32::from_le_bytes(line[w * 4..w * 4 + 4].try_into().unwrap());
            }
        }
        // pad the tail so padded rows never match (a = -inf fails a > X)
        for r in n..BATCH {
            buf[r * ROW_WORDS] = f32::NEG_INFINITY;
            buf[r * ROW_WORDS + 1] = f32::INFINITY;
        }
        let (mask, _count) = rt.select(&buf, x, y)?;
        for (r, &m) in mask.iter().enumerate().take(n) {
            if m == 1 {
                matches.push(base + r as u64);
            }
        }
        base += n as u64;
    }
    Ok(matches)
}

/// CPU baseline: scalar predicate scan (what the CPU-only curves of
/// Fig. 5 execute).
pub fn cpu_select_scan(
    store: &MemStore,
    first: LineAddr,
    rows: u64,
    x: f32,
    y: f32,
) -> Vec<u64> {
    let mut matches = Vec::new();
    for i in 0..rows {
        let line = store.read_line(LineAddr(first.0 + i));
        let (a, b) = row_ab(&line);
        if a > x && b < y {
            matches.push(i);
        }
    }
    matches
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operators::table::{build_table, select_params, TableSpec};
    use crate::proto::messages::LINE_BYTES;

    #[test]
    fn fpga_and_cpu_scans_agree_exactly() {
        // the native executor needs no artifacts; the PJRT path does
        if cfg!(feature = "xla")
            && !crate::runtime::Manifest::default_dir().join("manifest.json").exists()
        {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut rt = Runtime::load_default().unwrap();
        let rows = 10_000u64; // exercises batch padding (not a multiple of 4096)
        let spec = TableSpec::new(rows, 0.13);
        let mut store = MemStore::new(LineAddr(64), rows as usize * LINE_BYTES);
        build_table(&spec, &mut store);
        let (x, y) = select_params(0.13);
        let fpga = fpga_select_scan(&mut rt, &store, LineAddr(64), rows, x, y).unwrap();
        let cpu = cpu_select_scan(&store, LineAddr(64), rows, x, y);
        assert_eq!(fpga, cpu);
        let sel = fpga.len() as f64 / rows as f64;
        assert!((sel - 0.13).abs() < 0.02, "selectivity {sel}");
    }
}
