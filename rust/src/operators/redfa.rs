//! Regex -> DFA compiler (Rust mirror of `python/compile/redfa.py`).
//!
//! The FPGA regex operator needs per-pattern DFA tensors at *runtime*
//! (patterns arrive with queries; the AOT kernel takes the transition
//! matrices as inputs precisely so one artifact serves every pattern).
//! This compiler produces exactly the same DFAs as the Python one — same
//! parser, same Thompson construction, same subset construction with an
//! absorbing match sink — so build-time (Python-tested) and run-time
//! (Rust) semantics coincide; `tests/cross_dfa.rs` pins the equivalence
//! against the `regex` crate.
//!
//! Search semantics: the start state self-loops on every byte (".*"
//! prefix) and accept states absorb (".*" suffix), so running the DFA
//! over the whole fixed-length field answers "contains a match".

use std::collections::HashMap;

use crate::anyhow::{bail, Result};

pub const ALPHABET: usize = 256;

// ---------------------------------------------------------------------------
// AST + parser
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
enum Ast {
    Empty,
    Class(Vec<bool>), // 256 flags
    Cat(Vec<Ast>),
    Alt(Vec<Ast>),
    Star(Box<Ast>),
    Plus(Box<Ast>),
    Opt(Box<Ast>),
}

struct Parser<'a> {
    p: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.p.get(self.i).copied()
    }
    fn take(&mut self) -> Option<u8> {
        let c = self.peek();
        self.i += 1;
        c
    }

    fn parse(&mut self) -> Result<Ast> {
        let node = self.alternation()?;
        if self.peek().is_some() {
            bail!("unexpected {:?} at {}", self.peek().unwrap() as char, self.i);
        }
        Ok(node)
    }

    fn alternation(&mut self) -> Result<Ast> {
        let mut branches = vec![self.concat()?];
        while self.peek() == Some(b'|') {
            self.take();
            branches.push(self.concat()?);
        }
        Ok(if branches.len() > 1 { Ast::Alt(branches) } else { branches.pop().unwrap() })
    }

    fn concat(&mut self) -> Result<Ast> {
        let mut parts = Vec::new();
        while !matches!(self.peek(), None | Some(b'|') | Some(b')')) {
            parts.push(self.repeat()?);
        }
        Ok(match parts.len() {
            0 => Ast::Empty,
            1 => parts.pop().unwrap(),
            _ => Ast::Cat(parts),
        })
    }

    fn repeat(&mut self) -> Result<Ast> {
        let mut node = self.atom()?;
        while let Some(op) = self.peek() {
            node = match op {
                b'*' => Ast::Star(Box::new(node)),
                b'+' => Ast::Plus(Box::new(node)),
                b'?' => Ast::Opt(Box::new(node)),
                _ => break,
            };
            self.take();
        }
        Ok(node)
    }

    fn atom(&mut self) -> Result<Ast> {
        let Some(c) = self.take() else { bail!("unexpected end of pattern") };
        match c {
            b'(' => {
                let node = self.alternation()?;
                if self.take() != Some(b')') {
                    bail!("unbalanced parenthesis");
                }
                Ok(node)
            }
            b'[' => Ok(Ast::Class(self.char_class()?)),
            b'.' => Ok(Ast::Class(vec![true; ALPHABET])),
            b'\\' => Ok(Ast::Class(escape_class(self.take())?)),
            b'*' | b'+' | b'?' | b')' | b'|' => bail!("misplaced {:?}", c as char),
            c => {
                let mut f = vec![false; ALPHABET];
                f[c as usize] = true;
                Ok(Ast::Class(f))
            }
        }
    }

    fn char_class(&mut self) -> Result<Vec<bool>> {
        let mut negate = false;
        if self.peek() == Some(b'^') {
            self.take();
            negate = true;
        }
        let mut flags = vec![false; ALPHABET];
        let mut first = true;
        loop {
            let Some(c) = self.take() else { bail!("unterminated character class") };
            if c == b']' && !first {
                break;
            }
            first = false;
            if c == b'\\' {
                for (i, f) in escape_class(self.take())?.iter().enumerate() {
                    flags[i] |= f;
                }
                continue;
            }
            if self.peek() == Some(b'-') && !matches!(self.p.get(self.i + 1), None | Some(b']')) {
                self.take(); // '-'
                let hi = self.take().unwrap();
                for x in c..=hi {
                    flags[x as usize] = true;
                }
            } else {
                flags[c as usize] = true;
            }
        }
        if negate {
            for f in flags.iter_mut() {
                *f = !*f;
            }
        }
        Ok(flags)
    }
}

fn escape_class(c: Option<u8>) -> Result<Vec<bool>> {
    let Some(c) = c else { bail!("dangling escape") };
    let mut f = vec![false; ALPHABET];
    match c {
        b'd' => (b'0'..=b'9').for_each(|x| f[x as usize] = true),
        b'w' => {
            (b'a'..=b'z').for_each(|x| f[x as usize] = true);
            (b'A'..=b'Z').for_each(|x| f[x as usize] = true);
            (b'0'..=b'9').for_each(|x| f[x as usize] = true);
            f[b'_' as usize] = true;
        }
        b's' => b" \t\r\n\x0c\x0b".iter().for_each(|&x| f[x as usize] = true),
        c => f[c as usize] = true,
    }
    Ok(f)
}

// ---------------------------------------------------------------------------
// Thompson NFA
// ---------------------------------------------------------------------------

struct Nfa {
    eps: Vec<Vec<usize>>,
    edges: Vec<Vec<(usize, usize)>>, // state -> [(char, next)] (sparse)
}

impl Nfa {
    fn new_state(&mut self) -> usize {
        self.eps.push(Vec::new());
        self.edges.push(Vec::new());
        self.eps.len() - 1
    }

    fn build(&mut self, node: &Ast) -> (usize, usize) {
        match node {
            Ast::Empty => {
                let s = self.new_state();
                (s, s)
            }
            Ast::Class(flags) => {
                let a = self.new_state();
                let b = self.new_state();
                for (c, &on) in flags.iter().enumerate() {
                    if on {
                        self.edges[a].push((c, b));
                    }
                }
                (a, b)
            }
            Ast::Cat(parts) => {
                let (first_in, mut prev_out) = self.build(&parts[0]);
                for part in &parts[1..] {
                    let (pin, pout) = self.build(part);
                    self.eps[prev_out].push(pin);
                    prev_out = pout;
                }
                (first_in, prev_out)
            }
            Ast::Alt(branches) => {
                let a = self.new_state();
                let b = self.new_state();
                for branch in branches {
                    let (bin, bout) = self.build(branch);
                    self.eps[a].push(bin);
                    self.eps[bout].push(b);
                }
                (a, b)
            }
            Ast::Star(inner) | Ast::Plus(inner) | Ast::Opt(inner) => {
                let (iin, iout) = self.build(inner);
                let a = self.new_state();
                let b = self.new_state();
                self.eps[a].push(iin);
                self.eps[iout].push(b);
                if matches!(node, Ast::Star(_) | Ast::Opt(_)) {
                    self.eps[a].push(b);
                }
                if matches!(node, Ast::Star(_) | Ast::Plus(_)) {
                    self.eps[iout].push(iin);
                }
                (a, b)
            }
        }
    }

    fn eps_closure(&self, states: &mut Vec<usize>) {
        let mut seen: Vec<bool> = vec![false; self.eps.len()];
        for &s in states.iter() {
            seen[s] = true;
        }
        let mut stack = states.clone();
        while let Some(s) = stack.pop() {
            for &t in &self.eps[s] {
                if !seen[t] {
                    seen[t] = true;
                    states.push(t);
                    stack.push(t);
                }
            }
        }
        states.sort_unstable();
        states.dedup();
    }
}

// ---------------------------------------------------------------------------
// DFA
// ---------------------------------------------------------------------------

/// Dense search-semantics DFA; state 0 initial.
#[derive(Clone, Debug)]
pub struct Dfa {
    pub pattern: String,
    /// `[n_states * 256]` next-state table.
    pub table: Vec<u16>,
    /// `[n_states]` accept flags.
    pub accept: Vec<bool>,
}

impl Dfa {
    pub fn n_states(&self) -> usize {
        self.accept.len()
    }

    /// Does `data` contain a match?
    #[inline]
    pub fn matches(&self, data: &[u8]) -> bool {
        let mut s = 0usize;
        for &ch in data {
            s = self.table[s * ALPHABET + ch as usize] as usize;
        }
        self.accept[s]
    }

    /// One-hot transition tensor `[256 * S * S]` f32, padded to `s` states
    /// (the AOT kernel's fixed S); padding states self-loop.
    pub fn onehot_tmat(&self, s: usize) -> Vec<f32> {
        assert!(s >= self.n_states(), "DFA has {} states > padded {s}", self.n_states());
        let mut t = vec![0f32; ALPHABET * s * s];
        for st in 0..self.n_states() {
            for c in 0..ALPHABET {
                let nxt = self.table[st * ALPHABET + c] as usize;
                t[c * s * s + st * s + nxt] = 1.0;
            }
        }
        for st in self.n_states()..s {
            for c in 0..ALPHABET {
                t[c * s * s + st * s + st] = 1.0;
            }
        }
        t
    }

    /// Accept vector `[s]` f32.
    pub fn accept_vec(&self, s: usize) -> Vec<f32> {
        let mut v = vec![0f32; s];
        for (i, &a) in self.accept.iter().enumerate() {
            v[i] = a as u32 as f32;
        }
        v
    }
}

/// Compile `pattern` with at most `max_states` DFA states.
pub fn compile_regex(pattern: &str, max_states: usize) -> Result<Dfa> {
    let ast = Parser { p: pattern.as_bytes(), i: 0 }.parse()?;
    let mut nfa = Nfa { eps: Vec::new(), edges: Vec::new() };
    let (entry, exit) = nfa.build(&ast);
    // search semantics: ".*" prefix via a self-looping start
    let start = nfa.new_state();
    nfa.eps[start].push(entry);
    for c in 0..ALPHABET {
        nfa.edges[start].push((c, start));
    }

    let mut start_set = vec![start];
    nfa.eps_closure(&mut start_set);

    let mut index: HashMap<Vec<usize>, usize> = HashMap::new();
    index.insert(start_set.clone(), 0);
    let mut worklist = std::collections::VecDeque::from([start_set]);
    let mut rows: Vec<[u16; ALPHABET]> = Vec::new();
    let mut accept: Vec<bool> = Vec::new();
    let mut matched_sink: Option<usize> = None;

    while let Some(cur) = worklist.pop_front() {
        let cur_idx = rows.len();
        rows.push([0u16; ALPHABET]);
        let is_accept = cur.contains(&exit);
        accept.push(is_accept);
        if is_accept {
            // absorbing accept
            rows[cur_idx] = [cur_idx as u16; ALPHABET];
            continue;
        }
        for c in 0..ALPHABET {
            let mut nxt: Vec<usize> = Vec::new();
            for &s in &cur {
                for &(ec, et) in &nfa.edges[s] {
                    if ec == c {
                        nxt.push(et);
                    }
                }
            }
            nfa.eps_closure(&mut nxt);
            if nxt.contains(&exit) {
                let sink = match matched_sink {
                    Some(s) => s,
                    None => {
                        let sink_set = vec![exit];
                        let s = if let Some(&s) = index.get(&sink_set) {
                            s
                        } else {
                            let s = index.len();
                            index.insert(sink_set.clone(), s);
                            worklist.push_back(sink_set);
                            s
                        };
                        matched_sink = Some(s);
                        s
                    }
                };
                rows[cur_idx][c] = sink as u16;
                continue;
            }
            let next_idx = match index.get(&nxt) {
                Some(&i) => i,
                None => {
                    if index.len() >= max_states {
                        bail!("pattern {pattern:?} needs more than {max_states} DFA states");
                    }
                    let i = index.len();
                    index.insert(nxt.clone(), i);
                    worklist.push_back(nxt);
                    i
                }
            };
            rows[cur_idx][c] = next_idx as u16;
        }
    }

    Ok(Dfa {
        pattern: pattern.to_string(),
        table: rows.into_iter().flatten().collect(),
        accept,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn search(pattern: &str, data: &[u8]) -> bool {
        compile_regex(pattern, 32).unwrap().matches(data)
    }

    /// Oracle sweep against the external `regex` crate. The crate is not
    /// in the offline registry, so this is compiled only when a vendored
    /// copy is available (`--features regex-oracle`); the pinned-case
    /// test below covers the same semantics without the dependency.
    #[cfg(feature = "regex-oracle")]
    #[test]
    fn matches_regex_crate_on_cases() {
        let patterns = [
            "abc", "a|b", "ab*c", "a+", "(ab)+", "a?b", "[abc]", "[a-c]x", "[^a]b", "a.c",
            "x(y|z)*w", r"\d\d", r"\w+", "a[0-9]+b", "(a|b)(c|d)",
        ];
        let inputs: Vec<&[u8]> = vec![
            b"", b"a", b"b", b"ab", b"abc", b"xabcz", b"aaab", b"a0b", b"a99b", b"xyzw",
            b"xyyzw", b"bd", b"ac", b"12", b"hello_world", b"a c", b"zb", b"cx",
        ];
        for p in patterns {
            let re = regex::bytes::Regex::new(p).unwrap();
            for &i in &inputs {
                assert_eq!(search(p, i), re.is_match(i), "pattern {p:?} input {i:?}");
            }
        }
    }

    /// Hand-pinned oracle cases (contains-match semantics), mirroring
    /// what the `regex`-crate sweep checks without needing the crate.
    #[test]
    fn matches_pinned_oracle_cases() {
        let cases: [(&str, &[u8], bool); 16] = [
            ("abc", b"xabcz", true),
            ("abc", b"ab", false),
            ("a|b", b"", false),
            ("a|b", b"b", true),
            ("ab*c", b"ac", true),
            ("ab*c", b"abbbc", true),
            ("ab*c", b"abb", false),
            ("a+", b"aaab", true),
            ("a+", b"b", false),
            ("(ab)+", b"abab", true),
            ("(ab)+", b"ba", false),
            ("a?b", b"b", true),
            ("[a-c]x", b"cx", true),
            ("[^a]b", b"zb", true),
            ("[^a]b", b"ab", false),
            (r"\d\d", b"a99b", true),
        ];
        for (p, input, want) in cases {
            assert_eq!(search(p, input), want, "pattern {p:?} input {input:?}");
        }
    }

    #[test]
    fn search_semantics_match_anywhere() {
        assert!(search("err+or", b"xx errror yy"));
        assert!(!search("err+or", b"eror"));
        assert!(search("abc", b"abc"));
        assert!(search("abc", b"zzabczz"));
    }

    #[test]
    fn accept_absorbing_and_padding_stochastic() {
        let dfa = compile_regex("ab", 32).unwrap();
        for s in 0..dfa.n_states() {
            if dfa.accept[s] {
                for c in 0..ALPHABET {
                    assert_eq!(dfa.table[s * ALPHABET + c] as usize, s);
                }
            }
        }
        let t = dfa.onehot_tmat(32);
        // every (char, state) row one-hot
        for c in 0..ALPHABET {
            for st in 0..32 {
                let sum: f32 = (0..32).map(|n| t[c * 32 * 32 + st * 32 + n]).sum();
                assert_eq!(sum, 1.0, "char {c} state {st}");
            }
        }
    }

    #[test]
    fn state_budget_enforced() {
        assert!(compile_regex("(a|b)*a(a|b)(a|b)(a|b)(a|b)(a|b)(a|b)", 32).is_err());
    }

    #[test]
    fn rejects_malformed_patterns() {
        for p in ["(", ")", "a)", "[", "a**b(", "*a", "a|*"] {
            assert!(compile_regex(p, 32).is_err(), "{p:?} should fail");
        }
    }

    #[test]
    fn nul_bytes_behave_like_any_byte() {
        // fields are NUL-padded; patterns over printable chars must not
        // match into padding accidentally
        assert!(!search("ab", b"a\0b"));
        assert!(search("a.b", b"a\0b")); // '.' matches NUL, like Python re
    }
}
