//! Pointer-chasing KVS operator (paper §5.5): functional datapath.
//!
//! The FPGA path hashes request keys in batches through the AOT XLA
//! kernel (the dispatcher of Fig. 4 fans requests out to 32 engines by
//! bucket), then chases the chain in FPGA DRAM; the CPU baseline performs
//! the identical lookup against local memory.

use crate::agents::dram::MemStore;
use crate::anyhow;
use crate::runtime::{Runtime, BATCH};

use super::table::{kvs_lookup, KvsLayout};

/// Hash a batch of keys through the XLA kernel (padding the tail).
pub fn fpga_hash_batch(rt: &mut Runtime, keys: &[i32], bucket_mask: i32) -> anyhow::Result<Vec<i32>> {
    let mut out = Vec::with_capacity(keys.len());
    let mut base = 0usize;
    let mut buf = vec![0i32; BATCH];
    while base < keys.len() {
        let n = (keys.len() - base).min(BATCH);
        buf[..n].copy_from_slice(&keys[base..base + n]);
        buf[n..].fill(0);
        let buckets = rt.hash(&buf, bucket_mask)?;
        out.extend_from_slice(&buckets[..n]);
        base += n;
    }
    Ok(out)
}

/// Full lookup result: hops = dependent DRAM accesses performed (bucket
/// read + entries visited), which drives the Fig. 6 timing model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Lookup {
    pub found: bool,
    pub hops: u64,
}

/// FPGA engine lookup (functionally identical to the CPU baseline; the
/// two differ in the *timing* model applied by the machine).
pub fn lookup(store: &MemStore, layout: &KvsLayout, key: i32) -> Lookup {
    let (found, hops) = kvs_lookup(store, layout, key);
    Lookup { found: found.is_some(), hops }
}

/// CPU baseline lookup.
pub fn cpu_lookup(store: &MemStore, layout: &KvsLayout, key: i32) -> Lookup {
    lookup(store, layout, key)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::hash_bucket_ref;
    use crate::operators::table::{build_kvs, KvsSpec};
    use crate::proto::messages::{LineAddr, LINE_BYTES};

    #[test]
    fn kernel_hash_routes_to_the_chain_that_holds_the_key() {
        // the native executor needs no artifacts; the PJRT path does
        if cfg!(feature = "xla")
            && !crate::runtime::Manifest::default_dir().join("manifest.json").exists()
        {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut rt = Runtime::load_default().unwrap();
        let spec = KvsSpec { entries: 8192, chain_len: 8, seed: 5 };
        let mut store = MemStore::new(LineAddr(0), 2 * 8192 * LINE_BYTES);
        let layout = build_kvs(&spec, &mut store);

        let keys: Vec<i32> = layout.tail_keys.iter().copied().take(500).collect();
        let buckets = fpga_hash_batch(&mut rt, &keys, layout.bucket_mask).unwrap();
        for (i, &k) in keys.iter().enumerate() {
            // kernel agrees with the reference hash used by the builder
            assert_eq!(buckets[i], hash_bucket_ref(k, layout.bucket_mask));
            // and the key is found at the end of that chain
            let r = lookup(&store, &layout, k);
            assert!(r.found);
            assert_eq!(r.hops, 1 + layout.chain_len, "key {k}");
        }
    }
}
