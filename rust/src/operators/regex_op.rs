//! Regex pushdown operator (paper §5.6): functional datapath.
//!
//! The FPGA path evaluates the query's DFA through the AOT XLA kernel
//! (one-hot transition-matrix products — see DESIGN.md §2); the CPU
//! baseline walks the same DFA table scalar-wise (standing in for the
//! paper's optimized software regex library, with the `regex` crate used
//! in tests as an independent oracle).

use crate::agents::dram::MemStore;
use crate::anyhow;
use crate::proto::messages::LineAddr;
use crate::runtime::{Runtime, BATCH, DFA_STATES, STR_LEN};

use super::redfa::Dfa;
use super::table::row_str;

/// Scan `[first, first+rows)` with the XLA kernel.
pub fn fpga_regex_scan(
    rt: &mut Runtime,
    store: &MemStore,
    first: LineAddr,
    rows: u64,
    dfa: &Dfa,
) -> anyhow::Result<Vec<u64>> {
    let tmat = dfa.onehot_tmat(DFA_STATES);
    let accept = dfa.accept_vec(DFA_STATES);
    rt.set_dfa(&tmat, &accept)?;
    let mut matches = Vec::new();
    let mut chars = vec![0i32; BATCH * STR_LEN];
    let mut base = 0u64;
    while base < rows {
        let n = (rows - base).min(BATCH as u64) as usize;
        for r in 0..n {
            let line = store.read_line(LineAddr(first.0 + base + r as u64));
            let s = row_str(&line);
            for (j, &c) in s.iter().enumerate() {
                chars[r * STR_LEN + j] = c as i32;
            }
        }
        for r in n..BATCH {
            // padding rows: all-NUL strings; only all-matching patterns
            // would hit, and those are filtered below by taking only n
            chars[r * STR_LEN..(r + 1) * STR_LEN].fill(0);
        }
        let (mask, _count) = rt.regex_batch(&chars)?;
        for (r, &m) in mask.iter().enumerate().take(n) {
            if m == 1 {
                matches.push(base + r as u64);
            }
        }
        base += n as u64;
    }
    Ok(matches)
}

/// CPU baseline: scalar DFA walk over each row's string field.
pub fn cpu_regex_scan(store: &MemStore, first: LineAddr, rows: u64, dfa: &Dfa) -> Vec<u64> {
    let mut matches = Vec::new();
    for i in 0..rows {
        let line = store.read_line(LineAddr(first.0 + i));
        if dfa.matches(row_str(&line)) {
            matches.push(i);
        }
    }
    matches
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operators::redfa::compile_regex;
    use crate::operators::table::{build_table, TableSpec};
    use crate::proto::messages::LINE_BYTES;

    #[test]
    fn fpga_cpu_and_regex_crate_agree() {
        // the native executor needs no artifacts; the PJRT path does
        if cfg!(feature = "xla")
            && !crate::runtime::Manifest::default_dir().join("manifest.json").exists()
        {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut rt = Runtime::load_default().unwrap();
        let rows = 6_000u64;
        let spec = TableSpec::new(rows, 0.08);
        let mut store = MemStore::new(LineAddr(0), rows as usize * LINE_BYTES);
        build_table(&spec, &mut store);
        let dfa = compile_regex(&spec.needle, DFA_STATES).unwrap();
        let fpga = fpga_regex_scan(&mut rt, &store, LineAddr(0), rows, &dfa).unwrap();
        let cpu = cpu_regex_scan(&store, LineAddr(0), rows, &dfa);
        assert_eq!(fpga, cpu);
        assert_eq!(fpga.len(), (rows as f64 * 0.08).round() as usize);
        oracle_check(&spec.needle, &store, rows, &fpga);
    }

    /// Independent oracle against the external `regex` crate — compiled
    /// only when a vendored copy is available (`--features regex-oracle`,
    /// not in the offline registry).
    #[cfg(feature = "regex-oracle")]
    fn oracle_check(needle: &str, store: &MemStore, rows: u64, fpga: &[u64]) {
        let re = regex::bytes::Regex::new(needle).unwrap();
        for i in 0..rows {
            let line = store.read_line(LineAddr(i));
            assert_eq!(re.is_match(row_str(&line)), fpga.binary_search(&i).is_ok(), "row {i}");
        }
    }

    #[cfg(not(feature = "regex-oracle"))]
    fn oracle_check(_needle: &str, _store: &MemStore, _rows: u64, _fpga: &[u64]) {}
}
