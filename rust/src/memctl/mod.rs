//! The smart memory controller (paper Fig. 3/4): the FPGA-side
//! application that terminates ECI requests and serves operator results
//! straight into the CPU's LLC.
//!
//! Functional results come from [`crate::operators`] (computed through
//! the AOT XLA kernels — execution-driven, every byte checkable); this
//! module supplies the *service/timing* models:
//!
//! * [`FifoServer`] — the SELECT/regex result FIFO: a fully-pipelined
//!   table scan whose progress is bounded by FPGA DRAM bandwidth and
//!   engine throughput, with finite-FIFO backpressure; multiple cores
//!   read the FIFO concurrently and receive results first-come
//!   first-served (§5.3.1).
//! * [`KvsService`] — the Fig. 4 multi-engine pointer-chase pool: a
//!   dispatcher fans requests out to N engines, each performing dependent
//!   DRAM granule accesses (512-bit interface, §5.3.2).
//! * [`ComputeRegion`] — the §5.7 temporal-locality server: an
//!   addressable result region where every miss pays the full recompute
//!   cost.
//! * [`ConfigBlock`] — the off-critical-path config module (query
//!   parameters, regex upload) accessed over the ECI I/O VCs.

pub mod config_block;
pub mod fifo;
pub mod kvs_service;

pub use config_block::ConfigBlock;
pub use fifo::{regex_row_cycles, FifoServer, ScanTiming};
pub use kvs_service::{ComputeRegion, KvsService};
