//! The operator result FIFO (paper §5.3.1, Fig. 3).
//!
//! "The operator performs a table scan when triggered by a read from the
//! CPU to a FIFO address, and returns matching rows in order upon
//! receiving further reads. Multiple cores may safely read the FIFO
//! concurrently once the scan is initiated, and will receive interleaved
//! results. Matched rows are pushed to an output FIFO and returned on a
//! first-come first-served basis. The operator is fully pipelined."
//!
//! Timing model: the scan is an open-loop pipeline; result `k` becomes
//! available at `start + pipeline_offset[k]`, where the offset is the max
//! of the DRAM-feed time and the engine-compute time for the row that
//! produced it, except that a finite FIFO applies backpressure: the scan
//! can run at most `fifo_cap` results ahead of delivery.

use crate::proto::messages::Line;
use crate::sim::time::{Duration, Time};

/// Scan-rate parameters for offset precomputation.
#[derive(Clone, Copy, Debug)]
pub struct ScanTiming {
    /// Sustained FPGA DRAM feed, bytes/second (the scan streams rows).
    pub dram_bytes_per_sec: f64,
    /// Number of parallel compute engines.
    pub engines: u32,
    /// Engine clock.
    pub engine_hz: f64,
}

impl ScanTiming {
    /// Enzian FPGA defaults: 2ch DDR4-2400 at ~85% streaming efficiency,
    /// engines at 300 MHz.
    pub fn enzian(engines: u32) -> ScanTiming {
        ScanTiming {
            dram_bytes_per_sec: 38.4e9 * 0.85,
            engines,
            engine_hz: 300e6,
        }
    }
}

/// One operator's result FIFO.
pub struct FifoServer {
    /// Ready offset (ps from scan start) of each result, pipeline-only
    /// (no backpressure).
    pipeline_ready: Vec<u64>,
    /// The actual result payloads (the matched rows).
    results: Vec<Box<Line>>,
    /// Source row index of each result (for verification).
    pub source_rows: Vec<u64>,
    /// FIFO capacity in results.
    fifo_cap: usize,
    /// Scan start time (set by the first FIFO read).
    started: Option<Time>,
    /// Next result to hand out.
    next: usize,
    /// Delivery time of each delivered result (for backpressure).
    delivered_at: Vec<Time>,
    /// Total DRAM bytes the scan moves (for utilization reporting).
    pub scan_bytes: u64,
}

impl FifoServer {
    /// Build from functional scan output.
    ///
    /// * `match_rows` — indices (within the scanned range) of matching
    ///   rows, ascending (from `operators::fpga_*_scan`).
    /// * `row_cycles` — per-row engine cost in cycles (e.g. 62 for the
    ///   regex engines, ~1 for select comparators); indexed by row.
    /// * `payloads` — the matched rows' data, same order as `match_rows`.
    pub fn new(
        total_rows: u64,
        match_rows: Vec<u64>,
        payloads: Vec<Box<Line>>,
        row_cycles: impl Fn(u64) -> u64,
        timing: ScanTiming,
        fifo_cap: usize,
    ) -> FifoServer {
        assert_eq!(match_rows.len(), payloads.len());
        // DRAM feed: row i available to engines at (i+1) * 128 / bw
        let ps_per_row_dram = 128.0 / timing.dram_bytes_per_sec * 1e12;
        // engines consume rows round-robin; engine e handles rows
        // e, e+E, ...; its time is the sum of its rows' cycles.
        let e = timing.engines as usize;
        let ps_per_cycle = 1e12 / timing.engine_hz;
        let mut engine_busy_ps = vec![0f64; e];
        let mut pipeline_ready = Vec::with_capacity(match_rows.len());
        let mut m = 0usize;
        for row in 0..total_rows {
            let eng = (row as usize) % e;
            let feed = (row + 1) as f64 * ps_per_row_dram;
            let start = engine_busy_ps[eng].max(feed);
            let done = start + row_cycles(row) as f64 * ps_per_cycle;
            engine_busy_ps[eng] = done;
            if m < match_rows.len() && match_rows[m] == row {
                pipeline_ready.push(done as u64);
                m += 1;
            }
        }
        assert_eq!(m, match_rows.len(), "match_rows out of range or unsorted");
        FifoServer {
            pipeline_ready,
            results: payloads,
            source_rows: match_rows,
            fifo_cap,
            started: None,
            next: 0,
            delivered_at: Vec::new(),
            scan_bytes: total_rows * 128,
        }
    }

    pub fn total_results(&self) -> usize {
        self.results.len()
    }
    pub fn remaining(&self) -> usize {
        self.results.len() - self.next
    }

    /// A FIFO read arrives at `now`. Returns `(ready_time, payload)` for
    /// the next result, or `None` if the scan is exhausted (the operator
    /// returns an end-marker line).
    pub fn pop(&mut self, now: Time) -> Option<(Time, Box<Line>)> {
        let start = *self.started.get_or_insert(now);
        if self.next >= self.results.len() {
            return None;
        }
        let k = self.next;
        self.next += 1;
        // pipeline readiness
        let mut ready = start + Duration(self.pipeline_ready[k]);
        // backpressure: result k could only have been produced once
        // result k - fifo_cap had been delivered (its slot freed)
        if k >= self.fifo_cap {
            let freed = self.delivered_at[k - self.fifo_cap];
            let stalled = freed + Duration(self.pipeline_ready[k].saturating_sub(self.pipeline_ready[k - self.fifo_cap]));
            ready = ready.max(stalled);
        }
        let t = ready.max(now);
        self.delivered_at.push(t);
        Some((t, self.results[k].clone()))
    }

    /// End-marker line (all 0xFF): tells the CPU the scan is done.
    pub fn end_marker() -> Box<Line> {
        Box::new([0xFF; 128])
    }
}

/// Per-row engine cycles for the regex operator: one char per cycle,
/// "mismatches terminate early" (§5.6) — the engine stops when the DFA
/// reaches the absorbing match state; a definitive non-match still walks
/// the whole field (the NFA circuit cannot know earlier).
pub fn regex_row_cycles(dfa: &crate::operators::redfa::Dfa, s: &[u8]) -> u64 {
    let mut st = 0usize;
    for (i, &ch) in s.iter().enumerate() {
        st = dfa.table[st * 256 + ch as usize] as usize;
        if dfa.accept[st] {
            return (i + 1) as u64;
        }
    }
    s.len() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line(v: u8) -> Box<Line> {
        Box::new([v; 128])
    }

    fn mk(total: u64, matches: Vec<u64>, cap: usize) -> FifoServer {
        let payloads = matches.iter().map(|&r| line(r as u8)).collect();
        FifoServer::new(
            total,
            matches,
            payloads,
            |_| 1,
            ScanTiming { dram_bytes_per_sec: 128.0 * 1e12, engines: 1, engine_hz: 1e12 },
            cap,
        )
    }

    #[test]
    fn results_come_out_in_scan_order_with_monotone_ready_times() {
        // 1 row/ps feed, 1 cycle/row at 1 THz
        let mut f = mk(100, vec![3, 10, 50], 64);
        let (t1, d1) = f.pop(Time(0)).unwrap();
        let (t2, d2) = f.pop(Time(0)).unwrap();
        let (t3, d3) = f.pop(Time(0)).unwrap();
        assert!(t1 <= t2 && t2 <= t3);
        assert_eq!(d1[0], 3);
        assert_eq!(d2[0], 10);
        assert_eq!(d3[0], 50);
        assert!(f.pop(Time(0)).is_none(), "scan exhausted");
    }

    #[test]
    fn dram_feed_bounds_ready_times() {
        let mut f = mk(1000, vec![999], 64);
        // row 999 cannot be ready before 1000 rows were fed at 1 row/ps
        let (t, _) = f.pop(Time(0)).unwrap();
        assert!(t.ps() >= 1000, "{t:?}");
    }

    #[test]
    fn backpressure_stalls_scan_when_fifo_full() {
        // tiny FIFO of 2; consumer reads late
        let mut f = mk(100, (0..50).collect(), 2);
        // consume the first two immediately; the third at t=1000000
        let (_, _) = f.pop(Time(0)).unwrap();
        let (_, _) = f.pop(Time(0)).unwrap();
        let (t3, _) = f.pop(Time(1_000_000)).unwrap();
        assert!(t3.ps() >= 1_000_000);
        // result 4 was blocked on slot freed by result 2 (k - cap = 2):
        let (t4, _) = f.pop(Time(1_000_000)).unwrap();
        assert!(t4 >= t3);
    }

    #[test]
    fn engine_parallelism_scales_compute_bound_scans() {
        let matches: Vec<u64> = (0..512).collect();
        let payloads: Vec<Box<Line>> = matches.iter().map(|&r| line(r as u8)).collect();
        let slow = FifoServer::new(
            512,
            matches.clone(),
            payloads.clone(),
            |_| 100,
            ScanTiming { dram_bytes_per_sec: 1e15, engines: 1, engine_hz: 1e9 },
            1 << 20,
        );
        let fast = FifoServer::new(
            512,
            matches,
            payloads,
            |_| 100,
            ScanTiming { dram_bytes_per_sec: 1e15, engines: 8, engine_hz: 1e9 },
            1 << 20,
        );
        let last_slow = *slow.pipeline_ready.last().unwrap();
        let last_fast = *fast.pipeline_ready.last().unwrap();
        let speedup = last_slow as f64 / last_fast as f64;
        assert!(speedup > 7.0 && speedup <= 8.01, "speedup {speedup}");
    }

    #[test]
    fn regex_early_termination_counts_cycles() {
        let dfa = crate::operators::redfa::compile_regex("ab", 32).unwrap();
        assert_eq!(regex_row_cycles(&dfa, b"abxxxx"), 2); // matched at char 2
        assert_eq!(regex_row_cycles(&dfa, b"xxxxab"), 6);
        assert_eq!(regex_row_cycles(&dfa, b"xxxxxx"), 6); // no match: full walk
    }
}
