//! Multi-engine pointer-chase service (paper §5.3.2, Fig. 4) and the
//! §5.7 recompute-on-read region.

use crate::agents::dram::Dram;
use crate::sim::time::{Duration, Time};

/// The parallel-operator pool: "ECI requests are fanned out by a central
/// dispatcher to many operators, each incorporating a DRAM controller."
///
/// Each lookup performs `hops` *dependent* accesses; the 512-bit DRAM
/// controller interface means each 128-byte entry costs two serialized
/// 64-byte granule round-trips (§5.3.2's ~640 MB/s single-engine bound).
pub struct KvsService {
    /// Engine free times (the dispatcher picks the earliest-free engine).
    engines: Vec<Time>,
    /// Requests served (stats).
    pub served: u64,
    /// Total dependent DRAM accesses issued.
    pub dram_accesses: u64,
}

/// DRAM granule per controller-interface transfer: 512 bits.
pub const GRANULE_BYTES: u64 = 64;

impl KvsService {
    pub fn new(engines: usize) -> KvsService {
        KvsService { engines: vec![Time::ZERO; engines], served: 0, dram_accesses: 0 }
    }

    pub fn n_engines(&self) -> usize {
        self.engines.len()
    }

    /// Submit a lookup needing `hops` dependent 128-byte entry reads at
    /// `now`; returns when the result is ready. The shared `dram` model
    /// carries cross-engine channel contention.
    pub fn submit(&mut self, now: Time, hops: u64, dram: &mut Dram) -> Time {
        // dispatcher: earliest-free engine
        let (idx, _) = self
            .engines
            .iter()
            .enumerate()
            .min_by_key(|(_, &t)| t)
            .expect("no engines");
        let mut t = self.engines[idx].max(now);
        // dependent chain: each 128B entry = 2 serialized 64B granules
        for h in 0..hops {
            // granule 1: full random-access latency via the shared model
            let addr = crate::proto::messages::LineAddr(
                0x4000_0000 + (self.served.wrapping_mul(2654435761) + h) * 977,
            );
            t = dram.read(t, addr);
            self.dram_accesses += 2;
            // granule 2 follows the first (row already open): short burst
            t = t + Duration::from_ns(3);
        }
        self.engines[idx] = t;
        self.served += 1;
        t
    }

    /// Earliest time any engine is free (for queue-depth accounting).
    pub fn earliest_free(&self) -> Time {
        *self.engines.iter().min().unwrap()
    }
}

/// The §5.7 temporal-locality experiment's FPGA side: an addressable
/// result region where every read recomputes the result ("computed at
/// great cost"): fixed per-line recompute latency plus a DRAM read,
/// pipelined across `engines`.
pub struct ComputeRegion {
    engines: Vec<Time>,
    pub recompute: Duration,
    pub served: u64,
}

impl ComputeRegion {
    pub fn new(engines: usize, recompute: Duration) -> ComputeRegion {
        ComputeRegion { engines: vec![Time::ZERO; engines], recompute, served: 0 }
    }

    pub fn submit(&mut self, now: Time, dram: &mut Dram, addr: crate::proto::messages::LineAddr) -> Time {
        let (idx, _) = self.engines.iter().enumerate().min_by_key(|(_, &t)| t).unwrap();
        let start = self.engines[idx].max(now);
        let after_dram = dram.read(start, addr);
        let done = after_dram + self.recompute;
        self.engines[idx] = done;
        self.served += 1;
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::dram::DramConfig;

    #[test]
    fn single_engine_chase_rate_near_paper_bound() {
        // §5.3.2: ~100 ns latency, 512 b interface -> ~640 MB/s/engine.
        let mut dram = Dram::new(DramConfig::fpga_enzian());
        let mut svc = KvsService::new(1);
        let n = 2_000u64;
        let mut done = Time(0);
        for _ in 0..n {
            done = svc.submit(done, 1, &mut dram);
        }
        let mbps = (n * 128) as f64 / done.as_secs() / 1e6;
        assert!(
            (900.0..1400.0).contains(&mbps),
            "single-engine chase {mbps} MB/s (128B entry over 2 granules ~ 110ns)"
        );
        // per-entry latency ~ miss + burst + granule2
        let ns_per = done.as_ns() / n as f64;
        assert!((100.0..125.0).contains(&ns_per), "{ns_per} ns/entry");
    }

    #[test]
    fn engines_scale_throughput_until_dram_saturates() {
        let rate = |engines: usize| {
            let mut dram = Dram::new(DramConfig::fpga_enzian());
            let mut svc = KvsService::new(engines);
            let n = 4_000u64;
            let mut last = Time(0);
            for i in 0..n {
                // open-loop arrivals at 1 ns spacing
                let t = Time(i * 1_000);
                last = last.max(svc.submit(t, 1, &mut dram));
            }
            n as f64 / last.as_secs()
        };
        let r1 = rate(1);
        let r8 = rate(8);
        let r32 = rate(32);
        assert!(r8 > 5.0 * r1, "8 engines {r8} vs 1 {r1}");
        assert!(r32 > r8, "32 engines {r32} vs 8 {r8}");
    }

    #[test]
    fn longer_chains_cost_proportionally_more() {
        let mut dram = Dram::new(DramConfig::fpga_enzian());
        let mut svc = KvsService::new(1);
        let t1 = svc.submit(Time(0), 1, &mut dram);
        let start = t1;
        let t8 = svc.submit(start, 8, &mut dram);
        let per_hop = (t8 - start).as_ns() / 8.0;
        let first = t1.as_ns();
        assert!((per_hop / first - 1.0).abs() < 0.3, "hop {per_hop} vs single {first}");
    }

    #[test]
    fn compute_region_serializes_on_engines() {
        let mut dram = Dram::new(DramConfig::fpga_enzian());
        let mut cr = ComputeRegion::new(1, Duration::from_ns(500));
        let a = crate::proto::messages::LineAddr(0x4000_0000);
        let t1 = cr.submit(Time(0), &mut dram, a);
        let t2 = cr.submit(Time(0), &mut dram, a);
        assert!(t1.as_ns() >= 600.0);
        assert!(t2 >= t1 + Duration::from_ns(500));
    }
}
